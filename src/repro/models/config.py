"""Model configuration covering all 10 assigned architectures.

One dataclass, many knobs; per-arch constructors live in ``repro.configs``.
Families:
  dense  — llama-style decoder (gemma/phi3/qwen3/deepseek-7b)
  moe    — DeepSeek V2/V3 (MLA attention + routed experts [+ MTP])
  hybrid — RecurrentGemma (RG-LRU + local attention, 1:2 pattern)
  ssm    — xLSTM (mLSTM/sLSTM blocks, no separate FFN)
  audio  — MusicGen (decoder over EnCodec codebook tokens; frontend stub)
  vlm    — Llama-3.2-Vision (interleaved cross-attention layers; vision stub)
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | hybrid | ssm | audio | vlm

    # core dims
    n_layers: int = 12
    d_model: int = 1024
    n_heads: int = 8
    n_kv_heads: int = 8
    head_dim: int = 128
    d_ff: int = 4096
    vocab: int = 32000

    # attention flavor
    attn_kind: str = "gqa"            # gqa | mla
    qk_norm: bool = False
    rope_theta: float = 10000.0
    sliding_window: Optional[int] = None   # local attention window (if any)
    attn_logit_softcap: Optional[float] = None

    # activations / norms
    activation: str = "swiglu"        # swiglu | geglu
    rmsnorm_eps: float = 1e-6
    embed_scale: bool = False         # gemma-style sqrt(d_model) embedding scale
    tie_embeddings: bool = True

    # MLA (DeepSeek V2/V3)
    q_lora_rank: int = 0              # 0 = dense q projection
    kv_lora_rank: int = 512
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    moe_top_k: int = 2
    moe_d_ff: int = 0                 # per-expert hidden dim
    first_k_dense: int = 0            # leading dense layers (DeepSeek)
    capacity_factor: float = 1.25
    # MTP (DeepSeek V3 multi-token prediction)
    mtp_depth: int = 0

    # hybrid (RecurrentGemma / Griffin): repeating layer pattern
    block_pattern: Tuple[str, ...] = ()    # e.g. ("rglru", "rglru", "local_attn")
    lru_width: int = 0                     # RG-LRU recurrence width
    conv_width: int = 4

    # ssm (xLSTM)
    slstm_every: int = 8              # every k-th block is sLSTM; rest mLSTM
    mlstm_proj_factor: float = 2.0
    slstm_proj_factor: float = 4.0 / 3.0

    # audio (MusicGen)
    n_codebooks: int = 0

    # vlm (Llama-3.2-Vision)
    cross_attn_every: int = 0         # every k-th layer is cross-attention
    vision_dim: int = 0
    n_vision_tokens: int = 0

    # numerics / execution
    dtype: str = "bfloat16"
    remat_policy: str = "nothing"     # nothing | dots | full(=no remat)
    scan_layers: bool = True
    attn_chunk: int = 512             # query-chunked exact attention (train/prefill)
    mlstm_chunk: int = 256            # chunkwise-parallel mLSTM chunk
    # beyond-paper serving/training knobs (see EXPERIMENTS.md §Perf)
    serve_quant: str = "none"         # none | int8 — int8 KV/latent cache decode
    attn_remat: bool = False          # flash-style recompute of attn chunks in bwd
    moe_groups: int = 0               # >0: EP-local grouped MoE dispatch (= data shards)

    # ------------------------------------------------------------------
    @property
    def q_dim(self) -> int:
        if self.attn_kind == "mla":
            return self.n_heads * (self.nope_head_dim + self.rope_head_dim)
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def mla_cache_dim(self) -> int:
        return self.kv_lora_rank + self.rope_head_dim

    def param_count(self) -> int:
        """Exact parameter count (drives MODEL_FLOPS = 6*N*D roofline term)."""
        d = self.d_model
        n = 0
        n += self.vocab * d                       # embed
        if not self.tie_embeddings:
            n += self.vocab * d
        per_layer = 0
        if self.family in ("dense", "moe", "audio", "vlm"):
            per_layer += self._attn_params()
            per_layer += 2 * d                    # 2 rmsnorm scales
        if self.family == "dense" or self.family == "audio" or self.family == "vlm":
            per_layer += 3 * d * self.d_ff
        n += self.n_layers * per_layer
        if self.family == "moe":
            dense_ff = 3 * d * self.d_ff
            moe_ff = (
                self.n_experts * 3 * d * self.moe_d_ff
                + self.n_shared_experts * 3 * d * self.moe_d_ff
                + d * self.n_experts                      # router
            )
            n += self.first_k_dense * dense_ff
            n += (self.n_layers - self.first_k_dense) * moe_ff
        if self.family == "vlm":
            # cross layers are already inside n_layers; count only the delta
            # (their wk/wv read vision_dim instead of d) + kv_norm + gate
            n_cross = self.n_layers // max(self.cross_attn_every, 1)
            n += n_cross * (2 * (self.vision_dim - d) * self.kv_dim
                            + self.vision_dim + 1)
        if self.family == "hybrid":
            pat = self.block_pattern
            n_groups = self.n_layers // len(pat)
            for kind in pat:
                if kind == "local_attn":
                    blk = self._attn_params()
                else:  # rglru
                    w = self.lru_width
                    blk = 2 * d * w + w * d + 2 * w * w // 1 + 4 * w  # proj + gates + conv
                blk += 3 * d * self.d_ff + 2 * d
                n += n_groups * blk
        if self.family == "ssm":
            dh = self.d_model // self.n_heads
            f = self.mlstm_proj_factor
            dm_in = int(d * f)
            mlstm_blk = (
                2 * d * dm_in             # up projections (2 branches)
                + 3 * dm_in * dm_in // self.n_heads  # per-head qkv (block-diag)
                + 2 * self.n_heads * dm_in  # i/f gate logits
                + dm_in * d               # down proj
                + self.conv_width * dm_in
                + 2 * d
            )
            sf = self.slstm_proj_factor
            ds_in = int(d * sf)
            slstm_blk = (
                4 * d * d + 4 * d * dh    # recurrent (block-diag) + input projections
                + d * ds_in + ds_in * d   # post up/down
                + 2 * d
            )
            n_slstm = self.n_layers // self.slstm_every
            n += (self.n_layers - n_slstm) * mlstm_blk + n_slstm * slstm_blk
        if self.family == "moe" and self.mtp_depth > 0:
            n += self.mtp_depth * (self._attn_params() + 3 * d * self.moe_d_ff * (
                self.n_shared_experts + 0) + 2 * d * d)
        return int(n)

    def _attn_params(self) -> int:
        d = self.d_model
        if self.attn_kind == "mla":
            n = 0
            if self.q_lora_rank:
                n += d * self.q_lora_rank + self.q_lora_rank * self.q_dim
            else:
                n += d * self.q_dim
            n += d * (self.kv_lora_rank + self.rope_head_dim)          # down + k_rope
            n += self.kv_lora_rank * self.n_heads * (self.nope_head_dim + self.v_head_dim)
            n += self.n_heads * self.v_head_dim * d                    # out
            return n
        return d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d

    def active_param_count(self) -> int:
        """Active params per token (MoE): 6*N_active*D roofline term."""
        if self.family != "moe":
            return self.param_count()
        full = self.param_count()
        moe_layers = self.n_layers - self.first_k_dense
        inactive_experts = self.n_experts - self.moe_top_k
        full -= moe_layers * inactive_experts * 3 * self.d_model * self.moe_d_ff
        return int(full)
