"""Token-choice top-k MoE with capacity-based dispatch (DeepSeek V2/V3 style).

Routing: softmax router -> per-token top-k experts, renormalized gates.
Dispatch: token-order priority; each expert accepts up to
C = ceil(T * k / E * capacity_factor) tokens, the rest are dropped (their
gate mass is simply lost, standard for capacity MoE). Dispatch/combine are
gather/scatter-free on the hot path: we build a slot->token index table and
use one gather in, one gather out — a formulation the SPMD partitioner
handles with all-gather on the token axis (baseline; the EP-local shard_map
variant is a §Perf hillclimb).

Shared experts (DeepSeek) are a dense gated MLP fused as one wide block.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import dense_init


def init_moe_params(key: jax.Array, cfg: ModelConfig, dtype) -> dict:
    d, E, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 7)
    p = {
        "router": dense_init(ks[0], (d, E), jnp.float32),
        "w_gate": jax.vmap(lambda k: dense_init(k, (d, f), dtype))(
            jax.random.split(ks[1], E)),
        "w_up": jax.vmap(lambda k: dense_init(k, (d, f), dtype))(
            jax.random.split(ks[2], E)),
        "w_down": jax.vmap(lambda k: dense_init(k, (f, d), dtype, fan_in=f))(
            jax.random.split(ks[3], E)),
    }
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        p["shared_gate"] = dense_init(ks[4], (d, fs), dtype)
        p["shared_up"] = dense_init(ks[5], (d, fs), dtype)
        p["shared_down"] = dense_init(ks[6], (fs, d), dtype, fan_in=fs)
    return p


def capacity(tokens: int, cfg: ModelConfig) -> int:
    c = int(tokens * cfg.moe_top_k / cfg.n_experts * cfg.capacity_factor)
    return max(8, -(-c // 8) * 8)  # round up to 8


def moe_ffn_ep(p: dict, x: jax.Array, cfg: ModelConfig, mesh) -> tuple[jax.Array, jax.Array]:
    """Expert-parallel dispatch via shard_map (beyond-paper §Perf variant).

    The baseline pjit formulation routes over *global* token indices, which
    the SPMD partitioner implements with token all-gathers across the data
    axis (O(T*d) bytes per MoE layer). Here routing/dispatch/combine run
    *locally* per (data x model) shard: every device routes its local tokens,
    computes only its local experts, and a single psum over 'model' combines
    expert contributions — the same wire cost as the TP all-reduce the layer
    already pays, removing the dispatch collectives entirely.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    ba = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    msize = mesh.shape["model"]
    E = cfg.n_experts
    assert E % msize == 0
    E_loc = E // msize

    def local_fn(router, w_gate, w_up, w_down, shared, x_loc):
        B, S, d = x_loc.shape
        T = B * S
        k = cfg.moe_top_k
        C = capacity(T, cfg)
        xf = x_loc.reshape(T, d)
        logits = xf.astype(jnp.float32) @ router
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, ids = jax.lax.top_k(probs, k)
        gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)
        me = jnp.mean(probs, axis=0)
        ce = jnp.mean(jax.nn.one_hot(ids[:, 0], E, dtype=jnp.float32), axis=0)
        aux = E * jnp.sum(me * ce)

        my = jax.lax.axis_index("model")
        ids_flat = ids.reshape(T * k)
        local = (ids_flat // E_loc) == my
        ids_local = jnp.where(local, ids_flat % E_loc, E_loc)
        onehot = jax.nn.one_hot(ids_local, E_loc + 1, dtype=jnp.int32)
        pos = jnp.cumsum(onehot, axis=0) - onehot
        pos_flat = jnp.sum(pos * onehot, axis=-1)
        keep = local & (pos_flat < C)
        dest = jnp.where(keep, ids_local * C + pos_flat, E_loc * C)
        token_of_choice = jnp.arange(T * k, dtype=jnp.int32) // k
        slot_token = jnp.zeros((E_loc * C + 1,), jnp.int32).at[dest].set(token_of_choice)
        slot_used = jnp.zeros((E_loc * C + 1,), x_loc.dtype).at[dest].set(1)
        slot_token, slot_used = slot_token[:-1], slot_used[:-1]

        x_disp = (xf[slot_token] * slot_used[:, None]).reshape(E_loc, C, d)
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", x_disp, w_gate)) * \
            jnp.einsum("ecd,edf->ecf", x_disp, w_up)
        y_e = jnp.einsum("ecf,efd->ecd", h, w_down).reshape(E_loc * C, d)
        y_choice = y_e[jnp.minimum(dest, E_loc * C - 1)]
        y_choice *= (keep[:, None] * gate_vals.reshape(T * k)[:, None]
                     ).astype(y_choice.dtype)
        y = jnp.sum(y_choice.reshape(T, k, d), axis=1)

        if shared is not None:
            sg, su, sd = shared      # column-sharded over 'model'
            y = y + (jax.nn.silu(xf @ sg) * (xf @ su)) @ sd
        y = jax.lax.psum(y, "model")    # combine experts + shared partials
        return y.reshape(B, S, d).astype(x_loc.dtype), aux

    shared = None
    shared_specs = None
    if cfg.n_shared_experts:
        shared = (p["shared_gate"], p["shared_up"], p["shared_down"])
        shared_specs = (P(None, "model"), P(None, "model"), P("model", None))

    fn = shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(), P("model", None, None), P("model", None, None),
                  P("model", None, None), shared_specs, P(ba, None, None)),
        out_specs=(P(ba, None, None), P()),
        check_rep=False)
    return fn(p["router"], p["w_gate"], p["w_up"], p["w_down"], shared, x)


def moe_ffn(p: dict, x: jax.Array, cfg: ModelConfig, mesh=None) -> tuple[jax.Array, jax.Array]:
    """x: [B, S, d] -> (y [B, S, d], aux_loss [])."""
    if cfg.moe_groups and mesh is not None:
        return moe_ffn_ep(p, x, cfg, mesh)
    B, S, d = x.shape
    T = B * S
    E, k = cfg.n_experts, cfg.moe_top_k
    C = capacity(T, cfg)
    xf = x.reshape(T, d)

    logits = xf.astype(jnp.float32) @ p["router"]               # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, ids = jax.lax.top_k(probs, k)                    # [T, k]
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # load-balance aux (Switch-style): E * sum_e f_e * p_e
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(ids[:, 0], E, dtype=jnp.float32), axis=0)
    aux = E * jnp.sum(me * ce)

    # --- capacity assignment, token-major priority over the k choices -----
    ids_flat = ids.reshape(T * k)                               # choice (t, j) at t*k+j
    onehot = jax.nn.one_hot(ids_flat, E, dtype=jnp.int32)       # [T*k, E]
    pos = jnp.cumsum(onehot, axis=0) - onehot                   # position within expert
    pos_flat = jnp.sum(pos * onehot, axis=-1)                   # [T*k]
    keep = pos_flat < C
    dest = jnp.where(keep, ids_flat * C + pos_flat, E * C)      # drop -> scratch slot

    token_of_choice = jnp.arange(T * k, dtype=jnp.int32) // k
    slot_token = jnp.zeros((E * C + 1,), jnp.int32).at[dest].set(token_of_choice)
    slot_used = jnp.zeros((E * C + 1,), x.dtype).at[dest].set(1)
    slot_token, slot_used = slot_token[:-1], slot_used[:-1]

    x_disp = xf[slot_token] * slot_used[:, None]                # [E*C, d]
    x_disp = x_disp.reshape(E, C, d)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", x_disp, p["w_gate"])) * \
        jnp.einsum("ecd,edf->ecf", x_disp, p["w_up"])
    y_e = jnp.einsum("ecf,efd->ecd", h, p["w_down"]).reshape(E * C, d)

    y_choice = y_e[jnp.minimum(dest, E * C - 1)]                # [T*k, d]
    y_choice *= (keep[:, None] * gate_vals.reshape(T * k)[:, None]).astype(y_choice.dtype)
    y = jnp.sum(y_choice.reshape(T, k, d), axis=1)

    if cfg.n_shared_experts:
        y = y + (jax.nn.silu(xf @ p["shared_gate"]) * (xf @ p["shared_up"])) @ p["shared_down"]
    return y.reshape(B, S, d).astype(x.dtype), aux
