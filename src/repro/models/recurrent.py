"""Recurrent blocks: RG-LRU (RecurrentGemma/Griffin) and xLSTM cells.

RG-LRU is a diagonal linear recurrence with input-dependent gates
    a_t = exp(-c * softplus(Lambda) * r_t),
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
so training uses jax.lax.associative_scan (log-depth, MXU-free but fully
parallel); decode is a single fused step.

mLSTM (matrix-memory LSTM) uses *chunkwise-parallel* evaluation: within a
chunk the contribution is an attention-like matmul with cumulative-gate
weights; across chunks a small scan propagates the stabilized state
(C~ = C * exp(-m), n~ = n * exp(-m), m). This is exact (same recurrence, all
exponents stabilized by the running max m) and keeps the FLOPs on the MXU —
the TPU-native adaptation of the recurrence.

sLSTM has a nonlinear h_{t-1} dependency (block-diagonal recurrent matrix),
so it scans sequentially by construction.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import dense_init

_LRU_C = 8.0


# ---------------------------------------------------------------------------
# RG-LRU
# ---------------------------------------------------------------------------

def init_rglru_params(key: jax.Array, cfg: ModelConfig, dtype) -> dict:
    d, w = cfg.d_model, cfg.lru_width
    ks = jax.random.split(key, 7)
    return {
        "w_gate_in": dense_init(ks[0], (d, w), dtype),     # gelu branch
        "w_in": dense_init(ks[1], (d, w), dtype),          # recurrent branch
        "conv_w": dense_init(ks[2], (cfg.conv_width, w), dtype, fan_in=cfg.conv_width),
        "wa": dense_init(ks[3], (w, w), dtype),            # recurrence gate
        "wx": dense_init(ks[4], (w, w), dtype),            # input gate
        "lam": jnp.asarray(jax.random.uniform(ks[5], (w,), jnp.float32, 2.0, 5.0)),
        "w_out": dense_init(ks[6], (w, d), dtype),
    }


def _causal_conv_train(v: jax.Array, conv_w: jax.Array) -> jax.Array:
    """Depthwise causal conv via shifted adds. v: [B, S, w]."""
    out = jnp.zeros_like(v)
    W = conv_w.shape[0]
    for j in range(W):
        shifted = jnp.pad(v, ((0, 0), (j, 0), (0, 0)))[:, : v.shape[1]]
        out = out + shifted * conv_w[W - 1 - j]
    return out


def _rglru_gates(p: dict, v: jax.Array, cfg: ModelConfig):
    r = jax.nn.sigmoid((v @ p["wa"]).astype(jnp.float32))
    i = jax.nn.sigmoid((v @ p["wx"]).astype(jnp.float32))
    log_a = -_LRU_C * jax.nn.softplus(p["lam"]) * r          # [B, ., w]
    a = jnp.exp(log_a)
    # sqrt(1 - a^2) with a = exp(log_a); clamp for fp safety
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return a, beta * i * v.astype(jnp.float32)


def rglru_train(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Full Griffin recurrent block over [B, S, d] (parallel scan)."""
    u = jax.nn.gelu((x @ p["w_gate_in"]), approximate=True)
    v = _causal_conv_train(x @ p["w_in"], p["conv_w"])
    a, b = _rglru_gates(p, v, cfg)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    y = h.astype(x.dtype)
    return (u * y) @ p["w_out"]


def rglru_prefill(p: dict, x: jax.Array, cfg: ModelConfig):
    """Like rglru_train but also returns the decode state at the last step."""
    u = jax.nn.gelu((x @ p["w_gate_in"]), approximate=True)
    v_pre = x @ p["w_in"]
    v = _causal_conv_train(v_pre, p["conv_w"])
    a, b = _rglru_gates(p, v, cfg)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    y = (u * h.astype(x.dtype)) @ p["w_out"]
    cw = cfg.conv_width - 1
    state = {"h": h[:, -1], "conv": v_pre[:, -cw:]}
    return y, state


def rglru_init_state(cfg: ModelConfig, B: int, dtype) -> dict:
    w = cfg.lru_width
    return {
        "h": jnp.zeros((B, w), jnp.float32),
        "conv": jnp.zeros((B, cfg.conv_width - 1, w), dtype),
    }


def rglru_decode(p: dict, x: jax.Array, state: dict, cfg: ModelConfig):
    """One-step Griffin block. x: [B, 1, d]."""
    u = jax.nn.gelu(x @ p["w_gate_in"], approximate=True)[:, 0]
    v_new = (x @ p["w_in"])[:, 0]                            # [B, w]
    hist = jnp.concatenate([state["conv"], v_new[:, None]], axis=1)
    v = jnp.einsum("bcw,cw->bw", hist, p["conv_w"])
    a, b = _rglru_gates(p, v, cfg)
    h = a * state["h"] + b
    y = (u * h.astype(x.dtype)) @ p["w_out"]
    return y[:, None], {"h": h, "conv": hist[:, 1:]}


# ---------------------------------------------------------------------------
# mLSTM (chunkwise parallel)
# ---------------------------------------------------------------------------

def init_mlstm_params(key: jax.Array, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    din = int(d * cfg.mlstm_proj_factor)
    H = cfg.n_heads
    ks = jax.random.split(key, 9)
    return {
        "w_up": dense_init(ks[0], (d, din), dtype),
        "w_z": dense_init(ks[1], (d, din), dtype),           # gate branch
        "conv_w": dense_init(ks[2], (cfg.conv_width, din), dtype, fan_in=cfg.conv_width),
        # per-head (block-diagonal) qkv, as in the xLSTM paper
        "wq": jax.vmap(lambda k: dense_init(k, (din // H, din // H), dtype))(
            jax.random.split(ks[3], H)),
        "wk": jax.vmap(lambda k: dense_init(k, (din // H, din // H), dtype))(
            jax.random.split(ks[4], H)),
        "wv": jax.vmap(lambda k: dense_init(k, (din // H, din // H), dtype))(
            jax.random.split(ks[5], H)),
        "w_if": dense_init(ks[6], (din, 2 * H), jnp.float32),  # i/f gate logits
        "gn_scale": jnp.ones((din,), dtype),
        "w_down": dense_init(ks[7], (din, d), dtype, fan_in=din),
    }


def _mlstm_chunk_scan(q, k, v, ig, fg, chunk: int):
    """Exact chunkwise mLSTM. q,k,v: [B,S,H,dh]; ig,fg: [B,S,H] log-gates.

    Returns h [B,S,H,dh] and final (C~, n~, m).
    """
    B, S, H, dh = q.shape
    L = min(chunk, S)
    assert S % L == 0
    nc = S // L
    scale = dh ** -0.5
    q = q.astype(jnp.float32) * scale
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)

    def reshape_c(t):
        return jnp.moveaxis(t.reshape(B, nc, L, *t.shape[2:]), 1, 0)

    qc, kc, vc = reshape_c(q), reshape_c(k), reshape_c(v)
    igc, fgc = reshape_c(ig), reshape_c(fg)                  # [nc,B,L,H]

    C0 = jnp.zeros((B, H, dh, dh), jnp.float32)
    n0 = jnp.zeros((B, H, dh), jnp.float32)
    m0 = jnp.full((B, H), -1e30, jnp.float32)

    def chunk_body(carry, inp):
        Cp, np_, mp = carry
        qq, kk, vv, ii, ff = inp                             # [B,L,H,*]
        b = jnp.cumsum(ff, axis=1)                           # [B,L,H] cumulative log-f
        u = ii - b                                           # i_s - b_s
        g = jnp.maximum(mp[:, None, :], jax.lax.cummax(u, axis=1))  # [B,L,H]
        m_t = b + g
        # intra-chunk attention-like term
        a_log = u[:, None, :, :] - g[:, :, None, :]          # [B,t,s,H]
        mask = jnp.tril(jnp.ones((L, L), bool))
        w_ts = jnp.where(mask[None, :, :, None], jnp.exp(a_log), 0.0)
        qk = jnp.einsum("bthd,bshd->btsh", qq, kk)
        A = qk * w_ts                                        # [B,t,s,H]
        intra = jnp.einsum("btsh,bshd->bthd", A, vv)
        # inter-chunk (initial state) term
        inter_scale = jnp.exp(mp[:, None, :] - g)            # [B,L,H]
        qC = jnp.einsum("bthd,bhde->bthe", qq, Cp)
        num = intra + qC * inter_scale[..., None]
        den = jnp.einsum("btsh->bth", A) + \
              jnp.einsum("bthd,bhd->bth", qq, np_) * inter_scale
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]
        # state to end of chunk
        gL = g[:, -1, :]                                     # [B,H]
        wL = jnp.exp(u - gL[:, None, :])                     # w_s = exp(i_s - b_s - g_L), [B,L,H]
        kw = kk * wL[..., None]
        C_new = jnp.exp(mp - gL)[..., None, None] * Cp + \
            jnp.einsum("bshd,bshe->bhde", kw, vv)
        n_new = jnp.exp(mp - gL)[..., None] * np_ + jnp.einsum("bshd->bhd", kw)
        m_new = b[:, -1, :] + gL
        return (C_new, n_new, m_new), h

    (Cf, nf, mf), hs = jax.lax.scan(chunk_body, (C0, n0, m0), (qc, kc, vc, igc, fgc))
    h = jnp.moveaxis(hs, 0, 1).reshape(B, S, H, dh)
    return h, (Cf, nf, mf)


def mlstm_train(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Full mLSTM block over [B, S, d]."""
    y, _ = _mlstm_block_apply(p, x, cfg)
    return y


def _mlstm_block_apply(p: dict, x: jax.Array, cfg: ModelConfig):
    """Shared mLSTM block body; returns (y, final_state)."""
    B, S, _ = x.shape
    H = cfg.n_heads
    xm = x @ p["w_up"]
    z = x @ p["w_z"]
    xc = jax.nn.silu(_causal_conv_train(xm, p["conv_w"]))
    din = xm.shape[-1]
    dh = din // H
    xch = xc.reshape(B, S, H, dh)
    xmh = xm.reshape(B, S, H, dh)
    q = jnp.einsum("bshd,hde->bshe", xch, p["wq"])
    k = jnp.einsum("bshd,hde->bshe", xch, p["wk"])
    v = jnp.einsum("bshd,hde->bshe", xmh, p["wv"])
    gates = (xm.astype(jnp.float32) @ p["w_if"]).reshape(B, S, H, 2)
    ig = gates[..., 0]
    fg = jax.nn.log_sigmoid(gates[..., 1])
    h, (Cf, nf, mf) = _mlstm_chunk_scan(q, k, v, ig, fg, cfg.mlstm_chunk)
    hg = h.reshape(B, S, H, dh)
    mu = jnp.mean(hg, axis=-1, keepdims=True)
    var = jnp.var(hg, axis=-1, keepdims=True)
    hn = ((hg - mu) * jax.lax.rsqrt(var + 1e-6)).reshape(B, S, din)
    hn = (hn * p["gn_scale"]).astype(x.dtype)
    y = (hn * jax.nn.silu(z)) @ p["w_down"]
    cw = cfg.conv_width - 1
    state = {"C": Cf, "n": nf, "m": mf, "conv": xm[:, -cw:]}
    return y, state


def mlstm_prefill(p: dict, x: jax.Array, cfg: ModelConfig):
    return _mlstm_block_apply(p, x, cfg)


def slstm_prefill(p: dict, x: jax.Array, cfg: ModelConfig):
    """sLSTM block over [B,S,d] returning (y, final cell state)."""
    B, S, d = x.shape
    xg = x @ p["w_ifzo"]

    def step(st, x_t):
        st = _slstm_cell(p, x_t, st, cfg)
        return st, st["h"]

    st0 = slstm_init_state(cfg, B)
    st_f, hs = jax.lax.scan(step, st0, jnp.moveaxis(xg, 1, 0))
    h = jnp.moveaxis(hs, 0, 1)
    H = cfg.n_heads
    dh = d // H
    hg = h.reshape(B, S, H, dh)
    mu = jnp.mean(hg, -1, keepdims=True)
    var = jnp.var(hg, -1, keepdims=True)
    h = ((hg - mu) * jax.lax.rsqrt(var + 1e-6)).reshape(B, S, d)
    h = (h * p["gn_scale"]).astype(x.dtype)
    y = (jax.nn.silu(h @ p["w_up_gate"]) * (h @ p["w_up"])) @ p["w_down"]
    return y, st_f


def mlstm_init_state(cfg: ModelConfig, B: int, dtype) -> dict:
    din = int(cfg.d_model * cfg.mlstm_proj_factor)
    H = cfg.n_heads
    dh = din // H
    return {
        "C": jnp.zeros((B, H, dh, dh), jnp.float32),
        "n": jnp.zeros((B, H, dh), jnp.float32),
        "m": jnp.full((B, H), -1e30, jnp.float32),
        "conv": jnp.zeros((B, cfg.conv_width - 1, din), dtype),
    }


def mlstm_decode(p: dict, x: jax.Array, state: dict, cfg: ModelConfig):
    """One-step mLSTM block. x: [B, 1, d]."""
    B = x.shape[0]
    H = cfg.n_heads
    xm = (x @ p["w_up"])[:, 0]
    z = (x @ p["w_z"])[:, 0]
    hist = jnp.concatenate([state["conv"], xm[:, None]], axis=1)
    xc = jax.nn.silu(jnp.einsum("bcw,cw->bw", hist, p["conv_w"]))
    din = xm.shape[-1]
    dh = din // H
    xch = xc.reshape(B, H, dh)
    xmh = xm.reshape(B, H, dh)
    q = jnp.einsum("bhd,hde->bhe", xch, p["wq"]).astype(jnp.float32) * dh ** -0.5
    k = jnp.einsum("bhd,hde->bhe", xch, p["wk"]).astype(jnp.float32)
    v = jnp.einsum("bhd,hde->bhe", xmh, p["wv"]).astype(jnp.float32)
    gates = (xm.astype(jnp.float32) @ p["w_if"]).reshape(B, H, 2)
    ig = gates[..., 0]
    fg = jax.nn.log_sigmoid(gates[..., 1])
    m_new = jnp.maximum(fg + state["m"], ig)
    i_s = jnp.exp(ig - m_new)
    f_s = jnp.exp(fg + state["m"] - m_new)
    C = f_s[..., None, None] * state["C"] + i_s[..., None, None] * \
        jnp.einsum("bhd,bhe->bhde", k, v)
    n = f_s[..., None] * state["n"] + i_s[..., None] * k
    num = jnp.einsum("bhd,bhde->bhe", q, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q, n)), jnp.exp(-m_new))
    h = (num / den[..., None]).reshape(B, din)
    mu = jnp.mean(h.reshape(B, H, dh), -1, keepdims=True)
    var = jnp.var(h.reshape(B, H, dh), -1, keepdims=True)
    h = ((h.reshape(B, H, dh) - mu) * jax.lax.rsqrt(var + 1e-6)).reshape(B, din)
    h = (h * p["gn_scale"]).astype(x.dtype)
    y = (h * jax.nn.silu(z)) @ p["w_down"]
    return y[:, None], {"C": C, "n": n, "m": m_new, "conv": hist[:, 1:]}


# ---------------------------------------------------------------------------
# sLSTM (sequential by construction)
# ---------------------------------------------------------------------------

def _round_mult(x: float, m: int = 128) -> int:
    return max(m, int(-(-x // m) * m))


def init_slstm_params(key: jax.Array, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    H = cfg.n_heads
    dh = d // H
    dup = _round_mult(d * cfg.slstm_proj_factor, 128 if d >= 128 else 16)
    ks = jax.random.split(key, 8)
    return {
        "w_ifzo": dense_init(ks[0], (d, 4 * d), dtype),
        "r_ifzo": jax.vmap(lambda k: dense_init(k, (dh, 4 * dh), jnp.float32))(
            jax.random.split(ks[1], H)),                     # block-diag recurrent
        "b_ifzo": jnp.zeros((4 * d,), jnp.float32),
        "gn_scale": jnp.ones((d,), dtype),
        "w_up_gate": dense_init(ks[2], (d, dup), dtype),
        "w_up": dense_init(ks[3], (d, dup), dtype),
        "w_down": dense_init(ks[4], (dup, d), dtype, fan_in=dup),
    }


def slstm_init_state(cfg: ModelConfig, B: int) -> dict:
    d = cfg.d_model
    H = cfg.n_heads
    return {
        "c": jnp.zeros((B, d), jnp.float32),
        "n": jnp.zeros((B, d), jnp.float32),
        "h": jnp.zeros((B, d), jnp.float32),
        "m": jnp.full((B, H), -1e30, jnp.float32),
    }


def _slstm_cell(p: dict, x_t: jax.Array, st: dict, cfg: ModelConfig):
    """x_t: [B, d] pre-activation input projections applied outside."""
    B, d = st["h"].shape[0], cfg.d_model
    H = cfg.n_heads
    dh = d // H
    hr = st["h"].reshape(B, H, dh)
    rec = jnp.einsum("bhd,hde->bhe", hr, p["r_ifzo"]).reshape(B, 4 * d)
    pre = x_t.astype(jnp.float32) + rec + p["b_ifzo"]
    it, ft, zt, ot = jnp.split(pre, 4, axis=-1)
    ith = it.reshape(B, H, dh)
    fth = ft.reshape(B, H, dh)
    # exponential gating with per-head stabilizer (max over head dims)
    lf = jax.nn.log_sigmoid(fth)
    m_new = jnp.maximum(jnp.max(lf, -1) + st["m"], jnp.max(ith, -1))
    i_s = jnp.exp(ith - m_new[..., None]).reshape(B, d)
    f_s = jnp.exp(lf + st["m"][..., None] - m_new[..., None]).reshape(B, d)
    z = jnp.tanh(zt)
    o = jax.nn.sigmoid(ot)
    c = f_s * st["c"] + i_s * z
    n = f_s * st["n"] + i_s
    h = o * c / jnp.maximum(n, 1e-6)
    return {"c": c, "n": n, "h": h, "m": m_new}


def slstm_train(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Full sLSTM block over [B, S, d] (sequential scan over S)."""
    B, S, d = x.shape
    xg = x @ p["w_ifzo"]                                     # [B,S,4d]

    def step(st, x_t):
        st = _slstm_cell(p, x_t, st, cfg)
        return st, st["h"]

    st0 = slstm_init_state(cfg, B)
    _, hs = jax.lax.scan(step, st0, jnp.moveaxis(xg, 1, 0))
    h = jnp.moveaxis(hs, 0, 1)                               # [B,S,d]
    H = cfg.n_heads
    dh = d // H
    hg = h.reshape(B, S, H, dh)
    mu = jnp.mean(hg, -1, keepdims=True)
    var = jnp.var(hg, -1, keepdims=True)
    h = ((hg - mu) * jax.lax.rsqrt(var + 1e-6)).reshape(B, S, d)
    h = (h * p["gn_scale"]).astype(x.dtype)
    return (jax.nn.silu(h @ p["w_up_gate"]) * (h @ p["w_up"])) @ p["w_down"]


def slstm_decode(p: dict, x: jax.Array, state: dict, cfg: ModelConfig):
    """One-step sLSTM block. x: [B, 1, d]."""
    xg = (x @ p["w_ifzo"])[:, 0]
    st = _slstm_cell(p, xg, state, cfg)
    B, d = st["h"].shape
    H = cfg.n_heads
    dh = d // H
    hg = st["h"].reshape(B, H, dh)
    mu = jnp.mean(hg, -1, keepdims=True)
    var = jnp.var(hg, -1, keepdims=True)
    h = ((hg - mu) * jax.lax.rsqrt(var + 1e-6)).reshape(B, d)
    h = (h * p["gn_scale"]).astype(x.dtype)
    y = (jax.nn.silu(h @ p["w_up_gate"]) * (h @ p["w_up"])) @ p["w_down"]
    return y[:, None], st
