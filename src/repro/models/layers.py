"""Common layers: RMSNorm, RoPE, gated MLPs, embeddings, init helpers."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))).astype(dt)


def rope_freqs(head_dim: int, theta: float, positions: jax.Array) -> tuple[jax.Array, jax.Array]:
    """cos/sin tables [*pos_shape, head_dim//2], f32."""
    inv = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: [..., seq, heads, head_dim]; cos/sin: [..., seq, head_dim//2]."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    x1, x2 = jnp.split(x, 2, axis=-1)
    c = cos[..., None, :]
    s = sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(dt)


def gated_mlp(x: jax.Array, w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array,
              activation: str) -> jax.Array:
    """SwiGLU / GeGLU feed-forward."""
    g = x @ w_gate
    u = x @ w_up
    act = jax.nn.silu(g) if activation == "swiglu" else jax.nn.gelu(g, approximate=True)
    return (act * u) @ w_down


def softcap(logits: jax.Array, cap: float | None) -> jax.Array:
    if cap is None:
        return logits
    return cap * jnp.tanh(logits / cap)


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key: jax.Array, shape, dtype, fan_in: int | None = None) -> jax.Array:
    fan_in = fan_in if fan_in is not None else shape[0]
    std = 1.0 / jnp.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def stacked(keys, fn):
    """vmap an init over the leading (layer) axis."""
    return jax.vmap(fn)(keys)


def cross_entropy(logits: jax.Array, labels: jax.Array, mask: jax.Array | None = None) -> jax.Array:
    """Mean token-level CE; logits [..., V] f32-cast internally."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    if mask is None:
        return -jnp.mean(ll)
    mask = mask.astype(jnp.float32)
    return -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
