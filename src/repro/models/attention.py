"""GQA/MQA/MHA attention with RoPE, qk-norm, sliding window and softcap.

Training/prefill uses *query-chunked exact attention*: a lax.scan over query
chunks keeps the live score tensor at [B, H, chunk, S] instead of
[B, H, S, S], which is what makes 32k-token prefill of 100-layer models
compile inside an HBM budget without a custom kernel. Decode computes one
token against the KV cache; softmax statistics are written with explicit
max/sum reductions so the SPMD partitioner inserts the right collectives
when the cache is sequence-sharded (flash-decode style).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import apply_rope, dense_init, rmsnorm, rope_freqs, softcap

NEG_INF = -2.0e38


def init_attn_params(key: jax.Array, cfg: ModelConfig, dtype, cross: bool = False) -> dict:
    d = cfg.d_model
    hq = cfg.n_heads * cfg.head_dim
    hkv = cfg.n_kv_heads * cfg.head_dim
    ks = jax.random.split(key, 6)
    kv_in = cfg.vision_dim if cross and cfg.vision_dim else d
    p = {
        "wq": dense_init(ks[0], (d, hq), dtype),
        "wk": dense_init(ks[1], (kv_in, hkv), dtype),
        "wv": dense_init(ks[2], (kv_in, hkv), dtype),
        "wo": dense_init(ks[3], (hq, d), dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((cfg.head_dim,), dtype)
        p["k_norm"] = jnp.zeros((cfg.head_dim,), dtype)
    if cross:
        p["kv_norm"] = jnp.zeros((kv_in,), dtype)
    return p


def _qkv(p: dict, x: jax.Array, cfg: ModelConfig, kv_src: jax.Array | None = None):
    """Project to per-head q, k, v. kv_src overrides the kv input (cross-attn)."""
    B = x.shape[0]
    kv_x = x if kv_src is None else kv_src
    q = (x @ p["wq"]).reshape(B, -1, cfg.n_heads, cfg.head_dim)
    k = (kv_x @ p["wk"]).reshape(B, -1, cfg.n_kv_heads, cfg.head_dim)
    v = (kv_x @ p["wv"]).reshape(B, -1, cfg.n_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.rmsnorm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.rmsnorm_eps)
    return q, k, v


def _grouped(q: jax.Array, cfg: ModelConfig) -> jax.Array:
    """[B, S, H, dh] -> [B, S, Hkv, G, dh]."""
    B, S = q.shape[:2]
    g = cfg.n_heads // cfg.n_kv_heads
    return q.reshape(B, S, cfg.n_kv_heads, g, cfg.head_dim)


def _attend_chunk(q_c, k, v, mask, cfg: ModelConfig):
    """q_c [B,Cq,Hkv,G,dh] vs full k/v [B,S,Hkv,dh]; mask [Cq,S] bool(keep)."""
    scale = cfg.head_dim ** -0.5
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q_c.astype(jnp.bfloat16),
                   k.astype(jnp.bfloat16)).astype(jnp.float32) * scale
    s = softcap(s, cfg.attn_logit_softcap)
    s = jnp.where(mask[None, None, None, :, :], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s - jax.lax.stop_gradient(m))
    z = jnp.sum(e, axis=-1, keepdims=True)
    pr = (e / z).astype(v.dtype)
    return jnp.einsum("bhgqk,bkhd->bqhgd", pr, v)


def attn_train(p: dict, x: jax.Array, cfg: ModelConfig, pos0: int = 0) -> jax.Array:
    """Causal self-attention over the full sequence (chunked). x: [B,S,d]."""
    B, S, _ = x.shape
    q, k, v = _qkv(p, x, cfg)
    pos = pos0 + jnp.arange(S)
    cos, sin = rope_freqs(cfg.head_dim, cfg.rope_theta, pos)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    qg = _grouped(q, cfg)

    C = min(cfg.attn_chunk, S)
    assert S % C == 0, (S, C)
    n_chunks = S // C
    qg = qg.reshape(B, n_chunks, C, cfg.n_kv_heads, -1, cfg.head_dim)
    key_pos = jnp.arange(S)

    def chunk_body(_, inp):
        q_c, ci = inp
        qpos = ci * C + jnp.arange(C)
        keep = key_pos[None, :] <= qpos[:, None]
        if cfg.sliding_window is not None:
            keep &= key_pos[None, :] > qpos[:, None] - cfg.sliding_window
        return None, _attend_chunk(q_c, k, v, keep, cfg)

    if cfg.attn_remat:
        # flash-attention-style backward: probabilities/masks are never
        # stacked as residuals — each chunk recomputes scores in the bwd pass
        chunk_body = jax.checkpoint(chunk_body)
    _, o = jax.lax.scan(chunk_body, None,
                        (jnp.moveaxis(qg, 1, 0), jnp.arange(n_chunks)))
    o = jnp.moveaxis(o, 0, 1).reshape(B, S, cfg.n_heads * cfg.head_dim)
    return o @ p["wo"]


def attn_prefill(p: dict, x: jax.Array, cfg: ModelConfig):
    """Like attn_train but also returns the (k, v) cache [B,S,Hkv,dh]."""
    B, S, _ = x.shape
    q, k, v = _qkv(p, x, cfg)
    pos = jnp.arange(S)
    cos, sin = rope_freqs(cfg.head_dim, cfg.rope_theta, pos)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    qg = _grouped(q, cfg)
    C = min(cfg.attn_chunk, S)
    n_chunks = S // C
    qg_ = qg.reshape(B, n_chunks, C, cfg.n_kv_heads, -1, cfg.head_dim)
    key_pos = jnp.arange(S)

    def chunk_body(_, inp):
        q_c, ci = inp
        qpos = ci * C + jnp.arange(C)
        keep = key_pos[None, :] <= qpos[:, None]
        if cfg.sliding_window is not None:
            keep &= key_pos[None, :] > qpos[:, None] - cfg.sliding_window
        return None, _attend_chunk(q_c, k, v, keep, cfg)

    _, o = jax.lax.scan(chunk_body, None,
                        (jnp.moveaxis(qg_, 1, 0), jnp.arange(n_chunks)))
    o = jnp.moveaxis(o, 0, 1).reshape(B, S, cfg.n_heads * cfg.head_dim)
    return o @ p["wo"], (k, v)


def _quant_rows(x: jax.Array):
    """Symmetric int8 quantization along the last axis with f32 scales."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True) \
        / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127
                 ).astype(jnp.int8)
    return q, scale[..., 0].astype(jnp.float32)


def attn_decode(p: dict, x: jax.Array, cache, pos: jax.Array,
                cfg: ModelConfig, ring: bool = False):
    """One-token decode. x: [B,1,d]; cache: (k,v) [B,Smax,Hkv,dh], or the
    int8-quantized dict {"kq","ks","vq","vs"} when cfg.serve_quant == "int8"
    (per-position-per-head scales; contractions run in int8 and scales fold
    in after the dot, so cache reads are 1 byte/element).

    ``ring``: cache is a sliding-window ring buffer (local attention); the
    write index is pos % Smax and positions are reconstructed for masking.
    """
    B = x.shape[0]
    quant = isinstance(cache, dict)
    S_max = (cache["kq"] if quant else cache[0]).shape[1]
    q, k_new, v_new = _qkv(p, x, cfg)
    cos, sin = rope_freqs(cfg.head_dim, cfg.rope_theta, pos[None])
    q = apply_rope(q, cos, sin)
    k_new = apply_rope(k_new, cos, sin)
    slot = jnp.where(ring, pos % S_max, jnp.minimum(pos, S_max - 1))

    qg = _grouped(q, cfg)[:, 0]                       # [B,Hkv,G,dh]
    scale = cfg.head_dim ** -0.5
    if quant:
        knq, kns = _quant_rows(k_new)                 # [B,1,H,dh],[B,1,H]
        vnq, vns = _quant_rows(v_new)
        cache = {
            "kq": jax.lax.dynamic_update_slice(cache["kq"], knq, (0, slot, 0, 0)),
            "ks": jax.lax.dynamic_update_slice(cache["ks"], kns, (0, slot, 0)),
            "vq": jax.lax.dynamic_update_slice(cache["vq"], vnq, (0, slot, 0, 0)),
            "vs": jax.lax.dynamic_update_slice(cache["vs"], vns, (0, slot, 0)),
        }
        qq, qs = _quant_rows(qg)                      # [B,Hkv,G,dh],[B,Hkv,G]
        s_i32 = jnp.einsum("bhgd,bshd->bhgs", qq.astype(jnp.int32),
                           cache["kq"].astype(jnp.int32))
        s = (s_i32.astype(jnp.float32) * qs[..., None]
             * jnp.moveaxis(cache["ks"], 1, 2)[:, :, None, :]) * scale
    else:
        k_cache, v_cache = cache
        k_cache = jax.lax.dynamic_update_slice(k_cache, k_new, (0, slot, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(v_cache, v_new, (0, slot, 0, 0))
        cache = (k_cache, v_cache)
        s = jnp.einsum("bhgd,bshd->bhgs", qg.astype(jnp.bfloat16),
                       k_cache.astype(jnp.bfloat16)).astype(jnp.float32) * scale
    s = softcap(s, cfg.attn_logit_softcap)
    kpos = jnp.arange(S_max)
    if ring:
        # ring slot i holds absolute position: i if i <= slot else pos - S_max + ...
        abs_pos = jnp.where(kpos <= slot, pos - slot + kpos, pos - slot + kpos - S_max)
        keep = (abs_pos >= 0) & (abs_pos <= pos)
        if cfg.sliding_window is not None:
            keep &= abs_pos > pos - cfg.sliding_window
    else:
        keep = kpos <= pos
        if cfg.sliding_window is not None:
            keep &= kpos > pos - cfg.sliding_window
    s = jnp.where(keep[None, None, None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s - m)
    z = jnp.sum(e, axis=-1, keepdims=True)
    if quant:
        pr = (e / z) * jnp.moveaxis(cache["vs"], 1, 2)[:, :, None, :]
        pq, ps = _quant_rows(pr)                      # [B,Hkv,G,S]
        o_i32 = jnp.einsum("bhgs,bshd->bhgd", pq.astype(jnp.int32),
                           cache["vq"].astype(jnp.int32))
        o = (o_i32.astype(jnp.float32) * ps[..., None]).astype(x.dtype)
    else:
        pr = (e / z).astype(cache[1].dtype)
        o = jnp.einsum("bhgs,bshd->bhgd", pr, cache[1])
    o = o.reshape(B, 1, cfg.n_heads * cfg.head_dim)
    return o @ p["wo"], cache


# ---------------------------------------------------------------------------
# Cross-attention (VLM): queries from text stream, kv from vision embeddings
# ---------------------------------------------------------------------------

def cross_attn(p: dict, x: jax.Array, vis: jax.Array, cfg: ModelConfig) -> jax.Array:
    """x: [B,S,d]; vis: [B,Nv,vision_dim]. No causal mask, no rope."""
    B, S, _ = x.shape
    vis = rmsnorm(vis, p["kv_norm"], cfg.rmsnorm_eps)
    q, k, v = _qkv(p, x, cfg, kv_src=vis)
    qg = _grouped(q, cfg)
    scale = cfg.head_dim ** -0.5
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.bfloat16),
                   k.astype(jnp.bfloat16)).astype(jnp.float32) * scale
    m = jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s - jax.lax.stop_gradient(m))
    pr = (e / jnp.sum(e, axis=-1, keepdims=True)).astype(v.dtype)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", pr, v).reshape(B, S, -1)
    return o @ p["wo"]


def cross_attn_kv(p: dict, vis: jax.Array, cfg: ModelConfig):
    """Precompute cross KV from vision embeddings (cached for decode)."""
    B = vis.shape[0]
    vis = rmsnorm(vis, p["kv_norm"], cfg.rmsnorm_eps)
    k = (vis @ p["wk"]).reshape(B, -1, cfg.n_kv_heads, cfg.head_dim)
    v = (vis @ p["wv"]).reshape(B, -1, cfg.n_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        k = rmsnorm(k, p["k_norm"], cfg.rmsnorm_eps)
    return k, v


def cross_attn_decode(p: dict, x: jax.Array, kv: tuple, cfg: ModelConfig) -> jax.Array:
    """Decode-time cross-attention against cached vision KV."""
    B = x.shape[0]
    k, v = kv
    q = (x @ p["wq"]).reshape(B, -1, cfg.n_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.rmsnorm_eps)
    qg = _grouped(q, cfg)[:, 0]
    scale = cfg.head_dim ** -0.5
    s = jnp.einsum("bhgd,bshd->bhgs", qg.astype(jnp.bfloat16),
                   k.astype(jnp.bfloat16)).astype(jnp.float32) * scale
    m = jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s - m)
    pr = (e / jnp.sum(e, axis=-1, keepdims=True)).astype(v.dtype)
    o = jnp.einsum("bhgs,bshd->bhgd", pr, v).reshape(B, 1, -1)
    return o @ p["wo"]
