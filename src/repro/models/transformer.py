"""Model assembly for all assigned families.

Layers are *stacked along a group axis* and applied with lax.scan — this
keeps the HLO size O(1) in depth (compile-tractable at 100 layers / 512
devices) and matches how production JAX frameworks (MaxText et al.) stack
weights. Heterogeneous stacks (hybrid 2:1 patterns, VLM cross-attn every
k-th layer, MoE dense prefix, xLSTM sLSTM interleave) are handled by
scanning over *pattern groups*: each group holds one stacked param set per
pattern position.

Public API:
    init_params(key, cfg)                         -> params
    forward_train(params, batch, cfg)             -> (loss, metrics)
    init_cache(cfg, B, S_max)                     -> decode cache
    prefill(params, batch, cfg)                   -> (cache, last_logits)
    decode_step(params, cache, tokens, pos, cfg)  -> (cache, logits)

``batch`` is a dict: tokens [B,S] (audio: [B,S,n_codebooks]); vlm adds
vision [B,Nv,vision_dim]; labels for training.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from . import attention as attn
from . import mla as mla_mod
from . import moe as moe_mod
from . import recurrent as rec
from .config import ModelConfig
from .layers import cross_entropy, dense_init, gated_mlp, rmsnorm

Params = Any


def _dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def _remat(fn, cfg: ModelConfig):
    if cfg.remat_policy == "full":
        return fn
    if cfg.remat_policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    return jax.checkpoint(fn)


# ---------------------------------------------------------------------------
# Group structure
# ---------------------------------------------------------------------------

def group_layout(cfg: ModelConfig) -> tuple[int, tuple[str, ...]]:
    """(n_groups, pattern positions) for the scanned group axis."""
    if cfg.family in ("dense", "audio"):
        return cfg.n_layers, ("self",)
    if cfg.family == "moe":
        # dense prefix handled separately; groups cover the MoE layers
        return cfg.n_layers - cfg.first_k_dense, ("moe",)
    if cfg.family == "vlm":
        k = cfg.cross_attn_every
        assert cfg.n_layers % k == 0
        return cfg.n_layers // k, tuple(["self"] * (k - 1) + ["cross"])
    if cfg.family == "hybrid":
        pat = cfg.block_pattern
        assert cfg.n_layers % len(pat) == 0
        return cfg.n_layers // len(pat), pat
    if cfg.family == "ssm":
        k = cfg.slstm_every
        assert cfg.n_layers % k == 0
        return cfg.n_layers // k, tuple(["mlstm"] * (k - 1) + ["slstm"])
    raise ValueError(cfg.family)


# ---------------------------------------------------------------------------
# Per-position init
# ---------------------------------------------------------------------------

def _init_position(key, kind: str, cfg: ModelConfig, dt) -> dict:
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    p: dict = {"ln1": jnp.zeros((d,), dt)}
    if kind == "self":
        if cfg.attn_kind == "mla":
            p["attn"] = mla_mod.init_mla_params(ks[0], cfg, dt)
        else:
            p["attn"] = attn.init_attn_params(ks[0], cfg, dt)
        p["ln2"] = jnp.zeros((d,), dt)
        p["mlp"] = _init_mlp(ks[1], cfg, dt)
    elif kind == "cross":
        p["attn"] = attn.init_attn_params(ks[0], cfg, dt, cross=True)
        p["gate"] = jnp.zeros((1,), dt)          # llama-vision tanh gate
        p["ln2"] = jnp.zeros((d,), dt)
        p["mlp"] = _init_mlp(ks[1], cfg, dt)
    elif kind == "moe":
        p["attn"] = mla_mod.init_mla_params(ks[0], cfg, dt)
        p["ln2"] = jnp.zeros((d,), dt)
        p["moe"] = moe_mod.init_moe_params(ks[1], cfg, dt)
    elif kind == "local_attn":
        p["attn"] = attn.init_attn_params(ks[0], cfg, dt)
        p["ln2"] = jnp.zeros((d,), dt)
        p["mlp"] = _init_mlp(ks[1], cfg, dt)
    elif kind == "rglru":
        p["rec"] = rec.init_rglru_params(ks[0], cfg, dt)
        p["ln2"] = jnp.zeros((d,), dt)
        p["mlp"] = _init_mlp(ks[1], cfg, dt)
    elif kind == "mlstm":
        p["cell"] = rec.init_mlstm_params(ks[0], cfg, dt)
    elif kind == "slstm":
        p["cell"] = rec.init_slstm_params(ks[0], cfg, dt)
    else:
        raise ValueError(kind)
    return p


def _init_mlp(key, cfg: ModelConfig, dt) -> dict:
    ks = jax.random.split(key, 3)
    d, f = cfg.d_model, cfg.d_ff
    return {
        "w_gate": dense_init(ks[0], (d, f), dt),
        "w_up": dense_init(ks[1], (d, f), dt),
        "w_down": dense_init(ks[2], (f, d), dt, fan_in=f),
    }


def init_params(key: jax.Array, cfg: ModelConfig) -> Params:
    dt = _dtype(cfg)
    n_groups, pattern = group_layout(cfg)
    keys = jax.random.split(key, 8)
    d = cfg.d_model

    if cfg.family == "audio":
        embed = jax.vmap(lambda k: dense_init(k, (cfg.vocab, d), dt))(
            jax.random.split(keys[0], cfg.n_codebooks))
    else:
        embed = dense_init(keys[0], (cfg.vocab, d), dt)
    params: dict = {"embed": embed, "final_norm": jnp.zeros((d,), dt)}
    if not cfg.tie_embeddings:
        params["unembed"] = dense_init(keys[1], (d, cfg.vocab * max(cfg.n_codebooks, 1)), dt)
    elif cfg.family == "audio":
        params["unembed"] = dense_init(keys[1], (d, cfg.vocab * cfg.n_codebooks), dt)

    group_keys = jax.random.split(keys[2], n_groups)
    groups = {}
    for i, kind in enumerate(pattern):
        pos_name = f"{kind}_{i}"
        groups[pos_name] = jax.vmap(
            lambda k, kind=kind: _init_position(k, kind, cfg, dt)
        )(jax.vmap(lambda k, i=i: jax.random.fold_in(k, i))(group_keys))
    params["groups"] = groups

    if cfg.family == "moe" and cfg.first_k_dense:
        pre_keys = jax.random.split(keys[3], cfg.first_k_dense)
        moe_cfg_dense = cfg
        params["dense_prefix"] = jax.vmap(
            lambda k: _init_position(k, "self", moe_cfg_dense, dt)
        )(pre_keys)

    if cfg.family == "moe" and cfg.mtp_depth:
        # MTP: projection + one dense block + shared embed/unembed
        mtp = {
            "proj": dense_init(keys[4], (2 * d, d), dt),
            "block": _init_position(keys[5], "self", cfg, dt),
            "ln": jnp.zeros((d,), dt),
        }
        params["mtp"] = mtp
    return params


# ---------------------------------------------------------------------------
# Train-mode position application
# ---------------------------------------------------------------------------

def _apply_position_train(p: dict, kind: str, x, cfg: ModelConfig, extra,
                          mesh=None) -> tuple[jax.Array, jax.Array]:
    """Returns (x', aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = rmsnorm(x, p["ln1"], cfg.rmsnorm_eps)
    if kind == "self":
        o = (mla_mod.mla_train(p["attn"], h, cfg) if cfg.attn_kind == "mla"
             else attn.attn_train(p["attn"], h, cfg))
        x = x + o
        h2 = rmsnorm(x, p["ln2"], cfg.rmsnorm_eps)
        x = x + gated_mlp(h2, **p["mlp"], activation=cfg.activation)
    elif kind == "cross":
        o = attn.cross_attn(p["attn"], h, extra["vision"], cfg)
        x = x + jnp.tanh(p["gate"]) * o
        h2 = rmsnorm(x, p["ln2"], cfg.rmsnorm_eps)
        x = x + gated_mlp(h2, **p["mlp"], activation=cfg.activation)
    elif kind == "moe":
        o = mla_mod.mla_train(p["attn"], h, cfg)
        x = x + o
        h2 = rmsnorm(x, p["ln2"], cfg.rmsnorm_eps)
        y, aux = moe_mod.moe_ffn(p["moe"], h2, cfg, mesh=mesh)
        x = x + y
    elif kind == "local_attn":
        o = attn.attn_train(p["attn"], h, cfg)
        x = x + o
        h2 = rmsnorm(x, p["ln2"], cfg.rmsnorm_eps)
        x = x + gated_mlp(h2, **p["mlp"], activation=cfg.activation)
    elif kind == "rglru":
        x = x + rec.rglru_train(p["rec"], h, cfg)
        h2 = rmsnorm(x, p["ln2"], cfg.rmsnorm_eps)
        x = x + gated_mlp(h2, **p["mlp"], activation=cfg.activation)
    elif kind == "mlstm":
        x = x + rec.mlstm_train(p["cell"], h, cfg)
    elif kind == "slstm":
        x = x + rec.slstm_train(p["cell"], h, cfg)
    return x, aux


def _embed_tokens(params, batch, cfg: ModelConfig):
    if cfg.family == "audio":
        # sum of codebook embeddings; tokens [B, S, ncb]
        x = jnp.sum(jax.vmap(
            lambda emb, t: emb[t], in_axes=(0, 2), out_axes=2
        )(params["embed"], batch["tokens"]), axis=2)
    else:
        x = params["embed"][batch["tokens"]]
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return x


def _logits_chunked(params, x, cfg: ModelConfig, labels, mask=None):
    """CE over the vocab without materializing [B, S, V] f32: scan S-chunks."""
    B, S, d = x.shape
    unembed = params.get("unembed")
    if unembed is None:
        unembed = params["embed"].T
    C = min(512, S)
    nc = S // C
    xs = jnp.moveaxis(x.reshape(B, nc, C, d), 1, 0)
    ls = jnp.moveaxis(labels.reshape(B, nc, C, *labels.shape[2:]), 1, 0)

    def body(tot, inp):
        xc, lc = inp
        logits = xc @ unembed
        if cfg.family == "audio":
            logits = logits.reshape(B, C, cfg.n_codebooks, cfg.vocab)
        return tot + cross_entropy(logits, lc) * (1.0 / nc), None

    tot, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xs, ls))
    return tot


def forward_train(params: Params, batch: dict, cfg: ModelConfig, mesh=None):
    """Next-token LM loss (audio: per-codebook CE; vlm: text CE).

    ``mesh`` is only needed for shard_map-based layer variants
    (cfg.moe_groups expert parallelism); None keeps the pure-pjit path."""
    x = _embed_tokens(params, batch, cfg)
    extra = {k: batch[k] for k in ("vision",) if k in batch}
    aux_total = jnp.zeros((), jnp.float32)

    n_groups, pattern = group_layout(cfg)

    if cfg.family == "moe" and cfg.first_k_dense:
        def pre_body(h, gp):
            h, aux = _apply_position_train(gp, "self", h, cfg, extra)
            return h, aux
        pre_fn = _remat(pre_body, cfg)
        x, _ = jax.lax.scan(pre_fn, x, params["dense_prefix"])

    def group_body(carry, gp):
        h, aux_sum = carry
        for i, kind in enumerate(pattern):
            h, aux = _apply_position_train(gp[f"{kind}_{i}"], kind, h, cfg,
                                           extra, mesh=mesh)
            aux_sum = aux_sum + aux
        return (h, aux_sum), None

    group_fn = _remat(group_body, cfg)
    (x, aux_total), _ = jax.lax.scan(group_fn, (x, aux_total), params["groups"])

    x = rmsnorm(x, params["final_norm"], cfg.rmsnorm_eps)
    labels = batch["labels"]
    loss = _logits_chunked(params, x, cfg, labels)

    metrics = {"lm_loss": loss, "aux_loss": aux_total}
    if cfg.family == "moe":
        loss = loss + 0.001 * aux_total
    if cfg.family == "moe" and cfg.mtp_depth and "labels_mtp" in batch:
        # MTP: predict t+2 from [h_t ; emb(t_{t+1})]
        emb_next = params["embed"][batch["tokens_next"]]
        h_in = jnp.concatenate([x, emb_next.astype(x.dtype)], axis=-1) @ params["mtp"]["proj"]
        h_mtp, _ = _apply_position_train(params["mtp"]["block"], "self", h_in, cfg, extra)
        h_mtp = rmsnorm(h_mtp, params["mtp"]["ln"], cfg.rmsnorm_eps)
        mtp_loss = _logits_chunked(params, h_mtp, cfg, batch["labels_mtp"])
        metrics["mtp_loss"] = mtp_loss
        loss = loss + 0.3 * mtp_loss
    metrics["loss"] = loss
    return loss, metrics


# ---------------------------------------------------------------------------
# Decode caches
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, B: int, S_max: int) -> dict:
    """Per-group stacked decode state. Shapes depend on family."""
    dt = _dtype(cfg)
    n_groups, pattern = group_layout(cfg)
    cache: dict = {"pos": jnp.zeros((), jnp.int32)}
    quant = cfg.serve_quant == "int8"

    def kv(S, G=n_groups):
        if quant:
            return {
                "kq": jnp.zeros((G, B, S, cfg.n_kv_heads, cfg.head_dim), jnp.int8),
                "ks": jnp.zeros((G, B, S, cfg.n_kv_heads), jnp.float32),
                "vq": jnp.zeros((G, B, S, cfg.n_kv_heads, cfg.head_dim), jnp.int8),
                "vs": jnp.zeros((G, B, S, cfg.n_kv_heads), jnp.float32),
            }
        return (jnp.zeros((G, B, S, cfg.n_kv_heads, cfg.head_dim), dt),
                jnp.zeros((G, B, S, cfg.n_kv_heads, cfg.head_dim), dt))

    def ckv(S, G):
        if quant:
            return {"q": jnp.zeros((G, B, S, cfg.mla_cache_dim), jnp.int8),
                    "s": jnp.zeros((G, B, S), jnp.float32)}
        return jnp.zeros((G, B, S, cfg.mla_cache_dim), dt)

    if cfg.family in ("dense", "audio"):
        cache["kv"] = kv(S_max)
    elif cfg.family == "moe":
        cache["ckv"] = ckv(S_max, n_groups)
        if cfg.first_k_dense:
            cache["ckv_prefix"] = ckv(S_max, cfg.first_k_dense)
    elif cfg.family == "vlm":
        n_self = len(pattern) - 1
        cache["kv"] = (
            jnp.zeros((n_groups, n_self, B, S_max, cfg.n_kv_heads, cfg.head_dim), dt),
            jnp.zeros((n_groups, n_self, B, S_max, cfg.n_kv_heads, cfg.head_dim), dt))
        cache["cross_kv"] = (
            jnp.zeros((n_groups, B, cfg.n_vision_tokens, cfg.n_kv_heads, cfg.head_dim), dt),
            jnp.zeros((n_groups, B, cfg.n_vision_tokens, cfg.n_kv_heads, cfg.head_dim), dt))
    elif cfg.family == "hybrid":
        W = min(cfg.sliding_window or S_max, S_max)
        n_rec = sum(1 for k in pattern if k == "rglru")
        cache["rec"] = {
            "h": jnp.zeros((n_groups, n_rec, B, cfg.lru_width), jnp.float32),
            "conv": jnp.zeros((n_groups, n_rec, B, cfg.conv_width - 1, cfg.lru_width), dt),
        }
        cache["kv"] = (
            jnp.zeros((n_groups, B, W, cfg.n_kv_heads, cfg.head_dim), dt),
            jnp.zeros((n_groups, B, W, cfg.n_kv_heads, cfg.head_dim), dt))
    elif cfg.family == "ssm":
        din = int(cfg.d_model * cfg.mlstm_proj_factor)
        H = cfg.n_heads
        dh = din // H
        n_m = len(pattern) - 1
        cache["mlstm"] = {
            "C": jnp.zeros((n_groups, n_m, B, H, dh, dh), jnp.float32),
            "n": jnp.zeros((n_groups, n_m, B, H, dh), jnp.float32),
            "m": jnp.full((n_groups, n_m, B, H), -1e30, jnp.float32),
            "conv": jnp.zeros((n_groups, n_m, B, cfg.conv_width - 1, din), dt),
        }
        d = cfg.d_model
        cache["slstm"] = {
            "c": jnp.zeros((n_groups, B, d), jnp.float32),
            "n": jnp.zeros((n_groups, B, d), jnp.float32),
            "h": jnp.zeros((n_groups, B, d), jnp.float32),
            "m": jnp.full((n_groups, B, cfg.n_heads), -1e30, jnp.float32),
        }
    return cache


# ---------------------------------------------------------------------------
# Decode step
# ---------------------------------------------------------------------------

def _apply_position_decode(p, kind, x, pcache, pos, cfg: ModelConfig):
    """x: [B,1,d]. Returns (x', pcache')."""
    h = rmsnorm(x, p["ln1"], cfg.rmsnorm_eps)
    if kind in ("self", "local_attn"):
        if cfg.attn_kind == "mla":
            o, new = mla_mod.mla_decode(p["attn"], h, pcache, pos, cfg)
        else:
            ring = kind == "local_attn" or (
                cfg.sliding_window is not None and cfg.family == "hybrid")
            o, new = attn.attn_decode(p["attn"], h, pcache, pos, cfg, ring=ring)
        x = x + o
        h2 = rmsnorm(x, p["ln2"], cfg.rmsnorm_eps)
        x = x + gated_mlp(h2, **p["mlp"], activation=cfg.activation)
        return x, new
    if kind == "cross":
        o = attn.cross_attn_decode(p["attn"], h, pcache, cfg)
        x = x + jnp.tanh(p["gate"]) * o
        h2 = rmsnorm(x, p["ln2"], cfg.rmsnorm_eps)
        x = x + gated_mlp(h2, **p["mlp"], activation=cfg.activation)
        return x, pcache
    if kind == "moe":
        o, new = mla_mod.mla_decode(p["attn"], h, pcache, pos, cfg)
        x = x + o
        h2 = rmsnorm(x, p["ln2"], cfg.rmsnorm_eps)
        y, _ = moe_mod.moe_ffn(p["moe"], h2, cfg)
        return x + y, new
    if kind == "rglru":
        o, new = rec.rglru_decode(p["rec"], h, pcache, cfg)
        x = x + o
        h2 = rmsnorm(x, p["ln2"], cfg.rmsnorm_eps)
        x = x + gated_mlp(h2, **p["mlp"], activation=cfg.activation)
        return x, new
    if kind == "mlstm":
        o, new = rec.mlstm_decode(p["cell"], h, pcache, cfg)
        return x + o, new
    if kind == "slstm":
        o, new = rec.slstm_decode(p["cell"], h, pcache, cfg)
        return x + o, new
    raise ValueError(kind)


def _group_cache_slices(cache: dict, cfg: ModelConfig):
    """Rearrange the cache dict into per-group xs for lax.scan."""
    n_groups, pattern = group_layout(cfg)
    if cfg.family in ("dense", "audio"):
        return {"self_0": cache["kv"]}
    if cfg.family == "moe":
        return {"moe_0": cache["ckv"]}
    if cfg.family == "vlm":
        xs = {}
        for i in range(len(pattern) - 1):
            xs[f"self_{i}"] = jax.tree.map(lambda t, i=i: t[:, i], cache["kv"])
        xs[f"cross_{len(pattern)-1}"] = cache["cross_kv"]
        return xs
    if cfg.family == "hybrid":
        xs = {}
        ri = 0
        for i, kind in enumerate(pattern):
            if kind == "rglru":
                xs[f"rglru_{i}"] = jax.tree.map(lambda t, ri=ri: t[:, ri], cache["rec"])
                ri += 1
            else:
                xs[f"local_attn_{i}"] = cache["kv"]
        return xs
    if cfg.family == "ssm":
        xs = {}
        for i in range(len(pattern) - 1):
            xs[f"mlstm_{i}"] = jax.tree.map(lambda t, i=i: t[:, i], cache["mlstm"])
        xs[f"slstm_{len(pattern)-1}"] = cache["slstm"]
        return xs
    raise ValueError(cfg.family)


def _rebuild_cache(cache: dict, new_xs: dict, cfg: ModelConfig, pos) -> dict:
    n_groups, pattern = group_layout(cfg)
    out = dict(cache)
    out["pos"] = pos + 1
    if cfg.family in ("dense", "audio"):
        out["kv"] = new_xs["self_0"]
    elif cfg.family == "moe":
        out["ckv"] = new_xs["moe_0"]
    elif cfg.family == "vlm":
        ks = [new_xs[f"self_{i}"] for i in range(len(pattern) - 1)]
        out["kv"] = jax.tree.map(lambda *t: jnp.stack(t, axis=1), *ks)
    elif cfg.family == "hybrid":
        recs = [new_xs[f"rglru_{i}"] for i, k in enumerate(pattern) if k == "rglru"]
        out["rec"] = jax.tree.map(lambda *t: jnp.stack(t, axis=1), *recs)
        attn_key = next(f"local_attn_{i}" for i, k in enumerate(pattern)
                        if k == "local_attn")
        out["kv"] = new_xs[attn_key]
    elif cfg.family == "ssm":
        ms = [new_xs[f"mlstm_{i}"] for i in range(len(pattern) - 1)]
        out["mlstm"] = jax.tree.map(lambda *t: jnp.stack(t, axis=1), *ms)
        out["slstm"] = new_xs[f"slstm_{len(pattern)-1}"]
    return out


def decode_step(params: Params, cache: dict, tokens: jax.Array, cfg: ModelConfig,
                return_hidden: bool = False):
    """One decode step for a batch. tokens: [B] (audio [B, ncb])."""
    pos = cache["pos"]
    batch = {"tokens": tokens[:, None] if tokens.ndim == 1 else tokens[:, None, :]}
    x = _embed_tokens(params, batch, cfg)
    xs = _group_cache_slices(cache, cfg)
    _, pattern = group_layout(cfg)

    if cfg.family == "moe" and cfg.first_k_dense:
        def pre_body(h, gp_and_c):
            gp, c = gp_and_c
            h, new = _apply_position_decode(gp, "self", h, c, pos, cfg)
            return h, new
        x, new_pre = jax.lax.scan(
            pre_body, x, (params["dense_prefix"], cache["ckv_prefix"]))

    def group_body(h, inp):
        gp, cs = inp
        new_cs = {}
        for i, kind in enumerate(pattern):
            name = f"{kind}_{i}"
            h, new_cs[name] = _apply_position_decode(
                gp[name], kind, h, cs[name], pos, cfg)
        return h, new_cs

    x, new_xs = jax.lax.scan(group_body, x, (params["groups"], xs))
    cache = _rebuild_cache(cache, new_xs, cfg, pos)
    if cfg.family == "moe" and cfg.first_k_dense:
        cache["ckv_prefix"] = new_pre

    x = rmsnorm(x, params["final_norm"], cfg.rmsnorm_eps)
    unembed = params.get("unembed")
    if unembed is None:
        unembed = params["embed"].T
    logits = (x[:, 0] @ unembed).astype(jnp.float32)
    if cfg.family == "audio":
        logits = logits.reshape(-1, cfg.n_codebooks, cfg.vocab)
    if return_hidden:
        return cache, logits, x[:, 0]
    return cache, logits


# ---------------------------------------------------------------------------
# Prefill
# ---------------------------------------------------------------------------

def _apply_position_prefill(p, kind, x, pos_len, cfg: ModelConfig, extra):
    """Returns (x', pcache). Like train but collecting decode state."""
    h = rmsnorm(x, p["ln1"], cfg.rmsnorm_eps)
    if kind in ("self", "local_attn"):
        if cfg.attn_kind == "mla":
            o, ckv = mla_mod.mla_prefill(p["attn"], h, cfg)
            new = ckv
        else:
            o, kv_ = attn.attn_prefill(p["attn"], h, cfg)
            new = kv_
        x = x + o
        h2 = rmsnorm(x, p["ln2"], cfg.rmsnorm_eps)
        x = x + gated_mlp(h2, **p["mlp"], activation=cfg.activation)
        return x, new
    if kind == "cross":
        o = attn.cross_attn(p["attn"], h, extra["vision"], cfg)
        x = x + jnp.tanh(p["gate"]) * o
        h2 = rmsnorm(x, p["ln2"], cfg.rmsnorm_eps)
        x = x + gated_mlp(h2, **p["mlp"], activation=cfg.activation)
        return x, attn.cross_attn_kv(p["attn"], extra["vision"], cfg)
    if kind == "moe":
        o, ckv = mla_mod.mla_prefill(p["attn"], h, cfg)
        x = x + o
        h2 = rmsnorm(x, p["ln2"], cfg.rmsnorm_eps)
        y, _ = moe_mod.moe_ffn(p["moe"], h2, cfg)
        return x + y, ckv
    if kind == "rglru":
        y, st = rec.rglru_prefill(p["rec"], h, cfg)
        x = x + y
        h2 = rmsnorm(x, p["ln2"], cfg.rmsnorm_eps)
        x = x + gated_mlp(h2, **p["mlp"], activation=cfg.activation)
        return x, st
    if kind == "mlstm":
        y, st = rec.mlstm_prefill(p["cell"], h, cfg)
        return x + y, st
    if kind == "slstm":
        y, st = rec.slstm_prefill(p["cell"], h, cfg)
        return x + y, st
    raise ValueError(kind)


def _to_ring(kv: jax.Array, W: int, S: int) -> jax.Array:
    """Rearrange the last min(S, W) cache rows into ring-buffer slot order.

    kv: [..., B, S, Hkv, dh] -> [..., B, W, Hkv, dh] with row for absolute
    position p stored at slot p % W (matching attn_decode's ring writes).
    """
    n = min(S, W)
    tail = kv[..., S - n:, :, :]                     # last n positions
    slots = (jnp.arange(S - n, S) % W).astype(jnp.int32)
    out_shape = kv.shape[:-3] + (W,) + kv.shape[-2:]
    out = jnp.zeros(out_shape, kv.dtype)
    return out.at[..., slots, :, :].set(tail)


def prefill(params: Params, batch: dict, cfg: ModelConfig,
            s_max: int | None = None):
    """Process a full prompt; returns (cache, last-position logits).

    ``s_max``: decode-cache capacity (>= prompt length); defaults to the
    prompt length + 64 so generation can continue after prefill."""
    tokens = batch["tokens"]
    B, S = tokens.shape[:2]
    x = _embed_tokens(params, batch, cfg)
    extra = {k: batch[k] for k in ("vision",) if k in batch}
    _, pattern = group_layout(cfg)

    if cfg.family == "moe" and cfg.first_k_dense:
        def pre_body(h, gp):
            h, new = _apply_position_prefill(gp, "self", h, S, cfg, extra)
            return h, new
        x, pre_caches = jax.lax.scan(pre_body, x, params["dense_prefix"])

    def group_body(h, gp):
        outs = {}
        for i, kind in enumerate(pattern):
            name = f"{kind}_{i}"
            h, outs[name] = _apply_position_prefill(gp[name], kind, h, S, cfg, extra)
        return h, outs

    x, collected = jax.lax.scan(group_body, x, params["groups"])

    # hybrid local attention uses ring caches of width W: rearrange
    if cfg.family == "hybrid":
        W = cfg.sliding_window or S
        for i, kind in enumerate(pattern):
            if kind == "local_attn":
                name = f"local_attn_{i}"
                collected[name] = jax.tree.map(
                    lambda t: _to_ring(t, W, S), collected[name])
        cache_S = W
    else:
        # leave decode headroom: a cache sized exactly S cannot extend
        cache_S = s_max if s_max is not None else S + 64
        assert cache_S >= S
        if cache_S > S:
            def pad_seq(t):
                # collected self_/moe_ caches are [G, B, S, ...] (kv tuples
                # and MLA latents alike): the sequence axis is always 2
                pad = [(0, 0)] * t.ndim
                pad[2] = (0, cache_S - S)
                return jnp.pad(t, pad)
            if cfg.family in ("dense", "audio", "moe", "vlm"):
                for name in list(collected):
                    if name.startswith(("self_", "moe_")):
                        collected[name] = jax.tree.map(pad_seq, collected[name])
    cache = _rebuild_cache(
        init_cache(cfg, B, cache_S), collected, cfg, jnp.asarray(S - 1, jnp.int32))
    if cfg.family == "moe" and cfg.first_k_dense:
        if cache_S > S:
            pre_caches = jax.tree.map(pad_seq, pre_caches)
        cache["ckv_prefix"] = pre_caches
    cache["pos"] = jnp.asarray(S, jnp.int32)

    x = rmsnorm(x[:, -1:], params["final_norm"], cfg.rmsnorm_eps)
    unembed = params.get("unembed")
    if unembed is None:
        unembed = params["embed"].T
    logits = (x[:, 0] @ unembed).astype(jnp.float32)
    if cfg.family == "audio":
        logits = logits.reshape(-1, cfg.n_codebooks, cfg.vocab)
    return cache, logits
