"""Model zoo: composable decoder families for the assigned architectures."""
from . import attention, config, layers, mla, moe, recurrent, transformer
from .config import ModelConfig

__all__ = ["attention", "config", "layers", "mla", "moe", "recurrent",
           "transformer", "ModelConfig"]
