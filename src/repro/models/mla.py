"""Multi-head Latent Attention (DeepSeek V2/V3).

KV activations are down-projected to a compact latent c_kv (kv_lora_rank)
plus a shared RoPE key slice; per-head keys/values are up-projected from the
latent. The KV *cache* stores only [B, S, kv_lora_rank + rope_head_dim] —
the paper-critical memory saving.

Decode uses the absorbed formulation: W_UK is folded into the query
(q_lat = W_UK^T q_nope) and W_UV is applied after attending over latents, so
per-step FLOPs scale with kv_lora_rank instead of n_heads * head_dim and the
cache is read exactly once.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import apply_rope, dense_init, rmsnorm, rope_freqs

NEG_INF = -2.0e38


def init_mla_params(key: jax.Array, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    H = cfg.n_heads
    r = cfg.kv_lora_rank
    dn, dr, dv = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    ks = jax.random.split(key, 8)
    p = {}
    if cfg.q_lora_rank:
        p["wq_a"] = dense_init(ks[0], (d, cfg.q_lora_rank), dtype)
        p["q_norm_lora"] = jnp.zeros((cfg.q_lora_rank,), dtype)
        p["wq_b"] = dense_init(ks[1], (cfg.q_lora_rank, H * (dn + dr)), dtype)
    else:
        p["wq"] = dense_init(ks[0], (d, H * (dn + dr)), dtype)
    p["wkv_a"] = dense_init(ks[2], (d, r + dr), dtype)          # latent + rope key
    p["kv_norm_lora"] = jnp.zeros((r,), dtype)
    p["wk_b"] = dense_init(ks[3], (r, H * dn), dtype)           # W_UK
    p["wv_b"] = dense_init(ks[4], (r, H * dv), dtype)           # W_UV
    p["wo"] = dense_init(ks[5], (H * dv, d), dtype)
    return p


def _queries(p: dict, x: jax.Array, cfg: ModelConfig):
    B, S, _ = x.shape
    H, dn, dr = cfg.n_heads, cfg.nope_head_dim, cfg.rope_head_dim
    if cfg.q_lora_rank:
        q = rmsnorm(x @ p["wq_a"], p["q_norm_lora"], cfg.rmsnorm_eps) @ p["wq_b"]
    else:
        q = x @ p["wq"]
    q = q.reshape(B, S, H, dn + dr)
    return q[..., :dn], q[..., dn:]                              # nope, rope


def _latent(p: dict, x: jax.Array, cfg: ModelConfig, pos: jax.Array):
    """c_kv (normalized latent) and rotated shared rope key."""
    B, S, _ = x.shape
    kv = x @ p["wkv_a"]
    c = rmsnorm(kv[..., : cfg.kv_lora_rank], p["kv_norm_lora"], cfg.rmsnorm_eps)
    k_rope = kv[..., cfg.kv_lora_rank:].reshape(B, S, 1, cfg.rope_head_dim)
    cos, sin = rope_freqs(cfg.rope_head_dim, cfg.rope_theta, pos)
    k_rope = apply_rope(k_rope, cos, sin)[:, :, 0]               # [B,S,dr]
    return c, k_rope


def mla_train(p: dict, x: jax.Array, cfg: ModelConfig,
              latent: tuple | None = None) -> jax.Array:
    """Full-sequence causal MLA (non-absorbed: materialize per-head k, v)."""
    B, S, _ = x.shape
    H, dn, dr, dv = cfg.n_heads, cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    pos = jnp.arange(S)
    q_nope, q_rope = _queries(p, x, cfg)
    cos, sin = rope_freqs(dr, cfg.rope_theta, pos)
    q_rope = apply_rope(q_rope, cos, sin)
    c, k_rope = _latent(p, x, cfg, pos) if latent is None else latent
    k_nope = (c @ p["wk_b"]).reshape(B, S, H, dn)
    v = (c @ p["wv_b"]).reshape(B, S, H, dv)

    scale = (dn + dr) ** -0.5
    C = min(cfg.attn_chunk, S)
    n_chunks = S // C
    qn = jnp.moveaxis(q_nope.reshape(B, n_chunks, C, H, dn), 1, 0)
    qr = jnp.moveaxis(q_rope.reshape(B, n_chunks, C, H, dr), 1, 0)
    key_pos = jnp.arange(S)

    def chunk_body(_, inp):
        qn_c, qr_c, ci = inp
        s = (jnp.einsum("bqhd,bkhd->bhqk", qn_c.astype(jnp.bfloat16),
                        k_nope.astype(jnp.bfloat16))
             + jnp.einsum("bqhd,bkd->bhqk", qr_c.astype(jnp.bfloat16),
                          k_rope.astype(jnp.bfloat16))).astype(jnp.float32) * scale
        qpos = ci * C + jnp.arange(C)
        keep = key_pos[None, :] <= qpos[:, None]
        s = jnp.where(keep[None, None, :, :], s, NEG_INF)
        m = jnp.max(s, axis=-1, keepdims=True)
        e = jnp.exp(s - jax.lax.stop_gradient(m))
        pr = (e / jnp.sum(e, axis=-1, keepdims=True)).astype(v.dtype)
        return None, jnp.einsum("bhqk,bkhd->bqhd", pr, v)

    if cfg.attn_remat:
        chunk_body = jax.checkpoint(chunk_body)
    _, o = jax.lax.scan(chunk_body, None, (qn, qr, jnp.arange(n_chunks)))
    o = jnp.moveaxis(o, 0, 1).reshape(B, S, H * dv)
    return o @ p["wo"]


def mla_prefill(p: dict, x: jax.Array, cfg: ModelConfig):
    """Training-style attention + returns the latent cache [B,S,r+dr]."""
    B, S, _ = x.shape
    pos = jnp.arange(S)
    c, k_rope = _latent(p, x, cfg, pos)
    out = mla_train(p, x, cfg, latent=(c, k_rope))
    return out, jnp.concatenate([c, k_rope], axis=-1)


def _quant_rows(x: jax.Array, axis: int = -1):
    """Symmetric int8 quantization along ``axis`` with f32 scales."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=axis, keepdims=True) \
        / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127
                 ).astype(jnp.int8)
    return q, scale.squeeze(axis).astype(jnp.float32)


def _int8_dot(a_f: jax.Array, b_q: jax.Array, spec: str):
    """Quantize the small side and contract in int8 (MXU int8 path).

    a_f: float [..., K]; b_q: int8. Returns (int32 dot, a_scale)."""
    a_q, a_s = _quant_rows(a_f)
    out = jnp.einsum(spec, a_q.astype(jnp.int32), b_q.astype(jnp.int32))
    return out, a_s


def mla_decode(p: dict, x: jax.Array, cache, pos: jax.Array,
               cfg: ModelConfig):
    """Absorbed one-token decode against the latent cache.

    cache: [B, S_max, r + dr] (bf16), or a dict {"q": int8 [B,S,r+dr],
    "s": f32 [B,S]} when cfg.serve_quant == "int8" — the beyond-paper
    quantized-cache serving mode: scores contract in int8 and per-position
    scales are folded in after the dot, so the big cache operand is read at
    1 byte/element.
    """
    B = x.shape[0]
    H, dn, dr, dv, r = (cfg.n_heads, cfg.nope_head_dim, cfg.rope_head_dim,
                        cfg.v_head_dim, cfg.kv_lora_rank)
    q_nope, q_rope = _queries(p, x, cfg)                   # [B,1,H,*]
    cos, sin = rope_freqs(dr, cfg.rope_theta, pos[None])
    q_rope = apply_rope(q_rope, cos, sin)
    c_new, k_rope_new = _latent(p, x, cfg, pos[None])
    new_entry = jnp.concatenate([c_new, k_rope_new[:, :, None, :].reshape(B, 1, dr)], -1)
    quant = isinstance(cache, dict)
    S_max = (cache["q"] if quant else cache).shape[1]
    slot = jnp.minimum(pos, S_max - 1)

    wk_b = p["wk_b"].reshape(r, H, dn)
    q_lat = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0], wk_b)  # absorb W_UK
    q_full = jnp.concatenate([q_lat, q_rope[:, 0]], axis=-1)  # [B,H,r+dr]
    scale = (dn + dr) ** -0.5
    keep = jnp.arange(S_max) <= pos

    if quant:
        eq, es = _quant_rows(new_entry)                     # [B,1,*], [B,1]
        cache = {
            "q": jax.lax.dynamic_update_slice(cache["q"], eq, (0, slot, 0)),
            "s": jax.lax.dynamic_update_slice(cache["s"], es, (0, slot)),
        }
        s_i32, q_s = _int8_dot(q_full, cache["q"], "bhr,bsr->bhs")
        s = (s_i32.astype(jnp.float32) * q_s[..., None]
             * cache["s"][:, None, :]) * scale
        s = jnp.where(keep[None, None, :], s, NEG_INF)
        m = jnp.max(s, axis=-1, keepdims=True)
        e = jnp.exp(s - m)
        pr = e / jnp.sum(e, axis=-1, keepdims=True)         # f32 [B,H,S]
        pr_scaled = pr * cache["s"][:, None, :]             # fold cache scales
        o_i32, p_s = _int8_dot(pr_scaled, cache["q"][..., :r], "bhs,bsr->bhr")
        o_lat = o_i32.astype(jnp.float32) * p_s[..., None]
    else:
        cache = jax.lax.dynamic_update_slice(cache, new_entry, (0, slot, 0))
        c_all = cache[..., :r]
        k_rope_all = cache[..., r:]
        s = (jnp.einsum("bhr,bsr->bhs", q_lat.astype(jnp.bfloat16),
                        c_all.astype(jnp.bfloat16))
             + jnp.einsum("bhd,bsd->bhs", q_rope[:, 0].astype(jnp.bfloat16),
                          k_rope_all.astype(jnp.bfloat16))
             ).astype(jnp.float32) * scale
        s = jnp.where(keep[None, None, :], s, NEG_INF)
        m = jnp.max(s, axis=-1, keepdims=True)
        e = jnp.exp(s - m)
        pr = (e / jnp.sum(e, axis=-1, keepdims=True)).astype(c_all.dtype)
        o_lat = jnp.einsum("bhs,bsr->bhr", pr, c_all)       # attend over latents

    wv_b = p["wv_b"].reshape(r, H, dv)
    o = jnp.einsum("bhr,rhd->bhd", o_lat.astype(x.dtype), wv_b)  # absorb W_UV
    o = o.reshape(B, 1, H * dv)
    return o @ p["wo"], cache
