"""Fault tolerance: supervised training loop, straggler watchdog, elastic
restart.

The container is single-host, so hardware failure is *simulated* — but the
recovery machinery is real: the supervisor catches a step-time fault (any
exception, including an injected one), restores the newest checkpoint
(possibly onto a different mesh — elastic), fast-forwards the data stream
deterministically, and resumes. Tests kill training mid-run and assert
bit-continuation.

Straggler mitigation: on a synchronous fleet a slow host delays every
collective. The watchdog tracks a robust step-time median; a step exceeding
``straggler_factor`` x median raises a StragglerEvent, and the policy either
(a) records-and-continues (jitter absorption — TorR's own headroom
philosophy), or (b) after ``max_consecutive``, triggers a checkpoint +
elastic restart excluding the slow host (here: a re-mesh callback).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Iterator

import jax
import numpy as np

from ..checkpoint.manager import CheckpointManager


class InjectedFault(RuntimeError):
    """Simulated node failure."""


class EngineDead(RuntimeError):
    """A serving engine's worker (dispatcher/collector thread) died.

    Carries the original cause and the number of in-flight windows at the
    moment of death, so callers (and :class:`repro.serving.supervisor.
    ServeSupervisor`) can distinguish a crash from admission-control
    shedding (``WindowShed``) and know how much work needs replay. The
    message keeps the historical ``"worker died"`` phrasing so existing
    ``match="worker died"`` call sites keep working; the class subclasses
    RuntimeError for the same reason.
    """

    def __init__(self, cause: BaseException | None = None, inflight: int = 0,
                 thread: str | None = None):
        self.cause = cause
        self.inflight = inflight
        self.thread = thread
        where = f" ({thread})" if thread else ""
        why = f": {type(cause).__name__}: {cause}" if cause is not None else ""
        super().__init__(
            f"async engine worker died{where} with {inflight} windows "
            f"in flight{why}")


@dataclasses.dataclass
class FaultPlan:
    """Deterministic chaos injection for the serving engines.

    One fault, fired exactly once: on the named engine thread
    (``"dispatcher"`` or ``"collector"``; the sync ``StreamEngine`` plays
    both roles inside ``step()``), at the first step whose index is
    ``>= at_step``. The engines call :meth:`maybe_fire` at their step
    boundaries; firing raises :class:`InjectedFault`, which propagates
    through the engine's normal failure path (``_fail`` → futures fail
    with :class:`EngineDead`) — so recovery is exercised end-to-end, not
    simulated. ``kind`` is a free-form label stamped into the exception
    message (and chaos-harness artifacts).
    """

    at_step: int
    thread: str = "dispatcher"
    kind: str = "injected"
    fired: bool = False

    _THREADS = ("dispatcher", "collector")

    def __post_init__(self):
        if self.thread not in self._THREADS:
            raise ValueError(
                f"FaultPlan.thread must be one of {self._THREADS}, "
                f"got {self.thread!r}")

    def maybe_fire(self, thread: str, step: int) -> None:
        """Raise the planned fault if (thread, step) matches; else no-op."""
        if not self.fired and thread == self.thread and step >= self.at_step:
            self.fired = True
            raise InjectedFault(
                f"chaos[{self.kind}]: injected {self.thread} fault "
                f"@ step {step}")


@dataclasses.dataclass
class StragglerEvent:
    step: int
    duration: float
    median: float


@dataclasses.dataclass
class SupervisorConfig:
    ckpt_every: int = 20
    max_restarts: int = 5
    straggler_factor: float = 3.0
    straggler_window: int = 32
    max_consecutive_stragglers: int = 3


class StragglerWatchdog:
    def __init__(self, cfg: SupervisorConfig):
        self.cfg = cfg
        self.times: list[float] = []
        self.consecutive = 0
        self.events: list[StragglerEvent] = []

    def observe(self, step: int, dt: float) -> str:
        """Returns 'ok' | 'straggler' | 'evict'."""
        med = float(np.median(self.times)) if self.times else dt
        self.times.append(dt)
        if len(self.times) > self.cfg.straggler_window:
            self.times.pop(0)
        if self.times and dt > self.cfg.straggler_factor * med and \
                len(self.times) > 4:
            self.consecutive += 1
            self.events.append(StragglerEvent(step, dt, med))
            if self.consecutive >= self.cfg.max_consecutive_stragglers:
                self.consecutive = 0
                return "evict"
            return "straggler"
        self.consecutive = 0
        return "ok"


class TrainSupervisor:
    """Run a step function with checkpoint/restart under injected faults.

    ``state`` is any pytree (params, opt state, ...). ``data_stream(start)``
    must be deterministic and resumable from an arbitrary step — the
    skip-ahead contract every production loader implements.
    """

    def __init__(self, step_fn: Callable, ckpt: CheckpointManager,
                 cfg: SupervisorConfig = SupervisorConfig(),
                 on_evict: Callable | None = None):
        self.step_fn = step_fn
        self.ckpt = ckpt
        self.cfg = cfg
        self.watchdog = StragglerWatchdog(cfg)
        self.on_evict = on_evict
        self.restarts = 0

    def run(self, state, data_stream: Callable[[int], Iterator],
            n_steps: int, start_step: int = 0,
            fault_at: int | None = None, shardings=None):
        step = start_step
        while step < n_steps:
            try:
                stream = data_stream(step)
                for batch in stream:
                    if step >= n_steps:
                        break
                    t0 = time.perf_counter()
                    if fault_at is not None and step == fault_at:
                        fault_at = None  # fire once
                        raise InjectedFault(f"simulated node loss @ step {step}")
                    state = self.step_fn(state, batch)
                    dt = time.perf_counter() - t0
                    verdict = self.watchdog.observe(step, dt)
                    if verdict == "evict" and self.on_evict is not None:
                        self.ckpt.save(step + 1, state)
                        state, shardings = self.on_evict(state)
                    step += 1
                    if step % self.cfg.ckpt_every == 0:
                        self.ckpt.save(step, state)
            except InjectedFault:
                self.restarts += 1
                if self.restarts > self.cfg.max_restarts:
                    raise
                latest = self.ckpt.latest_step()
                if latest is None:
                    step = start_step  # cold restart
                    continue
                state, step = self.ckpt.restore(state, shardings=shardings)
            else:
                break
        self.ckpt.save(step, state)
        return state, step
