"""Jitted step builders: train / prefill / decode, with sharding plumbing.

These are the functions the launcher jits against the production mesh and
the dry-run lowers with ShapeDtypeStruct inputs.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from ..models import transformer as tf
from ..models.config import ModelConfig
from ..optim import adamw
from . import sharding as shd


def make_train_step(cfg: ModelConfig, opt_cfg: adamw.OptimConfig, mesh=None):
    def train_step(params, opt_state, batch):
        def loss_fn(p):
            return tf.forward_train(p, batch, cfg, mesh=mesh)

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params, opt_state, om = adamw.apply_updates(params, grads, opt_state, opt_cfg)
        return params, opt_state, {**metrics, **om}

    return train_step


def make_decode_step(cfg: ModelConfig):
    def decode_step(params, cache, tokens):
        return tf.decode_step(params, cache, tokens, cfg)

    return decode_step


def make_prefill(cfg: ModelConfig, s_max: int | None = None):
    def prefill_step(params, batch):
        return tf.prefill(params, batch, cfg, s_max=s_max)

    return prefill_step


# ---------------------------------------------------------------------------
# Lowering helpers (shared by dryrun and the launchers)
# ---------------------------------------------------------------------------

def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(lambda k: tf.init_params(k, cfg), jax.random.PRNGKey(0))


def lower_cell(cfg: ModelConfig, shape: dict, mesh, *,
               opt_cfg: adamw.OptimConfig | None = None,
               donate: bool = True):
    """Build + lower the step for one (arch x shape x mesh) cell.

    Returns (lowered, meta) where meta records the abstract shapes used.
    """
    from ..configs.registry import input_specs  # local to avoid cycle

    mode = shape["mode"]
    params_abs = abstract_params(cfg)
    p_shard = shd.params_sharding(params_abs, mesh)
    batch_abs = input_specs(cfg, shape)
    b_shard = shd.batch_sharding(batch_abs, mesh)

    if mode == "train":
        opt_cfg = opt_cfg or adamw.OptimConfig()
        step = make_train_step(cfg, opt_cfg,
                               mesh=mesh if cfg.moe_groups else None)
        opt_abs = jax.eval_shape(adamw.init_opt_state, params_abs)
        o_shard = shd.params_sharding(opt_abs, mesh)
        fn = jax.jit(
            step,
            in_shardings=(p_shard, o_shard, b_shard),
            donate_argnums=(0, 1) if donate else (),
        )
        lowered = fn.lower(params_abs, opt_abs, batch_abs)
        return lowered, {"mode": mode}

    if mode == "prefill":
        # dry-run cells lower with cache capacity == prompt length so the
        # roofline terms measure exactly the assigned shape
        step = make_prefill(cfg, s_max=shape["seq_len"])
        fn = jax.jit(step, in_shardings=(p_shard, b_shard))
        lowered = fn.lower(params_abs, batch_abs)
        return lowered, {"mode": mode}

    # decode: one token against an S-long cache
    B, S = shape["global_batch"], shape["seq_len"]
    cache_abs = jax.eval_shape(lambda: tf.init_cache(cfg, B, S))
    c_shard = shd.cache_sharding(cache_abs, mesh)
    step = make_decode_step(cfg)
    fn = jax.jit(
        step,
        in_shardings=(p_shard, c_shard, b_shard["tokens"]),
        donate_argnums=(1,) if donate else (),
    )
    lowered = fn.lower(params_abs, cache_abs, batch_abs["tokens"])
    return lowered, {"mode": mode}
