"""Sharding rules: DP / TP / EP / SP over the production mesh.

Parameters follow Megatron-style column/row parallelism over the 'model'
axis; MoE experts are expert-parallel over 'model'; batch shards over
('pod', 'data'). Decode caches pick, per tensor, the best shardable axis:
KV heads when divisible by the model-axis size, else sequence (flash-decode
style), else head_dim — so every (arch x shape) cell partitions without
padding.

Rules are *name-based on the trailing dims* and padded with leading Nones,
so the same rule covers a flat weight, a layer-stacked weight [L, ...] and a
vmapped group stack.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# trailing-dims spec per parameter leaf name
_COL = ("_col", (None, "model"))     # [in, out_sharded]
_ROW = ("_row", ("model", None))     # [in_sharded, out]

_PARAM_RULES: dict[str, tuple] = {
    # embeddings
    "embed": ("model", None),        # [V, d] vocab-sharded
    "unembed": (None, "model"),
    # attention & projections (column-parallel)
    "wq": _COL[1], "wk": _COL[1], "wv": _COL[1],
    "wq_a": (None, None), "wq_b": _COL[1],
    "wkv_a": (None, None), "wk_b": _COL[1], "wv_b": _COL[1],
    # row-parallel outputs
    "wo": _ROW[1], "w_down": _ROW[1], "w_out": _ROW[1],
    # MLPs / recurrent branches (column-parallel)
    "w_gate": _COL[1], "w_up": _COL[1], "w_z": _COL[1],
    "w_gate_in": _COL[1], "w_in": _COL[1], "w_ifzo": _COL[1],
    "w_up_gate": _COL[1],
    "shared_gate": _COL[1], "shared_up": _COL[1], "shared_down": _ROW[1],
    # gates / small
    "router": (None, None), "w_if": (None, None), "proj": (None, None),
    "wa": _COL[1], "wx": _COL[1],
    "conv_w": (None, "model"),
    "lam": ("model",), "gn_scale": ("model",),
    "r_ifzo": (None, None, None),
    "head": (None, None), "head_b": (None,),
}

# MoE expert stacks: leading experts dim is expert-parallel
_MOE_EXPERT_RULES = {
    "w_gate": ("model", None, None),
    "w_up": ("model", None, None),
    "w_down": ("model", None, None),
}

_REPLICATED_MARKERS = ("ln", "norm", "b_", "gate", "margin")


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if isinstance(entry, jax.tree_util.DictKey):
            return str(entry.key)
        if isinstance(entry, jax.tree_util.GetAttrKey):
            return entry.name
    return ""


def _in_moe(path) -> bool:
    return any(isinstance(e, jax.tree_util.DictKey) and str(e.key) == "moe"
               for e in path)


def param_spec(path, leaf) -> P:
    name = _leaf_name(path)
    rules = _MOE_EXPERT_RULES if _in_moe(path) and name in _MOE_EXPERT_RULES \
        else _PARAM_RULES
    if name in rules:
        trailing = rules[name]
        pad = leaf.ndim - len(trailing)
        if pad < 0:   # e.g. a 1-D leaf hitting a 2-D rule; replicate
            return P()
        return P(*((None,) * pad + tuple(trailing)))
    if name.startswith(_REPLICATED_MARKERS) or name.endswith("_norm") or \
            "norm" in name:
        return P()
    return P()


def _drop_indivisible(spec: P, leaf, mesh: Mesh) -> P:
    """Replace any sharded dim the leaf's shape can't divide with None."""
    out = []
    for dim, axes in enumerate(spec):
        if axes is None:
            out.append(None)
            continue
        size = 1
        for a in (axes if isinstance(axes, tuple) else (axes,)):
            size *= mesh.shape[a]
        out.append(axes if leaf.shape[dim] % size == 0 else None)
    return P(*out)


def params_pspecs(params, mesh: Mesh | None = None) -> Any:
    if mesh is None:
        return jax.tree_util.tree_map_with_path(param_spec, params)
    return jax.tree_util.tree_map_with_path(
        lambda p, l: _drop_indivisible(param_spec(p, l), l, mesh), params)


def params_sharding(params, mesh: Mesh):
    return jax.tree_util.tree_map_with_path(
        lambda p, l: NamedSharding(mesh, _drop_indivisible(param_spec(p, l), l, mesh)),
        params)


# ---------------------------------------------------------------------------
# Activations / batches / caches
# ---------------------------------------------------------------------------

def batch_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _divisible(n: int, mesh: Mesh, axes) -> bool:
    size = 1
    for a in (axes if isinstance(axes, tuple) else (axes,)):
        size *= mesh.shape[a]
    return n % size == 0 and n >= size


def batch_spec(mesh: Mesh, leaf) -> P:
    """Tokens/labels/vision: shard dim0 over (pod, data) when divisible."""
    ba = batch_axes(mesh)
    if _divisible(leaf.shape[0], mesh, ba):
        return P(ba, *([None] * (leaf.ndim - 1)))
    if _divisible(leaf.shape[0], mesh, "data"):
        return P("data", *([None] * (leaf.ndim - 1)))
    return P(*([None] * leaf.ndim))


def batch_sharding(batch, mesh: Mesh):
    return jax.tree.map(lambda l: NamedSharding(mesh, batch_spec(mesh, l)), batch)


def cache_spec(path, leaf, mesh: Mesh) -> P:
    """Decode-cache sharding. Layout conventions (see models.transformer):

    kv        [G(, pos), B, S, Hkv, dh]
    ckv       [G, B, S, r+dr]
    cross_kv  [G, B, Nv, Hkv, dh]
    rec.h     [G, n_rec, B, w]        rec.conv [G, n_rec, B, cw, w]
    mlstm.C   [G, n_m, B, H, dh, dh]  mlstm.n [G, n_m, B, H, dh]
    mlstm.m   [G, n_m, B, H]          mlstm.conv [G, n_m, B, cw, din]
    slstm.*   [G, B, d] / [G, B, H]
    """
    names = [str(e.key) for e in path if isinstance(e, jax.tree_util.DictKey)]
    if not names:
        return P()
    top = names[0]
    nd = leaf.ndim
    spec = [None] * nd
    msize = mesh.shape["model"]

    def shard_batch(dim):
        ba = batch_axes(mesh)
        if _divisible(leaf.shape[dim], mesh, ba):
            spec[dim] = ba
        elif _divisible(leaf.shape[dim], mesh, "data"):
            spec[dim] = "data"

    if top == "pos":
        return P()
    leafname = names[-1]
    if top in ("kv", "cross_kv"):
        if leafname in ("ks", "vs"):      # int8-cache scales: [.., B, S, Hkv]
            b_dim, s_dim, h_dim = nd - 3, nd - 2, nd - 1
            shard_batch(b_dim)
            if leaf.shape[h_dim] % msize == 0:
                spec[h_dim] = "model"
            elif top == "kv" and leaf.shape[s_dim] % msize == 0:
                spec[s_dim] = "model"
            return P(*spec)
        # k/v (or kq/vq) trailing dims: [B, S, Hkv, dh]
        b_dim, s_dim, h_dim, d_dim = nd - 4, nd - 3, nd - 2, nd - 1
        shard_batch(b_dim)
        if leaf.shape[h_dim] % msize == 0:
            spec[h_dim] = "model"
        elif top == "kv" and leaf.shape[s_dim] % msize == 0:
            spec[s_dim] = "model"
        elif leaf.shape[d_dim] % msize == 0:
            spec[d_dim] = "model"
        return P(*spec)
    if top.startswith("ckv"):   # 'ckv' and 'ckv_prefix' (dense-prefix MLA)
        if leafname == "s":               # int8 latent scales [G, B, S]
            b_dim, s_dim = nd - 2, nd - 1
            shard_batch(b_dim)
            if leaf.shape[s_dim] % msize == 0:
                spec[s_dim] = "model"
            return P(*spec)
        b_dim, s_dim = nd - 3, nd - 2
        shard_batch(b_dim)
        if leaf.shape[s_dim] % msize == 0:
            spec[s_dim] = "model"
        return P(*spec)
    if top == "rec":
        shard_batch(nd - 2 if names[-1] == "h" else nd - 3)
        if leaf.shape[nd - 1] % msize == 0:
            spec[nd - 1] = "model"
        return P(*spec)
    if top == "mlstm":
        leafname = names[-1]
        if leafname == "C":
            shard_batch(2)
            if leaf.shape[4] % msize == 0:
                spec[4] = "model"
        elif leafname in ("n", "conv"):
            shard_batch(2)
            if leaf.shape[nd - 1] % msize == 0:
                spec[nd - 1] = "model"
        elif leafname == "m":
            shard_batch(2)
        return P(*spec)
    if top == "slstm":
        shard_batch(1)
        if names[-1] != "m" and leaf.shape[nd - 1] % msize == 0:
            spec[nd - 1] = "model"
        return P(*spec)
    return P()


def cache_pspecs(cache, mesh: Mesh):
    return jax.tree_util.tree_map_with_path(
        lambda p, l: cache_spec(p, l, mesh), cache)


def cache_sharding(cache, mesh: Mesh):
    return jax.tree_util.tree_map_with_path(
        lambda p, l: NamedSharding(mesh, cache_spec(p, l, mesh)), cache)


def abstract_tree(init_fn, *args, **kwargs):
    """eval_shape an init function: ShapeDtypeStruct tree, no allocation."""
    return jax.eval_shape(init_fn, *args, **kwargs)


# ---------------------------------------------------------------------------
# Multi-stream serving: stacked per-stream state over a 1-D stream mesh
# ---------------------------------------------------------------------------
# The multi-stream TorR engine (serving.stream_engine / serving.async_engine)
# stacks every per-stream leaf with a leading stream-slot axis [S, ...].
# Streams are independent (the batched step is an exact vmap of the window
# FSM), so the only sensible partitioning is: shard the leading S axis,
# replicate the shared item memory. These helpers keep that rule in one
# place; the engine pads its slot count to a multiple of the device count so
# the leading axis always divides.

STREAM_AXIS = "stream"


def stream_mesh(n_devices: int | None = None) -> Mesh:
    """1-D mesh over (the first) ``n_devices`` devices for stream sharding."""
    devs = jax.devices()
    n = len(devs) if n_devices in (None, 0) else n_devices
    if n > len(devs):
        raise ValueError(f"requested {n} devices, only {len(devs)} present")
    return Mesh(np.asarray(devs[:n]), (STREAM_AXIS,))


def pad_stream_slots(n_slots: int, mesh: Mesh | None) -> int:
    """Round a slot count up to a multiple of the mesh's stream-axis size."""
    if mesh is None:
        return n_slots
    n_dev = mesh.shape[STREAM_AXIS]
    return -(-n_slots // n_dev) * n_dev


def stream_spec(leaf) -> P:
    """Shard the leading stream-slot axis; everything trailing replicated."""
    return P(STREAM_AXIS, *([None] * (leaf.ndim - 1)))


def stream_sharding(tree, mesh: Mesh):
    """NamedSharding tree for stacked per-stream state / batches.

    Every leaf must carry the leading [S] stream axis with S divisible by
    the mesh (guaranteed by :func:`pad_stream_slots`)."""
    return jax.tree.map(lambda l: NamedSharding(mesh, stream_spec(l)), tree)


def replicated_sharding(tree, mesh: Mesh):
    """Fully-replicated NamedSharding tree (shared item memory)."""
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)
