"""GPipe-style pipeline parallelism over the 'pod' axis.

The production mesh's `pod` axis defaults to outer data-parallel; this
module offers the alternative: each pod holds a contiguous slice of the
layer stack and microbatches stream through via `ppermute`. The schedule is
the classic scan over T = n_micro + n_stages − 1 ticks; because the whole
loop is jax-differentiable (ppermute has a transpose rule), `jax.grad`
through the pipelined forward yields the reverse-pipeline backward without
hand-written VJPs.

This is layer-granular (the stage function applies `layers_per_stage`
scanned layer groups), so it composes with the in-stage TP/DP sharding:
mesh ('pod'=stages, 'data', 'model').
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def pipeline_apply(
    stage_fn: Callable,       # (stage_params, x) -> x
    stage_params,             # pytree, leaves [n_stages, ...] (stage-major)
    x: jax.Array,             # [n_micro, micro_batch, ...] global microbatches
    mesh: Mesh,
    axis: str = "pod",
) -> jax.Array:
    """Run x through n_stages pipeline stages; returns outputs [n_micro, ...].

    ``stage_params`` leaves carry a leading stage dim sharded over ``axis``;
    ``x`` microbatches are replicated across ``axis`` (each stage sees the
    stream; only stage 0 consumes, only the last emits).
    """
    n_stages = mesh.shape[axis]

    def local(params, xs):
        # params: stage-local pytree (leading dim 1) ; xs: [n_micro, mb, ...]
        params = jax.tree.map(lambda t: t[0], params)
        stage = jax.lax.axis_index(axis)
        n_micro = xs.shape[0]
        T = n_micro + n_stages - 1
        buf = jnp.zeros_like(xs[0])
        outs = jnp.zeros_like(xs)

        def tick(carry, t):
            buf, outs = carry
            # stage 0 ingests microbatch t (when valid); others use recv
            x_in = jnp.where(stage == 0, xs[jnp.clip(t, 0, n_micro - 1)], buf)
            y = stage_fn(params, x_in)
            # valid iff this stage is processing a real microbatch at tick t
            mb_idx = t - stage
            valid = (mb_idx >= 0) & (mb_idx < n_micro)
            y = jnp.where(valid, y, jnp.zeros_like(y))
            # last stage writes output; others forward to the next stage
            outs = jax.lax.cond(
                (stage == n_stages - 1) & valid,
                lambda o: o.at[jnp.clip(mb_idx, 0, n_micro - 1)].set(y),
                lambda o: o, outs)
            buf = jax.lax.ppermute(
                y, axis, [(i, (i + 1) % n_stages) for i in range(n_stages)])
            return (buf, outs), None

        (buf, outs), _ = jax.lax.scan(tick, (buf, outs), jnp.arange(T))
        # only the last stage wrote real outputs (zeros elsewhere): a psum
        # over the stage axis broadcasts them to every pod, replicated out
        return jax.lax.psum(outs, axis)

    fn = shard_map(
        local, mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
        check_rep=False)
    return fn(stage_params, x)


def split_stages(params, n_stages: int):
    """Reshape layer-stacked params [L, ...] -> [n_stages, L/n_stages, ...]."""
    def reshape(t):
        L = t.shape[0]
        assert L % n_stages == 0, (L, n_stages)
        return t.reshape(n_stages, L // n_stages, *t.shape[1:])
    return jax.tree.map(reshape, params)
