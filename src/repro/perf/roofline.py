"""Roofline-term extraction from compiled dry-run artifacts.

Target hardware: TPU v5e — 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI.

    compute term    = HLO_FLOPs / (chips * peak_FLOPs)
    memory term     = HLO_bytes / (chips * HBM_bw)
    collective term = collective_bytes / (chips * link_bw)

``cost_analysis`` on a partitioned executable reports *per-device* flops /
bytes; we scale by device count to get global HLO terms (so the division by
chips above recovers per-chip time). Collective bytes are not in
cost_analysis: we parse the optimized HLO and sum the (per-device) output
bytes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute, times the device count — i.e. total bytes crossing the
fabric under a ring schedule (per-chip link time ~= local bytes / link_bw).
"""
from __future__ import annotations

import dataclasses
import json
import re

PEAK_FLOPS = 197e12       # bf16 / chip
HBM_BW = 819e9            # bytes/s / chip
LINK_BW = 50e9            # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of all array shapes appearing in a result-type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes_local(hlo_text: str) -> dict:
    """Per-device output bytes of each collective kind in optimized HLO."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if " = " not in stripped:
            continue
        lhs, rhs = stripped.split(" = ", 1)
        op = rhs.split("(", 1)[0].strip()
        # ops look like: bf16[8,128]{1,0} all-reduce(...), or tuple results
        m = re.match(r"^((?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?))\s+([a-z0-9\-\.]+)",
                     rhs)
        if not m:
            continue
        shape_str, opname = m.group(1), m.group(2)
        opbase = opname.split(".")[0]
        # normalize e.g. all-reduce-start
        for coll in _COLLECTIVES:
            if opbase == coll or opbase == coll + "-start":
                out[coll] += _shape_bytes(shape_str)
                counts[coll] += 1
                break
    out["_counts"] = counts
    return out


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_global: float
    bytes_global: float
    coll_bytes_global: float
    coll_breakdown: dict
    model_flops: float
    memory_per_device: dict

    @property
    def t_compute(self) -> float:
        return self.flops_global / (self.chips * PEAK_FLOPS)

    @property
    def t_memory(self) -> float:
        return self.bytes_global / (self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_global / (self.chips * LINK_BW)

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_frac(self) -> float:
        return self.model_flops / self.flops_global if self.flops_global else 0.0

    @property
    def roofline_frac(self) -> float:
        """Fraction of the peak implied by the dominant term if compute-bound
        at the model's useful FLOPs: MODEL_FLOPS / (chips*peak) / t_bound."""
        ideal = self.model_flops / (self.chips * PEAK_FLOPS)
        return ideal / self.t_bound if self.t_bound else 0.0

    def to_dict(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "flops_global": self.flops_global,
            "bytes_global": self.bytes_global,
            "coll_bytes_global": self.coll_bytes_global,
            "coll_breakdown": self.coll_breakdown,
            "model_flops": self.model_flops,
            "memory_per_device": self.memory_per_device,
            "t_compute": self.t_compute, "t_memory": self.t_memory,
            "t_collective": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_frac": self.useful_flops_frac,
            "roofline_frac": self.roofline_frac,
        }


def analyze(compiled, *, arch: str, shape: str, mesh_name: str, chips: int,
            model_flops: float) -> Roofline:
    """Roofline terms from the compiled executable.

    FLOPs / traffic / collectives come from the trip-count-aware HLO static
    analyzer (xla's cost_analysis counts while bodies once — see
    hlo_analyze); memory figures from compiled.memory_analysis().
    """
    from . import hlo_analyze

    hlo = compiled.as_text()
    an = hlo_analyze.analyze_text(hlo)
    flops_local = an.flops
    bytes_local = an.bytes_traffic
    coll = dict(an.collective_bytes)
    counts = dict(an.collective_counts)
    counts["bytes_pessimistic_global"] = an.bytes_traffic_pessimistic
    coll_local = an.total_collective_bytes()
    try:
        ma = compiled.memory_analysis()
        mem = {
            "argument_bytes": int(getattr(ma, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(ma, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(ma, "temp_size_in_bytes", 0)),
            "peak_bytes": int(getattr(ma, "peak_memory_in_bytes", 0) or 0),
        }
    except Exception:  # pragma: no cover - backend-dependent
        mem = {}
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        flops_global=flops_local * chips,
        bytes_global=bytes_local * chips,
        coll_bytes_global=coll_local * chips,
        coll_breakdown={k: v * chips for k, v in coll.items()} | {
            "counts": counts},
        model_flops=model_flops,
        memory_per_device=mem,
    )


def model_flops_for(cfg, shape: dict) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE); D = tokens processed.

    decode: D = global_batch (one token each). train: forward+backward = 6ND.
    prefill/decode (inference): 2*N*D forward-only.
    """
    n_active = cfg.active_param_count()
    tokens = shape["global_batch"] * (shape["seq_len"] if shape["mode"] in
                                      ("train", "prefill") else 1)
    mult = 6.0 if shape["mode"] == "train" else 2.0
    return mult * n_active * tokens
