"""Static analyzer for optimized HLO text with loop trip-count scaling.

``compiled.cost_analysis()`` counts a while (lax.scan) body ONCE, which
undercounts layer-scanned models by ~n_layers. This analyzer parses the
optimized HLO, builds the computation call graph (while bodies carry
``backend_config={"known_trip_count":{"n":...}}``), and propagates execution
multiplicity so that:

  * FLOPs   = sum over dot/convolution ops of 2*prod(out)*prod(contracted),
              times multiplicity (dots inside fusion computations included);
  * bytes   = HBM-traffic proxy: sum of (operand + output) bytes of top-level
              ops in executed computations. Ops *inside* fusion computations
              are excluded (a fusion is one kernel; its interior never
              round-trips HBM) — the fusion op itself is counted;
  * collectives = per-kind moved bytes (max of operand/output), times
              multiplicity.

All quantities are per-device (the SPMD module is the per-device program);
multiply by device count for global terms.
"""
from __future__ import annotations

import dataclasses
import json
import math
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "u2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1, "f8e5m2fnuz": 1,
    "f8e4m3fnuz": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*)$")
_KIND_RE = re.compile(r"^([\w\-]+)\((.*)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%([\w\.\-]+)\s*\((.*?)\)\s*->")
_PARAM_RE = re.compile(r"([\w\.\-]+):\s*(\(?[a-z0-9]+\[[0-9,]*\][^,)]*)")
_TRIP_RE = re.compile(r"\"known_trip_count\":\{\"n\":\"(\d+)\"\}")

_COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                     "all-to-all", "collective-permute")


def _shape_elems_bytes(shape_str: str) -> tuple[int, int]:
    elems = 0
    nbytes = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        nbytes += n * _DTYPE_BYTES[dt]
    return elems, nbytes


def _split_type(rhs: str) -> tuple[str | None, str]:
    """Split 'TYPE kind(args...)' where TYPE is 'dtype[..]{..}' or a tuple
    '(t1, t2, ...)' possibly containing '/*index=N*/' comments."""
    rhs = rhs.lstrip()
    if rhs.startswith("("):
        depth = 0
        for i, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    return rhs[: i + 1], rhs[i + 1:].lstrip()
        return None, ""
    sp = rhs.find(" ")
    if sp < 0:
        return None, ""
    return rhs[:sp], rhs[sp + 1:].lstrip()


def _split_operands(arg_str: str) -> tuple[list[str], str]:
    """Split 'op(...)rest' argument text into operand names and attr tail."""
    depth = 0
    for i, ch in enumerate(arg_str):
        if ch in "({[":
            depth += 1
        elif ch in ")}]":
            if depth == 0:
                operands = arg_str[:i]
                tail = arg_str[i + 1:]
                names = re.findall(r"%([\w\.\-]+)", operands)
                return names, tail
            depth -= 1
    return re.findall(r"%([\w\.\-]+)", arg_str), ""


@dataclasses.dataclass
class Op:
    name: str
    shape_str: str
    kind: str
    operands: list
    tail: str


@dataclasses.dataclass
class Computation:
    name: str
    params: dict                      # param name -> shape str
    ops: list                         # list[Op]


def parse_hlo(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    entry_name = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        if not line.startswith(" ") and ("{" in line) and ("->" in line):
            m = _COMP_HDR_RE.match(line.strip())
            if m:
                params = {p: s for p, s in _PARAM_RE.findall(m.group(2))}
                cur = Computation(m.group(1), params, [])
                comps[cur.name] = cur
                if line.lstrip().startswith("ENTRY"):
                    entry_name = cur.name
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, rhs = m.groups()
        shape_str, rest = _split_type(rhs)
        if shape_str is None:
            continue
        km = _KIND_RE.match(rest)
        if not km:
            continue
        kind, arg_str = km.groups()
        operands, tail = _split_operands(arg_str)
        cur.ops.append(Op(name, shape_str, kind, operands, tail))
    if entry_name:
        comps["__entry__"] = comps[entry_name]
    return comps


def _dot_flops(op: Op, shapes: dict) -> float:
    """2 * prod(output) * prod(contracting dims of lhs)."""
    out_elems, _ = _shape_elems_bytes(op.shape_str)
    lhs_shape = shapes.get(op.operands[0], "") if op.operands else ""
    m = _SHAPE_RE.search(lhs_shape)
    if not m:
        return 0.0
    dims = [int(d) for d in m.group(2).split(",") if d]
    cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.tail)
    contracted = 1
    if cm and cm.group(1):
        for idx in cm.group(1).split(","):
            i = int(idx)
            if i < len(dims):
                contracted *= dims[i]
    return 2.0 * out_elems * contracted


def _conv_flops(op: Op, shapes: dict) -> float:
    out_elems, _ = _shape_elems_bytes(op.shape_str)
    rhs_shape = shapes.get(op.operands[1], "") if len(op.operands) > 1 else ""
    m = _SHAPE_RE.search(rhs_shape)
    if not m:
        return 0.0
    kdims = [int(d) for d in m.group(2).split(",") if d]
    # kernel = spatial... x in_ch x out_ch; flops per output elem = 2*prod/out_ch
    if not kdims:
        return 0.0
    per_out = 2 * max(1, math.prod(kdims) // max(kdims[-1], 1))
    return float(out_elems * per_out)


_SKIP_BYTES_KINDS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "iota", "partition-id", "replica-id",
}

# Ops that materialize buffers in HBM on a TPU-grade compiler. Elementwise
# chains (add/mul/convert/select/...) fuse into producers/consumers and are
# counted as free; a `fusion` op counts only if its computation transitively
# contains an anchor (e.g. CPU-wrapped reduce), since a pure-elementwise
# fusion would melt into its neighbors on TPU.
_ANCHOR_KINDS = {
    "dot", "convolution", "reduce", "reduce-window", "sort", "scatter",
    "gather", "dynamic-slice", "dynamic-update-slice", "concatenate",
    "custom-call", "rng", "rng-bit-generator", "cholesky", "triangular-solve",
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "pad", "reverse",
}

# Ops that read only a slice-sized window of their operand.
_SLICER_KINDS = {"dynamic-slice", "slice", "gather"}

# Pure dtype/layout plumbing: a fusion whose interior contains only these is
# a CPU-backend artifact (e.g. oneDNN requires f32 operands, so XLA-CPU
# materializes f32 copies of bf16 weights before every dot). A TPU compile
# consumes bf16 natively, so such fusions carry no HBM traffic of their own.
_PLUMBING_KINDS = {"convert", "bitcast", "copy", "parameter", "transpose",
                   "reshape", "broadcast", "tuple", "get-tuple-element"}


def _is_plumbing_comp(comp: "Computation") -> bool:
    return all(op.kind in _PLUMBING_KINDS for op in comp.ops)


def _is_slicing_plumbing_comp(comp: "Computation") -> bool:
    """Slice + dtype/layout plumbing only (e.g. `w[i]` layer-weight slicing
    followed by a CPU-backend f32 convert). On TPU both melt into the
    consuming dot: the fusion itself carries no traffic and consumers charge
    the slice-sized source-dtype bytes."""
    allowed = _PLUMBING_KINDS | _SLICER_KINDS | {"constant"}
    return all(op.kind in allowed for op in comp.ops) and any(
        op.kind in _SLICER_KINDS for op in comp.ops)


def _slicer_output_bytes(comp: "Computation") -> int:
    return sum(_shape_elems_bytes(op.shape_str)[1]
               for op in comp.ops if op.kind in _SLICER_KINDS)


def _resolve_operand_bytes(name: str, shapes: dict, defs: dict,
                           comps: dict | None, depth: int = 0) -> int:
    """Bytes actually read for an operand: walk back through dtype/layout
    plumbing (convert/bitcast/copy chains and pure-plumbing fusions) and
    charge the smallest shape on the chain — a bf16 weight converted to f32
    for a CPU dot is read once as bf16 on TPU."""
    best = _shape_elems_bytes(shapes.get(name, ""))[1]
    cur = name
    while depth < 6 and cur in defs:
        op = defs[cur]
        if op.kind in ("convert", "bitcast", "copy", "reshape", "transpose"):
            if not op.operands:
                break
            cur = op.operands[0]
        elif op.kind == "fusion" and comps is not None:
            m = re.search(r"calls=%([\w\.\-]+)", op.tail)
            callee = comps.get(m.group(1)) if m else None
            if callee is None:
                break
            if _is_slicing_plumbing_comp(callee):
                # consumer reads only the slice window, at source dtype
                b = _slicer_output_bytes(callee)
                return min(best, b) if b else best
            if not _is_plumbing_comp(callee) or len(op.operands) != 1:
                break
            cur = op.operands[0]
        else:
            break
        depth += 1
        b = _shape_elems_bytes(shapes.get(cur, ""))[1]
        if b:
            best = min(best, b)
    return best


def _op_traffic(op: Op, shapes: dict, comps: dict | None,
                defs: dict | None = None) -> float:
    """HBM traffic of one top-level op, with in-place/slice semantics.

    * slicers read+write only the slice (2x output bytes);
    * dynamic-update-slice updates in place (2x update bytes);
    * scatter moves 2x updates (+ indices);
    * fusion charges its output write plus, per fusion parameter, either the
      slice-sized reads (if every interior consumer is a slicer) or the full
      parameter bytes — this models XLA fusing `w[i]` weight slicing into
      consumers without charging the whole scanned weight stack.
    """
    _, out_b = _shape_elems_bytes(op.shape_str)
    kind = op.kind

    defs = defs or {}

    def operand_bytes(i):
        if i < len(op.operands) and op.operands[i] in shapes:
            return _resolve_operand_bytes(op.operands[i], shapes, defs, comps)
        return 0

    if kind in _SLICER_KINDS:
        return 2.0 * out_b
    if kind == "dynamic-update-slice":
        return 2.0 * operand_bytes(1)
    if kind == "scatter":
        n = len(op.operands)
        upd = operand_bytes(n - 1)
        idx = operand_bytes(1) if n >= 3 else 0
        return 2.0 * upd + idx
    if kind == "fusion" and comps is not None:
        m = re.search(r"calls=%([\w\.\-]+)", op.tail)
        comp = comps.get(m.group(1)) if m else None
        if comp is not None:
            interior = dict(comp.params)
            defs = {}
            for o in comp.ops:
                interior[o.name] = o.shape_str
                defs[o.name] = o

            def resolve(name, depth=0):
                """Follow bitcast/copy/convert/reshape chains to a source."""
                while depth < 8 and name in defs and defs[name].kind in (
                        "bitcast", "copy", "convert", "reshape", "transpose"):
                    if not defs[name].operands:
                        break
                    name = defs[name].operands[0]
                    depth += 1
                return name

            dus_ops = [o for o in comp.ops
                       if o.kind == "dynamic-update-slice"]
            dus_buffer_srcs = {resolve(o.operands[0]) for o in dus_ops
                               if o.operands}
            charge = 0.0
            if dus_ops:
                # in-place stacking: traffic = read+write of the updated
                # window only (the buffer itself is aliased, not copied)
                for o in dus_ops:
                    if len(o.operands) > 1 and o.operands[1] in interior:
                        charge += 2.0 * _shape_elems_bytes(
                            interior[o.operands[1]])[1]
            else:
                charge = float(out_b)
            for pname, pshape in comp.params.items():
                if pname in dus_buffer_srcs:
                    continue  # aliased in-place buffer, charged via updates
                consumers = [o for o in comp.ops if pname in o.operands]
                if consumers and all(c.kind in _SLICER_KINDS
                                     for c in consumers):
                    charge += sum(_shape_elems_bytes(c.shape_str)[1]
                                  for c in consumers)
                else:
                    charge += _shape_elems_bytes(pshape)[1]
            return charge
    in_b = sum(operand_bytes(i) for i in range(len(op.operands)))
    return float(out_b + in_b)


def materialized_shapes(
    text: str, include_fusion_interiors: bool = True
) -> set:
    """All (dtype, dims) pairs produced by real ops anywhere in the module.

    The fused-kernel acceptance check: the jitted window step must not
    contain an ``[N, M, W]``-shaped xor/popcount intermediate anywhere —
    not even inside a fusion computation (a fusion interior is VMEM-resident
    on TPU, but an intermediate that *exists* in the program still bounds
    the fusion's working set; the fused kernel keeps it tile-sized by
    construction). ``include_fusion_interiors=False`` restricts to
    top-level ops of executed computations (the HBM-materialization view).
    Shape-plumbing ops (parameter/tuple/bitcast/iota/...) are skipped.
    """
    comps = parse_hlo(text)
    skip = _SKIP_BYTES_KINDS | {"broadcast", "reshape", "transpose", "copy"}
    # which computations are fusion interiors (called via calls= from a
    # fusion op) — only needed for the restricted view
    interior = set()
    if not include_fusion_interiors:
        for comp in comps.values():
            for op in comp.ops:
                if op.kind == "fusion":
                    for m in re.finditer(r"calls=%([\w\.\-]+)", op.tail):
                        interior.add(m.group(1))
    out = set()
    for name, comp in comps.items():
        if name == "__entry__" or name in interior:
            continue
        for op in comp.ops:
            if op.kind in skip:
                continue
            for dt, dims in _SHAPE_RE.findall(op.shape_str):
                out.add((dt, tuple(int(d) for d in dims.split(",") if d)))
    return out


def has_materialized_shape(
    text: str, dims, dtype: str | None = None,
    include_fusion_interiors: bool = True,
) -> bool:
    """True iff some real op in the module produces a ``dims``-shaped value
    (of ``dtype``, any when None). See :func:`materialized_shapes`."""
    dims = tuple(dims)
    return any(
        d == dims and (dtype is None or dt == dtype)
        for dt, d in materialized_shapes(text, include_fusion_interiors)
    )


def analyze_jit(fn, *args, **kwargs) -> "Analysis":
    """Lower + compile a jitted callable and analyze its optimized HLO.

    Convenience for per-executable acceptance checks (e.g. the compact
    dispatch's bytes-vs-bucket-tier curve): ``fn`` must be a ``jax.jit``
    wrapper; ``args``/``kwargs`` are its example inputs (static kwargs
    included). Returns the same :class:`Analysis` as :func:`analyze_text`.
    """
    return analyze_text(fn.lower(*args, **kwargs).compile().as_text())


@dataclasses.dataclass
class Analysis:
    flops: float
    bytes_traffic: float            # anchor-op (TPU-fusion-aware) traffic
    bytes_traffic_pessimistic: float  # every top-level op counted
    collective_bytes: dict
    collective_counts: dict

    def total_collective_bytes(self) -> float:
        return float(sum(self.collective_bytes.values()))


def analyze_text(text: str) -> Analysis:
    comps = parse_hlo(text)
    entry = comps.get("__entry__")
    if entry is None:
        return Analysis(0.0, 0.0, 0.0, {}, {})

    # --- pass 1: which computations transitively contain anchor ops -------
    fusion_callees: dict[str, list] = {}
    has_own_anchor: dict[str, bool] = {}
    for cname, comp in comps.items():
        if cname == "__entry__":
            continue
        callees = []
        own = False
        for op in comp.ops:
            base = op.kind[:-6] if op.kind.endswith("-start") else op.kind
            if base in _ANCHOR_KINDS:
                own = True
            if op.kind == "fusion":
                for am in re.finditer(r"calls=%([\w\.\-]+)", op.tail):
                    callees.append(am.group(1))
        fusion_callees[cname] = callees
        has_own_anchor[cname] = own

    anchor_memo: dict[str, bool] = {}

    def comp_has_anchor(cname: str) -> bool:
        if cname in anchor_memo:
            return anchor_memo[cname]
        anchor_memo[cname] = False  # cycle guard
        result = has_own_anchor.get(cname, False) or any(
            comp_has_anchor(c) for c in fusion_callees.get(cname, ()))
        anchor_memo[cname] = result
        return result

    # --- pass 2: per-computation raw costs + call edges --------------------
    comp_cost = {}
    for cname, comp in comps.items():
        if cname == "__entry__":
            continue
        shapes = dict(comp.params)
        defs = {}
        for op in comp.ops:
            shapes[op.name] = op.shape_str
            defs[op.name] = op
        flops = 0.0
        traffic = 0.0
        traffic_pess = 0.0
        coll_bytes = defaultdict(float)
        coll_counts = defaultdict(int)
        edges = []  # (callee, multiplier, via_fusion)
        for op in comp.ops:
            kind = op.kind
            if kind in ("dot", "dot-general"):
                flops += _dot_flops(op, shapes)
            elif kind == "convolution":
                flops += _conv_flops(op, shapes)
            if kind == "while":
                trip = 1
                tm = _TRIP_RE.search(op.tail)
                if tm:
                    trip = int(tm.group(1))
                for attr in ("condition", "body"):
                    am = re.search(attr + r"=%([\w\.\-]+)", op.tail)
                    if am:
                        edges.append((am.group(1), trip, False))
            elif kind == "conditional":
                bm = re.search(r"branch_computations=\{([^}]*)\}", op.tail)
                if bm:
                    for callee in re.findall(r"%([\w\.\-]+)", bm.group(1)):
                        edges.append((callee, 1, False))
                for attr in ("true_computation", "false_computation"):
                    am = re.search(attr + r"=%([\w\.\-]+)", op.tail)
                    if am:
                        edges.append((am.group(1), 1, False))
            elif kind in ("fusion", "reduce", "reduce-window", "sort", "map",
                          "scatter", "select-and-scatter", "reduce-scatter",
                          "all-reduce", "custom-call", "call"):
                for am in re.finditer(
                        r"(?:calls|to_apply)=%([\w\.\-]+)", op.tail):
                    edges.append((am.group(1), 1, kind == "fusion"))

            if kind in _SKIP_BYTES_KINDS:
                continue
            _, out_b = _shape_elems_bytes(op.shape_str)
            in_b = 0
            for o in op.operands:
                if o in shapes:
                    _, b = _shape_elems_bytes(shapes[o])
                    in_b += b
            traffic_pess += out_b + in_b
            base = kind[:-6] if kind.endswith("-start") else kind
            is_anchor = base in _ANCHOR_KINDS or (
                kind == "fusion" and any(
                    comp_has_anchor(am.group(1))
                    and not _is_slicing_plumbing_comp(comps[am.group(1)])
                    for am in re.finditer(r"calls=%([\w\.\-]+)", op.tail)))
            if is_anchor:
                traffic += _op_traffic(op, shapes, comps, defs)
            if base in _COLLECTIVE_KINDS:
                coll_bytes[base] += max(out_b, in_b)
                coll_counts[base] += 1
        comp_cost[cname] = dict(flops=flops, traffic=traffic,
                                traffic_pess=traffic_pess,
                                coll_bytes=coll_bytes,
                                coll_counts=coll_counts, edges=edges)

    # --- pass 3: propagate multiplicity over the call DAG ------------------
    flops_mult = defaultdict(float)    # counts flops + collectives
    traffic_mult = defaultdict(float)  # counts HBM traffic (no fusion interiors)

    def visit(cname, mult, traffic_on):
        if cname not in comp_cost or mult == 0:
            return
        flops_mult[cname] += mult
        if traffic_on:
            traffic_mult[cname] += mult
        for callee, k, via_fusion in comp_cost[cname]["edges"]:
            visit(callee, mult * k, traffic_on and not via_fusion)

    import sys
    old = sys.getrecursionlimit()
    sys.setrecursionlimit(100000)
    try:
        visit(entry.name, 1.0, True)
    finally:
        sys.setrecursionlimit(old)

    flops = 0.0
    traffic = 0.0
    traffic_pess = 0.0
    coll_b = defaultdict(float)
    coll_c = defaultdict(float)
    for cname, cost in comp_cost.items():
        fm = flops_mult.get(cname, 0.0)
        tm = traffic_mult.get(cname, 0.0)
        flops += fm * cost["flops"]
        traffic += tm * cost["traffic"]
        traffic_pess += tm * cost["traffic_pess"]
        for k, v in cost["coll_bytes"].items():
            coll_b[k] += fm * v
        for k, v in cost["coll_counts"].items():
            coll_c[k] += fm * v
    return Analysis(flops, traffic, traffic_pess, dict(coll_b), dict(coll_c))
