"""Cycle-accurate model of the TorR accelerator (paper Sec. 4.7 / 5.2).

Timing follows the paper's pipelined datapath at 1 GHz:
    cycles_full  ~= D' * ceil(M/W)          (one column/cycle, W lanes)
    cycles_delta ~= |Delta| * ceil(M/W)     (one flipped column/cycle)
    reasoner     ~= ceil(M/W) + c           (one score product/lane/cycle)
    PSU          ~= D'/32 + c               (XOR+popcount, 32 bits/cycle/word)
    sort/top-k   ~= M + k log k
    DMA          ~= query/score bits over a 128-bit/cycle host interface

Power follows Table 1 block peaks (TSMC 28 nm, 1 GHz), duty-cycled by the
fraction of window cycles each block is busy, with bank gating scaling the
aligner's dynamic power by D'/D. Static (clock tree + SRAM + leakage) power
is the calibration constant chosen so the five-task averages land on the
paper's measured 3.05-3.52 W envelope.

The model consumes WindowTelemetry traces — the *same* path decisions the
functional JAX pipeline makes — so functional and timing models cannot
drift apart.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..core.policy import aligner_cycles, bucket_tier, mw_cycles
from ..core.types import PATH_BYPASS, PATH_DELTA, PATH_FULL, TorrConfig

# --- Table 1 (TSMC 28 nm, 1 GHz): block peak powers in watts ---------------
P_ALIGNER = 3.52256
P_REASONER = 0.50432
P_PSU = 0.22016
P_SCORE_BUF = 0.11008
P_SORTER = 0.11008
P_CONTROLLER = 0.05504
P_HOST_DMA = 0.08256
P_FIFO_MISC = 0.05504
P_SRAM = 0.135
AREA = {
    "Associative Aligner": 4.488, "Lightweight Reasoner": 0.642,
    "Partial-Update Unit": 0.280, "Score Buffer (top-k)": 0.140,
    "Sorter": 0.140, "Controller (RT/QoS)": 0.070,
    "Host IF / DMA": 0.105, "Delta-index FIFO & misc.": 0.070,
    "Item memory (banked)": 0.50, "Query/Output caches": 0.03,
}
POWER_W = {
    "Associative Aligner": P_ALIGNER, "Lightweight Reasoner": P_REASONER,
    "Partial-Update Unit": P_PSU, "Score Buffer (top-k)": P_SCORE_BUF,
    "Sorter": P_SORTER, "Controller (RT/QoS)": P_CONTROLLER,
    "Host IF / DMA": P_HOST_DMA, "Delta-index FIFO & misc.": P_FIFO_MISC,
    "Item memory (banked)": 0.120, "Query/Output caches": 0.015,
}

# Calibrated constants (fit once against Table 3's five-task averages).
# A wider window (RT-30: dt = 33ms) aggregates ~2x the DVS events of RT-60,
# so encoder + aggregation cost scale with window width and inter-window
# coherence decays (rho_eff = rho^window_scale) — this is what reproduces
# the paper's near-2x latency growth from RT-60 to RT-30.
P_STATIC = 2.92          # clock tree + leakage + always-on control, W
DMA_BITS_PER_CYCLE = 128
ENCODER_CYCLES_PER_PROPOSAL = 36_000   # event-SNN share per proposal @ 60 FPS
HOST_OVERHEAD_CYCLES = 4_200_000       # window aggregation + driver @ 60 FPS


@dataclasses.dataclass
class WindowCost:
    cycles: dict            # per-block busy cycles
    total_cycles: float
    energy_j: float
    power_w: float


def latency_summary(lat_s, budget_s: float) -> dict:
    """Distribution summary of per-window latencies against an RT budget.

    Shared vocabulary between the simulated cycle model (``simulate_task``)
    and measured serving telemetry (``repro.serving.deadline``): both report
    the same keys, so dashboards/benchmarks can diff simulated vs measured
    envelopes directly. ``jitter_ms`` is p95 - median (the paper's jitter
    metric); ``miss_rate`` is the fraction of windows over budget.
    """
    lat = np.asarray(lat_s, np.float64)
    if lat.size == 0:
        return {"budget_ms": budget_s * 1e3, "n_windows": 0,
                "median_ms": 0.0, "p95_ms": 0.0, "p99_ms": 0.0,
                "min_ms": 0.0, "max_ms": 0.0, "jitter_ms": 0.0,
                "headroom_ms": budget_s * 1e3, "miss_rate": 0.0}
    med = float(np.median(lat))
    p95 = float(np.percentile(lat, 95))
    p99 = float(np.percentile(lat, 99))
    return {
        "budget_ms": budget_s * 1e3,
        "n_windows": int(lat.size),
        "median_ms": med * 1e3,
        "p95_ms": p95 * 1e3,
        "p99_ms": p99 * 1e3,
        "min_ms": float(lat.min()) * 1e3,
        "max_ms": float(lat.max()) * 1e3,
        "jitter_ms": (p95 - med) * 1e3,
        "headroom_ms": (budget_s - p95) * 1e3,
        "miss_rate": float(np.mean(lat > budget_s)),
    }


def lowering_scan_rows(n_full: int, n_valid: int, fused: str = "switch",
                       bucket_cap: int | None = None) -> int:
    """Full-scan rows a *lowering* actually pays for one window.

    The ASIC (and the branch-economy ``"switch"``/``"off"`` lowerings) scan
    exactly the full-path proposals; the hoisted ``"prefix"`` lowering
    scans every row of the window regardless of the path mix; the
    reuse-aware ``"compact"`` lowering scans its static bucket tier — the
    smallest ``core.policy.bucket_ladder`` capacity holding the full-path
    rows when ``bucket_cap`` is None (a perfectly-tiered dispatcher), or
    the latched tier, degrading to every row when the bucket overflows
    (the exact fallback rescans the window). This is what makes modeled
    cycles shrink with the *hit rate* under compact dispatch while the
    always-hoisted lowering stays flat.
    """
    if fused in ("off", "switch"):
        return n_full
    if fused == "prefix":
        return n_valid
    if fused == "compact":
        if n_valid < 1:
            return 0
        cap = bucket_tier(n_valid, max(n_full, 1)) if bucket_cap is None \
            else min(int(bucket_cap), n_valid)
        return cap if n_full <= cap else n_valid
    raise ValueError(f"unknown lowering {fused!r}")


def decide_psu_cycles(n_valid: int, d_eff: int, decide: str = "scan") -> int:
    """PSU (cache-nearest) cycles for one window's decide work.

    ``"scan"`` is the sequential per-proposal pass: each of the ``n_valid``
    proposals pays its D'/32-word popcount column *plus* the ~8-cycle
    pipeline restart (drain/refill between dependent lookups — proposal
    i+1's nearest cannot issue until proposal i's cache write lands).
    ``"batched"`` is the batched intra-window decide
    (``core.pipeline._decide_pass_batched``): the popcount columns of all
    proposals stream through one wide pass, so the restart constant is
    paid once per window instead of once per proposal — the conflict scan
    that replays intra-window writes is O(K) bookkeeping off the PSU's
    critical path. Batched is never priced above scan for any
    ``n_valid >= 1`` (pinned by ``tests/test_decide_batched.py``).
    """
    per_row = d_eff // 32
    if decide == "batched":
        return n_valid * per_row + 8
    if decide == "scan":
        return n_valid * (per_row + 8)
    raise ValueError(f"unknown decide lowering {decide!r}")


def window_cost(path: np.ndarray, delta_count: np.ndarray, banks: int,
                reasoner_active: np.ndarray, n_valid: int,
                cfg: TorrConfig, rt_budget_s: float,
                window_scale: float = 1.0,
                d_eff: int | None = None,
                fused: str = "switch",
                bucket_cap: int | None = None,
                decide: str = "scan") -> WindowCost:
    """Cost of one window from its telemetry trace.

    ``d_eff`` overrides the bank-implied effective dimension when the
    window ran under a precision-gated knob plan (D' = banks * bank_dims *
    planes / bit_planes); :func:`telemetry_cost` derives it from telemetry.
    The aligner term comes from the shared Sec. 4.3 helper in
    ``core.policy`` — the same math Alg. 1 and the QoS governor price with.
    ``fused``/``bucket_cap`` price the aligner's scan rows per the actual
    lowering (:func:`lowering_scan_rows`); the default (``"switch"``) is
    the ASIC-faithful per-full-proposal cost. ``decide`` prices the PSU's
    cache-nearest pass per the decide lowering (:func:`decide_psu_cycles`);
    the default (``"scan"``) is the ASIC-faithful sequential FSM.
    """
    mw = mw_cycles(cfg)
    d_eff = banks * cfg.bank_dims if d_eff is None else int(d_eff)
    path = np.asarray(path)[:n_valid]
    dc = np.asarray(delta_count)[:n_valid]
    ra = np.asarray(reasoner_active)[:n_valid]

    n_full = int(np.sum(path == PATH_FULL))
    n_delta = int(np.sum(path == PATH_DELTA))
    n_byp = int(np.sum(path == PATH_BYPASS))

    scan_rows = lowering_scan_rows(n_full, int(n_valid), fused, bucket_cap)
    aligner = int(aligner_cycles(
        scan_rows, int(np.sum(dc[path == PATH_DELTA])), d_eff, mw))
    psu = decide_psu_cycles(int(n_valid), d_eff, decide)
    reasoner = int(np.sum(ra)) * (mw + 4)
    sorter = (n_full + n_delta) * (cfg.M + 32)
    dma = n_valid * (d_eff + cfg.M * 16) // DMA_BITS_PER_CYCLE
    encoder = int(n_valid * ENCODER_CYCLES_PER_PROPOSAL * window_scale)
    ctrl = n_valid * 16

    busy = {
        "aligner": aligner, "psu": psu, "reasoner": reasoner,
        "sorter": sorter, "dma": dma, "ctrl": ctrl,
    }
    total = (aligner + psu + reasoner + sorter + dma + ctrl
             + encoder + HOST_OVERHEAD_CYCLES * window_scale)
    t_window = total / cfg.clock_hz
    budget_cycles = rt_budget_s * cfg.clock_hz

    duty = {k: v / budget_cycles for k, v in busy.items()}
    p_dyn = (
        P_ALIGNER * duty["aligner"] * (d_eff / cfg.D)
        + P_PSU * duty["psu"]
        + P_REASONER * duty["reasoner"]
        + (P_SORTER + P_SCORE_BUF) * duty["sorter"]
        + P_HOST_DMA * duty["dma"]
        + (P_CONTROLLER + P_FIFO_MISC) * duty["ctrl"]
        + P_SRAM * (duty["aligner"] + duty["psu"])
    )
    power = P_STATIC + p_dyn
    energy = power * rt_budget_s          # frame-budget-locked energy
    return WindowCost(busy, total, energy, power)


def telemetry_cost(tel, cfg: TorrConfig, rt_budget_s: float,
                   window_scale: float = 1.0,
                   use_recorded_lowering: bool = False) -> WindowCost:
    """Cost one served window straight from its (host-resident) telemetry.

    Reads the knob plan the window *actually* ran with — ``banks`` and
    ``planes`` are both recorded in :class:`~repro.core.types
    .WindowTelemetry` — so the QoS governor's energy feedback and any
    offline audit price precision-gated windows correctly.

    ``use_recorded_lowering=True`` additionally prices with the resolved
    ``fused_mode``/``decide_mode``/``bucket_tier`` the telemetry recorded
    (an opt-in: the default keeps the nominal ``fused="switch"`` pricing
    the governor's energy EWMA and table8's operating points are
    calibrated against, so enabling it changes modeled numbers — meant
    for lowering audits that diff measured vs modeled envelopes, e.g. on
    flight-recorder digests whose key names match these arguments).
    """
    banks = int(tel.banks)
    planes = int(tel.planes)
    kw = {}
    if use_recorded_lowering:
        from ..core.types import DECIDE_NAMES, FUSED_NAMES
        fused = FUSED_NAMES[int(tel.fused_mode)]
        decide_id = int(tel.decide_mode)
        tier = int(tel.bucket_tier)
        kw = {"fused": fused,
              "decide": DECIDE_NAMES[decide_id] if decide_id >= 0 else "scan",
              "bucket_cap": tier if tier > 0 else None}
    return window_cost(
        np.asarray(tel.path), np.asarray(tel.delta_count), banks,
        np.asarray(tel.reasoner_active), int(tel.n_valid), cfg, rt_budget_s,
        window_scale=window_scale,
        d_eff=int(cfg.d_eff_planned(banks, planes)), **kw)


def path_mix(rho: np.ndarray, delta: np.ndarray, high: bool,
             cfg: TorrConfig) -> np.ndarray:
    """Host-side (numpy) Alg. 1 path decision for trace simulation.

    Mirrors ``core.policy.select_path`` with the accumulator tag assumed
    valid — the shared decision table for every trace simulator
    (``simulate_task`` here, ``benchmarks.table8_pareto``), so the
    simulated path mix can't drift from the policy's rules.
    """
    path = np.full(rho.shape, PATH_FULL)
    path[(rho >= cfg.tau_q) & (delta <= cfg.delta_budget)] = PATH_DELTA
    if high:
        path[rho >= cfg.tau_byp] = PATH_BYPASS
    return path


# ---------------------------------------------------------------------------
# Task trace profiles (calibration documented in EXPERIMENTS.md): each task
# is a stochastic process over (object count, temporal coherence rho).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TaskProfile:
    name: str
    n_mean: float          # proposals per window
    n_std: float
    rho_mean: float        # query similarity between windows
    rho_std: float
    churn: float           # fraction of proposals that are new objects


TASK_PROFILES = {
    "pour wine": TaskProfile("pour wine", 86, 14, 0.86, 0.07, 0.10),
    "sports": TaskProfile("sports", 94, 18, 0.82, 0.09, 0.16),
    "cooking": TaskProfile("cooking", 74, 12, 0.88, 0.06, 0.08),
    "have breakfast": TaskProfile("have breakfast", 62, 9, 0.93, 0.04, 0.04),
    "take a rest": TaskProfile("take a rest", 64, 10, 0.92, 0.04, 0.05),
}


def _edge_config(rt: str) -> TorrConfig:
    return TorrConfig(D=8192, B=8, M=1024, K=8, N_max=128,
                      delta_budget=2048, W=64,
                      fps_target=60.0 if rt == "RT-60" else 30.0)


def simulate_task(task: str, rt: str = "RT-60", n_frames: int = 600,
                  seed: int = 0, cfg: TorrConfig | None = None) -> dict:
    """Replay a synthetic task trace through Alg. 1 + the cycle model."""
    prof = TASK_PROFILES[task]
    cfg = cfg or _edge_config(rt)
    rng = np.random.default_rng(seed)
    budget = 1.0 / cfg.fps_target
    window_scale = 60.0 * budget           # 1.0 @ RT-60, 2.0 @ RT-30
    mw = mw_cycles(cfg)

    lat, power, energy, banks_hist, mix = [], [], [], [], []
    for _ in range(n_frames):
        n = int(np.clip(rng.normal(prof.n_mean, prof.n_std), 4, cfg.N_max))
        queue = max(0, int(rng.normal(0.5, 0.8)))
        # Alg.1 line 9: D' to fit the budget in the worst (all-full) case
        banks = 1
        overhead = (HOST_OVERHEAD_CYCLES * window_scale
                    + n * ENCODER_CYCLES_PER_PROPOSAL * window_scale)
        for b in range(cfg.B, 0, -1):
            worst = aligner_cycles(n, 0, b * cfg.bank_dims, mw) + overhead
            if worst <= budget * cfg.clock_hz / (1.0 + queue):
                banks = b
                break
        d_eff = banks * cfg.bank_dims
        high = n >= cfg.N_hi or queue >= cfg.q_hi

        # wider windows decay coherence: rho_eff = rho ^ window_scale
        rho = np.clip(rng.normal(prof.rho_mean, prof.rho_std, n), -1, 1)
        rho_exp = 1.0 + 0.5 * (window_scale - 1.0)
        rho = np.sign(rho) * np.abs(rho) ** rho_exp
        new_obj = rng.random(n) < prof.churn * (1.0 + 0.5 * (window_scale - 1.0))
        rho = np.where(new_obj, rng.uniform(-0.1, 0.4, n), rho)
        delta = np.round((1 - rho) / 2 * d_eff).astype(int)

        path = path_mix(rho, delta, high, cfg)
        # reasoner gated on stable top-k: proxy with very high rho
        reasoner_active = (path != PATH_BYPASS) & (rho < 0.97)

        wc = window_cost(path, delta, banks, reasoner_active, n, cfg, budget,
                         window_scale)
        lat.append(wc.total_cycles / cfg.clock_hz)
        power.append(wc.power_w)
        energy.append(wc.energy_j)
        banks_hist.append(banks)
        mix.append([np.mean(path == p) for p in
                    (PATH_BYPASS, PATH_DELTA, PATH_FULL)])

    mix = np.array(mix)
    summary = latency_summary(np.array(lat), budget)
    summary.update({
        "task": task, "rt": rt,
        "power_w": float(np.mean(power)),
        "energy_mj": float(np.mean(energy) * 1e3),
        "banks_mean": float(np.mean(banks_hist)),
        "path_mix": {"bypass": float(mix[:, 0].mean()),
                     "delta": float(mix[:, 1].mean()),
                     "full": float(mix[:, 2].mean())},
    })
    return summary


def simulate_all(rt: str, n_frames: int = 600, seed: int = 0) -> list[dict]:
    return [simulate_task(t, rt, n_frames, seed) for t in TASK_PROFILES]
