"""Dry-run profiler: per-op traffic/FLOPs attribution from optimized HLO.

This is the 'profile' of the CPU-only workflow: since there is no wall-clock
TPU trace, optimization targets come from ranking ops by modeled HBM traffic
and FLOPs (trip-count-scaled). Usage:

    python -m repro.perf.profile_cell --hlo /tmp/cell.hlo --top 25
    python -m repro.perf.profile_cell --arch deepseek-v3-671b \
        --shape decode_32k --top 25        # lowers + compiles first
"""
from __future__ import annotations

import argparse
import re
from collections import defaultdict

from . import hlo_analyze as ha


def profile_text(text: str, top: int = 25):
    comps = ha.parse_hlo(text)
    entry = comps["__entry__"]

    comp_edges = {}
    for cname, comp in comps.items():
        if cname == "__entry__":
            continue
        edges = []
        for op in comp.ops:
            if op.kind == "while":
                trip = 1
                tm = ha._TRIP_RE.search(op.tail)
                if tm:
                    trip = int(tm.group(1))
                for attr in ("condition", "body"):
                    am = re.search(attr + r"=%([\w\.\-]+)", op.tail)
                    if am:
                        edges.append((am.group(1), trip))
        comp_edges[cname] = edges

    mult = defaultdict(float)

    def visit(c, m):
        mult[c] += m
        for callee, k in comp_edges.get(c, []):
            visit(callee, m * k)

    visit(entry.name, 1.0)

    # anchor detection (as in analyze_text)
    fusion_callees, own = {}, {}
    for cname, comp in comps.items():
        if cname == "__entry__":
            continue
        cs, o = [], False
        for op in comp.ops:
            base = op.kind[:-6] if op.kind.endswith("-start") else op.kind
            if base in ha._ANCHOR_KINDS:
                o = True
            if op.kind == "fusion":
                cs += [am.group(1) for am in
                       re.finditer(r"calls=%([\w\.\-]+)", op.tail)]
        fusion_callees[cname], own[cname] = cs, o
    memo = {}

    def has_anchor(c):
        if c in memo:
            return memo[c]
        memo[c] = False
        memo[c] = own.get(c, False) or any(has_anchor(x)
                                           for x in fusion_callees.get(c, []))
        return memo[c]

    rows = []
    for cname, comp in comps.items():
        if cname == "__entry__" or mult.get(cname, 0) == 0:
            continue
        shapes = dict(comp.params)
        defs = {}
        for op in comp.ops:
            shapes[op.name] = op.shape_str
            defs[op.name] = op
        for op in comp.ops:
            base = op.kind[:-6] if op.kind.endswith("-start") else op.kind
            is_anchor = base in ha._ANCHOR_KINDS or (
                op.kind == "fusion" and any(
                    has_anchor(am.group(1))
                    and not ha._is_slicing_plumbing_comp(comps[am.group(1)])
                    for am in re.finditer(r"calls=%([\w\.\-]+)", op.tail)))
            flops = 0.0
            if base in ("dot", "dot-general"):
                flops = ha._dot_flops(op, shapes) * mult[cname]
            traffic = (ha._op_traffic(op, shapes, comps, defs) * mult[cname]
                       if is_anchor else 0.0)
            if traffic or flops:
                meta = re.search(r'op_name="([^"]*)"', op.tail)
                rows.append((traffic, flops, op.kind, op.name,
                             op.shape_str[:48],
                             (meta.group(1) if meta else "")[:70]))
    return sorted(rows, key=lambda r: -r[0])[:top], sorted(
        rows, key=lambda r: -r[1])[:top]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--hlo")
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--top", type=int, default=25)
    args = ap.parse_args()

    if args.hlo:
        text = open(args.hlo).read()
    else:
        from ..configs.registry import SHAPES, get
        from ..launch.mesh import make_production_mesh
        from ..runtime import steps
        cfg = get(args.arch)
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        lowered, _ = steps.lower_cell(cfg, SHAPES[args.shape], mesh)
        text = lowered.compile().as_text()

    by_traffic, by_flops = profile_text(text, args.top)
    print(f"== top {args.top} by per-device HBM traffic ==")
    for t, f, kind, name, shape, meta in by_traffic:
        print(f"{t/1e9:10.2f} GB {kind:18s} {shape:48s} {meta}")
    print(f"\n== top {args.top} by per-device FLOPs ==")
    for t, f, kind, name, shape, meta in by_flops:
        print(f"{f/1e12:10.3f} TF {kind:18s} {shape:48s} {meta}")


if __name__ == "__main__":
    main()
