"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — required because the dry-run must set
XLA_FLAGS before any jax initialization.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1, pod: int | None = None):
    """Small mesh over however many (host) devices exist — for tests."""
    if pod is not None:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))
