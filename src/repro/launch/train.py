"""Training launcher: end-to-end driver with checkpoint/restart.

Runs a real training loop on whatever devices exist (CPU here; the same
code jits against the production mesh on a fleet). Supports fault injection
(--fault-at) to demonstrate supervised recovery, gradient compression on a
DP axis, and elastic restore from a checkpoint taken on a different mesh.

Example (smoke-size, a few hundred steps):
    PYTHONPATH=src python -m repro.launch.train --arch deepseek-7b \
        --smoke --steps 300 --batch 8 --seq 128 --ckpt /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint.manager import CheckpointManager
from ..configs import get, get_smoke
from ..data.tokens import TokenStream
from ..models import transformer as tf
from ..optim import adamw
from ..runtime import sharding as shd
from ..runtime.fault import SupervisorConfig, TrainSupervisor
from ..runtime.steps import make_train_step
from .mesh import make_host_mesh


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-trainable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--fault-at", type=int, default=None)
    ap.add_argument("--data-axis", type=int, default=1)
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get(args.arch)
    mesh = make_host_mesh(args.data_axis, args.model_axis)
    opt_cfg = adamw.OptimConfig(lr=args.lr, warmup_steps=min(20, args.steps // 5),
                                total_steps=args.steps)

    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    params = jax.device_put(params, shd.params_sharding(params, mesh))
    opt_state = adamw.init_opt_state(params)
    stream = TokenStream(cfg, args.batch, args.seq)

    raw_step = make_train_step(cfg, opt_cfg)
    jstep = jax.jit(raw_step, donate_argnums=(0, 1))

    ckpt = CheckpointManager(args.ckpt, keep_last=3)
    state = {"params": params, "opt": opt_state}
    start = 0
    if args.resume and ckpt.latest_step() is not None:
        state, start = ckpt.restore(state)
        print(f"[train] resumed from step {start}")

    losses = []

    def step_fn(state, batch):
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        p, o, metrics = jstep(state["params"], state["opt"], batch)
        losses.append(float(metrics["loss"]))
        return {"params": p, "opt": o}

    def data_stream(step):
        return stream.stream(step)

    sup = TrainSupervisor(step_fn, ckpt,
                          SupervisorConfig(ckpt_every=args.ckpt_every))
    t0 = time.time()
    state, end = sup.run(state, data_stream, args.steps, start_step=start,
                         fault_at=args.fault_at)
    dt = time.time() - t0
    k = max(1, min(10, len(losses)))
    print(f"[train] arch={cfg.name} steps={end} restarts={sup.restarts} "
          f"loss_first10={np.mean(losses[:k]):.4f} "
          f"loss_last10={np.mean(losses[-k:]):.4f} "
          f"({dt:.1f}s, {dt/max(len(losses),1)*1e3:.0f} ms/step)")
    if len(losses) > 20:
        assert np.mean(losses[-10:]) < np.mean(losses[:10]), \
            "loss did not improve"
        print("[train] loss improved ✓")


if __name__ == "__main__":
    main()
