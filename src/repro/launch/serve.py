"""Serving launcher: batched prefill + decode with optional TorR reranker.

Example:
    PYTHONPATH=src python -m repro.launch.serve --arch musicgen-large \
        --smoke --batch 4 --prompt-len 32 --gen 32 --rerank
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get, get_smoke
from ..core.types import TorrConfig
from ..models import transformer as tf
from ..serving import reranker as rr


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="musicgen-large")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--rerank", action="store_true")
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get(args.arch)
    key = jax.random.PRNGKey(0)
    params = tf.init_params(key, cfg)

    B, S = args.batch, args.prompt_len
    rng = np.random.default_rng(0)
    if cfg.family == "audio":
        tokens = rng.integers(0, cfg.vocab, (B, S, cfg.n_codebooks))
    else:
        tokens = rng.integers(0, cfg.vocab, (B, S))
    batch = {"tokens": jnp.asarray(tokens, jnp.int32)}
    if cfg.family == "vlm":
        batch["vision"] = jnp.asarray(
            rng.standard_normal((B, cfg.n_vision_tokens, cfg.vision_dim)),
            jnp.bfloat16)

    prefill = jax.jit(tf.prefill, static_argnames="cfg")
    decode = jax.jit(tf.decode_step, static_argnames=("cfg", "return_hidden"))

    t0 = time.time()
    cache, logits = prefill(params, batch, cfg)
    t_prefill = time.time() - t0

    rcfg, rparams, rim, rstate = None, None, None, None
    rstep = None
    if args.rerank:
        rcfg = TorrConfig(D=2048, B=8, M=min(cfg.vocab, 256), K=8,
                          N_max=B, feat_dim=cfg.d_model)
        rparams, rim = rr.init_reranker(jax.random.PRNGKey(7), rcfg,
                                        cfg.d_model, cfg.vocab, alpha=0.5)
        rstate = rr.init_state(rcfg, B)
        rstep = jax.jit(rr.rerank_step, static_argnames=("cfg",))

    sample_key = jax.random.PRNGKey(1)
    generated = []
    bypassed_frac = []
    hidden = None
    t0 = time.time()
    for i in range(args.gen):
        if args.rerank and hidden is not None and cfg.family != "audio":
            logits, rstate, tel = rstep(rparams, rstate, rim,
                                        hidden, logits, rcfg)
            bypassed_frac.append(float(jnp.mean(tel["bypassed"])))
        if cfg.family == "audio":
            lf = logits.reshape(B, cfg.n_codebooks, cfg.vocab)
            sample_key, k = jax.random.split(sample_key)
            nxt = jax.random.categorical(k, lf / args.temperature, axis=-1)
        else:
            sample_key, k = jax.random.split(sample_key)
            nxt = jax.random.categorical(k, logits / args.temperature, axis=-1)
        generated.append(np.asarray(nxt))
        cache, logits, hidden = decode(params, cache, nxt, cfg,
                                       return_hidden=True)
    t_decode = time.time() - t0

    print(f"[serve] arch={cfg.name} batch={B} prompt={S} gen={args.gen}")
    print(f"[serve] prefill {t_prefill*1e3:.1f} ms; decode "
          f"{t_decode/args.gen*1e3:.1f} ms/token "
          f"({B*args.gen/t_decode:.1f} tok/s)")
    if bypassed_frac:
        print(f"[serve] reranker bypass rate: {np.mean(bypassed_frac):.2f}")
    out = np.stack(generated, axis=1)
    print(f"[serve] generated shape {out.shape}, sample: {out[0].ravel()[:16]}")


if __name__ == "__main__":
    main()
