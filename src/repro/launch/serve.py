"""Serving launcher: batched prefill + decode with optional TorR reranker,
plus the multi-stream TorR window engine.

Examples:
    PYTHONPATH=src python -m repro.launch.serve --arch musicgen-large \
        --smoke --batch 4 --prompt-len 32 --gen 32 --rerank
    PYTHONPATH=src python -m repro.launch.serve --torr-streams 8 \
        --torr-frames 30
    # async dispatch/collect runtime, sharded over all devices, RT-60
    # deadline admission control:
    XLA_FLAGS=--xla_force_host_platform_device_count=4 PYTHONPATH=src \
        python -m repro.launch.serve --torr-streams 8 --torr-frames 30 \
        --async --mesh 4 --rt RT-60
    # closed-loop QoS control plane (slack-driven bank/precision gating
    # with the energy governor) on top of RT-60 admission control:
    TORR_GOV_ENERGY_MJ=60 PYTHONPATH=src python -m repro.launch.serve \
        --torr-streams 8 --torr-frames 30 --rt RT-60 --governor

QoS control plane (``--governor``)
==================================

``--governor`` arms the closed loop of ``repro.control``: per dispatched
step, the RT-deadline tracker's projected slack, the deepest per-slot
backlog and an EWMA of modeled window energy (``perf.cycle_model`` priced
on each window's own telemetry) drive a slack ladder of knob plans — D'
bank caps, bit-slice precision (dropping low-order planes of the packed
scan) and tau_q/tau_byp offsets — and the chosen plan is latched for the
step exactly like the ASIC's window-latched registers. Requires (and with
a bare ``--governor`` defaults to) an ``--rt`` operating point.

Governor knobs, their hysteresis defaults, and env overrides (read once by
``repro.control.governor.policy_from_env``):

    knob              | env var               | default | meaning
    ----------------- | --------------------- | ------- | -------------------
    slack margin      | ``TORR_GOV_MARGIN``   |    0.25 | fraction of the RT
                      |                       |         | budget held back as
                      |                       |         | safety slack
    recovery hold     | ``TORR_GOV_HOLD``     |       4 | consecutive
                      |                       |         | comfortable windows
                      |                       |         | before widening D'
                      |                       |         | back out (one ladder
                      |                       |         | level at a time)
    energy budget     | ``TORR_GOV_ENERGY_MJ``|     off | mJ/window target the
                      |                       |         | energy governor caps
                      |                       |         | the ladder level to
                      |                       |         | (0 disables)
    energy EWMA alpha | ``TORR_GOV_ALPHA``    |     0.2 | weight of the newest
                      |                       |         | window's modeled mJ

Degrading is immediate (a missed deadline beats a narrow window);
recovering takes ``TORR_GOV_HOLD`` comfortable windows per level so the
plan latch doesn't thrash the specialized executables. Every window's
telemetry records the (banks, planes) it actually ran with.

Reuse-aware kernel dispatch (``--torr-fused``)
==============================================

``--torr-fused`` pins the full path's kernel dispatch. Besides the PR-4
lowerings (``switch``/``prefix``/``off``), ``compact`` selects the
compact-then-compute dispatch — a metadata-only decide pass produces the
path vector, and the fused XNOR-popcount scan runs only over the
full-path proposals, compacted to a static power-of-two bucket tier
(``core.policy.bucket_ladder``; any tier is bit-exact, overflow falls
back to the hoisted scan) — and ``auto`` lets the engine pick compact vs
hoisted (and the bucket tier) per step from the telemetry path-mix EWMA,
so reuse-heavy traffic stops paying the full scan over lanes that resolve
via bypass/delta:

    PYTHONPATH=src python -m repro.launch.serve --torr-streams 8 \\
        --torr-frames 30 --torr-fused auto

Observability (``--metrics-port/-json`` / ``--flight-jsonl`` / ``--trace-json``)
================================================================================

Any of the four flags arms the ``repro.obs`` observability tier on the
stream engine, the deadline tracker and the governor:

* ``--metrics-port N`` serves Prometheus text on
  ``http://127.0.0.1:N/metrics`` (0 = ephemeral port, printed at startup)
  for the duration of the run — windows/path-mix/deadline/plan/span/SLO
  metric families, catalog in ``docs/observability.md``;
* ``--metrics-json PATH`` dumps the final registry snapshot as JSON (the
  CI bench-smoke artifact shape);
* ``--flight-jsonl PATH`` spills the flight recorder — one structured
  record per dispatched step (resolved lowering, latched plan, governor
  slack/energy, telemetry digest, per-window trace contexts) — replayable
  offline with ``repro.obs.flight.replay`` into the exact governor plan
  timeline;
* ``--trace-json PATH`` additionally arms per-window causal tracing
  (``repro.obs.trace``) and writes a Chrome trace-event JSON —
  ``chrome://tracing`` / https://ui.perfetto.dev load it directly, with
  per-window flow arrows across the async dispatcher→collector hand-off
  and counter tracks for plan level / energy EWMA / queue depth
  (trace-context model + Perfetto how-to in ``docs/observability.md``).

With an ``--rt`` operating point armed alongside observability, window
completions additionally feed the RT-SLO burn-rate engine
(``repro.obs.slo``): fast/slow rolling-window burn rates over the
deadline-miss budget, exported as ``torr_slo_*`` gauges and flight
events — semantics and the threshold table in ``docs/observability.md``.

Shutdown: SIGINT/SIGTERM unwind the serving loop cleanly — in-flight
windows are cancelled and every armed artifact (metrics JSON, flight
JSONL, Chrome trace) is still flushed before the process exits.
"""
from __future__ import annotations

import argparse
import signal
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get, get_smoke
from ..core.types import TorrConfig
from ..models import transformer as tf
from ..serving import reranker as rr


def _install_signal_handlers():
    """Route SIGINT/SIGTERM into KeyboardInterrupt so the serving loop
    unwinds through its cleanup path and flushes observability artifacts
    (a docker stop / CI cancel must not lose the flight log). Returns the
    previous handlers for restoration, or None off the main thread
    (signal.signal is main-thread-only)."""
    if threading.current_thread() is not threading.main_thread():
        return None

    def _raise(signum, _frame):
        raise KeyboardInterrupt(f"signal {signum}")

    previous = {}
    for sig in (signal.SIGINT, signal.SIGTERM):
        try:
            previous[sig] = signal.signal(sig, _raise)
        except (ValueError, OSError):  # exotic embeddings may refuse
            pass
    return previous


def _restore_signal_handlers(previous) -> None:
    if previous:
        for sig, handler in previous.items():
            try:
                signal.signal(sig, handler)
            except (ValueError, OSError):
                pass


def run_torr_streams(n_streams: int, n_frames: int, n_slots: int = 0,
                     serial: bool = False, use_async: bool = False,
                     mesh_devices: int = 0, rt: str = "",
                     governor: bool = False, fused: str | None = None,
                     metrics_port: int | None = None, metrics_json: str = "",
                     flight_jsonl: str = "", flight_capacity: int = 4096,
                     trace_json: str = "", supervise: bool = False,
                     state_store: str = "", snapshot_every: int = 1,
                     fault_at: int | None = None,
                     fault_kind: str = "dispatcher",
                     outputs_jsonl: str = ""):
    """Serve S synthetic TOOD streams through the batched window engine.

    ``use_async`` routes through the dispatch/collect
    :class:`repro.serving.async_engine.AsyncStreamEngine`; ``mesh_devices``
    additionally shards the stream slots over that many devices (0 = all).
    ``rt`` ("RT-30"/"RT-60") arms the deadline admission controller;
    ``governor`` closes the QoS loop (slack-driven bank/precision gating
    plus the energy governor — see the module docstring). ``fused`` picks
    the full path's kernel dispatch (None = the lowering-appropriate fused
    default, "off" = the jnp-oracle step; see ``repro.core.pipeline``).

    Any of ``metrics_port`` (HTTP exposition; 0 = ephemeral), their JSON
    dump (``metrics_json``), the flight-recorder spill (``flight_jsonl``)
    or the Chrome-trace export (``trace_json``, which also arms per-window
    causal tracing) arms the ``repro.obs`` tier across the
    engine/tracker/governor; an armed ``rt`` additionally feeds the RT-SLO
    burn-rate monitor. Returns None when observability is off; otherwise a
    dict with the final ``registry``/``flight``/``tracer``/``slo`` objects,
    the scraped ``metrics_text`` (when a server ran) and the engine
    ``summary`` — what ``tests/test_obs.py`` asserts the acceptance
    criteria against.

    Fault tolerance: ``supervise`` (implied by ``fault_at``) wraps the
    engine in a :class:`repro.serving.supervisor.ServeSupervisor` (which
    implies the async runtime); ``state_store`` points it at a JSONL
    session store (empty = in-memory), snapshotting every
    ``snapshot_every`` served windows. ``fault_at``/``fault_kind`` inject
    one deterministic worker death (the chaos harness); recovery replays
    the lost windows and the run still must account for every admitted
    window — any lost window raises SystemExit(3). ``outputs_jsonl``
    streams one fsync'd record per resolved window (stream, seq, best
    classes, scores digest) — the bit-match ledger the SIGKILL recovery
    test compares across runs; a killed process resumes from the store,
    skipping each stream's already-covered windows.
    """
    from ..core import hdc
    from ..data import tood_synth as ts
    from ..serving import tood_pipelines as tp
    from ..serving.stream_engine import StreamEngine

    # deadline admission, sharding, the governor and supervision live on
    # the async runtime; honor them for programmatic callers too, not just
    # main()'s CLI plumbing
    supervise = supervise or fault_at is not None
    use_async = (use_async or bool(rt) or governor or mesh_devices != 0
                 or supervise)

    # K >= N_max so a window cannot thrash its own cache out of reuse range
    cfg = TorrConfig(D=2048, B=8, M=64, K=16, N_max=16, delta_budget=256)
    world = ts.make_world(seed=0, M=cfg.M, d=cfg.feat_dim)
    sys_ = tp.build_system(world, cfg, seed=0)
    n_slots = n_slots or n_streams
    registry = flight = server = tracer = slo = None
    if metrics_port is not None or metrics_json or flight_jsonl or trace_json:
        from ..obs import FlightRecorder, MetricsRegistry, MetricsServer
        registry = MetricsRegistry()
        flight = FlightRecorder(flight_capacity, metrics=registry)
        if trace_json:
            from ..obs import Tracer
            tracer = Tracer(metrics=registry)
        if metrics_port is not None:
            server = MetricsServer(registry, port=metrics_port)
            print(f"[serve/torr] metrics endpoint "
                  f"http://127.0.0.1:{server.start()}/metrics")
    # fault-tolerance plumbing: session store, chaos plan, supervisor.
    # The FaultPlan instance is shared across engine rebuilds — it fires
    # exactly once, so the supervisor's replacement engine runs clean.
    store = None
    fault = None
    sup = None
    if supervise or state_store:
        from ..serving.state_store import InMemoryStateStore, JsonlStateStore
        store = (JsonlStateStore(state_store, metrics=registry)
                 if state_store else InMemoryStateStore(metrics=registry))
    if fault_at is not None:
        from ..runtime.fault import FaultPlan
        fault = FaultPlan(at_step=fault_at, thread=fault_kind,
                          kind=fault_kind)
    if use_async:
        from ..runtime import sharding as shd
        from ..serving.async_engine import AsyncStreamEngine
        from ..serving.deadline import DeadlineTracker, policy_for
        # sharding is opt-in via --mesh; bare --async stays single-device
        # (e.g. --torr-serial is valid async but cannot shard)
        mesh = None if mesh_devices == 0 else shd.stream_mesh(
            None if mesh_devices < 0 else mesh_devices)
        if governor and not rt:
            rt = "RT-60"    # the governor is slack-driven: needs a deadline
        tracker = None
        if rt:
            if registry is not None:
                from ..obs import SLOMonitor
                slo = SLOMonitor(metrics=registry, flight=flight)
            tracker = DeadlineTracker(policy_for(rt), metrics=registry,
                                      slo=slo)
        gov = None
        if governor:
            from ..control import Governor, policy_from_env
            gov = Governor(cfg, policy_from_env(rt), metrics=registry)

        def make_engine():
            # tracker/governor survive rebuilds deliberately: their EMAs
            # are measurements of the workload, not of one engine instance
            return AsyncStreamEngine(
                cfg, sys_.im, n_slots=n_slots, serial=serial, fused=fused,
                mesh=mesh, tracker=tracker, governor=gov, paused=True,
                metrics=registry, flight=flight, tracer=tracer,
                store=store, snapshot_every=snapshot_every,
                fault_plan=fault)

        if supervise:
            from ..serving.supervisor import ServeSupervisor
            sup = ServeSupervisor(make_engine, store, metrics=registry,
                                  flight=flight)
            eng = sup.engine
            if server is not None:
                server.set_ready(sup.health)    # /readyz mirrors recovery
        else:
            eng = make_engine()
    else:
        eng = StreamEngine(cfg, sys_.im, n_slots=n_slots, serial=serial,
                           fused=fused, metrics=registry, flight=flight,
                           tracer=tracer, store=store,
                           snapshot_every=snapshot_every, fault_plan=fault)
    front = sup if sup is not None else eng

    R = jnp.asarray(sys_.R)
    n_tasks = world.relevance.shape[0]
    paths, valids = [], []
    eng.warmup()  # compile the batched step outside the timed drains
    if use_async:
        eng.start()
    t_total = 0.0
    shed = 0
    submitted = accounted = resumed_skip = 0
    out_f = open(outputs_jsonl, "a", encoding="utf-8") \
        if outputs_jsonl else None
    out_lock = threading.Lock()

    def _ledger_cb(sid, seq):
        # async ledger writes ride the window's future resolution (the
        # collector thread) — strictly BEFORE that step's state-store
        # snapshot put, so a snapshot covering a window implies its
        # ledger record is on disk (the resume path's no-gap invariant)
        def cb(fut):
            if fut.cancelled() or fut.exception() is not None:
                return
            wout, _tel = fut.result()
            with out_lock:
                _write_output(out_f, sid, seq, wout)
        return cb

    interrupted = False
    engine_dead = None
    prev_handlers = None
    try:
        # handlers armed and the armed-line printed *inside* the try: an
        # operator (or the shutdown test) reacting to this line with an
        # immediate signal must land in the graceful-flush handler even
        # if it arrives before print() has returned
        prev_handlers = _install_signal_handlers()
        print("[serve/torr] serving (SIGINT/SIGTERM flushes artifacts)",
              flush=True)
        # admit streams in waves of n_slots: slots < streams just queues work
        for wave_start in range(0, n_streams, n_slots):
            wave = range(wave_start, min(wave_start + n_slots, n_streams))
            # synthesize + encode the wave's windows outside the timed
            # region: the async engine must not get a head start on
            # untimed work
            # (stream_id, q, valid, boxes, seq), submission order
            windows = []
            for s in wave:
                task = s % n_tasks
                front.admit(f"stream{s}", sys_.task_w[task])
                frames = ts.simulate_sequence(world, task, n_frames, seed=s,
                                              n_max=cfg.N_max)
                # cross-process resume: the store already covers the first
                # latest_seq windows of this (deterministic) stream — a
                # previous process served them before dying
                skip = 0
                if sup is not None:
                    skip = min(store.latest_seq(f"stream{s}"), len(frames))
                    resumed_skip += skip
                for seq, f in enumerate(frames[skip:], start=skip):
                    q = hdc.pack_bits(
                        hdc.sign_project(jnp.asarray(f.feats), R))
                    windows.append(
                        (f"stream{s}", np.asarray(q), f.valid, f.boxes,
                         seq))
            futures = []   # (future, valid-mask, sid, seq), submission order
            t0 = time.time()
            for sid, q, fvalid, fboxes, seq in windows:
                fut = front.submit(sid, q, fvalid, fboxes)
                submitted += 1
                if use_async:
                    if out_f is not None:
                        fut.add_done_callback(_ledger_cb(sid, seq))
                    futures.append((fut, fvalid, sid, seq))
                else:
                    valids.append(fvalid)
            if use_async:
                from ..serving.deadline import WindowShed
                front.flush()
                t_total += time.time() - t0
                for fut, vmask, sid, seq in futures:
                    try:
                        wout, tel = fut.result()
                    except WindowShed:
                        shed += 1
                        accounted += 1
                        continue
                    except Exception:   # noqa: BLE001 — lost window,
                        continue        # tallied by the zero-loss gate
                    accounted += 1
                    paths.append(np.asarray(tel.path))
                    valids.append(vmask)
            else:
                results = eng.drain()
                eng.sync()
                t_total += time.time() - t0
                for s in wave:
                    for seq, (wout, tel) in enumerate(
                            results[f"stream{s}"]):
                        accounted += 1
                        paths.append(np.asarray(tel.path))
                        if out_f is not None:
                            _write_output(out_f, f"stream{s}", seq, wout)
            for s in wave:
                front.retire(f"stream{s}")
    except KeyboardInterrupt:
        # SIGINT/SIGTERM (or a ^C): stop serving but keep going — the
        # whole point of the handler is that the artifact flush below
        # still runs on an interrupted run
        interrupted = True
        print("[serve/torr] interrupted — cancelling in-flight windows "
              "and flushing observability artifacts")
    except Exception as e:  # noqa: BLE001 — terminal engine death
        from ..runtime.fault import EngineDead
        if not isinstance(e, EngineDead):
            raise
        engine_dead = e
        print(f"[serve/torr] engine terminally dead: {e}")
    finally:
        if prev_handlers is not None:
            _restore_signal_handlers(prev_handlers)

    if use_async:
        if sup is not None:
            from ..runtime.fault import EngineDead
            try:
                sup.close(drain=not interrupted and engine_dead is None)
            except EngineDead:
                pass    # already accounted as lost windows
            eng = sup.engine    # a recovery may have swapped the instance
        else:
            eng.close(drain=not interrupted)
    mode = "async" if use_async else "sync"
    print(f"[serve/torr] streams={n_streams} slots={eng.n_slots} "
          f"frames/stream={n_frames} mode={mode}")
    if paths:
        # count only real proposal lanes: padding lanes report as bypass
        pvals = np.concatenate(paths)[np.concatenate(valids)]
        print(f"[serve/torr] {eng.stats.windows} windows in "
              f"{t_total*1e3:.1f} ms ({eng.stats.windows/t_total:.1f} "
              f"windows/s, occupancy {eng.stats.occupancy:.2f})")
    else:
        print("[serve/torr] no windows served")
    if shed:
        print(f"[serve/torr] shed {shed} windows past deadline")
    if paths:
        print(f"[serve/torr] path mix: bypass={np.mean(pvals == 0):.2f} "
              f"delta={np.mean(pvals == 1):.2f} full={np.mean(pvals == 2):.2f}")
    if use_async:
        summary = eng.deadline_summary()
        if summary is not None:
            print(f"[serve/torr] deadline: p99={summary['p99_ms']:.2f} ms "
                  f"jitter={summary['jitter_ms']:.2f} ms "
                  f"miss_rate={summary['miss_rate']:.3f} "
                  f"shed={summary['shed']} escalated={summary['escalated']}")
        gsum = eng.governor_summary()
        if gsum is not None:
            print(f"[serve/torr] governor: level={gsum['level']}"
                  f"/{gsum['n_levels'] - 1} "
                  f"plan=(banks={gsum['plan_banks']}, "
                  f"planes={gsum['plan_planes']}) "
                  f"switches={gsum['plan_switches']} "
                  f"energy_ewma={gsum['energy_ewma_mj']:.1f} mJ "
                  f"windows_by_level={gsum['windows_by_level']}")
        if slo is not None:
            ssum = slo.summary()
            print(f"[serve/torr] slo: alert={ssum['alert']} "
                  f"burn(fast={ssum['burn_fast']:.2f}, "
                  f"slow={ssum['burn_slow']:.2f}) "
                  f"missed={ssum['missed']}/{ssum['completed']} "
                  f"(objective {ssum['objective']:.2f})")

    sup_summary = None
    lost = 0
    if sup is not None:
        sup_summary = sup.summary()
        print(f"[serve/torr] supervisor: restarts={sup_summary['restarts']} "
              f"replayed={sup_summary['windows_replayed']} "
              f"rerun={sup_summary['windows_rerun']} "
              f"degraded={sup_summary['degraded']}")
        if resumed_skip:
            print(f"[serve/torr] resumed: skipped {resumed_skip} windows "
                  "already covered by the state store")
        if not interrupted:
            lost = submitted - accounted
            if lost:
                print(f"[serve/torr] LOST {lost} of {submitted} admitted "
                      "windows — recovery failed to replay them")
    if out_f is not None:
        out_f.close()

    if registry is None:
        if store is not None and hasattr(store, "close"):
            store.close()
        if lost:
            raise SystemExit(3)
        return None
    # fold any telemetry still deferred by the sync engine's double
    # buffering before the registry is read (no-op on the async runtime,
    # whose collector owns the fold)
    eng.flush_telemetry()
    metrics_text = None
    if server is not None:
        import urllib.request
        with urllib.request.urlopen(
                f"http://127.0.0.1:{server.port}/metrics") as resp:
            metrics_text = resp.read().decode()
        n_fam = metrics_text.count("# TYPE ")
        print(f"[serve/torr] metrics: {n_fam} families exposed at /metrics")
        server.close()
    if metrics_json:
        from ..obs import write_json_snapshot
        write_json_snapshot(registry, metrics_json)
        print(f"[serve/torr] metrics snapshot -> {metrics_json}")
    if flight_jsonl:
        n_rec = flight.dump_jsonl(flight_jsonl)
        print(f"[serve/torr] flight recorder: {n_rec} step records -> "
              f"{flight_jsonl}")
    if trace_json:
        from ..obs import write_chrome_trace
        n_ev = write_chrome_trace(flight.records(), trace_json)
        print(f"[serve/torr] chrome trace: {n_ev} events "
              f"({tracer.minted} windows traced) -> {trace_json}")
    result = {"registry": registry, "flight": flight, "tracer": tracer,
              "slo": slo, "metrics_text": metrics_text,
              "summary": eng.summary(), "interrupted": interrupted,
              "supervisor": sup_summary, "lost": lost,
              "submitted": submitted}
    if store is not None and hasattr(store, "close"):
        store.close()
    if lost:
        raise SystemExit(3)
    return result


def run_torr_gateway(n_slots: int = 8, serial: bool = False, rt: str = "",
                     governor: bool = False, fused: str | None = None,
                     metrics_port: int | None = None, metrics_json: str = "",
                     flight_jsonl: str = "", flight_capacity: int = 4096,
                     trace_json: str = "", supervise: bool = False,
                     state_store: str = "", snapshot_every: int = 1,
                     fault_at: int | None = None,
                     fault_kind: str = "dispatcher",
                     gateway_port: int = 0, gateway_host: str = "127.0.0.1",
                     gateway_rate: float = 200.0, gateway_burst: int = 100,
                     gateway_deadline_ms: float = 2000.0,
                     gateway_max_conns: int = 64,
                     gateway_tenant_sessions: int = 8,
                     run_seconds: float = 0.0,
                     use_async: bool = True):
    """Serve the TorR engine behind the network gateway until SIGTERM.

    The same engine stack as :func:`run_torr_streams` — config, synthetic
    TOOD world, observability tier, state store, chaos plan, supervisor —
    but instead of driving synthetic streams in-process, the
    :class:`repro.serving.gateway.Gateway` listens on
    ``gateway_host:gateway_port`` (0 = ephemeral, printed as a
    ``listening`` line that ``benchmarks/loadgen.py --spawn`` parses) and
    clients open tenant sessions over real sockets. SIGINT/SIGTERM
    triggers the graceful drain: stop accepting, flush in-flight
    requests, close the engine, write every armed artifact, exit 0.

    ``run_seconds > 0`` bounds the serve window (tests); 0 serves until
    a signal arrives.
    """
    from ..data import tood_synth as ts
    from ..serving import tood_pipelines as tp
    from ..serving.gateway import Gateway, GatewayLimits, SyncDriver

    supervise = supervise or fault_at is not None
    use_async = use_async or bool(rt) or governor or supervise

    cfg = TorrConfig(D=2048, B=8, M=64, K=16, N_max=16, delta_budget=256)
    world = ts.make_world(seed=0, M=cfg.M, d=cfg.feat_dim)
    sys_ = tp.build_system(world, cfg, seed=0)

    registry = flight = server = tracer = slo = None
    if metrics_port is not None or metrics_json or flight_jsonl or trace_json:
        from ..obs import FlightRecorder, MetricsRegistry, MetricsServer
        registry = MetricsRegistry()
        flight = FlightRecorder(flight_capacity, metrics=registry)
        if trace_json:
            from ..obs import Tracer
            tracer = Tracer(metrics=registry)
        if metrics_port is not None:
            server = MetricsServer(registry, port=metrics_port)
            print(f"[serve/gateway] metrics endpoint "
                  f"http://127.0.0.1:{server.start()}/metrics")

    store = fault = sup = None
    if supervise or state_store:
        from ..serving.state_store import InMemoryStateStore, JsonlStateStore
        store = (JsonlStateStore(state_store, metrics=registry)
                 if state_store else InMemoryStateStore(metrics=registry))
    if fault_at is not None:
        from ..runtime.fault import FaultPlan
        fault = FaultPlan(at_step=fault_at, thread=fault_kind,
                          kind=fault_kind)

    driver = None
    if use_async:
        from ..serving.async_engine import AsyncStreamEngine
        from ..serving.deadline import DeadlineTracker, policy_for
        if governor and not rt:
            rt = "RT-60"
        tracker = None
        if rt:
            if registry is not None:
                from ..obs import SLOMonitor
                slo = SLOMonitor(metrics=registry, flight=flight)
            tracker = DeadlineTracker(policy_for(rt), metrics=registry,
                                      slo=slo)
        gov = None
        if governor:
            from ..control import Governor, policy_from_env
            gov = Governor(cfg, policy_from_env(rt), metrics=registry)

        def make_engine():
            return AsyncStreamEngine(
                cfg, sys_.im, n_slots=n_slots, serial=serial, fused=fused,
                tracker=tracker, governor=gov, paused=True,
                metrics=registry, flight=flight, tracer=tracer,
                store=store, snapshot_every=snapshot_every,
                fault_plan=fault)

        if supervise:
            from ..serving.supervisor import ServeSupervisor
            sup = ServeSupervisor(make_engine, store, metrics=registry,
                                  flight=flight)
            eng = sup.engine
            if server is not None:
                server.set_ready(sup.health)
        else:
            eng = make_engine()
        front = sup if sup is not None else eng
    else:
        from ..serving.stream_engine import StreamEngine
        eng = StreamEngine(cfg, sys_.im, n_slots=n_slots, serial=serial,
                           fused=fused, metrics=registry, flight=flight,
                           tracer=tracer, store=store,
                           snapshot_every=snapshot_every, fault_plan=fault)
        driver = SyncDriver(eng, metrics=registry)
        front = driver

    eng.warmup()
    if use_async:
        eng.start()

    limits = GatewayLimits(
        rate_per_s=gateway_rate, burst=gateway_burst,
        request_deadline_s=gateway_deadline_ms / 1e3,
        max_connections=gateway_max_conns,
        max_sessions_per_tenant=gateway_tenant_sessions)
    gw = Gateway(front, cfg, sys_.task_w, limits=limits,
                 host=gateway_host, port=gateway_port,
                 metrics=registry, flight=flight)
    if server is not None and sup is None:
        server.set_ready(gw._front_health)

    interrupted = False
    prev_handlers = None
    try:
        prev_handlers = _install_signal_handlers()
        gw.start()
        # the loadgen --spawn handshake line: printed only once the
        # socket accepts (flush so a pipe reader sees it immediately)
        print(f"[serve/gateway] listening on "
              f"http://{gateway_host}:{gw.port} "
              f"(SIGINT/SIGTERM drains and flushes artifacts)", flush=True)
        t_end = None if run_seconds <= 0 else time.time() + run_seconds
        while t_end is None or time.time() < t_end:
            time.sleep(0.2)
    except KeyboardInterrupt:
        interrupted = True
        print("[serve/gateway] signal received — draining", flush=True)
    finally:
        if prev_handlers is not None:
            _restore_signal_handlers(prev_handlers)

    drained = gw.drain(timeout=max(10.0, 2 * limits.request_deadline_s))
    gw.close()
    summary = gw.summary()
    print(f"[serve/gateway] drained={drained} sessions={summary['sessions']}")
    from ..runtime.fault import EngineDead
    if sup is not None:
        try:
            sup.close(drain=False)
        except EngineDead:
            pass
        eng = sup.engine
        s = sup.summary()
        print(f"[serve/gateway] supervisor: restarts={s['restarts']} "
              f"replayed={s['windows_replayed']} rerun={s['windows_rerun']} "
              f"degraded={s['degraded']}")
    elif driver is not None:
        driver.close()
    elif use_async:
        try:
            eng.close(drain=False)
        except EngineDead:
            pass

    if registry is not None:
        eng.flush_telemetry()
        if server is not None:
            server.close()
        if metrics_json:
            from ..obs import write_json_snapshot
            write_json_snapshot(registry, metrics_json)
            print(f"[serve/gateway] metrics snapshot -> {metrics_json}")
        if flight_jsonl:
            n_rec = flight.dump_jsonl(flight_jsonl)
            print(f"[serve/gateway] flight recorder: {n_rec} records -> "
                  f"{flight_jsonl}")
        if trace_json:
            from ..obs import write_chrome_trace
            n_ev = write_chrome_trace(flight.records(), trace_json)
            print(f"[serve/gateway] chrome trace: {n_ev} events -> "
                  f"{trace_json}")
    if store is not None and hasattr(store, "close"):
        store.close()
    print(f"[serve/gateway] exit 0 (interrupted={interrupted})", flush=True)
    return {"registry": registry, "flight": flight, "drained": drained,
            "summary": summary,
            "supervisor": sup.summary() if sup is not None else None}


def _write_output(f, sid, seq, wout) -> None:
    """Append one resolved window's output record (fsync'd: the SIGKILL
    recovery test diffs these ledgers across runs, so a record must never
    be half-written)."""
    import hashlib
    import json
    import os

    scores = np.ascontiguousarray(np.asarray(wout.scores))
    rec = {"stream": sid, "seq": int(seq),
           "best": np.asarray(wout.best).tolist(),
           "scores_sha256": hashlib.sha256(scores.tobytes()).hexdigest()}
    f.write(json.dumps(rec) + "\n")
    f.flush()
    os.fsync(f.fileno())


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="musicgen-large")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--rerank", action="store_true")
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--torr-streams", type=int, default=0,
                    help="serve N synthetic TOOD streams through the "
                         "multi-stream window engine and exit")
    ap.add_argument("--torr-frames", type=int, default=30)
    ap.add_argument("--torr-slots", type=int, default=0,
                    help="stream slots (defaults to --torr-streams)")
    ap.add_argument("--torr-serial", action="store_true",
                    help="lax.map lowering (scalar branching; CPU-friendly) "
                         "instead of vmap lanes")
    ap.add_argument("--torr-fused", default="", metavar="MODE",
                    choices=["", "switch", "prefix", "compact", "auto",
                             "off"],
                    help="full-path kernel dispatch: switch | prefix | "
                         "compact (reuse-aware compact-then-compute) | "
                         "auto (load-aware: the engine picks compact vs "
                         "hoisted per step from the telemetry path-mix "
                         "EWMA) | off (oracle); default picks per "
                         "lowering — see repro.core.pipeline."
                         "torr_window_step")
    ap.add_argument("--async", dest="use_async", action="store_true",
                    help="dispatch/collect split: overlap host window "
                         "assembly with device steps (AsyncStreamEngine)")
    ap.add_argument("--mesh", type=int, default=0, metavar="N",
                    help="shard stream slots over N devices, -1 = all "
                         "available (implies --async; default 0 = no "
                         "sharding)")
    ap.add_argument("--rt", default="", choices=["", "RT-30", "RT-60"],
                    help="arm RT-deadline admission control at this "
                         "operating point (implies --async)")
    ap.add_argument("--governor", action="store_true",
                    help="close the QoS loop: slack-driven bank/precision "
                         "gating with the energy governor (implies --async; "
                         "defaults --rt to RT-60; see module docstring for "
                         "TORR_GOV_* env overrides)")
    ap.add_argument("--metrics-port", type=int, default=None, metavar="PORT",
                    help="serve Prometheus text on 127.0.0.1:PORT/metrics "
                         "for the duration of the run (0 = ephemeral port, "
                         "printed at startup); metric catalog in "
                         "docs/observability.md")
    ap.add_argument("--metrics-json", default="", metavar="PATH",
                    help="dump the final metrics registry snapshot as JSON "
                         "(the CI bench-smoke artifact shape)")
    ap.add_argument("--flight-jsonl", default="", metavar="PATH",
                    help="spill the flight recorder (one structured record "
                         "per dispatched step) to JSONL; replay offline "
                         "with repro.obs.flight.replay")
    ap.add_argument("--trace-json", default="", metavar="PATH",
                    help="arm per-window causal tracing and write a Chrome "
                         "trace-event JSON (open in chrome://tracing or "
                         "ui.perfetto.dev); see docs/observability.md")
    ap.add_argument("--supervise", action="store_true",
                    help="wrap the engine in a ServeSupervisor: worker "
                         "death restarts the engine, re-admits streams "
                         "warm from the state store and replays in-flight "
                         "windows (implies --async; see docs/robustness.md)")
    ap.add_argument("--state-store", default="", metavar="PATH",
                    help="file-backed JSONL session store (a SIGKILLed run "
                         "resumes from it); default with --supervise is "
                         "in-memory")
    ap.add_argument("--snapshot-every", type=int, default=1, metavar="N",
                    help="write-through a stream's session snapshot every "
                         "N served windows (default 1)")
    ap.add_argument("--fault-at", type=int, default=None, metavar="STEP",
                    help="chaos harness: kill the engine worker at this "
                         "dispatched-step index (implies --supervise)")
    ap.add_argument("--fault-kind", default="dispatcher",
                    choices=["dispatcher", "collector"],
                    help="which worker thread the injected fault kills "
                         "(default dispatcher)")
    ap.add_argument("--outputs-jsonl", default="", metavar="PATH",
                    help="stream one fsync'd record per resolved window "
                         "(stream, seq, best classes, scores digest) — the "
                         "recovery tests' bit-match ledger")
    ap.add_argument("--gateway-port", type=int, default=None, metavar="PORT",
                    help="serve the network event gateway on this port "
                         "(0 = ephemeral, printed at startup) instead of "
                         "driving synthetic streams in-process; runs until "
                         "SIGTERM, then drains gracefully "
                         "(docs/gateway.md)")
    ap.add_argument("--gateway-host", default="127.0.0.1")
    ap.add_argument("--gateway-rate", type=float, default=200.0,
                    metavar="N", help="per-tenant token-bucket refill "
                    "rate, windows/s (default 200)")
    ap.add_argument("--gateway-burst", type=int, default=100, metavar="N",
                    help="per-tenant token-bucket depth (default 100)")
    ap.add_argument("--gateway-deadline-ms", type=float, default=2000.0,
                    metavar="MS", help="default per-request wait budget "
                    "before a window parks with 503 (default 2000)")
    ap.add_argument("--gateway-max-conns", type=int, default=64, metavar="N")
    ap.add_argument("--gateway-tenant-sessions", type=int, default=8,
                    metavar="N", help="per-tenant session quota (fair "
                    "slot admission; default 8)")
    ap.add_argument("--gateway-seconds", type=float, default=0.0,
                    metavar="S", help="bound the serve window (0 = until "
                    "signal)")
    ap.add_argument("--gateway-sync", action="store_true",
                    help="drive the sync StreamEngine through the "
                         "SyncDriver adapter instead of the async runtime "
                         "(incompatible with --rt/--governor/--supervise)")
    args = ap.parse_args()

    if args.gateway_port is not None:
        run_torr_gateway(
            n_slots=args.torr_slots or 8, serial=args.torr_serial,
            rt=args.rt, governor=args.governor,
            fused=args.torr_fused or None,
            metrics_port=args.metrics_port, metrics_json=args.metrics_json,
            flight_jsonl=args.flight_jsonl, trace_json=args.trace_json,
            supervise=args.supervise, state_store=args.state_store,
            snapshot_every=args.snapshot_every, fault_at=args.fault_at,
            fault_kind=args.fault_kind, gateway_port=args.gateway_port,
            gateway_host=args.gateway_host, gateway_rate=args.gateway_rate,
            gateway_burst=args.gateway_burst,
            gateway_deadline_ms=args.gateway_deadline_ms,
            gateway_max_conns=args.gateway_max_conns,
            gateway_tenant_sessions=args.gateway_tenant_sessions,
            run_seconds=args.gateway_seconds,
            use_async=not args.gateway_sync)
        return

    if args.torr_streams > 0:
        run_torr_streams(args.torr_streams, args.torr_frames,
                         args.torr_slots, serial=args.torr_serial,
                         use_async=(args.use_async or args.mesh != 0
                                    or bool(args.rt) or args.governor),
                         mesh_devices=args.mesh, rt=args.rt,
                         governor=args.governor,
                         fused=args.torr_fused or None,
                         metrics_port=args.metrics_port,
                         metrics_json=args.metrics_json,
                         flight_jsonl=args.flight_jsonl,
                         trace_json=args.trace_json,
                         supervise=args.supervise,
                         state_store=args.state_store,
                         snapshot_every=args.snapshot_every,
                         fault_at=args.fault_at,
                         fault_kind=args.fault_kind,
                         outputs_jsonl=args.outputs_jsonl)
        return

    cfg = get_smoke(args.arch) if args.smoke else get(args.arch)
    key = jax.random.PRNGKey(0)
    params = tf.init_params(key, cfg)

    B, S = args.batch, args.prompt_len
    rng = np.random.default_rng(0)
    if cfg.family == "audio":
        tokens = rng.integers(0, cfg.vocab, (B, S, cfg.n_codebooks))
    else:
        tokens = rng.integers(0, cfg.vocab, (B, S))
    batch = {"tokens": jnp.asarray(tokens, jnp.int32)}
    if cfg.family == "vlm":
        batch["vision"] = jnp.asarray(
            rng.standard_normal((B, cfg.n_vision_tokens, cfg.vision_dim)),
            jnp.bfloat16)

    prefill = jax.jit(tf.prefill, static_argnames="cfg")
    decode = jax.jit(tf.decode_step, static_argnames=("cfg", "return_hidden"))

    t0 = time.time()
    cache, logits = prefill(params, batch, cfg)
    t_prefill = time.time() - t0

    rcfg, rparams, rim, rstate = None, None, None, None
    rstep = None
    if args.rerank:
        rcfg = TorrConfig(D=2048, B=8, M=min(cfg.vocab, 256), K=8,
                          N_max=B, feat_dim=cfg.d_model)
        rparams, rim = rr.init_reranker(jax.random.PRNGKey(7), rcfg,
                                        cfg.d_model, cfg.vocab, alpha=0.5)
        rstate = rr.init_state(rcfg, B)
        rstep = jax.jit(rr.rerank_step, static_argnames=("cfg",))

    sample_key = jax.random.PRNGKey(1)
    generated = []
    bypassed_frac = []
    hidden = None
    t0 = time.time()
    for i in range(args.gen):
        if args.rerank and hidden is not None and cfg.family != "audio":
            logits, rstate, tel = rstep(rparams, rstate, rim,
                                        hidden, logits, rcfg)
            bypassed_frac.append(float(jnp.mean(tel["bypassed"])))
        if cfg.family == "audio":
            lf = logits.reshape(B, cfg.n_codebooks, cfg.vocab)
            sample_key, k = jax.random.split(sample_key)
            nxt = jax.random.categorical(k, lf / args.temperature, axis=-1)
        else:
            sample_key, k = jax.random.split(sample_key)
            nxt = jax.random.categorical(k, logits / args.temperature, axis=-1)
        generated.append(np.asarray(nxt))
        cache, logits, hidden = decode(params, cache, nxt, cfg,
                                       return_hidden=True)
    t_decode = time.time() - t0

    print(f"[serve] arch={cfg.name} batch={B} prompt={S} gen={args.gen}")
    print(f"[serve] prefill {t_prefill*1e3:.1f} ms; decode "
          f"{t_decode/args.gen*1e3:.1f} ms/token "
          f"({B*args.gen/t_decode:.1f} tok/s)")
    if bypassed_frac:
        print(f"[serve] reranker bypass rate: {np.mean(bypassed_frac):.2f}")
    out = np.stack(generated, axis=1)
    print(f"[serve] generated shape {out.shape}, sample: {out[0].ravel()[:16]}")


if __name__ == "__main__":
    main()
