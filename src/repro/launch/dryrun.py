import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("DRYRUN_EXTRA_XLA_FLAGS", "")
).strip()

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST be run as its own process (`python -m repro.launch.dryrun ...`) so the
XLA_FLAGS above take effect before jax initializes. Produces one JSON per
cell under experiments/dryrun/ with memory analysis, cost analysis and the
three roofline terms.

Usage:
    python -m repro.launch.dryrun --arch deepseek-7b --shape train_4k
    python -m repro.launch.dryrun --arch all --shape all          # sweep
    python -m repro.launch.dryrun ... --multi-pod                 # 2x16x16
"""
import argparse
import json
import pathlib
import sys
import time
import traceback

import jax

from ..configs.registry import ARCHS, SHAPES, get, shape_for
from ..perf import roofline
from ..runtime import steps
from .mesh import make_production_mesh

OUT_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: pathlib.Path = OUT_DIR, variant: str = "baseline",
             cfg_override=None) -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cell_id = f"{arch}__{shape_name}__{mesh_name}__{variant}"
    out_path = out_dir / f"{cell_id}.json"
    out_dir.mkdir(parents=True, exist_ok=True)

    shape = shape_for(arch, shape_name)
    if shape is None:
        rec = {"cell": cell_id, "status": "SKIP",
               "reason": "full-attention arch; long_500k requires "
                         "sub-quadratic attention (see DESIGN.md)"}
        out_path.write_text(json.dumps(rec, indent=2))
        return rec

    cfg = cfg_override or get(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size

    t0 = time.time()
    try:
        lowered, meta = steps.lower_cell(cfg, shape, mesh)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        rl = roofline.analyze(
            compiled, arch=arch, shape=shape_name, mesh_name=mesh_name,
            chips=chips, model_flops=roofline.model_flops_for(cfg, shape))
        rec = {
            "cell": cell_id, "status": "OK", "mode": meta["mode"],
            "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
            "params": cfg.param_count(),
            "active_params": cfg.active_param_count(),
            **rl.to_dict(),
        }
    except Exception as e:  # noqa: BLE001 - report, don't crash the sweep
        rec = {"cell": cell_id, "status": "FAIL",
               "error": f"{type(e).__name__}: {e}",
               "trace": traceback.format_exc()[-4000:]}
    out_path.write_text(json.dumps(rec, indent=2))
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out-dir", default=str(OUT_DIR))
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--set", action="append", default=[],
                    help="config overrides for §Perf variants, e.g. "
                         "--set serve_quant=int8 --set attn_remat=True")
    args = ap.parse_args()

    cfg_override = None
    if args.set:
        import dataclasses
        kv = {}
        for item in args.set:
            k, v = item.split("=", 1)
            kv[k] = (v == "True" if v in ("True", "False")
                     else int(v) if v.lstrip("-").isdigit() else v)

        def make_override(arch):
            return dataclasses.replace(get(arch), **kv)
    archs = list(ARCHS) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    rc = 0
    for a in archs:
        for s in shapes:
            rec = run_cell(a, s, args.multi_pod,
                           pathlib.Path(args.out_dir), args.variant,
                           cfg_override=make_override(a) if args.set else None)
            status = rec["status"]
            extra = ""
            if status == "OK":
                extra = (f" bottleneck={rec['bottleneck']}"
                         f" t=({rec['t_compute']:.3e},{rec['t_memory']:.3e},"
                         f"{rec['t_collective']:.3e})s"
                         f" compile={rec['compile_s']}s")
            elif status == "FAIL":
                extra = " " + rec["error"][:200]
                rc = 1
            print(f"[dryrun] {rec['cell']}: {status}{extra}", flush=True)
    return rc


if __name__ == "__main__":
    sys.exit(main())
