"""XNOR-popcount associative similarity kernel (paper Sec. 4.2/4.3, full path).

TPU adaptation of the ASIC's shared bipolar-cosine micro-kernel: hypervectors
are packed 32 dims/word; XOR + population_count on the VPU gives the hamming
distance, and dot = d_eff - 2*hamming. Bank gating (D') is realized by
*static word-count specialization* — the wrapper slices the enabled prefix of
words, so each D' compiles to a kernel that genuinely reads less memory
(the TPU analogue of SRAM bank enables).

Grid: (queries, class-tiles, word-tiles), word dim fastest so each (n, m)
output block accumulates hamming counts across word tiles in VMEM.

Block shapes: item-memory tile (TM, TW) uint32 in VMEM; TW is a multiple of
128 (lane width), TM a multiple of 8 (sublane). The M x TW tile is broadcast
against one query row — the analogue of the ASIC's column broadcast to W
class lanes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(q_ref, im_ref, ham_ref):
    w = pl.program_id(2)

    @pl.when(w == 0)
    def _init():
        ham_ref[...] = jnp.zeros_like(ham_ref)

    x = jnp.bitwise_xor(q_ref[0, :][None, :], im_ref[...])      # [TM, TW]
    pc = jax.lax.population_count(x).astype(jnp.int32)
    ham_ref[...] += jnp.sum(pc, axis=1)[None, :]


@functools.partial(jax.jit, static_argnames=("tm", "tw", "interpret"))
def packed_hamming(
    q_packed: jax.Array,    # uint32 [N, W_eff]  (already sliced to enabled words)
    im_packed: jax.Array,   # uint32 [M, W_eff]
    *,
    tm: int = 128,
    tw: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Hamming distance of every query to every class: int32 [N, M]."""
    N, W = q_packed.shape
    M, W2 = im_packed.shape
    assert W == W2, (W, W2)
    tm = min(tm, M)
    tw = min(tw, W)
    assert M % tm == 0 and W % tw == 0, (M, tm, W, tw)

    grid = (N, M // tm, W // tw)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tw), lambda n, m, w: (n, w)),
            pl.BlockSpec((tm, tw), lambda n, m, w: (m, w)),
        ],
        out_specs=pl.BlockSpec((1, tm), lambda n, m, w: (n, m)),
        out_shape=jax.ShapeDtypeStruct((N, M), jnp.int32),
        interpret=interpret,
    )(q_packed, im_packed)
