"""XNOR-popcount associative similarity kernel (paper Sec. 4.2/4.3, full path).

TPU adaptation of the ASIC's shared bipolar-cosine micro-kernel: hypervectors
are packed 32 dims/word; XOR + population_count on the VPU gives the hamming
distance, and dot = d_eff - 2*hamming. Bank gating (D') is realized by
*static word-count specialization* — the wrapper slices the enabled prefix of
words, so each D' compiles to a kernel that genuinely reads less memory
(the TPU analogue of SRAM bank enables).

Grid: (query-tiles, class-tiles, word-tiles), word dim fastest so each
(n, m) output block accumulates hamming counts across word tiles in VMEM.
Each program processes a TQ x TM block of the output — a *block of queries*
per program rather than one row — which is what lets the multi-stream
engine amortize the item-memory tile across S stream slots' proposals
(S * N_max query rows per window batch). ``packed_hamming`` is the TQ=1
specialization kept for single-stream callers.

Block shapes: item-memory tile (TM, TW) uint32 in VMEM; TW is a multiple of
128 (lane width), TM a multiple of 8 (sublane), TQ a small sublane-multiple
(8 by default) so the TQ x TM x TW xor intermediate stays VMEM-resident.
The M x TW tile is broadcast against TQ query rows — the analogue of the
ASIC's column broadcast to W class lanes, repeated over a query block.

TPU autotuning without code edits: the ``tq``/``tm`` defaults are
overridable through environment variables and/or the autotune sweep's JSON
artifact, all read once at import. Precedence (highest first):

    knob | source              | default | constraint
    ---- | ------------------- | ------- | ---------------------------------
    tq   | ``TORR_TQ`` env     |       8 | query-block rows; sublane
         | ``TORR_TUNE_FILE``  |         | multiple (8) preferred, clipped
         | artifact ``best.tq``|         | to divide N
    tm   | ``TORR_TM`` env     |     128 | class-tile rows; multiple of 8,
         | ``TORR_TUNE_FILE``  |         | clipped to divide M
         | artifact ``best.tm``|         |
    tw   | (fixed)             |     128 | word-tile = lane width; not
         |                     |         | tunable (clipped to divide W)

``TORR_TUNE_FILE`` points at the JSON artifact written by
``benchmarks/autotune_blocks.py`` (``{"best": {"tq": .., "tm": ..}, ...}``),
so a sweep's winner applies fleet-wide without hand-exported shape vars;
an explicit ``TORR_TQ``/``TORR_TM`` still wins over the file, and a
missing/corrupt file named by the env var is an error, not a silent
fallback. The built-in defaults are interpret-mode safe and
VMEM-conservative (TQ*TM*TW*4B = 512 KiB intermediate at 8x128x128); on
real TPU sweep ``TORR_TQ in {8, 16, 32}`` x ``TORR_TM in {128, 256, 512}``
against ``benchmarks/micro_aligner.py`` — the direct kernel defaults, the
tile caps used by ``kernels.ops`` and the fused family in
``kernels.fused_window`` all honor the overrides, so no call site changes.
"""
from __future__ import annotations

import functools
import json
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _tuned_tiles() -> dict:
    """Block shapes from the ``TORR_TUNE_FILE`` autotune artifact (the JSON
    written by ``benchmarks/autotune_blocks.py``); {} when unset."""
    path = os.environ.get("TORR_TUNE_FILE", "")
    if not path:
        return {}
    try:
        with open(path) as f:
            artifact = json.load(f)
        best = artifact["best"]
        return {"tq": int(best["tq"]), "tm": int(best["tm"])}
    except (OSError, ValueError, KeyError, TypeError) as e:
        raise ValueError(
            f"TORR_TUNE_FILE={path!r} is not a readable autotune artifact "
            f"({{'best': {{'tq': .., 'tm': ..}}}}): {e}") from None


def _env_tile(name: str, default: int, tuned: int | None = None) -> int:
    """Block-shape override: env var wins, then the tune-file artifact,
    then the built-in default (bad values rejected)."""
    raw = os.environ.get(name, "")
    if not raw:
        val = default if tuned is None else tuned
    else:
        try:
            val = int(raw)
        except ValueError:
            raise ValueError(f"{name}={raw!r} is not an integer") from None
    if val <= 0:
        raise ValueError(f"{name}={val} must be positive")
    return val


_TUNED = _tuned_tiles()
TQ_DEFAULT = _env_tile("TORR_TQ", 8, _TUNED.get("tq"))
TM_DEFAULT = _env_tile("TORR_TM", 128, _TUNED.get("tm"))
TW = 128   # lane width; fixed


def fit_tile(n: int, cap: int) -> int:
    """Largest divisor of ``n`` that is <= ``cap`` (>= 1).

    Decrements (trace-time only; n is at most a few thousand) rather than
    halving so a non-power-of-two cap — e.g. TORR_TM=192 against M=1024 —
    lands on the biggest usable divisor (128) instead of degenerating to 1.
    Shared by this module's block-shape clipping and ``kernels.ops``'s tile
    caps."""
    t = max(1, min(cap, n))
    while n % t:
        t -= 1
    return t


def _kernel(q_ref, im_ref, ham_ref):
    w = pl.program_id(2)

    @pl.when(w == 0)
    def _init():
        ham_ref[...] = jnp.zeros_like(ham_ref)

    q = q_ref[...]                                              # [TQ, TW]
    im = im_ref[...]                                            # [TM, TW]
    x = jnp.bitwise_xor(q[:, None, :], im[None, :, :])          # [TQ, TM, TW]
    pc = jax.lax.population_count(x).astype(jnp.int32)
    ham_ref[...] += jnp.sum(pc, axis=-1)                        # [TQ, TM]


@functools.partial(jax.jit, static_argnames=("tq", "tm", "tw", "interpret"))
def packed_hamming_batched(
    q_packed: jax.Array,    # uint32 [N, W_eff]  (already sliced to enabled words)
    im_packed: jax.Array,   # uint32 [M, W_eff]
    *,
    tq: int | None = None,
    tm: int | None = None,
    tw: int = TW,
    interpret: bool = True,
) -> jax.Array:
    """Hamming distance of every query to every class: int32 [N, M].

    One grid program covers a (tq, tm) output block, so a batch of queries
    (e.g. all proposals of all admitted streams in one multi-stream window)
    reuses each item-memory tile tq times from VMEM. Used by both the
    full-path scan and the cache-nearest lookup (`ops.cache_nearest`), which
    is just this kernel with the query cache as the "item memory".

    ``tq``/``tm`` default to the ``TORR_TQ``/``TORR_TM`` environment
    overrides (module docstring has the defaults table).
    """
    N, W = q_packed.shape
    M, W2 = im_packed.shape
    assert W == W2, (W, W2)
    # clip the requested (or env-default) block shapes to actual divisors,
    # so any TORR_TQ/TORR_TM sweep value yields a runnable grid
    tq = fit_tile(N, TQ_DEFAULT if tq is None else tq)
    tm = fit_tile(M, TM_DEFAULT if tm is None else tm)
    tw = min(tw, W)
    assert W % tw == 0, (W, tw)

    grid = (N // tq, M // tm, W // tw)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tq, tw), lambda n, m, w: (n, w)),
            pl.BlockSpec((tm, tw), lambda n, m, w: (m, w)),
        ],
        out_specs=pl.BlockSpec((tq, tm), lambda n, m, w: (n, m)),
        out_shape=jax.ShapeDtypeStruct((N, M), jnp.int32),
        interpret=interpret,
    )(q_packed, im_packed)


@functools.partial(jax.jit, static_argnames=("tm", "tw", "interpret"))
def packed_hamming(
    q_packed: jax.Array,    # uint32 [N, W_eff]
    im_packed: jax.Array,   # uint32 [M, W_eff]
    *,
    tm: int | None = None,
    tw: int = TW,
    interpret: bool = True,
) -> jax.Array:
    """Row-per-program variant: the TQ=1 specialization of the batched grid."""
    return packed_hamming_batched(
        q_packed, im_packed, tq=1, tm=tm, tw=tw, interpret=interpret
    )
