"""Pallas TPU kernels for TorR's compute hot-spots, with jnp oracles.

Kernels (each: <name>.py = pl.pallas_call + BlockSpec; ops.py = jit'd
wrappers; ref.py = pure-jnp oracles):
  * xnor_popcount_sim — full-scan bipolar cosine (bit-packed, VPU popcount)
  * delta_update      — Eq. 6 sparse accumulator corrections (scalar-prefetch
                        index streaming = the Delta-FIFO's TPU analogue)
  * sign_project      — fused q = sign(R z) (MXU matmul + int8 quantize)
"""
from . import ops, ref
from .delta_update import delta_update
from .sign_project import sign_project
from .xnor_popcount_sim import packed_hamming, packed_hamming_batched

__all__ = ["ops", "ref", "delta_update", "sign_project", "packed_hamming",
           "packed_hamming_batched"]
