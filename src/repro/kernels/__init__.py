"""Pallas TPU kernels for TorR's compute hot-spots, with jnp oracles.

Kernels (each: <name>.py = pl.pallas_call + BlockSpec; ops.py = jit'd
wrappers; ref.py = pure-jnp oracles; README.md = the two dispatch
contracts):
  * xnor_popcount_sim — full-scan bipolar cosine (bit-packed, VPU popcount)
  * fused_window      — the jitted full path's fused family: gated scan +
                        integer accumulation + argmax/top-2 readout in one
                        grid, the traced-banks bank-prefix variant, the
                        delta scatter-accumulate entry, and the
                        encode->pack front-end
  * delta_update      — Eq. 6 sparse accumulator corrections (scalar-prefetch
                        index streaming = the Delta-FIFO's TPU analogue)
  * sign_project      — fused q = sign(R z) (MXU matmul + int8 quantize)
"""
from . import fused_window, ops, ref
from .delta_update import delta_update
from .fused_window import bank_prefix_hamming, fused_scores, sign_project_pack
from .sign_project import sign_project
from .xnor_popcount_sim import packed_hamming, packed_hamming_batched

__all__ = ["fused_window", "ops", "ref", "delta_update", "sign_project",
           "packed_hamming", "packed_hamming_batched", "fused_scores",
           "bank_prefix_hamming", "sign_project_pack"]
