"""Delta-update kernel: sparse accumulator corrections (paper Eq. 6, Sec 4.3).

The ASIC pops flipped-bit indices from a Delta-FIFO and touches only those
item-memory columns. On TPU the FIFO becomes a *scalar-prefetched index
array* (static delta-budget length): the grid's fast dimension walks the
budget, and the index_map uses the prefetched index to fetch exactly the
flipped row of the D-major item memory — so only O(|Delta| * M) bytes move,
never O(D * M). Padding entries carry weight 0 (and index 0), preserving
exactness.

Grid: (class-tiles, budget); per step the kernel adds
    weight[k] * dmajor[idx[k], m_tile]
into the persistent accumulator block, initialized from acc_in at k == 0.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(idx_ref, w_ref, acc_in_ref, dmaj_ref, out_ref):
    del idx_ref
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = acc_in_ref[...]

    out_ref[...] += w_ref[k] * dmaj_ref[0, :].astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("tm", "interpret"))
def delta_update(
    acc: jax.Array,      # int32 [M] persistent per-class accumulators
    dmajor: jax.Array,   # int8  [D, M] D-major item memory
    idx: jax.Array,      # int32 [budget] flipped dims (0-padded)
    weight: jax.Array,   # int32 [budget] in {-2, 0, +2}
    *,
    tm: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """acc + sum_k weight[k] * dmajor[idx[k], :], via sparse row streaming."""
    (M,) = acc.shape
    budget = idx.shape[0]
    tm = min(tm, M)
    assert M % tm == 0

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(M // tm, budget),
        in_specs=[
            pl.BlockSpec((tm,), lambda m, k, idx, w: (m,)),
            pl.BlockSpec((1, tm), lambda m, k, idx, w: (idx[k], m)),
        ],
        out_specs=pl.BlockSpec((tm,), lambda m, k, idx, w: (m,)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((M,), jnp.int32),
        interpret=interpret,
    )(idx, weight, acc, dmajor)
