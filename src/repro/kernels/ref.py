"""Pure-jnp oracles for every Pallas kernel (shape/dtype-exact)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def packed_hamming_ref(q_packed: jax.Array, im_packed: jax.Array) -> jax.Array:
    """int32 [N, M] hamming distances from packed uint32 words."""
    x = jnp.bitwise_xor(q_packed[:, None, :], im_packed[None, :, :])
    return jnp.sum(jax.lax.population_count(x).astype(jnp.int32), axis=-1)


def delta_update_ref(
    acc: jax.Array, dmajor: jax.Array, idx: jax.Array, weight: jax.Array
) -> jax.Array:
    """int32 [M]: acc + sum_k weight[k] * dmajor[idx[k], :]."""
    rows = dmajor[idx, :].astype(jnp.int32)
    return acc + jnp.einsum("k,km->m", weight, rows)


def sign_project_ref(z: jax.Array, R: jax.Array) -> jax.Array:
    """int8 [N, D] = sign(z @ R.T), sign(0) -> +1."""
    y = z.astype(jnp.float32) @ R.astype(jnp.float32).T
    return jnp.where(y >= 0.0, 1, -1).astype(jnp.int8)
