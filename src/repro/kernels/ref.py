"""Pure-jnp oracles for every Pallas kernel (shape/dtype-exact)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def packed_hamming_ref(q_packed: jax.Array, im_packed: jax.Array) -> jax.Array:
    """int32 [N, M] hamming distances from packed uint32 words."""
    x = jnp.bitwise_xor(q_packed[:, None, :], im_packed[None, :, :])
    return jnp.sum(jax.lax.population_count(x).astype(jnp.int32), axis=-1)


def fused_scores_ref(
    q_packed: jax.Array, im_packed: jax.Array, *, d_eff: int
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """(acc [N, M], best [N], top2 [N, 2]) — `fused_window.fused_scores`."""
    acc = d_eff - 2 * packed_hamming_ref(q_packed, im_packed)
    best = jnp.argmax(acc, axis=-1).astype(jnp.int32)
    if acc.shape[-1] < 2:
        top2 = jnp.concatenate(
            [acc, jnp.full_like(acc, -(2 ** 31))], axis=-1)
    else:
        top2 = jax.lax.top_k(acc, 2)[0]
    return acc, best, top2


def bank_prefix_hamming_ref(
    q_packed: jax.Array, im_packed: jax.Array, *, cap: int
) -> jax.Array:
    """int32 [N, M, cap] — `fused_window.bank_prefix_hamming` (materializes
    the [N, M, W] xor; the kernel exists so the jitted path never does)."""
    N, W = q_packed.shape
    M = im_packed.shape[0]
    epw = W // cap
    x = jnp.bitwise_xor(q_packed[:, None, :], im_packed[None, :, :])
    pc = jax.lax.population_count(x).astype(jnp.int32)          # [N, M, W]
    per_bank = pc.reshape(N, M, cap, epw).sum(axis=-1)          # [N, M, cap]
    return jnp.cumsum(per_bank, axis=-1)


def sign_project_pack_ref(z: jax.Array, R: jax.Array) -> jax.Array:
    """uint32 [N, D//32] — `fused_window.sign_project_pack`."""
    from ..core import hdc   # function-level: core imports this package

    return hdc.pack_bits(sign_project_ref(z, R))


def delta_update_ref(
    acc: jax.Array, dmajor: jax.Array, idx: jax.Array, weight: jax.Array
) -> jax.Array:
    """int32 [M]: acc + sum_k weight[k] * dmajor[idx[k], :]."""
    rows = dmajor[idx, :].astype(jnp.int32)
    return acc + jnp.einsum("k,km->m", weight, rows)


def sign_project_ref(z: jax.Array, R: jax.Array) -> jax.Array:
    """int8 [N, D] = sign(z @ R.T), sign(0) -> +1."""
    y = z.astype(jnp.float32) @ R.astype(jnp.float32).T
    return jnp.where(y >= 0.0, 1, -1).astype(jnp.int8)
