"""Jit'd public wrappers around the Pallas kernels.

These are the entry points the rest of the framework uses. Each chooses the
kernel when shapes are kernel-friendly and transparently falls back to the
oracle otherwise (ragged shapes, tiny trailing dims), so callers never see a
shape constraint. ``interpret`` defaults to True because this container runs
on CPU; on TPU pass interpret=False (the BlockSpecs are TPU-shaped).

Bank gating contract: ``banks`` is a *static* int here. The controller's
per-window bank choice is latched on the host (exactly like the ASIC's
window-latched registers, Sec. 4.6) and dispatches one of <= B specialized
executables. Fully-jitted pipelines, where the per-window bank choice is a
*traced* value, instead go through ``repro.core.aligner.full_scores_all`` —
the ``lax.switch`` / bank-prefix dispatch over the same kernel family in
``kernels.fused_window`` — or, when the path mix is known first, the
compacted-bucket dispatch ``repro.core.aligner.compact_full_scores``
(see ``kernels/README.md`` for the three contracts and when to use which).

Precision gating rides the same contract: ``planes`` (of ``plane_total``
bit-slice planes, ``core.item_memory``'s plane striping) is a static knob
from the latched QoS plan. With all planes kept, the enabled words are the
bank prefix and the original fast path runs unchanged; with planes dropped,
the wrappers select the enabled words *plane-major* — a contiguous
per-plane-block prefix of the item memory's ``pmajor`` view when the caller
provides it, a static column gather otherwise — so the XNOR-popcount scan
genuinely reads fewer words, the TPU analogue of not reading the low-order
bit-slice SRAMs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..core.item_memory import plane_sel
from . import fused_window, ref
from .sign_project import sign_project as _sign_kernel
from .xnor_popcount_sim import TM_DEFAULT, TQ_DEFAULT, TW, fit_tile as _tile
from .xnor_popcount_sim import packed_hamming_batched as _ham_kernel


def _batched_hamming(
    q: jax.Array,           # uint32 [N, W_eff]
    h: jax.Array,           # uint32 [M, W_eff]
    *,
    interpret: bool,
    use_kernel: bool,
) -> jax.Array:
    """Shared dispatch for every packed-hamming consumer (full-path scans
    and cache-nearest lookups): the batched kernel when shapes tile, the
    jnp oracle otherwise. In interpret mode the word tile clips to the
    largest divisor of the enabled word count (<= TW), so sub-lane-width
    D' (small-D configs, deep reduced plans) still rides the kernel; the
    compiled TPU path keeps the lane-width requirement (the BlockSpecs
    are TPU-shaped) and falls back to the oracle off lane alignment."""
    M = h.shape[0]
    words_eff = q.shape[1]
    # tile caps honor the TORR_TQ/TORR_TM autotuning overrides (see the
    # defaults table in kernels.xnor_popcount_sim)
    lane_ok = interpret or words_eff % TW == 0
    if use_kernel and M % 8 == 0 and lane_ok:
        return _ham_kernel(q, h, tq=_tile(q.shape[0], TQ_DEFAULT),
                           tm=_tile(M, TM_DEFAULT),
                           tw=_tile(words_eff, TW),
                           interpret=interpret)
    return ref.packed_hamming_ref(q, h)


def _plan_columns(
    arrays: tuple[jax.Array, ...],
    banks: int,
    bank_words: int,
    planes: int | None,
    plane_total: int,
    pmajor: jax.Array | None = None,
) -> tuple[tuple[jax.Array, ...], int]:
    """Restrict packed-word arrays to a (banks, planes) plan's enabled words.

    Returns the restricted arrays (all in the *same* column order — hamming
    sums over columns, so any shared order is exact) and the effective
    dimension. Full precision keeps the original contiguous bank-prefix
    slice; reduced precision selects plane-major columns — via a contiguous
    per-plane-block prefix of ``pmajor`` for the array it replaces (the
    item memory, pre-permuted once at build), a static gather otherwise.
    """
    words_eff = banks * bank_words
    if planes is None or planes >= plane_total:
        return tuple(a[:, :words_eff] for a in arrays), 32 * words_eff
    sel = plane_sel(words_eff, planes, plane_total)
    out = []
    for i, a in enumerate(arrays):
        if i == len(arrays) - 1 and pmajor is not None:
            # pmajor's plane blocks span all words; the plan's enabled
            # prefix of plane block p starts at p * (total_words / P)
            wpb = pmajor.shape[1] // plane_total
            keep = words_eff // plane_total
            out.append(jnp.concatenate(
                [pmajor[:, p * wpb: p * wpb + keep] for p in range(planes)],
                axis=1))
        else:
            out.append(a[:, sel])
    return tuple(out), 32 * sel.size


def packed_similarity(
    q_packed: jax.Array,     # uint32 [N, W_total]
    im_packed: jax.Array,    # uint32 [M, W_total]
    *,
    banks: int,
    bank_words: int,
    planes: int | None = None,
    plane_total: int = 4,
    pmajor: jax.Array | None = None,
    interpret: bool = True,
    use_kernel: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Full-scan scores under the (banks, planes) plan's enabled dims.

    D' = 32 * banks * bank_words * planes / plane_total. Returns
    (acc int32 [N, M], cosine f32 [N, M]). N may be the flattened proposal
    batch of many streams; the kernel processes a block of queries per
    program, so each item-memory tile is read once per block. ``planes``
    (static, from the latched QoS plan; None = all) drops low-order
    bit-slice planes; pass ``pmajor`` (``ItemMemory.pmajor``) to read them
    as contiguous plane-block prefixes instead of gathered columns.
    """
    (q, h), d_eff = _plan_columns(
        (q_packed, im_packed), banks, bank_words, planes, plane_total,
        pmajor=pmajor)
    ham = _batched_hamming(q, h, interpret=interpret, use_kernel=use_kernel)
    acc = d_eff - 2 * ham
    return acc, acc.astype(jnp.float32) / d_eff


def fused_similarity(
    q_packed: jax.Array,     # uint32 [N, W_total]
    im_packed: jax.Array,    # uint32 [M, W_total]
    *,
    banks: int,
    bank_words: int,
    planes: int | None = None,
    plane_total: int = 4,
    pmajor: jax.Array | None = None,
    interpret: bool = True,
    use_kernel: bool = True,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Host-latched entry to the *fused* window-step kernel
    (``kernels.fused_window.fused_scores``): one grid fuses the gated
    XNOR-popcount scan, the integer accumulation and the argmax/top-2
    readout, so neither the ``[N, M, W]`` xor nor a separate readout pass
    materializes. Same static ``(banks, planes)`` contract as
    :func:`packed_similarity`. Returns (acc int32 [N, M], cosine f32 [N, M],
    best int32 [N] — ``argmax(acc)``, top2 int32 [N, 2] — the two highest
    accumulators; ``top2[:, 0] - top2[:, 1]`` is the integer margin).
    """
    (q, h), d_eff = _plan_columns(
        (q_packed, im_packed), banks, bank_words, planes, plane_total,
        pmajor=pmajor)
    acc, best, top2 = fused_window.fused_scores_any(
        q, h, d_eff=d_eff, interpret=interpret, use_kernel=use_kernel)
    return acc, acc.astype(jnp.float32) / d_eff, best, top2


def encode_packed(
    z: jax.Array,   # f32 [N, d] encoder features
    R: jax.Array,   # f32 [D, d] projection
    *,
    interpret: bool | None = None,
    use_kernel: bool = True,
) -> jax.Array:
    """Fused encode front-end: uint32 [N, D//32] = pack(sign(z @ R.T)).

    On the Pallas lowering one kernel (``fused_window.sign_project_pack``)
    keeps the f32 projection *and* the int8 bipolar code in VMEM; only the
    packed words are written. Off-TPU (and off-tile) the jnp form runs —
    XLA fuses the sign into the matmul there, and the pack is cheap."""
    N, _ = z.shape
    D, _ = R.shape
    lowering = fused_window._pallas_lowering(interpret)
    if (use_kernel and lowering is not None
            and D % 128 == 0 and N % 8 == 0):
        td = 256 if D % 256 == 0 else 128
        return fused_window.sign_project_pack(z, R, tn=8, td=td,
                                              interpret=lowering)
    return _encode_packed_jnp(z, R)


_encode_packed_jnp = jax.jit(ref.sign_project_pack_ref)


def cache_nearest(
    q_packed: jax.Array,      # uint32 [N, W_total] query batch
    cache_packed: jax.Array,  # uint32 [K, W_total] cached queries
    cache_valid: jax.Array,   # bool [K]
    *,
    banks: int,
    bank_words: int,
    planes: int | None = None,
    plane_total: int = 4,
    interpret: bool = True,
    use_kernel: bool = True,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Batched PSU nearest-match: every query vs every cache entry.

    Same micro-kernel as the full-path scan — the cache's packed queries
    stand in for the item memory — so full-path and cache-nearest lookups
    share one specialized executable per (D', planes) plan. Returns
    (idx int32 [N], rho f32 [N] per Eq. 5, hamming int32 [N]); invalid
    entries are pushed to rho = -inf as in ``core.query_cache.nearest``.
    """
    (q, c), d_eff = _plan_columns(
        (q_packed, cache_packed), banks, bank_words, planes, plane_total)
    ham = _batched_hamming(q, c, interpret=interpret, use_kernel=use_kernel)
    rho = 1.0 - 2.0 * ham.astype(jnp.float32) / float(d_eff)
    rho = jnp.where(cache_valid[None, :], rho, -jnp.inf)
    idx = jnp.argmax(rho, axis=-1).astype(jnp.int32)
    n = jnp.arange(idx.shape[0])
    return idx, rho[n, idx], ham[n, idx]


def masked_hamming_all(
    q_packed: jax.Array,      # uint32 [N, W_total] query batch
    e_packed: jax.Array,      # uint32 [K, W_total] lookup entries
    wmask: jax.Array,         # bool [W_total] plan-enabled words (may be traced)
    *,
    interpret: bool | None = None,
    use_kernel: bool = True,
) -> jax.Array:
    """Plan-gated hamming lookup table: int32 [N, K], every query row vs
    every entry row, counted over the words ``wmask`` enables.

    The batched form of the per-proposal masked popcount inside
    ``core.query_cache.nearest`` — the one-wide-similarity-pass PSU shape
    the batched decide pass (``core.pipeline._decide_pass_batched``) runs
    over the window-entry cache snapshot and the proposal batch itself.
    Unlike the static-plan wrappers above, ``wmask`` may be a *traced*
    value (Alg. 1's per-window bank choice): both operands are pre-masked
    (disabled words zeroed on both sides, so their xor contributes zero
    popcount), which makes the plain packed-hamming kernel family compute
    the gated sum unchanged — bit-identical to masking the popcounts.

    Lowering selection follows the fused-family contract
    (``fused_window._pallas_lowering``): compiled Pallas on TPU, the jnp
    oracle elsewhere (the [N, K, W] xor is cache-depth-sized, where plain
    XLA beats interpret-mode grid machinery), ``TORR_FUSED_PALLAS=1``
    forces the interpret-mode grid; off-tile shapes fall back to the
    oracle in any mode.
    """
    wmask = wmask[None, :]
    q = jnp.where(wmask, q_packed, jnp.uint32(0))
    e = jnp.where(wmask, e_packed, jnp.uint32(0))
    lowering = fused_window._pallas_lowering(interpret)
    if lowering is None or not use_kernel:
        return ref.packed_hamming_ref(q, e)
    return _batched_hamming(q, e, interpret=lowering, use_kernel=use_kernel)


def delta_update(
    acc: jax.Array,       # int32 [M]
    dmajor: jax.Array,    # int8 [D, M]
    idx: jax.Array,       # int32 [budget]
    weight: jax.Array,    # int32 [budget]
    *,
    interpret: bool | None = None,
    use_kernel: bool = True,
) -> jax.Array:
    """Sparse Eq. 6 correction under the family's lowering-selection
    contract (``fused_window.delta_apply``): the scalar-prefetch kernel on
    the Pallas lowering, the vectorized O(|Delta| * M) gather-einsum
    elsewhere, the oracle off-tile. ``interpret=True`` forces the
    interpret-mode kernel grid (tests)."""
    return fused_window.delta_apply(acc, dmajor, idx, weight,
                                    interpret=interpret,
                                    use_kernel=use_kernel)


def sign_project(
    z: jax.Array,   # f32 [N, d]
    R: jax.Array,   # f32 [D, d]
    *,
    interpret: bool = True,
    use_kernel: bool = True,
) -> jax.Array:
    """Fused bipolar projection; falls back to the oracle off-tile."""
    N, _ = z.shape
    D, _ = R.shape
    if use_kernel and D % 128 == 0 and N % 8 == 0:
        td = 256 if D % 256 == 0 else 128
        return _sign_kernel(z, R, tn=8, td=td, interpret=interpret)
    return ref.sign_project_ref(z, R)
