"""Jit'd public wrappers around the Pallas kernels.

These are the entry points the rest of the framework uses. Each chooses the
kernel when shapes are kernel-friendly and transparently falls back to the
oracle otherwise (ragged shapes, tiny trailing dims), so callers never see a
shape constraint. ``interpret`` defaults to True because this container runs
on CPU; on TPU pass interpret=False (the BlockSpecs are TPU-shaped).

Bank gating contract: ``banks`` is a *static* int here. The controller's
per-window bank choice is latched on the host (exactly like the ASIC's
window-latched registers, Sec. 4.6) and dispatches one of <= B specialized
executables; the functionally-equivalent traced-banks path lives in
``repro.core.aligner`` for fully-jitted pipelines.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .delta_update import delta_update as _delta_kernel
from .sign_project import sign_project as _sign_kernel
from .xnor_popcount_sim import packed_hamming as _ham_kernel


def packed_similarity(
    q_packed: jax.Array,     # uint32 [N, W_total]
    im_packed: jax.Array,    # uint32 [M, W_total]
    *,
    banks: int,
    bank_words: int,
    interpret: bool = True,
    use_kernel: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Full-scan scores under D' = 32 * banks * bank_words enabled dims.

    Returns (acc int32 [N, M], cosine f32 [N, M]).
    """
    words_eff = banks * bank_words
    d_eff = 32 * words_eff
    q = q_packed[:, :words_eff]
    h = im_packed[:, :words_eff]
    M = im_packed.shape[0]
    if use_kernel and words_eff % 128 == 0 and M % 8 == 0:
        tm = M if M <= 128 else 128
        while M % tm:
            tm //= 2
        ham = _ham_kernel(q, h, tm=tm, tw=128, interpret=interpret)
    else:
        ham = ref.packed_hamming_ref(q, h)
    acc = d_eff - 2 * ham
    return acc, acc.astype(jnp.float32) / d_eff


def delta_update(
    acc: jax.Array,       # int32 [M]
    dmajor: jax.Array,    # int8 [D, M]
    idx: jax.Array,       # int32 [budget]
    weight: jax.Array,    # int32 [budget]
    *,
    interpret: bool = True,
    use_kernel: bool = True,
) -> jax.Array:
    """Sparse Eq. 6 correction; falls back to the oracle off-tile."""
    M = acc.shape[0]
    if use_kernel and M % 8 == 0:
        tm = M if M <= 128 else 128
        while M % tm:
            tm //= 2
        return _delta_kernel(acc, dmajor, idx, weight, tm=tm, interpret=interpret)
    return ref.delta_update_ref(acc, dmajor, idx, weight)


def sign_project(
    z: jax.Array,   # f32 [N, d]
    R: jax.Array,   # f32 [D, d]
    *,
    interpret: bool = True,
    use_kernel: bool = True,
) -> jax.Array:
    """Fused bipolar projection; falls back to the oracle off-tile."""
    N, _ = z.shape
    D, _ = R.shape
    if use_kernel and D % 128 == 0 and N % 8 == 0:
        td = 256 if D % 256 == 0 else 128
        return _sign_kernel(z, R, tn=8, td=td, interpret=interpret)
    return ref.sign_project_ref(z, R)
