"""Fused window-step kernels: the jitted full path's Pallas family.

The paper's throughput claim (Sec. 4.2/4.3) is a *memory-traffic* claim: the
bit-sliced XNOR-popcount item memory reads only the enabled banks/planes.
``repro.core.aligner``'s jnp oracle simulates that with masks — it still
reads (and, batched, materializes) the full ``[N, M, W]`` xor. This module
is the co-designed kernel family the fully-jitted pipeline dispatches
instead (``core.aligner.full_scores_all`` is the traced-banks shim):

  * :func:`fused_scores` — one grid fusing the plane/bank-gated
    XNOR-popcount scan, the per-class integer accumulation
    (``acc = D' - 2*hamming``) **and** the argmax / top-2 readout. The
    item-memory tile streams through VMEM (Pallas pipelines input blocks
    with automatic double buffering over the word grid axis), so the
    ``[TQ, TM, TW]`` xor lives only in registers/VMEM and nothing
    ``[N, M, W]``-shaped ever reaches HBM. Static ``(banks, planes)``
    specialization: callers pre-slice the enabled words, so each plan
    compiles to a kernel that genuinely reads less memory.
  * :func:`bank_prefix_hamming` — the traced-banks family member: one pass
    over the (static) plan-capped word prefix emitting the hamming count at
    *every* bank boundary ``[N, cap, M]``. A traced ``banks`` then selects
    its prefix with one gather — the vmap-safe dispatch the multi-stream
    engine uses, where ``lax.switch`` would execute every branch per batch.
    The reuse-aware compact dispatch (``core.aligner.compact_full_scores``,
    the third contract in ``README.md``) runs this same kernel over a
    *bucket* of only the full-path proposals, so ``N`` shrinks with the
    cache hit rate instead of staying pinned at the batch size.
  * :func:`delta_apply` — the delta path's scatter-accumulate (Eq. 6),
    dispatching to the scalar-prefetch ``delta_update`` kernel so the
    bypass/delta/full trio all avoid the jnp oracle inside the jitted step.
  * :func:`sign_project_pack` — encode front-end: sign-projection fused
    with bit-packing, writing uint32 words directly (neither the f32
    projection nor the int8 bipolar code round-trips HBM).

Every kernel keeps the oracle fallback contract of ``kernels.ops``: ragged
shapes transparently use the jnp reference, so callers never see a shape
constraint.

Lowering selection (the ``interpret`` knob of the ``*_any`` dispatchers):

  * ``None`` (default) — Pallas compiled on TPU; on other backends a
    *blocked-jnp* lowering with the identical tiling (a lax.scan over
    query blocks, tile-sized xor) runs instead, because the interpret-mode
    grid machinery loses to plain XLA there. ``TORR_FUSED_PALLAS=1``
    forces interpret-mode Pallas anywhere (how CI validates the kernel
    grids bit-exactly without a TPU).
  * ``True`` — interpret-mode Pallas (explicit; kernel-grid tests).
  * ``False`` — compiled Pallas (explicit TPU request).

Both lowerings are bit-identical (integer hamming sums are order-invariant)
and neither ever materializes an ``[N, M, W]``-shaped intermediate.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref
from .delta_update import delta_update as _delta_kernel
from .xnor_popcount_sim import TM_DEFAULT, TQ_DEFAULT, TW, fit_tile

_I32_MIN = -(2 ** 31)
_I32_MAX = 2 ** 31 - 1

# query-block rows of the blocked-jnp lowering: 8 keeps the [TQ, TM, TW]
# xor tile L2-resident on CPU (measured best in {4..128} at serving shapes)
TQ_BLOCKED = 8


def _pallas_lowering(interpret: bool | None) -> bool | None:
    """Resolve the dispatch knob: the pallas interpret flag to use, or
    None meaning 'take the blocked-jnp lowering'."""
    if interpret is not None:
        return interpret
    if jax.default_backend() == "tpu":
        return False
    if os.environ.get("TORR_FUSED_PALLAS", ""):
        return True
    return None


def resolve_interpret(interpret: bool | None) -> bool:
    """None -> interpret off-TPU only (the BlockSpecs are TPU-shaped)."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


# ---------------------------------------------------------------------------
# fused scan -> acc -> argmax/top-2 readout (static-plan specialization)
# ---------------------------------------------------------------------------

def _fused_kernel(q_ref, im_ref, acc_ref, best_ref, top2_ref,
                  *, d_eff: int, nw: int, tm: int):
    """Grid (query-tiles, class-tiles, word-tiles), word dim fastest.

    The hamming count accumulates in the ``acc_ref`` VMEM block across word
    tiles and is finalized to ``d_eff - 2*ham`` at the last tile; the
    argmax/top-2 state lives in the ``best``/``top2`` output blocks, whose
    index_map ignores (m, w) — for a fixed query tile they stay VMEM-resident
    across the whole class/word walk, giving a running readout for free.
    Tie-breaking matches ``jnp.argmax``/``lax.top_k``: strictly-greater to
    update plus lowest-index-first within a tile keeps the earliest class.
    """
    m, w = pl.program_id(1), pl.program_id(2)

    @pl.when(w == 0)
    def _init_ham():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(jnp.logical_and(m == 0, w == 0))
    def _init_readout():
        best_ref[...] = jnp.zeros_like(best_ref)
        top2_ref[...] = jnp.full_like(top2_ref, _I32_MIN)

    x = jnp.bitwise_xor(q_ref[...][:, None, :], im_ref[...][None, :, :])
    acc_ref[...] += jnp.sum(jax.lax.population_count(x).astype(jnp.int32), -1)

    @pl.when(w == nw - 1)
    def _finalize():
        blk = d_eff - 2 * acc_ref[...]                       # [TQ, TM] acc
        acc_ref[...] = blk
        bmax = jnp.max(blk, axis=1)
        iota = jax.lax.broadcasted_iota(jnp.int32, blk.shape, 1) + m * tm
        barg = jnp.min(jnp.where(blk == bmax[:, None], iota, _I32_MAX), axis=1)
        b2 = jnp.max(jnp.where(iota == barg[:, None], _I32_MIN, blk), axis=1)
        v1, v2 = top2_ref[:, 0], top2_ref[:, 1]
        upd = bmax > v1
        best_ref[:, 0] = jnp.where(upd, barg, best_ref[:, 0])
        top2_ref[:, 0] = jnp.where(upd, bmax, v1)
        top2_ref[:, 1] = jnp.maximum(jnp.minimum(bmax, v1),
                                     jnp.maximum(b2, v2))


@functools.partial(jax.jit,
                   static_argnames=("d_eff", "tq", "tm", "tw", "interpret"))
def fused_scores(
    q_packed: jax.Array,    # uint32 [N, W_eff] (pre-sliced enabled words)
    im_packed: jax.Array,   # uint32 [M, W_eff] (same column order as q)
    *,
    d_eff: int,
    tq: int | None = None,
    tm: int | None = None,
    tw: int = TW,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """(acc int32 [N, M], best int32 [N], top2 int32 [N, 2]) in one grid.

    ``best``/``top2`` are the argmax index and the two highest accumulator
    values (``top2[:, 0] - top2[:, 1]`` is the integer margin), bit-identical
    to ``jnp.argmax(acc)`` / ``lax.top_k(acc, 2)[0]``. ``tq``/``tm`` default
    to the ``TORR_TQ``/``TORR_TM`` overrides (see the knob table in
    ``kernels.xnor_popcount_sim``), clipped to divisors.
    """
    N, W = q_packed.shape
    M, W2 = im_packed.shape
    assert W == W2, (W, W2)
    tq = fit_tile(N, TQ_DEFAULT if tq is None else tq)
    tm = fit_tile(M, TM_DEFAULT if tm is None else tm)
    tw = fit_tile(W, tw)
    nw = W // tw
    kern = functools.partial(_fused_kernel, d_eff=d_eff, nw=nw, tm=tm)
    acc, best, top2 = pl.pallas_call(
        kern,
        grid=(N // tq, M // tm, nw),
        in_specs=[
            pl.BlockSpec((tq, tw), lambda n, m, w: (n, w)),
            pl.BlockSpec((tm, tw), lambda n, m, w: (m, w)),
        ],
        out_specs=[
            pl.BlockSpec((tq, tm), lambda n, m, w: (n, m)),
            pl.BlockSpec((tq, 1), lambda n, m, w: (n, 0)),
            pl.BlockSpec((tq, 2), lambda n, m, w: (n, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N, M), jnp.int32),
            jax.ShapeDtypeStruct((N, 1), jnp.int32),
            jax.ShapeDtypeStruct((N, 2), jnp.int32),
        ],
        interpret=resolve_interpret(interpret),
    )(q_packed, im_packed)
    return acc, best[:, 0], top2


def _blocked_scores(
    q_packed: jax.Array, im_packed: jax.Array, *, d_eff: int,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Blocked-jnp lowering of :func:`fused_scores`: the same query-block
    tiling as the kernel grid, expressed as a lax.scan so XLA vectorizes
    each tile — the xor intermediate is [TQ, M, W]-tile-sized, never
    [N, M, W]. Bit-identical (integer sums; argmax/top-2 on the acc)."""
    N, W = q_packed.shape
    M = im_packed.shape[0]
    tq = fit_tile(N, TQ_BLOCKED)
    qt = q_packed.reshape(N // tq, tq, W)

    def body(carry, qb):
        x = jnp.bitwise_xor(qb[:, None, :], im_packed[None, :, :])
        ham = jnp.sum(jax.lax.population_count(x).astype(jnp.int32), -1)
        return carry, d_eff - 2 * ham

    _, acc = jax.lax.scan(body, jnp.int32(0), qt)
    acc = acc.reshape(N, M)
    best = jnp.argmax(acc, axis=-1).astype(jnp.int32)
    if M < 2:
        top2 = jnp.concatenate([acc, jnp.full_like(acc, _I32_MIN)], axis=-1)
    else:
        top2 = jax.lax.top_k(acc, 2)[0]
    return acc, best, top2


def fused_scores_any(
    q_packed: jax.Array, im_packed: jax.Array, *, d_eff: int,
    interpret: bool | None = None, use_kernel: bool = True,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """:func:`fused_scores` under the lowering-selection contract (module
    docstring), with the transparent oracle fallback for ragged M."""
    if not (use_kernel and im_packed.shape[0] % 8 == 0):
        return ref.fused_scores_ref(q_packed, im_packed, d_eff=d_eff)
    lowering = _pallas_lowering(interpret)
    if lowering is None:
        return _blocked_scores(q_packed, im_packed, d_eff=d_eff)
    return fused_scores(q_packed, im_packed, d_eff=d_eff, interpret=lowering)


# ---------------------------------------------------------------------------
# bank-prefix hamming (traced-banks family member)
# ---------------------------------------------------------------------------

_PREFIX_VMEM_BUDGET = 4 * 1024 * 1024   # xor-tile bytes cap (VMEM is ~16 MB)


def _prefix_kernel(q_ref, im_ref, out_ref, *, cap: int, epw: int):
    """One (query-tile, class-tile) block per program: the xor against the
    whole plan-capped word prefix stays in VMEM/registers, the per-bank
    popcount reduce + running prefix sum happen in-register, and only the
    tiny ``[TQ, TM, cap]`` prefix counts are written out. Bank boundaries
    never constrain the tiling because banks are reduced *inside* the
    block, not across grid steps."""
    x = jnp.bitwise_xor(q_ref[...][:, None, :], im_ref[...][None, :, :])
    pc = jax.lax.population_count(x).astype(jnp.int32)      # [TQ, TM, W]
    tq, tm, _ = pc.shape
    per_bank = jnp.sum(pc.reshape(tq, tm, cap, epw), axis=-1)
    out_ref[...] = jnp.cumsum(per_bank, axis=-1)


@functools.partial(jax.jit,
                   static_argnames=("cap", "tq", "tm", "interpret"))
def bank_prefix_hamming(
    q_packed: jax.Array,    # uint32 [N, cap * epw] (plan-capped enabled words)
    im_packed: jax.Array,   # uint32 [M, cap * epw] (same column order)
    *,
    cap: int,
    tq: int | None = None,
    tm: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Hamming over the first 1..cap banks' enabled words: int32 [N, M, cap].

    One pass over the plan-capped prefix (bytes read scale with the *static*
    cap x planes, never the full width); a traced per-window bank choice
    selects its slice afterwards with one last-axis gather, which is what
    keeps the jitted multi-stream path exact without executing a
    ``lax.switch`` branch per bank per batch. ``N`` is typically the
    *flattened* proposal batch of a whole multi-stream step (S x N_max
    rows) — the batched engines hoist this single call out of their vmap,
    so each item-memory tile is read once per query block instead of once
    per stream.

    The class tile clips so the in-VMEM xor block (tq x tm x W x 4B) stays
    under a conservative budget; Pallas double-buffers the item-memory
    tiles across grid steps as usual.
    """
    N, W = q_packed.shape
    M, W2 = im_packed.shape
    assert W == W2 and W % cap == 0, (W, W2, cap)
    epw = W // cap                      # enabled words per bank
    tq = fit_tile(N, TQ_DEFAULT if tq is None else tq)
    tm_cap = TM_DEFAULT if tm is None else tm
    while tm_cap > 8 and tq * tm_cap * W * 4 > _PREFIX_VMEM_BUDGET:
        tm_cap //= 2
    tm = fit_tile(M, tm_cap)
    kern = functools.partial(_prefix_kernel, cap=cap, epw=epw)
    return pl.pallas_call(
        kern,
        grid=(N // tq, M // tm),
        in_specs=[
            pl.BlockSpec((tq, W), lambda n, m: (n, 0)),
            pl.BlockSpec((tm, W), lambda n, m: (m, 0)),
        ],
        out_specs=pl.BlockSpec((tq, tm, cap), lambda n, m: (n, m, 0)),
        out_shape=jax.ShapeDtypeStruct((N, M, cap), jnp.int32),
        interpret=resolve_interpret(interpret),
    )(q_packed, im_packed)


def _blocked_prefix(
    q_packed: jax.Array, im_packed: jax.Array, *, cap: int,
) -> jax.Array:
    """Blocked-jnp lowering of :func:`bank_prefix_hamming` (same tiling
    story as :func:`_blocked_scores`)."""
    N, W = q_packed.shape
    M = im_packed.shape[0]
    epw = W // cap
    tq = fit_tile(N, TQ_BLOCKED)
    qt = q_packed.reshape(N // tq, tq, W)

    def body(carry, qb):
        x = jnp.bitwise_xor(qb[:, None, :], im_packed[None, :, :])
        pc = jax.lax.population_count(x).astype(jnp.int32)
        per_bank = jnp.sum(pc.reshape(tq, M, cap, epw), -1)
        return carry, jnp.cumsum(per_bank, -1)       # [tq, M, cap]

    _, hp = jax.lax.scan(body, jnp.int32(0), qt)
    return hp.reshape(N, M, cap)


def bank_prefix_hamming_any(
    q_packed: jax.Array, im_packed: jax.Array, *, cap: int,
    interpret: bool | None = None, use_kernel: bool = True,
) -> jax.Array:
    """:func:`bank_prefix_hamming` under the lowering-selection contract,
    with the oracle fallback for ragged M."""
    if not (use_kernel and im_packed.shape[0] % 8 == 0):
        return ref.bank_prefix_hamming_ref(q_packed, im_packed, cap=cap)
    lowering = _pallas_lowering(interpret)
    if lowering is None:
        return _blocked_prefix(q_packed, im_packed, cap=cap)
    return bank_prefix_hamming(q_packed, im_packed, cap=cap,
                               interpret=lowering)


# ---------------------------------------------------------------------------
# delta path (Eq. 6) — same module so bypass/delta/full all avoid the oracle
# ---------------------------------------------------------------------------

def delta_apply(
    acc: jax.Array,       # int32 [M]
    dmajor: jax.Array,    # int8 [D, M]
    idx: jax.Array,       # int32 [budget] flipped dims (0-padded)
    weight: jax.Array,    # int32 [budget] in {-2, 0, +2}
    *,
    interpret: bool | None = None,
    use_kernel: bool = True,
) -> jax.Array:
    """Sparse scatter-accumulate ``acc += sum_k w[k] * dmajor[idx[k], :]``.

    Dispatches to the scalar-prefetch ``delta_update`` kernel (the
    Delta-FIFO's TPU analogue: only O(|Delta| * M) bytes move) under the
    Pallas lowering; elsewhere the vectorized gather-einsum *is* already
    the right O(|Delta| * M) form, so it is used directly. Safe under
    scan/switch/vmap — the jitted pipeline's delta branch calls this.
    """
    M = acc.shape[0]
    lowering = _pallas_lowering(interpret)
    if use_kernel and M % 8 == 0 and lowering is not None:
        tm = fit_tile(M, 128)
        return _delta_kernel(acc, dmajor, idx, weight, tm=tm,
                             interpret=lowering)
    return ref.delta_update_ref(acc, dmajor, idx, weight)


# ---------------------------------------------------------------------------
# encode front-end: sign-projection fused with bit-packing
# ---------------------------------------------------------------------------

def _pack_kernel(z_ref, r_ref, out_ref):
    y = jnp.dot(z_ref[...], r_ref[...].T,
                preferred_element_type=jnp.float32)          # [TN, TD]
    bits = (y >= 0.0).astype(jnp.uint32)
    tn, td = bits.shape
    bits = bits.reshape(tn, td // 32, 32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    out_ref[...] = jnp.sum(bits << shifts, axis=-1, dtype=jnp.uint32)


@functools.partial(jax.jit, static_argnames=("tn", "td", "interpret"))
def sign_project_pack(
    z: jax.Array,    # f32 [N, d]
    R: jax.Array,    # f32 [D, d]
    *,
    tn: int = 8,
    td: int = 256,
    interpret: bool | None = None,
) -> jax.Array:
    """Packed query words uint32 [N, D//32] = pack(sign(z @ R.T)).

    Extends the ``sign_project`` kernel one stage further: the f32
    projection *and* the int8 bipolar code both stay in VMEM; only the
    1-bit/dim packed words are written back (a 32x cut on the
    encoder->aligner hand-off, previously left to XLA as a separate pass).
    """
    N, d = z.shape
    D, d2 = R.shape
    assert d == d2 and D % 32 == 0
    tn = min(tn, N)
    td = min(td, D)
    assert N % tn == 0 and D % td == 0 and td % 32 == 0
    return pl.pallas_call(
        _pack_kernel,
        grid=(N // tn, D // td),
        in_specs=[
            pl.BlockSpec((tn, d), lambda n, dd: (n, 0)),
            pl.BlockSpec((td, d), lambda n, dd: (dd, 0)),
        ],
        out_specs=pl.BlockSpec((tn, td // 32), lambda n, dd: (n, dd)),
        out_shape=jax.ShapeDtypeStruct((N, D // 32), jnp.uint32),
        interpret=resolve_interpret(interpret),
    )(z, R)
