"""Fused sign-projection kernel: q = sign(R z) (paper Sec. 3.2).

Fuses the [D, d] x [d] projection with the sign quantization so the f32
intermediate y = R z never round-trips to HBM — only the int8 bipolar code
is written back (a 4x traffic cut on the encoder->aligner hand-off; the
subsequent 32x cut comes from bit-packing, left to XLA as a cheap reshape).

Grid: (batch-tiles, D-tiles); each step computes a (TN, TD) tile of the
matmul on the MXU, applies sign, and writes int8. d (feature dim) is kept
un-tiled: encoder features are small (d <= 1024), so one (TD, d) weight
slab fits VMEM comfortably (TD=256, d=512 f32 -> 512 KiB).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(z_ref, r_ref, out_ref):
    y = jnp.dot(
        z_ref[...], r_ref[...].T, preferred_element_type=jnp.float32
    )                                                   # [TN, TD]
    out_ref[...] = jnp.where(y >= 0.0, 1, -1).astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("tn", "td", "interpret"))
def sign_project(
    z: jax.Array,    # f32 [N, d]
    R: jax.Array,    # f32 [D, d]
    *,
    tn: int = 8,
    td: int = 256,
    interpret: bool = True,
) -> jax.Array:
    """Bipolar int8 [N, D] = sign(z @ R.T)."""
    N, d = z.shape
    D, d2 = R.shape
    assert d == d2
    tn = min(tn, N)
    td = min(td, D)
    assert N % tn == 0 and D % td == 0

    return pl.pallas_call(
        _kernel,
        grid=(N // tn, D // td),
        in_specs=[
            pl.BlockSpec((tn, d), lambda n, dd: (n, 0)),
            pl.BlockSpec((td, d), lambda n, dd: (dd, 0)),
        ],
        out_specs=pl.BlockSpec((tn, td), lambda n, dd: (n, dd)),
        out_shape=jax.ShapeDtypeStruct((N, D), jnp.int8),
        interpret=interpret,
    )(z, R)
