"""Async, device-sharded multi-stream serving runtime (dispatch/collect split).

Scheduling + sharding contract
==============================

``AsyncStreamEngine`` serves the same fixed-slot scheduling contract as the
synchronous :class:`repro.serving.stream_engine.StreamEngine` — admit binds a
stream to a slot and resets its cache row, submit enqueues one window per
call, retire drops the remaining backlog with the slot — but splits the
serving loop across two daemon threads so host work overlaps device compute:

  * the **dispatcher** pops the head window of every busy slot (the exact
    assembly the sync engine performs, so batch composition and per-stream
    queue depths are identical for the same submission order), applies the
    RT-deadline admission decision per popped window, launches the jitted
    ``torr_multi_stream_step`` — JAX dispatch is asynchronous, so the call
    returns while the device still computes — and hands the in-flight step
    to the collector through a bounded queue. A bounded depth of ``pipeline_depth``
    steps gives double buffering: the dispatcher assembles window t+1 on the
    host while step t executes, and blocks (backpressure) rather than
    running ahead of the device.
  * the **collector** blocks until the step's results are ready
    (``jax.block_until_ready`` lives here, *not* on the caller's or
    dispatcher's thread), moves them to host memory once per step, slices
    per-slot rows, and resolves each window's
    :class:`concurrent.futures.Future` with host-resident
    ``(WindowOutput, WindowTelemetry)`` numpy trees.

Determinism: with admission control disabled (``tracker=None``) and the
same submission order, every batch the dispatcher assembles is exactly the
batch the sync engine would build, so results are bit-identical to
``StreamEngine`` (tests/test_async_engine.py). Construct with
``paused=True`` and call :meth:`start` after submitting to reproduce the
sync engine's drain schedule exactly.

Sharding: pass ``mesh`` (a 1-D ``jax.sharding.Mesh`` from
``runtime.sharding.stream_mesh``) to shard the stacked ``TorrState`` and
every ``StreamBatch`` along the leading stream-slot axis, with the shared
item memory replicated. The slot count is padded up to a multiple of the
device count (``runtime.sharding.pad_stream_slots``); pad slots ride the
pipeline's pad branch. Streams are independent vmap lanes, so partitioning
the slot axis is communication-free and numerically exact; on a 1-device
mesh (or ``mesh=None``) placement is untouched — the bit-identical
fallback. The ``serial`` (lax.map) lowering is host-sequential and cannot
shard; it is rejected with a multi-device mesh.

Deadline control: pass a ``DeadlineTracker`` (``serving.deadline``) to
enforce RT-30/RT-60 per-window deadlines. The dispatcher consults the pure
decision table per popped window — ADMIT serves as-is, ESCALATE forces the
window's queue-depth input to Alg. 1's load gate ``H(N, q)`` to at least
``cfg.q_hi`` (bypass escalation drains the queue faster), SHED fails the
window's future with ``WindowShed`` without spending a slot-step on it.
The collector feeds measured step latencies back into the tracker's
projection EMA and records per-window latency for jitter/miss telemetry.

QoS governor: pass a ``Governor`` (``repro.control``) alongside the tracker
to close the loop between slack and the compute path. Per dispatched step
the dispatcher feeds the governor the head windows' projected slack (from
the tracker's arrival stamps and step EMA) plus the deepest per-slot
backlog; the governor returns a knob plan (D' cap, bit-slice precision,
tau offsets) that is latched for the step — a static jit argument, so each
plan runs its own specialized executable, and the governor's hysteresis
keeps that latch from thrashing. ``fused="auto"`` arms the load-aware
kernel dispatch the same way: the collector folds each step's full-path
fraction into a host-side EWMA, and the dispatcher picks the compact
bucket tier (or the hoisted default) per step — see
``StreamEngine._resolve_fused``. The collector closes the energy loop:
every served window's telemetry (which records the plan it actually ran
with) is priced by ``perf.cycle_model.telemetry_cost`` and folded into the
governor's EWMA energy estimate. With the governor pinned to the full plan
(or absent) results are bit-identical to the ungoverned engine.
"""
from __future__ import annotations

import queue
import threading
import time
from concurrent.futures import Future
from typing import Dict

import jax
import numpy as np

from ..core.item_memory import ItemMemory
from ..core.types import TorrConfig
from ..obs.bridge import telemetry_digest
from ..obs.spans import NULL_SPAN, span
from ..obs.trace import now_us, trace_scope
from ..perf.cycle_model import telemetry_cost
from ..runtime import sharding as shd
from ..runtime.fault import EngineDead
from .deadline import Decision, DeadlineTracker, WindowShed
from .stream_engine import (GATE_ADMIT, GATE_ESCALATE, GATE_SHED,
                            StreamEngine)

# the deadline tracker's Decision values are fed straight into
# StreamEngine._assemble's gate protocol — pin the alignment here, the one
# module that imports both layers
assert (GATE_ADMIT, GATE_ESCALATE, GATE_SHED) == (
    Decision.ADMIT, Decision.ESCALATE, Decision.SHED)


class AsyncStreamEngine(StreamEngine):
    """Dispatch/collect split over the slot scheduler; futures per window."""

    _ENGINE = "async"

    def __init__(
        self,
        cfg: TorrConfig,
        im: ItemMemory,
        n_slots: int = 16,
        jit: bool = True,
        serial: bool = False,
        fused: str | None = None,
        bucket_cap: int | None = None,
        decide: str | None = None,
        mesh=None,
        pipeline_depth: int = 2,
        tracker: DeadlineTracker | None = None,
        governor=None,
        paused: bool = False,
        metrics=None,
        flight=None,
        tracer=None,
        store=None,
        snapshot_every: int = 1,
        fault_plan=None,
    ):
        if governor is not None and tracker is None:
            raise ValueError(
                "the QoS governor is slack-driven: pass a DeadlineTracker "
                "alongside governor=")
        if mesh is not None and mesh.devices.size > 1 and serial:
            raise ValueError(
                "serial (lax.map) lowering is host-sequential and cannot "
                "shard the stream axis; use serial=False with a mesh")
        self._mesh = mesh if mesh is not None and mesh.devices.size > 1 else None
        super().__init__(cfg, im,
                         n_slots=shd.pad_stream_slots(n_slots, self._mesh),
                         jit=jit, serial=serial, fused=fused,
                         bucket_cap=bucket_cap, decide=decide,
                         metrics=metrics, flight=flight, tracer=tracer,
                         store=store, snapshot_every=snapshot_every,
                         fault_plan=fault_plan)
        # async-specific phase spans (the sync step() spans are unused
        # here); each runs on exactly one daemon thread
        sp = (lambda name: span(name, metrics)) \
            if metrics is not None or tracer is not None \
            else (lambda name: NULL_SPAN)
        self._sp_decide = sp("host_decide")
        self._sp_device = sp("device_step")
        self._sp_drain = sp("collector_drain")
        self._last_slack = None
        if self._mesh is not None:
            # stacked per-stream state sharded on the slot axis; item memory
            # (shared task knowledge) replicated on every device
            self._state = jax.device_put(
                self._state, shd.stream_sharding(self._state, self._mesh))
            self.im = jax.device_put(
                im, shd.replicated_sharding(im, self._mesh))
            # one sharding covers every batch leaf: leading slot axis
            # sharded, trailing dims (absent from the spec) replicated
            from jax.sharding import NamedSharding, PartitionSpec
            self._batch_sharding = NamedSharding(
                self._mesh, PartitionSpec(shd.STREAM_AXIS))
        self._tracker = tracker
        self._governor = governor

        self._lock = threading.Lock()
        self._work = threading.Condition(self._lock)     # backlog arrived
        self._settled = threading.Condition(self._lock)  # a window resolved
        self._inflight = 0      # submitted windows not yet resolved
        self._stop = False
        self._error: BaseException | None = None
        self._collect_q: queue.Queue = queue.Queue(maxsize=max(1, pipeline_depth))
        self._dispatcher = threading.Thread(
            target=self._dispatch_loop, name="torr-dispatch", daemon=True)
        self._collector = threading.Thread(
            target=self._collect_loop, name="torr-collect", daemon=True)
        self._started = False
        if not paused:
            self.start()

    # -- lifecycle ----------------------------------------------------------

    def start(self) -> None:
        """Start the dispatch/collect threads (no-op if already running)."""
        if not self._started:
            self._started = True
            self._dispatcher.start()
            self._collector.start()

    def close(self, drain: bool = True) -> None:
        """Stop the runtime; drain (default) or cancel the backlog first.

        Threads are always joined; a drain failure (worker death) is
        re-raised after shutdown completes."""
        if not self._started:
            return
        drain_err: BaseException | None = None
        if drain:
            try:
                self.flush()
            except BaseException as e:  # noqa: BLE001 — re-raised below
                drain_err = e
        cancelled = []
        with self._work:
            if not drain:
                for dq in self._pending:
                    while dq:
                        cancelled.append(dq.popleft()[3])
                        self._inflight -= 1
                self._settled.notify_all()
            self._stop = True
            self._work.notify_all()
        for fut in cancelled:   # done-callbacks must not run under the lock
            fut.cancel()
        self._dispatcher.join()
        self._collect_q.put(None)
        self._collector.join()
        self._started = False
        if drain_err is not None:
            raise drain_err

    def abandon(self) -> None:
        """Stop signal without joining the worker threads.

        The supervisor's recovery path runs under its own lock, which a
        mid-delivery collector may be waiting on inside a done-callback —
        ``close()``'s joins would deadlock there. Workers observe the stop
        flag and exit on their own; queued-but-undelivered windows stay
        pending on the supervisor's journal and are replayed by the
        replacement engine (at-least-once), and any late delivery from
        this engine is either bit-identical (deterministic replay of the
        same snapshot lineage) or ignored by the supervisor's epoch guard.
        """
        if not self._started:
            return
        with self._work:
            self._stop = True
            self._work.notify_all()
        # unblock a dispatcher parked on a full collect queue (collector
        # death) and wake a collector parked on an empty one
        try:
            while True:
                self._collect_q.get_nowait()
        except queue.Empty:
            pass
        self._collect_q.put(None)
        self._started = False

    def __enter__(self) -> "AsyncStreamEngine":
        return self

    def __exit__(self, *exc) -> None:
        self.close(drain=exc == (None, None, None))

    def _check_error(self) -> None:
        if self._error is not None:
            # _fail stored a typed EngineDead; raise a fresh instance per
            # caller (shared tracebacks across threads mutate) with the
            # same cause/inflight/thread payload
            dead = self._error
            raise EngineDead(cause=dead.cause, inflight=dead.inflight,
                             thread=dead.thread) from dead.cause

    # -- admission / submission (caller threads) ----------------------------

    def admit(self, stream_id, task_w, snapshot=None) -> int:
        with self._lock:
            slot = super().admit(stream_id, task_w, snapshot=snapshot)
            if self._mesh is not None:
                # super() rebuilt the state tree functionally; re-pin it so
                # the dispatcher's next step keeps the stream-axis layout
                self._state = jax.device_put(
                    self._state, shd.stream_sharding(self._state, self._mesh))
            return slot

    def retire(self, stream_id) -> None:
        """Drop the stream's backlog (cancelling its futures) and free its
        slot. Windows already dispatched to the device still resolve.

        Futures are cancelled *after* the lock is released: Future.cancel
        runs done-callbacks synchronously, and a callback that re-enters
        the engine (submit/flush) must not find the lock held."""
        with self._work:
            slot = self._slot_of[stream_id]
            cancelled = [w[3] for w in self._pending[slot]]
            self._inflight -= len(cancelled)
            super().retire(stream_id)
            self._settled.notify_all()
        for fut in cancelled:
            fut.cancel()

    def submit(self, stream_id, q_packed, valid, boxes) -> Future:
        """Enqueue one window; the future resolves to host-resident
        ``(WindowOutput, WindowTelemetry)`` numpy trees, or raises
        :class:`WindowShed` if admission control drops the window."""
        self._check_error()
        fut: Future = Future()
        arrival = self._tracker.now() if self._tracker else time.monotonic()
        ctx = (self._tracer.mint(stream_id, self._ENGINE)
               if self._tracer is not None else None)
        window = (np.asarray(q_packed, np.uint32), np.asarray(valid, bool),
                  np.asarray(boxes, np.float32), fut, arrival, ctx)
        with self._work:
            self._pending[self._slot_of[stream_id]].append(window)
            self._inflight += 1
            self._work.notify()
        return fut

    def backlog(self, stream_id) -> int:
        with self._lock:
            return super().backlog(stream_id)

    def flush(self, timeout: float | None = None) -> None:
        """Block until every submitted window has resolved (result, shed or
        cancel). Raises on worker death; TimeoutError on timeout."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._settled:
            while self._inflight > 0:
                self._check_error()
                left = None if deadline is None else deadline - time.monotonic()
                if left is not None and left <= 0:
                    raise TimeoutError(
                        f"flush timed out with {self._inflight} windows in flight")
                self._settled.wait(timeout=left)
            self._check_error()

    # the synchronous one-step-at-a-time API is owned by the dispatcher here
    def step(self):
        raise NotImplementedError(
            "AsyncStreamEngine dispatches internally; use submit() futures")

    def drain(self):
        raise NotImplementedError(
            "AsyncStreamEngine dispatches internally; use flush()")

    # -- dispatcher ---------------------------------------------------------

    @staticmethod
    def _ctx_of(extra):
        # submit's trailing payload here is (future, arrival, ctx)
        return extra[2]

    def _has_backlog(self) -> bool:
        return any(self._pending[s] for s in self._slot_of.values())

    def _assemble_admitted(self, deferred):
        """`StreamEngine._assemble` under the RT admission gate.

        Must run under the lock. Shed windows are popped and replaced by the
        next queued window of the same slot (re-decided in turn); escalated
        windows get their queue-depth lane forced to >= cfg.q_hi so H(N, q)
        escalates cheap paths — both mechanics live in
        ``StreamEngine._assemble``; this only supplies the decision + shed
        bookkeeping. Shed futures are appended to ``deferred`` as
        ``(future, exception)`` and resolved by the caller *outside* the
        lock (set_exception runs done-callbacks synchronously, and a
        callback may re-enter the engine)."""
        if self._tracker is None:
            return self._assemble()
        now = self._tracker.now()

        def gate(stream_id, backlog, extra):
            fut, arrival, ctx = extra
            decision = self._tracker.decide_head(arrival, backlog, now)
            if decision == Decision.SHED:
                self.stats.shed += 1
                if self._obs is not None:
                    self._obs.on_shed()
                self._inflight -= 1
                deferred.append((fut, WindowShed(
                    stream_id, self._tracker.lateness(arrival, now),
                    retry_after_s=self._tracker.retry_after_hint(backlog))))
                self._settled.notify_all()
                if ctx is not None:
                    # shed windows never reach a step: retire the context
                    # here so the tracer ring still accounts for them
                    ctx.decision = "shed"
                    self._tracer.complete(ctx)
            return decision

        return self._assemble(gate)

    def set_plan(self, plan) -> None:
        if self._governor is not None:
            raise RuntimeError(
                "the plan latch is owned by the armed QoS governor (it is "
                "re-latched every dispatched step); construct the engine "
                "without governor= to pin plans manually")
        super().set_plan(plan)

    def _govern(self, served) -> None:
        """Latch the governor's plan for the step about to dispatch.

        Must run under the lock (the latch feeds ``_dispatch``). Slack is
        the *tightest* head window's remaining time to deadline; backlog is
        the deepest per-slot queue (each batched step drains one window per
        slot, so that is the number of steps still owed) — read from the
        pending queues, NOT the batch's qd lanes, which the admission gate
        floors to cfg.q_hi for escalated windows."""
        if self._governor is None or not served:
            return
        now = self._tracker.now()
        wait = max(now - arrival for _sid, _slot, (_f, arrival, _c) in served)
        slack = self._tracker.policy.budget_s - wait
        backlog = max(len(self._pending[slot]) for _sid, slot, _x in served)
        self._plan = self._governor.update(
            slack, self._tracker.step_ema_s, backlog=backlog,
            n_windows=len(served))
        self._last_slack = slack

    def _fold_telemetry(self) -> None:
        # the dispatcher must never block on device telemetry; the
        # collector already holds host-resident traces and feeds
        # _observe_path_mix (and the observer) from there
        pass

    def _dispatch(self, q, v, b, qd):
        if self._mesh is None:
            return super()._dispatch(q, v, b, qd)
        from ..core.types import StreamBatch
        s = self._batch_sharding
        batch = StreamBatch(
            q_packed=jax.device_put(q, s), valid=jax.device_put(v, s),
            boxes=jax.device_put(b, s),
            queue_depth=jax.device_put(qd.astype(np.int32), s),
        )
        fused, bucket_cap, decide = self._resolve_fused()
        self._last_resolved = (fused, bucket_cap, decide)
        self._state, out, tel = self._step(
            self._state, self.im, batch, self.cfg, serial=self._serial,
            plan=self._plan, fused=fused, bucket_cap=bucket_cap,
            decide=decide)
        return out, tel

    def warmup(self) -> None:
        """Compile the batched step (with its sharded layout when meshed)
        outside any timed region: one all-pad step, a state no-op."""
        with self._lock:
            S = self.n_slots
            out, _tel = self._dispatch(
                np.broadcast_to(self._q0, (S,) + self._q0.shape),
                np.broadcast_to(self._v0, (S,) + self._v0.shape),
                np.broadcast_to(self._b0, (S,) + self._b0.shape),
                np.zeros((S,), np.int32))
            jax.block_until_ready(out.scores)

    def _dispatch_loop(self) -> None:
        deferred = []   # (future, exception) of windows shed under the lock
        try:
            while True:
                deferred = []
                step_ctxs = None
                with self._work:
                    while not self._stop and not self._has_backlog():
                        self._work.wait()
                    if self._stop:
                        break
                    if self._fault is not None:
                        # chaos injection: die at the planned step boundary
                        # with real backlog in flight — the outer handler's
                        # _fail path is exercised, not simulated
                        self._fault.maybe_fire("dispatcher", self.stats.steps)
                    # traced steps open a trace_scope over the decide +
                    # dispatch spans: _assemble populates step_ctxs with
                    # the admitted windows' contexts, and each span stamps
                    # its interval onto them at exit (dispatcher thread)
                    scope = NULL_SPAN
                    if self._tracer is not None:
                        step_ctxs = self._step_ctxs = []
                        scope = trace_scope(step_ctxs)
                    try:
                        with scope:
                            with self._sp_decide:
                                q, v, b, qd, served = \
                                    self._assemble_admitted(deferred)
                                if served:
                                    self._govern(served)
                            if served:
                                # dispatch under the lock: JAX async
                                # dispatch returns immediately, and
                                # admit/retire must not interleave a state
                                # rewrite between assemble and state advance
                                with self._sp_dispatch:
                                    t0 = time.monotonic()
                                    out, tel = self._dispatch(q, v, b, qd)
                    finally:
                        self._step_ctxs = None
                    if served:
                        self.stats.steps += 1
                        self.stats.windows += len(served)
                        self.stats.pad_slots += self.n_slots - len(served)
                        # lazy device slices of the post-step state; the
                        # collector materializes + writes them after the
                        # windows' results are delivered
                        snaps = self._collect_snaps(served) \
                            if self._store is not None else None
                        rec = None
                        if self._obs is not None:
                            gov = None
                            if self._governor is not None:
                                gov = {
                                    "level": self._governor.level,
                                    "slack": self._last_slack,
                                    "energy_ewma_mj":
                                        self._governor.energy_ewma_mj,
                                }
                            rec = self._obs.on_dispatch(
                                len(served), self.n_slots - len(served),
                                requested=self._last_resolved,
                                plan=self._plan, gov=gov,
                                full_ewma=(self._full_ewma if self._auto
                                           else None))
                            if rec is not None and self._tracer is not None:
                                rec["ts_us"] = now_us()
                                rec["queue_depth"] = int(qd.max())
                for fut, exc in deferred:   # callbacks run lock-free here
                    fut.set_exception(exc)
                if not served:      # whole backlog shed this pass
                    continue
                # bounded queue = pipeline depth: block here (not holding
                # the lock) instead of racing ahead of the device
                self._collect_q.put(
                    (served, out, tel, t0, rec, step_ctxs, snaps))
                if self._error is not None:
                    # the collector died while we were blocked in put():
                    # _fail's drain ran before our item landed, so nobody
                    # will ever resolve it — fail it ourselves
                    self._drain_collect_failing(self._error)
                    break
        except BaseException as e:  # noqa: BLE001 — surfaced via futures
            self._fail(e)
            # windows shed this pass were popped from _pending before the
            # crash, so _fail can't see them — resolve them here with their
            # intended shed exception
            for fut, exc in deferred:
                if not fut.done():
                    fut.set_exception(exc)

    # -- collector ----------------------------------------------------------

    def _collect_loop(self) -> None:
        n_collected = 0
        try:
            while True:
                item = self._collect_q.get()
                if item is None:
                    break
                if self._fault is not None:
                    # chaos injection: die with this step's windows still
                    # unresolved (their futures fail via _fail, and no
                    # snapshot covering them is ever written)
                    self._fault.maybe_fire("collector", n_collected)
                n_collected += 1
                served, out, tel, t0, rec, ctxs, snaps = item
                # traced steps re-open their context scope on the collector
                # thread: the device/drain spans stamp onto the same
                # windows the dispatcher's spans did — the cross-thread
                # half of the per-window timeline
                scope = trace_scope(ctxs) if ctxs else NULL_SPAN
                with scope:
                    with self._sp_device:
                        jax.block_until_ready(out.scores)
                    dur = time.monotonic() - t0
                    with self._sp_drain:
                        digest = self._drain_item(served, out, tel, rec,
                                                  dur, snaps)
                # finish *after* the drain span exits so collector_drain is
                # part of the serialized per-window event list
                if ctxs:
                    self._trace_finish(ctxs, rec, digest)
        except BaseException as e:  # noqa: BLE001
            self._fail(e)

    def _drain_item(self, served, out, tel, rec, dur, snaps=None):
        """Move one retired step to host and resolve its windows; returns
        the step's telemetry digest (for trace completion), or None when
        nothing downstream needs it."""
        # one device->host move per step, then cheap numpy slicing
        out_h = jax.tree_util.tree_map(np.asarray, out)
        tel_h = jax.tree_util.tree_map(np.asarray, tel)
        if self._auto:
            # feed the load-aware dispatcher's path-mix EWMA from
            # the host-resident trace (never blocks the dispatcher)
            self._observe_path_mix(tel_h.path, tel_h.n_valid)
        digest = None
        if self._obs is not None:
            digest = self._obs.observe_step(tel_h, rec, step_latency_s=dur)
        elif self._tracer is not None:
            digest = telemetry_digest(tel_h)
        if self._tracker is not None:
            self._tracker.observe_step(dur)
        now = (self._tracker.now() if self._tracker
               else time.monotonic())
        for stream_id, slot, (fut, arrival, _ctx) in served:
            tel_w = jax.tree_util.tree_map(lambda x: x[slot], tel_h)
            if self._governor is not None:
                # close the energy loop: price the plan the window
                # actually ran with (recorded in its telemetry);
                # window_scale follows the cycle model's convention
                # (1.0 @ RT-60, 2.0 @ RT-30) so the live EWMA and
                # table8's modeled operating points agree
                budget_s = self._tracker.policy.budget_s
                wc = telemetry_cost(
                    tel_w, self.cfg, budget_s,
                    window_scale=60.0 * budget_s)
                self._governor.observe_energy(wc.energy_j * 1e3)
            if fut.cancelled():
                # orphaned mid-flight (stream retired): nobody
                # consumes it — count the loss (the window was
                # served and observed, but its result is dropped)
                # and keep it out of the deadline envelope too
                self.stats.telemetry_dropped += 1
                if self._obs is not None:
                    self._obs.drop(1)
                continue
            result = (
                jax.tree_util.tree_map(lambda x: x[slot], out_h),
                tel_w,
            )
            if self._tracker is not None:
                self._tracker.complete(arrival, now)
            fut.set_result(result)
        with self._settled:
            self._inflight -= len(served)
            self._settled.notify_all()
        if snaps:
            # snapshot writes happen strictly AFTER the set_result loop
            # above: a snapshot's window_seq covering a window therefore
            # implies its result was delivered — the invariant that makes
            # cross-process resume (skip the first latest_seq windows)
            # gap-free. Duplicates on replay are fine (at-least-once).
            from .state_store import materialize_snapshot
            memo = {}  # one host transfer per stacked leaf per batch
            for pending in snaps:
                self._store.put(materialize_snapshot(pending, memo))
        return digest

    def _drain_collect(self) -> list:
        """Empty the collect queue; returns the drained windows' futures."""
        futs = []
        while True:
            try:
                item = self._collect_q.get_nowait()
            except queue.Empty:
                return futs
            if item is not None:
                # these steps were served and observed on-device, but
                # their telemetry never reached the fold — the silent
                # loss the telemetry_dropped counter exists for
                self.stats.telemetry_dropped += len(item[0])
                if self._obs is not None:
                    self._obs.drop(len(item[0]))
                futs.extend(f for _sid, _slot, (f, _arr, _c) in item[0])

    def _drain_collect_failing(self, exc: BaseException) -> None:
        for fut in self._drain_collect():
            if not fut.cancelled():
                fut.set_exception(exc)

    def _fail(self, exc: BaseException) -> None:
        """Worker died: fail every queued future and wake all waiters.

        The raw exception is wrapped into a typed :class:`EngineDead`
        carrying the cause, the in-flight window count at the moment of
        death, and which worker died — pending futures fail with it, so
        callers can tell a crash (replayable) from a ``WindowShed``
        (admission policy). Futures are resolved after the lock is
        released — set_exception runs done-callbacks synchronously, and
        one may re-enter the engine."""
        tname = threading.current_thread().name
        role = {"torr-dispatch": "dispatcher",
                "torr-collect": "collector"}.get(tname, tname)
        doomed = []
        with self._work:
            dead = exc if isinstance(exc, EngineDead) else EngineDead(
                cause=exc, inflight=self._inflight, thread=role)
            self._error = dead
            self._stop = True
            for dq in self._pending:
                while dq:
                    doomed.append(dq.popleft()[3])
            # if the collector died, drain its queue so a back-pressured
            # dispatcher blocked in put() unblocks; the dispatcher re-drains
            # after its put in case its in-flight item landed post-drain
            doomed.extend(self._drain_collect())
            self._inflight = 0
            self._settled.notify_all()
            self._work.notify_all()
        for fut in doomed:
            if not fut.cancelled():
                fut.set_exception(dead)

    # -- telemetry ----------------------------------------------------------

    @property
    def tracker(self) -> DeadlineTracker | None:
        return self._tracker

    @property
    def governor(self):
        return self._governor

    def deadline_summary(self) -> Dict | None:
        """Jitter/miss-rate envelope (cycle-model-compatible keys)."""
        return self._tracker.summary() if self._tracker else None

    def governor_summary(self) -> Dict | None:
        """Plan level / switch / energy telemetry of the QoS governor."""
        return self._governor.summary() if self._governor else None
