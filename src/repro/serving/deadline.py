"""RT-deadline admission control for the async serving runtime (Sec. 4.6).

The paper's QoS controller guarantees RT-30/RT-60 per-window deadlines as
object counts vary. On the serving side that becomes *admission control*:
every submitted window carries an arrival time and inherits the operating
point's budget (``configs.torr_edge.rt_budget_s``); at dispatch time the
controller projects the window's completion from how long it has already
waited plus the engine's measured per-step latency (EMA), and picks one of

  * **ADMIT**     — projected completion makes the deadline; serve as-is.
  * **ESCALATE**  — at risk (projected lateness within the escalate margin,
    or the backlog behind it projects over budget): serve it, but force the
    queue-depth input of Alg. 1's load gate ``H(N, q)`` high so the policy
    escalates cheap bypass/delta paths and the queue drains faster.
  * **SHED**      — already unsalvageably late: drop the window and fail its
    future with :class:`WindowShed`, freeing the slot-step for fresher work.

:func:`decide` is a pure function of ``(wait, backlog, step_ema, policy)``
so the decision table is unit-testable without threads or clocks;
:class:`DeadlineTracker` owns the mutable bookkeeping (arrival stamps, the
step-latency EMA, miss/shed/escalate counters) and emits a latency summary
through ``perf.cycle_model.latency_summary`` — the same key vocabulary the
cycle-accurate model reports, so measured and simulated RT envelopes diff
directly.
"""
from __future__ import annotations

import dataclasses
import enum
import time

import numpy as np

from ..configs.torr_edge import rt_budget_s
from ..perf.cycle_model import latency_summary


class Decision(enum.IntEnum):
    ADMIT = 0
    ESCALATE = 1
    SHED = 2


class WindowShed(Exception):
    """Set on a window's future when admission control sheds it.

    ``retry_after_s`` (when the shedding engine has a tracker projection)
    is the earliest resubmission delay for which the pure :func:`decide`
    table would return ADMIT again, assuming the backlog drains at the
    projected step cadence — supervised clients back off by it instead of
    hammering a saturated engine. None when no projection is available.
    """

    def __init__(self, stream_id, lateness_s: float, reason: str = "deadline",
                 retry_after_s: float | None = None):
        self.stream_id = stream_id
        self.lateness_s = lateness_s
        self.reason = reason
        self.retry_after_s = retry_after_s
        hint = "" if retry_after_s is None else \
            f"; retry after {retry_after_s * 1e3:.2f} ms"
        super().__init__(
            f"window for stream {stream_id!r} shed ({reason}; "
            f"projected {lateness_s * 1e3:.2f} ms past deadline{hint})"
        )


@dataclasses.dataclass(frozen=True)
class DeadlinePolicy:
    """Static thresholds for the pure decision function."""

    budget_s: float              # per-window deadline: arrival + budget_s
    escalate_margin_s: float     # lateness <= margin -> still salvageable
    allow_shed: bool = True      # False -> never drop, only escalate
    step_ema_alpha: float = 0.25 # EMA weight of the newest step latency
    step_init_s: float = 0.0     # optimistic prior before any step completes


def policy_for(rt: str = "RT-60", **overrides) -> DeadlinePolicy:
    """Policy for one of the paper's RT operating points (RT-30 / RT-60)."""
    budget = rt_budget_s(rt)
    base = DeadlinePolicy(budget_s=budget, escalate_margin_s=0.5 * budget)
    return dataclasses.replace(base, **overrides) if overrides else base


def decide(
    wait_s: float,
    backlog: int,
    step_s: float,
    policy: DeadlinePolicy,
) -> Decision:
    """Pure admission decision for the head window of one stream's queue.

    ``wait_s`` is how long the window has already queued since arrival,
    ``backlog`` is how many windows remain behind it, and ``step_s`` is the
    engine's projected per-step latency. The window's projected completion
    is ``wait_s + step_s``; its successors' is ``wait_s + (i+1) * step_s``.
    """
    lateness = wait_s + step_s - policy.budget_s
    if lateness > policy.escalate_margin_s and policy.allow_shed:
        return Decision.SHED
    if lateness > 0.0:
        return Decision.ESCALATE
    # on time itself, but a deep backlog projects the successors over budget
    if backlog > 0 and wait_s + (backlog + 1) * step_s > policy.budget_s:
        return Decision.ESCALATE
    return Decision.ADMIT


def retry_after_s(backlog: int, step_s: float, policy: DeadlinePolicy) -> float:
    """Earliest resubmission delay after a shed for which :func:`decide`
    would ADMIT a fresh window, under the drain model the decision table
    itself projects with (one window per ``step_s`` per slot, no new
    arrivals). A fresh window behind ``backlog`` others completes at
    ``(backlog + 1) * step_s``; whatever exceeds the budget is the wait:

        ``max(0, (backlog + 1) * step_s - budget_s)``

    After backing off by this, the remaining backlog projects exactly to
    the budget boundary and the table returns ADMIT (the property
    tests/test_deadline.py pins against :func:`decide` directly)."""
    return max(0.0, (backlog + 1) * step_s - policy.budget_s)


class DeadlineTracker:
    """Mutable deadline bookkeeping around the pure :func:`decide` table.

    The async engine's dispatcher consults :meth:`decide_head` per popped
    window; its collector feeds :meth:`observe_step` (device step latency,
    EMA'd into the projection) and :meth:`complete` (per-window latency,
    miss accounting). ``clock`` is injectable for deterministic tests.

    ``slo`` optionally wires a :class:`repro.obs.slo.SLOMonitor`: every
    completion feeds it one hit/miss event, which is what turns the raw
    miss counter into multi-window burn rates against the RT miss budget.
    """

    def __init__(self, policy: DeadlinePolicy, clock=time.monotonic,
                 metrics=None, slo=None):
        self.policy = policy
        self._clock = clock
        self._slo = slo
        self._step_s = policy.step_init_s
        self._lat: list[float] = []
        self.completed = 0
        self.missed = 0
        self.shed = 0
        self.escalated = 0
        # optional repro.obs wiring: pre-created handles so the per-window
        # path is a dict hit + one unlocked increment
        self._m_dec = None
        if metrics is not None:
            from ..obs.metrics import LATENCY_BUCKETS_S
            dec = metrics.counter(
                "torr_deadline_decisions_total",
                "RT admission verdicts per popped head window.",
                ["decision"])
            self._m_dec = {d: dec.labels(decision=d.name.lower())
                           for d in Decision}
            self._m_miss = metrics.counter(
                "torr_deadline_miss_total",
                "Served windows that completed past their RT budget.")
            self._m_lat = metrics.histogram(
                "torr_window_latency_seconds",
                "Arrival to results-ready latency of served windows.",
                buckets=LATENCY_BUCKETS_S)

    def now(self) -> float:
        return self._clock()

    # -- projection inputs --------------------------------------------------

    @property
    def step_ema_s(self) -> float:
        return self._step_s

    def observe_step(self, dur_s: float) -> None:
        """Fold one measured dispatch->results-ready step latency into the EMA."""
        a = self.policy.step_ema_alpha
        self._step_s = dur_s if self._step_s <= 0.0 else \
            (1.0 - a) * self._step_s + a * dur_s

    # -- decisions / accounting ---------------------------------------------

    def decide_head(self, arrival_s: float, backlog: int,
                    now: float | None = None) -> Decision:
        now = self.now() if now is None else now
        d = decide(now - arrival_s, backlog, self._step_s, self.policy)
        if d == Decision.ESCALATE:
            self.escalated += 1
        elif d == Decision.SHED:
            self.shed += 1
        if self._m_dec is not None:
            self._m_dec[d].inc()
        return d

    def lateness(self, arrival_s: float, now: float | None = None) -> float:
        now = self.now() if now is None else now
        return (now - arrival_s) + self._step_s - self.policy.budget_s

    def retry_after_hint(self, backlog: int) -> float:
        """The :func:`retry_after_s` backoff for the current step EMA."""
        return retry_after_s(backlog, self._step_s, self.policy)

    def complete(self, arrival_s: float, now: float | None = None) -> float:
        """Record one served window's arrival->results latency."""
        now = self.now() if now is None else now
        lat = now - arrival_s
        self._lat.append(lat)
        self.completed += 1
        missed = lat > self.policy.budget_s
        if missed:
            self.missed += 1
            if self._m_dec is not None:
                self._m_miss.inc()
        if self._m_dec is not None:
            self._m_lat.observe(lat)
        if self._slo is not None:
            self._slo.observe(missed)
        return lat

    # -- telemetry ----------------------------------------------------------

    def summary(self) -> dict:
        """Latency/jitter/miss envelope, cycle-model-compatible keys."""
        s = latency_summary(np.asarray(self._lat), self.policy.budget_s)
        s.update({
            "completed": self.completed,
            "miss_count": self.missed,
            "shed": self.shed,
            "escalated": self.escalated,
            "step_ema_ms": self._step_s * 1e3,
        })
        return s
