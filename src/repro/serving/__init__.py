"""Serving layer: evaluation pipelines, token reranker, multi-stream engine.

Modules:

  * ``tood_pipelines`` — dense / naive-HDC / TorR evaluation pipelines over
    the synthetic TOOD world (single stream, one window per call).
  * ``stream_engine``  — the multi-stream batched window engine. API sketch::

        eng = StreamEngine(cfg, im, n_slots=16)
        eng.admit("cam0", task_w0)          # bind stream -> slot, reset cache
        eng.submit("cam0", q_packed, valid, boxes)   # enqueue one window
        results = eng.step()                # one vmapped torr_multi_stream_step
        out, telemetry = results["cam0"]    # per-stream WindowOutput/telemetry
        eng.retire("cam0")                  # free the slot

    ``step()`` batches one pending window per admitted stream into a padded
    :class:`repro.core.types.StreamBatch`; per-stream caches, task weights
    and queue depths live in a stacked ``TorrState``, so results are
    bit-identical to running each stream alone through
    ``repro.core.pipeline.torr_window_step``.
  * ``async_engine``  — the asynchronous, device-sharded serving runtime:
    the same slot contract behind a dispatch/collect thread split. API
    sketch::

        with AsyncStreamEngine(cfg, im, n_slots=16,
                               mesh=stream_mesh(),          # optional
                               tracker=DeadlineTracker(policy_for("RT-60")),
                               ) as eng:                    # optional
            eng.admit("cam0", task_w0)
            fut = eng.submit("cam0", q_packed, valid, boxes)
            out, telemetry = fut.result()   # host-resident numpy trees
            eng.flush(); eng.retire("cam0")

    Host window assembly overlaps device steps; futures resolve from a
    collector thread; with admission control armed, late windows raise
    ``WindowShed`` instead of resolving.
  * ``deadline``      — RT-30/RT-60 admission control: pure decision table
    (admit / bypass-escalate / shed) + the tracker that projects window
    completion and emits cycle-model-compatible jitter/miss telemetry;
    ``WindowShed`` carries a ``retry_after_s`` hint derived from the same
    drain model the decision table uses.
  * ``state_store``   — externalized per-stream session state: either
    engine snapshots a stream's cache rows + task weights into a pluggable
    :class:`~repro.serving.state_store.StateStore` (in-memory or JSONL)
    every ``snapshot_every`` served windows, off the hot path; ``admit``
    accepts a :class:`~repro.serving.state_store.StreamSnapshot` for a
    warm start that is bit-identical to never having lost the slot.
  * ``supervisor``    — fault-tolerant front-end over either engine::

        sup = ServeSupervisor(lambda: AsyncStreamEngine(..., store=store,
                                                        paused=True),
                              store)
        sup.admit("cam0", task_w0)          # warm-starts from the store
        fut = sup.submit("cam0", q, valid, boxes)
        sup.flush()                         # survives EngineDead: rebuild,
                                            # re-admit, replay, resolve

    On :class:`~repro.runtime.fault.EngineDead` the supervisor rebuilds
    the engine from its factory, re-admits every stream from its latest
    snapshot and replays the uncovered journal suffix — recovered outputs
    are bit-identical to a fault-free run at ``snapshot_every=1``. A
    crash-loop breaker degrades the knob plan; bounded restarts fail
    pending futures with the terminal ``EngineDead``.
  * ``gateway`` / ``protocol`` — the network tier: a stdlib threaded
    socket/HTTP front mapping multi-tenant ``tenant/stream`` sessions to
    engine slots, with per-tenant token-bucket rate limits, strict frame
    validation, seq-based idempotent retries, recovery-aware 503s and
    graceful drain::

        gw = Gateway(sup, cfg, task_bank, metrics=reg, port=0)
        gw.start()                  # POST /v1/session, POST /v1/window,
                                    # /healthz /readyz /metrics /v1/config
        gw.drain()                  # SIGTERM path: flush in-flight, exit 0

    Every failure mode is a typed client outcome (400/408/409/413/429/503
    + Retry-After); the error taxonomy and wire schema live in
    ``protocol.py`` and docs/gateway.md. ``SyncDriver`` adapts the sync
    ``StreamEngine`` to the future-returning submit surface the gateway
    needs; ``benchmarks/loadgen.py`` is the production-shaped load/chaos
    harness that drives all of it over real sockets.
  * ``reranker``      — TorR as an LLM token-reranking sidecar.

Chaos injection: both engines accept a
:class:`~repro.runtime.fault.FaultPlan` (``fault_plan=``) that kills the
dispatcher or collector at a chosen step exactly once — the deterministic
harness behind ``repro.launch.serve --fault-at/--fault-kind`` and the
recovery tests.
"""
