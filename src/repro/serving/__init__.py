"""Serving layer: evaluation pipelines, token reranker, multi-stream engine.

Modules:

  * ``tood_pipelines`` — dense / naive-HDC / TorR evaluation pipelines over
    the synthetic TOOD world (single stream, one window per call).
  * ``stream_engine``  — the multi-stream batched window engine. API sketch::

        eng = StreamEngine(cfg, im, n_slots=16)
        eng.admit("cam0", task_w0)          # bind stream -> slot, reset cache
        eng.submit("cam0", q_packed, valid, boxes)   # enqueue one window
        results = eng.step()                # one vmapped torr_multi_stream_step
        out, telemetry = results["cam0"]    # per-stream WindowOutput/telemetry
        eng.retire("cam0")                  # free the slot

    ``step()`` batches one pending window per admitted stream into a padded
    :class:`repro.core.types.StreamBatch`; per-stream caches, task weights
    and queue depths live in a stacked ``TorrState``, so results are
    bit-identical to running each stream alone through
    ``repro.core.pipeline.torr_window_step``.
  * ``reranker``      — TorR as an LLM token-reranking sidecar.
"""
