"""Network-facing event gateway: multi-tenant ingestion over real sockets.

Everything below this tier already exists — admission control
(:mod:`repro.serving.deadline`), crash recovery
(:mod:`repro.serving.supervisor`), metrics/flight/tracing
(:mod:`repro.obs`). What was missing is the place where *other people's
code* meets ours: a network front where backpressure, overload and
misbehaving clients happen. The :class:`Gateway` is that tier, built on
the design rule that **every failure mode is a typed, client-visible
outcome** — a malformed frame, a saturated tenant, an engine death
mid-recovery, a slow-loris header, a mid-flight disconnect: each maps to
a deterministic HTTP status with a machine-readable reason and (where
retrying helps) a ``Retry-After`` hint. No client input can surface as a
worker exception; no accepted window is silently lost.

Design notes
------------
* **Hand-rolled HTTP/1.1 over threads**, not ``http.server``: the
  robustness surface *is* the byte-level read path — bounded header and
  body buffers, an absolute per-request read deadline (slow-loris
  becomes 408, not a parked thread), per-write timeouts, a connection
  cap. Stdlib-only, one daemon thread per connection, keep-alive serial
  per connection.
* **Sessions are the tenancy unit.** ``POST /v1/session`` maps
  ``tenant/stream`` to an engine slot (fair admission: a per-tenant
  session quota keeps one tenant from hoarding slots; slot exhaustion is
  a 429 ``no_slot``, not an error). Per-tenant token buckets rate-limit
  window submissions (429 ``rate_limit`` + Retry-After).
* **Strict sequencing is the idempotency contract.** Each session
  carries a client sequence number. A shed window (429) rolls the
  sequence back — shed windows never advanced engine state, so the
  retry is bit-safe. A request-deadline expiry (503 ``deadline``) parks
  the in-flight future — the engine saw the window exactly once, and the
  client's retry of the *same* seq attaches to the parked future (or
  replays the cached result), which is what keeps chaos-retry output
  bit-identical to a fault-free run. A mid-flight disconnect cancels the
  future (accounted in ``torr_telemetry_dropped_total``) but the window
  may already have advanced state, so a later retry of that seq is a
  409 ``seq_consumed``.
* **Recovery awareness.** A supervised front exposes
  ``health()``/``retry_after_s()``; while the supervisor is rebuilding
  an engine the gateway fast-fails windows with 503 ``recovering`` plus
  a backoff-derived retry hint instead of queueing threads on the
  supervisor lock, and ``/readyz`` goes not-ready. A background pump
  thread calls ``front.heal()`` so recovery starts promptly even when no
  traffic is arriving.
* **Graceful drain.** :meth:`Gateway.drain` (SIGTERM in
  ``serve.py --gateway-port``) stops accepting, lets in-flight requests
  resolve, answers new windows with 503 ``draining``, then closes every
  connection — exit 0, nothing lost.

Metrics land in the shared :class:`repro.obs.metrics.MetricsRegistry`
(``torr_gateway_*`` — catalog in docs/observability.md); they reconcile
exactly against a well-behaved client's own counts, which
``benchmarks/loadgen.py`` asserts.
"""
from __future__ import annotations

import collections
import dataclasses
import json
import select
import socket
import threading
import time
from concurrent.futures import CancelledError, Future
from concurrent.futures import TimeoutError as FutureTimeout
from typing import Dict, Optional

import numpy as np

from ..runtime.fault import EngineDead
from .deadline import WindowShed
from . import protocol
from .protocol import ProtocolError

_STATUS_TEXT = {
    200: "OK", 400: "Bad Request", 404: "Not Found",
    405: "Method Not Allowed", 408: "Request Timeout", 409: "Conflict",
    413: "Payload Too Large", 429: "Too Many Requests",
    500: "Internal Server Error", 503: "Service Unavailable",
}

_DROPPED_HELP = "Observed steps/windows lost before telemetry was folded."


@dataclasses.dataclass(frozen=True)
class GatewayLimits:
    """Tuning knobs for the network tier (docs/gateway.md)."""

    max_header_bytes: int = 8192       # request line + headers cap
    max_body_bytes: int = 2 << 20      # JSON body cap -> 413
    read_timeout_s: float = 5.0        # absolute budget to read one request
    idle_timeout_s: float = 30.0       # keep-alive wait for the next request
    write_timeout_s: float = 5.0       # per-send cap (slow readers)
    request_deadline_s: float = 2.0    # default wait for a window result
    max_connections: int = 64          # concurrent sockets -> 503 beyond
    rate_per_s: float = 200.0          # per-tenant token refill rate
    burst: int = 100                   # per-tenant bucket depth
    max_sessions_per_tenant: int = 8   # fair slot admission
    max_parked: int = 4                # deadline-expired futures kept/session
    poll_interval_s: float = 0.05      # future-wait poll + liveness cadence
    no_slot_retry_s: float = 0.25      # Retry-After when slots are exhausted


class _Disconnect(Exception):
    """Client went away mid-request; close the connection quietly."""


class _TokenBucket:
    """Per-tenant rate limiter. Returns 0.0 on admit, else the earliest
    delay after which one token will be available (the Retry-After)."""

    __slots__ = ("rate", "burst", "tokens", "last")

    def __init__(self, rate: float, burst: int, now: float):
        self.rate = float(rate)
        self.burst = float(burst)
        self.tokens = float(burst)
        self.last = now

    def take(self, now: float) -> float:
        self.tokens = min(self.burst,
                          self.tokens + (now - self.last) * self.rate)
        self.last = now
        if self.tokens >= 1.0:
            self.tokens -= 1.0
            return 0.0
        return (1.0 - self.tokens) / self.rate


@dataclasses.dataclass
class _Session:
    sid: str
    tenant: str
    slot: int
    task: int
    rt: str
    deadline_s: float
    lock: threading.Lock = dataclasses.field(default_factory=threading.Lock)
    next_seq: int = 0
    # deadline-expired futures by seq, oldest first (bounded max_parked)
    parked: "collections.OrderedDict[int, Future]" = dataclasses.field(
        default_factory=collections.OrderedDict)
    cached_seq: int = -1        # newest completed seq with a cached body
    cached_body: bytes = b""


class SyncDriver:
    """Future-returning facade over the synchronous :class:`StreamEngine`.

    A pump thread steps the engine whenever it has backlog and resolves
    per-stream FIFO futures with host-resident ``(out, telemetry)``
    trees — giving the sync engine the same submit surface the gateway
    needs from :class:`AsyncStreamEngine`/:class:`ServeSupervisor`.
    Admission-control shedding is not supported here (drive sync engines
    without a tracker); a step-time failure fails every pending future
    with a typed :class:`EngineDead`.
    """

    def __init__(self, engine, metrics=None):
        self.engine = engine
        self._lock = threading.Lock()
        self._expect: Dict[object, collections.deque] = {}
        self._wake = threading.Event()
        self._stop = False
        self._dead: Optional[EngineDead] = None
        self._m_dropped = None
        if metrics is not None:
            self._m_dropped = metrics.counter(
                "torr_telemetry_dropped_total", _DROPPED_HELP)
        self._thread = threading.Thread(
            target=self._pump, name="torr-syncdriver", daemon=True)
        self._thread.start()

    def admit(self, stream_id, task_w, snapshot=None) -> int:
        with self._lock:
            if self._dead is not None:
                raise self._dead
            slot = self.engine.admit(stream_id, task_w, snapshot=snapshot)
            self._expect[stream_id] = collections.deque()
            return slot

    def retire(self, stream_id) -> None:
        with self._lock:
            pending = self._expect.pop(stream_id, ())
            self.engine.retire(stream_id)
        for fut in pending:
            fut.cancel()

    def submit(self, stream_id, q_packed, valid, boxes) -> Future:
        with self._lock:
            if self._dead is not None:
                raise self._dead
            if stream_id not in self._expect:
                raise KeyError(stream_id)
            self.engine.submit(stream_id, q_packed, valid, boxes)
            fut: Future = Future()
            self._expect[stream_id].append(fut)
        self._wake.set()
        return fut

    def health(self) -> dict:
        return {"ready": self._dead is None, "recovering": False,
                "terminal": self._dead is not None, "restarts": 0,
                "degraded": False}

    def close(self) -> None:
        self._stop = True
        self._wake.set()
        self._thread.join(timeout=10.0)

    def _pump(self) -> None:
        import jax
        while not self._stop:
            self._wake.wait(timeout=0.05)
            self._wake.clear()
            while not self._stop:
                with self._lock:
                    if self._dead is not None or not self.engine.busy:
                        break
                    try:
                        results = self.engine.step()
                    except Exception as e:   # noqa: BLE001 — typed below
                        self._dead = EngineDead(
                            cause=e, thread="dispatcher",
                            inflight=sum(len(d)
                                         for d in self._expect.values()))
                        failed = [f for d in self._expect.values() for f in d]
                        for d in self._expect.values():
                            d.clear()
                        results = None
                    if results is None:
                        dead = self._dead
                        resolved = []
                    else:
                        resolved, failed = [], []
                        for sid, out_tel in results.items():
                            q = self._expect.get(sid)
                            if q:
                                resolved.append((q.popleft(), out_tel))
                # deliver outside the lock: callbacks may re-enter submit
                for fut, out_tel in resolved:
                    host = jax.tree_util.tree_map(np.asarray, out_tel)
                    if fut.cancelled():
                        self.engine.stats.telemetry_dropped += 1
                        if self._m_dropped is not None:
                            self._m_dropped.inc()
                    else:
                        try:
                            fut.set_result(host)
                        except Exception:   # cancelled in the gap
                            if self._m_dropped is not None:
                                self._m_dropped.inc()
                if results is None:
                    for fut in failed:
                        if not fut.done():
                            fut.set_exception(dead)
                    break
            with self._lock:
                if self.engine.busy and self._dead is None:
                    self._wake.set()    # backlog grew while delivering


class Gateway:
    """Threaded socket HTTP front mapping tenant sessions to stream slots.

    ``front`` is anything with the admit/retire/submit surface —
    :class:`ServeSupervisor`, :class:`AsyncStreamEngine`, or a
    :class:`SyncDriver`; ``health()``/``retry_after_s()``/``heal()`` are
    consulted when present. ``task_bank`` is the ``[n_tasks, M]`` matrix
    of reasoner task-weight rows sessions select from.
    """

    def __init__(self, front, cfg, task_bank, *, limits: GatewayLimits
                 | None = None, host: str = "127.0.0.1", port: int = 0,
                 metrics=None, flight=None, clock=time.monotonic):
        self._front = front
        self._cfg = cfg
        self._task_bank = np.asarray(task_bank, np.float32)
        if self._task_bank.ndim != 2:
            raise ValueError("task_bank must be [n_tasks, M]")
        self.limits = limits or GatewayLimits()
        self._metrics = metrics
        self._flight = flight
        self._clock = clock
        self._glock = threading.Lock()
        self._sessions: Dict[str, _Session] = {}
        self._buckets: Dict[str, _TokenBucket] = {}
        self._conns: set = set()
        self._active_requests = 0
        self._draining = False
        self._stop = False
        self._threads: list = []

        self._m_req = self._m_rej = self._m_hist = None
        if metrics is not None:
            from ..obs.metrics import LATENCY_BUCKETS_S
            self._m_req = metrics.counter(
                "torr_gateway_requests_total",
                "Gateway HTTP requests by route and response status.",
                ["route", "status"])
            self._m_rej = metrics.counter(
                "torr_gateway_rejects_total",
                "Gateway rejections by typed reason (docs/gateway.md).",
                ["reason"])
            self._m_conns = metrics.counter(
                "torr_gateway_connections_total",
                "Accepted gateway TCP connections.")
            self._g_open = metrics.gauge(
                "torr_gateway_connections_open",
                "Currently open gateway connections.")
            self._g_sessions = metrics.gauge(
                "torr_gateway_sessions_open",
                "Open gateway sessions (tenant/stream pairs).")
            self._m_disc = metrics.counter(
                "torr_gateway_disconnects_total",
                "Client connections lost mid-request.")
            self._g_drain = metrics.gauge(
                "torr_gateway_draining",
                "1 while the gateway is draining (stopped accepting).")
            self._m_hist = metrics.histogram(
                "torr_gateway_request_seconds",
                "Request receipt to response-written wall time.",
                ["route"], buckets=LATENCY_BUCKETS_S)
            self._m_dropped = metrics.counter(
                "torr_telemetry_dropped_total", _DROPPED_HELP)
        else:
            self._m_dropped = None

        self._lsock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._lsock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._lsock.bind((host, port))
        self._lsock.listen(128)
        self.host = host
        self.port = self._lsock.getsockname()[1]

    # -- lifecycle -----------------------------------------------------------

    def start(self) -> int:
        t = threading.Thread(target=self._accept_loop,
                             name="torr-gateway-accept", daemon=True)
        t.start()
        self._threads.append(t)
        p = threading.Thread(target=self._pump_loop,
                             name="torr-gateway-pump", daemon=True)
        p.start()
        self._threads.append(p)
        if self._flight is not None:
            self._flight.record(event="gateway_listening", port=self.port)
        return self.port

    def drain(self, timeout: float | None = 30.0) -> bool:
        """Graceful shutdown: stop accepting, flush in-flight requests,
        then close every connection. Returns True if in-flight work
        drained inside the timeout."""
        with self._glock:
            if self._draining:
                return True
            self._draining = True
        if self._metrics is not None:
            self._g_drain.set(1)
        if self._flight is not None:
            self._flight.record(event="gateway_drain_begin",
                                active=self._active_requests,
                                conns=len(self._conns))
        try:
            self._lsock.close()
        except OSError:
            pass
        deadline = None if timeout is None else self._clock() + timeout
        drained = True
        while True:
            with self._glock:
                active = self._active_requests
            if active == 0:
                break
            if deadline is not None and self._clock() >= deadline:
                drained = False
                break
            time.sleep(0.01)
        with self._glock:
            sessions = list(self._sessions.values())
            conns = list(self._conns)
        for sess in sessions:
            with sess.lock:
                # cancelled futures are accounted by the delivery path
                # (engine collector / supervisor / SyncDriver) in
                # torr_telemetry_dropped_total — not double-counted here
                for fut in sess.parked.values():
                    fut.cancel()
                sess.parked.clear()
        for c in conns:
            try:
                c.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                c.close()
            except OSError:
                pass
        self._stop = True
        if self._flight is not None:
            self._flight.record(event="gateway_drain_end", drained=drained)
        return drained

    def close(self) -> None:
        if not self._draining:
            self.drain(timeout=5.0)
        self._stop = True
        for t in self._threads:
            t.join(timeout=5.0)

    def __enter__(self) -> "Gateway":
        self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- background threads --------------------------------------------------

    def _accept_loop(self) -> None:
        while not self._stop:
            try:
                conn, _addr = self._lsock.accept()
            except OSError:
                return      # listener closed (drain/close)
            if self._draining or self._stop:
                self._refuse(conn, 503, "draining")
                continue
            with self._glock:
                over = len(self._conns) >= self.limits.max_connections
                if not over:
                    self._conns.add(conn)
            if over:
                self._refuse(conn, 503, "conn_limit")
                continue
            if self._metrics is not None:
                self._m_conns.inc()
                self._g_open.set(len(self._conns))
            t = threading.Thread(target=self._serve_conn, args=(conn,),
                                 name="torr-gateway-conn", daemon=True)
            t.start()

    def _pump_loop(self) -> None:
        """Keep recovery moving without traffic: a supervised front only
        notices engine death inside submit/admit/flush, so an idle
        gateway would otherwise sit on a dead engine until the next
        request pays the full recovery latency."""
        while not self._stop:
            heal = getattr(self._front, "heal", None)
            if callable(heal):
                try:
                    heal()
                except EngineDead:
                    pass    # terminal: health() now reports it
                except Exception:   # noqa: BLE001 — pump must survive
                    pass
            time.sleep(0.05)

    def _refuse(self, conn, status: int, reason: str) -> None:
        try:
            conn.settimeout(self.limits.write_timeout_s)
            body = json.dumps({"error": reason}).encode()
            conn.sendall(self._head(status, len(body),
                                    "application/json", False) + body)
        except OSError:
            pass
        finally:
            try:
                conn.close()
            except OSError:
                pass
        self._count("other", status, reason)

    # -- connection handling -------------------------------------------------

    def _serve_conn(self, conn) -> None:
        buf = bytearray()
        try:
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            while not self._stop:
                try:
                    req = self._read_request(conn, buf)
                except ProtocolError as e:
                    self._send_error(conn, "other", e, keep=False)
                    return
                if req is None:
                    return      # clean close or idle timeout
                method, path, headers, body = req
                want_close = headers.get("connection", "").lower() == "close"
                keep = not want_close and not self._draining
                with self._glock:
                    self._active_requests += 1
                try:
                    keep = self._dispatch(conn, method, path, body, keep)
                finally:
                    with self._glock:
                        self._active_requests -= 1
                if not keep:
                    return
        except _Disconnect:
            if self._metrics is not None:
                self._m_disc.inc()
        except OSError:
            pass
        finally:
            with self._glock:
                self._conns.discard(conn)
            if self._metrics is not None:
                self._g_open.set(len(self._conns))
            try:
                conn.close()
            except OSError:
                pass

    def _recv(self, conn, timeout: float) -> bytes:
        conn.settimeout(max(timeout, 1e-4))
        try:
            chunk = conn.recv(65536)
        except socket.timeout:
            raise ProtocolError(408, "slow_client",
                                "read deadline exceeded") from None
        except OSError:
            raise _Disconnect() from None
        if chunk == b"":
            raise _Disconnect()
        return chunk

    def _read_request(self, conn, buf: bytearray):
        """Read one full request with bounded buffers and an absolute
        deadline. Returns None on clean idle close/timeout before any
        byte of a new request arrived."""
        lim = self.limits
        # wait for the first byte of a new request (idle keep-alive)
        if not buf:
            conn.settimeout(lim.idle_timeout_s)
            try:
                chunk = conn.recv(65536)
            except socket.timeout:
                return None
            except OSError:
                return None
            if chunk == b"":
                return None
            buf += chunk
        deadline = self._clock() + lim.read_timeout_s
        while b"\r\n\r\n" not in buf:
            if len(buf) > lim.max_header_bytes:
                raise ProtocolError(400, "bad_request", "headers too large")
            left = deadline - self._clock()
            if left <= 0:
                raise ProtocolError(408, "slow_client",
                                    "headers not received in time")
            buf += self._recv(conn, left)
        head, rest = bytes(buf).split(b"\r\n\r\n", 1)
        if len(head) > lim.max_header_bytes:
            raise ProtocolError(400, "bad_request", "headers too large")
        del buf[:]
        buf += rest
        try:
            lines = head.decode("latin-1").split("\r\n")
            method, path, version = lines[0].split(" ", 2)
        except (UnicodeDecodeError, ValueError):
            raise ProtocolError(400, "bad_request",
                                "malformed request line") from None
        if not version.startswith("HTTP/1."):
            raise ProtocolError(400, "bad_request",
                                f"unsupported version {version!r}")
        headers = {}
        for line in lines[1:]:
            if ":" not in line:
                raise ProtocolError(400, "bad_request",
                                    "malformed header line")
            k, v = line.split(":", 1)
            headers[k.strip().lower()] = v.strip()
        if "transfer-encoding" in headers:
            raise ProtocolError(400, "bad_request",
                                "chunked bodies not supported")
        body = b""
        if method in ("POST", "PUT"):
            cl = headers.get("content-length")
            if cl is None or not cl.isdigit():
                raise ProtocolError(400, "bad_request",
                                    "Content-Length required")
            n = int(cl)
            if n > lim.max_body_bytes:
                raise ProtocolError(
                    413, "too_large",
                    f"body {n}B over cap {lim.max_body_bytes}B")
            while len(buf) < n:
                left = deadline - self._clock()
                if left <= 0:
                    raise ProtocolError(408, "slow_client",
                                        "body not received in time")
                buf += self._recv(conn, left)
            body = bytes(buf[:n])
            del buf[:n]
        return method, path, headers, body

    # -- response plumbing ---------------------------------------------------

    @staticmethod
    def _head(status: int, length: int, ctype: str, keep: bool,
              retry_after_s: float | None = None) -> bytes:
        lines = [
            f"HTTP/1.1 {status} {_STATUS_TEXT.get(status, 'Unknown')}",
            f"Content-Type: {ctype}",
            f"Content-Length: {length}",
            f"Connection: {'keep-alive' if keep else 'close'}",
        ]
        if retry_after_s is not None:
            # RFC 7231 allows only integer seconds; keep sub-second
            # precision in the JSON body, round up here so a compliant
            # client never retries early
            lines.append(f"Retry-After: {max(0, int(retry_after_s + 0.999))}")
            lines.append(f"X-Retry-After-S: {retry_after_s:.6f}")
        return ("\r\n".join(lines) + "\r\n\r\n").encode("latin-1")

    def _send(self, conn, status: int, body: bytes, ctype: str, keep: bool,
              retry_after_s: float | None = None) -> None:
        conn.settimeout(self.limits.write_timeout_s)
        try:
            conn.sendall(self._head(status, len(body), ctype, keep,
                                    retry_after_s) + body)
        except (OSError, socket.timeout):
            raise _Disconnect() from None

    def _send_json(self, conn, status: int, obj: dict, keep: bool,
                   retry_after_s: float | None = None) -> None:
        self._send(conn, status, json.dumps(obj).encode(),
                   "application/json", keep, retry_after_s)

    def _send_error(self, conn, route: str, err: ProtocolError,
                    keep: bool) -> None:
        self._count(route, err.status, err.reason)
        self._send_json(conn, err.status, err.body(), keep,
                        err.retry_after_s)

    def _count(self, route: str, status: int, reason: str | None) -> None:
        if self._metrics is None:
            return
        self._m_req.labels(route=route, status=str(status)).inc()
        if reason is not None and status >= 400:
            self._m_rej.labels(reason=reason).inc()

    # -- routing -------------------------------------------------------------

    _ROUTES = {"/healthz": "healthz", "/readyz": "readyz",
               "/metrics": "metrics", "/v1/config": "config",
               "/v1/session": "session", "/v1/window": "window"}

    def _dispatch(self, conn, method: str, path: str, body: bytes,
                  keep: bool) -> bool:
        path = path.split("?", 1)[0]
        route = self._ROUTES.get(path) or (
            "session" if path.startswith("/v1/session/") else "other")
        t0 = time.perf_counter()
        try:
            handler = getattr(self, f"_h_{route}", None)
            if handler is None:
                raise ProtocolError(404, "bad_request",
                                    f"no route {path!r}")
            handler(conn, method, path, body, keep)
        except ProtocolError as e:
            self._send_error(conn, route, e, keep)
            if e.status in (408, 413):
                keep = False    # the request stream is desynchronized
        except _Disconnect:
            raise
        except Exception as e:   # noqa: BLE001 — typed outcome, not a crash
            if self._flight is not None:
                self._flight.record(event="gateway_internal_error",
                                    route=route,
                                    error=f"{type(e).__name__}: {e}")
            self._send_error(conn, route, ProtocolError(
                500, "internal", f"{type(e).__name__}"), keep)
        finally:
            if self._m_hist is not None:
                self._m_hist.labels(route=route).observe(
                    time.perf_counter() - t0)
        return keep

    # -- endpoint handlers ---------------------------------------------------

    def _front_health(self) -> dict:
        h = getattr(self._front, "health", None)
        if callable(h):
            return h()
        return {"ready": True, "recovering": False, "terminal": False,
                "restarts": 0, "degraded": False}

    def _dead_reason(self) -> str:
        """503 reason for an EngineDead: ``recovering`` only when the
        front can actually recover (a supervisor with restarts left)."""
        state = self._front_health()
        if state.get("terminal") or \
                not callable(getattr(self._front, "heal", None)):
            return "engine_dead"
        return "recovering"

    def _front_retry_s(self) -> float:
        r = getattr(self._front, "retry_after_s", None)
        if callable(r):
            try:
                return float(r())
            except Exception:   # noqa: BLE001
                pass
        return self.limits.poll_interval_s * 2

    def _h_healthz(self, conn, method, path, body, keep) -> None:
        if method != "GET":
            raise ProtocolError(405, "bad_request", "GET only")
        self._count("healthz", 200, None)
        self._send_json(conn, 200, {"ok": True}, keep)

    def _h_readyz(self, conn, method, path, body, keep) -> None:
        if method != "GET":
            raise ProtocolError(405, "bad_request", "GET only")
        state = self._front_health()
        ready = bool(state.get("ready", True)) and not self._draining
        state = dict(state, draining=self._draining, ready=ready)
        status = 200 if ready else 503
        self._count("readyz", status, None)
        self._send_json(conn, status, state, keep,
                        None if ready else self._front_retry_s())

    def _h_metrics(self, conn, method, path, body, keep) -> None:
        if method != "GET":
            raise ProtocolError(405, "bad_request", "GET only")
        if self._metrics is None:
            raise ProtocolError(404, "bad_request", "metrics not armed")
        from ..obs.export import prometheus_text
        self._count("metrics", 200, None)
        self._send(conn, 200, prometheus_text(self._metrics).encode(),
                   "text/plain; version=0.0.4; charset=utf-8", keep)

    def _h_config(self, conn, method, path, body, keep) -> None:
        if method != "GET":
            raise ProtocolError(405, "bad_request", "GET only")
        self._count("config", 200, None)
        self._send_json(conn, 200, protocol.config_body(
            self._cfg, len(self._task_bank), self.limits), keep)

    def _h_session(self, conn, method, path, body, keep) -> None:
        if method == "POST" and path == "/v1/session":
            self._session_open(conn, body, keep)
        elif method == "DELETE" and path.startswith("/v1/session/"):
            self._session_close(conn, path[len("/v1/session/"):], keep)
        else:
            raise ProtocolError(405, "bad_request",
                                "POST /v1/session or DELETE "
                                "/v1/session/<tenant>/<stream>")

    def _session_open(self, conn, body: bytes, keep: bool) -> None:
        so = protocol.validate_session_open(
            protocol.parse_json_body(body), len(self._task_bank))
        sid = protocol.session_id(so.tenant, so.stream)
        from ..configs.torr_edge import rt_budget_s
        deadline_s = max(self.limits.request_deadline_s,
                         4.0 * rt_budget_s(so.rt))
        with self._glock:
            if self._draining:
                raise ProtocolError(503, "draining", "gateway is draining")
            existing = self._sessions.get(sid)
            if existing is not None:
                if existing.task != so.task or existing.rt != so.rt:
                    raise ProtocolError(
                        409, "session_exists",
                        f"{sid} already open with task={existing.task} "
                        f"rt={existing.rt}")
                self._count("session", 200, None)
                self._send_json(conn, 200, {
                    "session": sid, "slot": existing.slot,
                    "task": existing.task, "rt": existing.rt,
                    "next_seq": existing.next_seq}, keep)
                return
            wait = self._bucket(so.tenant).take(self._clock())
            if wait > 0.0:
                raise ProtocolError(429, "rate_limit",
                                    f"tenant {so.tenant} over rate",
                                    retry_after_s=wait)
            n_tenant = sum(1 for s in self._sessions.values()
                           if s.tenant == so.tenant)
            if n_tenant >= self.limits.max_sessions_per_tenant:
                raise ProtocolError(
                    429, "tenant_quota",
                    f"tenant {so.tenant} at session quota "
                    f"({self.limits.max_sessions_per_tenant})")
            state = self._front_health()
            if state.get("terminal"):
                raise ProtocolError(503, "engine_dead",
                                    "engine terminally failed")
            if state.get("recovering"):
                raise ProtocolError(503, "recovering",
                                    "engine is recovering",
                                    retry_after_s=self._front_retry_s())
            try:
                slot = self._front.admit(sid, self._task_bank[so.task])
            except EngineDead as e:
                # ordered before RuntimeError: EngineDead subclasses it
                raise ProtocolError(503, self._dead_reason(),
                                    f"engine died during admit: {e}",
                                    retry_after_s=self._front_retry_s()
                                    ) from e
            except ValueError as e:
                raise ProtocolError(409, "session_exists", str(e)) from e
            except RuntimeError as e:
                if "slot" in str(e):
                    raise ProtocolError(
                        429, "no_slot", "no free stream slots",
                        retry_after_s=self.limits.no_slot_retry_s) from e
                raise
            sess = _Session(sid=sid, tenant=so.tenant, slot=slot,
                            task=so.task, rt=so.rt, deadline_s=deadline_s)
            self._sessions[sid] = sess
            if self._metrics is not None:
                self._g_sessions.set(len(self._sessions))
        self._count("session", 200, None)
        self._send_json(conn, 200, {"session": sid, "slot": slot,
                                    "task": so.task, "rt": so.rt,
                                    "next_seq": 0}, keep)

    def _session_close(self, conn, sid: str, keep: bool) -> None:
        protocol.split_session_id(sid)
        with self._glock:
            sess = self._sessions.pop(sid, None)
            if self._metrics is not None:
                self._g_sessions.set(len(self._sessions))
        if sess is None:
            raise ProtocolError(404, "no_session", f"{sid} not open")
        with sess.lock:
            for fut in sess.parked.values():
                fut.cancel()
            sess.parked.clear()
        try:
            self._front.retire(sid)
        except (EngineDead, KeyError):
            pass    # a rebuilt engine simply won't re-admit it
        self._count("session", 200, None)
        self._send_json(conn, 200, {"closed": sid}, keep)

    def _h_window(self, conn, method, path, body, keep) -> None:
        if method != "POST":
            raise ProtocolError(405, "bad_request", "POST only")
        wr = protocol.validate_window(protocol.parse_json_body(body),
                                      self._cfg)
        with self._glock:
            if self._draining:
                raise ProtocolError(503, "draining", "gateway is draining")
            sess = self._sessions.get(wr.session)
            if sess is None:
                raise ProtocolError(404, "no_session",
                                    f"{wr.session} not open")
            wait = self._bucket(sess.tenant).take(self._clock())
        if wait > 0.0:
            raise ProtocolError(429, "rate_limit",
                                f"tenant {sess.tenant} over rate",
                                retry_after_s=wait)
        state = self._front_health()
        if state.get("terminal"):
            raise ProtocolError(503, "engine_dead",
                                "engine terminally failed")
        if state.get("recovering"):
            raise ProtocolError(503, "recovering", "engine is recovering",
                                retry_after_s=self._front_retry_s())
        deadline_s = wr.deadline_s or sess.deadline_s
        with sess.lock:
            self._window_locked(conn, sess, wr, deadline_s, keep)

    def _window_locked(self, conn, sess: _Session, wr, deadline_s: float,
                       keep: bool) -> None:
        seq = wr.seq
        if seq == sess.next_seq:
            try:
                fut = self._front.submit(sess.sid, wr.q, wr.valid, wr.boxes)
            except KeyError:
                raise ProtocolError(404, "no_session",
                                    f"{sess.sid} lost its slot") from None
            except WindowShed as e:
                raise ProtocolError(429, "shed", str(e),
                                    retry_after_s=e.retry_after_s) from e
            except EngineDead as e:
                raise ProtocolError(503, self._dead_reason(),
                                    f"engine died on submit: {e}",
                                    retry_after_s=self._front_retry_s()
                                    ) from e
            sess.next_seq += 1
            self._settle(conn, sess, seq, fut, deadline_s, keep)
        elif seq == sess.next_seq - 1 and seq in sess.parked:
            fut = sess.parked.pop(seq)
            self._settle(conn, sess, seq, fut, deadline_s, keep)
        elif seq == sess.next_seq - 1 and seq == sess.cached_seq:
            # idempotent retry of the newest completed window
            self._count("window", 200, None)
            self._send(conn, 200, sess.cached_body, "application/json",
                       keep)
        elif seq == sess.next_seq - 1:
            raise ProtocolError(
                409, "seq_consumed",
                f"seq {seq} was consumed but its result is gone "
                "(disconnected mid-flight?); resume at "
                f"seq {sess.next_seq}")
        else:
            raise ProtocolError(
                409, "out_of_order",
                f"expected seq {sess.next_seq}, got {seq}")

    def _settle(self, conn, sess: _Session, seq: int, fut: Future,
                deadline_s: float, keep: bool) -> None:
        """Wait for one submitted window's future, watching the client
        socket for liveness; every exit is a typed outcome."""
        t_end = self._clock() + deadline_s
        poll = self.limits.poll_interval_s
        while True:
            try:
                wout, _wtel = fut.result(timeout=poll)
                break
            except FutureTimeout:
                pass
            except CancelledError:
                raise ProtocolError(503, "draining",
                                    "window cancelled during drain"
                                    ) from None
            except WindowShed as e:
                # shed windows never advanced engine state: roll the
                # sequence back so the client's retry of the same seq is
                # a fresh, bit-safe submission
                if seq == sess.next_seq - 1:
                    sess.next_seq -= 1
                raise ProtocolError(429, "shed", str(e),
                                    retry_after_s=e.retry_after_s) from e
            except EngineDead as e:
                raise ProtocolError(503, self._dead_reason(), str(e),
                                    retry_after_s=self._front_retry_s()
                                    ) from e
            except Exception as e:   # noqa: BLE001
                raise ProtocolError(500, "internal",
                                    f"{type(e).__name__}") from e
            if self._clock() >= t_end:
                self._park(sess, seq, fut)
                raise ProtocolError(
                    503, "deadline",
                    f"window {seq} still in flight after "
                    f"{deadline_s * 1e3:.0f} ms; retry the same seq to "
                    "collect it", retry_after_s=self._front_retry_s())
            if not _client_alive(conn):
                # the window may already have advanced engine state, so
                # the seq stays consumed; the engine/supervisor accounts
                # the cancelled delivery in torr_telemetry_dropped_total
                if not fut.cancel() and fut.done() \
                        and fut.exception() is None:
                    self._cache(sess, seq, fut.result()[0])
                if self._metrics is not None:
                    self._m_disc.inc()
                    self._m_rej.labels(reason="disconnect").inc()
                raise _Disconnect()
        body = json.dumps(
            protocol.window_result_body(seq, wout)).encode()
        sess.cached_seq, sess.cached_body = seq, body
        self._count("window", 200, None)
        self._send(conn, 200, body, "application/json", keep)

    def _cache(self, sess: _Session, seq: int, wout) -> None:
        sess.cached_seq = seq
        sess.cached_body = json.dumps(
            protocol.window_result_body(seq, wout)).encode()

    def _park(self, sess: _Session, seq: int, fut: Future) -> None:
        sess.parked[seq] = fut
        while len(sess.parked) > self.limits.max_parked:
            _old_seq, old = sess.parked.popitem(last=False)
            old.cancel()

    def _bucket(self, tenant: str) -> _TokenBucket:
        b = self._buckets.get(tenant)
        if b is None:
            b = self._buckets[tenant] = _TokenBucket(
                self.limits.rate_per_s, self.limits.burst, self._clock())
        return b

    # -- introspection -------------------------------------------------------

    def summary(self) -> dict:
        with self._glock:
            return {
                "port": self.port,
                "sessions": len(self._sessions),
                "connections": len(self._conns),
                "active_requests": self._active_requests,
                "draining": self._draining,
            }


def _client_alive(conn) -> bool:
    """True while the client socket is readable-empty or quiet. A peer
    close shows as readable-with-EOF; buffered pipelined bytes count as
    alive (they stay queued — requests are served serially)."""
    try:
        r, _, _ = select.select([conn], [], [], 0)
        if not r:
            return True
        return conn.recv(1, socket.MSG_PEEK) != b""
    except (BlockingIOError, InterruptedError):
        return True
    except OSError:
        return False
