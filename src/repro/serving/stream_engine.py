"""Multi-stream batched window engine: slot scheduler over the vmapped step.

The single-stream serving loop (``tood_pipelines.run_torr``) dispatches one
``torr_window_step`` per frame and leaves the accelerator idle between
windows. This engine serves S independent camera/DVS streams through *one*
compiled ``torr_multi_stream_step``: streams are admitted into fixed stream
slots, each slot owns a stacked row of ``TorrState`` (its query cache, task
weights and backlog), and every ``step()`` drains one window per busy slot
as a padded :class:`repro.core.types.StreamBatch`.

Scheduling contract:

  * ``admit(stream_id, task_w)`` binds a stream to a free slot and resets
    that slot's cache (no cross-stream reuse leaks).
  * ``submit(stream_id, q_packed, valid, boxes)`` enqueues one window.
  * ``step()`` pops the head window of every busy slot, pads idle slots
    (valid all-False -> the pipeline's pad branch leaves their cache
    untouched), and returns {stream_id: (WindowOutput, WindowTelemetry)}.
    A stream's ``queue_depth`` is its remaining backlog after the pop, so
    Alg. 1's per-stream load gating (H, D') sees true per-stream pressure.
  * ``retire(stream_id)`` drops the stream's remaining backlog and frees
    the slot; admission asserts the recycled slot's queue is empty.

Because the batched step is an exact vmap of the window FSM, results are
bit-identical to running each stream alone (tests/test_multistream.py).

``fused="auto"`` arms the load-aware kernel dispatch: every step the
engine folds the previous step's full-path fraction into an EWMA and picks
between the hoisted lowering default and the reuse-aware compact dispatch
(``fused="compact"`` with a ``core.policy.bucket_ladder`` tier sized to the
predicted miss count) — reuse-heavy traffic stops paying the full
XNOR-popcount scan over lanes that resolve via bypass/delta. Every choice
is bit-identical (compact overflow falls back exactly), so auto is purely
a scheduling knob.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from ..core import pipeline, policy, query_cache
from ..core.item_memory import ItemMemory
from ..core.pipeline import TorrState, WindowOutput
from ..core.types import PATH_FULL, StreamBatch, TorrConfig, WindowTelemetry
from ..obs.bridge import StepObserver, telemetry_digest
from ..obs.spans import NULL_SPAN, span
from ..obs.trace import now_us, trace_scope

# admission-gate verdicts for `_assemble(gate=...)`; values align with
# `repro.serving.deadline.Decision` (an IntEnum) so trackers can be used
# as gates without this module importing the deadline layer
GATE_ADMIT, GATE_ESCALATE, GATE_SHED = 0, 1, 2

# load-aware fused="auto" dispatch: EWMA weight of the newest step's
# full-path fraction, and the headroom multiplier the predicted full count
# is padded by before rounding up to a bucket-ladder tier (a mispredict is
# never wrong — the compact dispatch falls back exactly on overflow — but
# the fallback rescans every row, so headroom is cheap insurance)
AUTO_ALPHA = 0.3
AUTO_HEADROOM = 2.0


@dataclasses.dataclass
class EngineStats:
    """Counters for the batched engine (host-side, cheap)."""

    steps: int = 0
    windows: int = 0          # non-pad windows processed
    pad_slots: int = 0        # idle slot-steps (wasted lanes)
    admitted: int = 0
    retired: int = 0
    dropped: int = 0          # backlog windows discarded by retire()
    shed: int = 0             # windows shed by RT admission control
    telemetry_dropped: int = 0  # observed windows lost before the fold
                                # (collector drain on worker death, futures
                                # cancelled mid-flight) — the silent-loss
                                # audit counter

    @property
    def occupancy(self) -> float:
        total = self.windows + self.pad_slots
        return self.windows / total if total else 0.0


class StreamEngine:
    """Fixed-slot scheduler feeding ``torr_multi_stream_step``."""

    # engine family stamped into minted trace contexts (async overrides)
    _ENGINE = "sync"

    def __init__(
        self,
        cfg: TorrConfig,
        im: ItemMemory,
        n_slots: int = 16,
        jit: bool = True,
        serial: bool = False,
        fused: str | None = None,
        bucket_cap: int | None = None,
        decide: str | None = None,
        metrics=None,
        flight=None,
        tracer=None,
        store=None,
        snapshot_every: int = 1,
        fault_plan=None,
    ):
        self.cfg = cfg
        self.im = im
        self.n_slots = n_slots
        self._state: TorrState = pipeline.init_multi_stream_state(
            cfg, jnp.zeros((n_slots, cfg.M), jnp.float32)
        )
        self._pending = [collections.deque() for _ in range(n_slots)]
        self._slot_of: Dict[object, int] = {}
        self._free = list(range(n_slots - 1, -1, -1))
        # `serial` picks the lowering (vmap lanes vs on-device lax.map); both
        # are bit-identical — see pipeline.torr_multi_stream_step. Jit the
        # module-level function (not a per-engine partial) so engines with
        # the same cfg share one compiled executable.
        self._serial = serial
        # `fused` picks the full path's kernel dispatch (None = the
        # lowering-appropriate fused default; "off" = the jnp-oracle
        # reference step). Static, like `serial`. "auto" arms the
        # load-aware dispatcher: each step picks compact-vs-hoisted (and
        # the compact bucket tier) from the telemetry path-mix EWMA.
        self._auto = fused == "auto"
        self._fused = None if self._auto else fused
        self._bucket_cap = bucket_cap
        # `decide` picks the compact dispatch's decide-pass lowering
        # (None = "batched"; "scan" pins the sequential reference oracle).
        # Static like `fused`; auto-picked compact steps ride it too.
        self._decide = decide
        # full-path fraction EWMA; starts pessimistic (a cold cache makes
        # every proposal a miss), so auto begins on the hoisted lowering.
        # The backlog holds telemetry of in-flight steps; only entries at
        # least one dispatch old are folded (see _fold_telemetry).
        self._full_ewma = 1.0
        self._tel_backlog: collections.deque = collections.deque()
        # The QoS control plane's latched knob plan: a static jit argument,
        # so each distinct plan dispatches its own specialized executable
        # (the window-latched register analogue). None = uncontrolled step.
        self._plan = None
        step = pipeline.torr_stream_batch_step
        self._step = (
            jax.jit(step, static_argnames=("cfg", "serial", "plan", "fused",
                                           "bucket_cap", "decide"))
            if jit else step
        )
        self.stats = EngineStats()
        # observability (repro.obs): a MetricsRegistry and/or FlightRecorder
        # attach a StepObserver; without either the engine pays nothing but
        # NULL_SPAN's empty context managers. The telemetry backlog rides
        # the same deferred-fold path the auto dispatcher uses, so obs never
        # blocks the host on an in-flight device step either.
        self._obs = (StepObserver(metrics, flight)
                     if metrics is not None or flight is not None else None)
        # causal tracing (repro.obs.trace): when a Tracer is armed, submit()
        # mints a per-window TraceContext that rides the pending tuple, the
        # step's spans stamp phase intervals onto it via trace_scope, and
        # the telemetry fold completes it with the resolved plan/lowering.
        # Spans are armed for a tracer even without a registry (span(name,
        # None) records no histogram but still feeds record_span).
        self._tracer = tracer
        self._step_ctxs = None  # live ctx list while a traced step assembles
        sp = (lambda name: span(name, metrics)) \
            if metrics is not None or tracer is not None \
            else (lambda name: NULL_SPAN)
        self._sp_assemble = sp("host_assemble")
        self._sp_dispatch = sp("dispatch_enqueue")
        self._sp_observe = sp("host_observe")
        self._last_resolved = (self._fused, self._bucket_cap, self._decide)
        # externalized session state (repro.serving.state_store): with a
        # store attached, every stream's cache rows + task weights write
        # through every `snapshot_every` served windows — sliced lazily at
        # dispatch, materialized on the deferred telemetry fold (sync) or
        # the collector (async), so the hot path never blocks on it
        self._store = store
        self._snapshot_every = max(1, int(snapshot_every))
        self._served_count: Dict[object, int] = {}
        # deterministic chaos injection (runtime.fault.FaultPlan): fired at
        # the engine's step boundaries; exercises the EngineDead + recovery
        # machinery end-to-end
        self._fault = fault_plan
        # reusable host-side pad buffers for batch assembly
        self._q0 = np.zeros((cfg.N_max, cfg.words), np.uint32)
        self._v0 = np.zeros((cfg.N_max,), bool)
        self._b0 = np.zeros((cfg.N_max, 4), np.float32)

    # -- admission control --------------------------------------------------

    def admit(self, stream_id, task_w, snapshot=None) -> int:
        """Bind a stream to a free slot; returns the slot index.

        ``snapshot`` (a :class:`repro.serving.state_store.StreamSnapshot`,
        or None) warm-starts the slot: the snapshot's cache rows (packed
        prototypes, accumulators, ``acc_tag``s, age/validity) and
        task-weight row overwrite the freshly-reset slot, and the
        stream's served-window count resumes from ``snapshot.window_seq``
        — a re-admitted stream keeps the reuse state that makes
        partial-similarity paths pay, instead of recomputing it cold.
        """
        if stream_id in self._slot_of:
            raise ValueError(f"stream {stream_id!r} already admitted")
        if not self._free:
            raise RuntimeError("no free stream slots; retire a stream first")
        slot = self._free.pop()
        # retire() drops a stream's un-popped backlog with the slot, so a
        # recycled slot must come back empty — anything else is a
        # cross-stream backlog leak.
        assert not self._pending[slot], (
            f"slot {slot} re-admitted with {len(self._pending[slot])} leaked "
            "backlog windows; retire() must drop them")
        self._slot_of[stream_id] = slot
        self._state = TorrState(
            cache=query_cache.reset_slot(self._state.cache, self.cfg, slot),
            task_weights=self._state.task_weights.at[slot].set(
                jnp.asarray(task_w, jnp.float32)
            ),
        )
        if snapshot is not None:
            from . import state_store as ss
            self._state = ss.restore_slot(self._state, self.cfg, slot,
                                          snapshot)
            self._served_count[stream_id] = int(snapshot.window_seq)
        else:
            self._served_count[stream_id] = 0
        self.stats.admitted += 1
        if self._obs is not None:
            self._obs.on_admit()
        return slot

    def retire(self, stream_id) -> None:
        """Release a stream's slot, dropping any un-popped backlog.

        The slot's cache is reset on the next admit; the backlog must be
        dropped *here* so a recycled slot can never serve a window (or leak
        queue-depth pressure) belonging to the retired stream."""
        slot = self._slot_of.pop(stream_id)
        n_dropped = len(self._pending[slot])
        self.stats.dropped += n_dropped
        self._pending[slot].clear()
        self._free.append(slot)
        self.stats.retired += 1
        self._served_count.pop(stream_id, None)
        if self._store is not None:
            self._store.delete(stream_id)
        if self._obs is not None:
            self._obs.on_retire(n_dropped)

    # -- window flow --------------------------------------------------------

    def submit(self, stream_id, q_packed, valid, boxes) -> None:
        """Enqueue one window (packed queries, validity, boxes) for a stream.

        With a tracer armed, a per-window :class:`TraceContext` is minted
        here (this is the window's admission timestamp) and rides the
        pending tuple as the trailing payload."""
        slot = self._slot_of[stream_id]
        window = (np.asarray(q_packed, np.uint32),
                  np.asarray(valid, bool),
                  np.asarray(boxes, np.float32))
        if self._tracer is not None:
            window += (self._tracer.mint(stream_id, self._ENGINE),)
        self._pending[slot].append(window)

    @staticmethod
    def _ctx_of(extra):
        """The window's TraceContext from ``submit``'s trailing payload
        (None when untraced). The async engine overrides — its payload
        carries (future, arrival, ctx)."""
        return extra[0] if extra else None

    def backlog(self, stream_id) -> int:
        return len(self._pending[self._slot_of[stream_id]])

    @property
    def busy(self) -> bool:
        return any(self._pending[s] for s in self._slot_of.values())

    def _assemble(self, gate=None):
        """Pop the head window of every busy slot into padded host buffers.

        Returns ``(q, v, b, qd, served)`` where served is a list of
        ``(stream_id, slot, extra)`` — ``extra`` is whatever trailing payload
        ``submit`` queued alongside the window arrays (the async engine
        rides its per-window future and arrival time here). Idle slots stay
        all-pad; ``qd`` is each served slot's *remaining* backlog after the
        pop, so Alg. 1's load gate sees true per-stream pressure.

        ``gate(stream_id, backlog_after_pop, extra) -> GATE_*`` is the
        optional admission hook (the async engine's RT-deadline controller):
        GATE_SHED drops the head (the gate owns failing its future) and the
        next queued window is offered in its place; GATE_ESCALATE serves the
        window with its queue-depth lane floored to ``cfg.q_hi`` so Alg. 1's
        ``H(N, q)`` goes high. With ``gate=None`` every window is admitted —
        the batch composition the bit-equivalence tests pin down."""
        S = self.n_slots
        q = np.broadcast_to(self._q0, (S,) + self._q0.shape).copy()
        v = np.broadcast_to(self._v0, (S,) + self._v0.shape).copy()
        b = np.broadcast_to(self._b0, (S,) + self._b0.shape).copy()
        qd = np.zeros((S,), np.int32)
        served = []  # (stream_id, slot, extra) of non-pad lanes this step
        for stream_id, slot in self._slot_of.items():
            dq = self._pending[slot]
            while dq:
                qw, vw, bw, *extra = dq[0]
                decision = GATE_ADMIT if gate is None else \
                    gate(stream_id, len(dq) - 1, extra)
                dq.popleft()
                if decision == GATE_SHED:
                    continue    # offer this slot's next queued window
                q[slot], v[slot], b[slot] = qw, vw, bw
                qd[slot] = len(dq)
                if decision == GATE_ESCALATE:
                    qd[slot] = max(qd[slot], self.cfg.q_hi)
                served.append((stream_id, slot, extra))
                ctx = self._ctx_of(extra)
                if ctx is not None:
                    ctx.slot = slot
                    if ctx.decision is None:  # a gate may have stamped it
                        ctx.decision = ("admit", "escalate",
                                        "shed")[decision]
                    if self._step_ctxs is not None:
                        self._step_ctxs.append(ctx)
                break
        return q, v, b, qd, served

    def set_plan(self, plan) -> None:
        """Latch a knob plan (``repro.control.plan.KnobPlan`` or None) for
        subsequent steps. Host-side only: takes effect on the next dispatch."""
        if plan is not None:
            plan.validate(self.cfg)
        self._plan = plan

    @property
    def plan(self):
        return self._plan

    # -- load-aware fused="auto" dispatch ------------------------------------

    def _observe_path_mix(self, path, n_valid) -> None:
        """Fold one (host-resident) step's full-path fraction into the EWMA.

        ``path`` is the step's [S, N_max] path trace, ``n_valid`` the [S]
        valid counts; pad lanes report bypass, so the full count needs no
        masking. Called by :meth:`_fold_telemetry` (sync engine) or the
        async collector, whichever owns host-side telemetry."""
        nv = int(np.sum(n_valid))
        if nv:
            f = float(np.sum(np.asarray(path) == PATH_FULL)) / nv
            self._full_ewma += AUTO_ALPHA * (f - self._full_ewma)

    # -- externalized session state (write-through snapshots) ----------------

    def _snap_meta(self) -> dict:
        """Host-side metadata stamped into every snapshot: the engine
        family, the auto dispatcher's path-mix EWMA (so a warm-started
        engine resumes load-aware dispatch where the dead one left off),
        and the latched knob plan, if any."""
        meta = {"engine": self._ENGINE, "full_ewma": float(self._full_ewma)}
        if self._plan is not None:
            meta["plan"] = {"banks": int(self._plan.banks),
                            "planes": int(self._plan.planes)}
        return meta

    def _collect_snaps(self, served):
        """Advance served-window counts and slice snapshot rows for streams
        that hit the ``snapshot_every`` cadence this step.

        Called right after ``_dispatch`` (the state already points at the
        post-step arrays), under the async engine's lock. The slices are
        *lazy device views* — materialization to host (and the store write)
        happens on the deferred telemetry fold (sync) or in the collector
        after ``block_until_ready`` (async), so the dispatcher never blocks
        on a snapshot."""
        from . import state_store as ss
        snaps = []
        for stream_id, slot, _extra in served:
            n = self._served_count.get(stream_id, 0) + 1
            self._served_count[stream_id] = n
            if n % self._snapshot_every == 0:
                snaps.append(ss.snapshot_rows(
                    self._state, slot, stream_id, n, self._snap_meta()))
        return snaps

    def _fold_one(self, tel, rec, ctxs=None, snaps=None) -> None:
        """Move one backlogged step's telemetry to host and consume it:
        the auto dispatcher's path-mix EWMA, the observer's metric digest +
        flight-record completion (``rec`` is the step's open flight record,
        or None), when the step was traced — completing its windows'
        contexts with the resolved plan/lowering off the same digest — and
        any pending state-store snapshots (materialized + written here,
        off the dispatch path)."""
        tel_h = jax.tree_util.tree_map(np.asarray, tel)
        if self._auto:
            self._observe_path_mix(tel_h.path, tel_h.n_valid)
        digest = None
        if self._obs is not None:
            digest = self._obs.observe_step(tel_h, rec)
        if ctxs:
            if digest is None:
                digest = telemetry_digest(tel_h)
            self._trace_finish(ctxs, rec, digest)
        if snaps:
            from . import state_store as ss
            memo = {}  # one host transfer per stacked leaf per fold batch
            for pending in snaps:
                self._store.put(ss.materialize_snapshot(pending, memo))

    def _trace_finish(self, ctxs, rec, digest) -> None:
        """Complete one step's trace contexts: stamp the resolved plan and
        lowering (read back off the step's telemetry digest — the same
        source the flight replay bit-matches against the governor's plan
        log), link the flight step index, embed the per-window dicts into
        the flight record under ``"trace"``, and retire the contexts into
        the tracer ring."""
        plan = {"banks": digest.get("banks"), "planes": digest.get("planes")}
        if rec is not None:
            gov = rec.get("governor") or {}
            if gov.get("level") is not None:
                plan["level"] = gov["level"]
        lowering = {"fused": digest.get("fused"),
                    "decide": digest.get("decide"),
                    "bucket_tier": digest.get("bucket_tier")}
        step = rec.get("step") if rec is not None else None
        for ctx in ctxs:
            ctx.step = step
            ctx.plan = plan
            ctx.lowering = lowering
            self._tracer.complete(ctx)
        if rec is not None:
            rec["trace"] = [ctx.to_dict() for ctx in ctxs]

    def _fold_telemetry(self) -> None:
        """Sync-engine EWMA feed: fold telemetry of steps that are at
        least one dispatch old. The newest entry stays in the backlog —
        reading it here would block on the step that may still be running
        on-device, serializing the host against the device every step;
        leaving one in flight preserves the dispatch/compute overlap
        (double buffering). The async engine overrides this with a no-op —
        its collector thread feeds :meth:`_observe_path_mix` from already
        host-resident traces without ever touching the dispatcher."""
        while len(self._tel_backlog) > 1:
            self._fold_one(*self._tel_backlog.popleft())

    def flush_telemetry(self) -> None:
        """Fold *every* backlogged step, including the newest (blocks on
        any step still executing). Call before reading summaries or
        spilling the flight recorder — otherwise up to one step's
        telemetry is still deferred by the double-buffering contract."""
        while self._tel_backlog:
            self._fold_one(*self._tel_backlog.popleft())

    def _resolve_fused(self):
        """(fused, bucket_cap, decide) for the next dispatch.

        Pinned modes pass straight through. In auto mode the predicted
        full-path rows (path-mix EWMA x total lanes, padded by
        ``AUTO_HEADROOM``) round up to a ``core.policy.bucket_ladder``
        tier: a tier below full capacity dispatches the compact lowering,
        full capacity falls back to the lowering-appropriate hoisted
        default (compaction would save nothing). The executable family
        stays bounded at ladder x plan — the recompile-guard test pins it.
        The engine's ``decide`` knob rides along unchanged: whichever
        decide-pass lowering was pinned at construction (None = batched)
        is what an auto-picked compact step runs with.
        """
        if not self._auto:
            return self._fused, self._bucket_cap, self._decide
        self._fold_telemetry()
        n_rows = self.n_slots * self.cfg.N_max
        want = int(np.ceil(self._full_ewma * n_rows * AUTO_HEADROOM))
        tier = policy.bucket_tier(n_rows, want)
        if tier >= n_rows:
            return None, None, self._decide  # hoisted default, no decide pass
        return "compact", tier, self._decide

    @property
    def full_path_ewma(self) -> float:
        """The auto dispatcher's current full-path-fraction estimate."""
        return self._full_ewma

    def _dispatch(self, q, v, b, qd):
        """Launch one batched step (asynchronously) and advance the state."""
        batch = StreamBatch(
            q_packed=jnp.asarray(q), valid=jnp.asarray(v),
            boxes=jnp.asarray(b), queue_depth=jnp.asarray(qd),
        )
        fused, bucket_cap, decide = self._resolve_fused()
        self._last_resolved = (fused, bucket_cap, decide)
        self._state, out, tel = self._step(
            self._state, self.im, batch, self.cfg, serial=self._serial,
            plan=self._plan, fused=fused, bucket_cap=bucket_cap,
            decide=decide,
        )
        return out, tel

    def step(self) -> Dict[object, tuple[WindowOutput, WindowTelemetry]]:
        """Drain one window per busy slot through the batched step."""
        # traced steps open a trace_scope around the assemble/dispatch
        # spans: _assemble populates step_ctxs as it admits windows, and
        # each span stamps its interval onto them at exit
        # chaos injection: the sync engine plays both worker roles inside
        # step() — "dispatcher" fires before assemble, "collector" after
        # the telemetry fold (mirroring where the async threads would die)
        if self._fault is not None:
            self._fault.maybe_fire("dispatcher", self.stats.steps)
        step_ctxs = None
        scope = NULL_SPAN
        if self._tracer is not None:
            step_ctxs = self._step_ctxs = []
            scope = trace_scope(step_ctxs)
        try:
            with scope:
                with self._sp_assemble:
                    q, v, b, qd, served = self._assemble()
                if not served:  # idle engine: skip the no-op device step
                    return {}
                with self._sp_dispatch:
                    out, tel = self._dispatch(q, v, b, qd)
        finally:
            self._step_ctxs = None
        self.stats.steps += 1
        self.stats.windows += len(served)
        self.stats.pad_slots += self.n_slots - len(served)
        snaps = self._collect_snaps(served) \
            if self._store is not None else None

        if self._auto or self._obs is not None or self._tracer is not None \
                or self._store is not None:
            rec = None
            if self._obs is not None:
                rec = self._obs.on_dispatch(
                    len(served), self.n_slots - len(served),
                    requested=self._last_resolved, plan=self._plan,
                    full_ewma=self._full_ewma if self._auto else None)
                if rec is not None and self._tracer is not None:
                    rec["ts_us"] = now_us()
                    rec["queue_depth"] = int(qd.max())
            # deferred fold: this step's telemetry enters the backlog, and
            # only entries at least one dispatch old are consumed now
            self._tel_backlog.append((tel, rec, step_ctxs, snaps))
            with self._sp_observe:
                self._fold_telemetry()

        if self._fault is not None:
            self._fault.maybe_fire("collector", self.stats.steps)

        results = {}
        for stream_id, slot, _extra in served:
            results[stream_id] = (
                jax.tree_util.tree_map(lambda x: x[slot], out),
                jax.tree_util.tree_map(lambda x: x[slot], tel),
            )
        return results

    def drain(self) -> Dict[object, list]:
        """Step until every backlog is empty; per-stream result lists."""
        acc: Dict[object, list] = {sid: [] for sid in self._slot_of}
        while self.busy:
            for sid, res in self.step().items():
                acc[sid].append(res)
        return acc

    def sync(self) -> None:
        """Block until all dispatched steps have executed on the device.

        Step results are dispatched asynchronously; timing code must call
        this before reading the clock."""
        jax.block_until_ready(self._state.cache.age)

    def summary(self) -> Dict[str, float]:
        """Engine counters as a flat dict (flushes deferred telemetry so
        the observer's numbers cover every dispatched step)."""
        self.flush_telemetry()
        s = dataclasses.asdict(self.stats)
        s["occupancy"] = self.stats.occupancy
        if self._auto:
            s["full_path_ewma"] = self._full_ewma
        return s

    def warmup(self) -> None:
        """Compile the batched step outside any timed region.

        Runs one all-pad step (a state no-op: every lane takes the pad
        branch) and discards the result; stats are not touched."""
        zero = StreamBatch(
            q_packed=jnp.asarray(np.broadcast_to(
                self._q0, (self.n_slots,) + self._q0.shape)),
            valid=jnp.asarray(np.broadcast_to(
                self._v0, (self.n_slots,) + self._v0.shape)),
            boxes=jnp.asarray(np.broadcast_to(
                self._b0, (self.n_slots,) + self._b0.shape)),
            queue_depth=jnp.zeros((self.n_slots,), jnp.int32),
        )
        fused, bucket_cap, decide = self._resolve_fused()
        out = self._step(self._state, self.im, zero, self.cfg,
                         serial=self._serial, plan=self._plan,
                         fused=fused, bucket_cap=bucket_cap, decide=decide)
        jax.block_until_ready(out[1].scores)
