"""Supervised serving: engine restart, warm-start re-admission, replay.

The training side has had checkpoint/restart supervision since the seed
(``runtime/fault.py``); this is its serving analogue. A
:class:`ServeSupervisor` wraps either engine family behind the same
admit/submit/flush surface and turns worker death from a terminal event
into a bounded recovery:

1. **Detect** — any engine failure surfaces as a typed
   :class:`~repro.runtime.fault.EngineDead` (cause-carrying, in-flight
   count at death). Pending futures fail with it, never with a bare
   RuntimeError, so clients distinguish crash (replayable) from
   :class:`~repro.serving.deadline.WindowShed` (admission policy).
2. **Restart** — the supervisor rebuilds a fresh engine via the caller's
   ``factory`` under exponential backoff (``backoff_s * 2**(n-1)``,
   capped), bounded by ``max_restarts``.
3. **Warm-start re-admission** — every live stream re-admits into the new
   engine with its cache rows, task weights and ``acc_tag``s restored
   from the :class:`~repro.serving.state_store.StateStore` snapshot the
   old engine wrote through; the engine-level path-mix EWMA restores from
   the newest snapshot's meta, so the auto-dispatch lowering choice does
   not reset to cold-cache pessimism.
4. **Replay** — the supervisor journals every submitted window until a
   store snapshot covers it. On recovery, journaled windows *after* the
   snapshot re-run in submission order: already-resolved ones rebuild the
   cache state silently (their outer futures stay resolved; shed windows
   are skipped — they never advanced state), unresolved ones re-dispatch
   into their original futures. With snapshot cadence 1 no silent re-runs
   are needed and replayed outputs are bit-identical to a fault-free run;
   with coarser cadences the re-run prefix restores bit-identity as long
   as admission control cannot re-decide a replayed window (tracker off,
   or generous budgets) — see docs/robustness.md.
5. **Crash-loop breaker** — ``breaker_restarts`` deaths inside
   ``breaker_window_s`` trips graceful degradation: the supervisor
   latches a cheap :class:`~repro.control.plan.KnobPlan` (the bottom of
   ``control.governor.build_ladder`` unless ``degrade_plan`` overrides)
   on the rebuilt engine, trading accuracy headroom for survival — the
   same move the governor makes under deadline pressure, triggered by
   instability instead of slack. Engines owned by a live governor keep
   their governor (the breaker then only records the trip).

Observability: ``torr_engine_restarts_total``,
``torr_windows_replayed_total``, a ``torr_recovery_duration_seconds``
histogram, and ``engine_crash`` / ``engine_recovered`` epoch events in
the flight recorder (rendered as instant markers in the Perfetto trace).
The counters reconcile exactly with the flight events — asserted in
tests/test_fault_serving.py.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from concurrent.futures import Future
from typing import Callable, Dict, List, Optional

import numpy as np

from ..runtime.fault import EngineDead
from .async_engine import AsyncStreamEngine
from .deadline import WindowShed
from .state_store import StateStore

# window status in the replay journal
_PENDING, _DONE, _SHED = "pending", "done", "shed"


@dataclasses.dataclass
class _Window:
    seq: int                    # per-stream submission index (0-based)
    q: np.ndarray
    valid: np.ndarray
    boxes: np.ndarray
    outer: Future
    status: str = _PENDING


@dataclasses.dataclass
class _Stream:
    sid: object
    task_w: np.ndarray
    next_seq: int = 0
    journal: collections.deque = dataclasses.field(
        default_factory=collections.deque)
    # sync engines return results positionally (FIFO per slot, no futures):
    # one entry per engine-submitted window, in submission order — the
    # _Window a result resolves, or None for a silent warm-start re-run
    # whose output is discarded. Rebuilt from scratch on every recovery.
    expect: collections.deque = dataclasses.field(
        default_factory=collections.deque)


class ServeSupervisor:
    """Crash-supervised facade over a (re-buildable) stream engine.

    ``factory()`` must return a *fresh* engine each call, wired to the
    same :class:`StateStore` (and snapshot cadence) the supervisor reads
    on recovery; the supervisor owns admit/retire bookkeeping, so the
    factory must return an engine with no admitted streams.
    """

    def __init__(
        self,
        factory: Callable[[], object],
        store: StateStore,
        *,
        max_restarts: int = 5,
        backoff_s: float = 0.02,
        backoff_cap_s: float = 1.0,
        breaker_restarts: int = 3,
        breaker_window_s: float = 30.0,
        degrade_plan=None,
        metrics=None,
        flight=None,
        clock=time.monotonic,
        sleep=time.sleep,
    ):
        self._factory = factory
        self.store = store
        self.max_restarts = max_restarts
        self._backoff_s = backoff_s
        self._backoff_cap_s = backoff_cap_s
        self._breaker_restarts = breaker_restarts
        self._breaker_window_s = breaker_window_s
        self._degrade_plan = degrade_plan
        self._flight = flight
        self._clock = clock
        self._sleep = sleep
        self.restarts = 0
        self.windows_replayed = 0
        self.windows_rerun = 0
        self.degraded = False
        # lock-free health flags: the gateway fast-fails requests on
        # `recovering` without queueing threads on self._lock, and
        # `terminal` marks a supervisor past max_restarts
        self.recovering = False
        self.terminal = False
        self._recent_crashes: collections.deque = collections.deque()
        self._streams: Dict[object, _Stream] = {}
        self._lock = threading.RLock()
        self._dead: Optional[EngineDead] = None  # flagged by callbacks
        self._epoch = 0   # bumped per rebuild; stale callbacks are ignored
        self._m_restarts = self._m_replayed = self._h_recovery = None
        self._m_dropped = None
        if metrics is not None:
            from ..obs.metrics import LATENCY_BUCKETS_S
            self._m_restarts = metrics.counter(
                "torr_engine_restarts_total",
                "Supervised engine rebuilds after worker death.")
            self._m_replayed = metrics.counter(
                "torr_windows_replayed_total",
                "Unresolved in-flight windows re-dispatched after a "
                "restart.")
            self._h_recovery = metrics.histogram(
                "torr_recovery_duration_seconds",
                "Crash detection to replay-complete recovery latency.",
                buckets=LATENCY_BUCKETS_S)
            self._m_dropped = metrics.counter(
                "torr_telemetry_dropped_total",
                "Observed steps/windows lost before telemetry was folded.")
        self.engine = factory()
        self._async = isinstance(self.engine, AsyncStreamEngine)

    # -- stream lifecycle ----------------------------------------------------

    def admit(self, stream_id, task_w) -> int:
        """Admit a stream — warm-starting it if the store already holds a
        snapshot (a previous *process* served it and died: cross-process
        resume). The journal's sequence numbers continue from the
        snapshot's ``window_seq``, so the caller must skip that many
        already-served windows of its (deterministic) input stream."""
        with self._lock:
            self._heal_if_dead()
            task_w = np.asarray(task_w, np.float32)
            snap = self.store.get(stream_id)
            slot = self._call_engine(
                lambda: self.engine.admit(stream_id, task_w, snapshot=snap))
            rec = _Stream(sid=stream_id, task_w=task_w)
            if snap is not None:
                rec.next_seq = int(snap.window_seq)
            self._streams[stream_id] = rec
            return slot

    def retire(self, stream_id) -> None:
        """Retire a stream cleanly: slot freed, session state deleted."""
        with self._lock:
            self._heal_if_dead()
            self._streams.pop(stream_id, None)
            try:
                self.engine.retire(stream_id)
            except EngineDead:
                pass    # the rebuilt engine will simply not re-admit it
            self.store.delete(stream_id)

    def submit(self, stream_id, q_packed, valid, boxes) -> Future:
        """Enqueue one window; the returned future survives engine death —
        it resolves once the window is served (possibly by a rebuilt
        engine) or fails with ``WindowShed`` / terminal ``EngineDead``."""
        with self._lock:
            self._heal_if_dead()
            rec = self._streams[stream_id]
            win = _Window(
                seq=rec.next_seq,
                q=np.asarray(q_packed, np.uint32),
                valid=np.asarray(valid, bool),
                boxes=np.asarray(boxes, np.float32),
                outer=Future(),
            )
            rec.next_seq += 1
            rec.journal.append(win)
            self._call_engine(
                lambda: self._submit_inner(stream_id, rec, win))
            return win.outer

    def flush(self, timeout: float | None = None) -> None:
        """Serve until every submitted window has resolved, recovering
        through any number of worker deaths up to ``max_restarts``."""
        deadline = None if timeout is None else self._clock() + timeout
        while True:
            try:
                if self._async:
                    left = (None if deadline is None
                            else max(deadline - self._clock(), 0.0))
                    self.engine.flush(timeout=left)
                else:
                    self._drive_sync()
            except EngineDead as e:
                with self._lock:
                    self._recover(e)
                continue
            with self._lock:
                if self._dead is not None:
                    self._heal_if_dead()
                    continue
                if self._n_pending() == 0:
                    return
            # pending windows but a clean, idle engine: a replay handed to
            # the engine is still settling — yield and re-enter the drain
            self._sleep(0.001)

    def close(self, drain: bool = True) -> None:
        if drain:
            self.flush()
        if self._async:
            try:
                self.engine.close(drain=False)
            except EngineDead:
                pass

    def __enter__(self) -> "ServeSupervisor":
        return self

    def __exit__(self, *exc) -> None:
        self.close(drain=exc == (None, None, None))

    # -- engine call guard ---------------------------------------------------

    def _call_engine(self, fn):
        """Run one engine call, recovering (and retrying) on EngineDead."""
        while True:
            try:
                return fn()
            except EngineDead as e:
                self._recover(e)

    def _heal_if_dead(self) -> None:
        if self._dead is not None:
            dead, self._dead = self._dead, None
            self._recover(dead)

    def heal(self) -> None:
        """Run any pending recovery *now*. The engine's death is only
        noticed inside submit/admit/flush; a network front with no
        traffic would otherwise sit on a dead engine until the next
        request pays the whole recovery latency — the gateway's pump
        thread calls this instead. Raises the terminal
        :class:`EngineDead` once ``max_restarts`` is exhausted."""
        with self._lock:
            self._heal_if_dead()

    # -- health (lock-free: read by the gateway's hot path) ------------------

    def health(self) -> dict:
        """Readiness snapshot for ``/readyz`` and gateway fast-fail."""
        return {
            "ready": not self.recovering and not self.terminal,
            "recovering": self.recovering,
            "terminal": self.terminal,
            "restarts": self.restarts,
            "degraded": self.degraded,
        }

    def retry_after_s(self) -> float:
        """Recovery-aware client backoff: the next restart's backoff
        sleep plus replay headroom — what a 503 during recovery carries
        as its Retry-After."""
        n = min(self.restarts + 1, 16)
        return min(self._backoff_s * (2.0 ** (n - 1)),
                   self._backoff_cap_s) + 0.05

    def _n_pending(self) -> int:
        return sum(1 for rec in self._streams.values()
                   for w in rec.journal if w.status == _PENDING)

    # -- submission plumbing -------------------------------------------------

    def _submit_inner(self, stream_id, rec: _Stream, win: _Window) -> None:
        if self._async:
            fut = self.engine.submit(stream_id, win.q, win.valid, win.boxes)
            fut.add_done_callback(
                lambda f, w=win, r=rec, e=self._epoch:
                self._on_done(r, w, f, e))
        else:
            self.engine.submit(stream_id, win.q, win.valid, win.boxes)
            rec.expect.append(win)

    def _on_done(self, rec: _Stream, win: _Window, fut: Future,
                 epoch: int = 0) -> None:
        """Inner-future resolution (collector thread). Engine death and
        cancellation leave the window pending for replay; everything else
        propagates to the caller-facing outer future. ``epoch`` is the
        engine generation that issued the inner future: an abandoned
        engine's collector may deliver late — its results are accepted
        only while the window is still pending (they are bit-identical to
        what the replay will produce), and its death flags are ignored so
        a stale crash can't restart a healthy replacement."""
        if fut.cancelled():
            return
        exc = fut.exception()
        if isinstance(exc, EngineDead):
            with self._lock:
                if epoch == self._epoch and self._dead is None:
                    self._dead = exc
            return
        with self._lock:
            if win.status != _PENDING:
                return  # duplicate delivery (abandoned engine vs replay)
            win.status = _SHED if isinstance(exc, WindowShed) else _DONE
            self._trim(rec)
        self._deliver(win, fut.result() if exc is None else None, exc)

    def _deliver(self, win: _Window, result, exc) -> None:
        """Resolve the caller-facing future, tolerating a gateway-side
        cancellation (client disconnected mid-flight): the window's
        state advance is kept — only the delivery is dropped, accounted
        in ``torr_telemetry_dropped_total``."""
        try:
            if exc is None:
                win.outer.set_result(result)
            else:
                win.outer.set_exception(exc)
        except BaseException:   # cancelled outer: InvalidStateError
            if self._m_dropped is not None:
                self._m_dropped.inc()

    def _trim(self, rec: _Stream) -> None:
        """Drop the journal prefix that is both resolved and covered by a
        store snapshot — those windows can never need replay."""
        if not rec.journal:
            return
        covered = self.store.latest_seq(rec.sid)
        while rec.journal and rec.journal[0].status != _PENDING \
                and rec.journal[0].seq < covered:
            rec.journal.popleft()

    # -- sync drive ----------------------------------------------------------

    def _drive_sync(self) -> None:
        """Step the sync engine until its backlog drains, resolving outer
        futures per served window; any step-time failure surfaces as a
        typed EngineDead for the shared recovery path."""
        import jax

        eng = self.engine
        try:
            while eng.busy:
                results = eng.step()
                with self._lock:
                    for sid, out_tel in results.items():
                        rec = self._streams.get(sid)
                        if rec is None:
                            continue
                        win = rec.expect.popleft() if rec.expect else None
                        if win is None or win.status != _PENDING:
                            continue    # a silent warm-start re-run
                        win.status = _DONE
                        self._trim(rec)
                        self._deliver(win, jax.tree_util.tree_map(
                            np.asarray, out_tel), None)
            eng.flush_telemetry()  # fold deferred snapshots/telemetry through
        except EngineDead:
            raise
        except Exception as e:
            raise EngineDead(cause=e, inflight=self._n_pending(),
                             thread="dispatcher") from e

    # -- recovery ------------------------------------------------------------

    def _recover(self, dead: EngineDead) -> None:
        """Rebuild the engine, warm-start every stream, replay the journal.

        Caller must hold the lock (or be the only thread, pre-start)."""
        t0 = self._clock()
        self.restarts += 1
        self._dead = None
        self.recovering = True
        try:
            self._recover_locked(dead, t0)
        finally:
            self.recovering = False

    def _recover_locked(self, dead: EngineDead, t0: float) -> None:
        if self._m_restarts is not None:
            self._m_restarts.inc()
        if self._flight is not None:
            self._flight.record(
                event="engine_crash", ts_us=_now_us(),
                cause=f"{type(dead.cause).__name__}: {dead.cause}"
                if dead.cause is not None else None,
                thread=dead.thread, inflight=dead.inflight,
                restarts=self.restarts)
        if self.restarts > self.max_restarts:
            self.terminal = True
            self._fail_pending(dead)
            raise dead
        # crash-loop breaker bookkeeping (before the backoff sleep so the
        # window measures crash arrivals, not our own sleeps)
        self._recent_crashes.append(t0)
        while self._recent_crashes and \
                t0 - self._recent_crashes[0] > self._breaker_window_s:
            self._recent_crashes.popleft()
        trip = len(self._recent_crashes) >= self._breaker_restarts
        n = min(self.restarts, 16)
        self._sleep(min(self._backoff_s * (2.0 ** (n - 1)),
                        self._backoff_cap_s))
        old, self.engine = self.engine, None
        if self._async and old is not None:
            try:
                # stop WITHOUT joining: a mid-delivery collector may be
                # blocked on self._lock inside _on_done — close()'s joins
                # would deadlock here. Its late deliveries are handled by
                # the epoch/status guards in _on_done.
                old.abandon()
            except BaseException:   # noqa: BLE001 — old engine is garbage
                pass
        self._epoch += 1
        self.engine = self._factory()
        self._async = isinstance(self.engine, AsyncStreamEngine)
        if trip and not self.degraded:
            self.degraded = True
            self._apply_degrade()
        elif self.degraded:
            self._apply_degrade()   # keep the cheap plan across rebuilds
        n_replayed = n_rerun = 0
        full_ewma = None
        for sid, rec in self._streams.items():
            snap = self.store.get(sid)
            self.engine.admit(sid, rec.task_w, snapshot=snap)
            base = snap.window_seq if snap is not None else 0
            if snap is not None and "full_ewma" in snap.meta:
                full_ewma = snap.meta["full_ewma"]
            rec.expect.clear()  # dead engine's positional results are gone
            # windows at or before the snapshot boundary are fully covered
            while rec.journal and rec.journal[0].seq < base \
                    and rec.journal[0].status != _PENDING:
                rec.journal.popleft()
            for win in rec.journal:
                if win.seq < base and win.status != _PENDING:
                    continue        # resolved & snapshotted (mixed prefix)
                if win.status == _SHED:
                    continue        # never advanced state: skip on replay
                if win.status == _DONE:
                    # silent re-run: rebuilds cache state between the
                    # snapshot boundary and the crash; output discarded
                    n_rerun += 1
                    self.engine.submit(sid, win.q, win.valid, win.boxes)
                    if not self._async:
                        rec.expect.append(None)
                else:
                    n_replayed += 1
                    self._submit_inner(sid, rec, win)
        if full_ewma is not None:
            self.engine._full_ewma = float(full_ewma)
        if self._async:
            # a paused factory engine must be started here — and only
            # after the replay submissions above, so the rebuilt
            # dispatcher sees the full replay backlog at once (the same
            # drain schedule a fault-free run would have used)
            self.engine.start()
        self.windows_replayed += n_replayed
        self.windows_rerun += n_rerun
        dur = self._clock() - t0
        if self._m_replayed is not None and n_replayed:
            self._m_replayed.inc(n_replayed)
        if self._h_recovery is not None:
            self._h_recovery.observe(dur)
        if self._flight is not None:
            self._flight.record(
                event="engine_recovered", ts_us=_now_us(),
                duration_s=dur, replayed=n_replayed, rerun=n_rerun,
                restarts=self.restarts, degraded=self.degraded)

    def _apply_degrade(self) -> None:
        """Crash-loop graceful degradation: latch a cheap plan (precision/
        bank-reduced, relaxed taus → bypass-heavy admission) on the fresh
        engine. Governor-owned engines keep their governor — set_plan is
        refused there by design, so the trip is record-only."""
        if getattr(self.engine, "_governor", None) is not None:
            return
        plan = self._degrade_plan
        if plan is None:
            from ..control.governor import build_ladder
            plan = build_ladder(self.engine.cfg)[-1]
        self.engine.set_plan(plan)

    def _fail_pending(self, dead: EngineDead) -> None:
        for rec in self._streams.values():
            for win in rec.journal:
                if win.status == _PENDING and not win.outer.done():
                    win.status = _DONE
                    win.outer.set_exception(dead)

    # -- telemetry -----------------------------------------------------------

    def summary(self) -> dict:
        with self._lock:
            return {
                "restarts": self.restarts,
                "windows_replayed": self.windows_replayed,
                "windows_rerun": self.windows_rerun,
                "degraded": self.degraded,
                "recovering": self.recovering,
                "terminal": self.terminal,
                "pending": self._n_pending(),
                "streams": len(self._streams),
            }


def _now_us() -> float:
    from ..obs.trace import now_us
    return now_us()


def recovery_events(records) -> List[dict]:
    """The crash/recovery epoch events of a flight record stream, in
    order — the reconciliation source for ``torr_engine_restarts_total``
    and ``torr_windows_replayed_total``."""
    return [r for r in records
            if r.get("event") in ("engine_crash", "engine_recovered")]
