"""Gateway wire protocol: framing, strict validation, typed rejections.

The network tier (:mod:`repro.serving.gateway`) speaks JSON over
HTTP/1.1. This module owns everything about the *bytes* so the gateway
can stay about *connections*: array encoding, request validation, the
error taxonomy, and the client-visible retry contract. Every invalid
input maps to a :class:`ProtocolError` carrying an HTTP status and a
machine-readable ``reason`` token — the gateway turns those into
responses, so a malformed frame can never surface as a worker exception.

Wire shapes
-----------
Arrays travel as ``{"dtype", "shape", "data": <base64>}`` — the exact
encoding :mod:`repro.serving.state_store` uses for snapshots, so a
window captured off the wire replays against a store snapshot without a
re-encode. Decoding is strict: the declared dtype and shape must match
the schema expected for that field (a client cannot smuggle an f64 query
or a [N, 5] box tensor past validation), and the payload length must
equal ``prod(shape) * itemsize`` exactly.

Requests
--------
``POST /v1/session``   ``{"tenant", "stream", "task", "rt"?}``
``POST /v1/window``    ``{"session", "seq", "q", "valid", "boxes",
                         "deadline_ms"?}``
``DELETE /v1/session/<tenant>/<stream>``

Identifiers are ``[A-Za-z0-9_.-]{1,64}``; a session id is
``"<tenant>/<stream>"``. ``seq`` is the client's per-session submission
index (0-based, strictly sequential) — the idempotency key the gateway's
retry/dedupe contract is built on (docs/gateway.md).

Error contract
--------------
400 ``bad_request``/``bad_frame`` malformed JSON, schema or dtype errors
408 ``slow_client``   header/body arrived slower than the read deadline
409 ``out_of_order``/``seq_consumed`` sequence contract violations
413 ``too_large``     body over ``GatewayLimits.max_body_bytes``
429 ``rate_limit``/``tenant_quota``/``no_slot``/``shed`` + Retry-After
503 ``recovering``/``engine_dead``/``deadline``/``draining`` + Retry-After
"""
from __future__ import annotations

import base64
import binascii
import dataclasses
import json
import re
from typing import Optional

import numpy as np

PROTOCOL_VERSION = 1

_ID_RE = re.compile(r"^[A-Za-z0-9_.-]{1,64}$")

# client-visible reject reasons (the label set of
# torr_gateway_rejects_total — keep this closed and small)
REJECT_REASONS = (
    "bad_request", "bad_frame", "slow_client", "out_of_order",
    "seq_consumed", "too_large", "rate_limit", "tenant_quota", "no_slot",
    "shed", "recovering", "engine_dead", "deadline", "draining",
    "disconnect", "internal", "no_session", "session_exists", "conn_limit",
)


class ProtocolError(Exception):
    """A client-attributable failure with an HTTP status and retry hint.

    ``reason`` is one of :data:`REJECT_REASONS`; ``retry_after_s`` (when
    set) is surfaced as a ``Retry-After`` header so supervised clients
    back off instead of hammering."""

    def __init__(self, status: int, reason: str, detail: str = "",
                 retry_after_s: Optional[float] = None):
        assert reason in REJECT_REASONS, reason
        self.status = int(status)
        self.reason = reason
        self.detail = detail
        self.retry_after_s = retry_after_s
        super().__init__(f"{status} {reason}: {detail}")

    def body(self) -> dict:
        out = {"error": self.reason, "detail": self.detail}
        if self.retry_after_s is not None:
            out["retry_after_s"] = round(float(self.retry_after_s), 6)
        return out


# -- array wire format -------------------------------------------------------

def encode_array(a: np.ndarray) -> dict:
    """Encode a host array for the wire (state-store-compatible shape)."""
    a = np.ascontiguousarray(a)
    return {"dtype": str(a.dtype), "shape": list(a.shape),
            "data": base64.b64encode(a.tobytes()).decode("ascii")}


def decode_array(obj, *, dtype, shape, field: str) -> np.ndarray:
    """Strictly decode one wire array against its schema.

    The *declared* dtype/shape must equal the schema (no casts — an f64
    query is a client bug, not something to silently round), and the
    payload must hold exactly the right number of bytes."""
    if not isinstance(obj, dict):
        raise ProtocolError(400, "bad_frame",
                            f"{field}: expected an encoded array object")
    want_dtype = np.dtype(dtype)
    if obj.get("dtype") != str(want_dtype):
        raise ProtocolError(
            400, "bad_frame",
            f"{field}: dtype {obj.get('dtype')!r} != {want_dtype}")
    got_shape = obj.get("shape")
    if not isinstance(got_shape, list) or \
            [int(s) for s in got_shape] != [int(s) for s in shape]:
        raise ProtocolError(
            400, "bad_frame",
            f"{field}: shape {got_shape!r} != {list(shape)}")
    data = obj.get("data")
    if not isinstance(data, str):
        raise ProtocolError(400, "bad_frame", f"{field}: missing data")
    try:
        raw = base64.b64decode(data.encode("ascii"), validate=True)
    except (binascii.Error, UnicodeEncodeError) as e:
        raise ProtocolError(400, "bad_frame",
                            f"{field}: base64 decode failed ({e})") from e
    n_want = int(np.prod(shape, dtype=np.int64)) * want_dtype.itemsize
    if len(raw) != n_want:
        raise ProtocolError(
            400, "bad_frame",
            f"{field}: payload {len(raw)}B != expected {n_want}B")
    return np.frombuffer(raw, dtype=want_dtype).reshape(shape).copy()


# -- request schemas ---------------------------------------------------------

def _require(body: dict, key: str, typ, detail: str = ""):
    if not isinstance(body, dict):
        raise ProtocolError(400, "bad_request", "body must be a JSON object")
    if key not in body:
        raise ProtocolError(400, "bad_request", f"missing field {key!r}")
    v = body[key]
    # bool is an int subclass; an int field must still reject true/false
    if typ is int and isinstance(v, bool) or not isinstance(v, typ):
        raise ProtocolError(
            400, "bad_request",
            detail or f"field {key!r} must be {getattr(typ, '__name__', typ)}")
    return v


def parse_json_body(raw: bytes) -> dict:
    try:
        body = json.loads(raw.decode("utf-8"))
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise ProtocolError(400, "bad_request",
                            f"body is not valid JSON ({e})") from e
    if not isinstance(body, dict):
        raise ProtocolError(400, "bad_request", "body must be a JSON object")
    return body


def validate_id(value, field: str) -> str:
    if not isinstance(value, str) or not _ID_RE.match(value):
        raise ProtocolError(
            400, "bad_request",
            f"{field} must match [A-Za-z0-9_.-]{{1,64}}")
    return value


def session_id(tenant: str, stream: str) -> str:
    return f"{tenant}/{stream}"


def split_session_id(sid) -> tuple:
    if not isinstance(sid, str) or sid.count("/") != 1:
        raise ProtocolError(400, "bad_request",
                            "session must be '<tenant>/<stream>'")
    tenant, stream = sid.split("/", 1)
    return validate_id(tenant, "tenant"), validate_id(stream, "stream")


@dataclasses.dataclass(frozen=True)
class SessionOpen:
    tenant: str
    stream: str
    task: int
    rt: str


@dataclasses.dataclass(frozen=True)
class WindowRequest:
    session: str
    seq: int
    q: np.ndarray        # uint32 [N_max, words]
    valid: np.ndarray    # bool   [N_max]
    boxes: np.ndarray    # f32    [N_max, 4]
    deadline_s: Optional[float]   # per-request gateway wait budget


def validate_session_open(body: dict, n_tasks: int) -> SessionOpen:
    tenant = validate_id(_require(body, "tenant", str), "tenant")
    stream = validate_id(_require(body, "stream", str), "stream")
    task = _require(body, "task", int)
    if not 0 <= task < n_tasks:
        raise ProtocolError(400, "bad_request",
                            f"task {task} out of range [0, {n_tasks})")
    rt = body.get("rt", "RT-60")
    if rt not in ("RT-30", "RT-60"):
        raise ProtocolError(400, "bad_request",
                            "rt must be 'RT-30' or 'RT-60'")
    return SessionOpen(tenant=tenant, stream=stream, task=task, rt=rt)


def validate_window(body: dict, cfg) -> WindowRequest:
    sid = _require(body, "session", str)
    split_session_id(sid)
    seq = _require(body, "seq", int)
    if seq < 0:
        raise ProtocolError(400, "bad_request", "seq must be >= 0")
    q = decode_array(_require(body, "q", dict,
                              "field 'q' must be an encoded array"),
                     dtype=np.uint32, shape=(cfg.N_max, cfg.words),
                     field="q")
    valid = decode_array(_require(body, "valid", dict,
                                  "field 'valid' must be an encoded array"),
                         dtype=np.bool_, shape=(cfg.N_max,), field="valid")
    boxes = decode_array(_require(body, "boxes", dict,
                                  "field 'boxes' must be an encoded array"),
                         dtype=np.float32, shape=(cfg.N_max, 4),
                         field="boxes")
    if not np.isfinite(boxes).all():
        raise ProtocolError(400, "bad_frame",
                            "boxes: non-finite coordinates")
    deadline_s = None
    if "deadline_ms" in body:
        dl = body["deadline_ms"]
        if isinstance(dl, bool) or not isinstance(dl, (int, float)) \
                or not (0 < dl <= 600_000):
            raise ProtocolError(400, "bad_request",
                                "deadline_ms must be in (0, 600000]")
        deadline_s = float(dl) / 1e3
    return WindowRequest(session=sid, seq=seq, q=q, valid=valid,
                         boxes=boxes, deadline_s=deadline_s)


# -- response bodies ---------------------------------------------------------

def window_result_body(seq: int, wout) -> dict:
    """The served-window response: the decision payload (`best`) plus a
    digest of the full score tensor — the same ``scores_sha256`` the
    serve.py output ledger records, so wire responses and on-disk ledgers
    reconcile bit-for-bit (the chaos test's merged-output identity check
    diffs exactly these bodies)."""
    import hashlib
    scores = np.ascontiguousarray(np.asarray(wout.scores))
    return {
        "seq": int(seq),
        "best": np.asarray(wout.best).tolist(),
        "scores_sha256": hashlib.sha256(scores.tobytes()).hexdigest(),
    }


def config_body(cfg, n_tasks: int, limits) -> dict:
    return {
        "protocol": PROTOCOL_VERSION,
        "N_max": int(cfg.N_max),
        "words": int(cfg.words),
        "D": int(cfg.D),
        "M": int(cfg.M),
        "n_tasks": int(n_tasks),
        "limits": {
            "max_body_bytes": int(limits.max_body_bytes),
            "rate_per_s": float(limits.rate_per_s),
            "burst": int(limits.burst),
            "max_sessions_per_tenant": int(limits.max_sessions_per_tenant),
            "request_deadline_s": float(limits.request_deadline_s),
        },
        "rt": ["RT-30", "RT-60"],
    }
