"""TOOD evaluation pipelines: dense CLIP-proxy vs naive HDC vs TorR.

Three aligners over the same synthetic world (data.tood_synth):

  * ``dense``  — float cosine against class prototypes, task-weighted by the
    ground-truth relevance table (the iTaskCLIP-proxy upper baseline);
  * ``hdc``    — sign-projected queries, full XNOR-popcount scan every
    window, HDC graph-reasoner weights (the paper's "SNN + naive HDC"
    baseline: no caching, no delta, no bypass);
  * ``torr``   — the full cache-gated pipeline (repro.core.pipeline) with
    query cache, delta updates, aggressive bypass and D' gating.

Item-memory construction mirrors how task knowledge is distilled into HDC:
each concept code bundles its projected visual prototype with the task
hypervectors of the tasks it serves, weighted by relevance — so the
reasoner weights w_j = cos(g_P, h_j) genuinely *retrieve* the task-class
affinity rather than reading a lookup table.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from ..core import hdc, pipeline, reasoner
from ..core.item_memory import build_item_memory
from ..core.types import TorrConfig
from ..data import tood_synth as ts
from ..kernels import ops


@dataclasses.dataclass
class TorrSystem:
    cfg: TorrConfig
    R: np.ndarray             # [D, d] projection
    im: object                # ItemMemory
    task_w: np.ndarray        # [T, M] reasoner weights (precomputed)
    graph: reasoner.TaskGraph


def build_system(world: ts.World, cfg: TorrConfig, seed: int = 0) -> TorrSystem:
    key = jax.random.PRNGKey(seed)
    kR, kg, kc = jax.random.split(key, 3)
    M, d = world.prototypes.shape
    R = np.asarray(jax.random.normal(kR, (cfg.D, d)) / np.sqrt(d))

    graph = reasoner.init_task_graph(kg, cfg, n_tasks=world.relevance.shape[0])
    # g_P per task from its relation path (Hadamard chain)
    g = np.stack([
        np.asarray(reasoner.compose_path(graph, t,
                                         jnp.asarray(world.task_paths[t])))
        for t in range(world.relevance.shape[0])])

    # concept codes: bundle projected prototype + relevance-weighted task
    # hypervectors. Weights matter: sign() bundling is winner-take-all per
    # dim, so the prototype weight must stay comparable to the summed task
    # component or the reasoner retrieves nothing (1.5 : 1 keeps ~0.7
    # prototype correlation and ~0.25 task correlation).
    proj = np.sign(world.prototypes @ R.T)          # [M, D]
    proj[proj == 0] = 1
    rel = world.relevance                           # [T, M]
    acc = 1.5 * proj + (rel.T @ g)                  # [M, D]
    codes = np.where(acc >= 0, 1, -1).astype(np.int8)
    im = build_item_memory(jnp.asarray(codes), plane_total=cfg.bit_planes)

    task_w = np.stack([
        np.asarray(reasoner.task_weights(jnp.asarray(g[t]), im, cfg, cfg.B))
        for t in range(rel.shape[0])])
    return TorrSystem(cfg, R, im, task_w, graph)


# ---------------------------------------------------------------------------
# Pipelines: each returns per-frame proposal scores
# ---------------------------------------------------------------------------

def run_dense(world: ts.World, frames, task_id: int):
    """Float cosine x GT relevance (oracle baseline)."""
    protos = world.prototypes
    rel = world.relevance[task_id]
    out = []
    for f in frames:
        z = f.feats / (np.linalg.norm(f.feats, axis=1, keepdims=True) + 1e-9)
        s = z @ protos.T                          # [N, M]
        score = np.max(s * rel[None, :], axis=1)
        score[~f.valid] = -1e9
        out.append(score)
    return out


def run_naive_hdc(sys: TorrSystem, frames, task_id: int):
    """Full scan every window, reasoner always on, no reuse."""
    w = sys.task_w[task_id]
    codes = np.asarray(sys.im.bipolar, np.float32)   # [M, D]
    out = []
    for f in frames:
        q = np.sign(f.feats @ sys.R.T)
        q[q == 0] = 1
        s = (q @ codes.T) / sys.cfg.D                # [N, M]
        score = np.max(s * w[None, :], axis=1)
        score[~f.valid] = -1e9
        out.append(score)
    return out


def run_torr(sys: TorrSystem, frames, task_id: int, queue_depth: int = 0):
    """The cache-gated pipeline; returns (scores, telemetry list)."""
    cfg = sys.cfg
    task_w = jnp.asarray(sys.task_w[task_id])
    state = pipeline.init_state(cfg, task_w)
    step = jax.jit(pipeline.torr_window_step, static_argnames="cfg")

    out, telems = [], []
    R = jnp.asarray(sys.R)
    for f in frames:
        z = jnp.asarray(f.feats)
        # fused encode front-end: projection + sign + bit-pack in one kernel
        # (bit-identical to hdc.pack_bits(hdc.sign_project(z, R)))
        q = ops.encode_packed(z, R)
        state, res, tel = step(state, sys.im, q, jnp.asarray(f.valid),
                               jnp.asarray(f.boxes),
                               jnp.asarray(queue_depth, jnp.int32), cfg)
        score = np.array(jnp.max(res.scores, axis=1))
        score[~f.valid] = -1e9
        out.append(score)
        telems.append(jax.tree.map(np.asarray, tel))
    return out, telems


def evaluate_task(world, sys: TorrSystem, task_id: int, n_frames: int = 120,
                  seed: int = 0, difficulty: float = 0.55,
                  queue_depth: int = 0) -> dict:
    frames = ts.simulate_sequence(world, task_id, n_frames, seed,
                                  difficulty=difficulty,
                                  n_max=sys.cfg.N_max)
    boxes = [f.boxes for f in frames]
    gts = [f.gt_boxes for f in frames]

    dense = ts.average_precision(run_dense(world, frames, task_id), boxes, gts)
    naive = ts.average_precision(run_naive_hdc(sys, frames, task_id), boxes, gts)
    torr_scores, telems = run_torr(sys, frames, task_id, queue_depth)
    torr = ts.average_precision(torr_scores, boxes, gts)
    paths = np.concatenate([t.path for t in telems])
    return {
        "task": ts.TASKS[task_id],
        "ap_dense": 100 * dense,
        "ap_naive_hdc": 100 * naive,
        "ap_torr": 100 * torr,
        "path_mix": {
            "bypass": float(np.mean(paths == 0)),
            "delta": float(np.mean(paths == 1)),
            "full": float(np.mean(paths == 2)),
        },
        "telemetry": telems,
    }
