"""TorR HDC reranker as an LM serving layer (DESIGN.md §Arch-applicability).

Attaches the paper's associative aligner + graph reasoner to a decoder's
serve step: the pre-unembed hidden state is sign-projected to a query
hypervector, scored against a concept item memory, task-weighted
(s_hat = s * w), and folded into the logits as a bias. The query cache works
across *decode steps of the same sequence*: when consecutive hidden states
are similar (rho >= tau), cached concept scores are reused — the paper's
bypass path, measured by the returned telemetry.

For small vocabularies (MusicGen's 2048-entry codebooks) concepts map 1:1
to tokens; for large vocabularies an [M, V]-sparse concept->token map
projects concept scores onto the vocabulary.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..core import hdc
from ..core.item_memory import ItemMemory, random_item_memory
from ..core.types import TorrConfig


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class RerankerParams:
    R: jax.Array          # [D, d_model] projection
    task_w: jax.Array     # [M] reasoner weights for the active task
    concept_map: jax.Array | None   # [M, V] or None (identity, M == V)
    alpha: jax.Array      # [] logit-bias scale

    def tree_flatten(self):
        return ((self.R, self.task_w, self.concept_map, self.alpha), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class RerankerState:
    prev_q: jax.Array     # uint32 [B, D//32] previous step's query
    prev_s: jax.Array     # f32 [B, M] cached task-weighted scores
    valid: jax.Array      # bool [B]

    def tree_flatten(self):
        return ((self.prev_q, self.prev_s, self.valid), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)


def init_reranker(key: jax.Array, cfg: TorrConfig, d_model: int, vocab: int,
                  alpha: float = 1.0) -> tuple[RerankerParams, ItemMemory]:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    im = random_item_memory(k1, cfg)
    R = jax.random.normal(k2, (cfg.D, d_model)) / jnp.sqrt(d_model)
    # reasoner weights: g_P vs item memory (random task graph offline)
    g = hdc.random_hv(k3, (cfg.D,))
    task_w = jnp.einsum("d,md->m", g.astype(jnp.int32),
                        im.bipolar.astype(jnp.int32)).astype(jnp.float32) / cfg.D
    task_w = 1.0 + task_w  # multiplicative-style weighting around 1
    concept_map = None
    if vocab != cfg.M:
        concept_map = (jax.random.normal(k4, (cfg.M, vocab)) *
                       (jax.random.uniform(k4, (cfg.M, vocab)) < 0.02))
    return RerankerParams(R, task_w, concept_map, jnp.float32(alpha)), im


def init_state(cfg: TorrConfig, B: int) -> RerankerState:
    return RerankerState(
        prev_q=jnp.zeros((B, cfg.words), jnp.uint32),
        prev_s=jnp.zeros((B, cfg.M), jnp.float32),
        valid=jnp.zeros((B,), bool),
    )


def rerank_step(params: RerankerParams, state: RerankerState, im: ItemMemory,
                hidden: jax.Array, logits: jax.Array, cfg: TorrConfig,
                tau: float = 0.9):
    """One decode step. hidden: [B, d_model]; logits: [B, V].

    Returns (logits', state', telemetry{rho, bypassed}).
    """
    q = hdc.sign_project(hidden.astype(jnp.float32), params.R)
    qp = hdc.pack_bits(q)                                   # [B, W]
    ham = jnp.sum(jax.lax.population_count(
        jnp.bitwise_xor(qp, state.prev_q)).astype(jnp.int32), axis=-1)
    rho = jnp.where(state.valid, 1.0 - 2.0 * ham / cfg.D, -1.0)
    bypass = rho >= tau                                     # [B]

    # full path: XNOR-popcount scores vs item memory (Eq. 4) + reasoner
    dots = cfg.D - 2 * jnp.sum(jax.lax.population_count(
        jnp.bitwise_xor(qp[:, None, :], im.packed[None, :, :])
    ).astype(jnp.int32), axis=-1)                           # [B, M]
    s_full = dots.astype(jnp.float32) / cfg.D * params.task_w[None, :]
    s = jnp.where(bypass[:, None], state.prev_s, s_full)

    bias = s if params.concept_map is None else s @ params.concept_map
    logits = logits + params.alpha * bias
    new_state = RerankerState(prev_q=qp, prev_s=s,
                              valid=jnp.ones_like(state.valid))
    return logits, new_state, {"rho": rho, "bypassed": bypass}
