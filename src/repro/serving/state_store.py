"""Externalized per-stream session state for fault-tolerant serving.

TorR's per-stream value is *state*: the depth-K query cache (packed
prototypes, per-class score accumulators, plan tags, age/validity), the
stream's task-weight row, and the engine-level control EWMAs. Today that
state lives only inside an engine's stacked ``TorrState`` — a dead worker
discards it, and every re-admitted stream pays cold-cache full scans until
reuse re-establishes. This module pulls it out into a pluggable store so
stream slots survive their engine (and, file-backed, their process):

* :class:`StreamSnapshot` — one stream's externalizable state at a window
  boundary: the cache leaves as host numpy arrays, the task-weight row,
  the count of served windows the snapshot covers (``window_seq``), and a
  small ``meta`` dict (engine path-mix EWMA, latched plan, wall time).
* :class:`StateStore` — the interface: ``put``/``get``/``latest_seq``/
  ``delete``/``keys``/``reap``. ``get`` of a TTL-expired session returns
  None (and reaps it) — dead sessions leave no stale rows, the
  stateless-worker pattern.
* :class:`InMemoryStateStore` — dict-backed; the in-process supervisor's
  default (restart recovery inside one process).
* :class:`JsonlStateStore` — append-only JSONL, latest-record-wins, with
  fsync-per-put crash safety; a *process* can die (SIGKILL) and a fresh
  one warm-starts every stream from the file. ``compact()`` rewrites the
  log to one live record per stream.

Write-through is owned by the engines (``snapshot_every`` windows, from
the sync telemetry fold / the async collector — never the dispatch hot
path); recovery is owned by :class:`repro.serving.supervisor.
ServeSupervisor` and ``launch/serve.py``'s cross-process resume. Metrics
(optional): ``torr_state_store_writes_total`` /
``torr_state_store_restores_total`` / ``torr_state_store_reaped_total``.

Schema (``STATE_SCHEMA_VERSION``): cache leaves are stored by field name
(`packed`/`acc`/`acc_tag`/`out`/`topk_key`/`margin`/`age`/`valid`) with
dtype + shape, base64-raw in the JSONL encoding. Restore validates the
leaf set against the engine's ``CacheState`` so a schema drift fails
loudly instead of warm-starting garbage.
"""
from __future__ import annotations

import base64
import dataclasses
import json
import os
import threading
import time
from typing import Dict, Iterable, List, Optional

import numpy as np

STATE_SCHEMA_VERSION = 1

# CacheState leaf names, in tree_flatten order (query_cache.CacheState);
# pinned here so snapshots taken by one engine build restore into another
CACHE_FIELDS = ("packed", "acc", "acc_tag", "out", "topk_key", "margin",
                "age", "valid")


@dataclasses.dataclass
class StreamSnapshot:
    """One stream's externalized session state at a window boundary."""

    stream_id: str
    window_seq: int                 # served windows this snapshot covers
    cache: Dict[str, np.ndarray]    # CACHE_FIELDS -> host arrays
    task_w: np.ndarray              # f32 [M] reasoner weight row
    meta: Dict = dataclasses.field(default_factory=dict)

    def validate(self) -> "StreamSnapshot":
        missing = [f for f in CACHE_FIELDS if f not in self.cache]
        if missing:
            raise ValueError(
                f"snapshot for {self.stream_id!r} missing cache leaves "
                f"{missing}; schema v{STATE_SCHEMA_VERSION} expects "
                f"{CACHE_FIELDS}")
        return self

    # -- JSON round-trip (the JSONL store's record format) -------------------

    def to_record(self) -> dict:
        return {
            "v": STATE_SCHEMA_VERSION,
            "stream_id": self.stream_id,
            "window_seq": int(self.window_seq),
            "cache": {k: _encode_array(v) for k, v in self.cache.items()},
            "task_w": _encode_array(np.asarray(self.task_w)),
            "meta": self.meta,
        }

    @classmethod
    def from_record(cls, rec: dict) -> "StreamSnapshot":
        if rec.get("v") != STATE_SCHEMA_VERSION:
            raise ValueError(
                f"state-store schema v{rec.get('v')} != "
                f"v{STATE_SCHEMA_VERSION}")
        return cls(
            stream_id=rec["stream_id"],
            window_seq=int(rec["window_seq"]),
            cache={k: _decode_array(v) for k, v in rec["cache"].items()},
            task_w=_decode_array(rec["task_w"]),
            meta=rec.get("meta", {}),
        ).validate()


def _encode_array(a: np.ndarray) -> dict:
    a = np.ascontiguousarray(a)
    return {"dtype": str(a.dtype), "shape": list(a.shape),
            "data": base64.b64encode(a.tobytes()).decode("ascii")}


def _decode_array(d: dict) -> np.ndarray:
    raw = base64.b64decode(d["data"])
    return np.frombuffer(raw, dtype=np.dtype(d["dtype"])).reshape(
        d["shape"]).copy()


class StateStore:
    """Pluggable per-stream session-state store (TTL-reaped).

    ``ttl_s`` bounds how long a session outlives its last write: a crashed
    client that never retires leaves no immortal rows — ``reap()`` (called
    opportunistically by ``get``/``keys`` and explicitly by owners) drops
    sessions whose newest snapshot is older than the TTL. ``clock`` is
    injectable for deterministic tests. ``metrics`` optionally wires the
    ``torr_state_store_*`` counters.
    """

    def __init__(self, ttl_s: float | None = None, clock=time.monotonic,
                 metrics=None):
        self.ttl_s = ttl_s
        self._clock = clock
        self._lock = threading.Lock()
        self._snaps: Dict[str, StreamSnapshot] = {}
        self._stamp: Dict[str, float] = {}
        self._m_writes = self._m_restores = self._m_reaped = None
        if metrics is not None:
            self._m_writes = metrics.counter(
                "torr_state_store_writes_total",
                "Stream-state snapshots written through to the store.")
            self._m_restores = metrics.counter(
                "torr_state_store_restores_total",
                "Stream-state snapshots read back for warm-start.")
            self._m_reaped = metrics.counter(
                "torr_state_store_reaped_total",
                "Sessions dropped by TTL reaping.")

    # -- write ---------------------------------------------------------------

    def put(self, snap: StreamSnapshot) -> None:
        snap.validate()
        with self._lock:
            cur = self._snaps.get(snap.stream_id)
            if cur is not None and cur.window_seq > snap.window_seq:
                return  # stale write (an abandoned engine's last delivery
                #         racing its replacement) must not regress coverage
            self._put_locked(snap)
            self._stamp[snap.stream_id] = self._clock()
        if self._m_writes is not None:
            self._m_writes.inc()

    def _put_locked(self, snap: StreamSnapshot) -> None:
        self._snaps[snap.stream_id] = snap

    # -- read ----------------------------------------------------------------

    def get(self, stream_id: str) -> Optional[StreamSnapshot]:
        """Newest snapshot for the stream, or None (absent / TTL-expired)."""
        with self._lock:
            self._reap_locked()
            snap = self._snaps.get(stream_id)
        if snap is not None and self._m_restores is not None:
            self._m_restores.inc()
        return snap

    def latest_seq(self, stream_id: str) -> int:
        """``window_seq`` of the newest snapshot (0 = none / expired)."""
        with self._lock:
            self._reap_locked()
            snap = self._snaps.get(stream_id)
        return snap.window_seq if snap is not None else 0

    def keys(self) -> List[str]:
        with self._lock:
            self._reap_locked()
            return sorted(self._snaps)

    # -- lifecycle -----------------------------------------------------------

    def delete(self, stream_id: str) -> None:
        """Drop a retired session's state (idempotent)."""
        with self._lock:
            self._snaps.pop(stream_id, None)
            self._stamp.pop(stream_id, None)

    def reap(self, now: float | None = None) -> List[str]:
        """Drop TTL-expired sessions; returns the reaped stream ids."""
        with self._lock:
            return self._reap_locked(now)

    def _reap_locked(self, now: float | None = None) -> List[str]:
        if self.ttl_s is None:
            return []
        now = self._clock() if now is None else now
        dead = [sid for sid, ts in self._stamp.items()
                if now - ts > self.ttl_s]
        for sid in dead:
            self._snaps.pop(sid, None)
            self._stamp.pop(sid, None)
        if dead and self._m_reaped is not None:
            self._m_reaped.inc(len(dead))
        return sorted(dead)


class InMemoryStateStore(StateStore):
    """Dict-backed store: in-process supervised restart recovery."""


class JsonlStateStore(StateStore):
    """Append-only JSONL store: latest record per stream wins.

    Crash safety: each ``put`` appends one line, flushes, and (by default)
    fsyncs — a SIGKILLed process loses at most the write in progress, and
    a torn trailing line is skipped on load (the previous snapshot of that
    stream still restores). ``delete`` appends a tombstone. ``compact()``
    rewrites the log to one live record per stream via tmp+rename (the
    checkpoint manager's commit protocol).
    """

    def __init__(self, path: str | os.PathLike, ttl_s: float | None = None,
                 clock=time.monotonic, metrics=None, fsync: bool = True):
        super().__init__(ttl_s=ttl_s, clock=clock, metrics=metrics)
        self.path = os.fspath(path)
        self._fsync = fsync
        self._load()
        self._f = open(self.path, "a", encoding="utf-8")

    # -- persistence ---------------------------------------------------------

    def _load(self) -> None:
        if not os.path.exists(self.path):
            return
        with open(self.path, "r", encoding="utf-8") as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    rec = json.loads(line)
                except json.JSONDecodeError:
                    continue    # torn trailing write: previous record wins
                if rec.get("tombstone"):
                    self._snaps.pop(rec["stream_id"], None)
                    self._stamp.pop(rec["stream_id"], None)
                    continue
                try:
                    snap = StreamSnapshot.from_record(rec)
                except (KeyError, ValueError):
                    continue    # torn/alien record: skip, don't poison load
                cur = self._snaps.get(snap.stream_id)
                if cur is not None and cur.window_seq > snap.window_seq:
                    continue    # out-of-order append: newest seq wins
                self._snaps[snap.stream_id] = snap
                self._stamp[snap.stream_id] = self._clock()

    def _append(self, rec: dict) -> None:
        self._f.write(json.dumps(rec) + "\n")
        self._f.flush()
        if self._fsync:
            os.fsync(self._f.fileno())

    # -- overrides -----------------------------------------------------------

    def _put_locked(self, snap: StreamSnapshot) -> None:
        super()._put_locked(snap)
        self._append(snap.to_record())

    def delete(self, stream_id: str) -> None:
        with self._lock:
            present = stream_id in self._snaps
            self._snaps.pop(stream_id, None)
            self._stamp.pop(stream_id, None)
            if present:
                self._append({"v": STATE_SCHEMA_VERSION,
                              "stream_id": stream_id, "tombstone": True})

    def compact(self) -> int:
        """Rewrite the log to one live record per stream; returns the
        number of live records kept."""
        with self._lock:
            self._reap_locked()
            tmp = self.path + ".tmp"
            with open(tmp, "w", encoding="utf-8") as f:
                for sid in sorted(self._snaps):
                    f.write(json.dumps(self._snaps[sid].to_record()) + "\n")
                f.flush()
                os.fsync(f.fileno())
            self._f.close()
            os.replace(tmp, self.path)
            self._f = open(self.path, "a", encoding="utf-8")
            return len(self._snaps)

    def close(self) -> None:
        self._f.close()


def snapshot_rows(state, slot: int, stream_id: str, window_seq: int,
                  meta: Optional[dict] = None):
    """Lazy per-slot snapshot handle over a stacked ``TorrState``.

    Returns ``(stream_id, window_seq, state, slot, meta)`` — a *reference*
    to the immutable post-step state tree, no device ops at all, so
    calling this on the dispatch path costs nothing. The caller (sync
    telemetry fold / async collector) forces it with
    :func:`materialize_snapshot` once the step has retired; passing one
    shared ``memo`` dict per fold batch makes all slots of a step share a
    single host transfer per cache leaf.
    """
    return (stream_id, window_seq, state, slot, dict(meta or {}))


def materialize_snapshot(pending, memo: Optional[dict] = None
                         ) -> StreamSnapshot:
    """Force one :func:`snapshot_rows` payload to host numpy arrays.

    ``memo`` (keyed by the state tree's identity) caches the full host
    copy of each stacked leaf, so a fold batch snapshotting many slots of
    the same step pays one device→host transfer per leaf, not per slot.
    The snapshot's rows are read-only *views* into that host copy — at
    the default cadence every slot of the leaf is referenced anyway, and
    views keep the fold off the step's critical path; a caller that
    snapshots sparsely and cares about pinning can ``.copy()`` rows.
    """
    stream_id, window_seq, state, slot, meta = pending
    key = id(state)
    host = memo.get(key) if memo is not None else None
    if host is None:
        host = {f: np.asarray(getattr(state.cache, f))
                for f in CACHE_FIELDS}
        host["__task_w__"] = np.asarray(state.task_weights)
        if memo is not None:
            memo[key] = host
    return StreamSnapshot(
        stream_id=stream_id,
        window_seq=window_seq,
        cache={f: host[f][slot] for f in CACHE_FIELDS},
        task_w=host["__task_w__"][slot],
        meta=meta,
    )


def restore_slot(state, cfg, slot: int, snap: StreamSnapshot):
    """Warm-start one slot of a stacked ``TorrState`` from a snapshot.

    Returns a new state tree with the slot's cache leaves and task-weight
    row overwritten by the snapshot's (dtype/shape validated against the
    freshly-reset slot, so schema drift fails loudly). The snapshot's
    ``acc_tag`` rides along, so stale-δ rejection across plan switches is
    preserved bit-exactly across the restore.
    """
    import jax.numpy as jnp

    from ..core.pipeline import TorrState

    snap.validate()
    cache = state.cache
    new_leaves = {}
    for f in CACHE_FIELDS:
        cur = getattr(cache, f)
        row = np.asarray(snap.cache[f])
        want = cur.shape[1:]
        if tuple(row.shape) != tuple(want) or row.dtype != np.dtype(
                cur.dtype):
            raise ValueError(
                f"snapshot leaf {f!r} is {row.dtype}{row.shape}, slot wants "
                f"{np.dtype(cur.dtype)}{tuple(want)} — config mismatch "
                "between snapshot and engine")
        new_leaves[f] = cur.at[slot].set(jnp.asarray(row))
    cache = dataclasses.replace(cache, **new_leaves)
    task_w = state.task_weights.at[slot].set(
        jnp.asarray(np.asarray(snap.task_w, np.float32)))
    return TorrState(cache=cache, task_weights=task_w)
