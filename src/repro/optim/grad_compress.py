"""Gradient compression for cross-pod reduction: int8 QSGD + error feedback.

At multi-pod scale the 'pod' axis rides the slowest links (DCI), so the
gradient all-reduce there is the byte budget that matters. We compress with
per-tensor-scaled int8 quantization (4x vs f32, 2x vs bf16) and keep the
quantization *residual* in an error-feedback accumulator, which restores
convergence to the uncompressed trajectory (Karimireddy et al.-style EF).

``compressed_psum`` runs inside shard_map on the compression axis: quantize
-> all_gather(int8 + scales) -> dequantize-sum locally. With k pods that
moves k*(n/4) f32-equivalent bytes instead of the ~2n of a ring all-reduce;
for k=2 pods it is a strict win and numerically transparent under EF.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_compress(grad: jax.Array, err: jax.Array) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Error-feedback compress one tensor. Returns (q, scale, new_err)."""
    corrected = grad.astype(jnp.float32) + err
    q, s = quantize_int8(corrected)
    new_err = corrected - dequantize_int8(q, s)
    return q, s, new_err


def compressed_psum(grad: jax.Array, err: jax.Array, axis: str):
    """int8 all-gather-sum over ``axis`` (call inside shard_map)."""
    q, s, new_err = ef_compress(grad, err)
    qs = jax.lax.all_gather(q, axis)            # [k, ...] int8
    ss = jax.lax.all_gather(s, axis)            # [k]
    summed = jnp.tensordot(ss, qs.astype(jnp.float32), axes=([0], [0]))
    return summed.astype(grad.dtype), new_err


def tree_compressed_psum(grads, err_state, axis: str):
    """Tree version; err_state mirrors grads (f32)."""
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e, _ = jax.tree_util.tree_flatten(err_state)
    out_g, out_e = [], []
    for g, e in zip(flat_g, flat_e):
        sg, ne = compressed_psum(g, e, axis)
        out_g.append(sg)
        out_e.append(ne)
    return (jax.tree_util.tree_unflatten(treedef, out_g),
            jax.tree_util.tree_unflatten(treedef, out_e))


def init_error_state(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def make_dp_compressed_train_step(loss_fn, opt_update, mesh, axis: str = "data"):
    """Data-parallel train step with explicit compressed gradient reduce.

    Runs the whole step under shard_map over ``axis``: per-shard grads via
    local value_and_grad, int8+EF all-gather-sum across the axis, optimizer
    applied identically on every shard. Params replicated over ``axis``.
    """
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    def local_step(params, err, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        grads, err = tree_compressed_psum(grads, err, axis)
        n = jax.lax.psum(1, axis)
        grads = jax.tree.map(lambda g: g / n, grads)
        params, opt_state, om = opt_update(params, grads, opt_state)
        return params, err, opt_state, {**metrics, **om, "loss": loss}

    batch_spec = P(axis)
    rep = P()
    return shard_map(
        local_step, mesh=mesh,
        in_specs=(rep, rep, rep, batch_spec),
        out_specs=(rep, rep, rep, rep),
        check_rep=False)
