"""AdamW + global-norm clipping + cosine schedule (pure pytree, optax-free)."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def schedule(step: jax.Array, cfg: OptimConfig) -> jax.Array:
    warm = cfg.lr * (step + 1) / max(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.lr * (cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 *
                    (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params) -> dict:
    zeros = lambda p: jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), p)
    return {"mu": zeros(params), "nu": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def apply_updates(params, grads, state: dict, cfg: OptimConfig):
    """One AdamW step; returns (params', state', metrics)."""
    step = state["step"]
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gn + 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    b1, b2 = cfg.b1, cfg.b2
    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["mu"], grads)
    nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state["nu"], grads)
    t = (step + 1).astype(jnp.float32)
    bc1 = 1 - b1 ** t
    bc2 = 1 - b2 ** t
    lr = schedule(step, cfg)

    def upd(p, m, v):
        u = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        if p.ndim >= 2:  # decay matrices only
            u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    new_state = {"mu": mu, "nu": nu, "step": step + 1}
    return new_params, new_state, {"grad_norm": gn, "lr": lr}
