"""Shared configuration/state types for the TorR core.

Everything here is a static (hashable) config or a JAX pytree. The config
mirrors the paper's deployment-time knobs: dimension D, bank count B (so the
effective dimension D' is a multiple of D/B), similarity thresholds
(tau_byp, tau_q), load thresholds (N_hi, q_hi), the delta budget, lane count
W and clock — the last two parameterize the cycle model of paper Sec. 4.7.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class TorrConfig:
    """Static TorR configuration (hashable; safe as a jit static arg)."""

    # --- HDC geometry -----------------------------------------------------
    D: int = 8192            # full hypervector dimension
    B: int = 8               # item-memory banks (D' = k * D/B, k in 1..B)
    M: int = 128             # number of concept hypervectors in item memory
    feat_dim: int = 512      # encoder feature dim d (z_e in R^d)

    # --- cache / reuse ----------------------------------------------------
    K: int = 8               # query-cache depth
    N_max: int = 16          # max proposals (queries) per window
    delta_budget: int = 1024 # static |Delta| budget (TPU adaptation; multiple of 128)

    # --- Alg. 1 thresholds --------------------------------------------------
    tau_byp: float = 0.95    # bypass similarity threshold
    tau_q: float = 0.60      # delta-vs-full similarity threshold
    N_hi: int = 8            # high-load object count
    q_hi: int = 4            # high-load queue depth

    # --- reasoner ----------------------------------------------------------
    n_relations: int = 16    # relation hypervectors (used-for, part-of, ...)
    max_hops: int = 3        # max k-hop relation path length
    top_k: int = 5           # top-k key width for reasoner gating
    margin_eps: float = 0.02 # margin tolerance for reasoner gating

    # --- hardware model (paper Sec. 4.3 / 4.7, TSMC 28nm @ 1 GHz) ----------
    W: int = 64              # class lanes in the associative aligner
    clock_hz: float = 1.0e9  # 1 GHz
    accum_bits: int = 8      # accumulator precision knob (int8; int4 has no TPU analogue)
    bit_planes: int = 4      # bit-slice planes per bank (precision gating grain)

    # --- QoS ---------------------------------------------------------------
    fps_target: float = 60.0

    def __post_init__(self):
        if self.D % (self.B * 32) != 0:
            raise ValueError(f"D={self.D} must be divisible by 32*B={32 * self.B}")
        if self.delta_budget % 8 != 0:
            raise ValueError("delta_budget must be a multiple of 8")
        if self.bank_words % self.bit_planes != 0:
            raise ValueError(
                f"bank words D/(32B)={self.bank_words} must be divisible by "
                f"bit_planes={self.bit_planes}")

    @property
    def words(self) -> int:
        """Total packed uint32 words per hypervector."""
        return self.D // 32

    @property
    def bank_dims(self) -> int:
        """Dimensions per bank (D/B)."""
        return self.D // self.B

    @property
    def bank_words(self) -> int:
        return self.bank_dims // 32

    def d_eff(self, banks: jax.Array | int) -> jax.Array | int:
        """Effective dimension D' for a given number of enabled banks."""
        return banks * self.bank_dims

    @property
    def plane_words(self) -> int:
        """Packed words per bit-slice plane within one bank."""
        return self.bank_words // self.bit_planes

    @property
    def plane_dims(self) -> int:
        """Dimensions per bit-slice plane within one bank."""
        return self.bank_dims // self.bit_planes

    def d_eff_planned(
        self, banks: jax.Array | int, planes: int
    ) -> jax.Array | int:
        """Effective dimension under combined bank + bit-plane gating."""
        return banks * (self.plane_dims * planes)

    @property
    def cycles_per_window_budget(self) -> float:
        return self.clock_hz / self.fps_target


# Path encodings shared by the policy, pipeline and cycle model.
PATH_BYPASS = 0
PATH_DELTA = 1
PATH_FULL = 2
PATH_NAMES = ("bypass", "delta", "full")

# Static-lowering encodings recorded in WindowTelemetry so lowering audits
# (flight recorder, cycle model) can read the resolved dispatch straight off
# the trace. Index-aligned name tuples are the shared decode vocabulary —
# ``FUSED_NAMES[int(tel.fused_mode)]`` — used by ``repro.obs`` and
# ``repro.perf.cycle_model``.
FUSED_NAMES = ("off", "switch", "prefix", "compact")
FUSED_IDS = {name: i for i, name in enumerate(FUSED_NAMES)}
DECIDE_NAMES = ("scan", "batched")
DECIDE_IDS = {name: i for i, name in enumerate(DECIDE_NAMES)}
DECIDE_NONE = -1   # non-compact lowerings run no decide pass

# The delta accumulator's exactness tag (Eq. 6): a delta correction is only
# valid against an accumulator computed under the *same* enabled dimensions,
# which under the QoS control plane means the same (banks, bit-planes) pair.
# One int32 packs both so the cache carries a single tag per entry; 0 (the
# init value) can never collide because banks >= 1 for any real scan.
PLAN_TAG_BASE = 256


def plan_tag(banks: jax.Array | int, planes: jax.Array | int):
    """int32 tag for an accumulator computed under (banks, planes)."""
    return banks * PLAN_TAG_BASE + planes


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class StreamBatch:
    """One batched multi-stream window step's inputs (S stream slots).

    The leading axis is the stream-slot axis of the engine
    (`repro.serving.stream_engine`): slot s carries stream s's next window.
    Idle slots are padded with ``valid`` all-False and ``queue_depth`` 0 —
    the pipeline's pad branch guarantees they leave that slot's cache
    untouched. ``queue_depth`` is per-stream (each stream has its own
    backlog), which is what lets Alg. 1's load gating stay per-stream
    under batching.
    """

    q_packed: jax.Array     # uint32 [S, N_max, D//32] proposal query HVs
    valid: jax.Array        # bool   [S, N_max]
    boxes: jax.Array        # f32    [S, N_max, 4]
    queue_depth: jax.Array  # int32  [S] per-stream backlog

    @property
    def n_streams(self) -> int:
        return self.q_packed.shape[0]

    def tree_flatten(self):
        return ((self.q_packed, self.valid, self.boxes, self.queue_depth),
                None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class WindowTelemetry:
    """Per-window execution trace (feeds the cycle-accurate model).

    ``queue_depth`` and ``high_load`` echo the load signals Alg. 1's gate
    H(N, q) actually saw, so host-side controllers (the RT-deadline
    admission control in ``repro.serving.deadline``) and the cycle model can
    attribute path decisions to backlog pressure without re-deriving it.
    ``banks`` and ``planes`` together record the knob plan the window
    actually ran with (the QoS governor's latched D'/precision choice), so
    energy accounting and plan audits read straight off the trace.
    ``fused_mode``/``decide_mode``/``bucket_tier`` record the *resolved*
    static lowering knobs the step actually dispatched with (``FUSED_IDS``/
    ``DECIDE_IDS`` encodings; ``DECIDE_NONE`` and tier 0 for lowerings that
    run no decide pass), so lowering audits never have to re-derive which
    executable a traced window went through.
    """

    path: jax.Array        # [N_max] int32, PATH_* per proposal
    delta_count: jax.Array # [N_max] int32, |Delta| per proposal
    banks: jax.Array       # [] int32, enabled banks this window
    rho: jax.Array         # [N_max] f32, similarity to nearest cached query
    n_valid: jax.Array     # [] int32, actual proposals this window
    reasoner_active: jax.Array  # [N_max] bool, reasoner ran (not gated)
    queue_depth: jax.Array # [] int32, backlog fed to H(N, q) this window
    high_load: jax.Array   # [] bool, H(N, q) as evaluated by Alg. 1
    planes: jax.Array      # [] int32, enabled bit-slice planes this window
    fused_mode: jax.Array  # [] int32, FUSED_IDS[...] the step ran with
    decide_mode: jax.Array # [] int32, DECIDE_IDS[...] or DECIDE_NONE
    bucket_tier: jax.Array # [] int32, compact bucket capacity (0 = n/a)

    def tree_flatten(self):
        return (
            (self.path, self.delta_count, self.banks, self.rho, self.n_valid,
             self.reasoner_active, self.queue_depth, self.high_load,
             self.planes, self.fused_mode, self.decide_mode,
             self.bucket_tier),
            None,
        )

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)
