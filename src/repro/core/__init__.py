"""TorR core: the paper's algorithmic contribution as composable JAX modules."""
from . import aligner, bridge, encoder, events, hdc, item_memory, pipeline, policy, query_cache, reasoner, types
from .types import (PATH_BYPASS, PATH_DELTA, PATH_FULL, PATH_NAMES,
                    TorrConfig, WindowTelemetry)

__all__ = [
    "aligner", "bridge", "encoder", "events", "hdc", "item_memory",
    "pipeline", "policy", "query_cache", "reasoner", "types",
    "TorrConfig", "WindowTelemetry",
    "PATH_BYPASS", "PATH_DELTA", "PATH_FULL", "PATH_NAMES",
]
