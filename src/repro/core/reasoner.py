"""HDC graph reasoner (paper Sec. 3.2 / 4.5).

Task knowledge lives in relation hypervectors {r_l} (used-for, part-of, ...).
A k-hop path P = (l1..lk) composes g_P = t (*) r_l1 (*) ... (*) r_lk by
Hadamard binding; the reasoner weight for concept j is w_j = cos(g_P, h_j)
and the final score is s_hat_j = s_j * w_j.

For fixed prompts the weights are precomputed once (``precompute_weights``);
online prompt changes reuse the same similarity kernel by treating g_P as a
query (Sec. 4.5). Reasoner *gating*: when the aligner's top-k key and margin
match the cached window, the multiply is skipped and the cached output is
forwarded.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from . import hdc
from .item_memory import ItemMemory, dim_mask
from .types import TorrConfig


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class TaskGraph:
    relations: jax.Array  # int8 [n_relations, D]
    text_hv: jax.Array    # int8 [n_tasks, D] prompt hypervectors t

    def tree_flatten(self):
        return ((self.relations, self.text_hv), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)


def init_task_graph(key: jax.Array, cfg: TorrConfig, n_tasks: int) -> TaskGraph:
    k1, k2 = jax.random.split(key)
    return TaskGraph(
        relations=hdc.random_hv(k1, (cfg.n_relations, cfg.D)),
        text_hv=hdc.random_hv(k2, (n_tasks, cfg.D)),
    )


def compose_path(
    graph: TaskGraph, task_id: jax.Array | int, path_ids: jax.Array
) -> jax.Array:
    """g_P = t (*) r_{l1} (*) ... (*) r_{lk}.

    ``path_ids`` is int32 [max_hops]; entries < 0 are padding (bind with the
    identity +1 vector), allowing variable-hop paths under static shapes.
    """
    t = graph.text_hv[task_id].astype(jnp.int32)

    def hop(g, rid):
        r = jnp.where(rid >= 0, graph.relations[jnp.maximum(rid, 0)].astype(jnp.int32), 1)
        return g * r, None

    g, _ = jax.lax.scan(hop, t, path_ids)
    return g.astype(jnp.int8)


def task_weights(
    g_P: jax.Array, im: ItemMemory, cfg: TorrConfig, banks: jax.Array | int
) -> jax.Array:
    """w_j = cos(g_P, h_j) over enabled dims, f32 [M]."""
    dmask = dim_mask(cfg, banks)
    g = jnp.where(dmask, g_P.astype(jnp.int32), 0)
    dots = jnp.einsum("d,md->m", g, im.bipolar.astype(jnp.int32))
    d_eff = jnp.sum(dmask.astype(jnp.int32)).astype(jnp.float32)
    return dots.astype(jnp.float32) / d_eff


def precompute_weights(
    graph: TaskGraph,
    im: ItemMemory,
    cfg: TorrConfig,
    task_paths: jax.Array,
) -> jax.Array:
    """Offline weights for fixed tasks: [n_tasks, M] at full D.

    ``task_paths`` is int32 [n_tasks, max_hops] with -1 padding.
    """
    n_tasks = graph.text_hv.shape[0]

    def one(tid):
        g = compose_path(graph, tid, task_paths[tid])
        return task_weights(g, im, cfg, cfg.B)

    return jax.vmap(one)(jnp.arange(n_tasks))


def online_weights(
    graph: TaskGraph, im: ItemMemory, cfg: TorrConfig,
    task_id: jax.Array, path_ids: jax.Array, banks: jax.Array | int,
) -> jax.Array:
    """Online prompt change (paper Sec. 4.5): recompute w_j at run time by
    treating g_P as a query through the same similarity kernel the aligner
    uses (XNOR-popcount over the packed item memory)."""
    from . import hdc
    from .item_memory import word_mask

    g = compose_path(graph, task_id, path_ids)
    gp = hdc.pack_bits(g)
    wmask = word_mask(cfg, banks)
    xor = jnp.bitwise_xor(gp[None, :], im.packed)            # [M, W]
    pc = jnp.where(wmask[None, :],
                   jax.lax.population_count(xor).astype(jnp.int32), 0)
    d_eff = jnp.asarray(banks, jnp.int32) * cfg.bank_dims
    dots = d_eff - 2 * jnp.sum(pc, axis=-1)
    return dots.astype(jnp.float32) / d_eff.astype(jnp.float32)


def topk_key_margin(scores: jax.Array, cfg: TorrConfig) -> tuple[jax.Array, jax.Array]:
    """Aligner top-k indices and top-1/top-2 margin used for gating."""
    vals, idx = jax.lax.top_k(scores, cfg.top_k)
    margin = vals[0] - vals[1]
    return idx.astype(jnp.int32), margin


def gate_and_apply(
    scores: jax.Array,
    weights: jax.Array,
    cached_out: jax.Array,
    cached_key: jax.Array,
    cached_margin: jax.Array,
    cfg: TorrConfig,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Sec. 4.5 gating. Returns (out [M], reasoner_active, new_key, new_margin)."""
    key, margin = topk_key_margin(scores, cfg)
    match = jnp.logical_and(
        jnp.all(key == cached_key),
        jnp.abs(margin - cached_margin) <= cfg.margin_eps,
    )
    reasoned = scores * weights
    out = jnp.where(match, cached_out, reasoned)
    return out, jnp.logical_not(match), key, margin
