"""Image->event training bridge (paper Sec. 3.2, Eq. 2-3).

Contrastive transfer that places event features near image features in CLIP
space while preserving text alignment:

    L_con = InfoNCE( f_img(I), f_evt(E_hat) ; tau_c )        (Eq. 2)
    L_zs  = InfoNCE( f_evt(E_hat), f_text(T) over vocab ; tau_t )   (Eq. 3)
    L     = L_con + alpha * L_zs

The CLIP encoders are *frozen*; offline we stand in deterministic frozen
proxy encoders (random MLPs) with the same interface — the bridge math,
gradients and convergence behaviour are identical, only the semantic quality
of the targets differs (documented in DESIGN.md).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


def _l2norm(x: jax.Array, eps: float = 1e-8) -> jax.Array:
    return x / (jnp.linalg.norm(x, axis=-1, keepdims=True) + eps)


def info_nce(anchor: jax.Array, positives: jax.Array, temperature: float) -> jax.Array:
    """Diagonal InfoNCE: anchor[i] should match positives[i]. [B, d] each."""
    a = _l2norm(anchor)
    p = _l2norm(positives)
    logits = (a @ p.T) / temperature                     # [B, B]
    labels = jnp.arange(a.shape[0])
    return jnp.mean(
        -jax.nn.log_softmax(logits, axis=-1)[labels, labels]
    )


def zero_shot_loss(
    event_emb: jax.Array, text_bank: jax.Array, labels: jax.Array, temperature: float
) -> jax.Array:
    """Eq. 3: event embedding vs the text vocabulary bank [V, d]."""
    e = _l2norm(event_emb)
    t = _l2norm(text_bank)
    logits = (e @ t.T) / temperature                     # [B, V]
    return jnp.mean(-jnp.take_along_axis(
        jax.nn.log_softmax(logits, axis=-1), labels[:, None], axis=1))


def bridge_loss(
    image_emb: jax.Array,
    event_emb: jax.Array,
    text_bank: jax.Array,
    labels: jax.Array,
    *,
    tau_c: float = 0.07,
    tau_t: float = 0.07,
    alpha: float = 1.0,
) -> tuple[jax.Array, dict]:
    """L = L_con + alpha * L_zs, with a metrics dict for logging."""
    l_con = info_nce(image_emb, event_emb, tau_c)
    l_zs = zero_shot_loss(event_emb, text_bank, labels, tau_t)
    loss = l_con + alpha * l_zs
    # zero-shot top-1 accuracy as a convergence signal
    logits = _l2norm(event_emb) @ _l2norm(text_bank).T
    acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
    return loss, {"l_con": l_con, "l_zs": l_zs, "zs_acc": acc}


# ---------------------------------------------------------------------------
# Frozen proxy CLIP encoders (offline stand-ins, deterministic)
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class FrozenProxy:
    w1: jax.Array
    w2: jax.Array

    def tree_flatten(self):
        return ((self.w1, self.w2), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)

    def __call__(self, x: jax.Array) -> jax.Array:
        h = jnp.tanh(x @ self.w1)
        return jax.lax.stop_gradient(h @ self.w2)


def make_frozen_proxy(key: jax.Array, in_dim: int, emb_dim: int, hidden: int = 256) -> FrozenProxy:
    k1, k2 = jax.random.split(key)
    return FrozenProxy(
        w1=jax.random.normal(k1, (in_dim, hidden)) / jnp.sqrt(in_dim),
        w2=jax.random.normal(k2, (hidden, emb_dim)) / jnp.sqrt(hidden),
    )
