"""TorR end-to-end window step (paper Fig. 3/4/5).

One call processes one event window: for each of up to N_max proposal
queries, the PSU finds the nearest cached query, Alg. 1 selects
bypass / delta / full, the associative aligner produces class scores, the
reasoner applies (or gates) task weights, and the query cache is refreshed.
Proposals are processed sequentially (lax.scan) so later proposals can hit
entries written earlier in the same window — matching the ASIC's per-window
FSM — and the three paths are real `lax.switch` branches, so only the
selected path executes.

By default the full path runs through the fused Pallas kernel family
(``fused="switch"``/``"prefix"``, see :func:`torr_window_step`): the whole
window's proposal batch takes one bank/plane-gated XNOR-popcount pass
*before* the scan (the full branch then only gathers its row), and the
delta branch's Eq. 6 correction streams through the scalar-prefetch
kernel. ``fused="off"`` restores the per-proposal jnp-oracle executable,
which the fused path is tested bit-identical against.

The returned :class:`WindowTelemetry` trace is the input to the
cycle-accurate model (`repro.perf.cycle_model`), keeping the functional and
timing models in lock-step by construction.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from . import aligner as al
from . import policy, query_cache, reasoner
from .item_memory import ItemMemory, plan_word_mask
from .query_cache import CacheState
from .types import (PATH_BYPASS, StreamBatch, TorrConfig, WindowTelemetry,
                    plan_tag)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class TorrState:
    cache: CacheState
    task_weights: jax.Array  # f32 [M] precomputed w_j for the active task

    def tree_flatten(self):
        return ((self.cache, self.task_weights), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)


def init_state(cfg: TorrConfig, task_w: jax.Array) -> TorrState:
    return TorrState(cache=query_cache.init_cache(cfg), task_weights=task_w)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class WindowOutput:
    scores: jax.Array   # f32 [N_max, M] final task-weighted scores
    best: jax.Array     # int32 [N_max] argmax class per proposal
    boxes: jax.Array    # f32 [N_max, 4] passthrough proposal boxes

    def tree_flatten(self):
        return ((self.scores, self.best, self.boxes), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)


def _proposal_body(cfg: TorrConfig, im: ItemMemory, task_w, banks, planes,
                   wmask, high, acc_full_all=None, fused_delta=False):
    """Scan body over proposals for a fixed window context (all closures are
    window-constant traced values; ``planes`` is static — the latched plan).

    ``acc_full_all`` is the fused path's pre-computed int32 [N_max, M]
    full-scan accumulator batch (``aligner.full_scores_all``): the full
    branch then just gathers its row, so the scan never re-reads the item
    memory. ``None`` keeps the legacy per-proposal jnp oracle in-branch
    (the reference executable the fused path is tested against)."""
    d_eff = cfg.d_eff_planned(banks, planes)
    tag = plan_tag(banks, planes)

    def body(cache: CacheState, inp):
        q_packed, valid, i = inp
        idx, rho, _ham = query_cache.nearest(cache, q_packed, cfg, banks,
                                             planes)
        d_idx, d_weight, d_count = al.delta_indices(
            q_packed, cache.packed[idx], wmask, cfg.delta_budget, cfg.D
        )
        # Eq. 6 exactness: the cached accumulator is only delta-correctable
        # under the exact (banks, planes) it was computed with
        tag_ok = cache.acc_tag[idx] == tag
        action = policy.select_path(rho, d_count, tag_ok, high, cfg)

        def bypass_branch(cache):
            out = cache.out[idx]
            return query_cache.touch(cache, idx), out, jnp.array(False)

        def delta_branch(cache):
            if fused_delta:
                acc = al.delta_apply(cache.acc[idx], im, d_idx, d_weight)
            else:
                acc = al.delta_correct(cache.acc[idx], im, d_idx, d_weight)
            s = al.readout(acc, d_eff)
            out, active, key, margin = reasoner.gate_and_apply(
                s, task_w, cache.out[idx], cache.topk_key[idx],
                cache.margin[idx], cfg,
            )
            cache = query_cache.write_entry(
                cache, idx, packed=q_packed, acc=acc, acc_tag=tag,
                out=out, topk_key=key, margin=margin,
            )
            return cache, out, active

        def full_branch(cache):
            if acc_full_all is None:
                acc = al.full_dot(q_packed, im, wmask)
            else:
                acc = acc_full_all[i]
            s = al.readout(acc, d_eff)
            out, active, key, margin = reasoner.gate_and_apply(
                s, task_w, cache.out[idx], cache.topk_key[idx],
                cache.margin[idx], cfg,
            )
            slot = query_cache.lru_slot(cache)
            cache = query_cache.write_entry(
                cache, slot, packed=q_packed, acc=acc, acc_tag=tag,
                out=out, topk_key=key, margin=margin,
            )
            return cache, out, active

        # Invalid (padding) proposals take a free branch that touches nothing.
        def pad_branch(cache):
            return cache, jnp.zeros((cfg.M,), jnp.float32), jnp.array(False)

        eff_action = jnp.where(valid, action, jnp.int32(3))
        cache, out, active = jax.lax.switch(
            eff_action, [bypass_branch, delta_branch, full_branch, pad_branch], cache
        )
        telem = (eff_action, jnp.where(valid, d_count, 0),
                 jnp.where(valid, rho, 0.0), active)
        return cache, (out, telem)

    return body


def torr_window_step(
    state: TorrState,
    im: ItemMemory,
    q_packed_all: jax.Array,   # uint32 [N_max, D//32] proposal query HVs
    valid: jax.Array,          # bool [N_max]
    boxes: jax.Array,          # f32 [N_max, 4]
    queue_depth: jax.Array,    # int32 []
    cfg: TorrConfig,
    plan=None,                 # static KnobPlan (None = uncontrolled)
    fused=None,                # static: "switch" | "prefix" | "off"
    ham_prefix_all=None,       # int32 [N_max, M, cap] hoisted prefix counts
) -> tuple[TorrState, WindowOutput, WindowTelemetry]:
    """Process one window; returns (new_state, detections, telemetry).

    ``plan`` is a static :class:`repro.control.plan.KnobPlan` latched by the
    QoS control plane: it caps Alg. 1's bank choice (``min`` — the full cap
    is a bit-exact no-op), selects the bit-slice planes the scans read, and
    offsets the tau thresholds. ``plan=None`` (or the full plan) reproduces
    the uncontrolled step bit-for-bit.

    ``fused`` (static) picks the full path's lowering. The default
    (``None`` -> ``"switch"``) routes the whole window's full-path scan
    through the Pallas kernel family (``aligner.full_scores_all``): all
    N_max proposals go through one fused bank/plane-gated XNOR-popcount
    pass *before* the scan, and the delta branch's Eq. 6 correction rides
    the scalar-prefetch kernel — bit-identical to the jnp oracle.
    ``"prefix"`` is the vmap-shaped lowering the batched multi-stream step
    selects (one bank-prefix pass instead of a per-bank switch;
    ``ham_prefix_all`` carries the counts when the caller hoisted the
    kernel over a whole stream batch); ``"off"`` keeps the legacy
    per-proposal oracle in-branch (the reference executable, and the
    cheaper trade for windows that rarely take the full path on branchy
    CPU backends — the hoisted scan runs per window, where the in-branch
    oracle runs per full-path proposal).
    """
    if fused is None:
        fused = "switch"
    if fused not in ("switch", "prefix", "off"):
        raise ValueError(f"fused={fused!r} not in ('switch','prefix','off')")
    if plan is None:
        planes = cfg.bit_planes
        cap = cfg.B
    else:
        plan.validate(cfg)
        planes = plan.planes
        cap = min(plan.banks, cfg.B)
        cfg = plan.thresholds(cfg)
    n_valid = jnp.sum(valid.astype(jnp.int32))
    high = policy.high_load(n_valid, queue_depth, cfg)
    banks = policy.select_banks(n_valid, queue_depth, cfg)
    if plan is not None and plan.banks < cfg.B:
        banks = jnp.minimum(banks, jnp.int32(plan.banks))
    wmask = plan_word_mask(cfg, banks, planes)

    acc_full_all = None
    if fused != "off":
        acc_full_all = al.full_scores_all(
            q_packed_all, im, banks, cfg, planes=planes, cap=cap, mode=fused,
            ham_prefix=ham_prefix_all)

    # The scalar-prefetch delta kernel pays off where branch economy is
    # real (the "switch" lowering: only the selected path executes). Under
    # the vmapped "prefix" lowering every lane computes all three branches,
    # and a budget-deep scalar-streaming grid per lane is the wrong shape —
    # the vectorized jnp gather-einsum IS the batched scatter-accumulate
    # there, so the oracle form is kept deliberately.
    body = _proposal_body(cfg, im, state.task_weights, banks, planes, wmask,
                          high, acc_full_all=acc_full_all,
                          fused_delta=fused == "switch")
    cache, (outs, telem) = jax.lax.scan(
        body, state.cache,
        (q_packed_all, valid, jnp.arange(cfg.N_max, dtype=jnp.int32)))

    actions, d_counts, rhos, active = telem
    # padding actions (3) are reported as bypass with zero cost
    path = jnp.where(actions == 3, PATH_BYPASS, actions)
    telemetry = WindowTelemetry(
        path=path.astype(jnp.int32),
        delta_count=d_counts.astype(jnp.int32),
        banks=banks,
        rho=rhos.astype(jnp.float32),
        n_valid=n_valid,
        reasoner_active=jnp.logical_and(active, valid),
        queue_depth=jnp.asarray(queue_depth, jnp.int32),
        high_load=high,
        planes=jnp.int32(planes),
    )
    out = WindowOutput(
        scores=outs,
        best=jnp.argmax(outs, axis=-1).astype(jnp.int32),
        boxes=boxes,
    )
    return TorrState(cache=cache, task_weights=state.task_weights), out, telemetry


# ---------------------------------------------------------------------------
# Multi-stream batched engine substrate
# ---------------------------------------------------------------------------

def init_multi_stream_state(cfg: TorrConfig, task_w: jax.Array) -> TorrState:
    """Stacked state for S independent streams.

    ``task_w`` is f32 [S, M] — one precomputed reasoner-weight row per
    stream slot (streams may serve different tasks). Every state leaf gains
    a leading stream axis; the per-stream query caches start empty.
    """
    task_w = jnp.asarray(task_w, jnp.float32)
    n_streams = task_w.shape[0]
    return TorrState(
        cache=query_cache.init_cache_batch(cfg, n_streams),
        task_weights=task_w,
    )


def torr_multi_stream_step(
    state: TorrState,          # stacked: every leaf has leading [S] axis
    im: ItemMemory,            # shared item memory (task knowledge)
    q_packed_all: jax.Array,   # uint32 [S, N_max, D//32]
    valid: jax.Array,          # bool [S, N_max]
    boxes: jax.Array,          # f32 [S, N_max, 4]
    queue_depth: jax.Array,    # int32 [S] per-stream backlog
    cfg: TorrConfig,
    serial: bool = False,      # static: lax.map instead of vmap
    plan=None,                 # static KnobPlan shared by all S windows
    fused=None,                # static: "switch" | "prefix" | "off"
) -> tuple[TorrState, WindowOutput, WindowTelemetry]:
    """One compiled step over S streams' windows.

    All S windows of one batched step share the latched ``plan`` (the
    window-latched register analogue: one plan per dispatch); each window's
    telemetry still records it individually.

    Semantically identical to running ``torr_window_step`` once per stream:
    each slot keeps its own cache, task weights and queue depth, so Alg. 1's
    load gating (H, D') is evaluated per stream. Idle slots (``valid``
    all-False) ride the pad branch and leave their cache intact.

    Two bit-identical lowerings, selected by the static ``serial`` flag:

      * ``serial=False`` (default) — ``jax.vmap`` of the window FSM: the
        XNOR-popcount and delta arithmetic of all S slots batch across
        vector lanes. Under vmap the per-proposal ``lax.switch`` lowers to
        compute-all-paths-and-select, the right trade on a TPU whose wide
        VPU is otherwise idle between windows.
      * ``serial=True`` — ``jax.lax.map`` over slots: streams run
        sequentially *inside one executable*, preserving scalar branch
        economy (only the selected path executes) while still amortizing
        the per-window host dispatch. The right trade on branchy CPU
        backends; ~2x over the per-stream Python loop in table6.

    ``fused`` defaults per lowering: the vmap lowering takes the
    ``"prefix"`` kernel dispatch (under vmap a per-bank ``lax.switch``
    would execute every branch on the whole batch), the serial lowering
    takes ``"switch"`` (branch economy survives inside ``lax.map``). In
    prefix mode the bank-prefix kernel is hoisted *out* of the per-stream
    lowering and runs once over the flattened S x N_max proposal batch —
    the item-memory tile is read once per query block for the whole step,
    and each stream's window selects its traced bank choice from the
    precomputed boundary counts. All of it is bit-identical to
    ``fused="off"``, the legacy oracle step.
    """
    if fused is None:
        fused = "switch" if serial else "prefix"

    ham_prefix = None
    if fused == "prefix":
        if plan is None:
            planes, cap = cfg.bit_planes, cfg.B
        else:
            plan.validate(cfg)
            planes, cap = plan.planes, min(plan.banks, cfg.B)
        S, N, W = q_packed_all.shape
        ham_prefix = al.plan_prefix_hamming(
            q_packed_all.reshape(S * N, W), im, cfg, planes=planes, cap=cap,
        ).reshape(S, N, cfg.M, cap)

    if serial:
        def body(args):
            st, q, v, b, qd, hp = args
            return torr_window_step(st, im, q, v, b, qd, cfg, plan=plan,
                                    fused=fused, ham_prefix_all=hp)

        return jax.lax.map(
            body,
            (state, q_packed_all, valid, boxes, queue_depth, ham_prefix),
        )

    def step(st, im_, q, v, b, qd, hp):
        return torr_window_step(st, im_, q, v, b, qd, cfg, plan=plan,
                                fused=fused, ham_prefix_all=hp)

    return jax.vmap(step, in_axes=(0, None, 0, 0, 0, 0, 0))(
        state, im, q_packed_all, valid, boxes, queue_depth, ham_prefix
    )


def torr_stream_batch_step(
    state: TorrState, im: ItemMemory, batch: StreamBatch, cfg: TorrConfig,
    serial: bool = False, plan=None, fused=None,
) -> tuple[TorrState, WindowOutput, WindowTelemetry]:
    """`torr_multi_stream_step` over a packed :class:`StreamBatch`."""
    return torr_multi_stream_step(
        state, im, batch.q_packed, batch.valid, batch.boxes,
        batch.queue_depth, cfg, serial=serial, plan=plan, fused=fused,
    )
