"""TorR end-to-end window step (paper Fig. 3/4/5).

One call processes one event window: for each of up to N_max proposal
queries, the PSU finds the nearest cached query, Alg. 1 selects
bypass / delta / full, the associative aligner produces class scores, the
reasoner applies (or gates) task weights, and the query cache is refreshed.
Proposals are processed sequentially (lax.scan) so later proposals can hit
entries written earlier in the same window — matching the ASIC's per-window
FSM — and the three paths are real `lax.switch` branches, so only the
selected path executes.

By default the full path runs through the fused Pallas kernel family
(``fused="switch"``/``"prefix"``, see :func:`torr_window_step`): the whole
window's proposal batch takes one bank/plane-gated XNOR-popcount pass
*before* the scan (the full branch then only gathers its row), and the
delta branch's Eq. 6 correction streams through the scalar-prefetch
kernel. ``fused="off"`` restores the per-proposal jnp-oracle executable,
which the fused path is tested bit-identical against.

``fused="compact"`` goes one step further (the reuse-aware dispatch): a
metadata-only *decide* pass produces the window's path vector first, and
the fused scan then runs only over the full-path proposals, compacted into
a dense bucket padded to a static ``bucket_cap`` tier
(``core.policy.bucket_ladder``). Cache hits *skip* the scan instead of
merely masking it — the kernel bytes scale with the miss rate.

The decide pass itself has two bit-identical lowerings (static
``decide`` knob): the sequential per-proposal FSM scan (``"scan"``, the
reference oracle) and the batched intra-window decide (``"batched"``, the
default) — one wide snapshot-nearest pass plus a K-metadata
conflict-resolution scan that replays the FSM's intra-window coupling
(self-hits on slots written earlier in the window, LRU eviction chains)
update-for-update. On the vmapped multi-stream lowering the batched
decide's writer chains additionally unlock the *batched apply*
(:func:`_apply_pass_batched`): Eq. 6 corrections become one dense matmul,
the reasoner's top-k one dispatch-wide pass, and the per-proposal scan
reduces to two cheap chain-resolution loops — the first lowering to break
the sequential FSM machinery's CPU floor, still bit-exact against the
oracle (``tests/test_decide_batched.py``).

The returned :class:`WindowTelemetry` trace is the input to the
cycle-accurate model (`repro.perf.cycle_model`), keeping the functional and
timing models in lock-step by construction.
"""
from __future__ import annotations

import dataclasses
import warnings

import jax
import jax.numpy as jnp

from . import aligner as al
from . import policy, query_cache, reasoner
from .item_memory import ItemMemory, plan_word_mask
from .query_cache import CacheState
from .types import (DECIDE_IDS, DECIDE_NONE, FUSED_IDS, PATH_BYPASS,
                    PATH_FULL, StreamBatch, TorrConfig, WindowTelemetry,
                    plan_tag)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class TorrState:
    cache: CacheState
    task_weights: jax.Array  # f32 [M] precomputed w_j for the active task

    def tree_flatten(self):
        return ((self.cache, self.task_weights), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)


def init_state(cfg: TorrConfig, task_w: jax.Array) -> TorrState:
    return TorrState(cache=query_cache.init_cache(cfg), task_weights=task_w)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class WindowOutput:
    scores: jax.Array   # f32 [N_max, M] final task-weighted scores
    best: jax.Array     # int32 [N_max] argmax class per proposal
    boxes: jax.Array    # f32 [N_max, 4] passthrough proposal boxes

    def tree_flatten(self):
        return ((self.scores, self.best, self.boxes), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)


def _proposal_body(cfg: TorrConfig, im: ItemMemory, task_w, banks, planes,
                   wmask, high, acc_full_all=None, fused_delta=False,
                   decided=False):
    """Scan body over proposals for a fixed window context (all closures are
    window-constant traced values; ``planes`` is static — the latched plan).

    ``acc_full_all`` is the fused path's pre-computed int32 [N_max, M]
    full-scan accumulator batch (``aligner.full_scores_all``): the full
    branch then just gathers its row, so the scan never re-reads the item
    memory. ``None`` keeps the legacy per-proposal jnp oracle in-branch
    (the reference executable the fused path is tested against).

    ``decided=True`` is the compact dispatch's apply pass: the scan input
    additionally carries the decide pass's per-proposal decisions
    (action, nearest idx, LRU slot, delta indices/weights/count, rho), so
    the body skips the PSU/Alg. 1 work entirely and only applies the
    value-carrying branch — its cache updates replay the decide pass's
    metadata updates exactly, keeping the two passes in lock-step."""
    d_eff = cfg.d_eff_planned(banks, planes)
    tag = plan_tag(banks, planes)

    def body(cache: CacheState, inp):
        if decided:
            (q_packed, valid, i,
             action, idx, lru, d_idx, d_weight, d_count, rho) = inp
        else:
            q_packed, valid, i = inp
            lru = None
            idx, rho, _ham = query_cache.nearest(cache, q_packed, cfg, banks,
                                                 planes)
            d_idx, d_weight, d_count = al.delta_indices(
                q_packed, cache.packed[idx], wmask, cfg.delta_budget, cfg.D
            )
            # Eq. 6 exactness: the cached accumulator is only
            # delta-correctable under the exact (banks, planes) it was
            # computed with
            tag_ok = cache.acc_tag[idx] == tag
            action = policy.select_path(rho, d_count, tag_ok, high, cfg)

        def bypass_branch(cache):
            out = cache.out[idx]
            return query_cache.touch(cache, idx), out, jnp.array(False)

        def delta_branch(cache):
            if fused_delta:
                acc = al.delta_apply(cache.acc[idx], im, d_idx, d_weight)
            else:
                acc = al.delta_correct(cache.acc[idx], im, d_idx, d_weight)
            s = al.readout(acc, d_eff)
            out, active, key, margin = reasoner.gate_and_apply(
                s, task_w, cache.out[idx], cache.topk_key[idx],
                cache.margin[idx], cfg,
            )
            cache = query_cache.write_entry(
                cache, idx, packed=q_packed, acc=acc, acc_tag=tag,
                out=out, topk_key=key, margin=margin,
            )
            return cache, out, active

        def full_branch(cache):
            if acc_full_all is None:
                acc = al.full_dot(q_packed, im, wmask)
            else:
                acc = acc_full_all[i]
            s = al.readout(acc, d_eff)
            out, active, key, margin = reasoner.gate_and_apply(
                s, task_w, cache.out[idx], cache.topk_key[idx],
                cache.margin[idx], cfg,
            )
            slot = query_cache.lru_slot(cache) if lru is None else lru
            cache = query_cache.write_entry(
                cache, slot, packed=q_packed, acc=acc, acc_tag=tag,
                out=out, topk_key=key, margin=margin,
            )
            return cache, out, active

        # Invalid (padding) proposals take a free branch that touches nothing.
        def pad_branch(cache):
            return cache, jnp.zeros((cfg.M,), jnp.float32), jnp.array(False)

        if decided:
            eff_action = action       # the decide pass already padded it
            d_count_t, rho_t = d_count, rho
        else:
            eff_action = jnp.where(valid, action, jnp.int32(3))
            d_count_t = jnp.where(valid, d_count, 0)
            rho_t = jnp.where(valid, rho, 0.0)
        cache, out, active = jax.lax.switch(
            eff_action, [bypass_branch, delta_branch, full_branch, pad_branch], cache
        )
        telem = (eff_action, d_count_t, rho_t, active)
        return cache, (out, telem)

    return body


def _apply_pass_batched(state: TorrState, im: ItemMemory, q_packed_all,
                        valid, boxes, queue_depth, cfg: TorrConfig, banks,
                        planes, high, n_valid, dec, aux, acc_rows,
                        bucket_tier=0):
    """Batched apply: replay a whole [S, N] dispatch's decisions without a
    value-carrying scan — the ``decide="batched"`` counterpart of the
    per-proposal :func:`_proposal_body` apply scan, bit-identical to it.

    The apply scan's floor at serving shapes is not the cache scatter (the
    [K, M] carry updates are cheap) but the per-lane *value math* it
    serializes: the Eq. 6 gather-einsum and the reasoner's top-k run once
    per proposal per stream. With the decisions — and the decide pass's
    conflict byproducts (``aux``: the per-proposal writer ``src`` and the
    final slot metadata) — known up front, every value becomes a batched
    dispatch-wide computation:

      1. Eq. 6 corrections are *accumulator-independent*
         (``delta_correct = acc + corr``), so one dense
         :func:`aligner.delta_corrections` matmul covers all S x N lanes;
      2. accumulators resolve along writer chains in an N-step scan whose
         per-step work is one [S, M] gather + add (``src`` says whether a
         proposal reads its slot's snapshot row or an earlier proposal's
         result — the intra-window coupling invariant, now data);
      3. the gate's top-k key/margin depend only on each proposal's own
         scores, so one batched ``lax.top_k`` covers the dispatch, and the
         *cached* key/margin each proposal compares against is a direct
         ``src`` gather (the writer's stored key IS its computed key);
      4. gated outputs resolve in a second N-step scan (a match forwards
         the read value, which may itself be a forwarded value);
      5. the final cache is assembled in one shot: each slot takes its
         last writer's resolved values (``aux``'s final writer table), and
         age/validity come from the decide carry, which already replayed
         ``meta_touch``/``meta_write`` update-for-update.

    Bit-exactness: every per-element op (int32 adds, the f32 readout
    divide, ``top_k`` tie order, the margin compare, ``scores * weights``)
    is the same op the scan body runs, merely batched — enforced by the
    differential harness in ``tests/test_decide_batched.py``."""
    eff, idx, lru, d_idx, d_weight, d_count, rho = dec
    src, writer_f, age_f, valid_f = aux
    cache = state.cache
    S, N, _W = q_packed_all.shape
    M = cfg.M
    del lru  # already folded into the decide pass's writer table

    is_byp = eff == jnp.int32(0)
    is_full = eff == jnp.int32(2)
    is_pad = eff == jnp.int32(3)
    is_write = jnp.logical_or(eff == jnp.int32(1), is_full)

    d_eff = cfg.d_eff_planned(jnp.asarray(banks, jnp.int32), planes)  # [S]
    tag = jnp.asarray(plan_tag(banks, planes), jnp.int32)             # [S]
    corr = al.delta_corrections(
        d_idx.reshape(S * N, -1), d_weight.reshape(S * N, -1), im, cfg.D
    ).reshape(S, N, M)

    # each proposal's snapshot view of its nearest slot
    snap_acc = jnp.take_along_axis(cache.acc, idx[..., None], axis=1)
    snap_out = jnp.take_along_axis(cache.out, idx[..., None], axis=1)
    snap_key = jnp.take_along_axis(cache.topk_key, idx[..., None], axis=1)
    snap_margin = jnp.take_along_axis(cache.margin, idx, axis=1)
    s_ix = jnp.arange(S)
    src_safe = jnp.maximum(src, 0)

    def acc_body(acc_res, i):
        read = jnp.where(src[:, i, None] < 0, snap_acc[:, i],
                         acc_res[s_ix, src_safe[:, i]])
        acc_i = jnp.where(is_full[:, i, None], acc_rows[:, i],
                          read + corr[:, i])
        return acc_res.at[:, i].set(acc_i), None

    acc_res, _ = jax.lax.scan(acc_body, jnp.zeros((S, N, M), jnp.int32),
                              jnp.arange(N))

    s_all = al.readout(acc_res, d_eff[:, None, None])        # [S, N, M]
    vals, kidx = jax.lax.top_k(s_all.reshape(S * N, M), cfg.top_k)
    # without this barrier XLA-CPU sees the sliced/reshaped consumers and
    # re-lowers TopK as a full row sort — ~5x the whole pass at M = 1024
    vals, kidx = jax.lax.optimization_barrier((vals, kidx))
    key_all = kidx.astype(jnp.int32).reshape(S, N, cfg.top_k)
    margin_all = (vals[:, 0] - vals[:, 1]).reshape(S, N)
    cached_key = jnp.where(
        src[..., None] < 0, snap_key,
        jnp.take_along_axis(key_all, src_safe[..., None], axis=1))
    cached_margin = jnp.where(
        src < 0, snap_margin,
        jnp.take_along_axis(margin_all, src_safe, axis=1))
    match = jnp.logical_and(
        jnp.all(key_all == cached_key, axis=-1),
        jnp.abs(margin_all - cached_margin) <= cfg.margin_eps)
    reasoned = s_all * state.task_weights[:, None, :]
    active = jnp.logical_and(is_write, jnp.logical_not(match))

    def out_body(out_res, i):
        read = jnp.where(src[:, i, None] < 0, snap_out[:, i],
                         out_res[s_ix, src_safe[:, i]])
        out_w = jnp.where(match[:, i, None], read, reasoned[:, i])
        emit = jnp.where(is_pad[:, i, None], 0.0,
                         jnp.where(is_byp[:, i, None], read, out_w))
        return out_res.at[:, i].set(out_w), emit

    out_res, outs = jax.lax.scan(out_body, jnp.zeros((S, N, M), jnp.float32),
                                 jnp.arange(N))
    outs = jnp.moveaxis(outs, 0, 1)                          # [S, N, M]

    written = writer_f >= 0                                  # [S, K]
    wsafe = jnp.maximum(writer_f, 0)
    w2 = written[..., None]

    def last_write(arr_prop, arr_snap):
        return jnp.where(
            w2, jnp.take_along_axis(arr_prop, wsafe[..., None], axis=1),
            arr_snap)

    cache = CacheState(
        packed=last_write(q_packed_all, cache.packed),
        acc=last_write(acc_res, cache.acc),
        acc_tag=jnp.where(written, tag[:, None], cache.acc_tag),
        out=last_write(out_res, cache.out),
        topk_key=last_write(key_all, cache.topk_key),
        margin=jnp.where(written,
                         jnp.take_along_axis(margin_all, wsafe, axis=1),
                         cache.margin),
        age=age_f,
        valid=valid_f,
    )
    telem = (eff, d_count, rho, active)
    return jax.vmap(
        _finish_window,
        in_axes=(0, 0, 0, 0, 0, 0, 0, 0, 0, 0, None, None, None, None))(
        cache, state.task_weights, outs, telem, valid, boxes, queue_depth,
        banks, n_valid, high, planes, FUSED_IDS["compact"],
        DECIDE_IDS["batched"], bucket_tier)


def _decide_body(cfg: TorrConfig, banks, planes, wmask, high):
    """Metadata-only FSM pass: the compact dispatch's *decide* scan.

    Runs Alg. 1 per proposal (cache-nearest, delta feasibility, path
    selection) and applies only the cache-*metadata* updates later
    proposals' decisions can observe — packed query, plan tag, age,
    validity — preserving the per-window FSM's intra-window hit semantics
    without touching a single item-memory row. The scan carries a
    :class:`query_cache.MetaCache`, NOT the full cache: the [K, M] value
    arrays (``acc``/``out``) must never ride the decide carry, or moving
    them through the loop costs more than the scan this pass exists to
    skip. The value-carrying work (full scans, Eq. 6 corrections,
    reasoner) is deferred to the apply pass, which replays these exact
    decisions."""
    tag = plan_tag(banks, planes)

    def body(meta: query_cache.MetaCache, inp):
        q_packed, valid = inp
        idx, rho, _ham = query_cache.nearest(meta, q_packed, cfg, banks,
                                             planes)
        d_idx, d_weight, d_count = al.delta_indices(
            q_packed, meta.packed[idx], wmask, cfg.delta_budget, cfg.D
        )
        tag_ok = meta.acc_tag[idx] == tag
        action = policy.select_path(rho, d_count, tag_ok, high, cfg)
        eff = jnp.where(valid, action, jnp.int32(3))
        # the LRU choice the apply pass's full branch will make — computed
        # here because both passes see identical age/validity sequences
        lru = query_cache.lru_slot(meta)

        def bypass_branch(meta):
            return query_cache.meta_touch(meta, idx)

        def delta_branch(meta):
            return query_cache.meta_write(meta, idx, packed=q_packed,
                                          acc_tag=tag)

        def full_branch(meta):
            return query_cache.meta_write(meta, lru, packed=q_packed,
                                          acc_tag=tag)

        def pad_branch(meta):
            return meta

        meta = jax.lax.switch(
            eff, [bypass_branch, delta_branch, full_branch, pad_branch], meta
        )
        dec = (eff, idx, lru, d_idx, d_weight,
               jnp.where(valid, d_count, 0), jnp.where(valid, rho, 0.0))
        return meta, dec

    return body


def _decide_pass(cache: CacheState, q_packed_all, valid, cfg: TorrConfig,
                 banks, planes, high):
    """Run the sequential decide scan over one window; returns the
    per-proposal decision arrays (action, idx, lru, d_idx, d_weight,
    d_count, rho).

    This is the *reference oracle* for the batched decide
    (:func:`_decide_pass_batched`, ``decide="batched"``): the differential
    harness in ``tests/test_decide_batched.py`` asserts the two produce
    bit-identical decision tuples and final cache state. Keep them
    update-for-update in lock-step."""
    wmask = plan_word_mask(cfg, banks, planes)
    _, dec = jax.lax.scan(
        _decide_body(cfg, banks, planes, wmask, high),
        query_cache.meta_view(cache), (q_packed_all, valid))
    return dec


def _decide_pass_batched_aux(cache: CacheState, q_packed_all, valid,
                             cfg: TorrConfig, banks, planes, high):
    """Batched intra-window decide: one wide similarity pass + a cheap
    conflict-resolution scan, bit-identical to :func:`_decide_pass`.

    The sequential scan's per-proposal cost is a K-entry masked nearest
    ([K, W] xor-popcount) plus an O(D) delta-index search — serialized
    N_max times. Here the similarity work is hoisted into two batched
    lookup passes over the *frozen* window-entry snapshot (the PSU's
    one-wide-pass shape):

      * ``ham_snap`` [N, K] — every proposal vs every snapshot entry;
      * ``ham_prop`` [N, N] — every proposal vs every *other proposal*,
        because the only packed values an intra-window write can install
        are earlier proposals' own queries (``meta_write(packed=q_j)``).

    The conflict pass is then a scan whose carry is only K-sized metadata
    — ``writer`` (which proposal last wrote each slot, -1 = snapshot),
    ``age`` and ``valid`` — so each step is O(K) gathers from the
    precomputed tables instead of popcount work: slot k's hamming is
    ``ham_snap[i, k]`` while untouched and ``ham_prop[i, writer[k]]``
    after a write. This preserves the intra-window coupling invariant
    (``policy.intra_window_coupled``): self-hits on slots written earlier
    in the window, LRU eviction chains and plan-tag refreshes resolve
    exactly as the sequential FSM would, because the carried metadata
    replays ``meta_touch``/``meta_write`` update-for-update. rho keeps
    Eq. 5's f32 arithmetic and argmax's first-max tie-breaking, so
    decisions are bit-exact, not merely equivalent.

    Delta-index extraction (the other per-proposal O(D) cost) is deferred
    to one vmapped pass after the scan, against each proposal's *resolved*
    old entry (snapshot row or earlier proposal's query, per the recorded
    writer).

    Returns ``(dec, aux)``: ``dec`` is the decision 7-tuple in the exact
    layout of :func:`_decide_pass` (the apply scan replays it unchanged),
    ``aux`` the conflict pass's byproducts the *batched* apply pass
    (:func:`_apply_pass_batched`) needs to resolve intra-window read
    chains without a value-carrying scan: ``src`` [N] (which earlier
    proposal wrote each proposal's nearest slot at decision time, -1 =
    snapshot) and the final ``(writer, age, valid)`` [K] metadata."""
    wmask = plan_word_mask(cfg, banks, planes)
    tag = plan_tag(banks, planes)
    meta = query_cache.meta_view(cache)
    ham_snap = query_cache.hamming_all(meta, q_packed_all, cfg, banks,
                                       planes)                    # [N, K]
    ham_prop = al.lookup_hamming_all(q_packed_all, q_packed_all,
                                     wmask)                       # [N, N]
    d_eff = jnp.asarray(
        cfg.d_eff_planned(jnp.asarray(banks, jnp.int32), planes), jnp.float32)
    snap_tag_ok = meta.acc_tag == tag                             # [K]
    int_max = jnp.iinfo(jnp.int32).max

    def body(carry, inp):
        writer, age, valid_k = carry
        hs, hp, v, i = inp
        live = writer >= 0
        ham_k = jnp.where(live, hp[jnp.maximum(writer, 0)], hs)   # [K]
        rho_k = 1.0 - 2.0 * ham_k.astype(jnp.float32) / d_eff     # Eq. 5
        rho_k = jnp.where(valid_k, rho_k, -jnp.inf)
        idx = jnp.argmax(rho_k).astype(jnp.int32)
        rho = rho_k[idx]
        d_count = ham_k[idx]
        src = writer[idx]
        tag_ok = jnp.where(live[idx], True, snap_tag_ok[idx])
        action = policy.select_path(rho, d_count, tag_ok, high, cfg)
        eff = jnp.where(v, action, jnp.int32(3))
        lru = jnp.argmax(jnp.where(valid_k, age, int_max)).astype(jnp.int32)

        # replay the meta_touch / meta_write metadata updates
        is_pad = eff == jnp.int32(3)
        is_write = jnp.logical_or(eff == jnp.int32(1), eff == jnp.int32(2))
        slot = jnp.where(eff == jnp.int32(2), lru, idx)
        bump = jnp.logical_not(is_pad)
        age = age + bump.astype(jnp.int32)
        age = age.at[slot].set(jnp.where(bump, 0, age[slot]))
        writer = writer.at[slot].set(jnp.where(is_write, i, writer[slot]))
        valid_k = valid_k.at[slot].set(
            jnp.logical_or(valid_k[slot], is_write))
        out = (eff, idx, lru, jnp.where(v, d_count, 0),
               jnp.where(v, rho, 0.0), src)
        return (writer, age, valid_k), out

    writer0 = jnp.full((cfg.K,), -1, jnp.int32)
    arange = jnp.arange(cfg.N_max, dtype=jnp.int32)
    carry_f, (eff, idx, lru, d_count, rho, src) = jax.lax.scan(
        body, (writer0, meta.age, meta.valid),
        (ham_snap, ham_prop, valid, arange))

    # one vmapped delta-index pass against the resolved old entries
    old_packed = jnp.where(src[:, None] < 0, cache.packed[idx],
                           q_packed_all[jnp.maximum(src, 0)])
    d_idx, d_weight, _cnt = jax.vmap(
        lambda qn, qo: al.delta_indices(qn, qo, wmask, cfg.delta_budget,
                                        cfg.D))(q_packed_all, old_packed)
    dec = (eff, idx, lru, d_idx, d_weight, d_count, rho)
    return dec, (src,) + carry_f


def _decide_pass_batched(cache: CacheState, q_packed_all, valid,
                         cfg: TorrConfig, banks, planes, high):
    """:func:`_decide_pass_batched_aux` restricted to the decision 7-tuple
    — the drop-in signature-compatible counterpart of :func:`_decide_pass`
    for callers that replay decisions through the apply *scan*."""
    dec, _aux = _decide_pass_batched_aux(cache, q_packed_all, valid, cfg,
                                         banks, planes, high)
    return dec


_FUSED_MODES = ("switch", "prefix", "compact", "off")
_DECIDE_MODES = ("scan", "batched")


def _resolve_decide(decide) -> str:
    """Static decide-pass lowering for the compact dispatch: the batched
    intra-window decide by default, ``"scan"`` pinning the sequential
    reference oracle."""
    if decide is None:
        decide = "batched"
    if decide not in _DECIDE_MODES:
        raise ValueError(f"decide={decide!r} not in {_DECIDE_MODES}")
    return decide


def _plan_static(plan, cfg: TorrConfig):
    """Resolve the latched plan to its static knobs: (planes, cap, cfg')."""
    if plan is None:
        return cfg.bit_planes, cfg.B, cfg
    plan.validate(cfg)
    return plan.planes, min(plan.banks, cfg.B), plan.thresholds(cfg)


def _resolve_bucket_cap(bucket_cap, plan, n_rows: int) -> int:
    """Static bucket capacity for the compact dispatch. Precedence (pinned
    by ``tests/test_decide_batched.py::test_bucket_cap_precedence``): the
    explicit ``bucket_cap`` argument wins, else the latched plan's
    ``KnobPlan.bucket_cap``, else full capacity (no overflow possible, no
    savings either).

    An explicit capacity above the dispatch's row count is clamped — a
    bucket can never hold more rows than exist — but *warns* (at trace
    time; the cap is static): silently shrinking a user's tier would let a
    ladder misconfigured for a different batch shape (e.g. an engine plan
    sized for S x N_max latched onto a single-window step) masquerade as a
    deliberate full-capacity choice."""
    cap, src = bucket_cap, "bucket_cap"
    if cap is None and plan is not None:
        cap, src = plan.bucket_cap, "plan.bucket_cap"
    if cap is None:
        return n_rows
    cap = int(cap)
    if cap < 1:
        raise ValueError(f"bucket_cap={cap} must be >= 1")
    if cap > n_rows:
        warnings.warn(
            f"{src}={cap} exceeds the dispatch's {n_rows} rows; clamping to "
            f"full capacity (the no-savings tier). The latched ladder was "
            f"likely sized for a different batch shape.",
            stacklevel=3)
        cap = n_rows
    return cap


def torr_window_step(
    state: TorrState,
    im: ItemMemory,
    q_packed_all: jax.Array,   # uint32 [N_max, D//32] proposal query HVs
    valid: jax.Array,          # bool [N_max]
    boxes: jax.Array,          # f32 [N_max, 4]
    queue_depth: jax.Array,    # int32 []
    cfg: TorrConfig,
    plan=None,                 # static KnobPlan (None = uncontrolled)
    fused=None,                # static: "switch" | "prefix" | "compact" | "off"
    ham_prefix_all=None,       # int32 [N_max, M, cap] hoisted prefix counts
    bucket_cap=None,           # static compact-dispatch bucket capacity
    decide=None,               # static: "batched" | "scan" (compact only)
) -> tuple[TorrState, WindowOutput, WindowTelemetry]:
    """Process one window; returns (new_state, detections, telemetry).

    ``plan`` is a static :class:`repro.control.plan.KnobPlan` latched by the
    QoS control plane: it caps Alg. 1's bank choice (``min`` — the full cap
    is a bit-exact no-op), selects the bit-slice planes the scans read, and
    offsets the tau thresholds. ``plan=None`` (or the full plan) reproduces
    the uncontrolled step bit-for-bit.

    ``fused`` (static) picks the full path's lowering. The default
    (``None`` -> ``"switch"``) routes the whole window's full-path scan
    through the Pallas kernel family (``aligner.full_scores_all``): all
    N_max proposals go through one fused bank/plane-gated XNOR-popcount
    pass *before* the scan, and the delta branch's Eq. 6 correction rides
    the scalar-prefetch kernel — bit-identical to the jnp oracle.
    ``"prefix"`` is the vmap-shaped lowering the batched multi-stream step
    selects (one bank-prefix pass instead of a per-bank switch;
    ``ham_prefix_all`` carries the counts when the caller hoisted the
    kernel over a whole stream batch); ``"compact"`` is the reuse-aware
    compact-then-compute dispatch: a metadata-only decide pass produces the
    path vector first, the fused scan runs only over the full-path
    proposals compacted to the static ``bucket_cap`` tier (see
    ``aligner.compact_full_scores`` — overflow falls back exactly), and an
    apply pass replays the decisions; ``"off"`` keeps the legacy
    per-proposal oracle in-branch (the reference executable, and the
    cheaper trade for windows that rarely take the full path on branchy
    CPU backends — the hoisted scan runs per window, where the in-branch
    oracle runs per full-path proposal).

    ``bucket_cap`` (static, ``fused="compact"`` only) caps the compacted
    bucket; ``None`` defers to the latched plan's ``bucket_cap``, else full
    capacity. Engines pick it per window from the telemetry path-mix EWMA
    (``fused="auto"``), bounded by ``core.policy.bucket_ladder``.

    ``decide`` (static, ``fused="compact"`` only) picks the decide pass's
    lowering: ``"batched"`` (the ``None`` default) runs the batched
    intra-window decide — one wide snapshot-nearest pass plus the
    conflict-resolution scan (:func:`_decide_pass_batched`) — while
    ``"scan"`` pins the sequential per-proposal FSM
    (:func:`_decide_pass`), kept as the reference oracle. Both are
    bit-identical by construction; the differential harness in
    ``tests/test_decide_batched.py`` enforces it.
    """
    if fused is None:
        fused = "switch"
    if fused not in _FUSED_MODES:
        raise ValueError(f"fused={fused!r} not in {_FUSED_MODES}")
    planes, cap, cfg = _plan_static(plan, cfg)
    n_valid = jnp.sum(valid.astype(jnp.int32))
    high = policy.high_load(n_valid, queue_depth, cfg)
    banks = policy.select_banks(n_valid, queue_depth, cfg)
    if plan is not None and plan.banks < cfg.B:
        banks = jnp.minimum(banks, jnp.int32(plan.banks))
    wmask = plan_word_mask(cfg, banks, planes)
    arange = jnp.arange(cfg.N_max, dtype=jnp.int32)

    decide_id, btier = DECIDE_NONE, 0
    if fused == "compact":
        decide_mode = _resolve_decide(decide)
        decide_id = DECIDE_IDS[decide_mode]
        btier = _resolve_bucket_cap(bucket_cap, plan, cfg.N_max)
        decide_fn = (_decide_pass_batched if decide_mode == "batched"
                     else _decide_pass)
        dec = decide_fn(state.cache, q_packed_all, valid, cfg, banks,
                        planes, high)
        acc_rows = al.compact_full_scores(
            q_packed_all, dec[0] == PATH_FULL,
            jnp.broadcast_to(banks, (cfg.N_max,)), im, cfg, planes=planes,
            cap=cap, bucket_cap=btier)
        body = _proposal_body(cfg, im, state.task_weights, banks, planes,
                              wmask, high, acc_full_all=acc_rows,
                              fused_delta=True, decided=True)
        cache, (outs, telem) = jax.lax.scan(
            body, state.cache, (q_packed_all, valid, arange) + dec)
    else:
        acc_full_all = None
        if fused != "off":
            acc_full_all = al.full_scores_all(
                q_packed_all, im, banks, cfg, planes=planes, cap=cap,
                mode=fused, ham_prefix=ham_prefix_all)

        # The scalar-prefetch delta kernel pays off where branch economy is
        # real (the "switch" lowering: only the selected path executes).
        # Under the vmapped "prefix" lowering every lane computes all three
        # branches, and a budget-deep scalar-streaming grid per lane is the
        # wrong shape — the vectorized jnp gather-einsum IS the batched
        # scatter-accumulate there, so the oracle form is kept deliberately.
        body = _proposal_body(cfg, im, state.task_weights, banks, planes,
                              wmask, high, acc_full_all=acc_full_all,
                              fused_delta=fused == "switch")
        cache, (outs, telem) = jax.lax.scan(
            body, state.cache, (q_packed_all, valid, arange))

    return _finish_window(cache, state.task_weights, outs, telem, valid,
                          boxes, queue_depth, banks, n_valid, high, planes,
                          fused_mode=FUSED_IDS[fused], decide_mode=decide_id,
                          bucket_tier=btier)


def _finish_window(cache, task_w, outs, telem, valid, boxes, queue_depth,
                   banks, n_valid, high, planes, fused_mode=FUSED_IDS["off"],
                   decide_mode=DECIDE_NONE, bucket_tier=0):
    """Assemble (state, output, telemetry) from one window's scan results —
    shared by every lowering of the step so the trace vocabulary cannot
    drift between them. ``fused_mode``/``decide_mode``/``bucket_tier`` are
    the *static* resolved lowering knobs (``types.FUSED_IDS`` /
    ``types.DECIDE_IDS`` encodings) the dispatching step records into the
    trace."""
    actions, d_counts, rhos, active = telem
    # padding actions (3) are reported as bypass with zero cost
    path = jnp.where(actions == 3, PATH_BYPASS, actions)
    telemetry = WindowTelemetry(
        path=path.astype(jnp.int32),
        delta_count=d_counts.astype(jnp.int32),
        banks=banks,
        rho=rhos.astype(jnp.float32),
        n_valid=n_valid,
        reasoner_active=jnp.logical_and(active, valid),
        queue_depth=jnp.asarray(queue_depth, jnp.int32),
        high_load=high,
        planes=jnp.int32(planes),
        fused_mode=jnp.int32(fused_mode),
        decide_mode=jnp.int32(decide_mode),
        bucket_tier=jnp.int32(bucket_tier),
    )
    out = WindowOutput(
        scores=outs,
        best=jnp.argmax(outs, axis=-1).astype(jnp.int32),
        boxes=boxes,
    )
    return TorrState(cache=cache, task_weights=task_w), out, telemetry


# ---------------------------------------------------------------------------
# Multi-stream batched engine substrate
# ---------------------------------------------------------------------------

def init_multi_stream_state(cfg: TorrConfig, task_w: jax.Array) -> TorrState:
    """Stacked state for S independent streams.

    ``task_w`` is f32 [S, M] — one precomputed reasoner-weight row per
    stream slot (streams may serve different tasks). Every state leaf gains
    a leading stream axis; the per-stream query caches start empty.
    """
    task_w = jnp.asarray(task_w, jnp.float32)
    n_streams = task_w.shape[0]
    return TorrState(
        cache=query_cache.init_cache_batch(cfg, n_streams),
        task_weights=task_w,
    )


def torr_multi_stream_step(
    state: TorrState,          # stacked: every leaf has leading [S] axis
    im: ItemMemory,            # shared item memory (task knowledge)
    q_packed_all: jax.Array,   # uint32 [S, N_max, D//32]
    valid: jax.Array,          # bool [S, N_max]
    boxes: jax.Array,          # f32 [S, N_max, 4]
    queue_depth: jax.Array,    # int32 [S] per-stream backlog
    cfg: TorrConfig,
    serial: bool = False,      # static: lax.map instead of vmap
    plan=None,                 # static KnobPlan shared by all S windows
    fused=None,                # static: "switch"|"prefix"|"compact"|"off"
    bucket_cap=None,           # static compact-dispatch bucket capacity
    decide=None,               # static: "batched" | "scan" (compact only)
) -> tuple[TorrState, WindowOutput, WindowTelemetry]:
    """One compiled step over S streams' windows.

    All S windows of one batched step share the latched ``plan`` (the
    window-latched register analogue: one plan per dispatch); each window's
    telemetry still records it individually.

    Semantically identical to running ``torr_window_step`` once per stream:
    each slot keeps its own cache, task weights and queue depth, so Alg. 1's
    load gating (H, D') is evaluated per stream. Idle slots (``valid``
    all-False) ride the pad branch and leave their cache intact.

    Two bit-identical lowerings, selected by the static ``serial`` flag:

      * ``serial=False`` (default) — ``jax.vmap`` of the window FSM: the
        XNOR-popcount and delta arithmetic of all S slots batch across
        vector lanes. Under vmap the per-proposal ``lax.switch`` lowers to
        compute-all-paths-and-select, the right trade on a TPU whose wide
        VPU is otherwise idle between windows.
      * ``serial=True`` — ``jax.lax.map`` over slots: streams run
        sequentially *inside one executable*, preserving scalar branch
        economy (only the selected path executes) while still amortizing
        the per-window host dispatch. The right trade on branchy CPU
        backends; ~2x over the per-stream Python loop in table6.

    ``fused`` defaults per lowering: the vmap lowering takes the
    ``"prefix"`` kernel dispatch (under vmap a per-bank ``lax.switch``
    would execute every branch on the whole batch), the serial lowering
    takes ``"switch"`` (branch economy survives inside ``lax.map``). In
    prefix mode the bank-prefix kernel is hoisted *out* of the per-stream
    lowering and runs once over the flattened S x N_max proposal batch —
    the item-memory tile is read once per query block for the whole step,
    and each stream's window selects its traced bank choice from the
    precomputed boundary counts. All of it is bit-identical to
    ``fused="off"``, the legacy oracle step.

    ``fused="compact"`` is the reuse-aware third lowering: the decide pass
    runs per stream (vmapped — metadata only, no item-memory reads), the
    full-path proposals of *all* S windows are compacted together into one
    static ``bucket_cap``-sized bucket (``core.policy.bucket_ladder`` tiers
    up to S x N_max), one fused kernel pass scans only the bucket, and the
    apply pass (vmap or lax.map per ``serial``) replays the decisions.
    Bit-identical to ``fused="off"`` for any tier — an overflowing bucket
    falls back to the hoisted all-rows pass via a scalar cond.
    """
    if fused is None:
        fused = "switch" if serial else "prefix"

    if fused == "compact":
        return _multi_stream_compact_step(
            state, im, q_packed_all, valid, boxes, queue_depth, cfg,
            serial=serial, plan=plan, bucket_cap=bucket_cap, decide=decide)

    ham_prefix = None
    if fused == "prefix":
        planes, cap, _ = _plan_static(plan, cfg)
        S, N, W = q_packed_all.shape
        ham_prefix = al.plan_prefix_hamming(
            q_packed_all.reshape(S * N, W), im, cfg, planes=planes, cap=cap,
        ).reshape(S, N, cfg.M, cap)

    if serial:
        def body(args):
            st, q, v, b, qd, hp = args
            return torr_window_step(st, im, q, v, b, qd, cfg, plan=plan,
                                    fused=fused, ham_prefix_all=hp)

        return jax.lax.map(
            body,
            (state, q_packed_all, valid, boxes, queue_depth, ham_prefix),
        )

    def step(st, im_, q, v, b, qd, hp):
        return torr_window_step(st, im_, q, v, b, qd, cfg, plan=plan,
                                fused=fused, ham_prefix_all=hp)

    return jax.vmap(step, in_axes=(0, None, 0, 0, 0, 0, 0))(
        state, im, q_packed_all, valid, boxes, queue_depth, ham_prefix
    )


def _multi_stream_compact_step(
    state: TorrState, im: ItemMemory, q_packed_all, valid, boxes,
    queue_depth, cfg: TorrConfig, *, serial: bool, plan, bucket_cap,
    decide=None,
) -> tuple[TorrState, WindowOutput, WindowTelemetry]:
    """The batched compact-then-compute lowering (``fused="compact"``).

    Three hoisted stages instead of one monolithic per-stream FSM:

      1. *decide* — the metadata-only Alg. 1 pass runs per stream (vmapped;
         it reads the depth-K cache, never the item memory), yielding each
         window's path vector and per-proposal decisions;
      2. *compact + compute* — the full-path rows of all S windows are
         compacted together into one static ``bucket_cap`` bucket and a
         single fused kernel pass scans only the bucket
         (``aligner.compact_full_scores``), so the XNOR-popcount bytes
         scale with the *miss* rate, not the proposal count;
      3. *apply* — the value-carrying scan replays the recorded decisions
         per stream (vmap lanes, or lax.map when ``serial`` for scalar
         branch economy), gathering full-path accumulators from the bucket.
    """
    planes, cap, cfg = _plan_static(plan, cfg)
    S, N, W = q_packed_all.shape
    bcap = _resolve_bucket_cap(bucket_cap, plan, S * N)

    n_valid = jnp.sum(valid.astype(jnp.int32), axis=-1)        # [S]
    high = policy.high_load(n_valid, queue_depth, cfg)          # [S]
    banks = jax.vmap(lambda n, qd: policy.select_banks(n, qd, cfg))(
        n_valid, queue_depth)                                   # [S]
    if plan is not None and plan.banks < cfg.B:
        banks = jnp.minimum(banks, jnp.int32(plan.banks))

    decide_mode = _resolve_decide(decide)
    if decide_mode == "batched":
        dec, aux = jax.vmap(
            lambda c, q, v, b, h: _decide_pass_batched_aux(c, q, v, cfg, b,
                                                           planes, h)
        )(state.cache, q_packed_all, valid, banks, high)
    else:
        dec = jax.vmap(
            lambda c, q, v, b, h: _decide_pass(c, q, v, cfg, b, planes, h)
        )(state.cache, q_packed_all, valid, banks, high)
        aux = None

    acc_rows = al.compact_full_scores(
        q_packed_all.reshape(S * N, W),
        (dec[0] == PATH_FULL).reshape(S * N),
        jnp.broadcast_to(banks[:, None], (S, N)).reshape(S * N),
        im, cfg, planes=planes, cap=cap, bucket_cap=bcap,
    ).reshape(S, N, cfg.M)

    # The batched decide's conflict byproducts unlock the batched apply
    # (value math hoisted dispatch-wide); ``decide="scan"`` pins the
    # sequential reference pipeline end-to-end — decide scan + per-proposal
    # apply scan — which is also the baseline the bench rows compare
    # against. The serial lowering keeps the apply scan regardless: its
    # lax.switch branch economy is real there.
    if decide_mode == "batched" and not serial:
        return _apply_pass_batched(state, im, q_packed_all, valid, boxes,
                                   queue_depth, cfg, banks, planes, high,
                                   n_valid, dec, aux, acc_rows,
                                   bucket_tier=bcap)

    def apply_one(args):
        st, q, v, b, qd, bk, h, nv, dec_s, accs = args
        wmask = plan_word_mask(cfg, bk, planes)
        body = _proposal_body(cfg, im, st.task_weights, bk, planes,
                              wmask, h, acc_full_all=accs,
                              fused_delta=True, decided=True)
        cache, (outs, telem) = jax.lax.scan(
            body, st.cache,
            (q, v, jnp.arange(cfg.N_max, dtype=jnp.int32)) + dec_s)
        return _finish_window(cache, st.task_weights, outs, telem, v, b, qd,
                              bk, nv, h, planes,
                              fused_mode=FUSED_IDS["compact"],
                              decide_mode=DECIDE_IDS[decide_mode],
                              bucket_tier=bcap)

    args = (state, q_packed_all, valid, boxes, queue_depth, banks, high,
            n_valid, dec, acc_rows)
    if serial:
        return jax.lax.map(apply_one, args)
    return jax.vmap(apply_one)(args)


def torr_stream_batch_step(
    state: TorrState, im: ItemMemory, batch: StreamBatch, cfg: TorrConfig,
    serial: bool = False, plan=None, fused=None, bucket_cap=None,
    decide=None,
) -> tuple[TorrState, WindowOutput, WindowTelemetry]:
    """`torr_multi_stream_step` over a packed :class:`StreamBatch`."""
    return torr_multi_stream_step(
        state, im, batch.q_packed, batch.valid, batch.boxes,
        batch.queue_depth, cfg, serial=serial, plan=plan, fused=fused,
        bucket_cap=bucket_cap, decide=decide,
    )
