"""Banked, bit-sliced item memory (paper Sec. 4.1/4.3).

The ASIC stores M concept hypervectors bit-sliced across B SRAM banks with
per-bank enables realizing the effective dimension D'. On TPU we keep three
coherent views, each matched to an access pattern:

  * ``bipolar``  int8  [M, D]   — source of truth (training / prototypes)
  * ``packed``   uint32 [M, D/32] — full-scan XNOR-popcount path. Banks are
    contiguous 32-bit word ranges, so D' gating is a *prefix* of words:
    words_eff = banks * bank_words. We mask (functional mode) or slice
    (kernel specialization) that prefix.
  * ``dmajor``   int8  [D, M]   — delta path: one flipped dimension i reads
    the contiguous row dmajor[i, :], the TPU analogue of the ASIC's
    column-broadcast to W class lanes.

All views are derived from ``bipolar`` by :func:`build_item_memory`; they are
plain pytree leaves so the structure shards/jits cleanly.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from . import hdc
from .types import TorrConfig


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class ItemMemory:
    bipolar: jax.Array   # int8  [M, D]
    packed: jax.Array    # uint32 [M, D//32]
    dmajor: jax.Array    # int8  [D, M]

    @property
    def M(self) -> int:
        return self.bipolar.shape[0]

    @property
    def D(self) -> int:
        return self.bipolar.shape[1]

    def tree_flatten(self):
        return ((self.bipolar, self.packed, self.dmajor), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)


def build_item_memory(bipolar: jax.Array) -> ItemMemory:
    """Derive all access-pattern views from bipolar codes [M, D]."""
    return ItemMemory(
        bipolar=bipolar.astype(jnp.int8),
        packed=hdc.pack_bits(bipolar),
        dmajor=jnp.transpose(bipolar).astype(jnp.int8),
    )


def random_item_memory(key: jax.Array, cfg: TorrConfig) -> ItemMemory:
    """Random concept codes (the classic HDC item memory)."""
    return build_item_memory(hdc.random_hv(key, (cfg.M, cfg.D)))


def item_memory_from_prototypes(
    feats: jax.Array, R: jax.Array, key: jax.Array | None = None
) -> ItemMemory:
    """Class prototypes: bundle sign-projected examples per class.

    ``feats`` is [M, n_examples, d]; ``R`` the [D, d] projection. This is how
    the item memory is *trained* from encoder features so that the associative
    aligner realizes the CLIP-transferred semantics.
    """
    hv = hdc.sign_project(feats, R)            # [M, n, D]
    M = hv.shape[0]
    if key is None:
        bundled = jnp.where(jnp.sum(hv.astype(jnp.int32), 1) >= 0, 1, -1).astype(jnp.int8)
    else:
        keys = jax.random.split(key, M)
        bundled = jax.vmap(hdc.bundle)(hv, keys)
    return build_item_memory(bundled)


def word_mask(cfg: TorrConfig, banks: jax.Array | int) -> jax.Array:
    """Boolean mask [D//32] of packed words enabled by ``banks`` banks."""
    words_eff = jnp.asarray(banks, jnp.int32) * cfg.bank_words
    return jnp.arange(cfg.words, dtype=jnp.int32) < words_eff


def dim_mask(cfg: TorrConfig, banks: jax.Array | int) -> jax.Array:
    """Boolean mask [D] of dimensions enabled by ``banks`` banks."""
    d_eff = jnp.asarray(banks, jnp.int32) * cfg.bank_dims
    return jnp.arange(cfg.D, dtype=jnp.int32) < d_eff
