"""Banked, bit-sliced item memory (paper Sec. 4.1/4.3).

The ASIC stores M concept hypervectors bit-sliced across B SRAM banks with
per-bank enables realizing the effective dimension D'. On TPU we keep four
coherent views, each matched to an access pattern:

  * ``bipolar``  int8  [M, D]   — source of truth (training / prototypes)
  * ``packed``   uint32 [M, D/32] — full-scan XNOR-popcount path. Banks are
    contiguous 32-bit word ranges, so D' gating is a *prefix* of words:
    words_eff = banks * bank_words. We mask (functional mode) or slice
    (kernel specialization) that prefix.
  * ``pmajor``   uint32 [M, D/32] — the same packed words reordered
    *bit-plane-major*: word w belongs to plane ``w % bit_planes`` and the
    planes are laid out contiguously (plane 0 first). Precision gating —
    the QoS governor dropping low-order planes under pressure — then reads
    a per-plane-block prefix instead of gathering strided columns, the TPU
    analogue of simply not reading the low-order bit-slice SRAMs.
  * ``dmajor``   int8  [D, M]   — delta path: one flipped dimension i reads
    the contiguous row dmajor[i, :], the TPU analogue of the ASIC's
    column-broadcast to W class lanes.

Because every bank's words are striped uniformly across the planes
(``bank_words % bit_planes == 0``, enforced by ``TorrConfig``), bank gating
and plane gating compose: the dims enabled by a (banks, planes) knob plan
are exactly ``{d : word(d) < banks * bank_words  and  word(d) % P < planes}``
with ``d_eff = banks * bank_dims * planes / P``.

All views are derived from ``bipolar`` by :func:`build_item_memory`; they are
plain pytree leaves so the structure shards/jits cleanly.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from . import hdc
from .types import TorrConfig


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class ItemMemory:
    bipolar: jax.Array   # int8  [M, D]
    packed: jax.Array    # uint32 [M, D//32]
    dmajor: jax.Array    # int8  [D, M]
    pmajor: jax.Array    # uint32 [M, D//32] plane-major word order

    @property
    def M(self) -> int:
        return self.bipolar.shape[0]

    @property
    def D(self) -> int:
        return self.bipolar.shape[1]

    def tree_flatten(self):
        return ((self.bipolar, self.packed, self.dmajor, self.pmajor), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)


def plane_permutation(words: int, plane_total: int) -> np.ndarray:
    """Word permutation packed -> plane-major: plane p's words (w % P == p)
    first, ascending within each plane block. Static (trace-time) numpy."""
    order = np.concatenate([
        np.arange(p, words, plane_total) for p in range(plane_total)
    ])
    return order.astype(np.int32)


def plane_sel(limit_words: int, planes: int, plane_total: int) -> np.ndarray:
    """Static indices of the enabled words among the first ``limit_words``
    packed words (a bank prefix), keeping ``planes`` of ``plane_total``
    bit-slice planes — in *plane-major* order, i.e. the column order of a
    contiguous per-plane-block prefix slice of ``pmajor``."""
    sel = np.concatenate([
        np.arange(p, limit_words, plane_total) for p in range(planes)
    ])
    return sel.astype(np.int32)


def plan_word_sel(cfg: TorrConfig, banks: int, planes: int) -> np.ndarray:
    """Static enabled-word indices for a (banks, planes) plan, plane-major.
    Used by the host-latched kernel wrappers (``kernels.ops``), where the
    plan is static."""
    return plane_sel(banks * cfg.bank_words, planes, cfg.bit_planes)


def bank_plane_sel(cfg: TorrConfig, banks: int, planes: int) -> np.ndarray:
    """Static enabled-word indices for a (banks, planes) plan in *bank-major*
    order (bank 0's enabled words first, plane-major inside each bank).

    This is the column order of the fused kernel path
    (``core.aligner.full_scores_all``): every bank's enabled words form a
    contiguous run, so the bank-prefix kernel can publish the hamming count
    at each bank boundary, and each static plan reads exactly its enabled
    words. Hamming sums over columns, so any shared q/im order is exact."""
    return np.concatenate([
        np.arange(b * cfg.bank_words + p, (b + 1) * cfg.bank_words,
                  cfg.bit_planes)
        for b in range(banks)
        for p in range(planes)
    ]).astype(np.int32)


def pmajor_bank_blocks(
    pmajor: jax.Array, cfg: TorrConfig, banks: int, planes: int
) -> jax.Array:
    """The (banks, planes) plan's enabled item-memory words in the
    *bank-major* column order of :func:`bank_plane_sel`, assembled from
    static contiguous slices of the ``pmajor`` view.

    ``pmajor``'s plane-p block lays that plane's words out in packed word
    order, so bank b's plane-p words are the contiguous run
    ``[p * wpb + b * bank_plane_words, p * wpb + (b + 1) * bank_plane_words)``
    — reduced plans genuinely *read* proportionally fewer bytes (static
    slices), never a full-width gather or mask. uint32 [M, banks * planes *
    plane_words]."""
    wpb = pmajor.shape[-1] // cfg.bit_planes      # words per plane block
    bpw = cfg.plane_words                         # bank's words per plane
    return jnp.concatenate([
        pmajor[..., p * wpb + b * bpw: p * wpb + (b + 1) * bpw]
        for b in range(banks)
        for p in range(planes)
    ], axis=-1)


def build_item_memory(bipolar: jax.Array, plane_total: int = 4) -> ItemMemory:
    """Derive all access-pattern views from bipolar codes [M, D].

    ``plane_total`` sets the bit-slice grain of the ``pmajor`` view and must
    match the consuming config's ``bit_planes`` (pass it explicitly when the
    config is at hand) — a pmajor striped at the wrong grain would silently
    select the wrong columns under precision gating, so a non-dividing
    grain is an error, not a fallback.
    """
    packed = hdc.pack_bits(bipolar)
    words = packed.shape[-1]
    if words % plane_total:
        raise ValueError(
            f"plane_total={plane_total} does not divide the packed word "
            f"count {words} (D={32 * words})")
    perm = plane_permutation(words, plane_total)
    return ItemMemory(
        bipolar=bipolar.astype(jnp.int8),
        packed=packed,
        dmajor=jnp.transpose(bipolar).astype(jnp.int8),
        pmajor=packed[:, perm],
    )


def random_item_memory(key: jax.Array, cfg: TorrConfig) -> ItemMemory:
    """Random concept codes (the classic HDC item memory)."""
    return build_item_memory(hdc.random_hv(key, (cfg.M, cfg.D)),
                             plane_total=cfg.bit_planes)


def item_memory_from_prototypes(
    feats: jax.Array, R: jax.Array, key: jax.Array | None = None,
    plane_total: int = 4,
) -> ItemMemory:
    """Class prototypes: bundle sign-projected examples per class.

    ``feats`` is [M, n_examples, d]; ``R`` the [D, d] projection. This is how
    the item memory is *trained* from encoder features so that the associative
    aligner realizes the CLIP-transferred semantics.
    """
    hv = hdc.sign_project(feats, R)            # [M, n, D]
    M = hv.shape[0]
    if key is None:
        bundled = jnp.where(jnp.sum(hv.astype(jnp.int32), 1) >= 0, 1, -1).astype(jnp.int8)
    else:
        keys = jax.random.split(key, M)
        bundled = jax.vmap(hdc.bundle)(hv, keys)
    return build_item_memory(bundled, plane_total=plane_total)


def word_mask(cfg: TorrConfig, banks: jax.Array | int) -> jax.Array:
    """Boolean mask [D//32] of packed words enabled by ``banks`` banks."""
    words_eff = jnp.asarray(banks, jnp.int32) * cfg.bank_words
    return jnp.arange(cfg.words, dtype=jnp.int32) < words_eff


def plan_word_mask(
    cfg: TorrConfig, banks: jax.Array | int, planes: int
) -> jax.Array:
    """Boolean mask [D//32] of words enabled by a (banks, planes) plan.

    ``planes`` is static (the plan is host-latched); with all planes kept
    this constant-folds to :func:`word_mask` bit-for-bit.
    """
    wm = word_mask(cfg, banks)
    if planes >= cfg.bit_planes:
        return wm
    plane_of = jnp.arange(cfg.words, dtype=jnp.int32) % cfg.bit_planes
    return jnp.logical_and(wm, plane_of < planes)


def dim_mask(cfg: TorrConfig, banks: jax.Array | int) -> jax.Array:
    """Boolean mask [D] of dimensions enabled by ``banks`` banks."""
    d_eff = jnp.asarray(banks, jnp.int32) * cfg.bank_dims
    return jnp.arange(cfg.D, dtype=jnp.int32) < d_eff


def plan_dim_mask(
    cfg: TorrConfig, banks: jax.Array | int, planes: int
) -> jax.Array:
    """Boolean mask [D] of dimensions enabled by a (banks, planes) plan —
    the oracle-side statement of the plan (tests mask bipolar dims with it)."""
    word_of = jnp.arange(cfg.D, dtype=jnp.int32) // 32
    dm = dim_mask(cfg, banks)
    if planes >= cfg.bit_planes:
        return dm
    return jnp.logical_and(dm, (word_of % cfg.bit_planes) < planes)
