"""Associative cosine aligner: full-scan, delta-update and score readout.

Functional (masked) reference implementations of the two hardware access
patterns (paper Sec. 4.2/4.3). The Pallas kernels in ``repro.kernels`` are
drop-in accelerated versions validated against these.

Accumulators are *integer dot products* over the enabled dimensions; cosine
is applied only at readout (the ASIC's "normalization shift by log2 D'").
This makes Eq. 6's delta corrections exact in the integer domain.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .item_memory import (ItemMemory, bank_plane_sel, pmajor_bank_blocks,
                          word_mask)
from .types import TorrConfig


def full_dot(q_packed: jax.Array, im: ItemMemory, wmask: jax.Array) -> jax.Array:
    """Integer dot <q, h_j> over enabled words for all M classes.

    q_packed: uint32 [W]; im.packed: uint32 [M, W]; wmask: bool [W].
    dot = d_eff - 2 * hamming, with hamming counted on enabled words only.
    """
    x = jnp.bitwise_xor(q_packed[None, :], im.packed)          # [M, W]
    pc = jax.lax.population_count(x).astype(jnp.int32)         # [M, W]
    pc = jnp.where(wmask[None, :], pc, 0)
    d_eff = 32 * jnp.sum(wmask.astype(jnp.int32))
    return d_eff - 2 * jnp.sum(pc, axis=-1)                    # [M]


def delta_indices(
    q_new_packed: jax.Array,
    q_old_packed: jax.Array,
    wmask: jax.Array,
    budget: int,
    D: int,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """PSU (Sec. 4.4): flipped dims between queries, within the delta budget.

    Returns (idx [budget] int32, weight [budget] int32 in {-2,0,+2},
    count [] int32 = true |Delta| over enabled words). Padding entries have
    weight 0 and idx 0; if count > budget the caller must escalate to full
    (TorR-on-TPU adaptation: static budget instead of a data-dependent FIFO).
    """
    xor = jnp.bitwise_xor(q_new_packed, q_old_packed)
    xor = jnp.where(wmask, xor, jnp.uint32(0))
    count = jnp.sum(jax.lax.population_count(xor).astype(jnp.int32))
    shifts = jnp.arange(32, dtype=jnp.uint32)
    flip_bits = ((xor[:, None] >> shifts) & jnp.uint32(1)).reshape(D)   # [D] 0/1
    # first `budget` flipped dims in ascending order, 0-padded — exactly
    # jnp.nonzero(size=budget, fill_value=0), but as a binary search over
    # the flip-rank cumsum: the k-th flipped dim is the smallest d whose
    # cumulative flip count reaches k+1. Sized-nonzero lowers to a full
    # [D] sort and a scatter formulation hits XLA-CPU's scalar scatter
    # loop; at one call per proposal per window either dominated the whole
    # scan (~0.2 ms/call on CPU — ~8x the searchsorted form).
    cum = jnp.cumsum(flip_bits)
    k = jnp.arange(budget, dtype=jnp.int32)
    in_budget = k < count
    idx = jnp.where(
        in_budget,
        jnp.searchsorted(cum, k + 1, side="left").astype(jnp.int32), 0)
    # q_new bit at flipped idx: +1 bit -> new value +1 -> correction +2.
    new_bits = (q_new_packed[idx // 32] >> (idx % 32).astype(jnp.uint32)) & jnp.uint32(1)
    weight = jnp.where(new_bits == 1, 2, -2).astype(jnp.int32)
    weight = jnp.where(in_budget, weight, 0)
    return idx, weight, count


def delta_correct(
    acc: jax.Array, im: ItemMemory, idx: jax.Array, weight: jax.Array
) -> jax.Array:
    """Eq. 6: acc_j += sum_{i in Delta} (q_i^t - q_i^{t-1}) h_{j,i}.

    acc: int32 [M]; gathers rows of the D-major item memory.
    """
    rows = im.dmajor[idx, :].astype(jnp.int32)                 # [budget, M]
    return acc + jnp.einsum("k,km->m", weight, rows)


def delta_corrections(
    d_idx: jax.Array,     # int32 [L, budget] flipped dims (0-padded)
    d_weight: jax.Array,  # int32 [L, budget] in {-2, 0, +2} (0 = padding)
    im: ItemMemory,
    D: int,
) -> jax.Array:
    """Eq. 6 correction *terms* for a whole proposal batch: int32 [L, M]
    with ``corr[l] = sum_k d_weight[l, k] * dmajor[d_idx[l, k]]``.

    The correction is independent of the accumulator it lands on
    (:func:`delta_correct` is ``acc + corr``), so the batched apply pass
    hoists it out of the per-proposal scan. Lowered as a dense f32 matmul:
    the sparse per-row weights scatter into a [L, D] vector and one
    GEMM against the D-major item memory reads every matrix once —
    instead of gathering ``budget`` [M] rows per lane (~4x the bytes at
    serving shapes). Bit-identical to the int32 gather-einsum: weights are
    in {-2, 0, +2}, dmajor entries in {-1, +1} and each row has at most
    ``budget`` nonzero terms, so every f32 partial sum is an integer of
    magnitude <= 2*budget << 2^24 — exact under any accumulation order.
    Padding entries scatter weight 0 onto dim 0, contributing nothing even
    when dim 0 is a genuine flip."""
    L = d_idx.shape[0]
    wvec = jnp.zeros((L, D), jnp.float32).at[
        jnp.arange(L)[:, None], d_idx].add(d_weight.astype(jnp.float32))
    return jnp.round(wvec @ im.dmajor.astype(jnp.float32)).astype(jnp.int32)


def readout(acc: jax.Array, d_eff: jax.Array | int) -> jax.Array:
    """Cosine scores from integer accumulators (normalization 'shift')."""
    return acc.astype(jnp.float32) / jnp.asarray(d_eff, jnp.float32)


def full_scores(
    q_packed: jax.Array, im: ItemMemory, cfg: TorrConfig, banks: jax.Array | int
) -> tuple[jax.Array, jax.Array]:
    """Convenience: (acc int32 [M], cosine f32 [M]) for a full scan."""
    wmask = word_mask(cfg, banks)
    acc = full_dot(q_packed, im, wmask)
    d_eff = jnp.asarray(banks, jnp.int32) * cfg.bank_dims
    return acc, readout(acc, d_eff)


# ---------------------------------------------------------------------------
# Fused-kernel dispatch shim (traced banks, static plan cap)
# ---------------------------------------------------------------------------

def _plan_columns_bank_major(
    q_packed_all: jax.Array, im: ItemMemory, banks: int, planes: int,
    cfg: TorrConfig,
) -> tuple[jax.Array, jax.Array]:
    """(q_sel, im_sel) restricted to a *static* (banks, planes) plan's
    enabled words, in the shared bank-major column order of
    ``item_memory.bank_plane_sel`` (bank boundaries stay word prefixes, the
    bank-prefix kernel's contract). Full precision keeps the original
    contiguous bank prefix of ``packed``; reduced precision assembles
    static contiguous slices of ``pmajor`` for the item memory and a static
    gather for the (tiny) query batch."""
    if planes >= cfg.bit_planes:
        we = banks * cfg.bank_words
        return q_packed_all[:, :we], im.packed[:, :we]
    sel = bank_plane_sel(cfg, banks, planes)
    return (q_packed_all[:, sel],
            pmajor_bank_blocks(im.pmajor, cfg, banks, planes))


def plan_prefix_hamming(
    q_packed: jax.Array,       # uint32 [N, D//32] (N may be S*N_max flattened)
    im: ItemMemory,
    cfg: TorrConfig,
    *,
    planes: int,
    cap: int,
    interpret: bool | None = None,
    use_kernel: bool = True,
) -> jax.Array:
    """Bank-prefix hamming over a (cap, planes) plan's enabled words:
    int32 [N, M, cap]. Column selection + the ``bank_prefix_hamming``
    kernel; the batched multi-stream step hoists this single call over its
    flattened S x N_max proposal batch (one kernel pass per step — a
    per-stream call under vmap would re-enter the grid once per stream)."""
    from ..kernels import fused_window as fw

    q_sel, im_sel = _plan_columns_bank_major(q_packed, im, cap, planes, cfg)
    return fw.bank_prefix_hamming_any(q_sel, im_sel, cap=cap,
                                      interpret=interpret,
                                      use_kernel=use_kernel)


def full_scores_all(
    q_packed_all: jax.Array,   # uint32 [N, D//32] all proposals of a window
    im: ItemMemory,
    banks: jax.Array,          # traced int32 [] — Alg. 1's per-window choice
    cfg: TorrConfig,
    *,
    planes: int,               # static (latched plan)
    cap: int,                  # static plan cap on banks (cfg.B uncontrolled)
    mode: str = "switch",
    ham_prefix: jax.Array | None = None,  # precomputed [N, M, cap] (hoisted)
    interpret: bool | None = None,
    use_kernel: bool = True,
) -> jax.Array:
    """Full-path integer accumulators for *all* proposals: int32 [N, M].

    The traced-banks dispatch shim over ``kernels.fused_window``: the whole
    window's proposal batch goes through one fused XNOR-popcount scan (the
    item-memory tile is read once per query block and streamed through
    VMEM), instead of one masked full-width ``[M, W]`` xor per proposal
    inside the scan — bit-identical to :func:`full_dot` under the same
    ``(banks, planes)``, because integer hamming sums are order-invariant
    and the readout formula is shared.

    Two lowerings of the traced ``banks``, both a bounded family of
    <= B x P specialized executables keyed by the static ``(cap, planes)``:

      * ``mode="switch"`` — ``lax.switch`` over the <= cap bank branches;
        only the selected branch executes (reads exactly ``banks`` banks'
        enabled words), the right trade wherever branches stay scalar
        (single-stream jit, the lax.map serial lowering).
      * ``mode="prefix"`` — one ``bank_prefix_hamming`` pass over the
        plan-capped prefix emitting every bank boundary's count, then a
        traced gather selects ``banks``. Under vmap a switch would execute
        *every* branch on the whole batch; the prefix pass reads the capped
        width once. The batched multi-stream step additionally hoists the
        kernel call itself over the flattened S x N_max proposal batch and
        passes the per-stream slice in as ``ham_prefix``.
    """
    from ..kernels import fused_window as fw

    banks = jnp.clip(jnp.asarray(banks, jnp.int32), 1, cap)
    if mode == "prefix":
        ham_p = ham_prefix
        if ham_p is None:
            ham_p = plan_prefix_hamming(
                q_packed_all, im, cfg, planes=planes, cap=cap,
                interpret=interpret, use_kernel=use_kernel)  # [N, M, cap]
        ham = ham_p[..., banks - 1]
        d_eff = cfg.d_eff_planned(banks, planes)
        return d_eff - 2 * ham
    if mode != "switch":
        raise ValueError(f"unknown fused dispatch mode {mode!r}")

    def make_branch(b: int):
        def branch(q):
            q_sel, im_sel = _plan_columns_bank_major(q, im, b, planes, cfg)
            acc, _best, _top2 = fw.fused_scores_any(
                q_sel, im_sel, d_eff=int(cfg.d_eff_planned(b, planes)),
                interpret=interpret, use_kernel=use_kernel)
            return acc
        return branch

    return jax.lax.switch(
        banks - 1, [make_branch(b) for b in range(1, cap + 1)], q_packed_all)


def prefix_select(
    ham_prefix: jax.Array,     # int32 [..., M, cap] bank-boundary counts
    banks: jax.Array,          # int32 [...] traced per-row bank choice
    planes: int,
    cfg: TorrConfig,
) -> jax.Array:
    """Accumulators from bank-prefix hamming counts: each row selects its
    traced bank boundary and normalizes by its own D'. int32 [..., M]."""
    ham = jnp.take_along_axis(
        ham_prefix, (banks - 1)[..., None, None], axis=-1)[..., 0]
    d_eff = cfg.d_eff_planned(banks, planes)
    return d_eff[..., None] - 2 * ham


def compact_full_scores(
    q_flat: jax.Array,         # uint32 [R, D//32] flattened proposal batch
    full_mask: jax.Array,      # bool [R] rows whose window FSM chose FULL
    banks_flat: jax.Array,     # int32 [R] each row's window's bank choice
    im: ItemMemory,
    cfg: TorrConfig,
    *,
    planes: int,               # static (latched plan)
    cap: int,                  # static plan cap on banks
    bucket_cap: int,           # static bucket capacity (the ladder tier)
    interpret: bool | None = None,
    use_kernel: bool = True,
) -> jax.Array:
    """Compact-then-compute full-path accumulators: int32 [R, M], exact on
    every ``full_mask`` row (other rows are zero — the apply pass never
    reads them).

    The third dispatch contract (``kernels/README.md``): the decide pass
    already produced the path vector, so the fused XNOR-popcount scan runs
    **only over the full-path rows**, compacted by a sized ``nonzero``
    gather into a dense bucket padded to the *static* ``bucket_cap`` (a
    ``core.policy.bucket_ladder`` tier — the executable family stays
    bounded at ladder x plan). Each bucket row selects its own window's
    traced bank boundary from the prefix counts, and the results scatter
    back to their flat positions. If the window mix overflows the latched
    tier (``n_full > bucket_cap``) a *scalar* ``lax.cond`` falls back to
    the hoisted all-rows prefix pass — bit-exact always, merely slower, so
    an engine's tier mispredict can never corrupt results.
    """
    R = q_flat.shape[0]
    bucket_cap = min(int(bucket_cap), R)
    banks_flat = jnp.clip(jnp.asarray(banks_flat, jnp.int32), 1, cap)
    n_full = jnp.sum(full_mask.astype(jnp.int32))

    def from_bucket():
        (rows,) = jnp.nonzero(full_mask, size=bucket_cap, fill_value=R)
        safe = jnp.minimum(rows, R - 1)
        ham_b = plan_prefix_hamming(
            q_flat[safe], im, cfg, planes=planes, cap=cap,
            interpret=interpret, use_kernel=use_kernel)     # [cap_b, M, cap]
        acc_b = prefix_select(ham_b, banks_flat[safe], planes, cfg)
        return jnp.zeros((R, cfg.M), jnp.int32).at[rows].set(
            acc_b, mode="drop")

    def hoisted():
        ham = plan_prefix_hamming(
            q_flat, im, cfg, planes=planes, cap=cap,
            interpret=interpret, use_kernel=use_kernel)     # [R, M, cap]
        acc = prefix_select(ham, banks_flat, planes, cfg)
        return jnp.where(full_mask[:, None], acc, 0)

    return jax.lax.cond(n_full <= bucket_cap, from_bucket, hoisted)


def lookup_hamming_all(
    q_packed_all: jax.Array,   # uint32 [N, W] query batch
    entries: jax.Array,        # uint32 [K, W] lookup entries
    wmask: jax.Array,          # bool [W] plan-enabled words (may be traced)
    *, interpret: bool | None = None, use_kernel: bool = True,
) -> jax.Array:
    """Batched associative-lookup hamming table: int32 [N, K] masked
    distances of every query against every entry (``ops.masked_hamming_all``
    — the batched decide pass's PSU primitive). ``entries`` may be the
    cache snapshot's packed queries or the proposal batch itself (the
    intra-window writer table); bit-identical to the per-proposal masked
    popcount in ``query_cache.nearest`` because disabled words are zeroed
    on both operands before the plain hamming sum."""
    from ..kernels import ops

    return ops.masked_hamming_all(q_packed_all, entries, wmask,
                                  interpret=interpret, use_kernel=use_kernel)


def delta_apply(
    acc: jax.Array, im: ItemMemory, idx: jax.Array, weight: jax.Array,
    *, interpret: bool | None = None, use_kernel: bool = True,
) -> jax.Array:
    """Eq. 6 through the kernel family (`fused_window.delta_apply`):
    scalar-prefetch row streaming instead of :func:`delta_correct`'s
    [budget, M] gather+einsum. Bit-identical (integer adds)."""
    from ..kernels import fused_window as fw

    return fw.delta_apply(acc, im.dmajor, idx, weight, interpret=interpret,
                          use_kernel=use_kernel)


def full_dot_mxu(q_bipolar: jax.Array, im: ItemMemory,
                 dmask: jax.Array) -> jax.Array:
    """Beyond-paper alternative: bipolar cosine as a bf16 MXU matmul.

    The paper's XNOR-popcount path minimizes *traffic* (1 bit/dim); on TPU
    the MXU's 197 TFLOP/s bf16 can beat the VPU popcount pipeline when the
    item memory already resides in VMEM (compute-bound regime, large M·D).
    Exact for D <= 2^24 (bf16 holds the ±1 products; accumulation is f32 on
    the MXU). q_bipolar: int8 [..., D]; returns int32 dots [..., M].
    """
    q = jnp.where(dmask, q_bipolar, 0).astype(jnp.bfloat16)
    h = im.bipolar.astype(jnp.bfloat16)
    dots = jax.lax.dot_general(
        q, h, (((q.ndim - 1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    return jnp.round(dots).astype(jnp.int32)
