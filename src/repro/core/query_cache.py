"""Depth-K query cache with per-entry per-class accumulators (paper Fig. 4).

Each entry carries everything the three execution paths need:

  * the packed query hypervector (for the PSU's nearest-match + XOR),
  * the integer per-class accumulator and the plan tag it was computed under
    (``types.plan_tag(banks, planes)``: delta corrections are only exact
    against the same enabled dimensions, i.e. the same banks *and* the same
    bit-slice planes),
  * the cached *final* output scores (for aggressive bypass),
  * the aligner top-k key + margin of the last window (reasoner gating),
  * age / validity bookkeeping for LRU refresh.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import hdc
from .item_memory import plan_word_mask
from .types import TorrConfig


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class CacheState:
    packed: jax.Array     # uint32 [K, D//32] cached queries
    acc: jax.Array        # int32  [K, M] per-class dot accumulators
    acc_tag: jax.Array    # int32  [K] plan tag (banks, planes) for acc
    out: jax.Array        # f32    [K, M] cached final (post-reasoner) scores
    topk_key: jax.Array   # int32  [K, top_k] aligner top-k indices last window
    margin: jax.Array     # f32    [K] aligner top-1/top-2 margin last window
    age: jax.Array        # int32  [K]
    valid: jax.Array      # bool   [K]

    def tree_flatten(self):
        return (
            (self.packed, self.acc, self.acc_tag, self.out, self.topk_key,
             self.margin, self.age, self.valid),
            None,
        )

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)


def init_cache(cfg: TorrConfig) -> CacheState:
    K = cfg.K
    return CacheState(
        packed=jnp.zeros((K, cfg.words), jnp.uint32),
        acc=jnp.zeros((K, cfg.M), jnp.int32),
        acc_tag=jnp.zeros((K,), jnp.int32),
        out=jnp.zeros((K, cfg.M), jnp.float32),
        topk_key=jnp.full((K, cfg.top_k), -1, jnp.int32),
        margin=jnp.zeros((K,), jnp.float32),
        age=jnp.full((K,), jnp.iinfo(jnp.int32).max // 2, jnp.int32),
        valid=jnp.zeros((K,), bool),
    )


def init_cache_batch(cfg: TorrConfig, n_streams: int) -> CacheState:
    """Stacked per-stream caches: every leaf gains a leading [S] axis.

    The result is the cache component of a multi-stream ``TorrState``; each
    stream slot owns an independent depth-K cache, so per-stream reuse
    survives batching (a stream's cache travels with its slot).
    """
    one = init_cache(cfg)
    return jax.tree_util.tree_map(
        lambda x: jnp.repeat(x[None], n_streams, axis=0), one
    )


def reset_slot(cache: CacheState, cfg: TorrConfig, slot: int) -> CacheState:
    """Invalidate one stream slot of a stacked cache (stream admit/retire)."""
    fresh = init_cache(cfg)
    return jax.tree_util.tree_map(
        lambda b, f: b.at[slot].set(f), cache, fresh
    )


def nearest(
    cache: CacheState, q_packed: jax.Array, cfg: TorrConfig,
    banks: jax.Array | int, planes: int | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Nearest cached query over the dims a (banks, planes) plan enables.

    Duck-typed over :class:`CacheState` and :class:`MetaCache` (reads only
    ``packed``/``valid``), as is :func:`lru_slot` (``age``/``valid``) —
    the decide pass scans the metadata view through the same functions.
    Returns (idx [] int32, rho [] f32 per Eq. 5, hamming [] int32).
    ``planes`` is the static bit-plane knob (None = all planes, the
    pre-control-plane behavior). Invalid entries are pushed to rho = -inf;
    if no entry is valid the caller sees rho = -inf and takes the full path.
    """
    planes = cfg.bit_planes if planes is None else planes
    wmask = plan_word_mask(cfg, banks, planes)
    xor = jnp.bitwise_xor(cache.packed, q_packed[None, :])       # [K, W]
    pc = jax.lax.population_count(xor).astype(jnp.int32)
    pc = jnp.where(wmask[None, :], pc, 0)
    ham = jnp.sum(pc, axis=-1)                                    # [K]
    d_eff = jnp.asarray(
        cfg.d_eff_planned(jnp.asarray(banks, jnp.int32), planes), jnp.float32)
    rho = 1.0 - 2.0 * ham.astype(jnp.float32) / d_eff             # Eq. 5
    rho = jnp.where(cache.valid, rho, -jnp.inf)
    idx = jnp.argmax(rho)
    return idx.astype(jnp.int32), rho[idx], ham[idx]


def hamming_all(
    cache: CacheState, q_packed_all: jax.Array, cfg: TorrConfig,
    banks: jax.Array | int, planes: int | None = None,
    *, use_kernel: bool = True,
) -> jax.Array:
    """Masked hamming of every query against every cache entry: int32
    [N, K] under the (banks, planes) plan's word mask — one batched lookup
    pass instead of N per-proposal ``nearest`` scans. Duck-typed over
    :class:`CacheState` / :class:`MetaCache` like :func:`nearest` (reads
    only ``packed``). The raw table the batched decide pass snapshots; the
    per-entry sums are bit-identical to N calls of :func:`nearest`."""
    from . import aligner

    planes = cfg.bit_planes if planes is None else planes
    wmask = plan_word_mask(cfg, banks, planes)
    return aligner.lookup_hamming_all(q_packed_all, cache.packed, wmask,
                                      use_kernel=use_kernel)


def nearest_all(
    cache: CacheState, q_packed_all: jax.Array, cfg: TorrConfig,
    banks: jax.Array | int, planes: int | None = None,
    *, use_kernel: bool = True,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Batched :func:`nearest`: (idx [N], rho [N], ham [N]) of every query
    against one *static* cache snapshot (no intra-window updates — callers
    that need the sequential FSM's self-hit semantics resolve conflicts on
    top, see ``pipeline._decide_pass_batched``). Bit-identical to calling
    :func:`nearest` per row: the hamming sums are the same integers, the
    Eq. 5 rho arithmetic is the same f32 expression, and ``argmax`` keeps
    the same first-max tie-breaking."""
    planes = cfg.bit_planes if planes is None else planes
    ham = hamming_all(cache, q_packed_all, cfg, banks, planes,
                      use_kernel=use_kernel)                      # [N, K]
    d_eff = jnp.asarray(
        cfg.d_eff_planned(jnp.asarray(banks, jnp.int32), planes), jnp.float32)
    rho = 1.0 - 2.0 * ham.astype(jnp.float32) / d_eff             # Eq. 5
    rho = jnp.where(cache.valid[None, :], rho, -jnp.inf)
    idx = jnp.argmax(rho, axis=-1).astype(jnp.int32)
    n = jnp.arange(idx.shape[0])
    return idx, rho[n, idx], ham[n, idx]


def lru_slot(cache: CacheState) -> jax.Array:
    """Slot to evict: first invalid entry, else the oldest."""
    score = jnp.where(cache.valid, cache.age, jnp.iinfo(jnp.int32).max)
    return jnp.argmax(score).astype(jnp.int32)


def write_entry(
    cache: CacheState,
    slot: jax.Array,
    *,
    packed: jax.Array,
    acc: jax.Array,
    acc_tag: jax.Array,
    out: jax.Array,
    topk_key: jax.Array,
    margin: jax.Array,
) -> CacheState:
    """Write/refresh one entry and rejuvenate it; everyone else ages."""
    age = cache.age + 1
    age = age.at[slot].set(0)
    return CacheState(
        packed=cache.packed.at[slot].set(packed),
        acc=cache.acc.at[slot].set(acc),
        acc_tag=cache.acc_tag.at[slot].set(jnp.asarray(acc_tag, jnp.int32)),
        out=cache.out.at[slot].set(out),
        topk_key=cache.topk_key.at[slot].set(topk_key),
        margin=cache.margin.at[slot].set(margin),
        age=age,
        valid=cache.valid.at[slot].set(True),
    )


class MetaCache(NamedTuple):
    """The decision-relevant slice of :class:`CacheState`.

    Everything later *path decisions* in the same window can observe —
    packed queries, plan tags, age, validity — and nothing else: the
    compact dispatch's decide pass (``core.pipeline``) scans over this
    view so the (much larger) ``acc``/``out`` value arrays never ride the
    scan carry. Duck-typed into :func:`nearest` / :func:`lru_slot`, which
    only touch these four fields.
    """

    packed: jax.Array    # uint32 [K, D//32]
    acc_tag: jax.Array   # int32  [K]
    age: jax.Array       # int32  [K]
    valid: jax.Array     # bool   [K]


def meta_view(cache: CacheState) -> MetaCache:
    return MetaCache(packed=cache.packed, acc_tag=cache.acc_tag,
                     age=cache.age, valid=cache.valid)


def meta_touch(meta: MetaCache, slot: jax.Array) -> MetaCache:
    """Metadata image of :func:`touch`: rejuvenate, content untouched."""
    age = meta.age + 1
    return meta._replace(age=age.at[slot].set(0))


def meta_write(
    meta: MetaCache, slot: jax.Array, *, packed: jax.Array,
    acc_tag: jax.Array,
) -> MetaCache:
    """Metadata image of :func:`write_entry`: refresh one entry's packed
    query + plan tag and rejuvenate it (everyone else ages), without the
    value fields the decide pass cannot yet know. The two must stay
    update-for-update identical or the decide and apply passes diverge."""
    age = meta.age + 1
    return MetaCache(
        packed=meta.packed.at[slot].set(packed),
        acc_tag=meta.acc_tag.at[slot].set(jnp.asarray(acc_tag, jnp.int32)),
        age=age.at[slot].set(0),
        valid=meta.valid.at[slot].set(True),
    )


def touch(cache: CacheState, slot: jax.Array) -> CacheState:
    """Bypass hit: rejuvenate the entry without modifying its contents."""
    age = cache.age + 1
    age = age.at[slot].set(0)
    return dataclasses.replace(cache, age=age)
