"""Hyperdimensional computing primitives.

Bipolar hypervectors live in {-1,+1}^D stored as int8; the packed form packs
32 dimensions per uint32 word (dimension i -> word i//32, bit i%32, bit value
1 <=> +1). All similarity identities used by the paper hold exactly in packed
form:

    <a, b>        = D - 2 * hamming(pack(a), pack(b))
    cos(a, b)     = <a, b> / D          (bipolar vectors have norm sqrt(D))
    rho           = 1 - 2|Delta|/D'     (Eq. 5)

Packing is the TPU adaptation of the paper's bit-sliced item memory: it
compresses alignment traffic 32x, which is the actual target of the ASIC
design (bandwidth, not FLOPs).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "random_hv", "bind", "bundle", "permute", "sign_project",
    "pack_bits", "unpack_bits", "dot_bipolar", "cosine_bipolar",
    "hamming_packed", "dot_packed", "cosine_packed",
]


def random_hv(key: jax.Array, shape, dtype=jnp.int8) -> jax.Array:
    """I.i.d. Rademacher hypervectors in {-1,+1}^shape[-1]."""
    bits = jax.random.bernoulli(key, 0.5, shape)
    return jnp.where(bits, 1, -1).astype(dtype)


def bind(*hvs: jax.Array) -> jax.Array:
    """Hadamard binding (elementwise product), associative and self-inverse."""
    out = hvs[0]
    for h in hvs[1:]:
        out = out * h
    return out


def bundle(hvs: jax.Array, key: jax.Array | None = None) -> jax.Array:
    """Majority bundling over the leading axis with random tie-breaking."""
    s = jnp.sum(hvs.astype(jnp.int32), axis=0)
    if key is not None:
        tie = random_hv(key, s.shape, dtype=jnp.int32)
        s = jnp.where(s == 0, tie, s)
    return jnp.where(s >= 0, 1, -1).astype(jnp.int8)


def permute(hv: jax.Array, shift: int = 1) -> jax.Array:
    """Cyclic permutation (role encoding)."""
    return jnp.roll(hv, shift, axis=-1)


def sign_project(z: jax.Array, R: jax.Array) -> jax.Array:
    """q = sign(R z): dense feature -> bipolar hypervector (paper Sec. 3.2).

    R is [D, d]; z is [..., d]. sign(0) is mapped to +1 so the output is
    strictly bipolar.
    """
    y = jnp.einsum("...d,Dd->...D", z.astype(jnp.float32), R.astype(jnp.float32))
    return jnp.where(y >= 0, 1, -1).astype(jnp.int8)


# ---------------------------------------------------------------------------
# Packed (1 bit/dim) representation
# ---------------------------------------------------------------------------

def pack_bits(bipolar: jax.Array) -> jax.Array:
    """Pack bipolar int8 [..., D] -> uint32 [..., D//32]. Bit=1 <=> +1."""
    D = bipolar.shape[-1]
    if D % 32:
        raise ValueError(f"D={D} must be a multiple of 32")
    bits = (bipolar > 0).astype(jnp.uint32)
    bits = bits.reshape(*bipolar.shape[:-1], D // 32, 32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return jnp.sum(bits << shifts, axis=-1, dtype=jnp.uint32)


def unpack_bits(packed: jax.Array, D: int) -> jax.Array:
    """Inverse of :func:`pack_bits`."""
    if D != packed.shape[-1] * 32:
        raise ValueError("D mismatch")
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (packed[..., None] >> shifts) & jnp.uint32(1)
    bits = bits.reshape(*packed.shape[:-1], D)
    return jnp.where(bits == 1, 1, -1).astype(jnp.int8)


def dot_bipolar(a: jax.Array, b: jax.Array) -> jax.Array:
    """Exact integer dot product of bipolar vectors."""
    return jnp.sum(a.astype(jnp.int32) * b.astype(jnp.int32), axis=-1)


def cosine_bipolar(a: jax.Array, b: jax.Array) -> jax.Array:
    return dot_bipolar(a, b).astype(jnp.float32) / a.shape[-1]


def hamming_packed(a: jax.Array, b: jax.Array) -> jax.Array:
    """Number of differing dimensions, from packed words (XOR + popcount)."""
    x = jax.lax.population_count(jnp.bitwise_xor(a, b))
    return jnp.sum(x.astype(jnp.int32), axis=-1)


def dot_packed(a: jax.Array, b: jax.Array, d_eff: jax.Array | int | None = None) -> jax.Array:
    """<a,b> over the first d_eff dims = d_eff - 2*hamming (XNOR-popcount kernel).

    ``a``/``b`` are packed words already restricted (sliced or masked) to the
    enabled banks; ``d_eff`` defaults to 32 * n_words.
    """
    if d_eff is None:
        d_eff = a.shape[-1] * 32
    return jnp.asarray(d_eff, jnp.int32) - 2 * hamming_packed(a, b)


def cosine_packed(a: jax.Array, b: jax.Array, d_eff: jax.Array | int | None = None) -> jax.Array:
    if d_eff is None:
        d_eff = a.shape[-1] * 32
    return dot_packed(a, b, d_eff).astype(jnp.float32) / jnp.asarray(d_eff, jnp.float32)
