"""DVS event aggregation (paper Sec. 2.2 / Eq. 1).

Events are (x, y, t, p) tuples; embedded systems aggregate them into windows
of width dt. We provide two views:

  * ``aggregate_window`` — the spatiotemporal tensor [T_bins, H, W, 2] fed to
    the spiking encoder (events binned over time and polarity);
  * ``eq1_frame`` — the normalized 2-D accumulation E_hat of Eq. 1 used by
    the image->event training bridge.

Event batches are fixed-size padded arrays with a validity count so the
whole path jits; real DVS streams are ragged, and the pad/truncate contract
mirrors how an embedded DMA engine would fill a fixed ring buffer.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class EventBatch:
    """Padded event window: arrays are [n_max]; ``count`` marks validity."""

    x: jax.Array       # int32 [n_max]
    y: jax.Array       # int32 [n_max]
    t: jax.Array       # f32   [n_max], relative to window start
    p: jax.Array       # int32 [n_max], polarity in {0, 1}
    count: jax.Array   # int32 []

    def tree_flatten(self):
        return ((self.x, self.y, self.t, self.p, self.count), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)


def aggregate_window(
    ev: EventBatch, dt: float, t_bins: int, height: int, width: int
) -> jax.Array:
    """Histogram events into [t_bins, H, W, 2] (scatter-add)."""
    valid = jnp.arange(ev.x.shape[0]) < ev.count
    tb = jnp.clip((ev.t / dt * t_bins).astype(jnp.int32), 0, t_bins - 1)
    xx = jnp.clip(ev.x, 0, width - 1)
    yy = jnp.clip(ev.y, 0, height - 1)
    pp = jnp.clip(ev.p, 0, 1)
    vol = jnp.zeros((t_bins, height, width, 2), jnp.float32)
    return vol.at[tb, yy, xx, pp].add(jnp.where(valid, 1.0, 0.0))


def eq1_frame(ev: EventBatch, height: int, width: int, eps: float = 1e-6) -> jax.Array:
    """Eq. 1: E_tilde(x,y) = sum of signed events; E_hat = E_tilde / max|E_tilde|."""
    valid = jnp.arange(ev.x.shape[0]) < ev.count
    sgn = jnp.where(ev.p > 0, 1.0, -1.0) * jnp.where(valid, 1.0, 0.0)
    xx = jnp.clip(ev.x, 0, width - 1)
    yy = jnp.clip(ev.y, 0, height - 1)
    e = jnp.zeros((height, width), jnp.float32).at[yy, xx].add(sgn)
    return e / (jnp.max(jnp.abs(e)) + eps)
