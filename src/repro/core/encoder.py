"""Event SNN encoder (paper Sec. 3.2, 'Event SNN encoder').

A lightweight spiking backbone over aggregated event windows: two conv-LIF
stages scanned over time bins, rate-coded readout, then a linear head to the
feature space z_e in R^d. Spikes use a straight-through surrogate gradient
(sigmoid derivative) so the contrastive bridge (Eq. 2-3) can train the SNN
end-to-end against frozen CLIP targets.

The per-proposal query hypervector is q = sign(R z_e) with a fixed random
projection R (not trained), per the paper.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from . import hdc

_SURROGATE_BETA = 4.0


@jax.custom_vjp
def spike(v: jax.Array) -> jax.Array:
    return (v > 0.0).astype(v.dtype)


def _spike_fwd(v):
    return spike(v), v


def _spike_bwd(v, g):
    s = jax.nn.sigmoid(_SURROGATE_BETA * v)
    return (g * _SURROGATE_BETA * s * (1.0 - s),)


spike.defvjp(_spike_fwd, _spike_bwd)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class EncoderParams:
    conv1: jax.Array   # [3, 3, 2, c1]
    conv2: jax.Array   # [3, 3, c1, c2]
    head: jax.Array    # [c2, d]
    head_b: jax.Array  # [d]

    def tree_flatten(self):
        return ((self.conv1, self.conv2, self.head, self.head_b), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    c1: int = 16
    c2: int = 32
    feat_dim: int = 512
    tau: float = 0.7        # LIF leak
    thresh: float = 0.5     # firing threshold


def init_encoder(key: jax.Array, cfg: EncoderConfig) -> EncoderParams:
    k1, k2, k3 = jax.random.split(key, 3)
    he = lambda k, shape, fan_in: jax.random.normal(k, shape) * jnp.sqrt(2.0 / fan_in)
    return EncoderParams(
        conv1=he(k1, (3, 3, 2, cfg.c1), 18),
        conv2=he(k2, (3, 3, cfg.c1, cfg.c2), 9 * cfg.c1),
        head=he(k3, (cfg.c2, cfg.feat_dim), cfg.c2),
        head_b=jnp.zeros((cfg.feat_dim,)),
    )


def _conv(x: jax.Array, w: jax.Array, stride: int) -> jax.Array:
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def encode(params: EncoderParams, vol: jax.Array, cfg: EncoderConfig) -> jax.Array:
    """vol: [T_bins, H, W, 2] (one proposal window) -> z_e [d].

    LIF membrane potentials persist across time bins; the readout is the
    spike rate of the second stage, globally pooled.
    """
    T, H, W, _ = vol.shape
    h1, w1 = -(-H // 2), -(-W // 2)
    h2, w2 = -(-h1 // 2), -(-w1 // 2)

    def step(carry, x_t):
        v1, v2, rate = carry
        c1 = _conv(x_t[None], params.conv1, 2)[0]            # [h1, w1, c1]
        v1 = cfg.tau * v1 + c1
        s1 = spike(v1 - cfg.thresh)
        v1 = v1 - s1 * cfg.thresh                             # soft reset
        c2 = _conv(s1[None], params.conv2, 2)[0]              # [h2, w2, c2]
        v2 = cfg.tau * v2 + c2
        s2 = spike(v2 - cfg.thresh)
        v2 = v2 - s2 * cfg.thresh
        return (v1, v2, rate + s2), None

    v1 = jnp.zeros((h1, w1, params.conv1.shape[-1]))
    v2 = jnp.zeros((h2, w2, params.conv2.shape[-1]))
    rate = jnp.zeros_like(v2)
    (v1, v2, rate), _ = jax.lax.scan(step, (v1, v2, rate), vol)
    pooled = jnp.mean(rate / T, axis=(0, 1))                  # [c2]
    return pooled @ params.head + params.head_b               # [d]


encode_batch = jax.vmap(encode, in_axes=(None, 0, None))


def make_projection(key: jax.Array, D: int, d: int) -> jax.Array:
    """Fixed random projection R [D, d] for q = sign(R z_e)."""
    return jax.random.normal(key, (D, d)) / jnp.sqrt(d)


def query_hv(params: EncoderParams, vol: jax.Array, R: jax.Array,
             cfg: EncoderConfig) -> jax.Array:
    """Full encoder -> bipolar query path."""
    return hdc.sign_project(encode(params, vol, cfg), R)
