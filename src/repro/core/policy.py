"""Algorithm 1: similarity-gated path policy + FPS/QoS bank gating.

The controller is a pure function of (rho, |Delta|, N, q) and static
thresholds, so it lowers to a handful of scalar ops and stays off the
critical path — mirroring the window-latched register file of Sec. 4.6.

TPU adaptations (recorded in DESIGN.md):
  * delta additionally requires |Delta| <= delta_budget (static-shape budget
    replaces the ASIC's data-dependent FIFO) and an accumulator whose D' tag
    matches the current bank mask (exactness of Eq. 6).
  * D' selection solves the cycle model of Sec. 4.3 for the largest bank
    count whose worst-case window latency fits the FPS budget.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .types import PATH_BYPASS, PATH_DELTA, PATH_FULL, TorrConfig


def high_load(n_objects: jax.Array, queue_depth: jax.Array, cfg: TorrConfig) -> jax.Array:
    """H(N, q) = (N >= N_hi) or (q >= q_hi)."""
    return jnp.logical_or(n_objects >= cfg.N_hi, queue_depth >= cfg.q_hi)


def select_path(
    rho: jax.Array,
    delta_count: jax.Array,
    acc_tag_ok: jax.Array,
    high: jax.Array,
    cfg: TorrConfig,
) -> jax.Array:
    """Alg. 1 lines 2-8, with the TPU delta-feasibility guards.

    Shape-polymorphic: every input may carry leading batch axes (the
    batched decide pass selects a whole window's paths in one call), and
    scalars broadcast — the scalar per-proposal form inside the sequential
    FSM scan is the same expression.
    """
    delta_ok = jnp.logical_and(
        rho >= cfg.tau_q,
        jnp.logical_and(delta_count <= cfg.delta_budget, acc_tag_ok),
    )
    bypass = jnp.logical_and(rho >= cfg.tau_byp, high)
    return jnp.where(
        bypass, PATH_BYPASS, jnp.where(delta_ok, PATH_DELTA, PATH_FULL)
    ).astype(jnp.int32)


def intra_window_coupled(actions: jax.Array, valid: jax.Array) -> jax.Array:
    """Conflict-set predicate of the batched decide pass: bool [N], True
    where proposal i's *path decision* could depend on an earlier proposal
    in the same window.

    Alg. 1's decision for a proposal reads only the cache's packed
    queries, plan tags and validity — which an earlier proposal mutates
    exactly when it takes a cache-*writing* path (delta refreshes its hit
    entry, full writes the LRU slot). Bypass merely touches ages, which
    can shift a later proposal's LRU choice but never its
    (action, idx, rho, |Delta|). So proposal i is coupled iff some valid
    j < i took delta or full; everything outside this set is guaranteed to
    decide identically against the frozen window-entry snapshot — the
    invariant ``pipeline._decide_pass_batched``'s conflict pass preserves
    and ``tests/test_decide_batched.py`` pins.

    Conservative (a superset): a coupled proposal's decision may still
    coincide with its snapshot decision (e.g. the write landed in a slot
    it never ranks first).
    """
    writes = jnp.logical_and(
        valid, jnp.logical_or(actions == PATH_DELTA, actions == PATH_FULL))
    before = jnp.cumsum(writes.astype(jnp.int32)) - writes.astype(jnp.int32)
    return before > 0


# ---------------------------------------------------------------------------
# Shared Sec. 4.3 cycle-cost math. Alg. 1's bank selection (below), the QoS
# governor (repro.control.governor) and the cycle-accurate simulator
# (repro.perf.cycle_model) all price aligner work through these two helpers,
# so the three consumers cannot drift apart. Plain arithmetic only: the same
# code runs traced (jnp) inside jit and on host numpy/python ints.
# ---------------------------------------------------------------------------

PROPOSAL_OVERHEAD_CYCLES = 64  # pipelined PSU + reasoner + sort constant


def mw_cycles(cfg: TorrConfig) -> int:
    """ceil(M/W): cycles per broadcast column across the W class lanes."""
    return -(-cfg.M // cfg.W)


def aligner_cycles(n_full, delta_cols, d_eff, mw):
    """Sec. 4.3 aligner core: a full scan costs D'*ceil(M/W); the delta path
    one ceil(M/W) column-broadcast per corrected dimension (``delta_cols``
    is the summed |Delta| over delta-path proposals)."""
    return (n_full * d_eff + delta_cols) * mw


def proposal_overhead(n_proposals, mw):
    """Per-proposal pipelined PSU + reasoner + sort: ~M/W plus a constant."""
    return n_proposals * (mw + PROPOSAL_OVERHEAD_CYCLES)


def window_cycles_deff(
    n_full, n_delta, d_eff, cfg: TorrConfig
):
    """Worst-case window cycles at an explicit effective dimension D'.

    The governor prices (banks, bit-planes) knob plans through this — D'
    under precision gating is not a whole number of banks."""
    mw = mw_cycles(cfg)
    return (aligner_cycles(n_full, n_delta * cfg.delta_budget, d_eff, mw)
            + proposal_overhead(n_full + n_delta, mw))


def window_cycles(
    n_full: jax.Array, n_delta: jax.Array, banks: jax.Array, cfg: TorrConfig
) -> jax.Array:
    """Cycle estimate per Sec. 4.3: full = D'*ceil(M/W), delta = |Dmax|*ceil(M/W).

    A small fixed per-proposal overhead models PSU + reasoner + sort
    (each pipelined, ~M/W plus constant).
    """
    return window_cycles_deff(n_full, n_delta, banks * cfg.bank_dims, cfg)


# ---------------------------------------------------------------------------
# Compact-dispatch bucket ladder. The compact full-path lowering
# (core.pipeline, fused="compact") pads the compacted full-path proposals to
# a *static* bucket capacity so the executable family stays bounded; the
# capacities form a power-of-two ladder shared by the pipeline, the serving
# engines' load-aware auto-dispatch and the cycle model's lowering-aware
# pricing. Host-side python ints only (the capacity is a static jit arg).
# ---------------------------------------------------------------------------

def bucket_ladder(n_rows: int) -> tuple[int, ...]:
    """Static bucket capacities for a flattened batch of ``n_rows``: powers
    of two up to ``n_rows``, plus ``n_rows`` itself (the no-savings tier —
    compaction at full capacity degenerates to the hoisted scan)."""
    if n_rows < 1:
        raise ValueError(f"n_rows={n_rows} must be >= 1")
    caps = []
    c = 1
    while c < n_rows:
        caps.append(c)
        c *= 2
    caps.append(n_rows)
    return tuple(caps)


def bucket_tier(n_rows: int, want: int) -> int:
    """Smallest ladder capacity >= ``want`` (clamped to [1, n_rows])."""
    want = max(1, min(int(want), n_rows))
    for c in bucket_ladder(n_rows):
        if c >= want:
            return c
    return n_rows


def select_banks(
    n_objects: jax.Array, queue_depth: jax.Array, cfg: TorrConfig
) -> jax.Array:
    """QoS bank gating: largest banks whose worst case (all-full) fits budget.

    Worst case assumes every proposal takes the full path; queue depth adds
    pressure by shrinking the effective budget (the window must drain
    backlog). Always returns at least 1 bank.
    """
    budget = cfg.cycles_per_window_budget / (1.0 + queue_depth.astype(jnp.float32))
    n = jnp.maximum(n_objects, 1)
    candidates = jnp.arange(1, cfg.B + 1, dtype=jnp.int32)
    worst = jax.vmap(lambda b: window_cycles(n, jnp.int32(0), b, cfg))(candidates)
    fits = worst.astype(jnp.float32) <= budget
    best = jnp.max(jnp.where(fits, candidates, 1))
    return best.astype(jnp.int32)


def decide(
    rho: jax.Array,
    delta_count: jax.Array,
    acc_tag_ok: jax.Array,
    n_objects: jax.Array,
    queue_depth: jax.Array,
    cfg: TorrConfig,
) -> tuple[jax.Array, jax.Array]:
    """(action, banks) per Alg. 1 line 9's combined return."""
    high = high_load(n_objects, queue_depth, cfg)
    banks = select_banks(n_objects, queue_depth, cfg)
    action = select_path(rho, delta_count, acc_tag_ok, high, cfg)
    return action, banks
