"""Assigned architectures x input shapes (public-literature configs).

Each entry mirrors the assignment block verbatim; bracketed sources are in
DESIGN.md. ``get_smoke`` shrinks every dimension while preserving the family
topology (pattern ratios, MoE routing, MLA ranks ...) so smoke tests exercise
the same code paths the full config lowers.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..models.config import ModelConfig

# ---------------------------------------------------------------------------
# Full (published) configs
# ---------------------------------------------------------------------------

def deepseek_v3_671b() -> ModelConfig:
    # [arXiv:2412.19437] 61L d7168 128H MLA d_ff(moe)=2048 vocab 129280,
    # 1 shared + 256 routed top-8, MTP, first 3 layers dense (d_ff 18432)
    return ModelConfig(
        name="deepseek-v3-671b", family="moe",
        n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128,
        head_dim=128, d_ff=18432, vocab=129280,
        attn_kind="mla", q_lora_rank=1536, kv_lora_rank=512,
        rope_head_dim=64, nope_head_dim=128, v_head_dim=128,
        n_experts=256, n_shared_experts=1, moe_top_k=8, moe_d_ff=2048,
        first_k_dense=3, mtp_depth=1, tie_embeddings=False,
    )


def deepseek_v2_236b() -> ModelConfig:
    # [arXiv:2405.04434] 60L d5120 128H MLA kv_lora=512 d_ff(moe)=1536
    # vocab 102400, 2 shared + 160 routed top-6, first layer dense (d_ff 12288)
    return ModelConfig(
        name="deepseek-v2-236b", family="moe",
        n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128,
        head_dim=128, d_ff=12288, vocab=102400,
        attn_kind="mla", q_lora_rank=1536, kv_lora_rank=512,
        rope_head_dim=64, nope_head_dim=128, v_head_dim=128,
        n_experts=160, n_shared_experts=2, moe_top_k=6, moe_d_ff=1536,
        first_k_dense=1, tie_embeddings=False,
    )


def gemma_7b() -> ModelConfig:
    # [arXiv:2403.08295] 28L d3072 16H kv16 head_dim 256 GeGLU d_ff 24576
    return ModelConfig(
        name="gemma-7b", family="dense",
        n_layers=28, d_model=3072, n_heads=16, n_kv_heads=16,
        head_dim=256, d_ff=24576, vocab=256000,
        activation="geglu", embed_scale=True, tie_embeddings=True,
    )


def phi3_mini_3_8b() -> ModelConfig:
    # [arXiv:2404.14219] 32L d3072 32H kv32 d_ff 8192 SwiGLU vocab 32064
    return ModelConfig(
        name="phi3-mini-3.8b", family="dense",
        n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32,
        head_dim=96, d_ff=8192, vocab=32064, tie_embeddings=False,
    )


def qwen3_14b() -> ModelConfig:
    # [hf:Qwen/Qwen3-14B] 40L d5120 40H kv8 d_ff 17408, qk_norm
    return ModelConfig(
        name="qwen3-14b", family="dense",
        n_layers=40, d_model=5120, n_heads=40, n_kv_heads=8,
        head_dim=128, d_ff=17408, vocab=151936,
        qk_norm=True, rope_theta=1e6, tie_embeddings=False,
    )


def deepseek_7b() -> ModelConfig:
    # [arXiv:2401.02954] llama-arch 30L d4096 32H kv32 d_ff 11008 vocab 102400
    return ModelConfig(
        name="deepseek-7b", family="dense",
        n_layers=30, d_model=4096, n_heads=32, n_kv_heads=32,
        head_dim=128, d_ff=11008, vocab=102400, tie_embeddings=False,
    )


def musicgen_large() -> ModelConfig:
    # [arXiv:2306.05284] 48L d2048 32H d_ff 8192, 4 EnCodec codebooks x 2048
    return ModelConfig(
        name="musicgen-large", family="audio",
        n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32,
        head_dim=64, d_ff=8192, vocab=2048,
        n_codebooks=4, tie_embeddings=False,
    )


def llama32_vision_90b() -> ModelConfig:
    # [hf:meta-llama/Llama-3.2-90B-Vision] 100L (80 self + 20 cross) d8192
    # 64H kv8 d_ff 28672 vocab 128256; vision frontend stubbed
    return ModelConfig(
        name="llama-3.2-vision-90b", family="vlm",
        n_layers=100, d_model=8192, n_heads=64, n_kv_heads=8,
        head_dim=128, d_ff=28672, vocab=128256,
        cross_attn_every=5, vision_dim=1280, n_vision_tokens=1601,
        rope_theta=5e5, tie_embeddings=False,
    )


def recurrentgemma_2b() -> ModelConfig:
    # [arXiv:2402.19427] 26L d2560 10H MQA(kv=1) head_dim 256 d_ff 7680
    # pattern (rglru, rglru, local_attn) window 2048, lru_width 2560
    return ModelConfig(
        name="recurrentgemma-2b", family="hybrid",
        n_layers=26 + 1, d_model=2560, n_heads=10, n_kv_heads=1,
        head_dim=256, d_ff=7680, vocab=256000,
        block_pattern=("rglru", "rglru", "local_attn"),
        sliding_window=2048, lru_width=2560,
        activation="geglu", embed_scale=True, tie_embeddings=True,
    )


def xlstm_1_3b() -> ModelConfig:
    # [arXiv:2405.04517] 48 blocks d2048 4H, mLSTM/sLSTM mix, no separate FFN
    return ModelConfig(
        name="xlstm-1.3b", family="ssm",
        n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4,
        head_dim=512, d_ff=0, vocab=50304,
        slstm_every=8, tie_embeddings=False,
    )


ARCHS = {
    "deepseek-v3-671b": deepseek_v3_671b,
    "deepseek-v2-236b": deepseek_v2_236b,
    "gemma-7b": gemma_7b,
    "phi3-mini-3.8b": phi3_mini_3_8b,
    "qwen3-14b": qwen3_14b,
    "deepseek-7b": deepseek_7b,
    "musicgen-large": musicgen_large,
    "llama-3.2-vision-90b": llama32_vision_90b,
    "recurrentgemma-2b": recurrentgemma_2b,
    "xlstm-1.3b": xlstm_1_3b,
}

# Pure full-attention archs skip long_500k (sub-quadratic required).
SUBQUADRATIC = {"recurrentgemma-2b", "xlstm-1.3b"}

SHAPES = {
    "train_4k": dict(seq_len=4096, global_batch=256, mode="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, mode="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, mode="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, mode="decode"),
}


def get(name: str) -> ModelConfig:
    return ARCHS[name]()


def shape_for(arch: str, shape: str) -> dict | None:
    """Shape dict, or None if the cell is skipped (with reason)."""
    if shape == "long_500k" and arch not in SUBQUADRATIC:
        return None
    return SHAPES[shape]


# ---------------------------------------------------------------------------
# Reduced smoke configs (same family topology, tiny dims)
# ---------------------------------------------------------------------------

def get_smoke(name: str) -> ModelConfig:
    full = get(name)
    common = dict(
        vocab=256, attn_chunk=32, mlstm_chunk=16, remat_policy="full")
    if full.family == "moe":
        return dataclasses.replace(
            full, n_layers=3, d_model=64, n_heads=4, n_kv_heads=4,
            head_dim=16, d_ff=128, q_lora_rank=32, kv_lora_rank=32,
            rope_head_dim=16, nope_head_dim=16, v_head_dim=16,
            n_experts=8, moe_top_k=2, moe_d_ff=64, first_k_dense=1,
            mtp_depth=full.mtp_depth, **common)
    if full.family == "vlm":
        return dataclasses.replace(
            full, n_layers=5, d_model=64, n_heads=4, n_kv_heads=2,
            head_dim=16, d_ff=128, cross_attn_every=5,
            vision_dim=48, n_vision_tokens=16, **common)
    if full.family == "hybrid":
        return dataclasses.replace(
            full, n_layers=3, d_model=64, n_heads=4, n_kv_heads=1,
            head_dim=16, d_ff=128, lru_width=64, sliding_window=16, **common)
    if full.family == "ssm":
        return dataclasses.replace(
            full, n_layers=4, d_model=64, n_heads=2, slstm_every=4, **common)
    if full.family == "audio":
        return dataclasses.replace(
            full, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
            head_dim=16, d_ff=128, **common)
    return dataclasses.replace(
        full, n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=max(1, full.n_kv_heads * 4 // full.n_heads),
        head_dim=16, d_ff=128, **common)


# ---------------------------------------------------------------------------
# ShapeDtypeStruct input specs for the dry-run (no allocation)
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: dict) -> dict:
    """Abstract inputs for train/prefill/decode lowering of ``cfg``."""
    B = shape["global_batch"]
    S = shape["seq_len"]
    mode = shape["mode"]
    i32 = jnp.int32

    def tok(*s):
        return jax.ShapeDtypeStruct(s, i32)

    if mode == "train":
        if cfg.family == "audio":
            batch = {"tokens": tok(B, S, cfg.n_codebooks),
                     "labels": tok(B, S, cfg.n_codebooks)}
        else:
            batch = {"tokens": tok(B, S), "labels": tok(B, S)}
        if cfg.family == "vlm":
            batch["vision"] = jax.ShapeDtypeStruct(
                (B, cfg.n_vision_tokens, cfg.vision_dim), jnp.bfloat16)
        if cfg.family == "moe" and cfg.mtp_depth:
            batch["tokens_next"] = tok(B, S)
            batch["labels_mtp"] = tok(B, S)
        return batch
    if mode == "prefill":
        if cfg.family == "audio":
            batch = {"tokens": tok(B, S, cfg.n_codebooks)}
        else:
            batch = {"tokens": tok(B, S)}
        if cfg.family == "vlm":
            batch["vision"] = jax.ShapeDtypeStruct(
                (B, cfg.n_vision_tokens, cfg.vision_dim), jnp.bfloat16)
        return batch
    # decode: one new token against an S-long cache
    if cfg.family == "audio":
        return {"tokens": tok(B, cfg.n_codebooks)}
    return {"tokens": tok(B)}
