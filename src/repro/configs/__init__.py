"""Architecture registry: one config per assigned architecture (+ torr_edge).

``get(name)`` returns the full published config; ``get_smoke(name)`` returns
a reduced same-family config for CPU smoke tests.
"""
from .registry import ARCHS, SHAPES, get, get_smoke, input_specs, shape_for

__all__ = ["ARCHS", "SHAPES", "get", "get_smoke", "input_specs", "shape_for"]
from .torr_edge import (rt_budget_s, torr_edge,  # noqa: E402,F401
                        torr_edge_no_reuse)

__all__ += ["rt_budget_s", "torr_edge", "torr_edge_no_reuse"]
