"""The paper's own workload: TorR edge deployment configuration.

Not one of the 40 LM dry-run cells — this is the accelerator configuration
the cycle model and the TOOD evaluation run (paper Sec. 5): D=8192 in 8
banks, 1024-concept item memory, depth-8 query cache, 64 aligner lanes at
1 GHz, with the RT-60/RT-30 QoS targets.
"""
from __future__ import annotations

import dataclasses

from ..core.types import TorrConfig


# The paper's two QoS operating points: per-window completion deadlines.
# These are the *serving* deadlines the RT controller enforces
# (repro.serving.deadline); the cycle model reuses the same budgets.
RT_BUDGETS_S = {"RT-60": 1.0 / 60.0, "RT-30": 1.0 / 30.0}


def rt_budget_s(rt: str = "RT-60") -> float:
    """Per-window deadline in seconds for an RT-30/RT-60 operating point."""
    try:
        return RT_BUDGETS_S[rt]
    except KeyError:
        raise ValueError(
            f"unknown RT target {rt!r}; expected one of {sorted(RT_BUDGETS_S)}"
        ) from None


def torr_edge(rt: str = "RT-60", **overrides) -> TorrConfig:
    base = TorrConfig(
        D=8192, B=8, M=1024, K=8, N_max=128,
        delta_budget=2048, W=64, clock_hz=1.0e9,
        fps_target=1.0 / rt_budget_s(rt),
        tau_byp=0.95, tau_q=0.60, N_hi=8, q_hi=4,
        feat_dim=512,
    )
    return dataclasses.replace(base, **overrides) if overrides else base


def torr_edge_no_reuse(rt: str = "RT-60") -> TorrConfig:
    """Ablation: thresholds that never fire => the SNN + naive-HDC baseline
    (every window takes the full path)."""
    return torr_edge(rt, tau_byp=2.0, tau_q=2.0)
