"""Checkpointing: atomic, keep-last-k, mesh-elastic restore.

Design (single-host container standing in for a multi-host fleet):

  * save(): gather each leaf to host, write one .npz per step into a temp
    dir, fsync, then atomically rename to ``step_{N:08d}`` — a crash
    mid-save never corrupts the latest checkpoint (the rename is the commit
    point, exactly the protocol a GCS/posix multi-host saver uses).
  * restore(): loads the newest complete checkpoint and ``device_put``s
    every leaf with the sharding derived from the *current* mesh — restoring
    onto a different mesh shape (elastic scale-up/down) is therefore free:
    resharding happens at placement time.
  * keep_last limits disk usage; an optional async thread moves the host
    gather off the training loop (overlap with the next step's compute).

On a real fleet each host writes only its addressable shards; the
tree-structure/manifest logic below is unchanged — only the leaf I/O layer
swaps (documented in DESIGN.md §Scale-out).
"""
from __future__ import annotations

import json
import os
import pathlib
import shutil
import tempfile
import threading
import zipfile
from typing import Any, Callable

import jax
import numpy as np

_MANIFEST = "manifest.json"


def _flatten_with_names(tree) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(
            str(getattr(e, "key", getattr(e, "idx", getattr(e, "name", e))))
            for e in path)
        out.append((name, leaf))
    return out


class CheckpointManager:
    def __init__(self, directory: str | os.PathLike, keep_last: int = 3,
                 async_save: bool = False):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep_last = keep_last
        self.async_save = async_save
        self._pending: threading.Thread | None = None

    # -- write ----------------------------------------------------------
    def save(self, step: int, tree) -> pathlib.Path:
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        if self.async_save:
            self.wait()
            t = threading.Thread(target=self._write, args=(step, host_tree))
            t.start()
            self._pending = t
            return self.dir / f"step_{step:08d}"
        return self._write(step, host_tree)

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _write(self, step: int, host_tree) -> pathlib.Path:
        final = self.dir / f"step_{step:08d}"
        named = _flatten_with_names(host_tree)
        treedef = jax.tree_util.tree_structure(host_tree)
        tmp = pathlib.Path(tempfile.mkdtemp(dir=self.dir, prefix=".tmp_"))
        try:
            # np.savez cannot round-trip ml_dtypes (bfloat16 etc.): store
            # such leaves as raw uint bits + a dtype tag in the manifest
            leaves, dtypes = {}, []
            for i, (_, leaf) in enumerate(named):
                dt = str(leaf.dtype)
                dtypes.append(dt)
                if leaf.dtype.kind not in "biufc":   # ml_dtypes
                    leaf = leaf.view(np.uint16 if leaf.dtype.itemsize == 2
                                     else np.uint8)
                leaves[f"leaf_{i}"] = leaf
            np.savez(tmp / "leaves.npz", **leaves)
            manifest = {
                "step": step,
                "names": [n for n, _ in named],
                "dtypes": dtypes,
                "treedef": str(treedef),
            }
            (tmp / _MANIFEST).write_text(json.dumps(manifest))
            # durability before the commit point: a rename can land on disk
            # before the data it names (write reordering across a power
            # cut), producing a complete-looking but torn checkpoint —
            # fsync both payload files and the temp dir first
            for f in ("leaves.npz", _MANIFEST):
                fd = os.open(tmp / f, os.O_RDONLY)
                try:
                    os.fsync(fd)
                finally:
                    os.close(fd)
            self._fsync_dir(tmp)
            if final.exists():  # idempotent re-save of the same step
                shutil.rmtree(final)
            os.replace(tmp, final)  # commit point
            self._fsync_dir(self.dir)  # persist the rename itself
        finally:
            if tmp.exists():
                shutil.rmtree(tmp, ignore_errors=True)
        self._gc()
        return final

    @staticmethod
    def _fsync_dir(path) -> None:
        fd = os.open(path, os.O_RDONLY)
        try:
            os.fsync(fd)
        except OSError:
            pass  # some filesystems refuse directory fsync; best-effort
        finally:
            os.close(fd)

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep_last] if self.keep_last else []:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # -- read -----------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for p in self.dir.iterdir():
            if p.name.startswith("step_") and (p / _MANIFEST).exists():
                out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def _load_leaves(self, step: int) -> list:
        """Read one checkpoint's raw leaves (any torn/truncated file
        raises — the caller decides whether to fall back)."""
        d = self.dir / f"step_{step:08d}"
        data = np.load(d / "leaves.npz")
        manifest = json.loads((d / _MANIFEST).read_text())
        dtypes = manifest.get("dtypes")
        leaves = []
        for i in range(len(data.files)):
            arr = data[f"leaf_{i}"]
            if dtypes and arr.dtype.kind == "u" and dtypes[i] not in (
                    str(arr.dtype),):
                import ml_dtypes
                arr = arr.view(np.dtype(getattr(ml_dtypes, dtypes[i], dtypes[i])))
            leaves.append(arr)
        return leaves

    # exception families a torn/truncated checkpoint surfaces as: zip
    # directory damage (BadZipFile subclasses Exception, not OSError),
    # short reads, missing entries, mangled JSON (JSONDecodeError
    # subclasses ValueError)
    _TORN_ERRORS = (OSError, ValueError, KeyError, EOFError,
                    zipfile.BadZipFile)

    def restore(self, template, step: int | None = None,
                shardings=None):
        """Restore into the structure of ``template``.

        With ``step=None`` the newest *readable* checkpoint wins: a torn
        or truncated latest (crash mid-write on a filesystem that
        reordered around the rename) is skipped with a warning and the
        previous step is restored instead — an explicit ``step`` is
        trusted and raises on damage. ``shardings``: optional matching
        tree of jax.sharding.Sharding — pass the *current* mesh's
        shardings to reshard elastically.
        """
        leaves = None
        if step is not None:
            leaves = self._load_leaves(step)
        else:
            for cand in reversed(self.all_steps()):
                try:
                    leaves = self._load_leaves(cand)
                    step = cand
                    break
                except self._TORN_ERRORS as e:
                    import warnings
                    warnings.warn(
                        f"checkpoint step_{cand:08d} is torn "
                        f"({type(e).__name__}: {e}); falling back to the "
                        "previous step", RuntimeWarning, stacklevel=2)
            if leaves is None:
                raise FileNotFoundError(
                    f"no readable checkpoints in {self.dir}")
        flat_t, treedef = jax.tree_util.tree_flatten(template)
        if len(flat_t) != len(leaves):
            raise ValueError(
                f"checkpoint has {len(leaves)} leaves, template {len(flat_t)}")
        if shardings is not None:
            flat_s, _ = jax.tree_util.tree_flatten(shardings)
            leaves = [jax.device_put(l.astype(t.dtype), s)
                      for l, t, s in zip(leaves, flat_t, flat_s)]
        else:
            leaves = [jax.device_put(l.astype(t.dtype)) for l, t in
                      zip(leaves, flat_t)]
        return jax.tree_util.tree_unflatten(treedef, leaves), step
