"""Metrics exposition: Prometheus text format, JSON dumps, HTTP endpoint.

Three consumers of :class:`repro.obs.metrics.MetricsRegistry` snapshots:

* :func:`prometheus_text` — the Prometheus text exposition format
  (version 0.0.4: ``# HELP``/``# TYPE`` headers, ``_bucket{le=...}``
  cumulative histogram rows, escaped label values). Works on either a
  live registry or an already-taken ``snapshot()`` dict, so CI artifacts
  and the live endpoint render identically.
* :func:`write_json_snapshot` — the JSON artifact shape bench-smoke
  uploads (schema: the raw ``snapshot()`` dict under ``"metrics"`` plus a
  ``"format"`` tag).
* :class:`MetricsServer` — a stdlib ``http.server`` daemon thread serving
  ``/metrics`` (text) and ``/metrics.json``; this is what
  ``launch/serve.py --metrics-port`` starts. Zero dependencies, one
  thread, scrape-safe (every request renders a fresh snapshot).

The server also answers ``/healthz`` (process liveness — always 200
while the thread runs) and ``/readyz`` (readiness: an optional ``ready``
callable, typically ``ServeSupervisor.health``, decides 200 vs 503 — a
recovering or terminally-failed supervisor reports not-ready). The
gateway serves the same two probes on its own port via
:func:`health_response`, so orchestrators can point one probe config at
either tier.
"""
from __future__ import annotations

import http.server
import json
import threading
from typing import Union

from .metrics import MetricsRegistry

_ESCAPES = str.maketrans({"\\": r"\\", '"': r"\"", "\n": r"\n"})
_HELP_ESCAPES = str.maketrans({"\\": r"\\", "\n": r"\n"})


def _fmt_value(v: float) -> str:
    if v == int(v) and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


def _fmt_labels(labels: dict, extra: str = "") -> str:
    parts = [f'{k}="{str(v).translate(_ESCAPES)}"' for k, v in labels.items()]
    if extra:
        parts.append(extra)
    return "{" + ",".join(parts) + "}" if parts else ""


def _fmt_le(edge: float) -> str:
    return _fmt_value(edge) if edge == int(edge) else repr(float(edge))


def prometheus_text(source: Union[MetricsRegistry, dict]) -> str:
    """Render a registry (or a ``snapshot()`` dict) as exposition text."""
    snap = source.snapshot() if isinstance(source, MetricsRegistry) else source
    lines = []
    for name in sorted(snap):
        fam = snap[name]
        if fam["help"]:
            lines.append(f"# HELP {name} "
                         f"{fam['help'].translate(_HELP_ESCAPES)}")
        lines.append(f"# TYPE {name} {fam['type']}")
        for series in fam["series"]:
            labels = series["labels"]
            if fam["type"] == "histogram":
                cum = 0
                for edge, n in zip(series["bucket_edges"], series["buckets"]):
                    cum += n
                    le = 'le="' + _fmt_le(edge) + '"'
                    lines.append(f"{name}_bucket{_fmt_labels(labels, le)}"
                                 f" {cum}")
                lines.append(
                    f"{name}_bucket" + _fmt_labels(labels, 'le="+Inf"')
                    + f" {series['count']}")
                lines.append(f"{name}_sum{_fmt_labels(labels)}"
                             f" {_fmt_value(series['sum'])}")
                lines.append(f"{name}_count{_fmt_labels(labels)}"
                             f" {series['count']}")
            else:
                lines.append(f"{name}{_fmt_labels(labels)}"
                             f" {_fmt_value(series['value'])}")
    return "\n".join(lines) + "\n"


def write_json_snapshot(registry: MetricsRegistry, path: str) -> None:
    """Dump the registry snapshot as a CI-artifact JSON file."""
    with open(path, "w") as f:
        json.dump({"format": "torr-metrics-snapshot-v1",
                   "metrics": registry.snapshot()}, f, indent=1)
        f.write("\n")


def health_response(ready) -> tuple:
    """Evaluate a readiness source into ``(status, body_dict)``.

    ``ready`` may be None (always ready), a bool, a zero-arg callable
    returning either a bool or a health dict with a ``"ready"`` key
    (:meth:`ServeSupervisor.health`). A raising callable is *not ready*
    — a probe must never 200 because the health check itself crashed."""
    state = {"ready": True}
    if callable(ready):
        try:
            ready = ready()
        except Exception as e:   # noqa: BLE001 — fail closed
            ready = {"ready": False, "error": f"{type(e).__name__}: {e}"}
    if isinstance(ready, dict):
        state = dict(ready)
        state["ready"] = bool(state.get("ready", True))
    elif ready is not None:
        state = {"ready": bool(ready)}
    return (200 if state["ready"] else 503), state


class _Handler(http.server.BaseHTTPRequestHandler):
    registry: MetricsRegistry = None  # patched per-server subclass
    ready = None                      # optional readiness callable

    def do_GET(self):  # noqa: N802 (stdlib handler contract)
        path = self.path.split("?", 1)[0]
        status = 200
        if path in ("/metrics", "/"):
            body = prometheus_text(self.registry).encode()
            ctype = "text/plain; version=0.0.4; charset=utf-8"
        elif path == "/metrics.json":
            body = json.dumps(self.registry.snapshot()).encode()
            ctype = "application/json"
        elif path == "/healthz":
            body = json.dumps({"ok": True}).encode()
            ctype = "application/json"
        elif path == "/readyz":
            status, state = health_response(type(self).ready)
            body = json.dumps(state).encode()
            ctype = "application/json"
        else:
            self.send_error(404)
            return
        self.send_response(status)
        self.send_header("Content-Type", ctype)
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def log_message(self, *args):  # scrapes are not serving events
        pass


class MetricsServer:
    """``/metrics`` + health-probe endpoint on a daemon thread.

    ``port=0`` binds an ephemeral port; read the bound one from ``.port``
    after :meth:`start`. The thread is a daemon so a crashed serving loop
    never hangs on the scrape endpoint. ``ready`` (optional callable,
    e.g. ``supervisor.health``) backs ``/readyz``.
    """

    def __init__(self, registry: MetricsRegistry, port: int = 0,
                 host: str = "127.0.0.1", ready=None):
        handler = type("_BoundHandler", (_Handler,),
                       {"registry": registry, "ready": staticmethod(ready)
                        if callable(ready) else ready})
        self._httpd = http.server.ThreadingHTTPServer((host, port), handler)
        self._httpd.daemon_threads = True
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, name="torr-metrics",
            daemon=True)
        self.port = self._httpd.server_address[1]

    def start(self) -> int:
        self._thread.start()
        return self.port

    def set_ready(self, ready) -> None:
        """(Re)wire the ``/readyz`` readiness source — the supervisor is
        usually built after the scrape server, so launchers wire it in
        late (``server.set_ready(sup.health)``)."""
        self._httpd.RequestHandlerClass.ready = \
            staticmethod(ready) if callable(ready) else ready

    def close(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join(timeout=5.0)
