"""Chrome trace-event JSON (Perfetto-loadable) from enriched flight records.

The flight recorder's per-step records, once enriched with per-window
:class:`~repro.obs.trace.TraceContext` dicts (the ``"trace"`` key the
engines attach when a :class:`~repro.obs.trace.Tracer` is armed), carry
everything a timeline UI needs: per-window phase intervals with the
thread that executed them, admission verdicts, resolved plan/lowering,
and the governor's state at dispatch. :func:`chrome_trace` renders that
into the Chrome trace-event format — ``chrome://tracing`` and
https://ui.perfetto.dev both load it directly:

* one **complete event** (``ph: "X"``) per (window, phase) interval,
  placed on the thread row that executed the phase (``host_decide`` /
  ``dispatch_enqueue`` on the dispatcher, ``device_step`` /
  ``collector_drain`` on the collector, the queue wait on a virtual
  ``admission_queue`` row), args carrying the window identity and its
  resolved plan/lowering;
* one **async flow** per window (``ph: "s"`` → ``ph: "f"``, ``id`` =
  window seq): the arrow leaves the dispatcher at its last
  dispatcher-side phase and binds to the collector's first phase —
  Perfetto draws the dispatcher→collector hand-off per window;
* **counter tracks** (``ph: "C"``) per dispatched step for the governor
  plan level, the energy EWMA (mJ) and the queue depth, so plan ladder
  moves line up visually with the windows that caused them;
* **instant markers** (``ph: "i"``, global scope) for the supervisor's
  ``engine_crash`` / ``engine_recovered`` epoch records, so a recovery
  window is visible as a bracketed gap in the timeline.

``ts``/``dur`` are microseconds on the process-wide trace epoch
(:func:`repro.obs.trace.now_us`), the unit the format specifies.
``python -m repro.launch.serve --trace-json out.json`` and
``python -m benchmarks.table7_async --trace-json out.json`` both write
this shape; schema assertions live in ``tests/test_trace.py``.
"""
from __future__ import annotations

import json
from typing import Iterable, List, Optional

PROCESS_NAME = "torr-serve"
QUEUE_THREAD = "admission_queue"

# stable row ordering in the UI: queue on top, then the engine threads in
# causal order; unknown thread names sort after these
_THREAD_ORDER = (QUEUE_THREAD, "MainThread", "torr-dispatch", "torr-collect")

# phases whose executing thread is the dispatch side of the flow arrow
_DISPATCH_PHASES = ("host_decide", "host_assemble", "dispatch_enqueue")


def _tid_table(records: Iterable[dict]) -> dict:
    """Deterministic thread-name → tid assignment over the record set."""
    names = [QUEUE_THREAD]
    for rec in records:
        for w in rec.get("trace") or ():
            for ev in w.get("events", ()):
                t = ev.get("thread")
                if t and t not in names:
                    names.append(t)
    names.sort(key=lambda n: (_THREAD_ORDER.index(n)
                              if n in _THREAD_ORDER else len(_THREAD_ORDER),
                              n))
    return {name: i + 1 for i, name in enumerate(names)}


def _window_args(w: dict) -> dict:
    args = {"seq": w.get("seq"), "stream": w.get("stream"),
            "slot": w.get("slot"), "step": w.get("step"),
            "decision": w.get("decision"), "engine": w.get("engine")}
    if w.get("plan"):
        args["plan"] = w["plan"]
    if w.get("lowering"):
        args["lowering"] = w["lowering"]
    return args


def chrome_trace(records: Iterable[dict], pid: int = 1) -> dict:
    """Render enriched flight records to a Chrome trace-event document.

    Records without a ``"trace"`` key (untraced runs, pure SLO event
    records) contribute nothing but their counter samples; per-window
    events, flows and counters all come from the same record set, so one
    flight JSONL spill is the complete export input.
    """
    records = list(records)
    tids = _tid_table(records)
    events: List[dict] = [{
        "name": "process_name", "ph": "M", "pid": pid, "tid": 0,
        "args": {"name": PROCESS_NAME},
    }]
    for name, tid in sorted(tids.items(), key=lambda kv: kv[1]):
        events.append({"name": "thread_name", "ph": "M", "pid": pid,
                       "tid": tid, "args": {"name": name}})
        events.append({"name": "thread_sort_index", "ph": "M", "pid": pid,
                       "tid": tid, "args": {"sort_index": tid}})

    for rec in records:
        step_ts: Optional[float] = rec.get("ts_us")
        if rec.get("event") in ("engine_crash", "engine_recovered") \
                and step_ts is not None:
            args = {k: v for k, v in rec.items()
                    if k not in ("event", "ts_us", "trace")}
            events.append({
                "name": rec["event"], "ph": "i", "s": "g", "cat": "recovery",
                "ts": step_ts, "pid": pid, "tid": 0, "args": args,
            })
            continue
        for w in rec.get("trace") or ():
            args = _window_args(w)
            seq = w.get("seq")
            evs = sorted(w.get("events", ()), key=lambda e: e["ts_us"])
            # queue wait: arrival → first engine phase, on the virtual row
            if evs and w.get("arrival_us") is not None:
                wait = max(evs[0]["ts_us"] - w["arrival_us"], 0.0)
                events.append({
                    "name": "queue_wait", "ph": "X", "cat": "window",
                    "ts": w["arrival_us"], "dur": wait, "pid": pid,
                    "tid": tids[QUEUE_THREAD], "args": args,
                })
            dispatch_end = collect_start = None
            collect_tid = None
            for ev in evs:
                tid = tids.get(ev.get("thread"), tids[QUEUE_THREAD])
                events.append({
                    "name": ev["phase"], "ph": "X", "cat": "window",
                    "ts": ev["ts_us"], "dur": ev["dur_us"], "pid": pid,
                    "tid": tid, "args": args,
                })
                if ev["phase"] in _DISPATCH_PHASES:
                    dispatch_end = (ev["ts_us"] + ev["dur_us"], tid)
                elif collect_start is None:
                    collect_start, collect_tid = ev["ts_us"], tid
            if step_ts is None and evs:
                step_ts = evs[0]["ts_us"]
            # flow arrow across the thread hand-off (async engine); a
            # same-thread run (sync engine) has no collector-side phase
            # after its last dispatch phase, so no arrow is emitted
            if (seq is not None and dispatch_end is not None
                    and collect_start is not None
                    and collect_tid != dispatch_end[1]):
                events.append({
                    "name": "window", "ph": "s", "cat": "flow", "id": seq,
                    "ts": dispatch_end[0], "pid": pid,
                    "tid": dispatch_end[1], "args": {"seq": seq},
                })
                events.append({
                    "name": "window", "ph": "f", "bp": "e", "cat": "flow",
                    "id": seq, "ts": max(collect_start, dispatch_end[0]),
                    "pid": pid, "tid": collect_tid, "args": {"seq": seq},
                })
        if step_ts is None:
            continue
        gov = rec.get("governor") or {}
        if gov.get("level") is not None:
            events.append({"name": "plan_level", "ph": "C", "ts": step_ts,
                           "pid": pid, "args": {"level": gov["level"]}})
        if gov.get("energy_ewma_mj") is not None:
            events.append({"name": "energy_ewma_mj", "ph": "C",
                           "ts": step_ts, "pid": pid,
                           "args": {"mj": gov["energy_ewma_mj"]}})
        if rec.get("queue_depth") is not None:
            events.append({"name": "queue_depth", "ph": "C", "ts": step_ts,
                           "pid": pid,
                           "args": {"windows": rec["queue_depth"]}})

    return {"traceEvents": events, "displayTimeUnit": "ms",
            "otherData": {"producer": "repro.obs.trace_export"}}


def write_chrome_trace(records: Iterable[dict], path: str) -> int:
    """Write the Chrome trace JSON; returns the event count."""
    doc = chrome_trace(records)
    with open(path, "w") as f:
        json.dump(doc, f)
        f.write("\n")
    return len(doc["traceEvents"])
