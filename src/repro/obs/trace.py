"""Per-window causal trace contexts: the serving stack's timeline tier.

PR 7's telemetry is all aggregates — histograms, counters, a flight ring —
so a single slow window cannot be *attributed*: was it admission queueing,
the host decide pass, the device step, or the collector drain? This module
adds the causal layer. A :class:`TraceContext` is minted per submitted
window (monotone window ``seq``, stream id, slot, engine family) and
threaded through the engines' dispatcher → device → collector path; every
phase the engines already wrap in a :class:`~repro.obs.spans.span` stamps
a ``(phase, ts_us, dur_us, thread)`` event onto the windows in flight, so
the span histograms' anonymous samples become causally-linked per-window
events — including across the async engine's dispatcher/collector thread
boundary, which is what lets :mod:`repro.obs.trace_export` draw Perfetto
flow arrows between the two threads.

Mechanics
=========

* :class:`Tracer` mints contexts (one lock hit per ``submit``) and keeps
  the completed ones in a bounded ring (``dropped`` counts falls off the
  old end, surfaced as ``torr_trace_windows_dropped_total``).
* :class:`trace_scope` attaches a *list* of contexts to the current
  thread. A :class:`~repro.obs.spans.span` exiting while a scope is
  active calls :func:`record_span`, which stamps the span's interval onto
  every context in the scope. The list may be populated *during* the
  scope (the dispatcher's decide span opens before admission picks the
  step's windows) — stamping happens at span exit, when the step's
  composition is known.
* Timestamps are microseconds on a process-wide ``perf_counter`` epoch
  (:func:`now_us`), so events from different threads order correctly and
  Chrome-trace ``ts`` fields need no further normalization.

Cost model: with no tracer armed the only addition to the span hot path
is one thread-local ``getattr`` per span exit (:func:`record_span`'s
empty-scope early-out), which keeps the instrumented engines inside the
``micro_aligner --obs-overhead`` ≤ 3% gate. With a tracer armed the cost
is one list append per (span, in-flight window) pair per step — never on
a per-proposal path.

The per-window dict shape (:meth:`TraceContext.to_dict`) is embedded into
flight records under ``"trace"`` (see ``docs/observability.md``), which
is the input :mod:`repro.obs.trace_export` renders to Chrome trace-event
JSON.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import List, Optional

TRACE_SCHEMA_VERSION = 1

# process-wide epoch: every trace timestamp is microseconds since import,
# comparable across threads (perf_counter is a single monotonic clock)
_EPOCH = time.perf_counter()


def now_us() -> float:
    """Microseconds since the process-wide trace epoch."""
    return (time.perf_counter() - _EPOCH) * 1e6


class TraceContext:
    """One window's causal timeline: identity, verdicts, phase events.

    Mutable by design — the dispatcher fills identity and the admission
    verdict, span exits append phase events (possibly from the collector
    thread), and the drain stamps the resolved plan/lowering read back
    off the step's telemetry. Single-writer per field: each field is
    owned by exactly one engine phase, so no lock is needed beyond the
    Tracer's mint/complete counters.
    """

    __slots__ = ("seq", "stream_id", "slot", "engine", "arrival_us",
                 "step", "decision", "plan", "lowering", "events",
                 "complete_us")

    def __init__(self, seq: int, stream_id, engine: str, arrival_us: float):
        self.seq = seq
        self.stream_id = stream_id
        self.engine = engine
        self.arrival_us = arrival_us
        self.slot: Optional[int] = None
        self.step: Optional[int] = None       # flight-record step index
        self.decision: Optional[str] = None   # admit / escalate / shed
        self.plan: Optional[dict] = None      # resolved (banks, planes[, level])
        self.lowering: Optional[dict] = None  # resolved (fused, decide, tier)
        self.events: List[dict] = []          # {phase, ts_us, dur_us, thread}
        self.complete_us: Optional[float] = None

    def stamp(self, phase: str, ts_us: float, dur_us: float,
              thread: Optional[str] = None) -> None:
        """Append one phase interval (``thread`` defaults to the caller's)."""
        self.events.append({
            "phase": phase, "ts_us": ts_us, "dur_us": dur_us,
            "thread": thread if thread is not None
            else threading.current_thread().name,
        })

    def to_dict(self) -> dict:
        """JSONL-ready dict — the flight record's ``"trace"`` entry shape."""
        return {
            "v": TRACE_SCHEMA_VERSION,
            "seq": self.seq,
            "stream": self.stream_id,
            "slot": self.slot,
            "engine": self.engine,
            "step": self.step,
            "decision": self.decision,
            "arrival_us": self.arrival_us,
            "complete_us": self.complete_us,
            "plan": self.plan,
            "lowering": self.lowering,
            "events": list(self.events),
        }


class Tracer:
    """Mints per-window contexts; keeps completed ones in a bounded ring.

    ``capacity`` bounds host memory exactly like the flight ring does
    (default 65536 windows ≈ tens of minutes of 60 FPS serving across 16
    streams); ``dropped`` counts contexts that fell off the old end,
    surfaced as ``torr_trace_windows_dropped_total`` when a registry is
    wired.
    """

    def __init__(self, capacity: int = 65536, metrics=None):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self._lock = threading.Lock()
        self._seq = 0
        self._ring: deque = deque(maxlen=capacity)
        self.dropped = 0
        self._c_minted = self._c_dropped = None
        if metrics is not None:
            self._c_minted = metrics.counter(
                "torr_trace_windows_total",
                "Windows minted a causal trace context at submission.")
            self._c_dropped = metrics.counter(
                "torr_trace_windows_dropped_total",
                "Completed trace contexts that fell off the bounded ring.")

    def mint(self, stream_id, engine: str) -> TraceContext:
        """New context with the next window sequence number."""
        with self._lock:
            seq = self._seq
            self._seq += 1
        if self._c_minted is not None:
            self._c_minted.inc()
        return TraceContext(seq, stream_id, engine, now_us())

    @property
    def minted(self) -> int:
        with self._lock:
            return self._seq

    def complete(self, ctx: TraceContext) -> None:
        """Retire one context into the bounded ring (drain/shed time)."""
        if ctx.complete_us is None:
            ctx.complete_us = now_us()
        with self._lock:
            if len(self._ring) == self.capacity:
                self.dropped += 1
                if self._c_dropped is not None:
                    self._c_dropped.inc()
            self._ring.append(ctx)

    def completed(self) -> List[TraceContext]:
        """Snapshot of the completed-window ring, oldest first."""
        with self._lock:
            return list(self._ring)


# -- span → context stamping --------------------------------------------------

_scope_tls = threading.local()


def _scope_stack() -> list:
    stack = getattr(_scope_tls, "stack", None)
    if stack is None:
        stack = _scope_tls.stack = []
    return stack


class trace_scope:
    """Attach a list of contexts to this thread for the enclosed region.

    Spans exiting inside the scope stamp their interval onto every
    context in ``ctxs`` *at exit time* — so a scope may be entered with
    an initially-empty list that the enclosed code populates (the
    dispatcher's decide span covers admission itself). Scopes nest; only
    the innermost receives span events (matching span nesting semantics:
    each level records independently).
    """

    __slots__ = ("ctxs",)

    def __init__(self, ctxs: List[TraceContext]):
        self.ctxs = ctxs

    def __enter__(self) -> "trace_scope":
        _scope_stack().append(self.ctxs)
        return self

    def __exit__(self, *exc) -> bool:
        stack = _scope_stack()
        if stack and stack[-1] is self.ctxs:
            stack.pop()
        return False


def record_span(name: str, t0_s: float, dur_s: float) -> None:
    """Stamp one finished span onto the innermost active scope's contexts.

    Called by :class:`repro.obs.spans.span` on every exit; with no active
    scope this is one thread-local ``getattr`` and a truthiness check —
    the price untraced engines pay.
    """
    stack = getattr(_scope_tls, "stack", None)
    if not stack:
        return
    ts_us = (t0_s - _EPOCH) * 1e6
    dur_us = dur_s * 1e6
    thread = threading.current_thread().name
    for ctx in stack[-1]:
        ctx.stamp(name, ts_us, dur_us, thread)
