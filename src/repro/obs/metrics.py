"""Process-local metrics registry: counters, gauges, fixed-bucket histograms.

The serving observability tier (ISSUE 7): a zero-dependency, best-effort
metrics substrate in the mold of production CLIP-serving backends'
``metrics.py`` counters/gauges/histograms — *not* a client-library clone.
Three metric kinds, each with optional labels:

  * :class:`Counter`   — monotonically increasing float (``inc``);
  * :class:`Gauge`     — instantaneous float (``set``/``inc``/``dec``);
  * :class:`Histogram` — fixed cumulative buckets + sum + count
    (``observe``), Prometheus-shaped so exposition is a straight dump.

Hot-path contract
=================

Increments are **lock-cheap**: a child (one labeled time series) mutates
plain Python floats without taking any lock. Under CPython's GIL a lost
update is possible only when two threads race the same read-modify-write —
acceptable for best-effort serving metrics, and the price of keeping
``inc()`` off every engine hot path's critical section. Registry- and
metric-level *structure* (new metric families, new label sets) is guarded
by one registry lock; :meth:`MetricsRegistry.snapshot` copies under that
lock, so a snapshot is an isolated, immutable view (mutating the registry
afterwards never changes an already-taken snapshot).

Label cardinality is bounded per metric family (``max_series``, default
512): the 513th distinct label set raises instead of silently eating
memory — an unbounded-label bug should fail loudly in CI, not OOM a
serving host.

Naming follows the Prometheus conventions: families are snake_case with a
``torr_`` prefix and unit suffixes (``_total``, ``_seconds``, ``_mj``);
the full catalog lives in ``docs/observability.md``. Exposition (text
format + JSON + the HTTP endpoint) lives in :mod:`repro.obs.export`.
"""
from __future__ import annotations

import math
import re
import threading
from typing import Dict, Iterable, Mapping, Optional, Sequence, Tuple

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")

# Default latency buckets for span/step histograms: 100 us .. 10 s, the
# envelope between a single fused dispatch and a badly backlogged step.
LATENCY_BUCKETS_S = (
    1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3, 1e-2, 2.5e-2, 5e-2,
    1e-1, 2.5e-1, 5e-1, 1.0, 2.5, 5.0, 10.0,
)


class _Child:
    """One labeled time series of a counter/gauge. Unlocked mutation."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        self.value += amount

    def dec(self, amount: float = 1.0) -> None:
        self.value -= amount

    def set(self, value: float) -> None:
        self.value = float(value)


class _HistChild:
    """One labeled histogram series: cumulative bucket counts + sum."""

    __slots__ = ("counts", "sum")

    def __init__(self, n_buckets: int):
        self.counts = [0] * (n_buckets + 1)   # +1 for the +Inf bucket
        self.sum = 0.0

    def observe(self, value: float, edges: Sequence[float]) -> None:
        # linear scan: span histograms have ~16 edges and the scan is
        # cheaper than bisect's function-call overhead at that width
        i = 0
        for edge in edges:
            if value <= edge:
                break
            i += 1
        self.counts[i] += 1
        self.sum += value

    @property
    def count(self) -> int:
        return sum(self.counts)


class _Metric:
    """Shared family machinery: name, help, label schema, child table."""

    kind = "untyped"

    def __init__(self, registry: "MetricsRegistry", name: str, help: str,
                 labelnames: Tuple[str, ...]):
        self._registry = registry
        self.name = name
        self.help = help
        self.labelnames = labelnames
        self._children: Dict[Tuple[str, ...], object] = {}
        if not labelnames:
            self._default = self._children[()] = self._new_child()

    def _new_child(self):
        raise NotImplementedError

    def labels(self, **labels: str):
        """The child for one label set (created on first use, then cached).

        Raises ``ValueError`` on a label-name mismatch or when the family
        would exceed the registry's ``max_series`` cardinality bound."""
        if tuple(sorted(labels)) != tuple(sorted(self.labelnames)):
            raise ValueError(
                f"metric {self.name!r} takes labels {self.labelnames}, "
                f"got {tuple(labels)}")
        key = tuple(str(labels[ln]) for ln in self.labelnames)
        child = self._children.get(key)
        if child is None:
            with self._registry._lock:
                child = self._children.get(key)
                if child is None:
                    if len(self._children) >= self._registry.max_series:
                        raise ValueError(
                            f"metric {self.name!r} exceeded max_series="
                            f"{self._registry.max_series} label sets "
                            f"(cardinality bound)")
                    child = self._children[key] = self._new_child()
        return child

    def _series(self) -> Iterable[Tuple[Tuple[str, ...], object]]:
        return list(self._children.items())


class Counter(_Metric):
    kind = "counter"

    def _new_child(self) -> _Child:
        return _Child()

    def inc(self, amount: float = 1.0) -> None:
        """Unlabeled fast path (labelless families only)."""
        self._default.inc(amount)

    @property
    def value(self) -> float:
        return self._default.value


class Gauge(_Metric):
    kind = "gauge"

    def _new_child(self) -> _Child:
        return _Child()

    def set(self, value: float) -> None:
        self._default.set(value)

    def inc(self, amount: float = 1.0) -> None:
        self._default.inc(amount)

    def dec(self, amount: float = 1.0) -> None:
        self._default.dec(amount)

    @property
    def value(self) -> float:
        return self._default.value


class HistogramChild:
    """Bound (child, edges) pair so ``observe`` needs no edge lookup."""

    __slots__ = ("_child", "_edges")

    def __init__(self, child: _HistChild, edges: Sequence[float]):
        self._child = child
        self._edges = edges

    def observe(self, value: float) -> None:
        self._child.observe(value, self._edges)


class Histogram(_Metric):
    kind = "histogram"

    def __init__(self, registry, name, help, labelnames,
                 buckets: Sequence[float]):
        edges = tuple(float(b) for b in buckets)
        if not edges or any(e2 <= e1 for e1, e2 in zip(edges, edges[1:])):
            raise ValueError(
                f"histogram {name!r} buckets must be a non-empty strictly "
                f"increasing sequence, got {buckets}")
        if any(math.isinf(e) for e in edges):
            raise ValueError("the +Inf bucket is implicit; do not pass it")
        self.buckets = edges
        super().__init__(registry, name, help, labelnames)

    def _new_child(self) -> _HistChild:
        return _HistChild(len(self.buckets))

    def labels(self, **labels: str) -> HistogramChild:
        return HistogramChild(super().labels(**labels), self.buckets)

    def observe(self, value: float) -> None:
        """Unlabeled fast path (labelless families only)."""
        self._default.observe(value, self.buckets)


class MetricsRegistry:
    """A process-local family table with snapshot/exposition support.

    ``max_series`` bounds label cardinality *per family* (see module
    docstring). Family registration is idempotent when the (kind, labels,
    buckets) schema matches — ``registry.counter(...)`` from two call
    sites returns the same family — and raises on a schema conflict.
    """

    def __init__(self, max_series: int = 512):
        self._lock = threading.Lock()
        self._metrics: Dict[str, _Metric] = {}
        self.max_series = max_series

    def _register(self, cls, name: str, help: str,
                  labelnames: Sequence[str] = (), **kw):
        if not _NAME_RE.match(name):
            raise ValueError(f"invalid metric name {name!r}")
        labelnames = tuple(labelnames)
        for ln in labelnames:
            if not _LABEL_RE.match(ln):
                raise ValueError(f"invalid label name {ln!r}")
        with self._lock:
            existing = self._metrics.get(name)
            if existing is not None:
                if (type(existing) is not cls
                        or existing.labelnames != labelnames
                        or kw.get("buckets") is not None
                        and getattr(existing, "buckets", None)
                        != tuple(kw["buckets"])):
                    raise ValueError(
                        f"metric {name!r} already registered with a "
                        f"different schema")
                return existing
            metric = (cls(self, name, help, labelnames, kw["buckets"])
                      if cls is Histogram
                      else cls(self, name, help, labelnames))
            self._metrics[name] = metric
            return metric

    def counter(self, name: str, help: str = "",
                labelnames: Sequence[str] = ()) -> Counter:
        return self._register(Counter, name, help, labelnames)

    def gauge(self, name: str, help: str = "",
              labelnames: Sequence[str] = ()) -> Gauge:
        return self._register(Gauge, name, help, labelnames)

    def histogram(self, name: str, help: str = "",
                  labelnames: Sequence[str] = (),
                  buckets: Sequence[float] = LATENCY_BUCKETS_S) -> Histogram:
        return self._register(Histogram, name, help, labelnames,
                              buckets=tuple(buckets))

    # -- read side ----------------------------------------------------------

    def snapshot(self) -> Dict[str, dict]:
        """Deep-copied, JSON-safe view of every family.

        ``{name: {"type", "help", "labelnames", "series": [...]}}`` where a
        counter/gauge series is ``{"labels": {...}, "value": v}`` and a
        histogram series additionally carries ``"buckets"`` (cumulative
        counts aligned with ``"bucket_edges"``), ``"sum"`` and ``"count"``.
        The copy is taken under the registry lock, so later mutation never
        leaks into an already-taken snapshot.
        """
        with self._lock:
            metrics = dict(self._metrics)
        out: Dict[str, dict] = {}
        for name, m in sorted(metrics.items()):
            series = []
            for key, child in m._series():
                labels = dict(zip(m.labelnames, key))
                if isinstance(child, _HistChild):
                    counts = list(child.counts)
                    series.append({
                        "labels": labels,
                        "bucket_edges": list(m.buckets),
                        "buckets": counts,
                        "sum": child.sum,
                        "count": sum(counts),
                    })
                else:
                    series.append({"labels": labels, "value": child.value})
            out[name] = {"type": m.kind, "help": m.help,
                         "labelnames": list(m.labelnames), "series": series}
        return out

    def collect(self) -> Mapping[str, _Metric]:
        """Live family table (read-only use; exposition iterates this)."""
        with self._lock:
            return dict(self._metrics)


def quantile(series: Mapping, q: float) -> float:
    """Estimate quantile ``q`` from one histogram *snapshot series*.

    ``series`` is one entry of ``snapshot()[name]["series"]`` — the dict
    carrying ``bucket_edges`` (finite upper bounds), ``buckets``
    (per-bucket counts, one extra for +Inf) and ``count``. The estimate
    interpolates linearly inside the bucket the quantile rank lands in,
    assuming uniform density between edges (the first bucket's lower
    bound is 0 — latency-shaped; Prometheus' ``histogram_quantile`` makes
    the same assumptions, so the two agree). A rank landing in the
    overflow bucket clamps to the last finite edge — the estimator never
    invents mass beyond what the buckets bound, also matching Prometheus.

    Raises ``ValueError`` outside ``0 <= q <= 1``; returns ``nan`` for an
    empty series. ``benchmarks/trend.py`` and ``table7_async`` derive
    p99s from snapshots through this instead of re-keeping raw sample
    lists.
    """
    if not 0.0 <= q <= 1.0:
        raise ValueError(f"quantile must be in [0, 1], got {q}")
    edges = series["bucket_edges"]
    counts = series["buckets"]
    total = series.get("count", sum(counts))
    if total <= 0:
        return float("nan")
    rank = q * total
    cum = 0.0
    lo = 0.0
    for edge, n in zip(edges, counts):
        if cum + n >= rank:
            if n <= 0 or rank <= cum:
                return float(lo)
            return float(lo + (edge - lo) * (rank - cum) / n)
        cum += n
        lo = edge
    return float(edges[-1])   # rank fell in the +Inf bucket: clamp


def snapshot_quantile(snapshot: Mapping, name: str, q: float,
                      labels: Optional[Mapping] = None) -> float:
    """:func:`quantile` over a full ``MetricsRegistry.snapshot()`` dict.

    Picks the ``name`` family's series matching ``labels`` (``None`` =
    the single/unlabeled series); returns ``nan`` when the family or
    series is absent, so artifact post-processing never crashes on a
    partially-instrumented run.
    """
    fam = snapshot.get(name)
    if fam is None or fam.get("type") != "histogram":
        return float("nan")
    for series in fam["series"]:
        if labels is None or series["labels"] == dict(labels):
            return quantile(series, q)
    return float("nan")


_default_registry = MetricsRegistry()


def default_registry() -> MetricsRegistry:
    """The process-wide default registry (what ``serve.py`` and the
    benchmark harness expose when no explicit registry is wired)."""
    return _default_registry
