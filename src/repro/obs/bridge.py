"""StepObserver: the engines' single attachment point for observability.

One :class:`StepObserver` instance per engine bundles the metric handles
(pre-created once, so the per-step path is pure counter increments), the
optional :class:`~repro.obs.flight.FlightRecorder`, and the
``WindowTelemetry`` → digest reduction shared by the sync fold, the async
collector, and the benchmark overhead gate. Either pillar may be absent:
``registry=None`` turns every metric update into a no-op attribute check,
``flight=None`` skips record construction.

Per-step protocol (both engines):

1. ``rec = obs.on_dispatch(...)`` right after the jitted step launches —
   counts the step/windows/pad lanes and opens a flight record carrying
   the requested lowering, the latched plan, and the governor's state at
   dispatch time (``None`` without a flight recorder).
2. ``obs.observe_step(tel_host, rec, step_latency_s)`` once the step's
   telemetry is host-resident (the sync engine's deferred fold; the async
   collector) — reduces the [S]-batched trace to a digest, feeds the
   path-mix/deadline/latency metrics, and completes the flight record.
3. ``obs.drop(n)`` whenever observed windows are lost before step 2
   (collector drain on worker death, futures cancelled mid-flight) —
   the ``torr_telemetry_dropped_total`` counter is the audit trail for
   the silent-loss bug class this subsystem closes.

The digest's key names deliberately match ``perf.cycle_model``'s
vocabulary (``path`` names from ``core.types.PATH_NAMES``, ``banks``/
``planes``/``fused``/``decide``/``bucket_tier`` as in ``window_cost``)
so measured and modeled envelopes diff directly.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from ..core.types import (DECIDE_NAMES, FUSED_NAMES, PATH_BYPASS, PATH_DELTA,
                          PATH_FULL, PATH_NAMES)
from .flight import FlightRecorder
from .metrics import LATENCY_BUCKETS_S, MetricsRegistry


def telemetry_digest(tel_h) -> dict:
    """Reduce a host-resident [S]-batched ``WindowTelemetry`` to a digest.

    The telemetry carries no per-lane valid mask (and valid lanes need not
    be prefix-packed), so lane accounting leans on the pipeline's pad
    invariant (``pipeline._finish_window``): invalid/pad lanes report
    bypass with ``delta_count == 0`` and ``rho == 0.0``, and
    ``reasoner_active`` is valid-masked at the source. Delta/full counts
    are therefore exact unmasked sums, the bypass count falls out by
    subtraction from ``n_valid``, and the rho quantiles drop exactly the
    pad lanes' zeros. Returns plain Python types — directly JSONL-able
    into a flight record.
    """
    path = np.asarray(tel_h.path)
    nv = np.asarray(tel_h.n_valid).astype(np.int64)
    n_valid = int(nv.sum())
    n_delta = int(np.sum(path == PATH_DELTA))
    n_full = int(np.sum(path == PATH_FULL))
    counts = {PATH_BYPASS: n_valid - n_delta - n_full,
              PATH_DELTA: n_delta, PATH_FULL: n_full}
    # pad lanes are exactly 0.0: strip their zeros, keep any genuine ones
    rho_all = np.asarray(tel_h.rho).ravel()
    rho_nz = rho_all[rho_all != 0.0]
    rho = np.concatenate(
        [rho_nz, np.zeros(max(n_valid - rho_nz.size, 0), rho_all.dtype)])
    fused_id = int(np.asarray(tel_h.fused_mode).reshape(-1)[0])
    decide_id = int(np.asarray(tel_h.decide_mode).reshape(-1)[0])
    digest = {
        "n_windows": int(np.sum(nv > 0)),
        "n_valid": n_valid,
        "path": {name: counts[i] for i, name in enumerate(PATH_NAMES)},
        "delta_dims": int(np.sum(
            np.asarray(tel_h.delta_count) * (path == PATH_DELTA))),
        "rho_p50": float(np.median(rho)) if rho.size else None,
        "rho_p90": float(np.quantile(rho, 0.9)) if rho.size else None,
        "reasoner_active": int(np.sum(np.asarray(tel_h.reasoner_active))),
        "high_load": int(np.sum(np.asarray(tel_h.high_load))),
        "banks": int(np.max(np.asarray(tel_h.banks))),
        "planes": int(np.max(np.asarray(tel_h.planes))),
        # resolved static lowering (identical across slots by construction:
        # fused/decide/bucket_cap are static jit args of the whole step)
        "fused": FUSED_NAMES[fused_id],
        "decide": DECIDE_NAMES[decide_id] if decide_id >= 0 else None,
        "bucket_tier": int(np.asarray(tel_h.bucket_tier).reshape(-1)[0]),
    }
    return digest


class StepObserver:
    """Metric handles + flight recorder behind one per-engine facade."""

    def __init__(self, registry: Optional[MetricsRegistry] = None,
                 flight: Optional[FlightRecorder] = None):
        self.registry = registry
        self.flight = flight
        r = registry
        if r is None:
            self._c_steps = None
            return
        self._c_steps = r.counter(
            "torr_steps_total", "Batched engine steps dispatched.")
        self._c_windows = r.counter(
            "torr_windows_total", "Non-pad windows served through steps.")
        self._c_pad = r.counter(
            "torr_pad_slots_total", "Idle slot-steps (wasted vmap lanes).")
        self._c_shed = r.counter(
            "torr_windows_shed_total",
            "Windows dropped by RT admission control.")
        self._c_admit = r.counter(
            "torr_streams_admitted_total", "Streams bound to slots.")
        self._c_retire = r.counter(
            "torr_streams_retired_total", "Streams released from slots.")
        self._c_dropped_windows = r.counter(
            "torr_windows_dropped_total",
            "Backlog windows discarded by retire().")
        path_c = r.counter(
            "torr_path_total",
            "Valid proposals by resolved Alg. 1 path.", ["path"])
        self._c_path = {i: path_c.labels(path=name)
                        for i, name in enumerate(PATH_NAMES)}
        self._c_delta = r.counter(
            "torr_delta_dims_total",
            "Summed |Delta| dimensions corrected via Eq. 6.")
        self._c_reasoner = r.counter(
            "torr_reasoner_active_total",
            "Proposals whose relational reasoner was not gated off.")
        self._c_high = r.counter(
            "torr_high_load_windows_total",
            "Windows whose load gate H(N, q) evaluated high.")
        self._c_tel_drop = r.counter(
            "torr_telemetry_dropped_total",
            "Observed steps/windows lost before telemetry was folded.")
        self._h_step = r.histogram(
            "torr_step_latency_seconds",
            "Dispatch to results-ready latency of one batched step.",
            buckets=LATENCY_BUCKETS_S)
        self._g_ewma = r.gauge(
            "torr_full_path_ewma",
            "Auto-dispatch full-path-fraction EWMA (compact tier input).")

    # -- scheduling events ---------------------------------------------------

    def on_admit(self) -> None:
        if self._c_steps is not None:
            self._c_admit.inc()

    def on_retire(self, dropped_windows: int) -> None:
        if self._c_steps is not None:
            self._c_retire.inc()
            if dropped_windows:
                self._c_dropped_windows.inc(dropped_windows)

    def on_shed(self, n: int = 1) -> None:
        if self._c_steps is not None:
            self._c_shed.inc(n)

    def drop(self, n: int) -> None:
        """Observed windows lost before their telemetry was folded."""
        if self._c_steps is not None:
            self._c_tel_drop.inc(n)

    # -- per-step protocol ---------------------------------------------------

    def on_dispatch(self, n_served: int, n_pad: int, requested=None,
                    plan=None, gov=None, full_ewma=None) -> Optional[dict]:
        """Record one launched step; returns the open flight record.

        ``requested`` is the ``(fused, bucket_cap, decide)`` static args
        the host dispatched with (the resolved lowering lands from the
        telemetry in :meth:`observe_step`); ``plan`` the latched
        ``KnobPlan`` (or None); ``gov`` a dict of the governor's state at
        dispatch (``level``/``slack``/``energy_ewma_mj``).
        """
        if self._c_steps is not None:
            self._c_steps.inc()
            self._c_windows.inc(n_served)
            self._c_pad.inc(n_pad)
            if full_ewma is not None:
                self._g_ewma.set(full_ewma)
        if self.flight is None:
            return None
        fields = {"n_windows": n_served}
        if requested is not None:
            fused, bucket_cap, decide = requested
            fields["requested"] = {
                "fused": fused, "bucket_cap": bucket_cap, "decide": decide}
        if plan is not None:
            fields["plan"] = {"banks": int(plan.banks),
                              "planes": int(plan.planes)}
        if gov is not None:
            fields["governor"] = gov
        return self.flight.record(**fields)

    def observe_step(self, tel_h, rec: Optional[dict] = None,
                     step_latency_s: Optional[float] = None) -> dict:
        """Fold one step's host-resident telemetry into metrics + record."""
        digest = telemetry_digest(tel_h)
        if self._c_steps is not None:
            for i, n in enumerate(digest["path"].values()):
                if n:
                    self._c_path[i].inc(n)
            if digest["delta_dims"]:
                self._c_delta.inc(digest["delta_dims"])
            if digest["reasoner_active"]:
                self._c_reasoner.inc(digest["reasoner_active"])
            if digest["high_load"]:
                self._c_high.inc(digest["high_load"])
            if step_latency_s is not None:
                self._h_step.observe(step_latency_s)
        if rec is not None:
            rec["telemetry"] = digest
            rec["lowering"] = {"fused": digest["fused"],
                               "decide": digest["decide"],
                               "bucket_tier": digest["bucket_tier"]}
            if step_latency_s is not None:
                rec["step_latency_s"] = step_latency_s
        return digest
