"""Profiling spans: nestable wall-clock timers feeding latency histograms.

``span("device_step")`` times a region and observes the duration into the
``torr_span_duration_seconds{span="device_step"}`` histogram of a
:class:`~repro.obs.metrics.MetricsRegistry`. The engines wrap their four
phases with these — dispatcher enqueue, device step, collector drain, and
the host decide/observe work — so the sync-vs-async overlap and the
host/device time split are readable live off ``/metrics`` instead of
inferred from table7 runs.

Spans nest: a thread-local stack tracks the active chain, and
:func:`current_span` exposes the innermost name (used by tests and handy
for debugging instrumentation placement). Nesting records each level
independently — parent durations *include* child durations, matching what
a sampling profiler would attribute.

Cost model: one ``perf_counter`` pair + one histogram observe per enter/
exit. With no registry wired (``registry=None``) entering a span is a
no-op stack push, so instrumented code paths stay below the 3% overhead
gate even when observability is off.

Causal linking: every span exit additionally offers its interval to
:func:`repro.obs.trace.record_span` — when a :class:`~repro.obs.trace.
trace_scope` is active on the thread, the span is stamped onto the
in-flight windows' :class:`~repro.obs.trace.TraceContext`\\ s, turning the
histogram's anonymous samples into causally-linked per-window events.
With no scope active the hook is one thread-local ``getattr``.
"""
from __future__ import annotations

import functools
import threading
import time
from typing import Optional

from .metrics import LATENCY_BUCKETS_S, MetricsRegistry
from .trace import record_span

SPAN_METRIC = "torr_span_duration_seconds"

_tls = threading.local()


class _NullSpan:
    """Do-nothing span for uninstrumented engines: the hot path pays two
    empty method calls per phase, nothing else (no stack push, no clock)."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


NULL_SPAN = _NullSpan()


def _stack():
    stack = getattr(_tls, "stack", None)
    if stack is None:
        stack = _tls.stack = []
    return stack


def current_span() -> Optional[str]:
    """Name of the innermost active span on this thread, if any."""
    stack = _stack()
    return stack[-1] if stack else None


def span_stack() -> tuple:
    """The active span chain on this thread, outermost first."""
    return tuple(_stack())


class span:
    """Context manager / decorator timing one named region.

    ``with span("collector_drain", registry): ...`` or::

        @span("host_decide", registry)
        def decide(...): ...

    The decorator form is thread-safe (per-call start times live on the
    call frame). A context-manager *instance* holds its start time, so
    don't share one instance across threads — construct per use, or keep
    one per single-threaded phase (what the engines do); construction
    after the first call is just a dict hit in the registry.
    """

    __slots__ = ("name", "_hist", "_t0")

    def __init__(self, name: str, registry: Optional[MetricsRegistry] = None):
        self.name = name
        if registry is None:
            self._hist = None
        else:
            self._hist = registry.histogram(
                SPAN_METRIC,
                "Wall-clock duration of instrumented serving phases.",
                ["span"], buckets=LATENCY_BUCKETS_S,
            ).labels(span=name)
        self._t0 = 0.0

    def __enter__(self):
        _stack().append(self.name)
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb):
        dur = time.perf_counter() - self._t0
        stack = _stack()
        if stack and stack[-1] == self.name:
            stack.pop()
        if self._hist is not None:
            self._hist.observe(dur)
        record_span(self.name, self._t0, dur)
        return False

    def __call__(self, fn):
        # decorator form: a fresh enter/exit per call, shared histogram
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            _stack().append(self.name)
            t0 = time.perf_counter()
            try:
                return fn(*args, **kwargs)
            finally:
                dur = time.perf_counter() - t0
                stack = _stack()
                if stack and stack[-1] == self.name:
                    stack.pop()
                if self._hist is not None:
                    self._hist.observe(dur)
                record_span(self.name, t0, dur)
        return wrapper
