"""RT-SLO burn-rate engine: multi-window alerting over the miss budget.

TorR's headline serving claim is *temporal* — RT-30/RT-60 deadlines held
as object counts vary — so the observability tier needs to watch whether
the miss budget is actually *burning*, not just count misses. This module
implements the standard multi-window burn-rate construction (the SRE
workbook's alerting-on-SLOs recipe) adapted to window-count rolling
windows, which keeps the engine clock-free and unit-testable:

* The **SLO** is an objective fraction of served windows that must
  complete inside their RT budget (default 99%); the **miss budget** is
  ``1 - objective``.
* **Burn rate** over a rolling window of the last ``N`` completions is
  ``miss_rate / miss_budget`` — burn 1.0 consumes the budget exactly at
  the sustainable rate; burn 14.4 exhausts a 30-day budget in ~2 days.
* Alerting is **multi-window**: a threshold trips only when *both* the
  fast window (reacts quickly, noisy alone) and the slow window
  (confirms the burn is sustained) exceed it. Two levels:

    level  | condition (fast AND slow burn)  | default threshold
    ------ | ------------------------------- | -----------------
    PAGE=2 | ``>= page_burn``                | 14.4
    WARN=1 | ``>= warn_burn``                | 6.0
    OK=0   | otherwise                       |

:class:`SLOMonitor.observe` is fed one boolean per completed window by
:class:`~repro.serving.deadline.DeadlineTracker.complete` (shed windows
never complete and are *not* SLO events — admission already paid for
them). State is exported three ways:

* gauges ``torr_slo_burn_rate{window=fast|slow}``, ``torr_slo_alert``
  (the level) and ``torr_slo_miss_budget_remaining`` (slow window);
* a flight event on every alert-level *transition* (an ``"slo"`` record
  in the flight ring, so the causal timeline shows when the budget
  started burning relative to plan/lowering changes);
* an optional ``on_alert(level, state)`` hook — the first concrete step
  toward the ROADMAP's trace-driven governor: ``Governor(..., slo=mon)``
  consults :attr:`alert_level` per update (WARN freezes plan recovery,
  PAGE forces one extra degrade step).
"""
from __future__ import annotations

import dataclasses
import threading
from collections import deque
from typing import Callable, Optional

SLO_OK, SLO_WARN, SLO_PAGE = 0, 1, 2
ALERT_NAMES = ("ok", "warn", "page")


@dataclasses.dataclass(frozen=True)
class SLOPolicy:
    """Static thresholds for the burn-rate engine (all clock-free)."""

    objective: float = 0.99      # fraction of windows that must make the RT
    fast_window: int = 64        # completions in the fast rolling window
    slow_window: int = 512       # completions in the slow rolling window
    warn_burn: float = 6.0       # fast AND slow burn >= -> WARN
    page_burn: float = 14.4      # fast AND slow burn >= -> PAGE
    min_events: int = 8          # completions before the fast window alerts

    def __post_init__(self):
        if not 0.0 < self.objective < 1.0:
            raise ValueError("objective must be in (0, 1)")
        if self.fast_window < 1 or self.slow_window < self.fast_window:
            raise ValueError("need 1 <= fast_window <= slow_window")
        if self.warn_burn > self.page_burn:
            raise ValueError("warn_burn must not exceed page_burn")

    @property
    def miss_budget(self) -> float:
        return 1.0 - self.objective


def burn_rate(misses: int, total: int, miss_budget: float) -> float:
    """Burn of one rolling window: observed miss rate over the budget."""
    if total <= 0:
        return 0.0
    return (misses / total) / miss_budget


class SLOMonitor:
    """Mutable rolling-window state around the pure burn-rate math.

    Thread-safe: ``observe`` is called from the async collector while
    ``summary``/gauge scrapes happen on caller threads; one small lock
    per completed *window* (never per proposal).
    """

    def __init__(self, policy: SLOPolicy = SLOPolicy(), metrics=None,
                 flight=None,
                 on_alert: Optional[Callable[[int, dict], None]] = None):
        self.policy = policy
        self._flight = flight
        self._on_alert = on_alert
        self._lock = threading.Lock()
        self._fast: deque = deque(maxlen=policy.fast_window)
        self._slow: deque = deque(maxlen=policy.slow_window)
        self._fast_miss = 0
        self._slow_miss = 0
        self.completed = 0
        self.missed = 0
        self.alert_transitions = 0
        self._level = SLO_OK
        self._g_burn = None
        if metrics is not None:
            burn = metrics.gauge(
                "torr_slo_burn_rate",
                "Miss-budget burn rate over the rolling windows.", ["window"])
            self._g_burn = {"fast": burn.labels(window="fast"),
                            "slow": burn.labels(window="slow")}
            self._g_alert = metrics.gauge(
                "torr_slo_alert",
                "RT-SLO alert level (0 = ok, 1 = warn, 2 = page).")
            self._g_budget = metrics.gauge(
                "torr_slo_miss_budget_remaining",
                "Fraction of the slow-window miss budget still unspent.")

    # -- feed ---------------------------------------------------------------

    def observe(self, missed: bool) -> int:
        """Fold one completed window; returns the (possibly new) level."""
        with self._lock:
            if len(self._fast) == self._fast.maxlen:
                self._fast_miss -= self._fast[0]
            if len(self._slow) == self._slow.maxlen:
                self._slow_miss -= self._slow[0]
            m = 1 if missed else 0
            self._fast.append(m)
            self._slow.append(m)
            self._fast_miss += m
            self._slow_miss += m
            self.completed += 1
            self.missed += m
            fast, slow = self._burns_locked()
            level = self._level_for(fast, slow)
            transition = level != self._level
            if transition:
                self._level = level
                self.alert_transitions += 1
        if self._g_burn is not None:
            self._g_burn["fast"].set(fast)
            self._g_burn["slow"].set(slow)
            self._g_alert.set(level)
            self._g_budget.set(max(0.0, 1.0 - slow))
        if transition:
            state = {"level": level, "alert": ALERT_NAMES[level],
                     "burn_fast": fast, "burn_slow": slow,
                     "completed": self.completed}
            if self._flight is not None:
                self._flight.record(slo=state)
            if self._on_alert is not None:
                self._on_alert(level, state)
        return level

    # -- read side ----------------------------------------------------------

    def _burns_locked(self) -> tuple:
        budget = self.policy.miss_budget
        return (burn_rate(self._fast_miss, len(self._fast), budget),
                burn_rate(self._slow_miss, len(self._slow), budget))

    def _level_for(self, fast: float, slow: float) -> int:
        # multi-window: a level trips only when both windows agree, and
        # never before the fast window has seen min_events completions
        if len(self._fast) < self.policy.min_events:
            return SLO_OK
        if fast >= self.policy.page_burn and slow >= self.policy.page_burn:
            return SLO_PAGE
        if fast >= self.policy.warn_burn and slow >= self.policy.warn_burn:
            return SLO_WARN
        return SLO_OK

    @property
    def alert_level(self) -> int:
        with self._lock:
            return self._level

    def burn_rates(self) -> tuple:
        """(fast, slow) burn over the current rolling windows."""
        with self._lock:
            return self._burns_locked()

    def summary(self) -> dict:
        with self._lock:
            fast, slow = self._burns_locked()
            return {
                "objective": self.policy.objective,
                "completed": self.completed,
                "missed": self.missed,
                "burn_fast": fast,
                "burn_slow": slow,
                "alert": ALERT_NAMES[self._level],
                "alert_level": self._level,
                "alert_transitions": self.alert_transitions,
            }
