"""Flight recorder: a bounded ring of structured per-step serving records.

Every dispatched engine step appends one record capturing *everything the
control plane decided and observed* for that step:

* the resolved static lowering (``fused``/``decide``/``bucket_tier``) the
  executable actually ran with;
* the governor's latched plan (banks/planes/level), measured slack ratio
  and energy EWMA at dispatch time;
* the deadline tracker's admit/escalate/shed verdicts for the step's
  windows;
* the host-side :class:`~repro.core.types.WindowTelemetry` digest once the
  step retires (path mix, delta totals, rho quantiles, per-window
  banks/planes as traced);
* wall-clock step latency.

The ring is bounded (``capacity`` records; default 4096 ≈ a couple minutes
of 60 FPS serving) so a long-running host's memory stays flat — when it
wraps, the *oldest* records fall off and ``dropped`` counts them.
:meth:`FlightRecorder.dump_jsonl` spills the live window to JSONL;
:func:`load_jsonl` + :func:`replay` reconstruct the governor/auto-dispatch
decision timeline offline, which is the input the ROADMAP's
governor-autotuning item fits plan ladders from.

Schema is versioned (``FLIGHT_SCHEMA_VERSION``, stamped into every record
as ``"v"``); bump it on any key rename/removal. Catalog in
``docs/observability.md``.
"""
from __future__ import annotations

import dataclasses
import json
import threading
import warnings
from collections import deque
from typing import Iterable, List, Optional, Sequence

FLIGHT_SCHEMA_VERSION = 1


def _jsonable(x):
    """Coerce numpy/JAX scalars and containers to plain JSON types."""
    if isinstance(x, dict):
        return {str(k): _jsonable(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_jsonable(v) for v in x]
    if isinstance(x, (str, bool, int, float)) or x is None:
        return x
    item = getattr(x, "item", None)   # numpy / JAX zero-d scalars
    if callable(item):
        try:
            return _jsonable(item())
        except (TypeError, ValueError):
            pass
    tolist = getattr(x, "tolist", None)  # numpy / JAX arrays
    if callable(tolist):
        return _jsonable(tolist())
    return repr(x)


class FlightRecorder:
    """Bounded ring buffer of per-step flight records.

    Thread-safe: the async engine's dispatcher opens a record while the
    collector completes it, so both :meth:`record` and the read side take
    the recorder lock (cheap — one deque append per *step*, not per
    window; never on a per-proposal path).
    """

    def __init__(self, capacity: int = 4096, metrics=None):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self._ring: deque = deque(maxlen=capacity)
        self._lock = threading.Lock()
        self._seq = 0
        self.dropped = 0   # records that fell off the ring's old end
        # wraparound is a real observability gap — a spill after the ring
        # wrapped silently misses the oldest steps — so surface it: a
        # counter when a registry is wired, and a one-line warning on the
        # *first* drop either way (warnings dedupe repeats by default)
        self._c_dropped = None
        if metrics is not None:
            self._c_dropped = metrics.counter(
                "torr_flight_dropped_total",
                "Flight records that fell off the bounded ring's old end.")

    def record(self, **fields) -> dict:
        """Append one step record; returns the (mutable) dict so the
        caller can complete it later (e.g. collector fills the telemetry
        digest after the device step retires). ``v`` and ``step`` keys are
        stamped automatically."""
        rec = {"v": FLIGHT_SCHEMA_VERSION}
        rec.update(fields)
        with self._lock:
            rec["step"] = self._seq
            self._seq += 1
            wrapped = len(self._ring) == self.capacity
            if wrapped:
                self.dropped += 1
            first_drop = wrapped and self.dropped == 1
            self._ring.append(rec)
        if wrapped and self._c_dropped is not None:
            self._c_dropped.inc()
        if first_drop:
            warnings.warn(
                f"FlightRecorder ring wrapped at capacity={self.capacity}: "
                f"oldest step records are being dropped (a later dump_jsonl "
                f"spill will miss them); size the capacity to the run or "
                f"spill periodically", RuntimeWarning, stacklevel=2)
        return rec

    def __len__(self) -> int:
        with self._lock:
            return len(self._ring)

    def records(self) -> List[dict]:
        """Snapshot of the live window, oldest first (records still being
        completed by a collector may gain keys after this returns)."""
        with self._lock:
            return list(self._ring)

    def clear(self) -> None:
        with self._lock:
            self._ring.clear()

    def dump_jsonl(self, path: str) -> int:
        """Spill the live window to JSONL (one record per line, numpy/JAX
        scalars coerced to JSON types). Returns the record count."""
        recs = self.records()
        with open(path, "w") as f:
            for rec in recs:
                f.write(json.dumps(_jsonable(rec)) + "\n")
        return len(recs)


def load_jsonl(path: str) -> List[dict]:
    """Load a spilled flight log (skipping blank lines)."""
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


@dataclasses.dataclass(frozen=True)
class ReplayStep:
    """One step of the reconstructed control-plane timeline."""

    step: int
    banks: Optional[int]
    planes: Optional[int]
    level: Optional[int]
    fused: Optional[str]
    decide: Optional[str]
    bucket_tier: Optional[int]
    slack: Optional[float]
    energy_ewma_mj: Optional[float]

    @property
    def plan(self):
        """(banks, planes, level) — the governor's per-update log entry."""
        return (self.banks, self.planes, self.level)


def replay(records: Iterable[dict]) -> List[ReplayStep]:
    """Reconstruct the governor/auto-dispatch decision timeline.

    Input is :meth:`FlightRecorder.records` or :func:`load_jsonl` output;
    records without a schema version or from a different major version are
    skipped (a spilled log may interleave versions across a restart). The
    output is ordered by step and is the offline twin of the governor's
    own plan log — ``tests/test_obs.py`` asserts they bit-match on a
    governed run, which is the property that makes trace-driven ladder
    fitting trustworthy.
    """
    steps = []
    for rec in records:
        if rec.get("v") != FLIGHT_SCHEMA_VERSION:
            continue
        plan = rec.get("plan") or {}
        low = rec.get("lowering") or {}
        gov = rec.get("governor") or {}
        steps.append(ReplayStep(
            step=int(rec.get("step", len(steps))),
            banks=plan.get("banks"),
            planes=plan.get("planes"),
            level=gov.get("level"),
            fused=low.get("fused"),
            decide=low.get("decide"),
            bucket_tier=low.get("bucket_tier"),
            slack=gov.get("slack"),
            energy_ewma_mj=gov.get("energy_ewma_mj"),
        ))
    steps.sort(key=lambda s: s.step)
    return steps


def plan_timeline(records: Iterable[dict]) -> List[tuple]:
    """The (banks, planes, level) sequence — governor plan-log shape."""
    return [s.plan for s in replay(records)]
