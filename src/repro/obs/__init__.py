"""Serving observability: metrics registry, flight recorder, span timers.

Zero *new* dependencies: stdlib + numpy, plus the ``core.types`` name
vocabulary (``PATH_NAMES``/``FUSED_NAMES``/``DECIDE_NAMES``) the bridge
decodes telemetry with. Metric catalog, flight schema and endpoint usage
live in ``docs/observability.md``.
"""
from .bridge import StepObserver, telemetry_digest
from .export import MetricsServer, prometheus_text, write_json_snapshot
from .flight import (FLIGHT_SCHEMA_VERSION, FlightRecorder, load_jsonl,
                     plan_timeline, replay)
from .metrics import (LATENCY_BUCKETS_S, Counter, Gauge, Histogram,
                      MetricsRegistry, default_registry)
from .spans import NULL_SPAN, current_span, span, span_stack

__all__ = [
    "Counter", "FLIGHT_SCHEMA_VERSION", "FlightRecorder", "Gauge",
    "Histogram", "LATENCY_BUCKETS_S", "MetricsRegistry", "MetricsServer",
    "NULL_SPAN", "StepObserver", "current_span", "default_registry",
    "load_jsonl", "plan_timeline", "prometheus_text", "replay", "span",
    "span_stack", "telemetry_digest", "write_json_snapshot",
]
