"""Serving observability: metrics registry, flight recorder, span timers,
per-window causal tracing, Chrome-trace export, and the RT-SLO burn-rate
engine.

Zero *new* dependencies: stdlib + numpy, plus the ``core.types`` name
vocabulary (``PATH_NAMES``/``FUSED_NAMES``/``DECIDE_NAMES``) the bridge
decodes telemetry with. Metric catalog, flight schema, trace-context
model and SLO semantics live in ``docs/observability.md``.
"""
from .bridge import StepObserver, telemetry_digest
from .export import (MetricsServer, health_response, prometheus_text,
                     write_json_snapshot)
from .flight import (FLIGHT_SCHEMA_VERSION, FlightRecorder, load_jsonl,
                     plan_timeline, replay)
from .metrics import (LATENCY_BUCKETS_S, Counter, Gauge, Histogram,
                      MetricsRegistry, default_registry, quantile,
                      snapshot_quantile)
from .slo import (SLO_OK, SLO_PAGE, SLO_WARN, SLOMonitor, SLOPolicy,
                  burn_rate)
from .spans import NULL_SPAN, current_span, span, span_stack
from .trace import (TRACE_SCHEMA_VERSION, TraceContext, Tracer, now_us,
                    trace_scope)
from .trace_export import chrome_trace, write_chrome_trace

__all__ = [
    "Counter", "FLIGHT_SCHEMA_VERSION", "FlightRecorder", "Gauge",
    "Histogram", "LATENCY_BUCKETS_S", "MetricsRegistry", "MetricsServer",
    "NULL_SPAN", "SLOMonitor", "SLOPolicy", "SLO_OK", "SLO_PAGE",
    "SLO_WARN", "StepObserver", "TRACE_SCHEMA_VERSION", "TraceContext",
    "Tracer", "burn_rate", "chrome_trace", "current_span",
    "default_registry", "health_response", "load_jsonl", "now_us",
    "plan_timeline",
    "prometheus_text", "quantile", "replay", "snapshot_quantile", "span",
    "span_stack", "telemetry_digest", "trace_scope", "write_chrome_trace",
    "write_json_snapshot",
]
