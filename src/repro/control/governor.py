"""Slack-driven QoS governor with an energy budget (paper Sec. 4.6 close-loop).

The paper's controller trades D', thresholds and precision at deployment
time to hit RT-30/RT-60 at millijoule energy. Here that is a *closed loop*
between serving telemetry and the compute path:

    deadline tracker ──projected slack──▶ governor ──KnobPlan──▶ engine step
         ▲                                   ▲                      │
         └── measured step latency (EMA) ────┴── EWMA energy ◀──────┘
                                                 (cycle model on telemetry)

Design:

  * **Plan ladder.** :func:`build_ladder` orders knob plans from the full
    plan (level 0) to the cheapest (drop one bit-slice plane at a time,
    then halve banks; the deepest levels also relax tau_q/tau_byp so the
    cheap delta/bypass paths trigger earlier). Every level's worst-case
    window cycles come from the *shared* Sec. 4.3 cost helper
    (``core.policy.window_cycles_deff``) — the same math Alg. 1's bank
    gating and the cycle-accurate simulator use, so the three cannot drift.
  * **Pure selection.** :func:`plan_level` is a pure function of
    (projected slack, queue depth, measured step EMA, EWMA energy,
    previous level) — unit-testable without clocks or threads, mirroring
    ``serving.deadline.decide``.
  * **Hysteresis.** Degrading (deeper level) is immediate — a missed
    deadline is worse than a narrow window. Recovering (wider D'/more
    planes) requires ``recover_hold`` consecutive comfortable windows and
    then steps up one level at a time, so the host-latched executables
    aren't thrashed by slack noise.
  * **Energy governor.** An optional mJ/window budget: the EWMA of modeled
    window energy (``perf.cycle_model`` applied to the telemetry each
    window actually produced) caps the ladder level even when slack is
    plentiful — static power is subtracted before scaling, since bank and
    plane gating only shed *dynamic* aligner power.

Environment overrides (read by :func:`policy_from_env`; documented in the
``launch.serve`` module docstring):

    var                  | default | meaning
    -------------------- | ------- | ------------------------------------
    ``TORR_GOV_MARGIN``  |    0.25 | fraction of the RT budget held back
    ``TORR_GOV_HOLD``    |       4 | comfortable windows before recovery
    ``TORR_GOV_ENERGY_MJ``|    off | mJ/window energy budget (0 = off)
    ``TORR_GOV_ALPHA``   |     0.2 | EWMA weight of newest window energy
"""
from __future__ import annotations

import dataclasses
import os

import numpy as np

from ..configs.torr_edge import rt_budget_s
from ..core import policy as alg1
from ..core.types import TorrConfig
from ..perf.cycle_model import P_STATIC
from .plan import KnobPlan, full_plan


@dataclasses.dataclass(frozen=True)
class GovernorPolicy:
    """Static thresholds for the pure :func:`plan_level` function."""

    budget_s: float               # RT deadline (same as the DeadlinePolicy's)
    slack_margin: float = 0.25    # fraction of budget held back as safety
    recover_hold: int = 4         # comfortable windows before stepping up
    energy_budget_mj: float | None = None  # mJ/window target (None = off)
    energy_alpha: float = 0.2     # EWMA weight of the newest window energy
    meas_alpha: float = 0.25      # how fast the measurement EMA tracks plan
                                  # switches; keep equal to the deadline
                                  # tracker's step_ema_alpha


def policy_for(rt: str = "RT-60", **overrides) -> GovernorPolicy:
    base = GovernorPolicy(budget_s=rt_budget_s(rt))
    return dataclasses.replace(base, **overrides) if overrides else base


def policy_from_env(rt: str = "RT-60") -> GovernorPolicy:
    """Governor policy with ``TORR_GOV_*`` environment overrides applied."""
    kw = {}
    if os.environ.get("TORR_GOV_MARGIN"):
        kw["slack_margin"] = float(os.environ["TORR_GOV_MARGIN"])
    if os.environ.get("TORR_GOV_HOLD"):
        kw["recover_hold"] = int(os.environ["TORR_GOV_HOLD"])
    if os.environ.get("TORR_GOV_ENERGY_MJ"):
        mj = float(os.environ["TORR_GOV_ENERGY_MJ"])
        kw["energy_budget_mj"] = mj if mj > 0 else None
    if os.environ.get("TORR_GOV_ALPHA"):
        kw["energy_alpha"] = float(os.environ["TORR_GOV_ALPHA"])
    return policy_for(rt, **kw)


def build_ladder(cfg: TorrConfig) -> tuple[KnobPlan, ...]:
    """Knob plans from full (level 0) to cheapest.

    Precision degrades before dimension (dropping a low-order bit-slice
    plane is the gentlest knob — TaskCLIP/ImageHD-style graceful decay);
    once a single plane remains, banks halve. The deepest (bank-reduced)
    levels additionally relax the similarity thresholds so Alg. 1 admits
    more delta/bypass traffic while the loop is under pressure.
    """
    P, B = cfg.bit_planes, cfg.B
    ladder = [full_plan(cfg)]
    banks, planes = B, P
    while banks > 1 or planes > 1:
        if planes > 1:
            planes -= 1
            off_q, off_b = 0.0, 0.0
        else:
            banks = max(1, banks // 2)
            off_q, off_b = -0.05, -0.03
        ladder.append(KnobPlan(banks=banks, planes=planes, plane_total=P,
                               tau_q_off=off_q, tau_byp_off=off_b))
    return tuple(ladder)


def ladder_rel_cost(ladder: tuple[KnobPlan, ...], cfg: TorrConfig) -> np.ndarray:
    """Worst-case window cycles of each level relative to the full plan.

    Priced by the shared Sec. 4.3 helper at a nominal heavy window (all
    proposals full-path, N = N_hi) — the same worst case Alg. 1's bank
    gating solves against.
    """
    n_nom = max(cfg.N_hi, 1)
    ref = alg1.window_cycles_deff(n_nom, 0, cfg.D, cfg)
    return np.asarray([
        alg1.window_cycles_deff(n_nom, 0, p.d_eff(cfg), cfg) / ref
        for p in ladder
    ], np.float64)


def plan_level(
    slack_s: float,
    backlog: int,
    step_s: float,
    level: int,
    recover: int,
    rel_cost: np.ndarray,
    pol: GovernorPolicy,
    energy_ewma_mj: float = 0.0,
    rel_meas: float | None = None,
) -> tuple[int, int]:
    """Pure level selection: (new_level, new_recover_count).

    ``slack_s`` is the head window's remaining time to deadline, ``step_s``
    the engine's measured per-step latency EMA (0 = no measurement yet,
    optimistic), ``backlog`` the windows queued behind the head (they must
    drain inside the same slack). ``rel_meas`` is the relative cost the
    measurement EMA reflects — an EMA blends steps taken at *past* levels,
    so right after a plan switch it lags ``rel_cost[level]``; the
    :class:`Governor` tracks it with the same alpha the deadline tracker
    blends latencies with (default: the current level's cost). The governor
    picks the widest (lowest-index) level whose predicted drain time fits
    the slack after the safety margin, then applies the energy cap and the
    recovery hysteresis.
    """
    n_levels = len(rel_cost)
    rel_meas = rel_cost[level] if rel_meas is None else rel_meas
    usable = slack_s - pol.slack_margin * pol.budget_s
    if step_s <= 0.0:
        desired = 0
    else:
        # re-normalize the measurement to the full plan, then predict each
        # level's drain time for head + backlog
        step_full = step_s / rel_meas
        fits = step_full * rel_cost * (1 + backlog) <= usable
        desired = int(np.argmax(fits)) if fits.any() else n_levels - 1

    if pol.energy_budget_mj is not None and energy_ewma_mj > 0.0:
        # bank/plane gating sheds dynamic power only; static is a floor
        static_mj = P_STATIC * pol.budget_s * 1e3
        dyn = max(energy_ewma_mj - static_mj, 0.0)
        pred_mj = static_mj + dyn * rel_cost / rel_meas
        e_fits = pred_mj <= pol.energy_budget_mj
        e_level = int(np.argmax(e_fits)) if e_fits.any() else n_levels - 1
        desired = max(desired, e_level)

    if desired > level:            # degrade immediately
        return desired, 0
    if desired < level:            # recover gradually, after a hold
        recover += 1
        if recover >= pol.recover_hold:
            return level - 1, 0
        return level, recover
    return level, 0


class Governor:
    """Mutable loop state around the pure :func:`plan_level` table."""

    def __init__(self, cfg: TorrConfig, pol: GovernorPolicy,
                 ladder: tuple[KnobPlan, ...] | None = None,
                 metrics=None, slo=None):
        self.cfg = cfg
        self.pol = pol
        self.ladder = tuple(ladder) if ladder is not None else build_ladder(cfg)
        for p in self.ladder:
            p.validate(cfg)
        self.rel_cost = ladder_rel_cost(self.ladder, cfg)
        self.level = 0
        self._recover = 0
        # authoritative control-plane audit trail: one (banks, planes,
        # level) entry per update() call, i.e. per dispatched governed
        # step. The flight recorder's replayed plan timeline must bit-match
        # this list (tests/test_obs.py) — that equivalence is what makes
        # trace-driven ladder fitting (ROADMAP: governor autotuning)
        # trustworthy. One small tuple per step; clear() between runs if
        # a long-lived host needs the memory back.
        self.plan_log: list[tuple[int, int, int]] = []
        self._g_level = None
        if metrics is not None:
            self._g_level = metrics.gauge(
                "torr_plan_level",
                "Current ladder position (0 = full plan).")
            self._g_energy = metrics.gauge(
                "torr_energy_ewma_mj",
                "EWMA of modeled per-window energy (mJ).")
            self._c_switch = metrics.counter(
                "torr_plan_switches_total",
                "Knob-plan latch changes (hysteresis-damped).")
        # relative cost of the steps the latency EMA currently reflects:
        # blended at the same rate the deadline tracker blends latencies,
        # so step_s / rel_meas stays an unbiased full-plan estimate across
        # plan switches
        self._rel_meas = float(self.rel_cost[0])
        self.energy_ewma_mj = 0.0
        self.switches = 0
        self.windows_by_level = [0] * len(self.ladder)
        # optional RT-SLO feedback (repro.obs.slo.SLOMonitor): per-update
        # slack is a *projection*, so slack noise can hold the plan wide
        # while real completions burn the miss budget. The burn-rate hook
        # closes that gap: at WARN the recovery hold is frozen (no widening
        # while the budget burns), at PAGE one extra degrade level is
        # forced. slo=None (the default) leaves plan_level's output
        # untouched — the plan_log bit-match tests pin that.
        self._slo = slo

    @property
    def plan(self) -> KnobPlan:
        return self.ladder[self.level]

    def update(self, slack_s: float, step_s: float, backlog: int = 0,
               n_windows: int = 1) -> KnobPlan:
        """One control step: pick the plan for the next dispatched batch."""
        level, self._recover = plan_level(
            slack_s, backlog, step_s, self.level, self._recover,
            self.rel_cost, self.pol, self.energy_ewma_mj,
            rel_meas=self._rel_meas)
        if self._slo is not None:
            alert = self._slo.alert_level
            if alert >= 1 and level < self.level:
                # WARN: the miss budget is burning — hold position instead
                # of widening on a projection
                level, self._recover = self.level, 0
            if alert >= 2:
                # PAGE: force one extra degrade step (bounded by ladder)
                level = min(max(level, self.level) + 1,
                            len(self.ladder) - 1)
                self._recover = 0
        if level != self.level:
            self.switches += 1
            self.level = level
            if self._g_level is not None:
                self._c_switch.inc()
        a = self.pol.meas_alpha
        self._rel_meas = (1 - a) * self._rel_meas + a * float(self.rel_cost[level])
        self.windows_by_level[level] += n_windows
        plan = self.ladder[level]
        self.plan_log.append((int(plan.banks), int(plan.planes), level))
        if self._g_level is not None:
            self._g_level.set(level)
        return plan

    def observe_energy(self, mj: float) -> None:
        """Fold one window's modeled energy into the EWMA."""
        a = self.pol.energy_alpha
        self.energy_ewma_mj = mj if self.energy_ewma_mj <= 0.0 else \
            (1.0 - a) * self.energy_ewma_mj + a * mj
        if self._g_level is not None:
            self._g_energy.set(self.energy_ewma_mj)

    def summary(self) -> dict:
        p = self.plan
        return {
            "level": self.level,
            "n_levels": len(self.ladder),
            "plan_banks": p.banks,
            "plan_planes": p.planes,
            "plan_switches": self.switches,
            "windows_by_level": list(self.windows_by_level),
            "energy_ewma_mj": self.energy_ewma_mj,
        }
