"""Closed-loop QoS control plane: slack-driven knob plans + energy governor.

Sits between serving telemetry (``repro.serving.deadline``) and the compute
path (``repro.core.pipeline`` / ``repro.kernels.ops``): the
:class:`~repro.control.governor.Governor` turns projected deadline slack,
queue depth and an EWMA of modeled window energy into a
:class:`~repro.control.plan.KnobPlan` (D' cap, bit-slice precision, tau
offsets) that the engines latch host-side per dispatched step.
"""
from .governor import (Governor, GovernorPolicy, build_ladder,
                       ladder_rel_cost, plan_level, policy_for,
                       policy_from_env)
from .plan import KnobPlan, full_plan

__all__ = [
    "Governor", "GovernorPolicy", "KnobPlan", "build_ladder", "full_plan",
    "ladder_rel_cost", "plan_level", "policy_for", "policy_from_env",
]
