"""Per-window knob plans: the control plane's unit of actuation (Sec. 4.6).

A :class:`KnobPlan` bundles every deployment-time knob the paper's QoS
controller trades at run time — effective dimension D' (a *cap* on Alg. 1's
bank choice), bit-slice precision for the packed XNOR-popcount path, and
offsets on the tau_q / tau_byp similarity thresholds. Plans are frozen and
hashable, and the pipeline takes them as a *static* jit argument: like the
ASIC's window-latched register file (and the static-banks contract in
``kernels.ops``), a plan is latched host-side per window and dispatches one
of a small set of specialized executables. The governor's hysteresis exists
precisely so this latch doesn't thrash the executable cache.

Semantics (all exact, nothing approximate):

  * ``banks`` caps Alg. 1's ``select_banks`` choice: effective banks =
    ``min(alg1_banks, plan.banks)``. A full-plan cap (B) is therefore a
    bit-exact no-op.
  * ``planes`` keeps the ``planes`` highest-order bit-slice planes of every
    enabled bank (of ``cfg.bit_planes`` total); the scan's enabled dims are
    ``item_memory.plan_dim_mask(cfg, banks, planes)`` and scores normalize
    by the reduced D'. ``planes == cfg.bit_planes`` is a bit-exact no-op.
  * ``tau_q_off`` / ``tau_byp_off`` shift the Alg. 1 thresholds (negative
    offsets make the cheap delta/bypass paths easier to enter). Zero
    offsets leave the config object untouched.
  * ``bucket_cap`` latches the compact dispatch's bucket tier
    (``fused="compact"``; see ``core.pipeline``). Pure scheduling — every
    tier is bit-exact, so it never participates in :attr:`is_full`.

Exactness under switching: the query cache tags each accumulator with
``types.plan_tag(banks, planes)``; after any plan switch the tag mismatches
and the stale delta path is rejected (Eq. 6's D' requirement), exactly as
the pre-existing banks-only tag did for bank changes.
"""
from __future__ import annotations

import dataclasses

from ..core.types import TorrConfig


@dataclasses.dataclass(frozen=True)
class KnobPlan:
    """Static per-window knob setting (hashable; safe as a jit static arg)."""

    banks: int               # cap on Alg. 1's bank choice (1..B)
    planes: int              # bit-slice planes kept (1..plane_total)
    plane_total: int         # cfg.bit_planes at build time (denominator)
    tau_q_off: float = 0.0   # shift on the delta-vs-full threshold
    tau_byp_off: float = 0.0 # shift on the bypass threshold
    # compact-dispatch bucket capacity (fused="compact"): the latched tier
    # of core.policy.bucket_ladder the full-path proposals compact to. A
    # *scheduling* knob, never a numeric one — any tier is bit-exact
    # (overflow falls back to the hoisted scan); None defers to the step's
    # bucket_cap argument / full capacity.
    bucket_cap: int | None = None

    def __post_init__(self):
        if not 1 <= self.planes <= self.plane_total:
            raise ValueError(
                f"planes={self.planes} outside 1..{self.plane_total}")
        if self.banks < 1:
            raise ValueError(f"banks={self.banks} must be >= 1")
        if self.bucket_cap is not None and self.bucket_cap < 1:
            raise ValueError(
                f"bucket_cap={self.bucket_cap} must be >= 1 (or None)")

    @property
    def is_full(self) -> bool:
        """True iff this plan is a bit-exact no-op on the uncontrolled step."""
        return (self.planes == self.plane_total
                and self.tau_q_off == 0.0 and self.tau_byp_off == 0.0)
        # note: a full *cap* (banks == B) is implied by min(); the cap only
        # matters when it actually binds, which is checked at the call site.

    def validate(self, cfg: TorrConfig) -> None:
        if self.plane_total != cfg.bit_planes:
            raise ValueError(
                f"plan built for {self.plane_total} bit planes, config has "
                f"{cfg.bit_planes}")
        if self.banks > cfg.B:
            raise ValueError(f"banks cap {self.banks} exceeds B={cfg.B}")

    def d_eff(self, cfg: TorrConfig) -> int:
        """D' when the bank cap binds (the plan's worst-case width)."""
        return cfg.d_eff_planned(min(self.banks, cfg.B), self.planes)

    def thresholds(self, cfg: TorrConfig) -> TorrConfig:
        """Config with this plan's tau offsets applied (identity at 0)."""
        if self.tau_q_off == 0.0 and self.tau_byp_off == 0.0:
            return cfg
        return dataclasses.replace(
            cfg,
            tau_q=cfg.tau_q + self.tau_q_off,
            tau_byp=cfg.tau_byp + self.tau_byp_off,
        )


def full_plan(cfg: TorrConfig) -> KnobPlan:
    """The identity plan: full banks, all planes, untouched thresholds."""
    return KnobPlan(banks=cfg.B, planes=cfg.bit_planes,
                    plane_total=cfg.bit_planes)
