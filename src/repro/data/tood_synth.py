"""Synthetic task-oriented object detection (TOOD) benchmark.

The paper evaluates on a private five-prompt DVS+RGB split that is not
available offline; this module generates a *parametric* TOOD world with the
same structure so the accuracy machinery (AP@0.5 with greedy IoU matching
and all-point PR integration) and the paper's *relative* claims can be
reproduced: bounded accuracy margin of HDC vs dense alignment, graceful
degradation under aggressive reuse, and reuse-friendly scenes benefiting
most (documented as a surrogate in EXPERIMENTS.md).

World model:
  * M object classes with prototype features in R^d (the CLIP-proxy space);
  * T tasks; a task's relevant classes come from a relation graph
    (task -used-for-> class), mirroring the paper's g_P = t (*) r_l chains;
  * scenes hold drifting objects (temporal coherence!) plus background
    clutter; proposals = jittered GT boxes + false positives;
  * proposal features = class prototype + difficulty-scaled noise, drifting
    with scene motion so consecutive-window queries are genuinely similar.
"""
from __future__ import annotations

import dataclasses

import numpy as np

TASKS = ["pour wine", "sports", "cooking", "have breakfast", "take a rest"]

# scene dynamics per task (coherent with perf.cycle_model.TASK_PROFILES)
_TASK_DYNAMICS = {
    # size < 1 makes objects smaller (harder IoU matching) — the paper's
    # Table 5 shows breakfast/rest are intrinsically harder for *every*
    # method (iTaskCLIP drops from ~63 to ~44 AP there too).
    "pour wine": dict(motion=0.05, churn=0.10, n_objects=9, size=1.20),
    "sports": dict(motion=0.09, churn=0.16, n_objects=11, size=1.15),
    "cooking": dict(motion=0.04, churn=0.08, n_objects=8, size=0.95),
    "have breakfast": dict(motion=0.02, churn=0.04, n_objects=7, size=0.62),
    "take a rest": dict(motion=0.02, churn=0.05, n_objects=7, size=0.62),
}


@dataclasses.dataclass
class World:
    prototypes: np.ndarray      # [M, d] class features (unit norm)
    relevance: np.ndarray       # [T, M] in [0, 1]: task-class affinity
    task_paths: np.ndarray      # [T, max_hops] relation ids (-1 pad)
    n_relations: int


def make_world(seed: int, M: int = 64, d: int = 512, n_tasks: int = 5,
               n_relations: int = 16, max_hops: int = 3) -> World:
    rng = np.random.default_rng(seed)
    protos = rng.standard_normal((M, d))
    protos /= np.linalg.norm(protos, axis=1, keepdims=True)
    # relation graph: each relation maps tasks to a class subset
    rel_class = rng.random((n_relations, M)) < 0.15
    relevance = np.zeros((n_tasks, M))
    task_paths = np.full((n_tasks, max_hops), -1, np.int32)
    for t in range(n_tasks):
        hops = rng.integers(1, max_hops + 1)
        rels = rng.choice(n_relations, size=hops, replace=False)
        task_paths[t, :hops] = rels
        mask = np.ones(M, bool)
        for r in rels:
            mask &= rel_class[r]
        if mask.sum() < 3:  # ensure each task has targets
            mask |= rng.random(M) < 0.08
        relevance[t] = np.where(mask, 1.0, 0.1)
    return World(protos, relevance, task_paths, n_relations)


@dataclasses.dataclass
class Frame:
    feats: np.ndarray        # [N, d] proposal features
    boxes: np.ndarray        # [N, 4] xyxy in [0,1]
    classes: np.ndarray      # [N] true class (-1 for background clutter)
    valid: np.ndarray        # [N] bool
    gt_boxes: np.ndarray     # [G, 4] task-relevant GT boxes
    gt_classes: np.ndarray   # [G]


def _rand_boxes(rng, n, size=1.0):
    cx, cy = rng.random((2, n))
    w, h = (0.08 + 0.12 * rng.random((2, n))) * size
    return np.clip(np.stack([cx - w / 2, cy - h / 2, cx + w / 2, cy + h / 2],
                            axis=1), 0, 1)


def simulate_sequence(world: World, task_id: int, n_frames: int,
                      seed: int, difficulty: float = 0.55,
                      n_max: int = 16) -> list[Frame]:
    """Temporal sequence with drifting objects and churn."""
    task = TASKS[task_id]
    dyn = _TASK_DYNAMICS[task]
    rng = np.random.default_rng((seed, task_id))
    M, d = world.prototypes.shape
    n_obj = dyn["n_objects"]

    relevant_classes = np.flatnonzero(world.relevance[task_id] > 0.5)

    def draw_class():
        # evaluation scenes contain target objects ~40% of the time
        if len(relevant_classes) and rng.random() < 0.4:
            return int(rng.choice(relevant_classes))
        return int(rng.integers(0, M))

    classes = np.array([draw_class() for _ in range(n_obj)])
    boxes = _rand_boxes(rng, n_obj, dyn["size"])
    base_noise = rng.standard_normal((n_obj, d)) * difficulty

    frames = []
    for _ in range(n_frames):
        # churn: some objects leave/arrive
        for i in range(n_obj):
            if rng.random() < dyn["churn"]:
                classes[i] = draw_class()
                boxes[i] = _rand_boxes(rng, 1, dyn["size"])[0]
                base_noise[i] = rng.standard_normal(d) * difficulty
        # motion: boxes drift, features drift proportionally
        drift = rng.standard_normal((n_obj, 4)) * dyn["motion"] * 0.06
        boxes = np.clip(boxes + drift, 0, 1)
        base_noise += rng.standard_normal((n_obj, d)) * dyn["motion"] * difficulty
        base_noise *= difficulty / (np.linalg.norm(base_noise, axis=1, keepdims=True)
                                    / np.sqrt(d) + 1e-9) * 1.0

        feats_obj = world.prototypes[classes] + base_noise / np.sqrt(d)
        # proposals: true objects (jittered) + hard-negative clutter
        # (spurious detections that *look like* real classes — the FP mode a
        # real detector produces; random-feature clutter is trivially
        # rejected by any aligner and would inflate AP to ~100)
        n_clutter = rng.integers(2, 5)
        clutter_cls = rng.integers(0, M, n_clutter)
        clutter_feats = (world.prototypes[clutter_cls]
                         + rng.standard_normal((n_clutter, d))
                         * 1.3 * difficulty / np.sqrt(d))
        clutter_boxes = _rand_boxes(rng, n_clutter, dyn["size"])
        # localization noise: some proposals straddle the IoU=0.5 boundary
        jitter = rng.standard_normal((n_obj, 4)) * 0.01
        sloppy = rng.random(n_obj) < 0.25
        jitter[sloppy] = rng.standard_normal((int(sloppy.sum()), 4)) * 0.035
        feats = np.concatenate([feats_obj, clutter_feats])[:n_max]
        pboxes = np.concatenate(
            [np.clip(boxes + jitter, 0, 1), clutter_boxes])[:n_max]
        pcls = np.concatenate([classes, -np.ones(n_clutter, np.int64)])[:n_max]
        n = feats.shape[0]
        pad = n_max - n
        if pad:
            feats = np.concatenate([feats, np.zeros((pad, d))])
            pboxes = np.concatenate([pboxes, np.zeros((pad, 4))])
            pcls = np.concatenate([pcls, -np.ones(pad, np.int64)])
        valid = np.arange(n_max) < n

        relevant = world.relevance[task_id] > 0.5
        keep = relevant[np.clip(classes, 0, M - 1)]
        frames.append(Frame(
            feats.astype(np.float32), pboxes.astype(np.float32),
            pcls.astype(np.int32), valid,
            boxes[keep].astype(np.float32), classes[keep].astype(np.int32)))
    return frames


# ---------------------------------------------------------------------------
# AP@0.5 (greedy IoU matching + all-point interpolated PR integration)
# ---------------------------------------------------------------------------

def iou_matrix(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """IoU between [N,4] and [G,4] xyxy boxes."""
    if len(a) == 0 or len(b) == 0:
        return np.zeros((len(a), len(b)))
    lt = np.maximum(a[:, None, :2], b[None, :, :2])
    rb = np.minimum(a[:, None, 2:], b[None, :, 2:])
    wh = np.clip(rb - lt, 0, None)
    inter = wh[..., 0] * wh[..., 1]
    area_a = (a[:, 2] - a[:, 0]) * (a[:, 3] - a[:, 1])
    area_b = (b[:, 2] - b[:, 0]) * (b[:, 3] - b[:, 1])
    union = area_a[:, None] + area_b[None, :] - inter
    return inter / np.maximum(union, 1e-9)


def average_precision(scores, boxes, gt_boxes_per_frame, iou_thr=0.5):
    """AP@iou over a sequence. scores/boxes: per-frame [N]; gts: [G,4]."""
    records = []   # (score, is_tp)
    n_gt = 0
    for s, b, g in zip(scores, boxes, gt_boxes_per_frame):
        n_gt += len(g)
        order = np.argsort(-s)
        matched = np.zeros(len(g), bool)
        ious = iou_matrix(b, g)
        for i in order:
            if s[i] <= -1e8:
                continue
            if len(g) == 0:
                records.append((s[i], False))
                continue
            j = int(np.argmax(np.where(matched, -1.0, ious[i])))
            if ious[i, j] >= iou_thr and not matched[j]:
                matched[j] = True
                records.append((s[i], True))
            else:
                records.append((s[i], False))
    if n_gt == 0 or not records:
        return 0.0
    records.sort(key=lambda r: -r[0])
    tp = np.cumsum([r[1] for r in records])
    fp = np.cumsum([not r[1] for r in records])
    recall = tp / n_gt
    precision = tp / np.maximum(tp + fp, 1)
    # all-point interpolation
    ap = 0.0
    prev_r = 0.0
    for r, p in zip(recall, np.maximum.accumulate(precision[::-1])[::-1]):
        ap += (r - prev_r) * p
        prev_r = r
    return float(ap)
