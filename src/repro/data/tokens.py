"""Deterministic synthetic LM token pipeline with skip-ahead.

Every batch is a pure function of (seed, step) so a restarted trainer can
resume mid-epoch without replaying — the skip-ahead contract production
loaders implement (tf.data checkpointing / grain index semantics).

The synthetic distribution is a Zipf-ish unigram mixture with induced
bigram structure so cross-entropy has meaningful, monotonically learnable
signal (unlike uniform noise).
"""
from __future__ import annotations

import numpy as np

from ..models.config import ModelConfig


class TokenStream:
    def __init__(self, cfg: ModelConfig, batch: int, seq_len: int,
                 seed: int = 0):
        self.cfg = cfg
        self.batch = batch
        self.seq_len = seq_len
        self.seed = seed
        V = cfg.vocab
        rng = np.random.default_rng(seed)
        probs = 1.0 / np.arange(1, V + 1) ** 1.1
        self.unigram = probs / probs.sum()
        # deterministic 'successor' map inducing bigram structure
        self.successor = rng.permutation(V)

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        B, S = self.batch, self.seq_len
        V = self.cfg.vocab
        first = rng.choice(V, size=(B, 1), p=self.unigram)
        noise = rng.choice(V, size=(B, S), p=self.unigram)
        copy_mask = rng.random((B, S)) < 0.5
        toks = np.empty((B, S), np.int32)
        toks[:, 0] = first[:, 0]
        for t in range(1, S):
            toks[:, t] = np.where(copy_mask[:, t],
                                  self.successor[toks[:, t - 1]],
                                  noise[:, t])
        labels = np.roll(toks, -1, axis=1)
        labels[:, -1] = toks[:, 0]
        batch = {"tokens": toks, "labels": labels}
        if self.cfg.family == "audio":
            nc = self.cfg.n_codebooks
            toks_a = rng.integers(0, V, size=(B, S, nc), dtype=np.int32)
            batch = {"tokens": toks_a,
                     "labels": np.roll(toks_a, -1, axis=1)}
        if self.cfg.family == "vlm":
            batch["vision"] = rng.standard_normal(
                (B, self.cfg.n_vision_tokens, self.cfg.vision_dim)
            ).astype(np.float32)
        if self.cfg.family == "moe" and self.cfg.mtp_depth:
            batch["tokens_next"] = labels
            batch["labels_mtp"] = np.roll(toks, -2, axis=1)
        return batch

    def stream(self, start_step: int = 0):
        step = start_step
        while True:
            yield self.batch_at(step)
            step += 1
