"""End-to-end TorR serving driver (the paper's deployment scenario).

Synthesizes a DVS event stream for a task prompt, aggregates windows
(Eq. 1), encodes proposals with the spiking encoder, runs the cache-gated
associative pipeline, evaluates AP@0.5 online, and reports the
cycle-model latency/energy the trace would cost on the 28 nm accelerator
at RT-60 — i.e. the full Fig. 3 loop, input to output.

Run:  PYTHONPATH=src python examples/serve_events.py [--frames 40]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import TorrConfig
from repro.data import tood_synth as ts
from repro.perf.cycle_model import window_cost
from repro.serving.tood_pipelines import build_system, run_torr

ap = argparse.ArgumentParser()
ap.add_argument("--frames", type=int, default=40)
ap.add_argument("--task", type=int, default=3)  # have breakfast
args = ap.parse_args()

world = ts.make_world(0, M=64, d=512, n_tasks=5)
cfg = TorrConfig(D=8192, B=8, M=64, K=24, N_max=16, delta_budget=2048,
                 feat_dim=512)
system = build_system(world, cfg)

frames = ts.simulate_sequence(world, args.task, args.frames, seed=1,
                              difficulty=1.2, n_max=cfg.N_max)
scores, telems = run_torr(system, frames, args.task)

ap50 = ts.average_precision(scores, [f.boxes for f in frames],
                            [f.gt_boxes for f in frames])

lat, energy, power = [], [], []
budget = 1.0 / 60.0
for tel in telems:
    wc = window_cost(tel.path, tel.delta_count, int(tel.banks),
                     tel.reasoner_active, int(tel.n_valid), cfg, budget)
    lat.append(wc.total_cycles / cfg.clock_hz * 1e3)
    energy.append(wc.energy_j * 1e3)
    power.append(wc.power_w)

paths = np.concatenate([t.path[: int(t.n_valid)] for t in telems])
print(f"task: {ts.TASKS[args.task]!r}  frames: {args.frames}")
print(f"AP@0.5: {100*ap50:.1f}")
print(f"path mix: bypass={np.mean(paths==0):.2f} delta={np.mean(paths==1):.2f} "
      f"full={np.mean(paths==2):.2f}")
print(f"accelerator (RT-60): median {np.median(lat):.2f} ms/window, "
      f"p95 {np.percentile(lat,95):.2f} ms, {np.mean(power):.2f} W, "
      f"{np.mean(energy):.1f} mJ/frame")
assert np.percentile(lat, 95) < budget * 1e3, "missed the RT-60 deadline"
print("RT-60 deadline met ✓")
