"""Quickstart: TorR's cache-gated HDC pipeline in ~60 lines.

Builds an item memory, streams temporally-coherent queries through the
similarity-gated window step, and shows the controller switching between
full / delta / bypass as scene dynamics change — the paper's core loop.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hdc, pipeline
from repro.core.item_memory import random_item_memory
from repro.core.types import PATH_NAMES, TorrConfig

cfg = TorrConfig(D=4096, B=8, M=128, K=8, N_max=8, delta_budget=1024,
                 feat_dim=256)
key = jax.random.PRNGKey(0)
im = random_item_memory(key, cfg)

# precomputed reasoner weights for one task (paper: w_j = cos(g_P, h_j))
g_P = hdc.random_hv(jax.random.PRNGKey(1), (cfg.D,))
task_w = jnp.einsum("d,md->m", g_P.astype(jnp.int32),
                    im.bipolar.astype(jnp.int32)).astype(jnp.float32) / cfg.D
task_w = 1.0 + task_w

state = pipeline.init_state(cfg, task_w)
step = jax.jit(pipeline.torr_window_step, static_argnames="cfg")

# a "scene": 4 objects whose queries drift slowly, then a scene cut
rng = np.random.default_rng(0)
z = rng.standard_normal((4, cfg.feat_dim))
R = np.asarray(jax.random.normal(jax.random.PRNGKey(2), (cfg.D, cfg.feat_dim))
               / np.sqrt(cfg.feat_dim))
boxes = jnp.zeros((cfg.N_max, 4))
valid = jnp.array([True] * 4 + [False] * 4)

print(f"{'win':>4} {'paths':24s} {'|Delta|':18s} {'banks':>5} {'rho':>24}")
for w in range(12):
    if w == 8:
        z = rng.standard_normal((4, cfg.feat_dim))   # scene cut!
    else:
        z = z + 0.02 * rng.standard_normal(z.shape)   # gentle drift
    q = hdc.sign_project(jnp.asarray(z), jnp.asarray(R))
    q = jnp.concatenate([q, jnp.zeros((4, cfg.D), jnp.int8)])
    qp = hdc.pack_bits(q)
    queue = jnp.int32(6 if 4 <= w < 6 else 0)         # load spike at w=4,5
    state, out, tel = step(state, im, qp, valid, boxes, queue, cfg)
    paths = ",".join(PATH_NAMES[int(p)] for p in tel.path[:4])
    deltas = ",".join(str(int(d)) for d in tel.delta_count[:4])
    rhos = ",".join(f"{float(r):+.2f}" for r in tel.rho[:4])
    note = "  <- scene cut" if w == 8 else ("  <- high load" if 4 <= w < 6 else "")
    print(f"{w:>4} {paths:24s} {deltas:18s} {int(tel.banks):>5} {rhos}{note}")

print("\nwindow 0: full scans (cold cache); drift: exact delta updates; "
      "load spike: bypass; scene cut: full refresh.")
