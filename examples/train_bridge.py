"""Train the image->event contrastive bridge (paper Eq. 1-3).

Synthesizes paired (image-embedding, event-window) data for a small class
vocabulary, trains the spiking encoder against frozen CLIP-proxy targets
with L = L_con + alpha * L_zs, and reports zero-shot accuracy — the
paper's training phase, miniaturized for CPU.

Run:  PYTHONPATH=src python examples/train_bridge.py [--steps 150]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bridge, encoder, events
from repro.optim import adamw

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=150)
ap.add_argument("--classes", type=int, default=8)
ap.add_argument("--batch", type=int, default=16)
ap.add_argument("--alpha", type=float, default=1.0)
args = ap.parse_args()

H = W = 16
T_BINS, EMB = 4, 64
ecfg = encoder.EncoderConfig(c1=8, c2=16, feat_dim=EMB)
key = jax.random.PRNGKey(0)
params = encoder.init_encoder(key, ecfg)

# frozen proxies: image encoder sees class "images"; text bank is fixed
f_img = bridge.make_frozen_proxy(jax.random.PRNGKey(1), args.classes, EMB)
text_bank = jax.random.normal(jax.random.PRNGKey(2), (args.classes, EMB))

# per-class event signature: a spatial blob whose events fire consistently
rng = np.random.default_rng(0)
centers = rng.integers(3, H - 3, (args.classes, 2))


def sample_batch(step):
    r = np.random.default_rng(step)
    labels = r.integers(0, args.classes, args.batch)
    vols = np.zeros((args.batch, T_BINS, H, W, 2), np.float32)
    for i, c in enumerate(labels):
        cy, cx = centers[c]
        n_ev = 60
        ys = np.clip(r.normal(cy, 1.5, n_ev).astype(int), 0, H - 1)
        xs = np.clip(r.normal(cx, 1.5, n_ev).astype(int), 0, W - 1)
        tb = r.integers(0, T_BINS, n_ev)
        pol = (r.random(n_ev) < 0.5).astype(int)
        np.add.at(vols[i], (tb, ys, xs, pol), 1.0)
    img = jax.nn.one_hot(jnp.asarray(labels), args.classes)
    return jnp.asarray(vols), f_img(img), jnp.asarray(labels)


def loss_fn(params, vols, img_emb, labels):
    ev_emb = encoder.encode_batch(params, vols, ecfg)
    return bridge.bridge_loss(img_emb, ev_emb, text_bank, labels,
                              alpha=args.alpha)


ocfg = adamw.OptimConfig(lr=2e-3, warmup_steps=10, total_steps=args.steps,
                         weight_decay=0.01)
opt = adamw.init_opt_state(params)

accs = []
for s in range(args.steps):
    vols, img_emb, labels = sample_batch(s)
    (loss, metrics), grads = jax.value_and_grad(
        loss_fn, has_aux=True)(params, vols, img_emb, labels)
    params, opt, om = adamw.apply_updates(params, grads, opt, ocfg)
    accs.append(float(metrics["zs_acc"]))
    if s % 25 == 0 or s == args.steps - 1:
        print(f"step {s:4d}  L={float(loss):.3f}  L_con={float(metrics['l_con']):.3f} "
              f"L_zs={float(metrics['l_zs']):.3f}  zs_acc={accs[-1]:.2f}")

first, last = np.mean(accs[:10]), np.mean(accs[-10:])
print(f"\nzero-shot accuracy: {first:.2f} -> {last:.2f}")
assert last > first + 0.2, "bridge did not learn"
print("bridge converged ✓ (event features aligned to CLIP-proxy space)")
