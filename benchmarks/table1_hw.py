"""Table 1: hardware footprint (TSMC 28 nm, 1 GHz) — block area/power model.

Prints the block inventory the cycle model is calibrated against, and checks
the paper's totals (5.937 mm^2 logic / 4659.84 mW logic / 6.467 mm^2 grand).
"""
from __future__ import annotations

from repro.perf.cycle_model import AREA, POWER_W

PAPER_LOGIC_AREA = 5.937
PAPER_LOGIC_POWER = 4659.84
PAPER_TOTAL_AREA = 6.467
PAPER_TOTAL_POWER = 4794.84

_SRAM = ("Item memory (banked)", "Query/Output caches")


def run() -> list[tuple]:
    rows = []
    logic_area = sum(v for k, v in AREA.items() if k not in _SRAM)
    logic_pw = sum(POWER_W[k] for k in AREA if k not in _SRAM) * 1e3
    total_area = sum(AREA.values())
    total_pw = sum(POWER_W.values()) * 1e3
    for k in AREA:
        rows.append((f"table1/{k}", AREA[k], POWER_W[k] * 1e3))
    rows.append(("table1/Total(logic)", logic_area, logic_pw))
    rows.append(("table1/GrandTotal", total_area, total_pw))
    # Note: the paper's printed totals (5.937 / 6.467 mm^2) exceed the sum of
    # its own block rows by 0.002 mm^2 — a rounding artifact in Table 1.
    assert abs(logic_area - PAPER_LOGIC_AREA) < 0.005
    assert abs(logic_pw - PAPER_LOGIC_POWER) < 0.5
    assert abs(total_area - PAPER_TOTAL_AREA) < 0.005
    assert abs(total_pw - PAPER_TOTAL_POWER) < 0.5
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
