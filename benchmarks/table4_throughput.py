"""Table 4: throughput/energy vs GPU baselines.

GPU rows are the paper's cited figures (450 W / FPS); ours come from the
cycle model. The derived column reports the energy advantage factor.
"""
from __future__ import annotations

import numpy as np

from repro.perf.cycle_model import simulate_all

GPU_BASELINES = [
    ("TOIST (DETR)", 20.0, 450.0 / 20.0),          # mid of 15-25 FPS
    ("iTaskCLIP (ViT-B/16)", 8.5, 450.0 / 8.5),    # mid of 5-12
    ("iTaskCLIP (ViT-L/14)", 4.0, 450.0 / 4.0),    # mid of 2-6
]


def run(n_frames: int = 300) -> list[tuple]:
    rows = []
    for name, fps, epf in GPU_BASELINES:
        rows.append((f"table4/{name.replace(' ', '_')}", fps,
                     f"J_per_frame={epf:.1f}"))
    for rt, fps_target in (("RT-60", 60.0), ("RT-30", 30.0)):
        res = simulate_all(rt, n_frames=n_frames)
        # sustained fps: all p95 within budget => target met
        e_mj = float(np.mean([r["energy_mj"] for r in res]))
        worst_gpu = max(b[2] for b in GPU_BASELINES)
        adv = worst_gpu / (e_mj / 1e3)
        rows.append((f"table4/Ours_{rt}", fps_target,
                     f"E_per_frame_mJ={e_mj:.0f};energy_advantage_x={adv:.0f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
