"""Roofline summary: aggregates the dry-run sweep into the per-cell table.

Reads experiments/dryrun/*.json (produced by repro.launch.dryrun) — this
benchmark does not compile anything itself, it reports the measured terms.
"""
from __future__ import annotations

import json
import pathlib

OUT = pathlib.Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def run(mesh: str = "pod16x16", variant: str = "baseline") -> list[tuple]:
    rows = []
    for p in sorted(OUT.glob(f"*__{mesh}__{variant}.json")):
        d = json.loads(p.read_text())
        cell = f"roofline/{d.get('arch', p.stem)}/{d.get('shape', '')}"
        if d["status"] == "SKIP":
            rows.append((cell, "SKIP", d["reason"][:60]))
            continue
        if d["status"] != "OK":
            rows.append((cell, "FAIL", d.get("error", "")[:80]))
            continue
        rows.append((
            cell,
            round(d["t_bound"] if "t_bound" in d else
                  max(d["t_compute"], d["t_memory"], d["t_collective"]), 6),
            (f"bound={d['bottleneck']};tc={d['t_compute']:.3e};"
             f"tm={d['t_memory']:.3e};tx={d['t_collective']:.3e};"
             f"roofline_frac={d['roofline_frac']:.4f};"
             f"useful={d['useful_flops_frac']:.3f}")))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
