"""Table 6 (beyond paper): multi-stream serving throughput.

The ROADMAP's north star is heavy multi-tenant traffic; this table measures
windows/sec as a function of concurrent stream count for

  * ``looped``  — the seed baseline: one jitted ``torr_window_step`` per
    frame per stream, streams served round-robin from Python;
  * ``vmap``    — the multi-stream engine, vmap lowering: one jitted
    ``torr_multi_stream_step`` over S stream slots per tick, all slots on
    vector lanes (every proposal pays the union of the three paths — the
    TPU-shaped trade);
  * ``serial``  — the same engine with the lax.map lowering: slots run
    sequentially inside one executable, keeping scalar branch economy
    while amortizing host dispatch (the CPU-shaped trade).

Both batched engines now ride the *fused* full path by default (the
``"prefix"`` kernel dispatch under vmap, ``"switch"`` under serial — see
``repro.core.pipeline``); ``--lowering {vmap,serial,fused}`` restricts the
measurement (``fused``, the default, measures both and records the winner
per backend in the ``table6/winner_S*`` rows and the ``--json`` output —
the re-measured vmap-vs-serial split from the ROADMAP).

All engines serve identical frame sequences and produce bit-identical
scores (tests/test_multistream.py), so the ratios are pure
scheduling/lowering effects.

Rows: ``table6/<engine>_S<streams>, windows_per_sec, speedup_vs_looped``.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import hdc, pipeline
from repro.core.item_memory import random_item_memory
from repro.core.types import TorrConfig
from repro.serving.stream_engine import StreamEngine

CFG = TorrConfig(D=2048, B=8, M=64, K=8, N_max=8, delta_budget=256)


def _make_streams(cfg: TorrConfig, n_streams: int, n_frames: int, seed: int):
    """Per-stream window sequences with temporal coherence (cache-friendly)."""
    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed)
    streams = []
    for s in range(n_streams):
        key, k = jax.random.split(key)
        base = np.array(hdc.random_hv(k, (cfg.N_max, cfg.D)), np.int8)
        frames = []
        for _ in range(n_frames):
            flips = rng.integers(0, cfg.D, (cfg.N_max, 16))
            for n in range(cfg.N_max):
                base[n, flips[n]] *= -1
            q = np.asarray(hdc.pack_bits(jnp.asarray(base)))
            valid = rng.random(cfg.N_max) < 0.85
            boxes = rng.random((cfg.N_max, 4)).astype(np.float32)
            frames.append((q, valid, boxes))
        streams.append(frames)
    return streams


def _run_looped(cfg, im, task_w, streams):
    """Round-robin python loop over per-stream single-window steps."""
    step = jax.jit(pipeline.torr_window_step, static_argnames="cfg")
    states = [pipeline.init_state(cfg, jnp.asarray(task_w[s]))
              for s in range(len(streams))]
    n_frames = len(streams[0])
    t0 = time.time()
    for t in range(n_frames):
        for s, frames in enumerate(streams):
            q, valid, boxes = frames[t]
            states[s], _, _ = step(
                states[s], im, jnp.asarray(q), jnp.asarray(valid),
                jnp.asarray(boxes), jnp.int32(n_frames - t - 1), cfg)
    # every stream's chain is independent; block on all of them
    jax.block_until_ready([st.cache.age for st in states])
    dt = time.time() - t0
    return len(streams) * n_frames / dt


def _run_batched(cfg, im, task_w, streams, serial):
    eng = StreamEngine(cfg, im, n_slots=len(streams), serial=serial)
    for s, frames in enumerate(streams):
        eng.admit(s, task_w[s])
        for q, valid, boxes in frames:
            eng.submit(s, q, valid, boxes)
    t0 = time.time()
    while eng.busy:
        eng.step()
    eng.sync()
    dt = time.time() - t0
    return eng.stats.windows / dt


def run(stream_counts=(1, 4, 16, 64), n_frames: int = 12,
        lowering: str = "fused") -> list[tuple]:
    """``lowering``: "vmap" / "serial" restrict to one batched lowering;
    "fused" (default) measures both — each riding its fused full path —
    and records the winner per backend."""
    if lowering not in ("vmap", "serial", "fused"):
        raise ValueError(f"lowering={lowering!r}")
    do_vmap = lowering in ("vmap", "fused")
    do_serial = lowering in ("serial", "fused")
    cfg = CFG
    im = random_item_memory(jax.random.PRNGKey(0), cfg)
    rows = []
    for S in stream_counts:
        task_w = np.asarray(
            jax.random.uniform(jax.random.PRNGKey(1), (S, cfg.M)))
        streams = _make_streams(cfg, S, n_frames, seed=S)
        # warm every executable outside the timed region
        warm = _make_streams(cfg, S, 1, seed=1000 + S)
        _run_looped(cfg, im, task_w, warm)
        if do_vmap:
            _run_batched(cfg, im, task_w, warm, serial=False)
        if do_serial:
            _run_batched(cfg, im, task_w, warm, serial=True)

        wps_loop = _run_looped(cfg, im, task_w, streams)
        rows.append((f"table6/looped_S{S}", round(wps_loop, 1), "speedup=1.0"))
        wps = {}
        if do_vmap:
            wps["vmap"] = _run_batched(cfg, im, task_w, streams, serial=False)
            rows.append((f"table6/batched_vmap_S{S}", round(wps["vmap"], 1),
                         f"speedup={wps['vmap'] / wps_loop:.2f}"))
        if do_serial:
            wps["serial"] = _run_batched(cfg, im, task_w, streams, serial=True)
            rows.append((f"table6/batched_serial_S{S}",
                         round(wps["serial"], 1),
                         f"speedup={wps['serial'] / wps_loop:.2f}"))
        if len(wps) == 2:
            winner = max(wps, key=wps.get)
            rows.append((f"table6/winner_S{S}", winner,
                         f"backend={jax.default_backend()}"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--lowering", default="fused",
                    choices=("vmap", "serial", "fused"),
                    help="batched lowering(s) to measure; 'fused' measures "
                         "both (each on its fused full path) and records "
                         "the winner per backend")
    ap.add_argument("--json", default="", metavar="PATH",
                    help="also write rows + per-S winners as JSON to PATH")
    args = ap.parse_args()
    rows = run(lowering=args.lowering)
    for r in rows:
        print(",".join(str(x) for x in r))
    if args.json:
        winners = {r[0].split("_S")[-1]: r[1] for r in rows
                   if r[0].startswith("table6/winner_S")}
        with open(args.json, "w") as f:
            json.dump({"rows": [list(r) for r in rows],
                       "backend": jax.default_backend(),
                       "lowering": args.lowering,
                       "winner_by_streams": winners}, f, indent=1)


if __name__ == "__main__":
    main()
