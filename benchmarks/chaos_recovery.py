"""Chaos recovery: supervised-restart cost under injected worker death.

Drives the async serving engine through the deterministic chaos harness
(``repro.runtime.fault.FaultPlan``) under the fault-tolerant front-end
(``repro.serving.supervisor.ServeSupervisor`` + an in-memory
``StateStore`` at ``snapshot_every=1``) and measures what a worker death
actually costs:

  * ``chaos/bare_wps``        — the unsupervised async engine, fault-free
    (the table7 configuration at S = 16): the throughput baseline.
  * ``chaos/supervised_wps``  — the same traffic behind the supervisor,
    fault-free: journalling + write-through snapshot overhead.
  * ``chaos/<kind>_fault_wps``       — one injected dispatcher/collector
    death mid-run: end-to-end throughput including crash detection,
    engine rebuild, warm-start re-admission and replay.
  * ``chaos/<kind>_recovery_ms``     — crash-detection → replay-complete
    latency, read off the supervisor's ``engine_recovered`` flight event.
  * ``chaos/<kind>_replayed``        — in-flight windows re-dispatched.

Every run serves the identical frame sequences and the benchmark asserts
the recovered outputs are *bit-identical* to the bare fault-free engine's
(the ISSUE 9 acceptance property) — the rows are pure recovery-cost
measurements, never a correctness trade. Registered as the ``chaos``
suite in ``benchmarks.run``; the registry snapshot (restart/replay/state-
store counters) rides the JSON artifact via ``metrics_snapshot``.
"""
from __future__ import annotations

import time

import numpy as np

import jax

from repro.core.item_memory import random_item_memory
from repro.runtime.fault import FaultPlan
from repro.serving.async_engine import AsyncStreamEngine
from repro.serving.state_store import InMemoryStateStore
from repro.serving.supervisor import ServeSupervisor

from .table6_multistream import CFG, _make_streams

_METRICS = None


def metrics_snapshot():
    """Metrics of the last run(), for the JSON artifact."""
    return _METRICS.snapshot() if _METRICS is not None else None


def _reference(cfg, im, task_w, streams):
    """Fault-free unsupervised outputs: (wps, {(sid, seq): best})."""
    eng = AsyncStreamEngine(cfg, im, n_slots=len(streams), paused=True)
    futs = []
    for s, frames in enumerate(streams):
        eng.admit(s, task_w[s])
        for t, (q, valid, boxes) in enumerate(frames):
            futs.append((s, t, eng.submit(s, q, valid, boxes)))
    eng.warmup()
    t0 = time.perf_counter()
    eng.start()
    eng.flush()
    dt = time.perf_counter() - t0
    wps = eng.stats.windows / dt
    eng.close()
    outs = {(s, t): np.asarray(f.result(timeout=1)[0].best)
            for s, t, f in futs}
    return wps, outs


def _supervised(cfg, im, task_w, streams, fault=None, metrics=None,
                flight=None):
    """One supervised drive; returns (wps, outputs, summary, flight recs)."""
    store = InMemoryStateStore(metrics=metrics)

    def make_engine():
        return AsyncStreamEngine(cfg, im, n_slots=len(streams), paused=True,
                                 store=store, snapshot_every=1,
                                 fault_plan=fault)

    sup = ServeSupervisor(make_engine, store, metrics=metrics, flight=flight)
    futs = []
    for s, frames in enumerate(streams):
        sup.admit(s, task_w[s])
        for t, (q, valid, boxes) in enumerate(frames):
            futs.append((s, t, sup.submit(s, q, valid, boxes)))
    sup.engine.warmup()
    t0 = time.perf_counter()
    sup.engine.start()
    sup.flush()
    dt = time.perf_counter() - t0
    n_win = sum(len(frames) for frames in streams)
    outs = {(s, t): np.asarray(f.result(timeout=1)[0].best)
            for s, t, f in futs}
    sup.close(drain=False)
    return n_win / dt, outs, sup.summary()


def _assert_identical(got: dict, want: dict, label: str) -> None:
    assert set(got) == set(want), (label, "lost windows",
                                   sorted(set(want) - set(got))[:5])
    for k in want:
        assert np.array_equal(got[k], want[k]), (label, k)


def run(n_streams: int = 16, n_frames: int = 12) -> list[tuple]:
    global _METRICS
    from repro.obs import FlightRecorder, MetricsRegistry
    from repro.serving.supervisor import recovery_events

    cfg = CFG
    im = random_item_memory(jax.random.PRNGKey(0), cfg)
    task_w = np.asarray(
        jax.random.uniform(jax.random.PRNGKey(1), (n_streams, cfg.M)))
    streams = _make_streams(cfg, n_streams, n_frames, seed=n_streams)
    _METRICS = reg = MetricsRegistry()

    wps_bare, ref = _reference(cfg, im, task_w, streams)
    wps_sup, outs, _ = _supervised(cfg, im, task_w, streams, metrics=reg)
    _assert_identical(outs, ref, "supervised-faultfree")
    rows = [
        ("chaos/bare_wps", round(wps_bare, 1),
         "windows/sec, unsupervised async, fault-free"),
        ("chaos/supervised_wps", round(wps_sup, 1),
         f"journal+snapshots(cadence=1); "
         f"ratio_vs_bare={wps_sup / wps_bare:.2f}"),
    ]
    for kind in ("dispatcher", "collector"):
        flight = FlightRecorder(1024)
        fault = FaultPlan(at_step=4, thread=kind)
        wps, outs, summary = _supervised(cfg, im, task_w, streams,
                                         fault=fault, metrics=reg,
                                         flight=flight)
        _assert_identical(outs, ref, f"{kind}-fault")
        assert summary["restarts"] == 1, summary
        recs = [r for r in recovery_events(flight.records())
                if r["event"] == "engine_recovered"]
        rec_ms = recs[-1]["duration_s"] * 1e3 if recs else float("nan")
        rows.extend([
            (f"chaos/{kind}_fault_wps", round(wps, 1),
             f"1 injected {kind} death @ step 4; "
             f"ratio_vs_faultfree={wps / wps_sup:.2f}"),
            (f"chaos/{kind}_recovery_ms", round(rec_ms, 2),
             "crash detection -> replay complete"),
            (f"chaos/{kind}_replayed", summary["windows_replayed"],
             "in-flight windows re-dispatched after restart"),
        ])
    return rows


def main() -> None:
    for r in run():
        print(",".join(str(x) for x in r))


if __name__ == "__main__":
    main()
