"""Perf-trend regression gate over ``benchmarks/run.py --json`` artifacts.

A single benchmark run can only say "this is how fast the commit is"; the
trend gate says "and that is N% slower than the last five runs" — the
check that catches a scheduling regression the correctness suites cannot
see. It keeps a rolling history in ``BENCH_trend.json``:

    {"format": "torr-bench-trend-v1",
     "entries": [{"sha": ..., "timestamp": ..., "backend": ...,
                  "metrics": {"table7/async_S16": 512.3, ...}}, ...]}

and, per artifact ingested:

1. extracts the *throughput* rows (the ``table6/``/``table7/`` windows/sec
   rows — higher is better; string-valued rows like the table6 winner are
   skipped) plus the run's provenance ``meta`` (stamped by
   ``benchmarks/run.py``);
2. compares each metric against the **rolling baseline**: the median of
   the last ``--baseline-runs`` (default 5) history entries from the same
   JAX backend (CPU and accelerator numbers must never gate each other);
3. flags a regression when ``value < (1 - threshold) * baseline``
   (default threshold 10%); ``--check`` turns flags into a non-zero exit
   (the CI gate), otherwise they are warnings;
4. appends the new entry and rewrites the history (unless ``--no-append``,
   which CI uses for pure gate re-runs).

Noise floor: windows/sec on shared CI runners jitters a few percent; the
10% threshold + median-of-5 baseline means a single noisy run neither
trips the gate nor poisons the baseline. Workflow details in
``docs/observability.md``.

Usage:
    python -m benchmarks.run --json bench.json
    python -m benchmarks.trend bench.json --check
"""
from __future__ import annotations

import argparse
import json
import os
import statistics
import sys
from typing import Dict, List, Optional

TREND_FORMAT = "torr-bench-trend-v1"
DEFAULT_THRESHOLD = 0.10
DEFAULT_BASELINE_RUNS = 5
DEFAULT_TREND_PATH = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_trend.json")

# suites whose numeric rows are windows/sec throughputs (higher = better);
# other suites report latencies/areas/AP where "lower" or "different" is
# not a regression in the same direction, so they are not gated here
THROUGHPUT_PREFIXES = ("table6/", "table7/")


def extract_metrics(doc: dict) -> Dict[str, float]:
    """Gated metric values from one ``run.py --json`` document.

    Accepts the suite-keyed shape (``{suite: {"rows": ...}}``) and the
    single-suite shape some benchmarks write standalone
    (``{"rows": [...], ...}``). Rows whose value is not a positive number
    (e.g. the table6 winner rows, failed suites) are skipped.
    """
    metrics: Dict[str, float] = {}

    def eat_rows(rows):
        for row in rows or ():
            if len(row) < 2 or not isinstance(row[0], str):
                continue
            name, value = row[0], row[1]
            if not name.startswith(THROUGHPUT_PREFIXES):
                continue
            if name.endswith("/_suite_seconds"):
                continue
            # latency/jitter rows are lower-is-better: gating them with
            # the throughput rule would flag *improvements*
            if any(t in name for t in ("_ms", "latency", "jitter", "p9")):
                continue
            if isinstance(value, bool) or not isinstance(value, (int, float)):
                continue
            if value > 0:
                metrics[name] = float(value)

    if "rows" in doc and isinstance(doc.get("rows"), list):
        eat_rows(doc["rows"])
    for key, suite in doc.items():
        if isinstance(suite, dict) and isinstance(suite.get("rows"), list):
            eat_rows(suite["rows"])
    return metrics


def load_trend(path: str) -> dict:
    """Load (or initialize) the rolling trend history."""
    if not os.path.exists(path):
        return {"format": TREND_FORMAT, "entries": []}
    with open(path) as f:
        trend = json.load(f)
    if trend.get("format") != TREND_FORMAT:
        raise ValueError(
            f"{path}: unknown trend format {trend.get('format')!r} "
            f"(expected {TREND_FORMAT!r})")
    trend.setdefault("entries", [])
    return trend


def save_trend(trend: dict, path: str) -> None:
    with open(path, "w") as f:
        json.dump(trend, f, indent=1, sort_keys=True)
        f.write("\n")


def make_entry(doc: dict, meta: Optional[dict] = None) -> dict:
    """One history entry from an artifact document (+ optional meta
    override; defaults to the document's own ``"meta"`` stamp)."""
    meta = meta if meta is not None else doc.get("meta") or {}
    return {
        "sha": meta.get("sha", "unknown"),
        "timestamp": meta.get("timestamp", ""),
        "backend": meta.get("backend", doc.get("backend", "unknown")),
        "metrics": extract_metrics(doc),
    }


def baseline_for(trend: dict, backend: str, metric: str,
                 baseline_runs: int = DEFAULT_BASELINE_RUNS
                 ) -> Optional[float]:
    """Rolling baseline: median of the metric over the last
    ``baseline_runs`` same-backend entries that carry it (None if the
    history has no usable sample — a fresh metric never gates)."""
    vals = [e["metrics"][metric] for e in trend["entries"]
            if e.get("backend") == backend and metric in e.get("metrics", {})]
    if not vals:
        return None
    return float(statistics.median(vals[-baseline_runs:]))


def check_entry(trend: dict, entry: dict,
                threshold: float = DEFAULT_THRESHOLD,
                baseline_runs: int = DEFAULT_BASELINE_RUNS) -> List[dict]:
    """Regressions of one new entry vs the rolling baseline.

    Returns one dict per regressed metric: ``{"metric", "value",
    "baseline", "drop"}`` where drop is the fractional loss.
    """
    regressions = []
    for metric, value in sorted(entry["metrics"].items()):
        base = baseline_for(trend, entry["backend"], metric, baseline_runs)
        if base is None or base <= 0:
            continue
        if value < (1.0 - threshold) * base:
            regressions.append({
                "metric": metric, "value": value, "baseline": base,
                "drop": 1.0 - value / base,
            })
    return regressions


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="append benchmark artifacts to the perf-trend history "
                    "and gate throughput regressions")
    ap.add_argument("artifacts", nargs="+", metavar="JSON",
                    help="benchmarks/run.py --json artifact(s) to ingest")
    ap.add_argument("--trend", default=DEFAULT_TREND_PATH, metavar="PATH",
                    help=f"trend history file (default {DEFAULT_TREND_PATH})")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero on any regression (the CI gate); "
                         "without it regressions are warnings")
    ap.add_argument("--threshold", type=float, default=DEFAULT_THRESHOLD,
                    help="fractional drop vs the rolling baseline that "
                         "counts as a regression (default 0.10)")
    ap.add_argument("--baseline-runs", type=int,
                    default=DEFAULT_BASELINE_RUNS,
                    help="history entries the rolling median baseline "
                         "spans (default 5)")
    ap.add_argument("--no-append", action="store_true",
                    help="gate only; do not append to / rewrite the history")
    args = ap.parse_args(argv)

    trend = load_trend(args.trend)
    any_regressed = False
    for path in args.artifacts:
        with open(path) as f:
            doc = json.load(f)
        entry = make_entry(doc)
        if not entry["metrics"]:
            print(f"[trend] {path}: no gated throughput rows "
                  f"(prefixes {THROUGHPUT_PREFIXES}); nothing to do")
            continue
        regressions = check_entry(trend, entry, args.threshold,
                                  args.baseline_runs)
        n_base = sum(1 for m in entry["metrics"]
                     if baseline_for(trend, entry["backend"], m,
                                     args.baseline_runs) is not None)
        print(f"[trend] {path}: {len(entry['metrics'])} metrics "
              f"({n_base} with a {entry['backend']} baseline), "
              f"{len(regressions)} regression(s)")
        for r in regressions:
            any_regressed = True
            print(f"[trend]   REGRESSION {r['metric']}: {r['value']:.1f} "
                  f"vs baseline {r['baseline']:.1f} "
                  f"(-{r['drop'] * 100.0:.1f}%, threshold "
                  f"{args.threshold * 100.0:.0f}%)")
        if not args.no_append:
            trend["entries"].append(entry)
    if not args.no_append:
        save_trend(trend, args.trend)
        print(f"[trend] history: {len(trend['entries'])} entries -> "
              f"{args.trend}")
    if any_regressed and args.check:
        print("[trend] FAILED: throughput regressed past the gate",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
