"""Production-shaped load/chaos harness for the network event gateway.

Open-loop trace replay against :class:`repro.serving.gateway.Gateway`
over real sockets (stdlib ``http.client`` + numpy only — the client is
deliberately dependency-free so it can drive a remote deployment):

  * **Arrival process** — per-stream Poisson arrivals thinned against a
    rate profile with a sinusoidal diurnal ramp and a configurable burst
    window (``burst_factor`` x for a fraction of the run). The schedule
    is precomputed from the seed, so a chaos run and a fault-free run
    replay the *identical* trace.
  * **Churn** — streams open and close at staggered offsets; tenants mix
    RT-30 and RT-60 sessions via the per-session ``deadline_ms``.
  * **Coordinated-omission-safe latency** — every window has a scheduled
    arrival time; latency is measured from the *schedule*, not from the
    (possibly delayed) send, so a stalled server cannot hide queueing
    delay from the percentiles.
  * **Retry contract** — 429/503 responses are retried honouring the
    server's ``Retry-After``/``X-Retry-After-S`` hint with bounded
    attempts; a 503 ``deadline`` retry re-sends the *same* seq, which
    collects the parked result (docs/gateway.md).
  * **Reconciliation** — after the drive, the gateway's own
    ``torr_gateway_requests_total{route="window",...}`` series are
    scraped and compared *exactly* against the client-side status
    counts: overload behaviour is measured, never asserted blind.

Modes: ``--target HOST:PORT`` drives an external gateway; ``--spawn``
launches ``repro.launch.serve --gateway-port 0`` as a subprocess
(optionally with an injected ``--fault-at`` worker death), parses the
handshake line for the ephemeral port, SIGTERMs it at the end and
requires a clean drain (exit 0). ``run()`` registers the in-process
``loadgen`` suite in ``benchmarks.run``: a supervised engine with one
dispatcher death behind a rate-limited gateway, asserting zero window
loss and a nonzero 429 count under measured overload.
"""
from __future__ import annotations

import argparse
import dataclasses
import http.client
import json
import math
import os
import re
import signal
import subprocess
import sys
import threading
import time

import numpy as np

_METRICS = None   # registry of the last in-process run(), for the artifact


def metrics_snapshot():
    """Metrics of the last run(), for the JSON artifact."""
    return _METRICS.snapshot() if _METRICS is not None else None


# ---------------------------------------------------------------------------
# plan + schedule


@dataclasses.dataclass
class LoadPlan:
    """Traffic shape for one drive (all times in seconds)."""

    seconds: float = 8.0         # scheduled arrival horizon
    streams: int = 6             # concurrent client streams
    tenants: int = 3             # streams are round-robined over tenants
    rate: float = 40.0           # aggregate steady-state windows/sec
    burst_factor: float = 6.0    # rate multiplier inside the burst window
    burst_at: float = 0.35       # burst start, fraction of the horizon
    burst_len: float = 0.2       # burst length, fraction of the horizon
    diurnal_amp: float = 0.5     # sinusoidal ramp amplitude (0..1)
    churn: float = 0.25          # open/close stagger, fraction of horizon
    rt30_frac: float = 0.4       # fraction of streams opened as RT-30
    max_attempts: int = 10       # bounded retries per window
    seed: int = 0
    timeout_s: float = 30.0      # socket timeout (>> server deadline)
    drain_grace_s: float = 15.0  # post-horizon budget to settle retries


def _profile(t: float, horizon: float, plan: LoadPlan) -> float:
    """Rate multiplier at time ``t``: diurnal ramp x burst window."""
    m = 1.0 + plan.diurnal_amp * math.sin(2.0 * math.pi * t / horizon)
    b0 = plan.burst_at * horizon
    if b0 <= t < b0 + plan.burst_len * horizon:
        m *= plan.burst_factor
    return m


def make_schedule(plan: LoadPlan) -> list[dict]:
    """Precompute the whole trace: per-stream lifespans + arrival times.

    Thinned Poisson: draw at the profile's peak rate, keep each arrival
    with probability ``profile(t)/peak``. Entirely determined by
    ``plan.seed`` — chaos and fault-free runs replay the same trace.
    """
    rng = np.random.default_rng(plan.seed)
    per_stream = plan.rate / max(1, plan.streams)
    peak = (1.0 + plan.diurnal_amp) * max(1.0, plan.burst_factor)
    streams = []
    for s in range(plan.streams):
        t_open = float(rng.uniform(0.0, plan.churn * plan.seconds))
        t_close = plan.seconds - float(
            rng.uniform(0.0, plan.churn * plan.seconds) * (s % 2))
        arrivals, t = [], t_open
        while True:
            t += float(rng.exponential(1.0 / (per_stream * peak)))
            if t >= t_close:
                break
            if rng.random() < _profile(t, plan.seconds, plan) / peak:
                arrivals.append(t)
        streams.append({
            "stream": f"s{s}",
            "tenant": f"t{s % max(1, plan.tenants)}",
            "rt": "RT-30" if s < plan.rt30_frac * plan.streams else "RT-60",
            "t_open": t_open,
            "t_close": t_close,
            "arrivals": arrivals,
        })
    return streams


class _FrameGen:
    """Deterministic per-stream window contents with temporal coherence.

    A base pool of packed hypervector frames; each window XORs a few
    single-bit masks into the previous frame (the cache-reuse-shaped
    pattern from ``table6_multistream._make_streams``, packed-domain).
    Content depends only on (seed, stream index, window index), never on
    timing, so replayed traces are bit-identical across runs.
    """

    def __init__(self, seed: int, sidx: int, n_max: int, words: int):
        self._rng = np.random.default_rng((seed + 1) * 1009 + sidx)
        self._n, self._w = n_max, words
        self._base = self._rng.integers(
            0, 1 << 32, (n_max, words), dtype=np.uint32)

    def next(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        rng = self._rng
        rows = rng.integers(0, self._n, 16)
        cols = rng.integers(0, self._w, 16)
        bits = rng.integers(0, 32, 16)
        for r, c, b in zip(rows, cols, bits):
            self._base[r, c] ^= np.uint32(1) << np.uint32(b)
        valid = rng.random(self._n) < 0.85
        if not valid.any():
            valid[0] = True
        boxes = rng.random((self._n, 4)).astype(np.float32)
        return self._base.copy(), valid, boxes


# ---------------------------------------------------------------------------
# HTTP client helpers (stdlib only — no repro imports on the client path)


def _b64(a: np.ndarray) -> dict:
    import base64
    return {"dtype": a.dtype.name, "shape": list(a.shape),
            "data": base64.b64encode(np.ascontiguousarray(a).tobytes())
            .decode("ascii")}


class _Client:
    """One keep-alive connection with JSON request/response plumbing."""

    def __init__(self, host: str, port: int, timeout_s: float):
        self.host, self.port, self.timeout_s = host, port, timeout_s
        self._conn = None

    def close(self) -> None:
        if self._conn is not None:
            try:
                self._conn.close()
            finally:
                self._conn = None

    def request(self, method: str, path: str, body: dict | None = None):
        """Returns ``(status, headers_dict, body_obj_or_bytes)``."""
        data = json.dumps(body).encode() if body is not None else None
        if self._conn is None:
            self._conn = http.client.HTTPConnection(
                self.host, self.port, timeout=self.timeout_s)
        conn = self._conn
        try:
            conn.request(method, path, body=data,
                         headers={"Content-Type": "application/json"}
                         if data else {})
            r = conn.getresponse()
            raw = r.read()
        except (OSError, http.client.HTTPException):
            self.close()
            raise
        if r.getheader("Connection", "").lower() == "close":
            self.close()
        headers = {k.lower(): v for k, v in r.getheaders()}
        if raw[:1] in (b"{", b"["):
            try:
                return r.status, headers, json.loads(raw)
            except ValueError:
                pass
        return r.status, headers, raw


def _retry_hint(headers: dict, body) -> float:
    """Server backoff hint in seconds (precise header > int header > body)."""
    for key in ("x-retry-after-s", "retry-after"):
        v = headers.get(key)
        if v is not None:
            try:
                return float(v)
            except ValueError:
                pass
    if isinstance(body, dict) and "retry_after_s" in body:
        try:
            return float(body["retry_after_s"])
        except (TypeError, ValueError):
            pass
    return 0.05


def _reason(body) -> str | None:
    """Typed reject reason from an error body (``{"error": <reason>}``)."""
    return body.get("error") if isinstance(body, dict) else None


# ---------------------------------------------------------------------------
# the drive


class _Counters:
    """Lock-guarded client-side ledger, reconciled against /metrics."""

    def __init__(self):
        self.lock = threading.Lock()
        self.window_status: dict = {}     # status code -> responses seen
        self.reject_reasons: dict = {}    # reason -> count (429/503/4xx)
        self.latency_ms: list = []        # 200s only, from scheduled arrival
        self.served = 0
        self.retries = 0
        self.gave_up = 0                  # windows dropped after max 429s
        self.abandoned = 0                # windows left in flight (loss!)
        self.lost = 0                     # 5xx internal / missing results
        self.transport_errors = 0
        self.anomalies: list = []         # unexpected (status, reason) pairs
        self.stopped_early = 0            # streams ended by drain/terminal
        self.session_status: dict = {}

    def count(self, table: str, key) -> None:
        with self.lock:
            d = getattr(self, table)
            d[key] = d.get(key, 0) + 1


def _drive_stream(spec: dict, plan: LoadPlan, host: str, port: int,
                  n_max: int, words: int, task: int, t0: float,
                  ctr: _Counters, bodies: dict) -> None:
    """One stream's client: open, replay arrivals serially, close."""
    cli = _Client(host, port, plan.timeout_s)
    sid = f"{spec['tenant']}/{spec['stream']}"
    gen = _FrameGen(plan.seed, int(spec["stream"][1:]), n_max, words)
    now = time.monotonic

    def _sleep_until(t_rel: float) -> None:
        dt = (t0 + t_rel) - now()
        if dt > 0:
            time.sleep(dt)

    # -- open the session (bounded retries: slots/tenant quota may be hot)
    _sleep_until(spec["t_open"])
    opened = False
    for _ in range(plan.max_attempts):
        try:
            st, hdr, body = cli.request(
                "POST", "/v1/session",
                {"tenant": spec["tenant"], "stream": spec["stream"],
                 "task": task, "rt": spec["rt"]})
        except (OSError, http.client.HTTPException):
            with ctr.lock:
                ctr.transport_errors += 1
            time.sleep(0.1)
            continue
        ctr.count("session_status", st)
        if st == 200:
            opened = True
            break
        if st in (429, 503):
            ctr.count("reject_reasons", _reason(body) or "?")
            time.sleep(min(_retry_hint(hdr, body), 1.0))
            continue
        with ctr.lock:
            ctr.anomalies.append(("session", st, _reason(body)))
        break
    if not opened:
        with ctr.lock:
            ctr.stopped_early += 1
            ctr.abandoned += len(spec["arrivals"])
        cli.close()
        return

    deadline_ms = 30.0 if spec["rt"] == "RT-30" else 60.0
    seq = 0
    hard_stop = t0 + plan.seconds + plan.drain_grace_s
    stopped = False
    for widx, t_arr in enumerate(spec["arrivals"]):
        _sleep_until(t_arr)
        q, valid, boxes = gen.next()
        req = {"session": sid, "seq": seq, "deadline_ms": deadline_ms,
               "q": _b64(q), "valid": _b64(valid), "boxes": _b64(boxes)}
        outcome = None
        for attempt in range(plan.max_attempts):
            if attempt:
                with ctr.lock:
                    ctr.retries += 1
            try:
                st, hdr, body = cli.request("POST", "/v1/window", req)
            except (OSError, http.client.HTTPException):
                with ctr.lock:
                    ctr.transport_errors += 1
                time.sleep(0.05)
                continue
            ctr.count("window_status", st)
            reason = _reason(body)
            if st == 200:
                lat_ms = (now() - (t0 + t_arr)) * 1e3
                with ctr.lock:
                    ctr.served += 1
                    ctr.latency_ms.append(lat_ms)
                bodies[(sid, widx)] = (body["seq"], body["scores_sha256"])
                seq += 1
                outcome = "served"
                break
            if reason:
                ctr.count("reject_reasons", reason)
            if st == 429:
                # shed / rate limit: server rolled the seq back; honour
                # the hint and retry the same seq (bit-safe)
                if now() > hard_stop:
                    outcome = "gave_up"
                    break
                time.sleep(min(_retry_hint(hdr, body), 2.0))
                continue
            if st == 503 and reason in ("deadline", "recovering"):
                # deadline: the engine holds this window; retrying the
                # SAME seq collects the parked result. recovering: the
                # supervisor is replaying; back off and retry.
                if now() > hard_stop:
                    outcome = "abandoned"
                    break
                time.sleep(min(_retry_hint(hdr, body), 2.0))
                continue
            if st == 503 and reason in ("draining", "engine_dead"):
                outcome = "stopped"
                break
            with ctr.lock:
                ctr.anomalies.append(
                    ("window", st, reason if reason else repr(body)[:200]))
            outcome = "lost"
            break
        if outcome is None:
            outcome = "gave_up"     # retry budget exhausted on 429s
        if outcome == "gave_up":
            with ctr.lock:
                ctr.gave_up += 1
        elif outcome == "abandoned":
            with ctr.lock:
                ctr.abandoned += 1
        elif outcome == "lost":
            with ctr.lock:
                ctr.lost += 1
        elif outcome == "stopped":
            with ctr.lock:
                ctr.stopped_early += 1
                ctr.abandoned += len(spec["arrivals"]) - widx - 1
            stopped = True
            break
    if not stopped:
        try:
            st, _, _ = cli.request("DELETE", f"/v1/session/{sid}")
            ctr.count("session_status", st)
        except (OSError, http.client.HTTPException):
            with ctr.lock:
                ctr.transport_errors += 1
    cli.close()


def _parse_prom(text: str) -> dict:
    """``{(name, (sorted label items)): value}`` from exposition text."""
    out = {}
    for line in text.splitlines():
        if not line or line.startswith("#"):
            continue
        m = re.match(r"^([A-Za-z_:][\w:]*)(?:\{(.*)\})?\s+(\S+)$", line)
        if not m:
            continue
        name, labels, value = m.group(1), m.group(2) or "", m.group(3)
        pairs = tuple(sorted(
            (k, v) for k, v in re.findall(r'(\w+)="((?:[^"\\]|\\.)*)"',
                                          labels)))
        out[(name, pairs)] = float(value)
    return out


def _reconcile(host: str, port: int, plan: LoadPlan,
               ctr: _Counters) -> dict:
    """Scrape the gateway and diff its window counters vs the client's."""
    cli = _Client(host, port, plan.timeout_s)
    try:
        st, _, raw = cli.request("GET", "/metrics")
    except (OSError, http.client.HTTPException) as e:
        return {"ok": False, "error": f"scrape failed: {e}"}
    finally:
        cli.close()
    if st != 200:
        return {"ok": False, "error": f"scrape status {st}"}
    fams = _parse_prom(raw.decode() if isinstance(raw, bytes) else str(raw))
    server = {}
    for (name, pairs), v in fams.items():
        if name != "torr_gateway_requests_total":
            continue
        d = dict(pairs)
        if d.get("route") == "window":
            server[d["status"]] = server.get(d["status"], 0) + int(v)
    client = {str(k): v for k, v in ctr.window_status.items()
              if k != "transport"}
    # the reconciliation scrape itself must be exact: the server counts
    # every response it wrote, the client every response it read — any
    # transport error breaks the bijection and fails the check
    ok = (server == client) and ctr.transport_errors == 0
    return {"ok": ok, "server": server, "client": client,
            "transport_errors": ctr.transport_errors}


def _percentile(xs: list, p: float) -> float:
    if not xs:
        return float("nan")
    return float(np.percentile(np.asarray(xs), p))


def run_load(host: str, port: int, plan: LoadPlan) -> dict:
    """Drive one full trace against a live gateway; return the report."""
    cli = _Client(host, port, plan.timeout_s)
    st, _, cfg = cli.request("GET", "/v1/config")
    cli.close()
    if st != 200 or not isinstance(cfg, dict):
        raise RuntimeError(f"/v1/config -> {st}: {cfg!r}")
    n_max, words = int(cfg["N_max"]), int(cfg["words"])
    n_tasks = int(cfg.get("n_tasks", 1))

    schedule = make_schedule(plan)
    n_scheduled = sum(len(s["arrivals"]) for s in schedule)
    ctr = _Counters()
    bodies: dict = {}
    t0 = time.monotonic()
    threads = []
    for i, spec in enumerate(schedule):
        th = threading.Thread(
            target=_drive_stream,
            args=(spec, plan, host, port, n_max, words, i % n_tasks, t0,
                  ctr, bodies), name=f"loadgen-{spec['stream']}",
            daemon=True)
        th.start()
        threads.append(th)
    for th in threads:
        th.join(timeout=plan.seconds + plan.drain_grace_s + plan.timeout_s)
    wall = time.monotonic() - t0

    reconcile = _reconcile(host, port, plan, ctr)
    alive = [th.name for th in threads if th.is_alive()]
    report = {
        "plan": dataclasses.asdict(plan),
        "scheduled_windows": n_scheduled,
        "wall_s": round(wall, 2),
        "served": ctr.served,
        "goodput_w_s": round(ctr.served / wall, 2) if wall else 0.0,
        "latency_ms": {
            "p50": round(_percentile(ctr.latency_ms, 50), 2),
            "p90": round(_percentile(ctr.latency_ms, 90), 2),
            "p99": round(_percentile(ctr.latency_ms, 99), 2),
            "max": round(max(ctr.latency_ms), 2) if ctr.latency_ms
            else float("nan"),
        },
        "window_status": {str(k): v for k, v in
                          sorted(ctr.window_status.items(), key=str)},
        "session_status": {str(k): v for k, v in
                           sorted(ctr.session_status.items(), key=str)},
        "reject_reasons": dict(sorted(ctr.reject_reasons.items())),
        "retries": ctr.retries,
        "gave_up": ctr.gave_up,
        "abandoned": ctr.abandoned,
        "lost": ctr.lost,
        "stopped_early": ctr.stopped_early,
        "transport_errors": ctr.transport_errors,
        "anomalies": ctr.anomalies[:20],
        "stuck_threads": alive,
        # every scheduled window reached a terminal, accounted outcome
        # and none vanished: the zero-window-loss acceptance property
        "zero_loss": (ctr.lost == 0 and ctr.abandoned == 0
                      and not ctr.anomalies and not alive
                      and ctr.served + ctr.gave_up == n_scheduled),
        "reconcile": reconcile,
        "bodies": {f"{k[0]}#{k[1]}": list(v) for k, v in
                   sorted(bodies.items())},
    }
    return report


# ---------------------------------------------------------------------------
# spawn mode: drive a real serve.py subprocess over its ephemeral port

_HANDSHAKE = re.compile(r"listening on http://([\d.]+):(\d+)")


def spawn_server(extra_args: list, startup_timeout_s: float = 180.0):
    """Launch ``repro.launch.serve --gateway-port 0`` and parse the port.

    Returns ``(proc, host, port)``; the caller owns SIGTERM + wait."""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(repo, "src"),
                    env.get("PYTHONPATH", "")) if p)
    env.setdefault("PYTHONUNBUFFERED", "1")
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.serve",
         "--gateway-port", "0"] + list(extra_args),
        cwd=repo, env=env, stdout=subprocess.PIPE,
        stderr=subprocess.STDOUT, text=True)
    t_stop = time.monotonic() + startup_timeout_s
    lines = []
    while time.monotonic() < t_stop:
        line = proc.stdout.readline()
        if not line:
            break
        lines.append(line)
        m = _HANDSHAKE.search(line)
        if m:
            return proc, m.group(1), int(m.group(2))
    proc.kill()
    raise RuntimeError("server never printed the gateway handshake:\n"
                       + "".join(lines[-40:]))


def stop_server(proc) -> tuple[int, str]:
    """SIGTERM -> graceful drain; returns (exit_code, output_tail)."""
    proc.send_signal(signal.SIGTERM)
    try:
        out, _ = proc.communicate(timeout=120)
    except subprocess.TimeoutExpired:
        proc.kill()
        out, _ = proc.communicate()
        return -9, out[-4000:] if out else ""
    return proc.returncode, out[-4000:] if out else ""


# ---------------------------------------------------------------------------
# in-process benchmark suite (registered as ``loadgen`` in benchmarks.run)


def run(seconds: float = 6.0) -> list[tuple]:
    """Chaos-under-load smoke: supervised engine + rate-limited gateway,
    one dispatcher death mid-run, measured (not asserted) overload."""
    global _METRICS
    import jax

    from repro.core.item_memory import random_item_memory
    from repro.obs import FlightRecorder, MetricsRegistry
    from repro.runtime.fault import FaultPlan
    from repro.serving.async_engine import AsyncStreamEngine
    from repro.serving.gateway import Gateway, GatewayLimits
    from repro.serving.state_store import InMemoryStateStore
    from repro.serving.supervisor import ServeSupervisor

    from .table6_multistream import CFG as cfg

    plan = LoadPlan(seconds=seconds, streams=6, tenants=3, rate=40.0,
                    burst_factor=8.0, seed=7)
    _METRICS = reg = MetricsRegistry()
    flight = FlightRecorder(2048)
    store = InMemoryStateStore(metrics=reg)
    im = random_item_memory(jax.random.PRNGKey(0), cfg)
    task_w = np.asarray(
        jax.random.uniform(jax.random.PRNGKey(1), (4, cfg.M)), np.float32)
    fault = FaultPlan(at_step=25, thread="dispatcher")
    faults = [fault]    # fire exactly once, on the first engine build

    def make_engine():
        plan_ = faults.pop() if faults else None
        return AsyncStreamEngine(cfg, im, n_slots=plan.streams, paused=True,
                                 store=store, snapshot_every=1,
                                 metrics=reg, flight=flight,
                                 fault_plan=plan_)

    sup = ServeSupervisor(make_engine, store, metrics=reg, flight=flight)
    sup.engine.warmup()
    sup.engine.start()
    limits = GatewayLimits(rate_per_s=25.0, burst=10,
                           request_deadline_s=2.0)
    gw = Gateway(sup, cfg, task_w, limits=limits, metrics=reg,
                 flight=flight, port=0)
    gw.start()
    try:
        report = run_load("127.0.0.1", gw.port, plan)
    finally:
        gw.drain(timeout=10.0)
        gw.close()
        sup.close(drain=False)
    summary = sup.summary()

    # acceptance: the trace survived one worker death with zero window
    # loss, the burst actually tripped the rate limiter, and the server
    # and client ledgers reconcile exactly
    assert summary["restarts"] >= 1, summary
    assert report["zero_loss"], {k: report[k] for k in
                                 ("served", "gave_up", "abandoned", "lost",
                                  "anomalies", "stuck_threads")}
    n_429 = report["window_status"].get("429", 0)
    assert n_429 > 0, report["window_status"]
    assert report["reconcile"]["ok"], report["reconcile"]

    return [
        ("loadgen/goodput_w_s", report["goodput_w_s"],
         f"open-loop replay, {plan.streams} streams / {plan.tenants} "
         f"tenants, 1 dispatcher death"),
        ("loadgen/served", report["served"],
         f"of {report['scheduled_windows']} scheduled"),
        ("loadgen/p99_ms", report["latency_ms"]["p99"],
         "from scheduled arrival (coordinated-omission-safe)"),
        ("loadgen/rejected_429", n_429,
         "rate-limit + shed responses under the burst"),
        ("loadgen/retries", report["retries"],
         "Retry-After-honouring re-sends"),
        ("loadgen/zero_loss", 1,
         "every scheduled window reached a terminal outcome"),
        ("loadgen/reconcile_ok", 1,
         "server torr_gateway_requests_total == client ledger"),
        ("loadgen/restarts", summary["restarts"],
         "supervised engine rebuilds during the drive"),
    ]


# ---------------------------------------------------------------------------
# CLI


def main() -> None:
    ap = argparse.ArgumentParser(
        description="open-loop load/chaos harness for the TorR gateway")
    tgt = ap.add_mutually_exclusive_group(required=True)
    tgt.add_argument("--target", metavar="HOST:PORT",
                     help="drive an already-running gateway")
    tgt.add_argument("--spawn", action="store_true",
                     help="launch repro.launch.serve --gateway-port 0 "
                          "as a subprocess and drive it")
    ap.add_argument("--seconds", type=float, default=8.0)
    ap.add_argument("--streams", type=int, default=6)
    ap.add_argument("--tenants", type=int, default=3)
    ap.add_argument("--rate", type=float, default=40.0,
                    help="aggregate steady-state windows/sec")
    ap.add_argument("--burst-factor", type=float, default=6.0)
    ap.add_argument("--diurnal-amp", type=float, default=0.5)
    ap.add_argument("--churn", type=float, default=0.25)
    ap.add_argument("--rt30-frac", type=float, default=0.4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--max-attempts", type=int, default=10)
    ap.add_argument("--json", default="", metavar="PATH",
                    help="write the full report as JSON")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero unless zero_loss, reconcile_ok and "
                         "a nonzero 429/shed count all hold (CI gate)")
    # spawn-mode server shape
    ap.add_argument("--fault-at", type=int, default=None, metavar="STEP",
                    help="(spawn) inject one worker death at engine step N")
    ap.add_argument("--fault-kind", default="dispatcher",
                    choices=["dispatcher", "collector"])
    ap.add_argument("--server-rate", type=float, default=30.0,
                    help="(spawn) per-tenant token refill rate")
    ap.add_argument("--server-burst", type=int, default=15,
                    help="(spawn) per-tenant bucket depth")
    ap.add_argument("--server-deadline-ms", type=float, default=2000.0)
    ap.add_argument("--server-args", default="", metavar="ARGS",
                    help="(spawn) extra space-separated serve.py flags")
    args = ap.parse_args()

    plan = LoadPlan(seconds=args.seconds, streams=args.streams,
                    tenants=args.tenants, rate=args.rate,
                    burst_factor=args.burst_factor,
                    diurnal_amp=args.diurnal_amp, churn=args.churn,
                    rt30_frac=args.rt30_frac, seed=args.seed,
                    max_attempts=args.max_attempts)

    proc = None
    server = {}
    if args.spawn:
        extra = ["--supervise", "--metrics-port", "0",
                 "--gateway-rate", str(args.server_rate),
                 "--gateway-burst", str(args.server_burst),
                 "--gateway-deadline-ms", str(args.server_deadline_ms)]
        if args.fault_at is not None:
            extra += ["--fault-at", str(args.fault_at),
                      "--fault-kind", args.fault_kind]
        if args.server_args:
            extra += args.server_args.split()
        proc, host, port = spawn_server(extra)
        print(f"[loadgen] spawned gateway pid={proc.pid} "
              f"at {host}:{port}", file=sys.stderr)
    else:
        host, port_s = args.target.rsplit(":", 1)
        port = int(port_s)

    try:
        report = run_load(host, port, plan)
    finally:
        if proc is not None:
            code, tail = stop_server(proc)
            m = re.findall(r"restarts=(\d+)", tail)
            server = {"exit_code": code,
                      "restarts": max((int(x) for x in m), default=0)}
            print(tail, file=sys.stderr)
    if server:
        report["server"] = server

    brief = {k: report[k] for k in
             ("scheduled_windows", "served", "goodput_w_s", "latency_ms",
              "window_status", "reject_reasons", "retries", "gave_up",
              "zero_loss")}
    brief["reconcile_ok"] = report["reconcile"]["ok"]
    print(json.dumps(brief, indent=1))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=1, sort_keys=True)
        print(f"[loadgen] wrote {args.json}", file=sys.stderr)

    if args.check:
        n_429 = report["window_status"].get("429", 0)
        shed = sum(v for k, v in report["reject_reasons"].items()
                   if k in ("shed", "rate_limit", "tenant_quota", "no_slot"))
        failures = []
        if not report["zero_loss"]:
            failures.append("window loss detected")
        if not report["reconcile"]["ok"]:
            failures.append(f"ledger mismatch: {report['reconcile']}")
        if n_429 + shed == 0:
            failures.append("overload never tripped (no 429/shed)")
        if proc is not None and server.get("exit_code") != 0:
            failures.append(f"server exit {server.get('exit_code')}"
                            " (drain failed)")
        if failures:
            print("[loadgen] CHECK FAILED: " + "; ".join(failures),
                  file=sys.stderr)
            sys.exit(1)
        print("[loadgen] CHECK PASSED: zero loss, ledgers reconcile, "
              f"{n_429} x 429 under overload", file=sys.stderr)


if __name__ == "__main__":
    main()
