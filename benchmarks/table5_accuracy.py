"""Table 5: AP@0.5 per task — dense CLIP-proxy vs naive HDC vs TorR.

Synthetic-surrogate reproduction (see DESIGN.md §7): absolute AP is world-
dependent; the reproduced claims are (i) TorR within a bounded margin of the
dense baseline (paper: 75-86% per task), (ii) reuse is accuracy-neutral
(TorR ~= naive HDC despite bypass/delta traffic savings), (iii) coherent
scenes show the smallest gaps.
"""
from __future__ import annotations

import numpy as np

from repro.core.types import TorrConfig
from repro.data import tood_synth as ts
from repro.serving.tood_pipelines import build_system, evaluate_task

PAPER_OURS = {"pour wine": 54.62, "sports": 52.07, "cooking": 46.40,
              "have breakfast": 34.07, "take a rest": 34.17}
PAPER_MEAN = 44.27


def run(n_frames: int = 100, difficulty: float = 1.4) -> list[tuple]:
    world = ts.make_world(0, M=64, d=512, n_tasks=5)
    cfg = TorrConfig(D=8192, B=8, M=64, K=24, N_max=16, delta_budget=2048,
                     feat_dim=512)
    sys_ = build_system(world, cfg)
    rows, aps = [], []
    for t in range(5):
        r = evaluate_task(world, sys_, t, n_frames=n_frames,
                          difficulty=difficulty)
        aps.append([r["ap_dense"], r["ap_naive_hdc"], r["ap_torr"]])
        frac = r["ap_torr"] / max(r["ap_dense"], 1e-9)
        rows.append((
            f"table5/{r['task'].replace(' ', '_')}", round(r["ap_torr"], 2),
            (f"dense={r['ap_dense']:.1f};naive_hdc={r['ap_naive_hdc']:.1f};"
             f"frac_of_dense={frac:.2f};paper_ours={PAPER_OURS[r['task']]};"
             f"mix_byp={r['path_mix']['bypass']:.2f};"
             f"mix_delta={r['path_mix']['delta']:.2f}")))
    m = np.mean(aps, axis=0)
    rows.append(("table5/mean", round(float(m[2]), 2),
                 f"dense={m[0]:.1f};naive={m[1]:.1f};paper_mean={PAPER_MEAN};"
                 f"frac_of_dense={m[2]/max(m[0],1e-9):.2f} (paper 0.75-0.86)"))
    # claim checks: bounded margin + reuse-neutrality
    assert m[2] / m[0] > 0.6, "TorR margin to dense baseline not bounded"
    assert abs(m[2] - m[1]) < 5.0, "reuse is not accuracy-neutral"
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
