"""Benchmark harness: one module per paper table + microbenchmarks.

Prints ``name,value,derived`` CSV rows (value is the table's primary
quantity: mm^2/mW for Table 1, ms for Tables 2-3, FPS for Table 4, AP for
Table 5, cycles/us for micro, seconds for roofline, windows/sec for the
multi-stream Tables 6-7). ``--json PATH`` additionally writes the whole
suite as one JSON document: ``{suite: {"rows": [[name, value, derived],
...], "seconds": s, "ok": bool}}`` — the machine-readable artifact CI and
dashboards diff across commits. Suites instrumented with ``repro.obs``
(table7, table8, chaos, micro) additionally carry a ``"metrics"`` key:
the registry snapshot of the run's serving traffic (see
``docs/observability.md``).

The document also carries a top-level ``"meta"`` key (git SHA, UTC
timestamp, JAX backend, argv) so ``benchmarks/trend.py`` can append the
run to the perf-trend history and gate regressions against the rolling
baseline — workflow in ``docs/observability.md``.
"""
from __future__ import annotations

import argparse
import datetime
import json
import os
import subprocess
import sys
import time
import traceback


def run_meta() -> dict:
    """Provenance stamp for a benchmark artifact: git SHA (``GITHUB_SHA``
    or ``git rev-parse``), UTC timestamp, JAX backend, argv."""
    sha = os.environ.get("GITHUB_SHA", "")
    if not sha:
        try:
            sha = subprocess.run(
                ["git", "rev-parse", "HEAD"], capture_output=True,
                text=True, timeout=10,
                cwd=os.path.dirname(os.path.abspath(__file__)),
            ).stdout.strip()
        except (OSError, subprocess.SubprocessError):
            sha = ""
    try:
        import jax
        backend = jax.default_backend()
    except Exception:  # noqa: BLE001 — provenance must never fail the run
        backend = "unknown"
    return {
        "sha": sha or "unknown",
        "timestamp": datetime.datetime.now(
            datetime.timezone.utc).isoformat(timespec="seconds"),
        "backend": backend,
        "argv": list(sys.argv[1:]),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="", metavar="PATH",
                    help="also write results as JSON to PATH")
    ap.add_argument("--only", default="", metavar="NAMES",
                    help="run a comma-separated subset of suites "
                         "(e.g. table7,table8)")
    args = ap.parse_args()

    from . import (autotune_blocks, chaos_recovery, loadgen, micro_aligner,
                   roofline_summary, table1_hw, table2_envelope,
                   table3_runtime, table4_throughput, table5_accuracy,
                   table6_multistream, table7_async, table8_pareto,
                   torr_reuse_ablation)

    suites = [
        ("table1", table1_hw),
        ("table2", table2_envelope),
        ("table3", table3_runtime),
        ("table4", table4_throughput),
        ("table5", table5_accuracy),
        ("table6", table6_multistream),
        ("table7", table7_async),
        ("table8", table8_pareto),
        ("torr_ablation", torr_reuse_ablation),
        ("chaos", chaos_recovery),
        ("loadgen", loadgen),
        ("micro", micro_aligner),
        ("autotune", autotune_blocks),
        ("roofline", roofline_summary),
    ]
    if args.only:
        names = [n.strip() for n in args.only.split(",") if n.strip()]
        valid = [n for n, _ in suites]
        unknown = set(names) - set(valid)
        if unknown:
            print(f"unknown suite(s) {sorted(unknown)}; "
                  f"valid suites: {', '.join(valid)}", file=sys.stderr)
            sys.exit(2)
        suites = [(n, m) for n, m in suites if n in names]
    failed = []
    report = {"meta": run_meta()}

    def _write_report() -> None:
        if args.json:
            with open(args.json, "w") as f:
                json.dump(report, f, indent=1, sort_keys=True)
            print(f"wrote {args.json}", file=sys.stderr)

    print("name,value,derived")
    try:
        for name, mod in suites:
            t0 = time.time()
            rows = []
            error = None
            try:
                for row in mod.run():
                    rows.append(row)
                    print(",".join(str(x) for x in row), flush=True)
                ok = True
                print(f"{name}/_suite_seconds,{time.time()-t0:.1f},ok",
                      flush=True)
            except Exception:  # noqa: BLE001
                ok = False
                error = traceback.format_exc()
                failed.append(name)
                traceback.print_exc()
                print(f"{name}/_suite_seconds,{time.time()-t0:.1f},FAILED",
                      flush=True)
            report[name] = {"rows": [list(r) for r in rows],
                            "seconds": round(time.time() - t0, 1), "ok": ok}
            if error is not None:
                # keep the partial rows AND the cause: a suite that dies
                # mid-run still contributes everything it measured
                report[name]["error"] = error
            # suites instrumented with repro.obs (table7/table8/micro)
            # expose their registry snapshot for the artifact; a snapshot
            # crash must not discard the suite's rows
            snap_fn = getattr(mod, "metrics_snapshot", None)
            if snap_fn is not None:
                try:
                    snap = snap_fn()
                except Exception:  # noqa: BLE001
                    report[name].setdefault(
                        "error", traceback.format_exc())
                else:
                    if snap is not None:
                        report[name]["metrics"] = snap
    except BaseException:
        # KeyboardInterrupt / SystemExit / MemoryError mid-run: the JSON
        # still lands with every completed suite's rows and an "error"
        # marker instead of being discarded wholesale
        report["error"] = traceback.format_exc()
        _write_report()
        raise
    _write_report()
    if failed:
        print(f"FAILED suites: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
