"""Benchmark harness: one module per paper table + microbenchmarks.

Prints ``name,value,derived`` CSV rows (value is the table's primary
quantity: mm^2/mW for Table 1, ms for Tables 2-3, FPS for Table 4, AP for
Table 5, cycles/us for micro, seconds for roofline).
"""
from __future__ import annotations

import sys
import time
import traceback


def main() -> None:
    from . import (micro_aligner, roofline_summary, table1_hw,
                   table2_envelope, table3_runtime, table4_throughput,
                   table5_accuracy, table6_multistream, torr_reuse_ablation)

    suites = [
        ("table1", table1_hw.run),
        ("table2", table2_envelope.run),
        ("table3", table3_runtime.run),
        ("table4", table4_throughput.run),
        ("table5", table5_accuracy.run),
        ("table6", table6_multistream.run),
        ("torr_ablation", torr_reuse_ablation.run),
        ("micro", micro_aligner.run),
        ("roofline", roofline_summary.run),
    ]
    failed = []
    print("name,value,derived")
    for name, fn in suites:
        t0 = time.time()
        try:
            for row in fn():
                print(",".join(str(x) for x in row), flush=True)
            print(f"{name}/_suite_seconds,{time.time()-t0:.1f},ok", flush=True)
        except Exception:  # noqa: BLE001
            failed.append(name)
            traceback.print_exc()
            print(f"{name}/_suite_seconds,{time.time()-t0:.1f},FAILED",
                  flush=True)
    if failed:
        print(f"FAILED suites: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
