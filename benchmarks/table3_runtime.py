"""Table 3: per-task runtime/power/energy at RT-60/RT-30 (cycle model)."""
from __future__ import annotations

from repro.perf.cycle_model import simulate_all

PAPER = {
    "RT-60": {"pour wine": (9.4, 11.3, 1.9, 3.20, 53),
              "sports": (9.8, 11.9, 2.1, 3.22, 54),
              "cooking": (8.7, 10.6, 1.9, 3.12, 51),
              "have breakfast": (7.9, 9.4, 1.5, 3.05, 50),
              "take a rest": (8.1, 9.7, 1.6, 3.06, 50)},
    "RT-30": {"pour wine": (17.2, 19.9, 2.7, 3.50, 116),
              "sports": (17.8, 20.6, 2.8, 3.52, 117),
              "cooking": (16.5, 18.8, 2.3, 3.40, 113),
              "have breakfast": (15.1, 17.3, 2.2, 3.32, 110),
              "take a rest": (15.4, 17.6, 2.2, 3.33, 110)},
}


def run(n_frames: int = 400) -> list[tuple]:
    rows = []
    for rt in ("RT-60", "RT-30"):
        budget = 1000.0 / (60 if rt == "RT-60" else 30)
        for r in simulate_all(rt, n_frames=n_frames):
            p = PAPER[rt][r["task"]]
            rows.append((
                f"table3/{rt}/{r['task'].replace(' ', '_')}",
                r["median_ms"],
                (f"p95={r['p95_ms']:.1f};jit={r['jitter_ms']:.1f};"
                 f"head={r['headroom_ms']:.1f};P={r['power_w']:.2f}W;"
                 f"E={r['energy_mj']:.0f}mJ;"
                 f"paper_med={p[0]};paper_p95={p[1]};paper_P={p[3]};paper_E={p[4]}")))
            assert r["p95_ms"] < budget, (rt, r["task"], r["p95_ms"])
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
