"""Table 8 (beyond paper): governor on/off Pareto sweep under a load ramp.

Drives the *actual* closed-loop QoS governor (``repro.control``) through the
cycle-accurate model of the paper's accelerator (``repro.perf.cycle_model``)
on a synthetic load ramp — light traffic, a steep climb to N_max proposals,
then sustained overload — and compares three operating modes per RT target:

  * ``full``     — always-full D' (banks=B, all bit planes): no gating at
    all; the energy ceiling.
  * ``static``   — the deployment-time configuration the repo had before
    the control plane: D' solved *once* against the nominal (ramp-start)
    load via the shared Sec. 4.3 cost helper, then held fixed. Misses
    deadlines once the ramp exceeds its design point.
  * ``governor`` — the closed loop: projected slack + backlog + EWMA
    energy pick a knob plan per window (bank cap, bit-slice precision,
    tau offsets); hysteresis widens D' back out when the ramp relaxes.
    ``governor+e`` additionally arms the energy budget at the paper's
    operating point (~50 mJ @ RT-60, ~113 mJ @ RT-30).

Latency follows a work-conserving single server: windows arrive on the
frame period, backlog carries over, and a window's latency is its queue
wait plus modeled service time. Energy is the cycle model's frame-locked
mJ/window (duty-cycled block powers at the D' each window actually ran).

Rows: ``table8/<rt>_<mode>, <mJ/window>, miss_rate=..|p99_ms=..|
banks=..|planes=..`` plus the two paper operating-point rows. The
acceptance claim (ISSUE 3) reads off directly: under the ramp, ``static``
misses deadlines, ``governor`` holds miss_rate ~0 at lower mJ than
``full``.
"""
from __future__ import annotations

import numpy as np

from repro.configs.torr_edge import rt_budget_s, torr_edge
from repro.control import Governor, GovernorPolicy, full_plan
from repro.core.policy import window_cycles_deff
from repro.core.types import PATH_BYPASS
from repro.perf.cycle_model import (ENCODER_CYCLES_PER_PROPOSAL,
                                    HOST_OVERHEAD_CYCLES, path_mix,
                                    window_cost)

PAPER_MJ = {"RT-60": 50.0, "RT-30": 113.0}
N_NOMINAL = 80   # the static config's design-point load (ramp-start mean)


def _ramp(n_frames: int, n_max: int, rng) -> np.ndarray:
    """Proposal counts: nominal third, steep climb, sustained overload."""
    third = n_frames // 3
    nominal = rng.normal(N_NOMINAL, 6, third)
    climb = np.linspace(N_NOMINAL, n_max, third) + rng.normal(0, 4, third)
    peak = rng.normal(0.97 * n_max, 3, n_frames - 2 * third)
    return np.clip(np.concatenate([nominal, climb, peak]), 4, n_max).astype(int)


def _static_banks(cfg, n_nominal: int, window_scale: float) -> int:
    """Deployment-time D' solve at the design-point load: the largest banks
    whose worst (all-full) window — shared Sec. 4.3 aligner math plus the
    fixed encoder/host overheads — fits the budget. Solved once, held
    forever: exactly the static knob the repo had before the control plane."""
    fixed = (n_nominal * ENCODER_CYCLES_PER_PROPOSAL
             + HOST_OVERHEAD_CYCLES) * window_scale
    for b in range(cfg.B, 0, -1):
        worst = window_cycles_deff(n_nominal, 0, b * cfg.bank_dims, cfg)
        if worst + fixed <= cfg.cycles_per_window_budget:
            return b
    return 1


# registry fed by the governed simulate() runs of the last run() sweep
# (ladder level / energy-EWMA gauges, plan-switch counter); embedded in
# the JSON artifact via metrics_snapshot()
_METRICS = None


def metrics_snapshot():
    """Metrics of the last run() sweep, for the JSON artifact."""
    return _METRICS.snapshot() if _METRICS is not None else None


def simulate(rt: str, mode: str, n_frames: int = 240, seed: int = 0,
             energy_budget_mj: float | None = None, metrics=None) -> dict:
    """One mode's trip through the load ramp; cycle-model-priced."""
    cfg = torr_edge(rt)
    budget = rt_budget_s(rt)
    window_scale = 60.0 * budget           # 1.0 @ RT-60, 2.0 @ RT-30
    rng = np.random.default_rng(seed)
    ns = _ramp(n_frames, cfg.N_max, rng)

    gov = None
    if mode == "governor":
        gov = Governor(cfg, GovernorPolicy(
            budget_s=budget, energy_budget_mj=energy_budget_mj),
            metrics=metrics)
    static_b = _static_banks(cfg, N_NOMINAL, window_scale)

    plan = full_plan(cfg)
    backlog_s = 0.0
    step_ema = 0.0
    lat, energy, banks_hist, planes_hist = [], [], [], []
    for n in ns:
        backlog_w = int(np.ceil(backlog_s / budget))
        if gov is not None:
            plan = gov.update(budget - backlog_s, step_ema,
                              backlog=backlog_w)

        if mode == "full":
            banks, planes = cfg.B, cfg.bit_planes
        elif mode == "static":
            banks, planes = static_b, cfg.bit_planes
        else:
            banks, planes = plan.banks, plan.planes
        d_eff = int(cfg.d_eff_planned(banks, planes))
        ecfg = plan.thresholds(cfg) if gov is not None else cfg

        # temporally coherent traffic whose *churn* (new objects: low rho,
        # full path) climbs with load — the clutter that makes the ramp a
        # ramp: at the peak most proposals need a full D'-wide scan
        rho = np.clip(rng.normal(0.88, 0.05, n), -1, 1)
        churn = 0.05 + 0.65 * (n / cfg.N_max) ** 2
        new_obj = rng.random(n) < churn
        rho = np.where(new_obj, rng.uniform(-0.1, 0.4, n), rho)
        delta = np.round((1 - rho) / 2 * d_eff).astype(int)
        high = n >= ecfg.N_hi or backlog_w >= ecfg.q_hi
        path = path_mix(rho, delta, bool(high), ecfg)
        reasoner = (path != PATH_BYPASS) & (rho < 0.97)

        wc = window_cost(path, delta, banks, reasoner, int(n), cfg, budget,
                         window_scale=window_scale, d_eff=d_eff)
        t_win = wc.total_cycles / cfg.clock_hz
        lat.append(backlog_s + t_win)        # queue wait + service
        backlog_s = max(0.0, backlog_s + t_win - budget)
        step_ema = t_win if step_ema <= 0 else 0.75 * step_ema + 0.25 * t_win
        energy.append(wc.energy_j * 1e3)
        banks_hist.append(banks)
        planes_hist.append(planes)
        if gov is not None:
            gov.observe_energy(wc.energy_j * 1e3)

    lat = np.asarray(lat)
    out = {
        "rt": rt, "mode": mode,
        "miss_rate": float(np.mean(lat > budget)),
        "p99_ms": float(np.percentile(lat, 99) * 1e3),
        "energy_mj": float(np.mean(energy)),
        "banks_mean": float(np.mean(banks_hist)),
        "planes_mean": float(np.mean(planes_hist)),
    }
    if gov is not None:
        out["plan_switches"] = gov.switches
    return out


def run(n_frames: int = 240) -> list[tuple]:
    global _METRICS
    from repro.obs import MetricsRegistry
    _METRICS = reg = MetricsRegistry()
    rows = []
    for rt in ("RT-60", "RT-30"):
        results = {}
        for mode, ebudget in (("full", None), ("static", None),
                              ("governor", None),
                              ("governor+e", PAPER_MJ[rt])):
            r = simulate(rt, mode.replace("+e", "") if "+e" in mode
                         else mode, n_frames=n_frames,
                         energy_budget_mj=ebudget, metrics=reg)
            results[mode] = r
            derived = (f"miss_rate={r['miss_rate']:.3f}"
                       f"|p99_ms={r['p99_ms']:.2f}"
                       f"|banks={r['banks_mean']:.2f}"
                       f"|planes={r['planes_mean']:.2f}")
            if "plan_switches" in r:
                derived += f"|switches={r['plan_switches']}"
            rows.append((f"table8/{rt}_{mode}", round(r["energy_mj"], 1),
                         derived))
        # the paper's operating point is the mJ-budgeted deployment: the
        # governor pinned to the paper's energy target at that RT rate
        rows.append((
            f"table8/operating_point_{rt}",
            round(results["governor+e"]["energy_mj"], 1),
            f"paper ~{PAPER_MJ[rt]:.0f} mJ @ {rt[3:]} FPS",
        ))
    return rows


if __name__ == "__main__":
    for row in run():
        print(",".join(str(x) for x in row))
