"""Microbenchmarks: full vs delta vs bypass aligner paths (Sec. 4.3 claims).

Measures (a) modeled accelerator cycles — the paper's cycles_full ~= D'*M/W
vs cycles_delta ~= |Delta|*M/W scaling, (b) wall-clock of the jitted
functional kernels on this host (interpret-mode Pallas + XLA), (c) the
bank-gating (D') sweep, and (d) the three-way full-path comparison at the
table6 default shapes:

  * ``fullpath_oracle``  — the legacy jitted full path: one masked
    ``aligner.full_dot`` ([M, W] xor) per proposal inside a scan;
  * ``fullpath_batched`` — the host-latched static-banks kernel wrapper
    (``ops.packed_similarity``) over the whole proposal batch;
  * ``fullpath_fused``   — the traced-banks fused dispatch the jitted
    pipeline now defaults to (``aligner.full_scores_all``), in both the
    ``switch`` and ``prefix`` lowerings.

The fused-vs-oracle ratio is a CPU acceptance gate (>= 1.3x at the table6
shapes), and (e) the reuse-mix sweep (``--reuse-mix 0,0.5,0.9,0.99``):
synthetic traces at fixed bypass/delta/full ratios, comparing the
always-hoisted ``prefix`` scan against the reuse-aware ``compact``
dispatch at both the full-path-dispatch and end-to-end-step level (see
``reuse_mix_rows``) — the ISSUE 5 acceptance gate is compact >= 1.3x
prefix dispatch windows/sec at mix 0.9, S = 64, on CPU. The same sweep
also reports step-level windows/sec for the compact dispatch under the
*sequential* vs *batched* decide pass (``decide="scan"`` vs
``"batched"``): the ISSUE 6 acceptance gate is batched >= 3x the
sequential-decide baseline at mix 0.9, S = 64, M = 1024, on CPU. Finally
(f) the observability overhead gate (``--obs-overhead``, see
``obs_overhead_rows``): the same step-level drive with a live
``repro.obs`` metrics registry + flight recorder attached must stay
within 3% windows/sec of the bare drive (ISSUE 7 acceptance, asserted
in-benchmark). ``python -m benchmarks.micro_aligner --json PATH`` writes
``{"rows": [[name, value, derived], ...]}`` for the bench-smoke CI
artifact; rows are also printed as CSV either way.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aligner, hdc, pipeline, policy
from repro.core.item_memory import random_item_memory, word_mask
from repro.core.types import PATH_BYPASS, PATH_DELTA, PATH_FULL, TorrConfig
from repro.kernels import ops

# the table6 multi-stream serving shapes — the fused-path acceptance point
# (imported so a table6 retune moves this gate with it)
from benchmarks.table6_multistream import CFG as TABLE6_CFG


def _time(fn, *args, iters: int = 20):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def fullpath_three_way(cfg: TorrConfig = TABLE6_CFG, n_streams: int = 64,
                       iters: int = 30):
    """Rows for oracle vs batched-kernel vs fused-path.

    Measured on the flattened S x N_max proposal batch of one multi-stream
    step (the default serving substrate since PR 1) — the shape at which
    the fused dispatch is actually invoked by ``torr_multi_stream_step``.
    All four variants are verified to produce identical integer
    accumulators before timing; times are best-of-5 rounds.
    """
    im = random_item_memory(jax.random.PRNGKey(0), cfg)
    n_rows = n_streams * cfg.N_max
    qp = hdc.pack_bits(hdc.random_hv(jax.random.PRNGKey(1),
                                     (n_rows, cfg.D)))
    banks_t = jnp.int32(cfg.B)

    # (1) legacy oracle: one masked full_dot per proposal inside a scan,
    # traced banks — exactly the full path the jitted pipeline ran before
    # the fused dispatch landed.
    @jax.jit
    def oracle(q, banks):
        wm = word_mask(cfg, banks)

        def body(c, qr):
            return c, aligner.full_dot(qr, im, wm)

        _, accs = jax.lax.scan(body, jnp.int32(0), q)
        return accs

    # (1b) batched oracle: ref-style whole-batch xor — materializes the
    # [N, M, W] intermediate the fused path exists to kill.
    @jax.jit
    def oracle_batched(q, banks):
        wm = word_mask(cfg, banks)
        x = jnp.bitwise_xor(q[:, None, :], im.packed[None, :, :])
        pc = jnp.where(wm[None, None, :],
                       jax.lax.population_count(x).astype(jnp.int32), 0)
        return 32 * jnp.sum(wm.astype(jnp.int32)) - 2 * jnp.sum(pc, -1)

    # (2) host-latched batched kernel wrapper (static banks).
    batched = jax.jit(lambda q: ops.packed_similarity(
        q, im.packed, banks=cfg.B, bank_words=cfg.bank_words)[0])

    # (3) traced-banks fused dispatch (what the jitted step now runs).
    def fused(mode):
        @jax.jit
        def f(q, banks):
            return aligner.full_scores_all(
                q, im, banks, cfg, planes=cfg.bit_planes, cap=cfg.B,
                mode=mode)
        return f

    f_switch, f_prefix = fused("switch"), fused("prefix")

    # sanity: all variants produce identical integer accumulators
    want = np.asarray(oracle(qp, banks_t))
    for name, got in (("oracle_batched", oracle_batched(qp, banks_t)),
                      ("batched", batched(qp)),
                      ("switch", f_switch(qp, banks_t)),
                      ("prefix", f_prefix(qp, banks_t))):
        assert np.array_equal(np.asarray(got), want), name

    def best_of(fn, rounds=5):
        return min(_time(fn, iters=iters) for _ in range(rounds))

    us_oracle = best_of(lambda: oracle(qp, banks_t))
    us_oracle_b = best_of(lambda: oracle_batched(qp, banks_t))
    us_batched = best_of(lambda: batched(qp))
    us_switch = best_of(lambda: f_switch(qp, banks_t))
    us_prefix = best_of(lambda: f_prefix(qp, banks_t))

    shape = f"N{n_rows}_M{cfg.M}_D{cfg.D}"
    best_fused = min(us_switch, us_prefix)
    return [
        (f"micro/fullpath_oracle_{shape}", round(us_oracle, 1), "us"),
        (f"micro/fullpath_oracle_batched_{shape}", round(us_oracle_b, 1),
         "us (materializes [N,M,W])"),
        (f"micro/fullpath_batched_{shape}", round(us_batched, 1),
         f"speedup_vs_oracle={us_oracle / us_batched:.2f}"),
        (f"micro/fullpath_fused_switch_{shape}", round(us_switch, 1),
         f"speedup_vs_oracle={us_oracle / us_switch:.2f}"),
        (f"micro/fullpath_fused_prefix_{shape}", round(us_prefix, 1),
         f"speedup_vs_oracle={us_oracle / us_prefix:.2f}"),
        (f"micro/fullpath_fused_speedup_{shape}",
         round(us_oracle / best_fused, 2), "acceptance: >= 1.3"),
    ]


# --- reuse-mix sweep: compact vs always-hoisted dispatch --------------------

# serving-shaped config for the reuse sweep: the paper's edge class count
# (M = 1024) so the full scan is serving-scale, and K >= N_max so a window
# cannot thrash its own cache out of reuse range
REUSE_CFG = TorrConfig(D=2048, B=8, M=1024, K=16, N_max=16,
                       delta_budget=128)


def _mix_trace(cfg: TorrConfig, mix: float, S: int, T: int, seed: int = 0,
               numpy: bool = False):
    """S streams x (T+1) windows at a fixed bypass/delta/full mix.

    Window 0 is the cold-cache warm-up (all full). From window 1 on, each
    proposal independently keeps its previous query exactly (rho = 1 ->
    bypass under the pinned high load), flips D/32 dims (rho = 0.9375 ->
    delta at any dimension) or resamples fresh (rho ~0 -> full), with
    probabilities mix/2, mix/2, 1 - mix. Queue depth is pinned at q_hi so
    the bypass gate H(N, q) is open; the *achieved* mix is measured from
    telemetry (LRU evictions pull a few intended hits back to full at
    middle mixes). The single reuse-mix synthesizer — the compact-dispatch
    bit-identity tests drive the same traces (``numpy=True`` returns host
    arrays for the engine submit path).
    """
    rng = np.random.default_rng(seed)
    n_flip = max(1, cfg.D // 32)
    base = (rng.integers(0, 2, (S, cfg.N_max, cfg.D)) * 2 - 1).astype(np.int8)
    valid = np.ones((S, cfg.N_max), bool)
    boxes = np.zeros((S, cfg.N_max, 4), np.float32)
    qd = np.full((S,), cfg.q_hi, np.int32)
    windows = []
    for t in range(T + 1):
        if t:
            r = rng.random((S, cfg.N_max))
            for s in range(S):
                for n in range(cfg.N_max):
                    if r[s, n] < mix / 2:
                        continue                              # bypass
                    if r[s, n] < mix:                         # delta
                        flips = rng.choice(cfg.D, n_flip, replace=False)
                        base[s, n, flips] *= -1
                    else:                                     # full
                        base[s, n] = (rng.integers(0, 2, cfg.D) * 2
                                      - 1).astype(np.int8)
        q = np.asarray(jax.vmap(hdc.pack_bits)(jnp.asarray(base)))
        win = (q, valid.copy(), boxes, qd)
        windows.append(win if numpy else
                       tuple(jnp.asarray(x) for x in win))
    return windows


def reuse_mix_rows(mixes=(0.0, 0.5, 0.9, 0.99), cfg: TorrConfig = REUSE_CFG,
                   n_streams: int = 64, n_windows: int = 10,
                   rounds: int = 3) -> list[tuple]:
    """Compact vs always-hoisted full-path dispatch at fixed reuse mixes.

    Two row families per mix, both on the same trace:

      * ``*_dispatch_*`` — the full-path *scoring dispatch* alone (this
        module's genre, like ``fullpath_three_way``): producing each
        window's full-path accumulators via the always-hoisted prefix pass
        over all S x N_max rows vs the compacted bucket at the oracle tier
        (smallest ladder capacity holding the trace's worst window — what
        a perfect ``fused="auto"`` dispatcher latches). This isolates the
        paper's memory-traffic claim — hits *skip* the scan — and carries
        the ISSUE 5 acceptance gate (>= 1.3x at mix 0.9, S = 64, CPU).
      * ``*_step_*`` — the end-to-end jitted multi-stream step under each
        lowering. The ``decide_scan`` row pins the sequential reference
        pipeline end-to-end (per-proposal decide FSM + per-proposal apply
        scan — the step as it stood before the batched decide), while
        ``decide_batched`` is the compact default: batched decide plus the
        batched apply (``pipeline._apply_pass_batched``), which hoists the
        Eq. 6 corrections into one dense matmul and the reasoner top-k
        into one dispatch-wide pass. This is the ISSUE 6 step-level gate
        (>= 3x at mix 0.9, S = 64, M = 1024, CPU): the sequential FSM
        machinery used to floor every lowering at ~0.6 s/step on CPU; the
        batched pipeline is the first to break that floor.
    """
    im = random_item_memory(jax.random.PRNGKey(0), cfg)
    task_w = jax.random.uniform(jax.random.PRNGKey(1), (n_streams, cfg.M))
    step = jax.jit(pipeline.torr_multi_stream_step,
                   static_argnames=("cfg", "serial", "plan", "fused",
                                    "bucket_cap", "decide"))
    R = n_streams * cfg.N_max
    rows = []
    for mix in mixes:
        windows = _mix_trace(cfg, mix, n_streams, n_windows)
        warm, timed = windows[0], windows[1:]

        def drive(fused, bucket_cap=None, collect=False, decide=None):
            st = pipeline.init_multi_stream_state(cfg, task_w)
            st, _, _ = step(st, im, *warm, cfg, fused=fused,
                            bucket_cap=bucket_cap, decide=decide)
            tels = []
            for q, v, b, qd in timed:
                st, _out, tel = step(st, im, q, v, b, qd, cfg, fused=fused,
                                     bucket_cap=bucket_cap, decide=decide)
                if collect:
                    tels.append(tel)
            jax.block_until_ready(st.cache.age)
            return st, tels

        # reference drive: achieved mix, per-window path vectors, and the
        # oracle bucket tier
        _, tels = drive("prefix", collect=True)
        paths = np.stack([np.asarray(t.path) for t in tels])
        frac = {p: float(np.mean(paths == p))
                for p in (PATH_BYPASS, PATH_DELTA, PATH_FULL)}
        max_full = max(int(np.sum(p == PATH_FULL)) for p in paths)
        tier = policy.bucket_tier(R, max(max_full, 1))

        # sanity: compact at the chosen tier is bit-identical to prefix
        st_p, _ = drive("prefix")
        st_c, _ = drive("compact", tier)
        for a, b in zip(jax.tree_util.tree_leaves(st_p.cache),
                        jax.tree_util.tree_leaves(st_c.cache)):
            assert np.array_equal(np.asarray(a), np.asarray(b)), mix

        def best_of(fn):
            fn()                               # compile outside the timing
            best = float("inf")
            for _ in range(rounds):
                t0 = time.perf_counter()
                fn()
                best = min(best, time.perf_counter() - t0)
            return best

        n_win = n_streams * len(timed)
        t_sprefix = best_of(lambda: drive("prefix"))
        # compact's decide default IS "batched"; time the sequential-decide
        # baseline separately for the ISSUE 6 step-level gate
        t_scompact = best_of(lambda: drive("compact", tier))
        t_sscan = best_of(lambda: drive("compact", tier, decide="scan"))

        # dispatch-only: the recorded path vectors replay through the two
        # full-path scoring dispatches (what the decide pass hands them)
        qs = [w[0].reshape(R, cfg.words) for w in timed]
        masks = [jnp.asarray(p == PATH_FULL).reshape(R) for p in paths]
        banks_rows = jnp.full((R,), cfg.B, jnp.int32)
        prefix_fn = jax.jit(lambda q, banks: aligner.full_scores_all(
            q, im, banks, cfg, planes=cfg.bit_planes, cap=cfg.B,
            mode="prefix"))
        compact_fn = jax.jit(lambda q, m: aligner.compact_full_scores(
            q, m, banks_rows, im, cfg, planes=cfg.bit_planes, cap=cfg.B,
            bucket_cap=tier))

        def d_prefix():
            for q in qs:
                r = prefix_fn(q, jnp.int32(cfg.B))
            jax.block_until_ready(r)

        def d_compact():
            for q, m in zip(qs, masks):
                r = compact_fn(q, m)
            jax.block_until_ready(r)

        t_dprefix = best_of(d_prefix)
        t_dcompact = best_of(d_compact)

        tag = f"S{n_streams}_mix{mix}"
        rows.extend([
            (f"micro/reuse_{tag}_achieved", round(frac[PATH_FULL], 3),
             f"bypass={frac[PATH_BYPASS]:.2f},delta={frac[PATH_DELTA]:.2f},"
             f"full={frac[PATH_FULL]:.2f}"),
            (f"micro/reuse_{tag}_dispatch_prefix_wps",
             round(n_win / t_dprefix, 1),
             "windows/sec, full-path dispatch (always-hoisted scan)"),
            (f"micro/reuse_{tag}_dispatch_compact_wps",
             round(n_win / t_dcompact, 1),
             f"tier={tier};speedup_vs_prefix={t_dprefix / t_dcompact:.2f}"
             + (";acceptance: >= 1.3" if mix == 0.9 else "")),
            (f"micro/reuse_{tag}_step_prefix_wps",
             round(n_win / t_sprefix, 1),
             "windows/sec, end-to-end step (FSM-machinery-bound on CPU)"),
            (f"micro/reuse_{tag}_step_compact_wps",
             round(n_win / t_scompact, 1),
             f"tier={tier};speedup_vs_prefix={t_sprefix / t_scompact:.2f}"),
            (f"micro/reuse_{tag}_step_decide_scan_wps",
             round(n_win / t_sscan, 1),
             "windows/sec, compact step, sequential decide FSM"),
            (f"micro/reuse_{tag}_step_decide_batched_wps",
             round(n_win / t_scompact, 1),
             f"speedup_vs_scan={t_sscan / t_scompact:.2f}"
             + (";acceptance: >= 3.0" if mix == 0.9 else "")),
        ])
    return rows


# --- observability overhead gate -------------------------------------------

# registry snapshot of the last instrumented obs_overhead drive; embedded
# in the JSON artifact (benchmarks.run and --json) via metrics_snapshot()
_METRICS_SNAPSHOT = None


def metrics_snapshot():
    """Metrics of the last instrumented run, for the JSON artifact."""
    return _METRICS_SNAPSHOT


def obs_overhead_rows(cfg: TorrConfig = REUSE_CFG, n_streams: int = 64,
                      n_windows: int = 10, rounds: int = 3) -> list[tuple]:
    """Per-step observability overhead on the serving-shaped compact drive.

    Times the mix-0.9 step-level drive (S = 64, M = 1024 — the ISSUE 6
    gate's shape) twice: bare, and with a live ``repro.obs`` stack (metrics
    registry + flight recorder + ``StepObserver``) *plus* write-through
    state-store snapshots (``snapshot_every=1``, every stream every step —
    the worst-case externalization cadence) folded exactly the way the
    sync engine folds it — deferred one step behind dispatch, so the host
    never blocks on in-flight device work, with the final drain inside
    the timed region (the engine pays it at ``summary()``). The ISSUE 7
    acceptance gate is overhead <= 3% windows/sec, asserted here so CI
    bench-smoke fails loudly if instrumentation (or snapshotting) creeps
    onto the hot path.
    """
    from collections import deque

    from repro.obs import FlightRecorder, MetricsRegistry, StepObserver
    from repro.serving import state_store as ss

    im = random_item_memory(jax.random.PRNGKey(0), cfg)
    task_w = jax.random.uniform(jax.random.PRNGKey(1), (n_streams, cfg.M))
    step = jax.jit(pipeline.torr_multi_stream_step,
                   static_argnames=("cfg", "serial", "plan", "fused",
                                    "bucket_cap", "decide"))
    R = n_streams * cfg.N_max
    windows = _mix_trace(cfg, 0.9, n_streams, n_windows)
    warm, timed = windows[0], windows[1:]

    # oracle tier for the trace, same as reuse_mix_rows
    st = pipeline.init_multi_stream_state(cfg, task_w)
    st, _, _ = step(st, im, *warm, cfg, fused="prefix")
    max_full = 1
    for q, v, b, qd in timed:
        st, _o, tel = step(st, im, q, v, b, qd, cfg, fused="prefix")
        max_full = max(max_full, int(np.sum(np.asarray(tel.path) == PATH_FULL)))
    tier = policy.bucket_tier(R, max_full)

    def drive(obs, store=None):
        st = pipeline.init_multi_stream_state(cfg, task_w)
        st, _, _ = step(st, im, *warm, cfg, fused="compact", bucket_cap=tier)
        backlog = deque()
        for t, (q, v, b, qd) in enumerate(timed):
            st, _out, tel = step(st, im, q, v, b, qd, cfg, fused="compact",
                                 bucket_cap=tier)
            if obs is not None:
                rec = obs.on_dispatch(n_streams, 0,
                                      requested=("compact", tier, None))
                # the engine's lazy per-slot snapshot slices ride the same
                # deferred fold as the telemetry (cadence 1: every stream)
                snaps = None
                if store is not None:
                    snaps = [ss.snapshot_rows(st, s, f"stream{s}", t + 1,
                                              {"engine": "bench"})
                             for s in range(n_streams)]
                backlog.append((tel, rec, snaps))
                # the sync engine's deferred fold: everything but the
                # newest (possibly in-flight) step
                while len(backlog) > 1:
                    tel0, rec0, sn0 = backlog.popleft()
                    obs.observe_step(
                        jax.tree_util.tree_map(np.asarray, tel0), rec0)
                    memo = {}
                    for pending in sn0 or ():
                        store.put(ss.materialize_snapshot(pending, memo))
        jax.block_until_ready(st.cache.age)
        while backlog:                         # flush_telemetry()
            tel0, rec0, sn0 = backlog.popleft()
            obs.observe_step(jax.tree_util.tree_map(np.asarray, tel0), rec0)
            memo = {}
            for pending in sn0 or ():
                store.put(ss.materialize_snapshot(pending, memo))

    # interleave base/obs rounds so slow host drift (the drives are ~1 s
    # each) cancels instead of biasing one arm; best-of over rounds
    drive(None)                                # compile / warm caches
    t_base = t_obs = float("inf")
    obs = store = None
    for _ in range(rounds):
        t0 = time.perf_counter()
        drive(None)
        t_base = min(t_base, time.perf_counter() - t0)
        obs = StepObserver(MetricsRegistry(), FlightRecorder())
        store = ss.InMemoryStateStore(metrics=obs.registry)
        t0 = time.perf_counter()
        drive(obs, store)
        t_obs = min(t_obs, time.perf_counter() - t0)

    # the instrumented drive must have actually observed every step and
    # written through every snapshot (cadence 1: one per stream per step)
    snap = obs.registry.snapshot()
    n_steps = snap["torr_steps_total"]["series"][0]["value"]
    assert n_steps == len(timed), (n_steps, len(timed))
    assert len(obs.flight.records()) == len(timed)
    assert all("telemetry" in r for r in obs.flight.records())
    assert len(store.keys()) == n_streams
    assert store.latest_seq("stream0") == len(timed)
    global _METRICS_SNAPSHOT
    _METRICS_SNAPSHOT = snap

    n_win = n_streams * len(timed)
    pct = (t_obs - t_base) / t_base * 100.0
    rows = [
        (f"micro/obs_overhead_S{n_streams}_mix0.9_base_wps",
         round(n_win / t_base, 1), "windows/sec, compact step, no obs"),
        (f"micro/obs_overhead_S{n_streams}_mix0.9_obs_wps",
         round(n_win / t_obs, 1),
         "windows/sec, metrics+flight+state-store snapshots "
         "(deferred fold, snapshot_every=1)"),
        (f"micro/obs_overhead_S{n_streams}_mix0.9_pct", round(pct, 2),
         "acceptance: <= 3.0"),
    ]
    assert pct <= 3.0, f"observability overhead {pct:.2f}% > 3% gate"
    return rows


def run() -> list[tuple]:
    cfg = TorrConfig(D=8192, B=8, M=1024, W=64, delta_budget=1024)
    key = jax.random.PRNGKey(0)
    im = random_item_memory(key, cfg)
    q = hdc.random_hv(jax.random.PRNGKey(1), (8, cfg.D))
    qp = hdc.pack_bits(q)
    mw = -(-cfg.M // cfg.W)

    rows = []
    # (a) modeled cycles: full sweep over banks vs delta
    for banks in (2, 4, 8):
        d_eff = banks * cfg.bank_dims
        rows.append((f"micro/cycles_full_D{d_eff}", d_eff * mw,
                     "paper: D'*ceil(M/W)"))
    for delta in (128, 512, 1024):
        rows.append((f"micro/cycles_delta_{delta}", delta * mw,
                     f"speedup_vs_full={cfg.D * mw / (delta * mw):.1f}x"))

    # (b) wall-clock of the functional kernels (CPU, interpret-mode Pallas)
    for banks in (2, 8):
        us = _time(lambda qp=qp, banks=banks: ops.packed_similarity(
            qp, im.packed, banks=banks, bank_words=cfg.bank_words)[0])
        rows.append((f"micro/wallclock_full_banks{banks}", round(us, 1), "us"))

    acc = jnp.zeros((cfg.M,), jnp.int32)
    idx = jax.random.randint(jax.random.PRNGKey(2), (cfg.delta_budget,), 0, cfg.D)
    w = jnp.where(jax.random.bernoulli(jax.random.PRNGKey(3), 0.5,
                                       (cfg.delta_budget,)), 2, -2).astype(jnp.int32)
    us = _time(lambda: ops.delta_update(acc, im.dmajor, idx, w))
    rows.append(("micro/wallclock_delta", round(us, 1), "us"))

    z = jax.random.normal(jax.random.PRNGKey(4), (8, 512))
    R = jax.random.normal(jax.random.PRNGKey(5), (cfg.D, 512))
    us = _time(lambda: ops.sign_project(z, R))
    rows.append(("micro/wallclock_sign_project", round(us, 1), "us"))
    us = _time(lambda: ops.encode_packed(z, R))
    rows.append(("micro/wallclock_encode_packed", round(us, 1),
                 "us (fused sign+pack)"))

    # (d) the three-way full-path comparison (PR acceptance gate)
    rows.extend(fullpath_three_way())
    # (e) compact-vs-hoisted dispatch at the reuse-mix extremes (the full
    # sweep is `--reuse-mix 0,0.5,0.9,0.99`; CI tracks these two points)
    rows.extend(reuse_mix_rows(mixes=(0.0, 0.9)))
    # (f) observability overhead gate (metrics+flight within 3% of bare)
    rows.extend(obs_overhead_rows())
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="", metavar="PATH",
                    help="also write rows as JSON to PATH")
    ap.add_argument("--reuse-mix", default="", metavar="MIXES",
                    help="run only the reuse-mix sweep at these comma-"
                         "separated bypass+delta fractions (e.g. "
                         "0,0.5,0.9,0.99): per-lowering windows/sec for "
                         "the always-hoisted prefix vs compact dispatch")
    ap.add_argument("--obs-overhead", action="store_true",
                    help="run only the observability overhead gate "
                         "(metrics+flight vs bare step drive, <= 3%%)")
    args = ap.parse_args()
    if args.obs_overhead:
        rows = obs_overhead_rows()
    elif args.reuse_mix:
        mixes = tuple(float(m) for m in args.reuse_mix.split(",") if m)
        rows = reuse_mix_rows(mixes=mixes)
    else:
        rows = run()
    for r in rows:
        print(",".join(str(x) for x in r))
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"rows": [list(r) for r in rows],
                       "backend": jax.default_backend(),
                       "metrics": metrics_snapshot()}, f, indent=1)


if __name__ == "__main__":
    main()
