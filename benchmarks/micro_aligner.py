"""Microbenchmarks: full vs delta vs bypass aligner paths (Sec. 4.3 claims).

Measures (a) modeled accelerator cycles — the paper's cycles_full ~= D'*M/W
vs cycles_delta ~= |Delta|*M/W scaling, (b) wall-clock of the jitted
functional kernels on this host (interpret-mode Pallas + XLA), (c) the
bank-gating (D') sweep, and (d) the three-way full-path comparison at the
table6 default shapes:

  * ``fullpath_oracle``  — the legacy jitted full path: one masked
    ``aligner.full_dot`` ([M, W] xor) per proposal inside a scan;
  * ``fullpath_batched`` — the host-latched static-banks kernel wrapper
    (``ops.packed_similarity``) over the whole proposal batch;
  * ``fullpath_fused``   — the traced-banks fused dispatch the jitted
    pipeline now defaults to (``aligner.full_scores_all``), in both the
    ``switch`` and ``prefix`` lowerings.

The fused-vs-oracle ratio is the PR's CPU acceptance gate (>= 1.3x at the
table6 shapes). ``python -m benchmarks.micro_aligner --json PATH`` writes
``{"rows": [[name, value, derived], ...]}`` for the bench-smoke CI
artifact; rows are also printed as CSV either way.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aligner, hdc
from repro.core.item_memory import random_item_memory, word_mask
from repro.core.types import TorrConfig
from repro.kernels import ops

# the table6 multi-stream serving shapes — the fused-path acceptance point
# (imported so a table6 retune moves this gate with it)
from benchmarks.table6_multistream import CFG as TABLE6_CFG


def _time(fn, *args, iters: int = 20):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def fullpath_three_way(cfg: TorrConfig = TABLE6_CFG, n_streams: int = 64,
                       iters: int = 30):
    """Rows for oracle vs batched-kernel vs fused-path.

    Measured on the flattened S x N_max proposal batch of one multi-stream
    step (the default serving substrate since PR 1) — the shape at which
    the fused dispatch is actually invoked by ``torr_multi_stream_step``.
    All four variants are verified to produce identical integer
    accumulators before timing; times are best-of-5 rounds.
    """
    im = random_item_memory(jax.random.PRNGKey(0), cfg)
    n_rows = n_streams * cfg.N_max
    qp = hdc.pack_bits(hdc.random_hv(jax.random.PRNGKey(1),
                                     (n_rows, cfg.D)))
    banks_t = jnp.int32(cfg.B)

    # (1) legacy oracle: one masked full_dot per proposal inside a scan,
    # traced banks — exactly the full path the jitted pipeline ran before
    # the fused dispatch landed.
    @jax.jit
    def oracle(q, banks):
        wm = word_mask(cfg, banks)

        def body(c, qr):
            return c, aligner.full_dot(qr, im, wm)

        _, accs = jax.lax.scan(body, jnp.int32(0), q)
        return accs

    # (1b) batched oracle: ref-style whole-batch xor — materializes the
    # [N, M, W] intermediate the fused path exists to kill.
    @jax.jit
    def oracle_batched(q, banks):
        wm = word_mask(cfg, banks)
        x = jnp.bitwise_xor(q[:, None, :], im.packed[None, :, :])
        pc = jnp.where(wm[None, None, :],
                       jax.lax.population_count(x).astype(jnp.int32), 0)
        return 32 * jnp.sum(wm.astype(jnp.int32)) - 2 * jnp.sum(pc, -1)

    # (2) host-latched batched kernel wrapper (static banks).
    batched = jax.jit(lambda q: ops.packed_similarity(
        q, im.packed, banks=cfg.B, bank_words=cfg.bank_words)[0])

    # (3) traced-banks fused dispatch (what the jitted step now runs).
    def fused(mode):
        @jax.jit
        def f(q, banks):
            return aligner.full_scores_all(
                q, im, banks, cfg, planes=cfg.bit_planes, cap=cfg.B,
                mode=mode)
        return f

    f_switch, f_prefix = fused("switch"), fused("prefix")

    # sanity: all variants produce identical integer accumulators
    want = np.asarray(oracle(qp, banks_t))
    for name, got in (("oracle_batched", oracle_batched(qp, banks_t)),
                      ("batched", batched(qp)),
                      ("switch", f_switch(qp, banks_t)),
                      ("prefix", f_prefix(qp, banks_t))):
        assert np.array_equal(np.asarray(got), want), name

    def best_of(fn, rounds=5):
        return min(_time(fn, iters=iters) for _ in range(rounds))

    us_oracle = best_of(lambda: oracle(qp, banks_t))
    us_oracle_b = best_of(lambda: oracle_batched(qp, banks_t))
    us_batched = best_of(lambda: batched(qp))
    us_switch = best_of(lambda: f_switch(qp, banks_t))
    us_prefix = best_of(lambda: f_prefix(qp, banks_t))

    shape = f"N{n_rows}_M{cfg.M}_D{cfg.D}"
    best_fused = min(us_switch, us_prefix)
    return [
        (f"micro/fullpath_oracle_{shape}", round(us_oracle, 1), "us"),
        (f"micro/fullpath_oracle_batched_{shape}", round(us_oracle_b, 1),
         "us (materializes [N,M,W])"),
        (f"micro/fullpath_batched_{shape}", round(us_batched, 1),
         f"speedup_vs_oracle={us_oracle / us_batched:.2f}"),
        (f"micro/fullpath_fused_switch_{shape}", round(us_switch, 1),
         f"speedup_vs_oracle={us_oracle / us_switch:.2f}"),
        (f"micro/fullpath_fused_prefix_{shape}", round(us_prefix, 1),
         f"speedup_vs_oracle={us_oracle / us_prefix:.2f}"),
        (f"micro/fullpath_fused_speedup_{shape}",
         round(us_oracle / best_fused, 2), "acceptance: >= 1.3"),
    ]


def run() -> list[tuple]:
    cfg = TorrConfig(D=8192, B=8, M=1024, W=64, delta_budget=1024)
    key = jax.random.PRNGKey(0)
    im = random_item_memory(key, cfg)
    q = hdc.random_hv(jax.random.PRNGKey(1), (8, cfg.D))
    qp = hdc.pack_bits(q)
    mw = -(-cfg.M // cfg.W)

    rows = []
    # (a) modeled cycles: full sweep over banks vs delta
    for banks in (2, 4, 8):
        d_eff = banks * cfg.bank_dims
        rows.append((f"micro/cycles_full_D{d_eff}", d_eff * mw,
                     "paper: D'*ceil(M/W)"))
    for delta in (128, 512, 1024):
        rows.append((f"micro/cycles_delta_{delta}", delta * mw,
                     f"speedup_vs_full={cfg.D * mw / (delta * mw):.1f}x"))

    # (b) wall-clock of the functional kernels (CPU, interpret-mode Pallas)
    for banks in (2, 8):
        us = _time(lambda qp=qp, banks=banks: ops.packed_similarity(
            qp, im.packed, banks=banks, bank_words=cfg.bank_words)[0])
        rows.append((f"micro/wallclock_full_banks{banks}", round(us, 1), "us"))

    acc = jnp.zeros((cfg.M,), jnp.int32)
    idx = jax.random.randint(jax.random.PRNGKey(2), (cfg.delta_budget,), 0, cfg.D)
    w = jnp.where(jax.random.bernoulli(jax.random.PRNGKey(3), 0.5,
                                       (cfg.delta_budget,)), 2, -2).astype(jnp.int32)
    us = _time(lambda: ops.delta_update(acc, im.dmajor, idx, w))
    rows.append(("micro/wallclock_delta", round(us, 1), "us"))

    z = jax.random.normal(jax.random.PRNGKey(4), (8, 512))
    R = jax.random.normal(jax.random.PRNGKey(5), (cfg.D, 512))
    us = _time(lambda: ops.sign_project(z, R))
    rows.append(("micro/wallclock_sign_project", round(us, 1), "us"))
    us = _time(lambda: ops.encode_packed(z, R))
    rows.append(("micro/wallclock_encode_packed", round(us, 1),
                 "us (fused sign+pack)"))

    # (d) the three-way full-path comparison (PR acceptance gate)
    rows.extend(fullpath_three_way())
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="", metavar="PATH",
                    help="also write rows as JSON to PATH")
    args = ap.parse_args()
    rows = run()
    for r in rows:
        print(",".join(str(x) for x in r))
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"rows": [list(r) for r in rows],
                       "backend": jax.default_backend()}, f, indent=1)


if __name__ == "__main__":
    main()
