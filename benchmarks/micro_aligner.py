"""Microbenchmarks: full vs delta vs bypass aligner paths (Sec. 4.3 claims).

Measures (a) modeled accelerator cycles — the paper's cycles_full ~= D'*M/W
vs cycles_delta ~= |Delta|*M/W scaling, (b) wall-clock of the jitted
functional kernels on this host (interpret-mode Pallas + XLA), and (c) the
bank-gating (D') sweep.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hdc
from repro.core.item_memory import random_item_memory
from repro.core.types import TorrConfig
from repro.kernels import ops


def _time(fn, *args, iters: int = 20):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def run() -> list[tuple]:
    cfg = TorrConfig(D=8192, B=8, M=1024, W=64, delta_budget=1024)
    key = jax.random.PRNGKey(0)
    im = random_item_memory(key, cfg)
    q = hdc.random_hv(jax.random.PRNGKey(1), (8, cfg.D))
    qp = hdc.pack_bits(q)
    mw = -(-cfg.M // cfg.W)

    rows = []
    # (a) modeled cycles: full sweep over banks vs delta
    for banks in (2, 4, 8):
        d_eff = banks * cfg.bank_dims
        rows.append((f"micro/cycles_full_D{d_eff}", d_eff * mw,
                     "paper: D'*ceil(M/W)"))
    for delta in (128, 512, 1024):
        rows.append((f"micro/cycles_delta_{delta}", delta * mw,
                     f"speedup_vs_full={cfg.D * mw / (delta * mw):.1f}x"))

    # (b) wall-clock of the functional kernels (CPU, interpret-mode Pallas)
    for banks in (2, 8):
        us = _time(lambda qp=qp, banks=banks: ops.packed_similarity(
            qp, im.packed, banks=banks, bank_words=cfg.bank_words)[0])
        rows.append((f"micro/wallclock_full_banks{banks}", round(us, 1), "us"))

    acc = jnp.zeros((cfg.M,), jnp.int32)
    idx = jax.random.randint(jax.random.PRNGKey(2), (cfg.delta_budget,), 0, cfg.D)
    w = jnp.where(jax.random.bernoulli(jax.random.PRNGKey(3), 0.5,
                                       (cfg.delta_budget,)), 2, -2).astype(jnp.int32)
    us = _time(lambda: ops.delta_update(acc, im.dmajor, idx, w))
    rows.append(("micro/wallclock_delta", round(us, 1), "us"))

    z = jax.random.normal(jax.random.PRNGKey(4), (8, 512))
    R = jax.random.normal(jax.random.PRNGKey(5), (cfg.D, 512))
    us = _time(lambda: ops.sign_project(z, R))
    rows.append(("micro/wallclock_sign_project", round(us, 1), "us"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
