"""Autotune the batched XNOR-popcount kernel's TQ/TM block shapes.

Closes the ROADMAP item: the ``TORR_TQ``/``TORR_TM`` env overrides (read
once at import by ``repro.kernels.xnor_popcount_sim``) make the block-shape
sweep a no-code-edit loop — so this benchmark runs each (tq, tm) candidate
in a fresh subprocess (the only way to re-read the env), times the batched
``packed_hamming_batched`` kernel on a multi-stream-shaped workload, and
emits the winning shapes as a JSON artifact::

    {"best": {"tq": .., "tm": ..}, "grid": [{"tq":..,"tm":..,"us":..}, ..],
     "workload": {"N": .., "M": .., "D": ..}, "backend": "cpu-interpret"}

Artifact path: ``TORR_AUTOTUNE_OUT`` env var, default
``autotune_blocks.json`` in the working directory. Point ``TORR_TUNE_FILE``
at the written artifact and every kernel consumer (the direct defaults,
``kernels.ops``'s tile caps and the fused family) loads the swept winner at
import — no hand-exported ``TORR_TQ``/``TORR_TM`` needed; explicit env vars
still win (precedence table in ``kernels.xnor_popcount_sim``). On real TPU
run the same sweep with a denser grid (the module docstring of
``xnor_popcount_sim`` suggests TQ in {8,16,32} x TM in {128,256,512}); the
defaults here are kept small so the CPU interpret-mode suite stays fast.

Rows: ``autotune/tq<tq>_tm<tm>, <us>, us`` per candidate plus
``autotune/best, <us>, tq=..|tm=..``.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

# the child re-imports the kernel module under the swept env overrides and
# prints one JSON line with the measured per-call latency
_CHILD = """
import json, time
import jax
from repro.core import hdc
from repro.kernels.xnor_popcount_sim import (TM_DEFAULT, TQ_DEFAULT,
                                             packed_hamming_batched)

N, M, D = {N}, {M}, {D}
q = hdc.pack_bits(hdc.random_hv(jax.random.PRNGKey(0), (N, D)))
h = hdc.pack_bits(hdc.random_hv(jax.random.PRNGKey(1), (M, D)))
fn = lambda: packed_hamming_batched(q, h)
jax.block_until_ready(fn())              # compile
t0 = time.perf_counter()
iters = {iters}
for _ in range(iters):
    out = fn()
jax.block_until_ready(out)
us = (time.perf_counter() - t0) / iters * 1e6
print(json.dumps(dict(tq=TQ_DEFAULT, tm=TM_DEFAULT, us=us)))
"""


def _time_combo(tq: int, tm: int, N: int, M: int, D: int,
                iters: int) -> dict:
    env = dict(os.environ, PYTHONPATH=SRC, JAX_PLATFORMS="cpu",
               TORR_TQ=str(tq), TORR_TM=str(tm))
    code = _CHILD.format(N=N, M=M, D=D, iters=iters)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    if out.returncode != 0:
        raise RuntimeError(
            f"autotune child (tq={tq}, tm={tm}) failed:\n{out.stderr[-2000:]}")
    return json.loads(out.stdout.strip().splitlines()[-1])


def run(tq_grid=(8, 16), tm_grid=(64, 128), N: int = 16, M: int = 256,
        D: int = 4096, iters: int = 3) -> list[tuple]:
    """Sweep the grid, report each candidate, persist the best as JSON."""
    grid = []
    for tq in tq_grid:
        for tm in tm_grid:
            r = _time_combo(tq, tm, N, M, D, iters)
            grid.append(r)
    best = min(grid, key=lambda r: r["us"])

    artifact = {
        "best": {"tq": best["tq"], "tm": best["tm"]},
        "grid": grid,
        "workload": {"N": N, "M": M, "D": D, "iters": iters},
        "backend": "cpu-interpret",
    }
    out_path = os.environ.get("TORR_AUTOTUNE_OUT", "autotune_blocks.json")
    with open(out_path, "w") as f:
        json.dump(artifact, f, indent=1, sort_keys=True)

    rows = [(f"autotune/tq{r['tq']}_tm{r['tm']}", round(r["us"], 1), "us")
            for r in grid]
    rows.append(("autotune/best", round(best["us"], 1),
                 f"tq={best['tq']}|tm={best['tm']}|json={out_path}"
                 "|apply_via=TORR_TUNE_FILE"))
    return rows


if __name__ == "__main__":
    for row in run():
        print(",".join(str(x) for x in row))
