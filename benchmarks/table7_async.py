"""Table 7 (beyond paper): async dispatch/collect vs synchronous serving.

Measures end-to-end serving throughput (windows/sec) and completion-cadence
jitter as a function of concurrent stream count for

  * ``sync``    — the PR 1 ``StreamEngine`` driven from one thread: each
    ``step()``'s results are moved to host memory before the next step is
    assembled (what a real server does before shipping detections), so host
    assembly, device compute and result conversion serialize;
  * ``async``   — ``AsyncStreamEngine``: the dispatcher assembles and
    launches step t+1 while the collector blocks on / converts step t, so
    host work overlaps device compute (one bulk device->host move per step,
    per-window futures);
  * ``sharded`` — the async engine with the stacked stream state sharded
    over all local devices (only emitted when >1 device is visible; run
    standalone under ``XLA_FLAGS=--xla_force_host_platform_device_count=4``
    to exercise it on CPU).

All engines serve identical frame sequences and (sync vs async) produce
bit-identical outputs — tests/test_async_engine.py — so the ratios are pure
runtime-scheduling effects.

Jitter is completion-cadence jitter: p99 minus median of the gaps between
consecutive window completions, in ms. A smooth server emits windows at a
steady cadence; stalls (e.g. result conversion blocking the dispatch
thread) show up as a heavy p99 tail.

Rows: ``table7/<engine>_S<streams>, windows_per_sec,
speedup=<vs sync>|p99_jitter_ms=<jitter>``.
"""
from __future__ import annotations

import time

import numpy as np

import jax

from repro.core.item_memory import random_item_memory
from repro.runtime import sharding as shd
from repro.serving.async_engine import AsyncStreamEngine
from repro.serving.stream_engine import StreamEngine

from .table6_multistream import CFG, _make_streams


def _cadence_jitter_ms(times: np.ndarray) -> float:
    """p99 - median of inter-completion gaps (ms); 0 if too few samples."""
    if times.size < 3:
        return 0.0
    gaps = np.diff(np.sort(times)) * 1e3
    return float(np.percentile(gaps, 99) - np.median(gaps))


# registry shared by every engine run of the last run() call: counters
# accumulate over the whole sweep, so the JSON artifact's snapshot is the
# suite-total serving traffic (windows, path mix, span latencies). The
# micro_aligner obs gate bounds the measurement perturbation at <= 3%.
_METRICS = None


def metrics_snapshot():
    """Metrics of the last run() sweep, for the JSON artifact."""
    return _METRICS.snapshot() if _METRICS is not None else None


def _run_sync(cfg, im, task_w, streams, metrics=None):
    eng = StreamEngine(cfg, im, n_slots=len(streams), metrics=metrics)
    for s, frames in enumerate(streams):
        eng.admit(s, task_w[s])
        for q, valid, boxes in frames:
            eng.submit(s, q, valid, boxes)
    eng.warmup()
    done = []
    t0 = time.perf_counter()
    while eng.busy:
        res = eng.step()
        # ship each window's detections: results must be host-resident
        for _sid, (out, tel) in res.items():
            np.asarray(out.scores), np.asarray(out.best), np.asarray(tel.path)
        done.extend([time.perf_counter()] * len(res))
    dt = time.perf_counter() - t0
    eng.flush_telemetry()
    return eng.stats.windows / dt, _cadence_jitter_ms(np.asarray(done))


def _run_async(cfg, im, task_w, streams, mesh=None, metrics=None,
               flight=None, tracer=None):
    eng = AsyncStreamEngine(cfg, im, n_slots=len(streams), mesh=mesh,
                            paused=True, metrics=metrics, flight=flight,
                            tracer=tracer)
    done = []
    futs = []
    for s, frames in enumerate(streams):
        eng.admit(s, task_w[s])
        for q, valid, boxes in frames:
            fut = eng.submit(s, q, valid, boxes)
            fut.add_done_callback(lambda _f: done.append(time.perf_counter()))
            futs.append(fut)
    eng.warmup()
    t0 = time.perf_counter()
    eng.start()
    eng.flush()
    dt = time.perf_counter() - t0
    wps = eng.stats.windows / dt
    eng.close()
    for f in futs:   # surface any worker error instead of reporting garbage
        f.result(timeout=1)
    return wps, _cadence_jitter_ms(np.asarray(done))


def run(stream_counts=(4, 16, 64), n_frames: int = 12) -> list[tuple]:
    global _METRICS
    from repro.obs import MetricsRegistry
    cfg = CFG
    im = random_item_memory(jax.random.PRNGKey(0), cfg)
    multi_dev = len(jax.devices()) > 1
    _METRICS = reg = MetricsRegistry()
    rows = []
    for S in stream_counts:
        task_w = np.asarray(
            jax.random.uniform(jax.random.PRNGKey(1), (S, cfg.M)))
        streams = _make_streams(cfg, S, n_frames, seed=S)

        wps_sync, jit_sync = _run_sync(cfg, im, task_w, streams, metrics=reg)
        wps_async, jit_async = _run_async(cfg, im, task_w, streams,
                                          metrics=reg)
        rows.append((f"table7/sync_S{S}", round(wps_sync, 1),
                     f"speedup=1.00|p99_jitter_ms={jit_sync:.2f}"))
        rows.append((f"table7/async_S{S}", round(wps_async, 1),
                     f"speedup={wps_async / wps_sync:.2f}"
                     f"|p99_jitter_ms={jit_async:.2f}"))
        if multi_dev:
            mesh = shd.stream_mesh()
            wps_sh, jit_sh = _run_async(cfg, im, task_w, streams, mesh=mesh,
                                        metrics=reg)
            rows.append((
                f"table7/sharded_S{S}x{mesh.devices.size}",
                round(wps_sh, 1),
                f"speedup={wps_sh / wps_sync:.2f}"
                f"|p99_jitter_ms={jit_sh:.2f}"))
    # suite-total step-latency quantiles off the shared registry's
    # histogram (estimator: repro.obs.metrics.quantile — linear
    # interpolation in the fixed buckets, so p99 resolution is bucket
    # width). The async collector records dispatch->results-ready per
    # step; name them *_ms so the perf-trend gate's throughput filter
    # (higher-is-better only) skips them.
    from repro.obs import snapshot_quantile
    snap = reg.snapshot()
    for q, tag in ((0.5, "p50"), (0.99, "p99")):
        v = snapshot_quantile(snap, "torr_step_latency_seconds", q)
        if v == v:  # NaN -> histogram never observed (no async steps)
            rows.append((f"table7/step_latency_{tag}_ms",
                         round(v * 1e3, 3), "async dispatch->ready"))
    return rows


def main(argv=None) -> None:
    """Standalone entry: the table sweep, optionally with a Chrome-trace
    export of one traced async run (``--trace-json``)."""
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--trace-json", default="", metavar="PATH",
                    help="after the sweep, run one traced async pass "
                         "(16 streams) and write a Chrome trace-event "
                         "JSON; open in chrome://tracing / ui.perfetto.dev")
    ap.add_argument("--frames", type=int, default=12)
    args = ap.parse_args(argv)

    for r in run(n_frames=args.frames):
        print(",".join(str(x) for x in r))
    if args.trace_json:
        from repro.obs import (FlightRecorder, MetricsRegistry, Tracer,
                               write_chrome_trace)
        cfg = CFG
        im = random_item_memory(jax.random.PRNGKey(0), cfg)
        S = 16
        task_w = np.asarray(
            jax.random.uniform(jax.random.PRNGKey(1), (S, cfg.M)))
        streams = _make_streams(cfg, S, args.frames, seed=S)
        reg = MetricsRegistry()
        flight = FlightRecorder(4096, metrics=reg)
        tracer = Tracer(metrics=reg)
        _run_async(cfg, im, task_w, streams, metrics=reg, flight=flight,
                   tracer=tracer)
        n_ev = write_chrome_trace(flight.records(), args.trace_json)
        print(f"table7/trace,{n_ev},events -> {args.trace_json}")


if __name__ == "__main__":
    main()
