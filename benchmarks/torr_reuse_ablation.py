"""Reuse ablation: TorR (Alg. 1) vs no-reuse (SNN + naive HDC baseline).

The paper's central claim: caching turns temporal coherence into latency/
energy headroom. Rows report the cycle model on identical traces with the
policy enabled vs thresholds that never fire.
"""
from __future__ import annotations

from repro.configs.torr_edge import torr_edge, torr_edge_no_reuse
from repro.perf.cycle_model import TASK_PROFILES, simulate_task


def run(n_frames: int = 300) -> list[tuple]:
    rows = []
    for task in TASK_PROFILES:
        on = simulate_task(task, "RT-60", n_frames, cfg=torr_edge("RT-60"))
        off = simulate_task(task, "RT-60", n_frames,
                            cfg=torr_edge_no_reuse("RT-60"))
        speedup = off["median_ms"] / on["median_ms"]
        e_save = 1 - on["energy_mj"] / off["energy_mj"]
        rows.append((
            f"torr_ablation/{task.replace(' ', '_')}",
            round(speedup, 2),
            (f"median {off['median_ms']:.1f}->{on['median_ms']:.1f}ms;"
             f"E {off['energy_mj']:.0f}->{on['energy_mj']:.0f}mJ"
             f" (-{100*e_save:.0f}%);P {off['power_w']:.2f}->{on['power_w']:.2f}W")))
        assert speedup > 1.2, (task, speedup)
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
