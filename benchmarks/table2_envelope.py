"""Table 2: min/max end-to-end per-frame latency across the five tasks."""
from __future__ import annotations

from repro.perf.cycle_model import TASK_PROFILES, simulate_all

PAPER = {"RT-60": (6.8, 13.8), "RT-30": (12.9, 23.6)}


def run(n_frames: int = 400) -> list[tuple]:
    rows = []
    for rt in ("RT-60", "RT-30"):
        res = simulate_all(rt, n_frames=n_frames)
        gmin = min(r["min_ms"] for r in res)
        gmax = max(r["max_ms"] for r in res)
        tmin = min(res, key=lambda r: r["min_ms"])["task"]
        tmax = max(res, key=lambda r: r["max_ms"])["task"]
        budget = 1000.0 / (60 if rt == "RT-60" else 30)
        rows.append((f"table2/{rt}/global_min_ms", gmin,
                     f"task={tmin};paper={PAPER[rt][0]}"))
        rows.append((f"table2/{rt}/global_max_ms", gmax,
                     f"task={tmax};paper={PAPER[rt][1]};budget={budget:.2f}"))
        assert gmax < budget, f"{rt}: max {gmax} exceeds frame budget {budget}"
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
