"""Batched intra-window decide pass: differential-oracle harness (ISSUE 6).

The sequential decide scan (``pipeline._decide_pass``) is the reference
oracle; the batched decide (``pipeline._decide_pass_batched``, the compact
dispatch's ``decide="batched"`` default) must be *bit-identical* to it —
the same decision tuple ``(action, idx, lru, d_idx, d_weight, d_count,
rho)`` for every proposal of every window, and the same final
:class:`~repro.core.query_cache.CacheState` after the apply pass replays
those decisions. "Identical" means integer-equal hamming/d_idx/d_weight
and float-bit-equal rho, not allclose.

Layers, fastest first:

  * decide-level differential: both passes on the same evolving cache,
    window by window, across the (banks, planes) plan grid and reuse mixes
    — plus a property-driven episode sweep (hypothesis when available,
    the deterministic ``_hypothesis_compat`` fallback otherwise);
  * adversarial conflict windows: duplicate queries, a query equal to an
    HV written earlier in the same window, full-path LRU eviction chains
    longer than K, all-padding windows, delta-then-full across a plan
    switch — each aimed at the intra-window coupling the conflict pass
    must resolve;
  * step/engine-level differential: ``decide="batched"`` vs
    ``decide="scan"`` vs the ``fused="off"`` oracle through the jitted
    single-window and multi-stream steps, every bucket tier, and the
    stream engines (1 device here; 4 fake devices in the subprocess test);
  * the ``policy.intra_window_coupled`` superset invariant, the
    ``_resolve_bucket_cap`` precedence/warn contract, and the cycle
    model's decide-aware PSU pricing.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from _hypothesis_compat import given, settings, st

from repro.control import KnobPlan
from repro.core import hdc, pipeline, policy, query_cache
from repro.core.item_memory import random_item_memory
from repro.core.types import PATH_DELTA, PATH_FULL, TorrConfig
from repro.perf import cycle_model

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

CFG = TorrConfig(D=1024, B=8, M=32, K=4, N_max=8, delta_budget=128,
                 feat_dim=64)

PLANS = [(8, 4), (8, 2), (4, 4), (4, 1), (2, 2), (1, 1)]

DEC_NAMES = ("action", "idx", "lru", "d_idx", "d_weight", "d_count", "rho")

STEP = jax.jit(pipeline.torr_window_step,
               static_argnames=("cfg", "plan", "fused", "bucket_cap",
                                "decide"))
MSTEP = jax.jit(pipeline.torr_multi_stream_step,
                static_argnames=("cfg", "serial", "plan", "fused",
                                 "bucket_cap", "decide"))


def _plan(banks, planes, cfg=CFG, **kw):
    return KnobPlan(banks=banks, planes=planes, plane_total=cfg.bit_planes,
                    **kw)


# --- window-sequence generator ----------------------------------------------

def _episode(cfg, mix, n_windows, seed, p_valid=0.85, flip_max=24):
    """Multi-window episode at a target reuse mix.

    Each proposal is, with probability ``mix``, a lightly perturbed copy of
    some earlier proposal in the episode (including *this window's* — the
    intra-window self-hit case the conflict pass exists for); otherwise a
    fresh random HV. Returns [(q [N, W] uint32, valid [N] bool), ...].
    """
    rng = np.random.default_rng(seed)
    pool: list[np.ndarray] = []
    windows = []
    for _ in range(n_windows):
        qs, vs = [], []
        for _ in range(cfg.N_max):
            if pool and rng.random() < mix:
                q = pool[int(rng.integers(len(pool)))].copy()
                for _ in range(int(rng.integers(0, flip_max))):
                    w = int(rng.integers(cfg.words))
                    q[w] ^= np.uint32(1) << np.uint32(rng.integers(32))
            else:
                q = rng.integers(0, 2 ** 32, size=cfg.words, dtype=np.uint32)
            pool.append(q)
            qs.append(q)
            vs.append(bool(rng.random() < p_valid))
        windows.append((np.stack(qs), np.asarray(vs, bool)))
    return windows


def _window_knobs(cfg, valid, queue_depth, plan):
    """(banks, planes, high) exactly as ``torr_window_step`` derives them."""
    planes = cfg.bit_planes if plan is None else plan.planes
    n_valid = jnp.sum(jnp.asarray(valid).astype(jnp.int32))
    qd = jnp.int32(queue_depth)
    high = policy.high_load(n_valid, qd, cfg)
    banks = policy.select_banks(n_valid, qd, cfg)
    if plan is not None and plan.banks < cfg.B:
        banks = jnp.minimum(banks, jnp.int32(plan.banks))
    return banks, planes, high


def _assert_dec_equal(dec_a, dec_b, ctx=()):
    for name, a, b in zip(DEC_NAMES, dec_a, dec_b):
        assert np.array_equal(np.asarray(a), np.asarray(b)), (*ctx, name)


def _assert_cache_equal(ca, cb, ctx=()):
    for i, (a, b) in enumerate(zip(jax.tree_util.tree_leaves(ca),
                                   jax.tree_util.tree_leaves(cb))):
        assert np.array_equal(np.asarray(a), np.asarray(b)), (*ctx, i)


def _differential_episode(cfg, windows, plan=None, qd_seq=None, ctx=()):
    """Run both decide passes on the same evolving cache, window by window,
    asserting bit-identical decision tuples; the cache advances through the
    real (jitted) step so later windows see warmed, churned state. Also
    checks the batched decide drives the full step to the oracle's exact
    final state."""
    effective = cfg if plan is None else plan.thresholds(cfg)
    im = random_item_memory(jax.random.PRNGKey(0), cfg)
    task_w = jax.random.uniform(jax.random.PRNGKey(1), (cfg.M,))
    st_b = st_o = pipeline.init_state(cfg, task_w)
    for t, (q, v) in enumerate(windows):
        qd = 0 if qd_seq is None else qd_seq[t]
        q, v = jnp.asarray(q), jnp.asarray(v)
        banks, planes, high = _window_knobs(effective, v, qd, plan)
        dec_a = pipeline._decide_pass(st_b.cache, q, v, effective, banks,
                                      planes, high)
        dec_b = pipeline._decide_pass_batched(st_b.cache, q, v, effective,
                                              banks, planes, high)
        _assert_dec_equal(dec_a, dec_b, (*ctx, t))
        boxes = jnp.zeros((cfg.N_max, 4), jnp.float32)
        st_b, out_b, tel_b = STEP(st_b, im, q, v, boxes, jnp.int32(qd), cfg,
                                  plan=plan, fused="compact",
                                  decide="batched")
        st_o, out_o, tel_o = STEP(st_o, im, q, v, boxes, jnp.int32(qd), cfg,
                                  plan=plan, fused="off")
        assert np.array_equal(np.asarray(out_b.scores),
                              np.asarray(out_o.scores)), (*ctx, t)
        assert np.array_equal(np.asarray(tel_b.path),
                              np.asarray(tel_o.path)), (*ctx, t)
        _assert_cache_equal(st_b.cache, st_o.cache, (*ctx, t))


# --- decide-level differential: plan grid x reuse mixes ----------------------

@pytest.mark.parametrize("banks,planes", [(8, 4), (4, 1), (1, 1)])
@pytest.mark.parametrize("mix", [0.0, 0.9])
def test_decide_differential_smoke(banks, planes, mix):
    """Tier-1 subset of the property sweep: two plan corners x two mixes,
    short episodes with a queue-depth spike so bypass fires."""
    windows = _episode(CFG, mix, n_windows=3, seed=banks * 10 + planes)
    _differential_episode(CFG, windows, plan=_plan(banks, planes),
                          qd_seq=[0, CFG.q_hi, 0],
                          ctx=(banks, planes, mix))


@pytest.mark.slow
@given(st.integers(0, 2 ** 31 - 1),
       st.sampled_from(PLANS),
       st.sampled_from([0.0, 0.5, 0.9, 0.99]),
       st.sampled_from([0, 1]))
@settings(max_examples=20, deadline=None)
def test_decide_differential_property(seed, plan_bp, mix, spike):
    """The full differential sweep: random episodes across the plan grid x
    reuse mixes {0, 0.5, 0.9, 0.99}, optional load spikes. Every window of
    every episode must produce bit-identical decision tuples and an
    oracle-identical final cache."""
    banks, planes = plan_bp
    qd_seq = [0, CFG.q_hi, 0, CFG.q_hi] if spike else None
    windows = _episode(CFG, mix, n_windows=4, seed=seed)
    _differential_episode(CFG, windows, plan=_plan(banks, planes),
                          qd_seq=qd_seq, ctx=(seed, banks, planes, mix))


@pytest.mark.slow
@given(st.integers(0, 2 ** 31 - 1), st.sampled_from([0.5, 0.99]))
@settings(max_examples=6, deadline=None)
def test_decide_differential_deep_cache_property(seed, mix):
    """K > N_max: conflicts live alongside plenty of untouched snapshot
    entries, so the batched pass must blend live and snapshot rows."""
    cfg = TorrConfig(D=1024, B=8, M=32, K=16, N_max=4, delta_budget=128,
                     feat_dim=64)
    windows = _episode(cfg, mix, n_windows=4, seed=seed)
    _differential_episode(cfg, windows, ctx=(seed, mix))


# --- adversarial conflict windows --------------------------------------------

def _dup_window(cfg, seed, copies):
    """A window whose trailing proposals repeat the leading ones exactly."""
    rng = np.random.default_rng(seed)
    q = rng.integers(0, 2 ** 32, size=(cfg.N_max, cfg.words),
                     dtype=np.uint32)
    for i, j in copies:
        q[j] = q[i]
    return q, np.ones((cfg.N_max,), bool)


def test_decide_duplicate_queries_in_window():
    """Duplicates must self-hit the earlier proposal's freshly written slot
    (ham 0 against a live entry), not the stale snapshot."""
    windows = [_dup_window(CFG, 3, copies=[(0, 1), (0, 7), (2, 3)])]
    _differential_episode(CFG, windows, ctx=("dup",))


def test_decide_query_equals_earlier_write():
    """A cold full-path write followed in the *same window* by its exact
    query: the follower's nearest is the intra-window entry, and its delta
    set against that entry is empty."""
    q, v = _dup_window(CFG, 5, copies=[(0, 4)])
    _differential_episode(CFG, [(q, v)], ctx=("self-hit",))
    # and with a perturbed follower: small nonzero delta against the live
    # entry, exercising the resolved-old-entry d_idx path
    q2 = q.copy()
    q2[4, 0] ^= np.uint32(0b1011)
    _differential_episode(CFG, [(q2, v)], ctx=("self-hit-perturbed",))


def test_decide_lru_chain_longer_than_K():
    """All-fresh windows: every proposal takes the full path, and with
    N_max = 2K the eviction chain wraps the cache twice — each LRU choice
    depends on every earlier write's age churn."""
    windows = _episode(CFG, mix=0.0, n_windows=3, seed=11, p_valid=1.0)
    _differential_episode(CFG, windows, ctx=("lru-chain",))


def test_decide_all_padding_window():
    """valid all-False: every proposal pads, the cache is untouched, and
    the (still computed) idx/lru/d_idx/d_weight lanes must match bitwise."""
    rng = np.random.default_rng(13)
    q = rng.integers(0, 2 ** 32, size=(CFG.N_max, CFG.words),
                     dtype=np.uint32)
    v = np.zeros((CFG.N_max,), bool)
    warm = _episode(CFG, mix=0.0, n_windows=1, seed=14)
    _differential_episode(CFG, warm + [(q, v)] + warm, ctx=("all-pad",))


def test_decide_delta_then_full_across_plan_switch():
    """Plan A warms the cache and serves deltas; switching to plan B stales
    every acc tag, forcing full re-scans whose LRU churn the batched pass
    must replay — under both decide lowerings, against the oracle."""
    cfg = CFG
    im = random_item_memory(jax.random.PRNGKey(0), cfg)
    task_w = jax.random.uniform(jax.random.PRNGKey(1), (cfg.M,))
    plan_a, plan_b = _plan(8, 4), _plan(4, 2)
    q_bip = hdc.random_hv(jax.random.PRNGKey(7), (cfg.N_max, cfg.D))
    valid = jnp.asarray(np.arange(cfg.N_max) < cfg.K - 1)
    boxes = jnp.zeros((cfg.N_max, 4), jnp.float32)
    q0 = jax.vmap(hdc.pack_bits)(q_bip)
    q1 = jax.vmap(hdc.pack_bits)(q_bip.at[:, :4].multiply(-1))
    nv = int(np.sum(np.asarray(valid)))

    def run(**kw):
        st = pipeline.init_state(cfg, task_w)
        st, _, tel0 = STEP(st, im, q0, valid, boxes, jnp.int32(0), cfg,
                           plan=plan_a, **kw)
        assert (np.asarray(tel0.path)[:nv] == PATH_FULL).all()
        st, _, tel_a = STEP(st, im, q1, valid, boxes, jnp.int32(0), cfg,
                            plan=plan_a, **kw)
        assert (np.asarray(tel_a.path)[:nv] == PATH_DELTA).all()
        st, out_b, tel_b = STEP(st, im, q1, valid, boxes, jnp.int32(0), cfg,
                                plan=plan_b, **kw)
        assert (np.asarray(tel_b.path)[:nv] == PATH_FULL).all()
        return st, out_b

    st0, out0 = run(fused="off")
    for decide in ("scan", "batched"):
        st1, out1 = run(fused="compact", decide=decide)
        assert np.array_equal(np.asarray(out0.scores),
                              np.asarray(out1.scores)), decide
        _assert_cache_equal(st0.cache, st1.cache, (decide,))


# --- the conflict-set predicate ----------------------------------------------

def test_intra_window_coupled_is_superset():
    """Wherever the sequential FSM's (action, idx, d_count, rho) diverge
    from a frozen-snapshot decide (``query_cache.nearest_all`` against the
    window-entry cache), ``policy.intra_window_coupled`` must flag the
    proposal — the invariant that makes the batched pass's conflict scan
    sufficient. LRU is exempt by contract (bypass age-churn shifts it
    without coupling the path decision)."""
    cfg = CFG
    tag = jnp.int32(0)  # fresh cache: every acc_tag is 0
    hits = 0
    for seed in range(8):
        for mix in (0.5, 0.9, 0.99):
            windows = _episode(cfg, mix, n_windows=1, seed=seed,
                               p_valid=1.0)
            q, v = map(jnp.asarray, windows[0])
            cache = query_cache.init_cache(cfg)
            banks, planes, high = _window_knobs(cfg, v, 0, None)
            dec = pipeline._decide_pass(cache, q, v, cfg, banks, planes,
                                        high)
            action, idx, _lru, _di, _dw, d_count, rho = dec
            # frozen-snapshot decisions: no intra-window updates at all
            s_idx, s_rho, s_ham = query_cache.nearest_all(cache, q, cfg,
                                                          banks, planes)
            tag_ok = cache.acc_tag[s_idx] == tag
            s_action = policy.select_path(s_rho, s_ham, tag_ok, high, cfg)
            diverged = np.zeros((cfg.N_max,), bool)
            for got, snap in ((action, s_action), (idx, s_idx),
                              (d_count, s_ham)):
                diverged |= np.asarray(got) != np.asarray(snap)
            diverged |= ~np.isclose(np.asarray(rho),
                                    np.asarray(jnp.where(v, s_rho, 0.0)))
            coupled = np.asarray(policy.intra_window_coupled(action, v))
            assert not np.any(diverged & ~coupled), (seed, mix)
            hits += int(np.sum(diverged))
    assert hits > 0, "sweep never exercised an intra-window conflict"


# --- bucket_cap precedence + clamp warning -----------------------------------

def test_bucket_cap_precedence():
    """Explicit arg > plan.bucket_cap > full capacity; an over-capacity tier
    clamps *loudly*; a sub-1 tier is an error."""
    resolve = pipeline._resolve_bucket_cap
    plan = _plan(8, 4, bucket_cap=2)
    assert resolve(4, plan, 8) == 4          # explicit beats plan
    assert resolve(None, plan, 8) == 2       # plan beats default
    assert resolve(None, None, 8) == 8       # default: full capacity
    assert resolve(None, _plan(8, 4), 8) == 8  # plan without a cap
    with pytest.warns(UserWarning, match="bucket_cap=16 exceeds"):
        assert resolve(16, plan, 8) == 8     # loud clamp, explicit arg
    with pytest.warns(UserWarning, match="plan.bucket_cap=2 exceeds"):
        assert resolve(None, plan, 1) == 1   # loud clamp, plan tier
    with pytest.raises(ValueError):
        resolve(0, None, 8)


def test_bucket_cap_overflow_warns_and_stays_exact():
    """An engine ladder tier latched onto a smaller dispatch (bucket_cap >
    rows) warns at trace time and still runs bit-identically at the
    clamped full-capacity tier."""
    cfg = CFG
    im = random_item_memory(jax.random.PRNGKey(0), cfg)
    task_w = jax.random.uniform(jax.random.PRNGKey(1), (cfg.M,))
    windows = _episode(cfg, 0.5, n_windows=2, seed=21)
    boxes = jnp.zeros((cfg.N_max, 4), jnp.float32)

    def run(fused, bucket_cap=None):
        st = pipeline.init_state(cfg, task_w)
        outs = []
        for q, v in windows:
            st, out, _ = STEP(st, im, jnp.asarray(q), jnp.asarray(v), boxes,
                              jnp.int32(0), cfg, fused=fused,
                              bucket_cap=bucket_cap)
            outs.append(np.asarray(out.scores))
        return st, outs

    base_st, base_outs = run("off")
    with pytest.warns(UserWarning, match="exceeds"):
        got_st, got_outs = run("compact", bucket_cap=4 * cfg.N_max)
    for a, b in zip(base_outs, got_outs):
        assert np.array_equal(a, b)
    _assert_cache_equal(base_st.cache, got_st.cache)


# --- step/engine-level differential ------------------------------------------

def test_decide_knob_validation():
    with pytest.raises(ValueError, match="decide='psychic'"):
        pipeline._resolve_decide("psychic")
    assert pipeline._resolve_decide(None) == "batched"
    assert pipeline._resolve_decide("scan") == "scan"


@pytest.mark.parametrize("serial", [False, True])
@pytest.mark.parametrize("tier", [1, 8, None])
def test_multi_stream_decide_modes_identical(serial, tier):
    """Both decide lowerings through the multi-stream compact step, every
    tier class (overflowing, partial, full), both apply lowerings."""
    cfg = TorrConfig(D=1024, B=8, M=32, K=8, N_max=8, delta_budget=128,
                     feat_dim=64)
    S, T = 4, 3
    im = random_item_memory(jax.random.PRNGKey(0), cfg)
    task_w = jax.random.uniform(jax.random.PRNGKey(1), (S, cfg.M))
    eps = [_episode(cfg, 0.7, T, seed=s) for s in range(S)]

    def run(fused, decide=None):
        st = pipeline.init_multi_stream_state(cfg, task_w)
        outs = []
        for t in range(T):
            q = jnp.asarray(np.stack([eps[s][t][0] for s in range(S)]))
            v = jnp.asarray(np.stack([eps[s][t][1] for s in range(S)]))
            b = jnp.zeros((S, cfg.N_max, 4), jnp.float32)
            qd = jnp.asarray([0, 2, cfg.q_hi, 0], jnp.int32)
            st, out, tel = MSTEP(st, im, q, v, b, qd, cfg, serial=serial,
                                 fused=fused, bucket_cap=tier, decide=decide)
            outs.append((np.asarray(out.scores), np.asarray(tel.path)))
        return st, outs

    base_st, base = run("off")
    for decide in ("scan", "batched"):
        got_st, got = run("compact", decide)
        for t, ((s0, p0), (s1, p1)) in enumerate(zip(base, got)):
            assert np.array_equal(s0, s1), (decide, t)
            assert np.array_equal(p0, p1), (decide, t)
        _assert_cache_equal(base_st.cache, got_st.cache, (decide,))


def test_stream_engine_decide_knob_bit_identical():
    """The engines' `decide` knob: pinned-compact and auto engines under
    both decide lowerings reproduce the oracle engine bit for bit."""
    from repro.serving.stream_engine import StreamEngine

    cfg = TorrConfig(D=1024, B=8, M=32, K=8, N_max=8, delta_budget=128,
                     feat_dim=64)
    S, T = 2, 5
    im = random_item_memory(jax.random.PRNGKey(0), cfg)
    task_w = np.asarray(jax.random.uniform(jax.random.PRNGKey(1),
                                           (S, cfg.M)))
    eps = [_episode(cfg, 0.9, T, seed=40 + s) for s in range(S)]

    def run(**kw):
        eng = StreamEngine(cfg, im, n_slots=S, **kw)
        for s in range(S):
            eng.admit(s, task_w[s])
            for q, v in eps[s]:
                eng.submit(s, q, v, np.zeros((cfg.N_max, 4), np.float32))
        return eng.drain()

    base = run(fused="off")
    for kw in (dict(fused="compact", bucket_cap=8, decide="scan"),
               dict(fused="compact", bucket_cap=8, decide="batched"),
               dict(fused="compact", bucket_cap=8),      # default = batched
               dict(fused="auto"),
               dict(fused="auto", decide="scan")):
        got = run(**kw)
        for s in range(S):
            for t in range(T):
                assert np.array_equal(np.asarray(got[s][t][0].scores),
                                      np.asarray(base[s][t][0].scores)), \
                    (kw, s, t)
                assert np.array_equal(np.asarray(got[s][t][1].path),
                                      np.asarray(base[s][t][1].path)), \
                    (kw, s, t)


@pytest.mark.slow
def test_decide_batched_four_fake_devices():
    """The batched decide under vmap + stream-axis sharding on 4 fake CPU
    devices: bit-identical to the single-device sequential oracle
    (subprocess: XLA_FLAGS must precede jax init)."""
    code = """
import numpy as np, jax, jax.numpy as jnp
assert jax.device_count() == 4, jax.devices()
from repro.core import pipeline
from repro.core.item_memory import random_item_memory
from repro.core.types import TorrConfig
from repro.runtime import sharding as shd
from repro.serving.async_engine import AsyncStreamEngine
from repro.serving.stream_engine import StreamEngine
from tests.test_decide_batched import _episode

cfg = TorrConfig(D=1024, B=8, M=32, K=8, N_max=8, delta_budget=128,
                 feat_dim=64)
S, T = 4, 3
im = random_item_memory(jax.random.PRNGKey(0), cfg)
task_w = np.asarray(jax.random.uniform(jax.random.PRNGKey(1), (S, cfg.M)))
eps = [_episode(cfg, 0.9, T, seed=60 + s) for s in range(S)]
boxes = np.zeros((cfg.N_max, 4), np.float32)

sync = StreamEngine(cfg, im, n_slots=S, fused="compact", decide="scan")
for s in range(S):
    sync.admit(s, task_w[s])
    for q, v in eps[s]:
        sync.submit(s, q, v, boxes)
base = sync.drain()

eng = AsyncStreamEngine(cfg, im, n_slots=S, mesh=shd.stream_mesh(),
                        fused="compact", bucket_cap=8, decide="batched",
                        paused=True)
futs = {s: [] for s in range(S)}
for s in range(S):
    eng.admit(s, task_w[s])
    for q, v in eps[s]:
        futs[s].append(eng.submit(s, q, v, boxes))
eng.start()
eng.flush(timeout=300)
for s in range(S):
    for t, f in enumerate(futs[s]):
        aout, atel = f.result(timeout=10)
        assert np.array_equal(aout.scores,
                              np.asarray(base[s][t][0].scores)), (s, t)
        assert np.array_equal(np.asarray(atel.path),
                              np.asarray(base[s][t][1].path)), (s, t)
eng.close()
print("DECIDE-BATCHED-SHARDED-MATCH")
"""
    env = dict(os.environ,
               PYTHONPATH=os.pathsep.join(
                   (SRC, os.path.dirname(SRC), os.path.dirname(__file__))),
               XLA_FLAGS="--xla_force_host_platform_device_count=4")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "DECIDE-BATCHED-SHARDED-MATCH" in out.stdout


# --- cycle model: decide-aware PSU pricing -----------------------------------

def test_cycle_model_batched_decide_never_costlier():
    for d_eff in (256, 1024, 8192):
        for n_valid in (1, 2, 8, 64, 128):
            scan = cycle_model.decide_psu_cycles(n_valid, d_eff, "scan")
            bat = cycle_model.decide_psu_cycles(n_valid, d_eff, "batched")
            assert bat <= scan, (d_eff, n_valid)
    # one proposal: nothing to batch, identical price
    assert (cycle_model.decide_psu_cycles(1, 1024, "batched")
            == cycle_model.decide_psu_cycles(1, 1024, "scan"))
    with pytest.raises(ValueError):
        cycle_model.decide_psu_cycles(4, 1024, "fancy")


def test_cycle_model_window_cost_decide_kwarg():
    path = np.array([PATH_FULL] * 4 + [PATH_DELTA] * 4)
    dc = np.array([0] * 4 + [10] * 4)
    ra = np.ones((8,), bool)
    kw = dict(banks=8, reasoner_active=ra, n_valid=8, cfg=CFG,
              rt_budget_s=1e-3)
    scan = cycle_model.window_cost(path, dc, decide="scan", **kw)
    bat = cycle_model.window_cost(path, dc, decide="batched", **kw)
    assert bat.cycles["psu"] < scan.cycles["psu"]
    assert bat.total_cycles < scan.total_cycles
