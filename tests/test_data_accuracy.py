"""Data pipelines + AP machinery + TOOD claims (fast subset)."""
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.data import tood_synth as ts
from repro.data.tokens import TokenStream


def test_token_stream_deterministic_skip_ahead():
    cfg = get_smoke("deepseek-7b")
    s1 = TokenStream(cfg, 2, 16, seed=3)
    s2 = TokenStream(cfg, 2, 16, seed=3)
    b_direct = s1.batch_at(41)
    it = s2.stream(start_step=41)
    b_stream = next(it)
    np.testing.assert_array_equal(b_direct["tokens"], b_stream["tokens"])


def test_token_stream_has_bigram_structure():
    cfg = get_smoke("deepseek-7b")
    s = TokenStream(cfg, 4, 256, seed=0)
    b = s.batch_at(0)
    toks = b["tokens"]
    hits = np.mean(toks[:, 1:] == s.successor[toks[:, :-1]])
    assert hits > 0.3, "successor structure missing -> nothing to learn"


def test_iou_matrix():
    a = np.array([[0, 0, 1, 1]], np.float32)
    b = np.array([[0, 0, 1, 1], [0.5, 0.5, 1.5, 1.5], [2, 2, 3, 3]], np.float32)
    iou = ts.iou_matrix(a, b)
    np.testing.assert_allclose(iou[0], [1.0, 0.25 / 1.75, 0.0], atol=1e-6)


def test_ap_perfect_and_empty():
    gt = [np.array([[0, 0, 1, 1], [2, 2, 3, 3]], np.float32)]
    boxes = [np.array([[0, 0, 1, 1], [2, 2, 3, 3]], np.float32)]
    scores = [np.array([0.9, 0.8])]
    assert ts.average_precision(scores, boxes, gt) == pytest.approx(1.0)
    # all misses
    boxes_bad = [np.array([[5, 5, 6, 6], [7, 7, 8, 8]], np.float32)]
    assert ts.average_precision(scores, boxes_bad, gt) == 0.0


def test_ap_penalizes_false_positives():
    gt = [np.array([[0, 0, 1, 1]], np.float32)]
    boxes = [np.array([[0, 0, 1, 1], [5, 5, 6, 6]], np.float32)]
    ap_fp_high = ts.average_precision([np.array([0.2, 0.9])], boxes, gt)
    ap_fp_low = ts.average_precision([np.array([0.9, 0.2])], boxes, gt)
    assert ap_fp_low > ap_fp_high


def test_sequences_are_temporally_coherent():
    world = ts.make_world(0)
    frames = ts.simulate_sequence(world, 3, 10, seed=0)  # have breakfast
    # consecutive frames share most object classes
    same = [np.mean(frames[i].classes[:7] == frames[i + 1].classes[:7])
            for i in range(9)]
    assert np.mean(same) > 0.7


def test_every_task_has_ground_truth():
    world = ts.make_world(0)
    for t in range(5):
        frames = ts.simulate_sequence(world, t, 12, seed=0)
        assert sum(len(f.gt_boxes) for f in frames) > 0, ts.TASKS[t]
