"""Observability subsystem: registry semantics, exposition, flight replay.

Covers the ISSUE 7 tentpole from the outside in: metric family semantics
(registration idempotence, label cardinality bound, histogram bucket
edges, snapshot isolation), the Prometheus text exposition (line-format
golden test + a parse check over a real serving run), the flight
recorder's bounded ring + JSONL round-trip, span nesting, and the two
engine integrations — a sync engine whose registry counters reconcile
with its own summary, and the acceptance-criteria property: a governed
async run whose flight-recorder plan timeline bit-matches the governor's
own ``plan_log``. The cancelled-future path pins the telemetry-loss
accounting (``telemetry_dropped``) the subsystem exists to close.
"""
import json
import urllib.request

import numpy as np
import pytest

import jax

from repro.control import Governor, GovernorPolicy
from repro.core.item_memory import random_item_memory
from repro.obs.bridge import StepObserver, telemetry_digest
from repro.obs.export import (MetricsServer, health_response,
                              prometheus_text, write_json_snapshot)
from repro.obs.flight import (FLIGHT_SCHEMA_VERSION, FlightRecorder,
                              load_jsonl, plan_timeline, replay)
from repro.obs.metrics import MetricsRegistry
from repro.obs.spans import NULL_SPAN, current_span, span, span_stack
from repro.serving.async_engine import AsyncStreamEngine
from repro.serving.deadline import DeadlinePolicy, DeadlineTracker
from repro.serving.stream_engine import StreamEngine

from test_multistream import CFG, _make_inputs

FLUSH_S = 120


# --- metrics registry -------------------------------------------------------


def test_counter_gauge_basics():
    reg = MetricsRegistry()
    c = reg.counter("torr_c_total", "help")
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    g = reg.gauge("torr_g")
    g.set(4.0)
    g.inc()
    g.dec(2.0)
    assert g.value == 3.0
    snap = reg.snapshot()
    assert snap["torr_c_total"]["type"] == "counter"
    assert snap["torr_c_total"]["series"] == [{"labels": {}, "value": 3.5}]
    assert snap["torr_g"]["series"][0]["value"] == 3.0


def test_registration_idempotent_and_schema_conflicts():
    reg = MetricsRegistry()
    a = reg.counter("torr_x_total", "h", ["k"])
    assert reg.counter("torr_x_total", "h", ["k"]) is a
    with pytest.raises(ValueError):
        reg.gauge("torr_x_total")                      # kind conflict
    with pytest.raises(ValueError):
        reg.counter("torr_x_total", "h", ["other"])    # label conflict
    h = reg.histogram("torr_h_seconds", buckets=(1.0, 2.0))
    assert reg.histogram("torr_h_seconds", buckets=(1.0, 2.0)) is h
    with pytest.raises(ValueError):
        reg.histogram("torr_h_seconds", buckets=(1.0, 3.0))
    with pytest.raises(ValueError):
        reg.counter("0bad")                            # invalid name
    with pytest.raises(ValueError):
        reg.counter("torr_y_total", "h", ["bad-label"])


def test_label_cardinality_bound():
    reg = MetricsRegistry(max_series=3)
    c = reg.counter("torr_many_total", "h", ["k"])
    for i in range(3):
        c.labels(k=str(i)).inc()
    c.labels(k="0").inc()                              # cached: no new series
    with pytest.raises(ValueError, match="max_series"):
        c.labels(k="overflow")
    with pytest.raises(ValueError, match="takes labels"):
        c.labels(wrong="x")


def test_histogram_bucket_edges():
    reg = MetricsRegistry()
    h = reg.histogram("torr_lat_seconds", "h", buckets=(1.0, 2.0))
    for v in (0.5, 1.0, 1.5, 5.0):                     # le is inclusive
        h.observe(v)
    (s,) = reg.snapshot()["torr_lat_seconds"]["series"]
    assert s["bucket_edges"] == [1.0, 2.0]
    assert s["buckets"] == [2, 1, 1]                   # per-bucket, not cum
    assert s["count"] == 4
    assert s["sum"] == pytest.approx(8.0)
    with pytest.raises(ValueError):
        reg.histogram("torr_bad", buckets=(2.0, 1.0))  # not increasing
    with pytest.raises(ValueError):
        reg.histogram("torr_bad2", buckets=(1.0, float("inf")))


def test_snapshot_isolation():
    reg = MetricsRegistry()
    c = reg.counter("torr_c_total")
    h = reg.histogram("torr_h_seconds", buckets=(1.0,))
    c.inc()
    h.observe(0.5)
    snap = reg.snapshot()
    c.inc(10)
    h.observe(0.5)
    assert snap["torr_c_total"]["series"][0]["value"] == 1.0
    assert snap["torr_h_seconds"]["series"][0]["count"] == 1
    assert reg.snapshot()["torr_c_total"]["series"][0]["value"] == 11.0


# --- Prometheus text exposition ---------------------------------------------


def test_prometheus_text_golden():
    reg = MetricsRegistry()
    c = reg.counter("torr_widgets_total", "Widgets made.", ["kind"])
    c.labels(kind="a").inc()
    c.labels(kind='we"ird\\').inc(2)
    reg.gauge("torr_temp", "Temp.").set(1.5)
    h = reg.histogram("torr_lat_seconds", "Lat.", buckets=(0.1, 1.0))
    for v in (0.05, 0.5, 2.0):
        h.observe(v)
    assert prometheus_text(reg) == (
        "# HELP torr_lat_seconds Lat.\n"
        "# TYPE torr_lat_seconds histogram\n"
        'torr_lat_seconds_bucket{le="0.1"} 1\n'
        'torr_lat_seconds_bucket{le="1"} 2\n'
        'torr_lat_seconds_bucket{le="+Inf"} 3\n'
        "torr_lat_seconds_sum 2.55\n"
        "torr_lat_seconds_count 3\n"
        "# HELP torr_temp Temp.\n"
        "# TYPE torr_temp gauge\n"
        "torr_temp 1.5\n"
        "# HELP torr_widgets_total Widgets made.\n"
        "# TYPE torr_widgets_total counter\n"
        'torr_widgets_total{kind="a"} 1\n'
        'torr_widgets_total{kind="we\\"ird\\\\"} 2\n'
    )
    # rendering an already-taken snapshot is identical to the live registry
    assert prometheus_text(reg.snapshot()) == prometheus_text(reg)


def _assert_parseable(text: str) -> set:
    """Minimal 0.0.4 line-format check; returns the family names."""
    import re
    sample = re.compile(
        r'^[a-zA-Z_:][a-zA-Z0-9_:]*(\{[a-zA-Z_][a-zA-Z0-9_]*="[^"]*"'
        r'(,[a-zA-Z_][a-zA-Z0-9_]*="(\\.|[^"\\])*")*\})? \S+$')
    families = set()
    for line in text.strip().splitlines():
        if line.startswith("# TYPE "):
            families.add(line.split()[2])
        elif not line.startswith("#"):
            assert sample.match(line), line
    return families


def test_metrics_server_scrape(tmp_path):
    reg = MetricsRegistry()
    reg.counter("torr_scrapes_total", "h").inc(7)
    srv = MetricsServer(reg, port=0)
    port = srv.start()
    try:
        with urllib.request.urlopen(
                f"http://127.0.0.1:{port}/metrics", timeout=10) as r:
            assert r.headers["Content-Type"].startswith(
                "text/plain; version=0.0.4")
            text = r.read().decode()
        assert "torr_scrapes_total 7" in text
        _assert_parseable(text)
        with urllib.request.urlopen(
                f"http://127.0.0.1:{port}/metrics.json", timeout=10) as r:
            snap = json.loads(r.read())
        assert snap["torr_scrapes_total"]["series"][0]["value"] == 7
    finally:
        srv.close()
    path = tmp_path / "metrics.json"
    write_json_snapshot(reg, str(path))
    doc = json.loads(path.read_text())
    assert doc["format"] == "torr-metrics-snapshot-v1"
    assert doc["metrics"]["torr_scrapes_total"]["series"][0]["value"] == 7


def test_health_response_shapes_and_fail_closed():
    # None / bools
    assert health_response(None) == (200, {"ready": True})
    assert health_response(True) == (200, {"ready": True})
    assert health_response(False) == (503, {"ready": False})
    # callable returning a bool or a supervisor-style health dict
    assert health_response(lambda: True)[0] == 200
    st, state = health_response(
        lambda: {"ready": False, "recovering": True, "restarts": 2})
    assert st == 503 and state["recovering"] is True
    # a raising readiness check must fail CLOSED, never 200
    def boom():
        raise RuntimeError("probe crashed")
    st, state = health_response(boom)
    assert st == 503 and state["ready"] is False
    assert "RuntimeError" in state["error"]


def test_metrics_server_healthz_and_readyz():
    reg = MetricsRegistry()
    state = {"ready": True}
    srv = MetricsServer(reg, port=0, ready=lambda: dict(state))
    port = srv.start()

    def probe(path):
        try:
            with urllib.request.urlopen(
                    f"http://127.0.0.1:{port}{path}", timeout=10) as r:
                return r.status, json.loads(r.read())
        except urllib.error.HTTPError as e:
            return e.code, json.loads(e.read())

    try:
        assert probe("/healthz") == (200, {"ok": True})
        assert probe("/readyz")[0] == 200
        # readiness flips with the source (a recovering supervisor)
        state["ready"] = False
        state["recovering"] = True
        st, body = probe("/readyz")
        assert st == 503 and body["recovering"] is True
        # liveness is unaffected by readiness
        assert probe("/healthz") == (200, {"ok": True})
        # launchers wire the supervisor in late: set_ready rebinds
        srv.set_ready(lambda: True)
        assert probe("/readyz")[0] == 200
    finally:
        srv.close()


# --- flight recorder --------------------------------------------------------


def test_flight_ring_wraparound():
    fl = FlightRecorder(capacity=4)
    for i in range(10):
        fl.record(n_windows=i)
    assert len(fl) == 4
    assert fl.dropped == 6
    recs = fl.records()
    assert [r["step"] for r in recs] == [6, 7, 8, 9]   # oldest fell off
    assert all(r["v"] == FLIGHT_SCHEMA_VERSION for r in recs)
    # the returned record is mutable: late completion lands in the ring
    rec = fl.record()
    rec["telemetry"] = {"n_windows": 1}
    assert fl.records()[-1]["telemetry"] == {"n_windows": 1}


def test_flight_drop_counter_and_first_drop_warning():
    """Wraparound is surfaced: a counter when a registry is wired, and a
    one-line RuntimeWarning on the *first* dropped record only."""
    import warnings
    reg = MetricsRegistry()
    fl = FlightRecorder(capacity=2, metrics=reg)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        for i in range(5):
            fl.record(n_windows=i)
    assert fl.dropped == 3
    snap = reg.snapshot()
    assert snap["torr_flight_dropped_total"]["series"][0]["value"] == 3
    warns = [w for w in caught if issubclass(w.category, RuntimeWarning)]
    assert len(warns) == 1                             # first drop only
    assert "capacity=2" in str(warns[0].message)
    # no registry: the Python-side counter still counts, silently
    with warnings.catch_warnings():
        warnings.simplefilter("error", RuntimeWarning)
        fl2 = FlightRecorder(capacity=1)
        fl2.record()
        with pytest.raises(RuntimeWarning):
            fl2.record()
    assert fl2.dropped == 1


def test_flight_jsonl_round_trip(tmp_path):
    fl = FlightRecorder()
    fl.record(n_windows=np.int32(3), plan={"banks": np.int64(8), "planes": 4},
              governor={"slack": np.float32(0.5), "level": 0})
    fl.record(n_windows=2, lowering={"fused": "compact", "decide": None,
                                     "bucket_tier": 64})
    path = tmp_path / "flight.jsonl"
    assert fl.dump_jsonl(str(path)) == 2
    loaded = load_jsonl(str(path))
    assert loaded == [
        {"v": 1, "n_windows": 3, "plan": {"banks": 8, "planes": 4},
         "governor": {"slack": 0.5, "level": 0}, "step": 0},
        {"v": 1, "n_windows": 2, "lowering": {"fused": "compact",
                                              "decide": None,
                                              "bucket_tier": 64}, "step": 1},
    ]
    steps = replay(loaded)
    assert [s.step for s in steps] == [0, 1]
    assert steps[0].plan == (8, 4, 0)
    assert steps[1].fused == "compact" and steps[1].bucket_tier == 64


def test_replay_skips_foreign_versions_and_sorts():
    recs = [
        {"v": FLIGHT_SCHEMA_VERSION, "step": 2,
         "plan": {"banks": 4, "planes": 2}, "governor": {"level": 3}},
        {"v": 999, "step": 0, "plan": {"banks": 1, "planes": 1}},
        {"step": 1},                                   # unversioned: skipped
        {"v": FLIGHT_SCHEMA_VERSION, "step": 1,
         "plan": {"banks": 8, "planes": 4}, "governor": {"level": 0}},
    ]
    assert plan_timeline(recs) == [(8, 4, 0), (4, 2, 3)]


# --- spans ------------------------------------------------------------------


def test_span_nesting_and_histogram():
    reg = MetricsRegistry()
    assert current_span() is None
    with span("outer", reg):
        assert current_span() == "outer"
        with span("inner", reg):
            assert span_stack() == ("outer", "inner")
        assert span_stack() == ("outer",)
    assert span_stack() == ()

    @span("work", reg)
    def work():
        return current_span()

    assert work() == "work"
    work()
    snap = reg.snapshot()["torr_span_duration_seconds"]
    by_label = {s["labels"]["span"]: s for s in snap["series"]}
    assert by_label["outer"]["count"] == 1
    assert by_label["inner"]["count"] == 1
    assert by_label["work"]["count"] == 2
    with NULL_SPAN:                                     # no stack, no metric
        assert current_span() is None


# --- engine integration -----------------------------------------------------


def _submit_all(eng, task_w, steps, S):
    futs = []
    for s in range(S):
        eng.admit(f"cam{s}", task_w[s])
        for q, valid, boxes, _qd in steps:
            futs.append(eng.submit(f"cam{s}", q[s], valid[s], boxes[s]))
    return futs


def test_sync_engine_metrics_reconcile_with_summary():
    cfg = CFG
    S, T = 3, 4
    im = random_item_memory(jax.random.PRNGKey(0), cfg)
    task_w = np.asarray(jax.random.uniform(jax.random.PRNGKey(1), (S, cfg.M)))
    steps = _make_inputs(cfg, S, T)
    reg, fl = MetricsRegistry(), FlightRecorder()
    eng = StreamEngine(cfg, im, n_slots=S, metrics=reg, flight=fl)
    for s in range(S):
        eng.admit(f"cam{s}", task_w[s])
        for q, valid, boxes, _qd in steps:
            eng.submit(f"cam{s}", q[s], valid[s], boxes[s])
    eng.drain()
    summ = eng.summary()
    assert summ["telemetry_dropped"] == 0
    snap = reg.snapshot()

    def total(name):
        return sum(s["value"] for s in snap[name]["series"])

    assert total("torr_steps_total") == summ["steps"] == T
    assert total("torr_windows_total") == summ["windows"] == S * T
    assert total("torr_streams_admitted_total") == S
    # every valid proposal resolved exactly one path — exact even though
    # the submitted valid masks are not prefix-packed
    assert total("torr_path_total") == sum(
        int(np.sum(v)) for _q, v, _b, _qd in steps)
    # flight: one completed record per step, digest attached after fold
    recs = fl.records()
    assert len(recs) == T
    assert all("telemetry" in r and "lowering" in r for r in recs)
    assert sum(r["telemetry"]["n_windows"] for r in recs) == S * T


def test_governed_async_flight_matches_governor_plan_log():
    """Acceptance: the replayed flight plan timeline IS the governor log."""
    cfg = CFG
    S, T = 4, 6
    im = random_item_memory(jax.random.PRNGKey(0), cfg)
    task_w = np.asarray(jax.random.uniform(jax.random.PRNGKey(1), (S, cfg.M)))
    steps = _make_inputs(cfg, S, T)
    reg, fl = MetricsRegistry(), FlightRecorder()
    # generous budget + shedding off: every window is served, so the
    # record count is deterministic (T steps)
    tracker = DeadlineTracker(
        DeadlinePolicy(budget_s=30.0, escalate_margin_s=15.0,
                       allow_shed=False),
        metrics=reg)
    gov = Governor(cfg, GovernorPolicy(budget_s=30.0), metrics=reg)
    with AsyncStreamEngine(cfg, im, n_slots=S, tracker=tracker, governor=gov,
                           paused=True, metrics=reg, flight=fl) as eng:
        futs = _submit_all(eng, task_w, steps, S)
        eng.start()
        eng.flush(timeout=FLUSH_S)
        for f in futs:
            f.result(timeout=10)
    recs = fl.records()
    assert len(recs) == len(gov.plan_log) == T
    assert plan_timeline(recs) == gov.plan_log
    assert all("telemetry" in r and "lowering" in r for r in recs)
    for r in recs:
        assert isinstance(r["governor"]["level"], int)
        assert r["governor"]["slack"] is not None
    # digest vocabulary: recorded lowering matches what was requested
    assert all(r["lowering"]["fused"] == r["requested"]["fused"]
               or r["requested"]["fused"] is None for r in recs)
    # exposition covers the acceptance floor of 12 distinct families
    families = _assert_parseable(prometheus_text(reg))
    assert len(families) >= 12
    assert {"torr_steps_total", "torr_path_total", "torr_plan_level",
            "torr_energy_ewma_mj", "torr_deadline_decisions_total",
            "torr_window_latency_seconds", "torr_span_duration_seconds",
            "torr_telemetry_dropped_total"} <= families
    assert eng.summary()["telemetry_dropped"] == 0


def test_cancelled_future_counts_as_telemetry_dropped():
    """A window orphaned mid-flight is counted, not silently lost."""
    cfg = CFG
    S, T = 2, 3
    im = random_item_memory(jax.random.PRNGKey(0), cfg)
    task_w = np.asarray(jax.random.uniform(jax.random.PRNGKey(1), (S, cfg.M)))
    steps = _make_inputs(cfg, S, T)
    reg = MetricsRegistry()
    with AsyncStreamEngine(cfg, im, n_slots=S, paused=True,
                           metrics=reg) as eng:
        futs = _submit_all(eng, task_w, steps, S)
        assert futs[0].cancel()          # orphan one pending window
        eng.start()
        eng.flush(timeout=FLUSH_S)
        for f in futs[1:]:
            f.result(timeout=10)
    assert eng.stats.telemetry_dropped == 1
    assert eng.summary()["telemetry_dropped"] == 1
    snap = reg.snapshot()
    assert snap["torr_telemetry_dropped_total"]["series"][0]["value"] == 1


def test_step_observer_digest_without_registry():
    """flight-only / metrics-only degradation paths stay functional."""
    fl = FlightRecorder()
    obs = StepObserver(registry=None, flight=fl)
    obs.on_admit()
    rec = obs.on_dispatch(2, 0, requested=("switch", None, None))
    assert rec is not None and rec["requested"]["fused"] == "switch"
    obs2 = StepObserver(registry=MetricsRegistry(), flight=None)
    assert obs2.on_dispatch(2, 0) is None               # no flight: no rec
