"""QoS control plane: knob plans, plan-tag exactness, governor dynamics.

The tentpole invariants (ISSUE 3):

  * pinned to the full plan, the governed engine/step is *bit-identical* to
    the ungoverned one;
  * under any reduced plan, full-path scores equal the jnp oracle restricted
    to the same dims/bit-planes;
  * a delta accumulator tagged under one (banks, planes) plan is rejected
    after any plan switch (Eq. 6 exactness), property-tested across plan
    pairs via the hypothesis-optional shim.
"""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

import jax
import jax.numpy as jnp

from repro.control import (Governor, GovernorPolicy, KnobPlan, build_ladder,
                           full_plan, ladder_rel_cost, plan_level)
from repro.core import aligner, hdc, pipeline, query_cache
from repro.core.item_memory import (plan_dim_mask, plan_word_mask,
                                    plan_word_sel, random_item_memory)
from repro.core.types import PATH_DELTA, PATH_FULL, TorrConfig
from repro.kernels import ops

CFG = TorrConfig(D=1024, B=8, M=32, K=4, N_max=8, delta_budget=128,
                 feat_dim=64)

PLANS = [(8, 4), (8, 2), (8, 1), (4, 4), (4, 1), (2, 2), (1, 1)]


def _plan(banks, planes, cfg=CFG):
    return KnobPlan(banks=banks, planes=planes, plane_total=cfg.bit_planes)


def _window(cfg, seed, n_valid=None):
    q_bip = hdc.random_hv(jax.random.PRNGKey(seed), (cfg.N_max, cfg.D))
    valid = np.arange(cfg.N_max) < (n_valid if n_valid is not None else cfg.K - 1)
    return q_bip, jnp.asarray(valid), jnp.zeros((cfg.N_max, 4), jnp.float32)


# --- plan geometry ----------------------------------------------------------

def test_plan_word_sel_matches_mask():
    """The static kernel-side word selection and the traced mask agree for
    every (banks, planes) knob setting."""
    for banks, planes in PLANS:
        sel = plan_word_sel(CFG, banks, planes)
        mask = np.asarray(plan_word_mask(CFG, banks, planes))
        assert sorted(sel.tolist()) == np.nonzero(mask)[0].tolist(), \
            (banks, planes)
        assert sel.size * 32 == int(CFG.d_eff_planned(banks, planes))


def test_pmajor_is_plane_permuted_packed():
    im = random_item_memory(jax.random.PRNGKey(0), CFG)
    from repro.core.item_memory import plane_permutation
    perm = plane_permutation(CFG.words, CFG.bit_planes)
    assert np.array_equal(np.asarray(im.pmajor),
                          np.asarray(im.packed)[:, perm])


# --- kernel wrappers vs jnp oracle -----------------------------------------

@pytest.mark.parametrize("banks,planes", PLANS)
def test_packed_similarity_planned_matches_oracle(banks, planes):
    """Plane-gated scan == integer dot over the plan's enabled dims."""
    hv = hdc.random_hv(jax.random.PRNGKey(0), (CFG.M, CFG.D))
    q = hdc.random_hv(jax.random.PRNGKey(1), (5, CFG.D))
    im = random_item_memory(jax.random.PRNGKey(0), CFG)
    dmask = np.asarray(plan_dim_mask(CFG, banks, planes))
    assert np.array_equal(np.asarray(im.bipolar), np.asarray(hv))

    acc, cos = ops.packed_similarity(
        hdc.pack_bits(q), im.packed, banks=banks, bank_words=CFG.bank_words,
        planes=planes, plane_total=CFG.bit_planes, pmajor=im.pmajor)
    want = jnp.einsum("nd,md->nm",
                      jnp.where(dmask, q.astype(jnp.int32), 0),
                      jnp.where(dmask, hv.astype(jnp.int32), 0))
    assert np.array_equal(np.asarray(acc), np.asarray(want)), (banks, planes)
    d_eff = int(CFG.d_eff_planned(banks, planes))
    assert np.allclose(np.asarray(cos), np.asarray(want) / d_eff)

    # without the pmajor fast path (static gather) the result is identical
    acc2, _ = ops.packed_similarity(
        hdc.pack_bits(q), im.packed, banks=banks, bank_words=CFG.bank_words,
        planes=planes, plane_total=CFG.bit_planes)
    assert np.array_equal(np.asarray(acc2), np.asarray(acc))


@pytest.mark.parametrize("banks,planes", [(8, 4), (8, 2), (4, 1), (2, 2)])
def test_cache_nearest_planned_matches_core(banks, planes):
    cache = query_cache.init_cache(CFG)
    from repro.core.types import plan_tag
    for i in range(3):
        qe = hdc.pack_bits(hdc.random_hv(jax.random.PRNGKey(10 + i), (CFG.D,)))
        cache = query_cache.write_entry(
            cache, jnp.int32(i), packed=qe,
            acc=jnp.zeros((CFG.M,), jnp.int32),
            acc_tag=plan_tag(banks, planes),
            out=jnp.zeros((CFG.M,), jnp.float32),
            topk_key=jnp.zeros((CFG.top_k,), jnp.int32), margin=jnp.float32(0))
    qs = jax.vmap(hdc.pack_bits)(
        hdc.random_hv(jax.random.PRNGKey(99), (4, CFG.D)))
    idx, rho, ham = ops.cache_nearest(
        qs, cache.packed, cache.valid, banks=banks,
        bank_words=CFG.bank_words, planes=planes,
        plane_total=CFG.bit_planes)
    for n in range(qs.shape[0]):
        i1, r1, h1 = query_cache.nearest(cache, qs[n], CFG, banks, planes)
        assert int(idx[n]) == int(i1)
        assert float(rho[n]) == float(r1)
        assert int(ham[n]) == int(h1)


# --- pipeline under plans ---------------------------------------------------

def test_full_plan_is_bit_exact_noop():
    """plan=full_plan(cfg) reproduces plan=None bit-for-bit over a warm
    cache sequence (full -> delta -> bypass traffic)."""
    cfg = CFG
    im = random_item_memory(jax.random.PRNGKey(0), cfg)
    task_w = jax.random.uniform(jax.random.PRNGKey(1), (cfg.M,))
    step = jax.jit(pipeline.torr_window_step,
                   static_argnames=("cfg", "plan"))

    states = [pipeline.init_state(cfg, task_w) for _ in range(2)]
    q_bip, valid, boxes = _window(cfg, seed=2)
    for t, qd in enumerate([0, 0, cfg.q_hi]):
        q = jax.vmap(hdc.pack_bits)(
            q_bip.at[:, t::131].multiply(-1) if t else q_bip)
        outs = []
        for i, plan in enumerate([None, full_plan(cfg)]):
            states[i], out, tel = step(states[i], im, q, valid, boxes,
                                       jnp.int32(qd), cfg, plan=plan)
            outs.append((out, tel))
        (o0, t0), (o1, t1) = outs
        assert np.array_equal(np.asarray(o0.scores), np.asarray(o1.scores))
        for f in ("path", "delta_count", "banks", "rho", "planes",
                  "high_load"):
            assert np.array_equal(np.asarray(getattr(t0, f)),
                                  np.asarray(getattr(t1, f))), (t, f)


@pytest.mark.parametrize("banks,planes", [(8, 2), (4, 4), (4, 2), (2, 1)])
def test_reduced_plan_full_scores_match_oracle(banks, planes):
    """Cold-cache full-path scores under a reduced plan == the jnp oracle
    restricted to the plan's dims/planes (times the task weights — the
    reasoner multiply, ungated on a cold cache)."""
    cfg = CFG
    im = random_item_memory(jax.random.PRNGKey(0), cfg)
    task_w = jax.random.uniform(jax.random.PRNGKey(1), (cfg.M,))
    plan = _plan(banks, planes)
    state = pipeline.init_state(cfg, task_w)
    q_bip, valid, boxes = _window(cfg, seed=3)
    q = jax.vmap(hdc.pack_bits)(q_bip)

    _, out, tel = pipeline.torr_window_step(
        state, im, q, valid, boxes, jnp.int32(0), cfg, plan=plan)
    nv = int(np.sum(np.asarray(valid)))
    assert (np.asarray(tel.path)[:nv] == PATH_FULL).all()
    assert int(tel.banks) == banks and int(tel.planes) == planes

    wmask = plan_word_mask(cfg, banks, planes)
    d_eff = int(cfg.d_eff_planned(banks, planes))
    for n in range(nv):
        acc = aligner.full_dot(q[n], im, wmask)
        want = acc.astype(jnp.float32) / d_eff * task_w
        assert np.array_equal(np.asarray(out.scores[n]), np.asarray(want)), n


@given(st.integers(0, 2**31 - 1),
       st.sampled_from(PLANS), st.sampled_from(PLANS))
@settings(max_examples=8, deadline=None)
def test_plan_switch_rejects_stale_delta(seed, pa, pb):
    """Property (Eq. 6): a delta accumulator tagged under plan A is never
    delta-corrected under plan B != A — the window re-scans full, and its
    scores are bit-identical to a cold-cache run under plan B."""
    if pa == pb:
        return
    cfg = CFG
    rng = np.random.default_rng(seed)
    im = random_item_memory(jax.random.PRNGKey(seed % 7), cfg)
    task_w = jax.random.uniform(jax.random.PRNGKey(1), (cfg.M,))
    step = jax.jit(pipeline.torr_window_step,
                   static_argnames=("cfg", "plan"))
    plan_a, plan_b = _plan(*pa), _plan(*pb)

    q_bip = hdc.random_hv(jax.random.PRNGKey(seed % 1009), (cfg.N_max, cfg.D))
    valid = jnp.asarray(np.arange(cfg.N_max) < cfg.K - 1)
    boxes = jnp.zeros((cfg.N_max, 4), jnp.float32)
    q0 = jax.vmap(hdc.pack_bits)(q_bip)
    # drift a few dims of word 0 (plane 0, bank 0: enabled under every plan)
    flips = rng.choice(32, size=4, replace=False)
    q_bip2 = q_bip.at[:, flips].multiply(-1)
    q1 = jax.vmap(hdc.pack_bits)(q_bip2)

    state = pipeline.init_state(cfg, task_w)
    state, _, tel0 = step(state, im, q0, valid, boxes, jnp.int32(0), cfg,
                          plan=plan_a)
    nv = int(np.sum(np.asarray(valid)))
    assert (np.asarray(tel0.path)[:nv] == PATH_FULL).all()

    # same plan: drift takes the delta path (the tag matches)...
    st_a, out_a, tel_a = step(state, im, q1, valid, boxes, jnp.int32(0), cfg,
                              plan=plan_a)
    assert (np.asarray(tel_a.path)[:nv] == PATH_DELTA).all(), (pa, pb)

    # ...switched plan: the stale tag must force a full re-scan, and the
    # re-scan is exact — scores equal the oracle over plan B's dims (for
    # proposals where the reasoner multiply ran; a gated proposal forwards
    # its cached output by design)
    _, out_b, tel_b = step(state, im, q1, valid, boxes, jnp.int32(0), cfg,
                           plan=plan_b)
    assert (np.asarray(tel_b.path)[:nv] == PATH_FULL).all(), (pa, pb)
    wmask_b = plan_word_mask(cfg, plan_b.banks, plan_b.planes)
    d_eff_b = int(cfg.d_eff_planned(plan_b.banks, plan_b.planes))
    for n in range(nv):
        if bool(tel_b.reasoner_active[n]):
            acc = aligner.full_dot(q1[n], im, wmask_b)
            want = acc.astype(jnp.float32) / d_eff_b * task_w
            assert np.array_equal(np.asarray(out_b.scores[n]),
                                  np.asarray(want)), (pa, pb, n)


# --- fused full path vs oracle (ISSUE 4 tentpole) ---------------------------

TELEM_CHECK = ("path", "delta_count", "banks", "rho", "planes", "high_load")


def _run_windows(cfg, im, task_w, plan, fused, n_windows=3, qd_seq=None,
                 seed=11):
    """Drive a warm full -> delta -> bypass sequence through one lowering;
    returns (state, [(out, tel), ...])."""
    step = jax.jit(pipeline.torr_window_step,
                   static_argnames=("cfg", "plan", "fused"))
    state = pipeline.init_state(cfg, task_w)
    q_bip, valid, boxes = _window(cfg, seed=seed)
    outs = []
    for t in range(n_windows):
        q = jax.vmap(hdc.pack_bits)(
            q_bip.at[:, t::131].multiply(-1) if t else q_bip)
        qd = jnp.int32((qd_seq or [0] * n_windows)[t])
        state, out, tel = step(state, im, q, valid, boxes, qd, cfg,
                               plan=plan, fused=fused)
        outs.append((out, tel))
    return state, outs


@pytest.mark.parametrize("banks,planes", PLANS)
@pytest.mark.parametrize("mode", ["switch", "prefix"])
def test_fused_full_path_bit_identical_over_plan_grid(banks, planes, mode):
    """Acceptance (ISSUE 4): the fused jitted full path is bit-identical to
    the jnp-oracle step — argmax, scores, telemetry AND cache state — for
    every (banks, planes) plan in the ladder, in both fused lowerings,
    over a warm window sequence that exercises full, delta and bypass."""
    cfg = CFG
    im = random_item_memory(jax.random.PRNGKey(0), cfg)
    task_w = jax.random.uniform(jax.random.PRNGKey(1), (cfg.M,))
    plan = _plan(banks, planes)
    qd_seq = [0, 0, cfg.q_hi]

    st0, base = _run_windows(cfg, im, task_w, plan, "off", qd_seq=qd_seq)
    st1, got = _run_windows(cfg, im, task_w, plan, mode, qd_seq=qd_seq)
    for t, ((o0, t0), (o1, t1)) in enumerate(zip(base, got)):
        assert np.array_equal(np.asarray(o0.scores), np.asarray(o1.scores))
        assert np.array_equal(np.asarray(o0.best), np.asarray(o1.best))
        for f in TELEM_CHECK:
            assert np.array_equal(np.asarray(getattr(t0, f)),
                                  np.asarray(getattr(t1, f))), (t, f)
    for a, b in zip(jax.tree_util.tree_leaves(st0.cache),
                    jax.tree_util.tree_leaves(st1.cache)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("mode", ["switch", "prefix"])
def test_fused_ragged_fallback_bit_identical(mode):
    """Ragged M (not a multiple of 8) rides the transparent oracle
    fallback inside the fused dispatch — still bit-identical end to end."""
    cfg = TorrConfig(D=1024, B=8, M=27, K=4, N_max=5, delta_budget=128,
                     feat_dim=64)
    im = random_item_memory(jax.random.PRNGKey(3), cfg)
    task_w = jax.random.uniform(jax.random.PRNGKey(4), (cfg.M,))
    _, base = _run_windows(cfg, im, task_w, None, "off", seed=5)
    _, got = _run_windows(cfg, im, task_w, None, mode, seed=5)
    for (o0, _), (o1, _) in zip(base, got):
        assert np.array_equal(np.asarray(o0.scores), np.asarray(o1.scores))


@pytest.mark.parametrize("mode", ["switch", "prefix"])
def test_fused_delta_then_full_exact_after_plan_switch(mode):
    """Eq. 6 exactness through the fused path: delta-correct under plan A,
    then a plan switch forces a full re-scan whose scores equal the oracle
    restricted to plan B — same invariant as the oracle-path property test,
    run end-to-end on the fused lowering."""
    cfg = CFG
    im = random_item_memory(jax.random.PRNGKey(0), cfg)
    task_w = jax.random.uniform(jax.random.PRNGKey(1), (cfg.M,))
    step = jax.jit(pipeline.torr_window_step,
                   static_argnames=("cfg", "plan", "fused"))
    plan_a, plan_b = _plan(8, 4), _plan(4, 2)
    q_bip, valid, boxes = _window(cfg, seed=7)
    nv = int(np.sum(np.asarray(valid)))
    q0 = jax.vmap(hdc.pack_bits)(q_bip)
    q1 = jax.vmap(hdc.pack_bits)(q_bip.at[:, :4].multiply(-1))

    state = pipeline.init_state(cfg, task_w)
    state, _, tel0 = step(state, im, q0, valid, boxes, jnp.int32(0), cfg,
                          plan=plan_a, fused=mode)
    assert (np.asarray(tel0.path)[:nv] == PATH_FULL).all()
    st_a, _, tel_a = step(state, im, q1, valid, boxes, jnp.int32(0), cfg,
                          plan=plan_a, fused=mode)
    assert (np.asarray(tel_a.path)[:nv] == PATH_DELTA).all()
    # delta-corrected accumulators are exact (== a fresh full scan would be)
    wmask_a = plan_word_mask(cfg, plan_a.banks, plan_a.planes)
    for n in range(nv):
        acc = np.asarray(aligner.full_dot(q1[n], im, wmask_a))
        slot = int(np.argwhere(
            (np.asarray(st_a.cache.packed) == np.asarray(q1[n])).all(-1)
        )[0, 0])
        assert np.array_equal(np.asarray(st_a.cache.acc[slot]), acc), n

    # plan switch: stale tag -> full re-scan, exact under plan B
    _, out_b, tel_b = step(st_a, im, q1, valid, boxes, jnp.int32(0), cfg,
                           plan=plan_b, fused=mode)
    assert (np.asarray(tel_b.path)[:nv] == PATH_FULL).all()
    wmask_b = plan_word_mask(cfg, plan_b.banks, plan_b.planes)
    d_eff_b = int(cfg.d_eff_planned(plan_b.banks, plan_b.planes))
    for n in range(nv):
        if bool(tel_b.reasoner_active[n]):
            acc = aligner.full_dot(q1[n], im, wmask_b)
            want = acc.astype(jnp.float32) / d_eff_b * task_w
            assert np.array_equal(np.asarray(out_b.scores[n]),
                                  np.asarray(want)), n


@pytest.mark.parametrize("serial", [False, True])
def test_fused_multi_stream_bit_identical(serial):
    """Both batched lowerings (vmap -> hoisted prefix kernel, lax.map ->
    switch) are bit-identical to the oracle step under heterogeneous
    per-stream load (different Alg. 1 bank choices per slot)."""
    cfg = TorrConfig(D=1024, B=8, M=32, K=4, N_max=8, delta_budget=128,
                     feat_dim=64, fps_target=40000.0)
    im = random_item_memory(jax.random.PRNGKey(0), cfg)
    S = 4
    task_w = jax.random.uniform(jax.random.PRNGKey(1), (S, cfg.M))
    step = jax.jit(pipeline.torr_multi_stream_step,
                   static_argnames=("cfg", "serial", "plan", "fused"))
    q_bip = hdc.random_hv(jax.random.PRNGKey(2), (S, cfg.N_max, cfg.D))
    valid = jnp.asarray(np.arange(cfg.N_max) < 6)[None].repeat(S, 0)
    boxes = jnp.zeros((S, cfg.N_max, 4), jnp.float32)
    qd = jnp.asarray([0, 2, 8, 30], jnp.int32)   # forces banks 8/8/3/1

    res = {}
    for fused in ("off", None):
        st = pipeline.init_multi_stream_state(cfg, task_w)
        outs = []
        for t in range(3):
            q = jax.vmap(jax.vmap(hdc.pack_bits))(
                q_bip.at[:, :, t::97].multiply(-1) if t else q_bip)
            st, out, tel = step(st, im, q, valid, boxes, qd, cfg,
                                serial=serial, fused=fused)
            outs.append((out, tel))
        res[fused] = (st, outs)
    banks_seen = np.asarray(res[None][1][0][1].banks)
    assert len(set(banks_seen.tolist())) > 1, "want heterogeneous banks"
    for t in range(3):
        (o0, t0), (o1, t1) = res["off"][1][t], res[None][1][t]
        assert np.array_equal(np.asarray(o0.scores), np.asarray(o1.scores))
        for f in TELEM_CHECK:
            assert np.array_equal(np.asarray(getattr(t0, f)),
                                  np.asarray(getattr(t1, f))), (t, f)
    for a, b in zip(jax.tree_util.tree_leaves(res["off"][0].cache),
                    jax.tree_util.tree_leaves(res[None][0].cache)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


# --- governor dynamics ------------------------------------------------------

def test_ladder_shape_and_costs():
    ladder = build_ladder(CFG)
    assert ladder[0] == full_plan(CFG)
    rel = ladder_rel_cost(ladder, CFG)
    assert rel[0] == 1.0
    assert (np.diff(rel) < 0).all()          # strictly cheaper down the ladder
    for p in ladder:
        p.validate(CFG)


def test_governor_degrades_immediately_recovers_with_hysteresis():
    pol = GovernorPolicy(budget_s=1.0, slack_margin=0.0, recover_hold=3)
    gov = Governor(CFG, pol)
    deepest = len(gov.ladder) - 1

    # optimistic start: no measurement => full plan
    assert gov.update(slack_s=1.0, step_s=0.0).is_full and gov.level == 0
    # hopeless slack => immediate drop to the deepest level
    gov.update(slack_s=0.001, step_s=0.9)
    assert gov.level == deepest
    # ample slack: recovery is held back, then climbs ONE level at a time
    for _ in range(pol.recover_hold - 1):
        gov.update(slack_s=1.0, step_s=0.001)
        assert gov.level == deepest
    gov.update(slack_s=1.0, step_s=0.001)
    assert gov.level == deepest - 1
    assert gov.switches == 2

    # backlog shrinks effective slack: deep backlog forces a deeper level
    lvl = gov.level
    gov.update(slack_s=1.0, step_s=0.9, backlog=10)
    assert gov.level > lvl


def test_energy_governor_caps_level():
    pol = GovernorPolicy(budget_s=1 / 60, slack_margin=0.0, recover_hold=1,
                         energy_budget_mj=50.0)
    gov = Governor(CFG, pol)
    # plentiful slack, but the EWMA energy is far over budget: the energy
    # governor must keep the plan off the full level
    gov.observe_energy(120.0)
    gov.update(slack_s=10.0, step_s=1e-6)
    assert gov.level > 0
    # and with energy back under budget, slack rules again
    gov.energy_ewma_mj = 10.0
    for _ in range(len(gov.ladder)):
        gov.update(slack_s=10.0, step_s=1e-6)
    assert gov.level == 0


def test_plan_level_is_pure():
    pol = GovernorPolicy(budget_s=1.0, slack_margin=0.0, recover_hold=2)
    rel = np.array([1.0, 0.5, 0.25])
    a = plan_level(0.3, 0, 0.4, 0, 0, rel, pol)
    b = plan_level(0.3, 0, 0.4, 0, 0, rel, pol)
    assert a == b == (1, 0)                  # level 1 fits (0.2 <= 0.3)
    # nothing fits => deepest
    assert plan_level(0.01, 0, 1.0, 0, 0, rel, pol)[0] == 2


# --- engine integration -----------------------------------------------------

def test_async_engine_governor_pinned_full_bit_identical():
    """Acceptance: governor pinned to the full plan => engine outputs are
    bit-identical to the ungoverned async engine."""
    from repro.serving.async_engine import AsyncStreamEngine
    from repro.serving.deadline import DeadlinePolicy, DeadlineTracker
    from test_multistream import TELEM_FIELDS, _make_inputs

    cfg = CFG
    S, T = 3, 4
    im = random_item_memory(jax.random.PRNGKey(0), cfg)
    task_w = np.asarray(jax.random.uniform(jax.random.PRNGKey(1), (S, cfg.M)))
    steps = _make_inputs(cfg, S, T)
    pol = DeadlinePolicy(budget_s=1e6, escalate_margin_s=1e6)  # never fires

    def run(eng):
        futs = {s: [] for s in range(S)}
        for s in range(S):
            eng.admit(s, task_w[s])
            for q, v, b, _qd in steps:
                futs[s].append(eng.submit(s, q[s], v[s], b[s]))
        eng.start()
        eng.flush(timeout=120)
        return {s: [f.result(timeout=10) for f in futs[s]] for s in range(S)}

    with AsyncStreamEngine(cfg, im, n_slots=S, paused=True) as eng0:
        base = run(eng0)
    gov = Governor(cfg, GovernorPolicy(budget_s=1e6),
                   ladder=(full_plan(cfg),))
    with AsyncStreamEngine(cfg, im, n_slots=S, paused=True,
                           tracker=DeadlineTracker(pol),
                           governor=gov) as eng1:
        gvd = run(eng1)
    assert gov.level == 0 and sum(gov.windows_by_level) == S * T
    for s in range(S):
        for t in range(T):
            (o0, t0), (o1, t1) = base[s][t], gvd[s][t]
            assert np.array_equal(o0.scores, o1.scores), (s, t)
            assert np.array_equal(o0.best, o1.best), (s, t)
            for f in TELEM_FIELDS + ("planes",):
                assert np.array_equal(np.asarray(getattr(t0, f)),
                                      np.asarray(getattr(t1, f))), (s, t, f)


def test_table8_governor_beats_static_on_the_ramp():
    """Acceptance (ISSUE 3): under table8's load ramp the governor meets
    the RT-60 budget where the static-banks baseline misses deadlines, at
    lower modeled energy than always-full-D'."""
    from benchmarks.table8_pareto import simulate

    full = simulate("RT-60", "full", n_frames=150)
    static = simulate("RT-60", "static", n_frames=150)
    gov = simulate("RT-60", "governor", n_frames=150)
    assert static["miss_rate"] > 0.2          # the ramp breaks the static knob
    assert gov["miss_rate"] == 0.0            # the closed loop holds RT-60
    assert gov["energy_mj"] < full["energy_mj"]
    assert gov["planes_mean"] < CFG.bit_planes  # precision gating engaged


def test_async_engine_governor_degrades_under_pressure():
    """A hopeless RT budget (shedding disabled) drives the governor to the
    deepest plan; served windows record the reduced (banks, planes)."""
    from repro.serving.async_engine import AsyncStreamEngine
    from repro.serving.deadline import DeadlinePolicy, DeadlineTracker
    from test_multistream import _make_inputs

    cfg = CFG
    S, T = 2, 5
    im = random_item_memory(jax.random.PRNGKey(0), cfg)
    task_w = np.asarray(jax.random.uniform(jax.random.PRNGKey(1), (S, cfg.M)))
    steps = _make_inputs(cfg, S, T)
    pol = DeadlinePolicy(budget_s=1e-9, escalate_margin_s=1e-9,
                         allow_shed=False)
    gov = Governor(cfg, GovernorPolicy(budget_s=1e-9, recover_hold=10**6))

    with AsyncStreamEngine(cfg, im, n_slots=S, paused=True,
                           tracker=DeadlineTracker(pol),
                           governor=gov) as eng:
        futs = []
        for s in range(S):
            eng.admit(s, task_w[s])
            for q, v, b, _qd in steps:
                futs.append(eng.submit(s, q[s], v[s], b[s]))
        eng.start()
        eng.flush(timeout=120)
        tels = [f.result(timeout=10)[1] for f in futs]

    deepest = gov.ladder[-1]
    assert gov.level == len(gov.ladder) - 1
    assert gov.switches >= 1
    assert gov.energy_ewma_mj > 0.0
    # at least one window actually ran the deepest plan's knobs
    planes_run = {(int(t.banks), int(t.planes)) for t in tels}
    assert (deepest.banks, deepest.planes) in planes_run
    summary = eng.governor_summary()
    assert summary["windows_by_level"][-1] > 0
