"""Async serving runtime == synchronous StreamEngine, plus RT admission.

The tentpole invariant (ISSUE 2): with admission control off, the
dispatch/collect ``AsyncStreamEngine`` produces bit-identical outputs and
telemetry to the synchronous ``StreamEngine`` for the same submission order
— on one device and (subprocess) on N fake devices with the stream axis
sharded. Deadline integration: shed windows fail their futures with
``WindowShed``; escalated windows are served with the load gate forced high.

Every ``future.result``/``flush`` call here carries a timeout so a
deadlocked dispatcher fails the test fast instead of hanging the suite.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

import jax

from repro.core import pipeline
from repro.core.item_memory import random_item_memory
from repro.serving.async_engine import AsyncStreamEngine
from repro.serving.deadline import (DeadlinePolicy, DeadlineTracker,
                                    WindowShed)
from repro.serving.stream_engine import StreamEngine

from test_multistream import CFG, TELEM_FIELDS, _make_inputs

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
FLUSH_S = 120  # generous CI margin; a deadlock fails in minutes, not hours


def _submit_all(eng, task_w, steps, S):
    """Admit S streams and enqueue every window; returns per-stream futures."""
    futs = {s: [] for s in range(S)}
    for s in range(S):
        eng.admit(f"cam{s}", task_w[s])
        for q, valid, boxes, _qd in steps:
            futs[s].append(eng.submit(f"cam{s}", q[s], valid[s], boxes[s]))
    return futs


@pytest.mark.parametrize("S", [1, 4, 16])
def test_async_matches_sync_bitwise(S):
    """Same submission order => identical batches => bit-identical results."""
    cfg = CFG
    T = 4
    im = random_item_memory(jax.random.PRNGKey(0), cfg)
    task_w = np.asarray(jax.random.uniform(jax.random.PRNGKey(1), (S, cfg.M)))
    steps = _make_inputs(cfg, S, T)

    sync = StreamEngine(cfg, im, n_slots=S)
    for s in range(S):
        sync.admit(f"cam{s}", task_w[s])
        for q, valid, boxes, _qd in steps:
            sync.submit(f"cam{s}", q[s], valid[s], boxes[s])
    res_sync = sync.drain()

    # paused: the dispatcher sees the full backlog, reproducing the sync
    # drain schedule (and its queue-depth trace) exactly
    with AsyncStreamEngine(cfg, im, n_slots=S, paused=True) as eng:
        futs = _submit_all(eng, task_w, steps, S)
        eng.start()
        eng.flush(timeout=FLUSH_S)
        for s in range(S):
            for t, fut in enumerate(futs[s]):
                aout, atel = fut.result(timeout=10)
                sout, stel = res_sync[f"cam{s}"][t]
                assert np.array_equal(aout.scores, np.asarray(sout.scores))
                assert np.array_equal(aout.best, np.asarray(sout.best))
                assert np.array_equal(aout.boxes, np.asarray(sout.boxes))
                for f in TELEM_FIELDS + ("queue_depth", "high_load"):
                    assert np.array_equal(
                        np.asarray(getattr(atel, f)),
                        np.asarray(getattr(stel, f))), (s, t, f)
    assert eng.stats.windows == S * T


def test_async_matches_sync_live_submission():
    """Un-paused engine (windows race the dispatcher): per-stream outputs
    still match a sequential replay fed the same queue-depth trace."""
    cfg = CFG
    S, T = 3, 5
    im = random_item_memory(jax.random.PRNGKey(0), cfg)
    task_w = np.asarray(jax.random.uniform(jax.random.PRNGKey(1), (S, cfg.M)))
    steps = _make_inputs(cfg, S, T)

    with AsyncStreamEngine(cfg, im, n_slots=S) as eng:
        futs = _submit_all(eng, task_w, steps, S)
        eng.flush(timeout=FLUSH_S)
        results = {s: [f.result(timeout=10) for f in futs[s]]
                   for s in range(S)}

    # replay each stream alone, feeding the queue depths the engine saw
    sstep = jax.jit(pipeline.torr_window_step, static_argnames="cfg")
    import jax.numpy as jnp
    for s in range(S):
        st = pipeline.init_state(cfg, jnp.asarray(task_w[s]))
        for t, (q, valid, boxes, _qd) in enumerate(steps):
            aout, atel = results[s][t]
            st, out, _tel = sstep(st, im, jnp.asarray(q[s]),
                                  jnp.asarray(valid[s]), jnp.asarray(boxes[s]),
                                  jnp.asarray(atel.queue_depth), cfg)
            assert np.array_equal(aout.scores, np.asarray(out.scores)), (s, t)


def test_async_sharded_matches_sync_on_fake_devices():
    """4 host-platform devices: slot padding + stream-axis sharding is
    bit-exact vs the single-device sync engine (subprocess: the forked
    runtime must see XLA_FLAGS before jax initializes)."""
    code = """
import numpy as np, jax
assert jax.device_count() == 4, jax.devices()
from repro.core.item_memory import random_item_memory
from repro.runtime import sharding as shd
from repro.serving.async_engine import AsyncStreamEngine
from repro.serving.stream_engine import StreamEngine
from tests.test_multistream import CFG, _make_inputs

S, T = 6, 3   # 6 slots pad to 8 over 4 devices
im = random_item_memory(jax.random.PRNGKey(0), CFG)
task_w = np.asarray(jax.random.uniform(jax.random.PRNGKey(1), (S, CFG.M)))
steps = _make_inputs(CFG, S, T)

sync = StreamEngine(CFG, im, n_slots=S)
for s in range(S):
    sync.admit(s, task_w[s])
    for q, v, b, _qd in steps:
        sync.submit(s, q[s], v[s], b[s])
res = sync.drain()

eng = AsyncStreamEngine(CFG, im, n_slots=S, mesh=shd.stream_mesh(),
                        paused=True)
assert eng.n_slots == 8, eng.n_slots
futs = {s: [] for s in range(S)}
for s in range(S):
    eng.admit(s, task_w[s])
    for q, v, b, _qd in steps:
        futs[s].append(eng.submit(s, q[s], v[s], b[s]))
eng.start()
eng.flush(timeout=300)
for s in range(S):
    for t, f in enumerate(futs[s]):
        aout, atel = f.result(timeout=10)
        sout, stel = res[s][t]
        assert np.array_equal(aout.scores, np.asarray(sout.scores)), (s, t)
        assert np.array_equal(np.asarray(atel.path),
                              np.asarray(stel.path)), (s, t)
eng.close()
print("SHARDED-MATCH")
"""
    env = dict(os.environ,
               PYTHONPATH=SRC + os.pathsep + os.path.dirname(SRC),
               XLA_FLAGS="--xla_force_host_platform_device_count=4")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "SHARDED-MATCH" in out.stdout


def test_deadline_shed_fails_futures():
    """An impossible budget sheds every window with WindowShed; nothing is
    dispatched to the device."""
    cfg = CFG
    S, T = 2, 3
    im = random_item_memory(jax.random.PRNGKey(0), cfg)
    task_w = np.asarray(jax.random.uniform(jax.random.PRNGKey(1), (S, cfg.M)))
    steps = _make_inputs(cfg, S, T)
    pol = DeadlinePolicy(budget_s=1e-12, escalate_margin_s=1e-12)

    with AsyncStreamEngine(cfg, im, n_slots=S, paused=True,
                           tracker=DeadlineTracker(pol)) as eng:
        futs = _submit_all(eng, task_w, steps, S)
        eng.start()
        eng.flush(timeout=FLUSH_S)
        for s in range(S):
            for fut in futs[s]:
                with pytest.raises(WindowShed):
                    fut.result(timeout=10)
    assert eng.stats.shed == S * T
    assert eng.stats.windows == 0
    assert eng.tracker.shed == S * T
    assert eng.deadline_summary()["n_windows"] == 0


def test_deadline_escalate_forces_load_gate():
    """allow_shed=False turns hopeless lateness into bypass escalation: the
    served window's telemetry shows queue_depth >= q_hi and H(N, q) high."""
    cfg = CFG
    S, T = 2, 3
    im = random_item_memory(jax.random.PRNGKey(0), cfg)
    task_w = np.asarray(jax.random.uniform(jax.random.PRNGKey(1), (S, cfg.M)))
    steps = _make_inputs(cfg, S, T)
    pol = DeadlinePolicy(budget_s=1e-12, escalate_margin_s=1e-12,
                         allow_shed=False)

    with AsyncStreamEngine(cfg, im, n_slots=S, paused=True,
                           tracker=DeadlineTracker(pol)) as eng:
        futs = _submit_all(eng, task_w, steps, S)
        eng.start()
        eng.flush(timeout=FLUSH_S)
        for s in range(S):
            for fut in futs[s]:
                _out, tel = fut.result(timeout=10)
                assert int(tel.queue_depth) >= cfg.q_hi
                assert bool(tel.high_load)
    assert eng.stats.windows == S * T
    assert eng.tracker.escalated == S * T
    summary = eng.deadline_summary()
    assert summary["completed"] == S * T
    assert summary["miss_rate"] == 1.0  # everything blew the 1ps budget


def test_retire_cancels_backlog_and_readmits_clean():
    """retire() drops the un-popped backlog (cancelling futures); the slot
    re-admits with an empty queue and a cold cache."""
    cfg = CFG
    im = random_item_memory(jax.random.PRNGKey(0), cfg)
    task_w = np.zeros((cfg.M,), np.float32)
    steps = _make_inputs(cfg, 1, 3)

    with AsyncStreamEngine(cfg, im, n_slots=1, paused=True) as eng:
        futs = []
        eng.admit("a", task_w)
        for q, v, b, _qd in steps:
            futs.append(eng.submit("a", q[0], v[0], b[0]))
        eng.retire("a")          # engine paused: nothing was dispatched
        assert all(f.cancelled() for f in futs)
        assert eng.stats.dropped == 3

        eng.start()
        eng.admit("b", task_w)   # recycled slot must be clean
        fut = eng.submit("b", *[a[0] for a in steps[0][:3]])
        out, tel = fut.result(timeout=FLUSH_S)
        # cold cache: every valid proposal takes the full path
        valid = steps[0][1][0]
        assert (np.asarray(tel.path)[valid] == 2).all()
        eng.flush(timeout=FLUSH_S)
    assert eng.stats.windows == 1


def test_future_callbacks_may_reenter_engine():
    """Done-callbacks fire without the engine lock held: a callback that
    calls back into the engine (here backlog()) must not deadlock the
    dispatcher — for shed futures and for cancelled ones alike."""
    cfg = CFG
    im = random_item_memory(jax.random.PRNGKey(0), cfg)
    task_w = np.zeros((cfg.M,), np.float32)
    steps = _make_inputs(cfg, 1, 2)
    pol = DeadlinePolicy(budget_s=1e-12, escalate_margin_s=1e-12)
    reentered = []

    with AsyncStreamEngine(cfg, im, n_slots=1, paused=True,
                           tracker=DeadlineTracker(pol)) as eng:
        eng.admit("a", task_w)
        for q, v, b, _qd in steps:
            fut = eng.submit("a", q[0], v[0], b[0])
            fut.add_done_callback(
                lambda _f: reentered.append(eng.backlog("a")))
        eng.start()
        eng.flush(timeout=FLUSH_S)   # deadlock here = regression
        assert len(reentered) == 2

    # retire()'s cancel path must be lock-free for callbacks too (paused:
    # the window is guaranteed still queued when retire cancels it)
    with AsyncStreamEngine(cfg, im, n_slots=1, paused=True) as eng:
        eng.admit("a", task_w)
        fut = eng.submit("a", steps[0][0][0], steps[0][1][0], steps[0][2][0])
        fut.add_done_callback(lambda _f: reentered.append(eng.stats.dropped))
        eng.retire("a")
        assert fut.cancelled() and len(reentered) == 3
        eng.start()   # context exit close() joins started threads


def test_worker_error_surfaces_on_flush():
    """A poisoned submission kills the dispatcher; flush and later submits
    raise instead of deadlocking, and queued futures are failed."""
    cfg = CFG
    im = random_item_memory(jax.random.PRNGKey(0), cfg)
    steps = _make_inputs(cfg, 1, 1)
    q, v, b, _qd = steps[0]

    eng = AsyncStreamEngine(cfg, im, n_slots=1, paused=True)
    eng.admit("a", np.zeros((cfg.M,), np.float32))
    fut = eng.submit("a", q[0], v[0], b[0])
    # poison the queue directly: wrong-shaped window arrays (un-broadcastable)
    bad = eng.submit("a", q[0][:, :4], v[0], b[0])
    eng.start()
    with pytest.raises(RuntimeError, match="worker died"):
        eng.flush(timeout=FLUSH_S)
    with pytest.raises(Exception):
        bad.result(timeout=10)
    del fut
    with pytest.raises(RuntimeError, match="worker died"):
        eng.close()              # drain re-raises, but threads are released
    assert not eng._dispatcher.is_alive() and not eng._collector.is_alive()
