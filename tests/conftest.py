import os
import sys

# keep tests on 1 CPU device; multi-device tests spawn subprocesses
os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# repo root, so tests can import the benchmarks package (e.g. table8's
# governor Pareto sim is acceptance-tested in test_control.py)
sys.path.insert(1, os.path.join(os.path.dirname(__file__), ".."))


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: long-running test (deselect with -m 'not slow' for tier-1 CI)",
    )
