"""Query-cache path invariants (paper Fig. 4 / Eq. 6 / Sec. 4.6).

Three contracts the reuse paths must honor:
  * delta: the delta-corrected accumulator equals a full recompute whenever
    the true flip count fits the budget (Eq. 6 exactness);
  * LRU: ``lru_slot`` prefers invalid slots, then evicts the least-recent;
  * bypass: a bypass hit returns the cached scores bit-identically.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aligner, hdc, pipeline, query_cache
from repro.core.item_memory import build_item_memory, word_mask
from repro.core.types import PATH_BYPASS, PATH_FULL, TorrConfig

CFG = TorrConfig(D=1024, B=8, M=32, K=4, N_max=8, delta_budget=128,
                 feat_dim=64)


def _entry_kwargs(cfg, key, banks=8):
    from repro.core.types import plan_tag
    q = hdc.pack_bits(hdc.random_hv(key, (cfg.D,)))
    return dict(
        packed=q, acc=jnp.zeros((cfg.M,), jnp.int32),
        acc_tag=plan_tag(banks, cfg.bit_planes),
        out=jnp.zeros((cfg.M,), jnp.float32),
        topk_key=jnp.zeros((cfg.top_k,), jnp.int32), margin=jnp.float32(0),
    )


@pytest.mark.parametrize("n_flips", [0, 1, 50, 128])
def test_delta_correct_equals_full_recompute(n_flips):
    """acc_old + Eq.6 corrections == full_dot(q_new) when |Delta| <= budget."""
    cfg = CFG
    im = build_item_memory(hdc.random_hv(jax.random.PRNGKey(0), (cfg.M, cfg.D)))
    wmask = word_mask(cfg, cfg.B)
    q_old = hdc.random_hv(jax.random.PRNGKey(1), (cfg.D,))
    flips = jax.random.choice(jax.random.PRNGKey(2), cfg.D, (max(n_flips, 1),),
                              replace=False)[:n_flips]
    q_new = q_old.at[flips].multiply(-1) if n_flips else q_old

    acc_old = aligner.full_dot(hdc.pack_bits(q_old), im, wmask)
    idx, w, cnt = aligner.delta_indices(
        hdc.pack_bits(q_new), hdc.pack_bits(q_old), wmask,
        cfg.delta_budget, cfg.D)
    assert int(cnt) == n_flips
    assert int(cnt) <= cfg.delta_budget
    acc_new = aligner.delta_correct(acc_old, im, idx, w)
    want = aligner.full_dot(hdc.pack_bits(q_new), im, wmask)
    assert (np.asarray(acc_new) == np.asarray(want)).all()


def test_lru_slot_prefers_invalid_then_oldest():
    cfg = CFG
    cache = query_cache.init_cache(cfg)
    # empty cache: any slot works; convention is the first
    assert int(query_cache.lru_slot(cache)) == 0
    for i in range(cfg.K):
        cache = query_cache.write_entry(
            cache, jnp.int32(i), **_entry_kwargs(cfg, jax.random.PRNGKey(i)))
        if i + 1 < cfg.K:
            # a still-invalid slot must win over any valid one
            assert int(query_cache.lru_slot(cache)) == i + 1
    # all valid: slot 0 is now the least recently written
    assert int(query_cache.lru_slot(cache)) == 0
    # touching slot 0 (bypass hit) rejuvenates it; slot 1 becomes LRU
    cache = query_cache.touch(cache, jnp.int32(0))
    assert int(query_cache.lru_slot(cache)) == 1
    # rewriting slot 1 moves LRU on to slot 2
    cache = query_cache.write_entry(
        cache, jnp.int32(1), **_entry_kwargs(cfg, jax.random.PRNGKey(99)))
    assert int(query_cache.lru_slot(cache)) == 2


def test_bypass_returns_cached_scores_bit_identical():
    """Second window with the identical query under high load must take the
    bypass path and emit the exact cached scores."""
    cfg = CFG
    im = build_item_memory(hdc.random_hv(jax.random.PRNGKey(0), (cfg.M, cfg.D)))
    task_w = jax.random.uniform(jax.random.PRNGKey(1), (cfg.M,))
    state = pipeline.init_state(cfg, task_w)
    step = jax.jit(pipeline.torr_window_step, static_argnames="cfg")

    q = jax.vmap(hdc.pack_bits)(
        hdc.random_hv(jax.random.PRNGKey(2), (cfg.N_max, cfg.D)))
    valid = jnp.zeros((cfg.N_max,), bool).at[0].set(True)
    boxes = jnp.zeros((cfg.N_max, 4), jnp.float32)
    qd = jnp.asarray(cfg.q_hi, jnp.int32)  # high load => bypass eligible

    state, out1, tel1 = step(state, im, q, valid, boxes, qd, cfg)
    assert int(tel1.path[0]) == PATH_FULL  # cold cache
    state, out2, tel2 = step(state, im, q, valid, boxes, qd, cfg)
    assert int(tel2.path[0]) == PATH_BYPASS  # rho = 1 >= tau_byp, high load
    assert np.array_equal(np.asarray(out2.scores[0]), np.asarray(out1.scores[0]))
    assert not bool(tel2.reasoner_active[0])  # bypass skips the reasoner


def test_reset_slot_invalidates_one_stream():
    cfg = CFG
    batch = query_cache.init_cache_batch(cfg, 3)
    batch = dataclasses.replace(batch, valid=batch.valid.at[1].set(True))
    assert bool(batch.valid[1].all())
    batch = query_cache.reset_slot(batch, cfg, 1)
    assert not bool(batch.valid[1].any())
    assert batch.packed.shape == (3, cfg.K, cfg.words)
