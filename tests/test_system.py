"""End-to-end behaviour tests: the paper's system claims, executed."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.types import TorrConfig
from repro.data import tood_synth as ts
from repro.perf.cycle_model import window_cost
from repro.serving.tood_pipelines import build_system, evaluate_task, run_torr


@pytest.fixture(scope="module")
def world_and_system():
    world = ts.make_world(0, M=32, d=128, n_tasks=5)
    cfg = TorrConfig(D=2048, B=8, M=32, K=24, N_max=16, delta_budget=512,
                     feat_dim=128)
    return world, build_system(world, cfg)


def test_reuse_is_accuracy_neutral(world_and_system):
    """TorR with caching ~= naive HDC without (the paper's core claim)."""
    world, sys_ = world_and_system
    r = evaluate_task(world, sys_, 3, n_frames=30, difficulty=0.8)
    assert abs(r["ap_torr"] - r["ap_naive_hdc"]) < 8.0
    reuse = r["path_mix"]["bypass"] + r["path_mix"]["delta"]
    assert reuse > 0.2, f"no reuse achieved: {r['path_mix']}"


def test_bounded_margin_to_dense(world_and_system):
    world, sys_ = world_and_system
    aps = [evaluate_task(world, sys_, t, n_frames=25, difficulty=0.8)
           for t in range(5)]
    dense = np.mean([a["ap_dense"] for a in aps])
    torr = np.mean([a["ap_torr"] for a in aps])
    assert torr > 0.5 * dense, (torr, dense)


def test_coherent_scenes_reuse_more(world_and_system):
    world, sys_ = world_and_system
    calm = evaluate_task(world, sys_, 3, n_frames=30, difficulty=0.8)   # breakfast
    busy = evaluate_task(world, sys_, 1, n_frames=30, difficulty=0.8)   # sports
    calm_reuse = calm["path_mix"]["bypass"] + calm["path_mix"]["delta"]
    busy_reuse = busy["path_mix"]["bypass"] + busy["path_mix"]["delta"]
    assert calm_reuse > busy_reuse


def test_reuse_cuts_modeled_traffic(world_and_system):
    """Telemetry -> cycle model: reuse reduces cycles vs all-full."""
    world, sys_ = world_and_system
    cfg = sys_.cfg
    frames = ts.simulate_sequence(world, 3, 25, seed=0, difficulty=0.8,
                                  n_max=cfg.N_max)
    _, telems = run_torr(sys_, frames, 3)
    budget = 1 / 60
    actual = sum(window_cost(t.path, t.delta_count, int(t.banks),
                             t.reasoner_active, int(t.n_valid), cfg,
                             budget).cycles["aligner"] for t in telems)
    allfull = sum(window_cost(np.full(int(t.n_valid), 2),
                              np.zeros(int(t.n_valid), int), int(t.banks),
                              np.ones(int(t.n_valid), bool), int(t.n_valid),
                              cfg, budget).cycles["aligner"] for t in telems)
    # encoder/host overheads are path-independent; the aligner traffic is
    # what reuse saves (paper Sec. 4.7)
    assert actual < 0.6 * allfull, (actual, allfull)


def test_training_loop_learns():
    """The launcher's loop reduces loss on a tiny model (integration)."""
    import subprocess
    import sys
    import os
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "gemma-7b",
         "--smoke", "--steps", "40", "--batch", "8", "--seq", "64",
         "--ckpt", "/tmp/test_sys_ck"],
        env=dict(os.environ, PYTHONPATH="src"),
        capture_output=True, text=True, timeout=1200,
        cwd=os.path.join(os.path.dirname(__file__), ".."))
    assert out.returncode == 0, out.stderr[-2000:]
    assert "loss improved" in out.stdout


def test_serving_loop_generates():
    import subprocess
    import sys
    import os
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch",
         "musicgen-large", "--smoke", "--batch", "2", "--prompt-len", "16",
         "--gen", "8"],
        env=dict(os.environ, PYTHONPATH="src"),
        capture_output=True, text=True, timeout=1200,
        cwd=os.path.join(os.path.dirname(__file__), ".."))
    assert out.returncode == 0, out.stderr[-2000:]
    assert "generated shape (2, 8, 4)" in out.stdout
