"""GPipe pipeline over the 'pod' axis: forward + autodiff-backward exactness."""
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


@pytest.mark.slow
def test_pipeline_matches_sequential_and_grads():
    code = """
import jax, jax.numpy as jnp, numpy as np
from repro.runtime.pipeline_parallel import pipeline_apply, split_stages

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
L, d = 4, 16
key = jax.random.PRNGKey(0)
W = jax.random.normal(key, (L, d, d)) * 0.3

def layer(w, x):
    return jnp.tanh(x @ w)

def stage_fn(params, x):
    def body(h, w):
        return layer(w, h), None
    h, _ = jax.lax.scan(body, x, params)
    return h

def sequential(W, xs):
    def full(x):
        h = x
        for i in range(L):
            h = layer(W[i], h)
        return h
    return jax.vmap(full)(xs)

n_micro, mb = 4, 2
xs = jax.random.normal(jax.random.PRNGKey(1), (n_micro, mb, d))
Wst = split_stages(W, 2)

out_pipe = pipeline_apply(stage_fn, Wst, xs, mesh, "pod")
out_seq = sequential(W, xs)
np.testing.assert_allclose(np.asarray(out_pipe), np.asarray(out_seq),
                           atol=1e-5)
print("FWD_OK")

# gradient through the pipeline == sequential gradient
def loss_pipe(W):
    return jnp.sum(pipeline_apply(stage_fn, split_stages(W, 2), xs, mesh,
                                  "pod") ** 2)
def loss_seq(W):
    return jnp.sum(sequential(W, xs) ** 2)
g_pipe = jax.grad(loss_pipe)(W)
g_seq = jax.grad(loss_seq)(W)
np.testing.assert_allclose(np.asarray(g_pipe), np.asarray(g_seq),
                           atol=1e-4, rtol=1e-4)
print("GRAD_OK")
"""
    env = dict(os.environ, PYTHONPATH=SRC,
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=1200)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "FWD_OK" in out.stdout and "GRAD_OK" in out.stdout
