"""Optional-`hypothesis` shim for the property tests.

The seed suite uses hypothesis for property-based tests, but the package is
not part of the runtime environment. When hypothesis is installed the real
``given`` / ``settings`` / ``st`` are re-exported unchanged; when it is
absent this module provides a tiny deterministic fallback: each strategy
knows how to draw an example from a seeded ``random.Random``, and ``given``
unrolls the test body over ``max_examples`` drawn tuples. The fallback keeps
the same decorator stacking order the tests already use::

    @given(st.integers(0, 2**31 - 1), st.sampled_from([32, 64]))
    @settings(max_examples=20, deadline=None)
    def test_something(seed, D): ...

Only the strategy constructors the suite needs are implemented
(``integers``, ``sampled_from``, ``floats``, ``booleans``); extend here if a
new test needs more.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings  # noqa: F401
    from hypothesis import strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import random
    import zlib

    HAVE_HYPOTHESIS = False

    class _Strategy:
        """A draw function plus nothing else — enough for `given`."""

        def __init__(self, draw):
            self._draw = draw

        def example(self, rnd: random.Random):
            return self._draw(rnd)

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rnd: rnd.randint(min_value, max_value))

        @staticmethod
        def sampled_from(elements):
            elems = list(elements)
            return _Strategy(lambda rnd: rnd.choice(elems))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda rnd: rnd.uniform(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda rnd: rnd.random() < 0.5)

    st = _Strategies()

    def settings(**kwargs):
        """Record settings on the function; `given` reads max_examples."""

        def deco(fn):
            fn._compat_settings = dict(kwargs)
            return fn

        return deco

    def given(*strategies):
        """Unroll the test over deterministically drawn example tuples."""

        def deco(fn):
            n = getattr(fn, "_compat_settings", {}).get("max_examples", 10)

            def wrapper(*args, **kwargs):
                seed = zlib.crc32(fn.__qualname__.encode())
                rnd = random.Random(seed)
                for _ in range(n):
                    drawn = tuple(s.example(rnd) for s in strategies)
                    fn(*args, *drawn, **kwargs)

            # NOT functools.wraps: pytest follows __wrapped__ to the original
            # signature and would demand fixtures for the drawn parameters.
            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = fn.__qualname__
            wrapper.__doc__ = fn.__doc__
            if hasattr(fn, "pytestmark"):  # marks applied below @given
                wrapper.pytestmark = fn.pytestmark
            return wrapper

        return deco
