"""Cycle model + HLO analyzer + roofline invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.types import PATH_BYPASS, PATH_DELTA, PATH_FULL, TorrConfig
from repro.perf import hlo_analyze, roofline
from repro.perf.cycle_model import (AREA, POWER_W, TASK_PROFILES,
                                    simulate_all, simulate_task, window_cost)


def test_table1_totals():
    logic = [k for k in AREA if "memory" not in k and "caches" not in k]
    assert abs(sum(AREA[k] for k in logic) - 5.937) < 0.005
    assert abs(sum(POWER_W[k] for k in logic) * 1e3 - 4659.84) < 0.5


def test_delta_cheaper_than_full_bypass_cheapest():
    cfg = TorrConfig(D=8192, B=8, M=1024, W=64, N_max=16, delta_budget=1024)
    n = 8
    budget = 1 / 60
    full = window_cost(np.full(n, PATH_FULL), np.zeros(n, int), 8,
                       np.ones(n, bool), n, cfg, budget)
    delta = window_cost(np.full(n, PATH_DELTA), np.full(n, 512), 8,
                        np.ones(n, bool), n, cfg, budget)
    byp = window_cost(np.full(n, PATH_BYPASS), np.zeros(n, int), 8,
                      np.zeros(n, bool), n, cfg, budget)
    assert byp.total_cycles < delta.total_cycles < full.total_cycles
    assert byp.power_w < delta.power_w < full.power_w


def test_bank_gating_reduces_cost():
    cfg = TorrConfig(D=8192, B=8, M=1024, W=64, N_max=16)
    n = 8
    budget = 1 / 60
    c8 = window_cost(np.full(n, PATH_FULL), np.zeros(n, int), 8,
                     np.ones(n, bool), n, cfg, budget)
    c2 = window_cost(np.full(n, PATH_FULL), np.zeros(n, int), 2,
                     np.ones(n, bool), n, cfg, budget)
    assert c2.total_cycles < c8.total_cycles
    assert c2.power_w < c8.power_w


def test_rt_budget_compliance_all_tasks():
    for rt, fps in (("RT-60", 60), ("RT-30", 30)):
        for r in simulate_all(rt, n_frames=150):
            assert r["p95_ms"] < 1000.0 / fps, (rt, r["task"])


def test_coherent_tasks_are_cheaper():
    fast = simulate_task("have breakfast", "RT-60", 200)
    slow = simulate_task("sports", "RT-60", 200)
    assert fast["median_ms"] < slow["median_ms"]
    assert fast["energy_mj"] <= slow["energy_mj"]


# --- HLO analyzer -----------------------------------------------------------

def test_analyzer_trip_count_scaling():
    def f_scan(w, x):
        def body(h, wi):
            return jnp.tanh(h @ wi), None
        h, _ = jax.lax.scan(body, x, w)
        return h.sum()

    w = jnp.zeros((8, 64, 64))
    x = jnp.zeros((4, 64))
    c = jax.jit(f_scan).lower(w, x).compile()
    a = hlo_analyze.analyze_text(c.as_text())
    assert a.flops == pytest.approx(8 * 2 * 4 * 64 * 64, rel=0.01)


def test_analyzer_counts_unrolled_identically():
    def f_unroll(w, x):
        h = x
        for i in range(8):
            h = jnp.tanh(h @ w[i])
        return h.sum()

    w = jnp.zeros((8, 64, 64))
    x = jnp.zeros((4, 64))
    c = jax.jit(f_unroll).lower(w, x).compile()
    a = hlo_analyze.analyze_text(c.as_text())
    assert a.flops == pytest.approx(8 * 2 * 4 * 64 * 64, rel=0.01)


def test_fused_step_kills_nmw_intermediate():
    """Acceptance (ISSUE 4): the fused jitted multi-stream step contains no
    [S, M, W]-shaped xor intermediate anywhere in its HLO; the legacy
    oracle step does (the A/B proves the assertion has teeth). Dims are
    chosen pairwise-distinct so shape matching cannot alias."""
    from repro.core import pipeline
    from repro.core.item_memory import random_item_memory

    cfg = TorrConfig(D=2048, B=8, M=48, K=4, N_max=8, delta_budget=128,
                     feat_dim=64)
    im = random_item_memory(jax.random.PRNGKey(0), cfg)
    S = 4
    st = pipeline.init_multi_stream_state(cfg, jnp.zeros((S, cfg.M)))
    args = (st, im,
            jnp.zeros((S, cfg.N_max, cfg.words), jnp.uint32),
            jnp.ones((S, cfg.N_max), bool),
            jnp.zeros((S, cfg.N_max, 4), jnp.float32),
            jnp.zeros((S,), jnp.int32))
    step = jax.jit(pipeline.torr_multi_stream_step,
                   static_argnames=("cfg", "serial", "plan", "fused"))

    def hlo(fused):
        return step.lower(*args, cfg, serial=False,
                          fused=fused).compile().as_text()

    smw = (S, cfg.M, cfg.words)
    assert hlo_analyze.has_materialized_shape(hlo("off"), smw, "u32")
    for fused in ("prefix", "switch"):
        text = hlo(fused)
        assert not hlo_analyze.has_materialized_shape(text, smw, "u32"), fused
        # nor the flattened-batch variant [S*N, M, W]
        assert not hlo_analyze.has_materialized_shape(
            text, (S * cfg.N_max, cfg.M, cfg.words), "u32"), fused


def test_fused_step_bytes_scale_with_plan():
    """Acceptance (ISSUE 4): HBM bytes read by the fused jitted step scale
    *down* with the (banks, planes) plan — reduced plans genuinely read
    proportionally less (static slices), not masked-same."""
    from repro.control.plan import KnobPlan
    from repro.core import pipeline
    from repro.core.item_memory import random_item_memory

    cfg = TorrConfig(D=2048, B=8, M=48, K=4, N_max=8, delta_budget=128,
                     feat_dim=64)
    im = random_item_memory(jax.random.PRNGKey(0), cfg)
    S = 4
    st = pipeline.init_multi_stream_state(cfg, jnp.zeros((S, cfg.M)))
    args = (st, im,
            jnp.zeros((S, cfg.N_max, cfg.words), jnp.uint32),
            jnp.ones((S, cfg.N_max), bool),
            jnp.zeros((S, cfg.N_max, 4), jnp.float32),
            jnp.zeros((S,), jnp.int32))
    step = jax.jit(pipeline.torr_multi_stream_step,
                   static_argnames=("cfg", "serial", "plan", "fused"))

    def traffic(banks, planes):
        plan = KnobPlan(banks=banks, planes=planes,
                        plane_total=cfg.bit_planes)
        text = step.lower(*args, cfg, serial=False, plan=plan,
                          fused="prefix").compile().as_text()
        return hlo_analyze.analyze_text(text).bytes_traffic

    ladder = [(8, 4), (8, 2), (4, 2), (2, 1)]
    measured = [traffic(b, p) for b, p in ladder]
    for hi, lo in zip(measured, measured[1:]):
        assert lo < hi, (ladder, measured)
    # the item-memory slice the kernel reads matches each plan's width:
    # 1/8 of the words enabled => the full-plan slice must shrink by more
    # than the per-plan kernel-input delta alone would if it were masked
    assert measured[-1] < measured[0]


def test_compact_step_bytes_scale_with_bucket_tier():
    """Acceptance (ISSUE 5): ``hlo_analyze.bytes_traffic`` of the compacted
    multi-stream executable decreases strictly with the bucket tier —
    smaller buckets genuinely move fewer bytes, they don't mask them."""
    from repro.core import pipeline

    from repro.core.item_memory import random_item_memory

    cfg = TorrConfig(D=2048, B=8, M=48, K=4, N_max=8, delta_budget=128,
                     feat_dim=64)
    im = random_item_memory(jax.random.PRNGKey(0), cfg)
    S = 4
    st = pipeline.init_multi_stream_state(cfg, jnp.zeros((S, cfg.M)))
    args = (st, im,
            jnp.zeros((S, cfg.N_max, cfg.words), jnp.uint32),
            jnp.ones((S, cfg.N_max), bool),
            jnp.zeros((S, cfg.N_max, 4), jnp.float32),
            jnp.zeros((S,), jnp.int32))
    step = jax.jit(pipeline.torr_multi_stream_step,
                   static_argnames=("cfg", "serial", "plan", "fused",
                                    "bucket_cap"))
    measured = [
        hlo_analyze.analyze_jit(step, *args, cfg, serial=False,
                                fused="compact", bucket_cap=tier)
        .bytes_traffic
        for tier in (32, 16, 8, 4)
    ]
    for hi, lo in zip(measured, measured[1:]):
        assert lo < hi, measured


def test_lowering_scan_rows_shrink_with_hit_rate():
    """The lowering-aware cycle model: under compact dispatch the modeled
    window cycles shrink as the hit rate rises (the bucket tier tracks the
    miss count); the always-hoisted prefix lowering stays flat; an
    overflowed bucket degrades to the all-rows fallback."""
    from repro.perf.cycle_model import lowering_scan_rows

    n_valid = 64
    for n_full, tier in ((64, 64), (16, 16), (4, 4), (1, 1)):
        assert lowering_scan_rows(n_full, n_valid, "compact") == tier
    assert lowering_scan_rows(3, n_valid, "compact") == 4      # ladder pad
    assert lowering_scan_rows(16, n_valid, "prefix") == n_valid
    assert lowering_scan_rows(16, n_valid, "switch") == 16
    # latched tier: used when it holds, all-rows fallback when it overflows
    assert lowering_scan_rows(5, n_valid, "compact", bucket_cap=8) == 8
    assert lowering_scan_rows(9, n_valid, "compact", bucket_cap=8) == n_valid

    cfg = TorrConfig(D=8192, B=8, M=1024, W=64, N_max=64, delta_budget=1024)
    budget = 1 / 60

    def scan_cycles(n_full, fused):
        path = np.concatenate([np.full(n_full, PATH_FULL),
                               np.full(64 - n_full, PATH_BYPASS)])
        return window_cost(path, np.zeros(64, int), 8, np.ones(64, bool),
                           64, cfg, budget, fused=fused).cycles["aligner"]

    compact = [scan_cycles(n, "compact") for n in (64, 16, 4)]
    prefix = [scan_cycles(n, "prefix") for n in (64, 16, 4)]
    assert compact[0] > compact[1] > compact[2]
    assert prefix[0] == prefix[1] == prefix[2]
    assert compact[-1] < prefix[-1]


def test_shape_bytes_parsing():
    assert hlo_analyze._shape_elems_bytes("bf16[8,128]{1,0}") == (1024, 2048)
    assert hlo_analyze._shape_elems_bytes("(f32[4], s8[8])") == (12, 24)
    assert hlo_analyze._shape_elems_bytes("pred[]") == (1, 1)


def test_roofline_terms_and_bottleneck():
    r = roofline.Roofline(
        arch="a", shape="s", mesh="m", chips=256,
        flops_global=197e12 * 256,          # exactly 1s of compute
        bytes_global=819e9 * 256 * 2,       # 2s of memory
        coll_bytes_global=50e9 * 256 * 0.5, # 0.5s of collectives
        coll_breakdown={}, model_flops=197e12 * 256 * 0.5,
        memory_per_device={})
    assert r.t_compute == pytest.approx(1.0)
    assert r.t_memory == pytest.approx(2.0)
    assert r.t_collective == pytest.approx(0.5)
    assert r.bottleneck == "memory"
    assert r.roofline_frac == pytest.approx(0.25)   # 0.5s ideal / 2s bound


def test_model_flops_modes():
    from repro.configs import get
    cfg = get("deepseek-7b")
    n = cfg.param_count()
    train = roofline.model_flops_for(cfg, dict(mode="train", seq_len=128,
                                               global_batch=4))
    dec = roofline.model_flops_for(cfg, dict(mode="decode", seq_len=128,
                                             global_batch=4))
    assert train == pytest.approx(6 * n * 512)
    assert dec == pytest.approx(2 * n * 4)
