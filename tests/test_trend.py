"""Perf-trend gate + histogram quantile estimator.

Pins the ISSUE 8 regression-gate semantics end to end: the quantile
estimator `benchmarks/trend.py` and `table7_async` derive p99s through
(linear interpolation, overflow-bucket clamp, nan on empty/missing), the
artifact metric extraction (suite-keyed and single-suite shapes, string
rows and latency rows excluded), the rolling median baseline with
backend isolation, and the CLI's `--check` exit codes on an injected
15% regression fixture vs a healthy run.
"""
import json
import math

import pytest

from repro.obs.metrics import MetricsRegistry, quantile, snapshot_quantile

import benchmarks.trend as trend


# --- quantile estimator ------------------------------------------------------


def _series(edges, observations):
    reg = MetricsRegistry()
    h = reg.histogram("torr_test_seconds", "h", buckets=tuple(edges))
    for v in observations:
        h.observe(v)
    return reg.snapshot()["torr_test_seconds"]["series"][0]


def test_quantile_uniform_interpolation():
    # 10 samples spread over one [0, 10] bucket: rank interpolates linearly
    s = _series([10.0], [5.0] * 10)
    assert quantile(s, 0.5) == pytest.approx(5.0)
    assert quantile(s, 0.0) == pytest.approx(0.0)
    assert quantile(s, 1.0) == pytest.approx(10.0)


def test_quantile_known_distribution():
    # 2 in (0,1], 6 in (1,2], 2 in (2,4]
    s = _series([1.0, 2.0, 4.0], [0.5, 0.5, 1.5] * 1 + [1.5] * 5 + [3.0] * 2)
    # p50: rank 5 of 10 -> 3 into the 6-count (1,2] bucket
    assert quantile(s, 0.5) == pytest.approx(1.5)
    assert quantile(s, 0.2) == pytest.approx(1.0)        # exactly at an edge
    assert quantile(s, 0.9) == pytest.approx(3.0)


def test_quantile_overflow_bucket_clamps():
    # half the mass beyond the last finite edge: p99 must clamp to the
    # edge, never invent values past what the buckets bound
    s = _series([1.0], [0.5] * 5 + [100.0] * 5)
    assert quantile(s, 0.99) == pytest.approx(1.0)
    assert quantile(s, 0.4) == pytest.approx(0.8)


def test_quantile_edge_cases():
    s = _series([1.0, 2.0], [])
    assert math.isnan(quantile(s, 0.5))                  # empty series
    with pytest.raises(ValueError):
        quantile(s, 1.5)
    with pytest.raises(ValueError):
        quantile(s, -0.1)


def test_snapshot_quantile_lookup():
    reg = MetricsRegistry()
    h = reg.histogram("torr_lat_seconds", "h", buckets=(1.0, 2.0),
                      labelnames=["k"])
    h.labels(k="a").observe(0.5)
    h.labels(k="a").observe(1.5)
    snap = reg.snapshot()
    assert snapshot_quantile(snap, "torr_lat_seconds", 0.5,
                             labels={"k": "a"}) == pytest.approx(1.0)
    # missing family / series / non-histogram -> nan, never a crash
    assert math.isnan(snapshot_quantile(snap, "torr_absent", 0.5))
    assert math.isnan(snapshot_quantile(snap, "torr_lat_seconds", 0.5,
                                        labels={"k": "zzz"}))
    reg.counter("torr_c_total").inc()
    assert math.isnan(snapshot_quantile(reg.snapshot(), "torr_c_total", 0.5))


# --- metric extraction -------------------------------------------------------


def _doc(wps=500.0, backend="cpu"):
    return {
        "meta": {"sha": "abc123", "timestamp": "2026-08-08T00:00:00+00:00",
                 "backend": backend},
        "table7": {"rows": [
            ["table7/async_S16", wps, "speedup=2.0"],
            ["table7/sync_S16", wps / 2.0, "speedup=1.00"],
            ["table7/step_latency_p99_ms", 12.0, "async dispatch->ready"],
            ["table7/_suite_seconds", 33.0, "ok"],
        ], "seconds": 33.0, "ok": True},
        "table6": {"rows": [
            ["table6/vmap_S4", 100.0, "x"],
            ["table6/winner_S4", "vmap", "x"],               # string row
        ], "seconds": 5.0, "ok": True},
        "table5": {"rows": [["table5/ap", 0.9, "x"]]},        # not gated
    }


def test_extract_metrics_suite_keyed():
    m = trend.extract_metrics(_doc())
    assert m == {"table7/async_S16": 500.0, "table7/sync_S16": 250.0,
                 "table6/vmap_S4": 100.0}


def test_extract_metrics_single_suite_shape():
    m = trend.extract_metrics({"rows": [["table7/async_S4", 42.0, ""]]})
    assert m == {"table7/async_S4": 42.0}


def test_extract_metrics_excludes_latency_and_garbage():
    m = trend.extract_metrics({"rows": [
        ["table7/p99_jitter_ms", 3.0, ""],      # lower-is-better: excluded
        ["table7/step_latency_p50_ms", 1.0, ""],
        ["table7/flag", True, ""],              # bool is not a throughput
        ["table7/zero", 0.0, ""],               # non-positive
        [123, 4.0, ""],                         # non-str name
        ["table7/ok", 7.5, ""],
    ]})
    assert m == {"table7/ok": 7.5}


# --- rolling baseline + gate -------------------------------------------------


def _history(values, backend="cpu"):
    return {"format": trend.TREND_FORMAT, "entries": [
        {"sha": f"s{i}", "timestamp": "", "backend": backend,
         "metrics": {"table7/async_S16": v}} for i, v in enumerate(values)]}


def test_baseline_is_rolling_median_per_backend():
    hist = _history([100.0, 900.0, 600.0, 580.0, 620.0, 640.0, 610.0])
    # last 5: [600, 580, 620, 640, 610] -> median 610; the old outliers
    # (100, 900) have rolled out of the window
    assert trend.baseline_for(hist, "cpu", "table7/async_S16") == 610.0
    assert trend.baseline_for(hist, "tpu", "table7/async_S16") is None
    assert trend.baseline_for(hist, "cpu", "table7/other") is None
    assert trend.baseline_for(hist, "cpu", "table7/async_S16",
                              baseline_runs=2) == 625.0


def test_check_entry_flags_15pct_regression_not_6pct():
    hist = _history([600.0] * 5)
    bad = trend.make_entry(_doc(wps=510.0))              # -15%
    (reg,) = trend.check_entry(hist, bad)
    assert reg["metric"] == "table7/async_S16"
    assert reg["drop"] == pytest.approx(0.15)
    ok = trend.make_entry(_doc(wps=564.0))               # -6%
    assert trend.check_entry(hist, ok) == []
    # fresh metrics (sync_S16, vmap_S4 have no history) never gate
    assert {r["metric"] for r in trend.check_entry(hist, bad)} == {
        "table7/async_S16"}


def test_check_entry_backend_isolation():
    hist = _history([600.0] * 5, backend="tpu")
    # same 15% drop, but the history is all-TPU and the run is CPU
    assert trend.check_entry(hist, trend.make_entry(_doc(wps=510.0))) == []


def test_make_entry_provenance():
    e = trend.make_entry(_doc())
    assert e["sha"] == "abc123" and e["backend"] == "cpu"
    assert e["metrics"]["table7/async_S16"] == 500.0
    assert trend.make_entry({"rows": []}) == {
        "sha": "unknown", "timestamp": "", "backend": "unknown",
        "metrics": {}}


# --- CLI ---------------------------------------------------------------------


def _write(tmp_path, name, doc):
    p = tmp_path / name
    p.write_text(json.dumps(doc))
    return str(p)


def test_cli_check_fails_on_injected_regression(tmp_path, capsys):
    tpath = str(tmp_path / "trend.json")
    trend.save_trend(_history([600.0] * 5), tpath)
    bad = _write(tmp_path, "bad.json", _doc(wps=510.0))  # injected -15%
    assert trend.main([bad, "--trend", tpath, "--check",
                       "--no-append"]) == 1
    out = capsys.readouterr()
    assert "REGRESSION table7/async_S16" in out.out
    assert "FAILED" in out.err
    # --no-append left the history untouched
    assert len(trend.load_trend(tpath)["entries"]) == 5


def test_cli_check_passes_and_appends_healthy_run(tmp_path):
    tpath = str(tmp_path / "trend.json")
    trend.save_trend(_history([600.0] * 5), tpath)
    good = _write(tmp_path, "good.json", _doc(wps=590.0))
    assert trend.main([good, "--trend", tpath, "--check"]) == 0
    hist = trend.load_trend(tpath)
    assert len(hist["entries"]) == 6
    assert hist["entries"][-1]["sha"] == "abc123"
    # without --check a regression warns but exits 0
    bad = _write(tmp_path, "bad.json", _doc(wps=400.0))
    assert trend.main([bad, "--trend", tpath]) == 0


def test_cli_fresh_history_and_unknown_format(tmp_path):
    tpath = str(tmp_path / "new_trend.json")
    art = _write(tmp_path, "a.json", _doc())
    assert trend.main([art, "--trend", tpath, "--check"]) == 0
    assert len(trend.load_trend(tpath)["entries"]) == 1
    (tmp_path / "corrupt.json").write_text('{"format": "nope"}')
    with pytest.raises(ValueError, match="unknown trend format"):
        trend.load_trend(str(tmp_path / "corrupt.json"))


def test_repo_trend_file_is_valid():
    """The committed BENCH_trend.json must always load."""
    hist = trend.load_trend(trend.DEFAULT_TREND_PATH)
    assert hist["format"] == trend.TREND_FORMAT
    assert isinstance(hist["entries"], list)
