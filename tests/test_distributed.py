"""Sharding rules + multi-device plumbing (subprocess: needs >1 device)."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke
from repro.models import transformer as tf
from repro.runtime import sharding as shd

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run_subprocess(code: str) -> str:
    env = dict(os.environ,
               PYTHONPATH=SRC,
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=1200)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_param_specs_respect_divisibility():
    from jax.sharding import PartitionSpec as P
    cfg = get_smoke("xlstm-1.3b")
    params = jax.eval_shape(lambda k: tf.init_params(k, cfg),
                            jax.random.PRNGKey(0))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    specs = shd.params_pspecs(params, mesh)
    # every sharded dim divides
    flat, _ = jax.tree_util.tree_flatten_with_path(
        jax.tree.map(lambda s: s, specs))
    leaves, _ = jax.tree_util.tree_flatten(params)
    for (path, spec), leaf in zip(flat, leaves):
        for dim, ax in enumerate(spec):
            if ax is not None:
                size = mesh.shape[ax] if isinstance(ax, str) else \
                    int(jnp.prod(jnp.array([mesh.shape[a] for a in ax])))
                assert leaf.shape[dim] % size == 0


@pytest.mark.slow
def test_lower_and_run_on_2x4_mesh():
    code = """
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_smoke
from repro.runtime import steps, sharding as shd
from repro.models import transformer as tf
from repro.optim import adamw
from repro.data.tokens import TokenStream

mesh = jax.make_mesh((2, 4), ("data", "model"))
cfg = get_smoke("qwen3-14b")
lowered, _ = steps.lower_cell(cfg, dict(seq_len=64, global_batch=4, mode="train"), mesh)
compiled = lowered.compile()

# actually execute one step on the 8 fake devices
params = tf.init_params(jax.random.PRNGKey(0), cfg)
params = jax.device_put(params, shd.params_sharding(params, mesh))
opt = adamw.init_opt_state(params)
opt = jax.device_put(opt, shd.params_sharding(opt, mesh))
batch = {k: jnp.asarray(v) for k, v in TokenStream(cfg, 4, 64).batch_at(0).items()}
batch = jax.device_put(batch, shd.batch_sharding(batch, mesh))
step = jax.jit(steps.make_train_step(cfg, adamw.OptimConfig()))
p2, o2, m = step(params, opt, batch)
assert np.isfinite(float(m["loss"]))
print("MESH_STEP_OK", float(m["loss"]))
"""
    out = _run_subprocess(code)
    assert "MESH_STEP_OK" in out


@pytest.mark.slow
def test_multipod_mesh_and_compressed_grads():
    code = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.optim import grad_compress as gc
from repro.optim.adamw import OptimConfig, init_opt_state, apply_updates

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
# compressed psum on the pod axis inside shard_map
def loss_fn(params, batch):
    x, y = batch
    return jnp.mean((x @ params["w"] - y) ** 2), {}

ocfg = OptimConfig(lr=5e-2, warmup_steps=0, total_steps=100, weight_decay=0.0)
def local_step(params, err, opt_state, batch):
    (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
    grads, err = gc.tree_compressed_psum(grads, err, "pod")
    grads = jax.tree.map(lambda g: g / jax.lax.psum(1, "pod"), grads)
    params, opt_state, _ = apply_updates(params, grads, opt_state, ocfg)
    return params, err, opt_state, jax.lax.pmean(loss, "pod")

step = shard_map(local_step, mesh=mesh,
                 in_specs=(P(), P(), P(), P("pod")),
                 out_specs=(P(), P(), P(), P()), check_rep=False)
W = jax.random.normal(jax.random.PRNGKey(0), (4, 2))
p = {"w": jnp.zeros((4, 2))}
err = gc.init_error_state(p); opt = init_opt_state(p)
for i in range(60):
    x = jax.random.normal(jax.random.PRNGKey(i), (8, 4))
    p, err, opt, l = step(p, err, opt, (x, x @ W))
assert float(l) < 0.5, float(l)
print("POD_COMPRESS_OK", float(l))
"""
    out = _run_subprocess(code)
    assert "POD_COMPRESS_OK" in out


@pytest.mark.slow
def test_elastic_restore_across_meshes():
    code = """
import jax, jax.numpy as jnp, numpy as np, tempfile
from repro.checkpoint.manager import CheckpointManager
from repro.runtime import sharding as shd
from repro.configs import get_smoke
from repro.models import transformer as tf

cfg = get_smoke("gemma-7b")
params = tf.init_params(jax.random.PRNGKey(0), cfg)
mesh_a = jax.make_mesh((4, 2), ("data", "model"))
mesh_b = jax.make_mesh((2, 2), ("data", "model"))  # "after losing a pod"
pa = jax.device_put(params, shd.params_sharding(params, mesh_a))
d = tempfile.mkdtemp()
cm = CheckpointManager(d)
cm.save(7, pa)
pb, step = cm.restore(params, shardings=shd.params_sharding(params, mesh_b))
assert step == 7
ref = jax.tree.leaves(params)[0]
got = jax.tree.leaves(pb)[0]
np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))
print("ELASTIC_OK")
"""
    out = _run_subprocess(code)
    assert "ELASTIC_OK" in out


def test_cache_specs_cover_all_families():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    for name in ("deepseek-v3-671b", "recurrentgemma-2b", "xlstm-1.3b",
                 "llama-3.2-vision-90b", "musicgen-large"):
        cfg = get_smoke(name)
        cache = jax.eval_shape(lambda cfg=cfg: tf.init_cache(cfg, 2, 32))
        specs = shd.cache_pspecs(cache, mesh)
        assert jax.tree_util.tree_structure(specs) == \
            jax.tree_util.tree_structure(cache)
