"""TorR window-step behaviour: the paper's Alg. 1 + Fig. 4 semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aligner, hdc, pipeline, policy
from repro.core.item_memory import random_item_memory, word_mask
from repro.core.types import (PATH_BYPASS, PATH_DELTA, PATH_FULL, TorrConfig)

CFG = TorrConfig(D=2048, B=8, M=32, K=6, N_max=4, delta_budget=512,
                 feat_dim=64)


@pytest.fixture(scope="module")
def setup():
    im = random_item_memory(jax.random.PRNGKey(0), CFG)
    task_w = jnp.ones((CFG.M,), jnp.float32)
    step = jax.jit(pipeline.torr_window_step, static_argnames="cfg")
    qs = hdc.random_hv(jax.random.PRNGKey(1), (CFG.N_max, CFG.D))
    return im, task_w, step, qs


def _run(step, state, im, q_bip, queue=0, valid=None):
    valid = jnp.ones((CFG.N_max,), bool) if valid is None else valid
    return step(state, im, hdc.pack_bits(q_bip), valid,
                jnp.zeros((CFG.N_max, 4)), jnp.int32(queue), CFG)


def test_cold_cache_full_then_delta_then_bypass(setup):
    im, task_w, step, qs = setup
    state = pipeline.init_state(CFG, task_w)
    state, out, tel = _run(step, state, im, qs)
    assert (np.asarray(tel.path) == PATH_FULL).all()
    # tiny drift -> delta
    qs2 = qs.at[:, ::97].multiply(-1)
    state, out2, tel2 = _run(step, state, im, qs2)
    assert (np.asarray(tel2.path) == PATH_DELTA).all()
    # high load + high similarity -> bypass
    state, out3, tel3 = _run(step, state, im, qs2, queue=CFG.q_hi)
    assert (np.asarray(tel3.path) == PATH_BYPASS).all()
    # bypass reuses cached outputs exactly
    np.testing.assert_array_equal(np.asarray(out3.scores),
                                  np.asarray(out2.scores))


def test_scene_cut_forces_full(setup):
    im, task_w, step, qs = setup
    state = pipeline.init_state(CFG, task_w)
    state, _, _ = _run(step, state, im, qs)
    fresh = hdc.random_hv(jax.random.PRNGKey(99), (CFG.N_max, CFG.D))
    _, _, tel = _run(step, state, im, fresh)
    assert (np.asarray(tel.path) == PATH_FULL).all()


def test_delta_path_is_exact(setup):
    """Scores after a delta window == scores of a from-scratch full scan."""
    im, task_w, step, qs = setup
    state = pipeline.init_state(CFG, task_w)
    state, _, _ = _run(step, state, im, qs)
    qs2 = qs.at[:, 5::61].multiply(-1)
    state, out, tel = _run(step, state, im, qs2)
    assert (np.asarray(tel.path) == PATH_DELTA).all()
    # fresh pipeline, same queries -> full path reference
    state_ref = pipeline.init_state(CFG, task_w)
    _, out_ref, tel_ref = _run(step, state_ref, im, qs2)
    assert (np.asarray(tel_ref.path) == PATH_FULL).all()
    np.testing.assert_allclose(np.asarray(out.scores),
                               np.asarray(out_ref.scores), atol=1e-5)


def test_padding_proposals_cost_nothing(setup):
    im, task_w, step, qs = setup
    state = pipeline.init_state(CFG, task_w)
    valid = jnp.array([True, True, False, False])
    _, out, tel = _run(step, state, im, qs, valid=valid)
    assert int(tel.n_valid) == 2
    assert (np.asarray(out.scores[2:]) == 0).all()
    assert (np.asarray(tel.delta_count[2:]) == 0).all()


def test_delta_budget_overflow_escalates_to_full(setup):
    im, task_w, step, qs = setup
    state = pipeline.init_state(CFG, task_w)
    state, _, _ = _run(step, state, im, qs)
    # flip more than delta_budget dims but keep rho above tau_q
    n_flip = CFG.delta_budget + 64          # 576 of 2048 -> rho = 0.4375...
    qs2 = qs.at[:, :n_flip].multiply(-1)
    rho = 1 - 2 * n_flip / CFG.D
    _, _, tel = _run(step, state, im, qs2)
    if rho >= CFG.tau_q:
        assert (np.asarray(tel.path) == PATH_FULL).all(), \
            "over-budget delta must escalate to full"


def test_policy_truth_table():
    cfg = CFG
    hi = jnp.array(True)
    lo = jnp.array(False)
    ok = jnp.array(True)
    # bypass requires BOTH rho>=tau_byp and high load
    assert int(policy.select_path(jnp.float32(0.99), jnp.int32(10), ok, hi, cfg)) == PATH_BYPASS
    assert int(policy.select_path(jnp.float32(0.99), jnp.int32(10), ok, lo, cfg)) == PATH_DELTA
    assert int(policy.select_path(jnp.float32(0.7), jnp.int32(10), ok, hi, cfg)) == PATH_DELTA
    assert int(policy.select_path(jnp.float32(0.1), jnp.int32(10), ok, hi, cfg)) == PATH_FULL
    # tag mismatch (D' changed) disables delta
    assert int(policy.select_path(jnp.float32(0.7), jnp.int32(10),
                                  jnp.array(False), lo, cfg)) == PATH_FULL


def test_bank_selection_monotone():
    cfg = CFG
    b_low = int(policy.select_banks(jnp.int32(1), jnp.int32(0), cfg))
    b_hi = int(policy.select_banks(jnp.int32(cfg.N_max), jnp.int32(8), cfg))
    assert 1 <= b_hi <= b_low <= cfg.B
