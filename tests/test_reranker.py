"""TorR HDC reranker as an LM serving layer."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import TorrConfig
from repro.serving import reranker as rr

CFG = TorrConfig(D=1024, B=8, M=64, K=8, N_max=4, feat_dim=32)


def test_bias_applied_and_state_updates():
    params, im = rr.init_reranker(jax.random.PRNGKey(0), CFG, d_model=32,
                                  vocab=100, alpha=1.0)
    state = rr.init_state(CFG, B=3)
    hidden = jax.random.normal(jax.random.PRNGKey(1), (3, 32))
    logits = jnp.zeros((3, 100))
    out, state2, tel = rr.rerank_step(params, state, im, hidden, logits, CFG)
    assert out.shape == (3, 100)
    assert float(jnp.max(jnp.abs(out))) > 0          # bias applied
    assert bool(jnp.all(state2.valid))
    assert not bool(jnp.any(tel["bypassed"]))         # cold state: no bypass


def test_identical_hidden_bypasses_and_reuses_scores():
    params, im = rr.init_reranker(jax.random.PRNGKey(0), CFG, d_model=32,
                                  vocab=CFG.M, alpha=1.0)  # identity map
    state = rr.init_state(CFG, B=2)
    hidden = jax.random.normal(jax.random.PRNGKey(1), (2, 32))
    logits = jnp.zeros((2, CFG.M))
    out1, state, tel1 = rr.rerank_step(params, state, im, hidden, logits, CFG)
    out2, state, tel2 = rr.rerank_step(params, state, im, hidden, logits, CFG)
    assert bool(jnp.all(tel2["bypassed"]))
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), rtol=1e-6)
    assert float(jnp.min(tel2["rho"])) == 1.0


def test_divergent_hidden_recomputes():
    params, im = rr.init_reranker(jax.random.PRNGKey(0), CFG, d_model=32,
                                  vocab=CFG.M, alpha=1.0)
    state = rr.init_state(CFG, B=2)
    h1 = jax.random.normal(jax.random.PRNGKey(1), (2, 32))
    h2 = jax.random.normal(jax.random.PRNGKey(2), (2, 32))
    logits = jnp.zeros((2, CFG.M))
    _, state, _ = rr.rerank_step(params, state, im, h1, logits, CFG)
    _, state, tel = rr.rerank_step(params, state, im, h2, logits, CFG)
    assert not bool(jnp.any(tel["bypassed"]))


def test_concept_map_projects_to_vocab():
    params, im = rr.init_reranker(jax.random.PRNGKey(0), CFG, d_model=32,
                                  vocab=5000, alpha=0.5)
    assert params.concept_map.shape == (CFG.M, 5000)
    state = rr.init_state(CFG, B=1)
    hidden = jax.random.normal(jax.random.PRNGKey(1), (1, 32))
    out, _, _ = rr.rerank_step(params, state, im, hidden,
                               jnp.zeros((1, 5000)), CFG)
    assert out.shape == (1, 5000)
